# Repro verification / tooling entry points.  `make verify` is the gate:
# tier-1 tests (ROADMAP.md) + the doc-link check (README/docs must not rot).

PY ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: verify test test-kernels test-serve test-chaos test-paged test-topology test-obs docs-check bench-kernels bench-kernels-smoke bench-serve bench-serve-smoke bench-chaos bench-chaos-smoke bench-methods bench-methods-smoke bench-obs bench-obs-smoke

verify: test docs-check bench-kernels-smoke bench-serve-smoke bench-chaos-smoke bench-methods-smoke bench-obs-smoke

test:
	$(PY) -m pytest -x -q

# kernel tier only (marker registered in pytest.ini): interpret-mode Pallas
# parity, custom-VJP grads, PackState/AttnSchedule machinery — the slice to
# re-run after touching src/repro/kernels or core/{pack,attn_sched}.py
test-kernels:
	$(PY) -m pytest -x -q -m kernels

# serving tier only: continuous-batching engine, per-slot decode, scheduler,
# sampler — the slice to re-run after touching src/repro/serving or the
# decode path (models/{attention,model}.py, launch/serve.py)
test-serve:
	$(PY) -m pytest -x -q -m serve

# paged-KV tier only: BlockPool allocator properties, paged-vs-contiguous
# engine equivalence, COW shared-prefix admission, pool leak accounting —
# re-run after touching serving/{block_pool,engine}.py or the paged cache
# helpers (models/attention.py pools, kernels/flash_attention.py paged path)
test-paged:
	$(PY) -m pytest -x -q -m paged

# topology tier only: mask-update invariants (cardinality, zero-init grows,
# Top-KAST A ⊆ B superset bounds, determinism), superset-gradient parity vs
# dense, methods_comparison telemetry smoke — re-run after touching
# core/{rigl,topology,pack}.py or the training-step dispatch plumbing
test-topology:
	$(PY) -m pytest -x -q -m topology

docs-check:
	$(PY) scripts/check_doc_links.py

bench-kernels:
	$(PY) -m benchmarks.kernel_bench

# same rows without overwriting the tracked BENCH_kernels.json — the
# accounting assertions (fused-epilogue pass removal, GQA fold, softcap and
# Pallas parity canaries) all still run, which is what `make verify` gates on
bench-kernels-smoke:
	$(PY) -m benchmarks.kernel_bench --out /tmp/BENCH_kernels_smoke.json

# full serving bench: engine vs lockstep on the Poisson staggered workload;
# regenerates BENCH_serve.json and FAILS under a 1.5x throughput speedup
bench-serve:
	$(PY) -m benchmarks.serve_bench

# tiny smoke of the same path for `make verify` (seconds; no speedup gate —
# fixed dispatch overheads dominate at this scale)
bench-serve-smoke:
	$(PY) -m benchmarks.serve_bench --smoke-bench --out /tmp/BENCH_serve_smoke.json

# fault-tolerance tier only: quarantine isolation, shedding/backpressure,
# pack-integrity and torn-checkpoint guards — re-run after touching the
# failure paths (serving/{engine,queue,faults}.py, checkpoint, train guard)
test-chaos:
	$(PY) -m pytest -x -q -m chaos

# chaos harness: fault-injected serving must degrade, never corrupt —
# regenerates BENCH_chaos.json and FAILS on any isolation/shedding
# invariant violation (the robustness analogue of bench-serve's gate)
bench-chaos:
	$(PY) -m benchmarks.chaos_bench

bench-chaos-smoke:
	$(PY) -m benchmarks.chaos_bench --smoke-bench --out /tmp/BENCH_chaos_smoke.json

# methods comparison (paper Fig 2-top-right) with per-method topology
# telemetry columns; regenerates BENCH_methods.json
bench-methods:
	$(PY) -m benchmarks.methods_comparison

# tiny run of the same path for `make verify` (2 mask updates per method;
# asserts nothing beyond finishing — the finiteness gate lives in
# tests/test_topology_invariants.py)
bench-methods-smoke:
	$(PY) -m benchmarks.methods_comparison --smoke-bench --out /tmp/BENCH_methods_smoke.json

# observability tier only: metrics/trace/export semantics, instrumented
# engine determinism, quarantine trace <-> injector correlation — re-run
# after touching src/repro/obs/ or the engine/train instrumentation hooks
test-obs:
	$(PY) -m pytest -x -q -m obs

# observability bench: instrumented-vs-bare engine throughput (FAILS above a
# 3% overhead), token identity, and the chaos-trace correlation invariants —
# regenerates BENCH_obs.json plus the Perfetto-loadable chaos trace
bench-obs:
	$(PY) -m benchmarks.obs_bench

bench-obs-smoke:
	$(PY) -m benchmarks.obs_bench --smoke-bench --out /tmp/BENCH_obs_smoke.json

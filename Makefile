# Repro verification / tooling entry points.  `make verify` is the gate:
# tier-1 tests (ROADMAP.md) + the doc-link check (README/docs must not rot).

PY ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: verify test test-kernels docs-check bench-kernels

verify: test docs-check

test:
	$(PY) -m pytest -x -q

# kernel tier only (marker registered in pytest.ini): interpret-mode Pallas
# parity, custom-VJP grads, PackState/AttnSchedule machinery — the slice to
# re-run after touching src/repro/kernels or core/{pack,attn_sched}.py
test-kernels:
	$(PY) -m pytest -x -q -m kernels

docs-check:
	$(PY) scripts/check_doc_links.py

bench-kernels:
	$(PY) -m benchmarks.kernel_bench

# Repro verification / tooling entry points.  `make verify` is the gate:
# tier-1 tests (ROADMAP.md) + the doc-link check (README/docs must not rot).

PY ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: verify test docs-check bench-kernels

verify: test docs-check

test:
	$(PY) -m pytest -x -q

docs-check:
	$(PY) scripts/check_doc_links.py

bench-kernels:
	$(PY) -m benchmarks.kernel_bench

"""Shared sparse-MLP trainer for the paper-figure benchmarks.

Student MLP trained on the planted sparse-teacher regression task
(repro.data.teacher): ground-truth sparse topology exists, so the relative
ordering of sparse-training methods (paper Fig 2) is probed directly.
All methods run at IDENTICAL step counts; FLOP costs come from
core.flops.method_train_flops so quality-vs-FLOPs plots match Appendix H.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    LayerSpec,
    SparseAlgo,
    TopologyTrace,
    UpdateSchedule,
    apply_masks,
    dense_to_sparse_grad,
    get_distribution,
    init_masks,
    rigl_update,
    snip_masks,
    topkast_backward_masks,
)
from repro.core.flops import DenseSpec, method_train_flops, model_fwd_flops, sparse_fwd_flops
from repro.core.pruning import PruningSchedule, prune_step
from repro.data import make_teacher, teacher_batch

D_IN, D_H, D_OUT = 32, 256, 16


def mlp_apply(params, x):
    h = jax.nn.relu(x @ params["w1"])
    return h @ params["w2"]


def mlp_loss(params, batch):
    x, y = batch
    pred = mlp_apply(params, x)
    return jnp.mean((pred - y) ** 2)


@dataclasses.dataclass
class Result:
    method: str
    sparsity: float
    final_loss: float
    train_flops_mult: float
    test_flops_mult: float
    masks: dict
    params: dict
    topology: dict = dataclasses.field(default_factory=dict)


def _init(key, dims=(D_IN, D_H, D_OUT)):
    k1, k2 = jax.random.split(key)
    return {
        "w1": jax.random.normal(k1, (dims[0], dims[1])) / np.sqrt(dims[0]),
        "w2": jax.random.normal(k2, (dims[1], dims[2])) / np.sqrt(dims[1]),
    }


def train_mlp(
    method: str = "rigl",
    sparsity: float = 0.9,
    steps: int = 400,
    delta_t: int = 25,
    alpha: float = 0.3,
    distribution: str = "erk",
    decay: str = "cosine",
    seed: int = 0,
    lr: float = 0.05,
    momentum: float = 0.9,
    teacher_sparsity: float = 0.9,
    dims=(D_IN, D_H, D_OUT),
    init_params=None,
    init_masks_override=None,
    batch: int = 256,
    backward_extra: float = 0.1,
) -> Result:
    key = jax.random.PRNGKey(seed)
    teacher = make_teacher(jax.random.PRNGKey(99), dims[0], 128, dims[2], teacher_sparsity)

    if method == "small_dense":
        # match ACTIVE param count with a narrower dense network
        total = dims[0] * dims[1] + dims[1] * dims[2]
        h = max(int(dims[1] * (1 - sparsity)), 2)
        dims = (dims[0], h, dims[2])
        sparsity_eff = 0.0
    else:
        sparsity_eff = sparsity if method != "dense" else 0.0

    params = _init(key, dims) if init_params is None else jax.tree_util.tree_map(jnp.asarray, init_params)
    specs = [LayerSpec("w1", (dims[0], dims[1])), LayerSpec("w2", (dims[1], dims[2]))]
    if sparsity_eff > 0 and method != "pruning":
        smap = get_distribution(distribution, specs, sparsity_eff, dense_first=False)
        masks = init_masks(jax.random.fold_in(key, 1), params, smap)
        if method == "snip":
            g = jax.grad(mlp_loss)(params, teacher_batch(teacher, 0, batch))
            masks = snip_masks(params, g, smap)
    else:
        masks = {"w1": jnp.ones(params["w1"].shape, bool), "w2": jnp.ones(params["w2"].shape, bool)}
    if init_masks_override is not None:
        masks = jax.tree_util.tree_map(jnp.asarray, init_masks_override)
    params = apply_masks(params, masks)
    mom = jax.tree_util.tree_map(jnp.zeros_like, params)
    dense_mom = jax.tree_util.tree_map(jnp.zeros_like, params)

    sched = UpdateSchedule(delta_t=delta_t, t_end=int(0.75 * steps), alpha=alpha, decay=decay)
    algo = SparseAlgo(
        method=method if method in ("rigl", "set", "snfs", "topkast") else "static",
        schedule=sched,
        backward_extra=backward_extra,
    )
    prune_sched = PruningSchedule(sparsity, begin_step=steps // 8, end_step=int(0.75 * steps), prune_every=delta_t)

    # Top-KAST trains on the backward superset B ⊇ A: the optimizer sees
    # gradients masked to B (exploration set B\A learns while contributing
    # zero forward FLOPs); every other method masks gradients to A itself.
    bwd_masks = None
    if method == "topkast":
        bwd_masks = topkast_backward_masks(
            params, masks, backward_extra, jax.random.fold_in(key, 2)
        )

    @jax.jit
    def step_fn(params, masks, grad_masks, mom, dense_mom, batch_):
        w_eff = apply_masks(params, masks)
        loss, g = jax.value_and_grad(mlp_loss)(w_eff, batch_)
        gs = dense_to_sparse_grad(g, grad_masks)
        mom2 = jax.tree_util.tree_map(lambda m, gg: momentum * m + gg, mom, gs)
        params2 = jax.tree_util.tree_map(lambda p, m: p - lr * m, params, mom2)
        dm2 = jax.tree_util.tree_map(lambda m, gg: momentum * m + gg, dense_mom, g)
        return params2, mom2, dm2, loss

    @jax.jit
    def update_fn(params, masks, bwd_masks, mom, dense_mom, t, batch_):
        w_eff = apply_masks(params, masks)
        g = jax.grad(mlp_loss)(w_eff, batch_)
        p2, m2, grown = rigl_update(
            params, masks, g, t, algo, jax.random.fold_in(key, t),
            dense_momentum=dense_mom, bwd_masks=bwd_masks,
        )
        mom2 = jax.tree_util.tree_map(
            lambda m, gr: jnp.where(gr, 0.0, m), mom, grown
        )
        return p2, m2, mom2

    @jax.jit
    def refresh_superset_fn(params, masks, bwd_masks, mom, t):
        b2 = topkast_backward_masks(
            params, masks, backward_extra, jax.random.fold_in(key, 2**20 + t)
        )
        # leavers B_old \ B_new fall out of the trainable set: zero their
        # weights and momentum so re-entry later starts from scratch.
        p2 = jax.tree_util.tree_map(
            lambda w, bo, bn: jnp.where(bo & ~bn, 0.0, w).astype(w.dtype),
            params, bwd_masks, b2,
        )
        mom2 = jax.tree_util.tree_map(
            lambda m, bn: jnp.where(bn, m, 0.0), mom, b2
        )
        return p2, b2, mom2

    topo_trace = TopologyTrace()
    grad_masks = bwd_masks if method == "topkast" else masks
    for t in range(steps):
        b = teacher_batch(teacher, t, batch)
        if (
            method in ("rigl", "set", "snfs", "topkast")
            and t > 0
            and t % delta_t == 0
            and t < sched.t_end
        ):
            prev = topo_trace.snapshot(masks)
            params, masks, mom = update_fn(params, masks, bwd_masks, mom, dense_mom, t, b)
            topo_trace.record(prev, masks, step=t)
            if method == "topkast":
                params, bwd_masks, mom = refresh_superset_fn(params, masks, bwd_masks, mom, t)
            grad_masks = bwd_masks if method == "topkast" else masks
        else:
            params, mom, dense_mom, _ = step_fn(params, masks, grad_masks, mom, dense_mom, b)
        if method == "pruning" and t % prune_sched.prune_every == 0 and t >= prune_sched.begin_step:
            prev = topo_trace.snapshot(masks)
            params, masks = prune_step(params, masks, t, prune_sched)
            topo_trace.record(prev, masks, step=t)
            grad_masks = masks

    # eval on held-out batches
    w_eff = apply_masks(params, masks)
    eval_loss = float(
        np.mean([float(mlp_loss(w_eff, teacher_batch(teacher, 10_000 + i, 512))) for i in range(4)])
    )

    layers = [DenseSpec("w1", dims[0], dims[1]), DenseSpec("w2", dims[1], dims[2])]
    base = [DenseSpec("w1", D_IN, D_H), DenseSpec("w2", D_H, D_OUT)]
    f_d = model_fwd_flops(base)
    nnz = {n: float(1.0 - jnp.mean(masks[n].astype(jnp.float32))) for n in masks}
    f_s = sparse_fwd_flops(layers, nnz)
    f_s_bwd = None
    if bwd_masks is not None:
        bwd_sp = {
            n: float(1.0 - jnp.mean(bwd_masks[n].astype(jnp.float32)))
            for n in bwd_masks
        }
        f_s_bwd = sparse_fwd_flops(layers, bwd_sp)
    # small_dense trains a narrower DENSE net: cost 3*f_small == "static" form
    m = method if method in (
        "dense", "static", "snip", "set", "snfs", "rigl", "pruning", "topkast"
    ) else "static"
    train_f = method_train_flops(m, f_d, f_s, delta_t=delta_t,
                                 pruning_schedule=prune_sched, total_steps=steps,
                                 f_sparse_bwd=f_s_bwd)
    return Result(
        method=method,
        sparsity=sparsity,
        final_loss=eval_loss,
        train_flops_mult=train_f / (3 * f_d),
        test_flops_mult=f_s / f_d,
        masks=jax.device_get(masks),
        params=jax.device_get(params),
        topology=topo_trace.summary(),
    )

"""Chaos harness: fault-injected serving must degrade, never corrupt.

  PYTHONPATH=src python -m benchmarks.chaos_bench            # writes BENCH_chaos.json
  PYTHONPATH=src python -m benchmarks.chaos_bench --smoke-bench --out /tmp/c.json

Two deterministic scenarios against the continuous-batching ServeEngine
(serving/engine.py), both driven by a VIRTUAL clock so every run replays
bit-identically:

  isolation   a reference fault-free run records every request's token
              stream; then the same workload runs with a seeded
              FaultInjector (serving/faults.py) poisoning random
              (step, slot) logits rows to NaN.
                * with max_retries=0: every poisoned request must land
                  FAILED, and every UNTOUCHED request's stream must be
                  bit-identical to the reference — quarantine is per-slot,
                  corruption does not leak through the shared cache/batch;
                * with retries: EVERY request (poisoned ones included) must
                  complete with the reference stream — sampling is a pure
                  function of (weights, prompt, seed), so a retry replays
                  the fault-free tokens exactly.
  shedding    a burst storm (serving/faults.py::burst_storm) of more
              requests than the pool can clear within their deadline, on a
              bounded queue: some must SHED (backpressure is real), some
              must complete, none may sit past its admission deadline, and
              the books must balance (done + shed == submitted + rejected).

The process EXITS NONZERO if any invariant is violated — this is the
robustness analogue of serve_bench's speedup gate.  Results land in
BENCH_chaos.json.  ``--smoke-bench`` shrinks the workload for make verify.
"""
from __future__ import annotations

import argparse
import json
import pathlib

from repro.configs import get_config
from repro.launch.serve import configure_kernel, init_serving_state
from repro.serving import FaultInjector, ServeEngine, Status, burst_storm


def _drain(engine, *, dt: float = 1.0, max_steps: int = 10_000) -> float:
    """Step under a virtual clock until the engine is idle; returns the
    final virtual time.  dt=1.0 per step makes deadline math exact in
    test-land: a ttl of K means 'admitted within K steps'."""
    now = 0.0
    steps = 0
    while len(engine.queue) or engine.active.any():
        engine.step(now)
        now += dt
        steps += 1
        if steps > max_steps:
            raise SystemExit("chaos_bench: engine failed to drain (livelock?)")
    return now


def _streams(engine) -> dict[int, list[int]]:
    return {
        r.rid: list(r.generated)
        for r in engine.queue.done
        if r.status is Status.DONE
    }


def run_isolation(cfg, params, masks, pack, *, capacity, max_len, n_requests,
                  n_faults, seed) -> dict:
    def fresh_reqs():
        return burst_storm(cfg, n_requests, prompt_len=8, max_new_tokens=8,
                           seed=seed)

    def run(faults=None, max_retries=0):
        engine = ServeEngine(cfg, params, capacity=capacity, max_len=max_len,
                             masks=masks, pack=pack, max_retries=max_retries,
                             faults=faults)
        for r in fresh_reqs():
            engine.submit(r)
        _drain(engine)
        return engine

    ref = _streams(run())

    violations = []

    # no-retry: poisoned requests FAIL, everyone else is bit-identical
    inj = FaultInjector(seed)
    pairs = inj.poison_random(n_faults, max_step=n_requests * 4,
                              capacity=capacity)
    eng = run(faults=inj, max_retries=0)
    got = _streams(eng)
    failed = {r.rid for r in eng.queue.done if r.status is Status.FAILED}
    if eng.n_quarantined != len(failed):
        violations.append(
            f"no-retry: {eng.n_quarantined} quarantines but {len(failed)} "
            "FAILED requests (each detection should be terminal here)"
        )
    for rid, toks in got.items():
        if toks != ref[rid]:
            violations.append(
                f"ISOLATION BROKEN: request {rid} completed but its stream "
                f"differs from the fault-free run ({toks} != {ref[rid]})"
            )
    if len(got) + len(failed) != n_requests:
        violations.append(
            f"no-retry books don't balance: {len(got)} done + {len(failed)} "
            f"failed != {n_requests} submitted"
        )

    # with retries: EVERYONE completes with the reference stream
    inj2 = FaultInjector(seed)
    inj2.poison_random(n_faults, max_step=n_requests * 4, capacity=capacity)
    eng2 = run(faults=inj2, max_retries=3)
    got2 = _streams(eng2)
    if len(got2) != n_requests:
        bad = [r.rid for r in eng2.queue.done if r.status is not Status.DONE]
        violations.append(
            f"retry: {len(got2)}/{n_requests} completed (non-DONE rids {bad})"
        )
    for rid, toks in got2.items():
        if toks != ref[rid]:
            violations.append(
                f"RETRY NOT EXACT: request {rid} retried but its stream "
                f"differs from the fault-free run"
            )

    return {
        "requests": n_requests,
        "planned_faults": len(pairs),
        "no_retry": {"done": len(got), "failed": len(failed),
                     "quarantined": eng.n_quarantined},
        "with_retry": {"done": len(got2), "quarantined": eng2.n_quarantined,
                       "retries": eng2.n_retries_total},
        "violations": violations,
    }


def run_shedding(cfg, params, masks, pack, *, capacity, max_len, n_requests,
                 seed) -> dict:
    # every request wants admission within `ttl` virtual seconds; the pool
    # can only clear ~capacity requests per (prompt 8 + gen 8) window, so a
    # storm of n >> capacity MUST shed the tail
    ttl = 10.0
    engine = ServeEngine(cfg, params, capacity=capacity, max_len=max_len,
                         masks=masks, pack=pack,
                         queue_limit=n_requests // 2, deadline=ttl)
    rejected = 0
    for r in burst_storm(cfg, n_requests, prompt_len=8, max_new_tokens=8,
                         seed=seed):
        if not engine.submit(r):
            rejected += 1
    _drain(engine)
    stats = engine.stats(0.0)

    violations = []
    done = [r for r in engine.queue.done if r.status is Status.DONE]
    shed = [r for r in engine.queue.done if r.status is Status.SHED]
    if rejected == 0:
        violations.append(
            f"backpressure never fired: queue_limit {n_requests // 2} "
            f"absorbed all {n_requests} burst submissions"
        )
    if not shed or not done:
        violations.append(
            f"expected BOTH sheds and completions under the storm, got "
            f"{len(shed)} shed / {len(done)} done"
        )
    if len(done) + len(shed) != n_requests:
        violations.append(
            f"books don't balance: {len(done)} done + {len(shed)} shed "
            f"!= {n_requests} submitted"
        )
    late = [r.rid for r in done
            if r.t_admitted is not None and r.t_admitted - r.arrival > ttl]
    if late:
        violations.append(
            f"deadline violated: rids {late} admitted past ttl={ttl}"
        )
    return {
        "requests": n_requests,
        "queue_limit": n_requests // 2,
        "ttl": ttl,
        "rejected_at_submit": rejected,
        "done": len(done),
        "shed": len(shed),
        "queue_wait_p95_s": stats["queue_wait_p95_s"],
        "violations": violations,
    }


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="h2o-danube-1.8b")
    p.add_argument("--capacity", type=int, default=3)
    p.add_argument("--requests", type=int, default=12)
    p.add_argument("--faults", type=int, default=3)
    p.add_argument("--max-len", type=int, default=32)
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--kernel", default=None,
                   choices=["dense", "masked", "block_sparse"])
    p.add_argument("--block", type=int, default=16)
    p.add_argument("--out", default="BENCH_chaos.json")
    p.add_argument("--smoke-bench", action="store_true",
                   help="tiny workload for make verify (seconds, not minutes)")
    args = p.parse_args()

    if args.smoke_bench:
        args.requests = min(args.requests, 6)
        args.faults = min(args.faults, 2)

    cfg = configure_kernel(
        get_config(args.arch, smoke=True), kernel=args.kernel, block=args.block
    )
    params, masks, pack = init_serving_state(cfg)

    iso = run_isolation(cfg, params, masks, pack, capacity=args.capacity,
                        max_len=args.max_len, n_requests=args.requests,
                        n_faults=args.faults, seed=args.seed)
    storm = run_shedding(cfg, params, masks, pack, capacity=args.capacity,
                         max_len=args.max_len, n_requests=args.requests * 2,
                         seed=args.seed)

    violations = iso["violations"] + storm["violations"]
    out = {
        "meta": {
            "arch": cfg.name,
            "kernel": cfg.sparse.kernel,
            "capacity": args.capacity,
            "seed": args.seed,
            "smoke_bench": bool(args.smoke_bench),
        },
        "isolation": iso,
        "shedding": storm,
        "ok": not violations,
    }
    pathlib.Path(args.out).write_text(json.dumps(out, indent=1))
    print(f"isolation: {iso['no_retry']['done']} done / "
          f"{iso['no_retry']['failed']} failed (no retry); "
          f"{iso['with_retry']['done']}/{iso['requests']} done with retries "
          f"({iso['with_retry']['retries']} retries)")
    print(f"shedding:  {storm['done']} done / {storm['shed']} shed / "
          f"{storm['rejected_at_submit']} rejected at submit "
          f"(queue wait p95 {storm['queue_wait_p95_s']:.1f}s, "
          f"ttl {storm['ttl']:.0f}s)")
    print(f"-> {args.out}")
    if violations:
        for v in violations:
            print(f"VIOLATION: {v}")
        raise SystemExit(f"chaos_bench: {len(violations)} invariant "
                         "violation(s) — see above")
    print("all chaos invariants hold")


if __name__ == "__main__":
    main()

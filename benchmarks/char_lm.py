"""Paper §4.2 / Fig 4-left: character-level LM with the paper's exact GRU
architecture (embed 128, GRU 512, readouts 256/128, byte vocab 256), RigL vs
SET vs Static vs Dense at 75% sparsity, Adam — on an offline byte corpus.
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    LayerSpec,
    SparseAlgo,
    UpdateSchedule,
    apply_masks,
    dense_to_sparse_grad,
    get_distribution,
    init_masks,
    rigl_update,
    tree_paths,
)
from repro.data import byte_corpus, text_batch
from repro.models.gru import gru_lm_init, gru_lm_apply
from repro.optim import OptConfig, apply_opt, init_opt, reset_new_connections


def _loss(params, batch):
    logits = gru_lm_apply(params, jnp.asarray(batch["tokens"]))
    tgt = jnp.asarray(batch["targets"])
    lse = jax.nn.logsumexp(logits, -1)
    picked = jnp.take_along_axis(logits, tgt[..., None], -1)[..., 0]
    return jnp.mean(lse - picked)


def _train(method, steps, sparsity=0.75, seed=0, batch=8, seq=96):
    key = jax.random.PRNGKey(seed)
    params, axes, flags = gru_lm_init(key)
    if method == "dense" or sparsity == 0:
        masks = jax.tree_util.tree_map(lambda p, f: jnp.ones(p.shape, bool) if f else None, params, flags)
    else:
        flat_p, flat_f = tree_paths(params), tree_paths(flags)
        specs = [LayerSpec(n, flat_p[n].shape) for n, f in flat_f.items() if f]
        smap = get_distribution("uniform", specs, sparsity, dense_first=False)
        masks = init_masks(jax.random.fold_in(key, 1), params, smap)
        params = apply_masks(params, masks)
    opt_cfg = OptConfig(kind="adam", weight_decay=5e-4, grad_clip=10.0)
    opt = init_opt(opt_cfg, params)
    # paper Appendix I: delta_t=100, alpha=0.1, update till the end (200k
    # steps). At the quick 600-step budget the recovery window between
    # updates must scale too: delta_t=steps/3, alpha=0.3 (fewer, larger
    # updates) — measured to preserve the paper's RigL-best ordering.
    dt = max(100, steps // 3)
    algo = SparseAlgo(
        method=method if method in ("rigl", "set", "snfs") else "static",
        schedule=UpdateSchedule(delta_t=dt, t_end=steps, alpha=0.3 if steps < 1000 else 0.1),
    )
    corpus = byte_corpus(".")

    @jax.jit
    def step_fn(params, masks, opt, batch_):
        w = apply_masks(params, masks)
        loss, g = jax.value_and_grad(_loss)(w, batch_)
        gs = dense_to_sparse_grad(g, masks)
        p2, opt2 = apply_opt(opt_cfg, gs, opt, params, 7e-4)
        return p2, opt2, loss

    @jax.jit
    def update_fn(params, masks, opt, t, batch_):
        w = apply_masks(params, masks)
        g = jax.grad(_loss)(w, batch_)
        p2, m2, grown = rigl_update(params, masks, g, t, algo, jax.random.fold_in(key, t))
        return p2, m2, reset_new_connections(opt, grown)

    for t in range(steps):
        b = text_batch(t, batch, seq, corpus=corpus)
        if method in ("rigl", "set") and t > 0 and t % dt == 0 and t < int(0.9 * steps):
            params, masks, opt = update_fn(params, masks, opt, t, b)
        else:
            params, opt, _ = step_fn(params, masks, opt, b)

    w = apply_masks(params, masks)
    vloss = np.mean([
        float(_loss(w, text_batch(i, 16, seq, corpus=corpus, split="valid")))
        for i in range(4)
    ])
    return vloss / np.log(2)  # bits per byte (paper reports bits)


def run(quick=True):
    steps = 600 if quick else 2000
    rows = []
    for m in ("dense", "static", "set", "rigl"):
        t0 = time.time()
        bits = _train(m, steps)
        rows.append({
            "name": f"char_lm/{m}",
            "us_per_call": (time.time() - t0) * 1e6 / steps,
            "derived": {"valid_bits_per_byte": round(float(bits), 4),
                        "sparsity": 0.0 if m == "dense" else 0.75},
        })
    return rows

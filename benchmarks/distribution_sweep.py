"""Paper Fig 5-left (+ Appendix C): uniform vs ER vs ERK."""
import time

from ._mlp import train_mlp


def run(quick=True):
    steps = 300 if quick else 1200
    rows = []
    for dist in ("uniform", "er", "erk"):
        for m in ("rigl", "set"):
            t0 = time.time()
            r = train_mlp(method=m, sparsity=0.9, steps=steps, distribution=dist)
            rows.append({
                "name": f"distribution/{m}_{dist}",
                "us_per_call": (time.time() - t0) * 1e6 / steps,
                "derived": {"final_loss": round(r.final_loss, 5),
                            "test_flops_mult": round(r.test_flops_mult, 4)},
            })
    return rows

"""Paper Fig 2-left / Table 4 FLOPs columns, reproduced analytically."""
import time

from repro.core.flops import resnet50_flop_multipliers

PAPER = {  # (sparsity, dist) -> {method: (train, test)} from Fig 2-left/Table 4
    (0.8, "uniform"): {"rigl": (0.23, 0.23), "static": (0.23, 0.23), "snfs": (None, None)},
    (0.9, "uniform"): {"rigl": (0.10, 0.10)},
    (0.8, "erk"): {"rigl": (0.42, 0.42)},
    (0.9, "erk"): {"rigl": (0.25, 0.24)},
    (0.95, "uniform"): {"rigl": (0.23 * 0.35, 0.08)},  # Table 4: 0.08x test
    (0.965, "uniform"): {"rigl": (None, 0.07)},
}


def run(quick=True):
    rows = []
    t0 = time.time()
    for (s, dist), methods in PAPER.items():
        ours = resnet50_flop_multipliers(s, dist)
        for m, (pt, pe) in methods.items():
            rows.append({
                "name": f"flops_table/{m}_s{s}_{dist}",
                "us_per_call": (time.time() - t0) * 1e6 / max(len(rows), 1),
                "derived": {
                    "train_mult": round(ours[m]["train"], 4),
                    "test_mult": round(ours[m]["test"], 4),
                    "paper_train": pt,
                    "paper_test": pe,
                },
            })
    return rows

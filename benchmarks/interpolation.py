"""Paper Fig 6: loss landscape between static and RigL solutions.

(left) linear interpolation static->rigl shows a high-loss barrier;
(right) restarting RigL FROM the static solution escapes it, while
continuing static training cannot.
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import apply_masks
from repro.data import make_teacher, teacher_batch
from ._mlp import mlp_loss, train_mlp


def run(quick=True):
    steps = 300 if quick else 1200
    t0 = time.time()
    static = train_mlp(method="static", sparsity=0.9, steps=steps, seed=0)
    rigl = train_mlp(method="rigl", sparsity=0.9, steps=steps, seed=0)
    teacher = make_teacher(jax.random.PRNGKey(99), 32, 128, 16, 0.9)
    batch = teacher_batch(teacher, 12345, 1024)

    w_s = apply_masks(static.params, static.masks)
    w_r = apply_masks(rigl.params, rigl.masks)
    losses = []
    for lam in np.linspace(0, 1, 11):
        w = jax.tree_util.tree_map(lambda a, b: (1 - lam) * a + lam * b, w_s, w_r)
        losses.append(float(mlp_loss(w, batch)))
    barrier = max(losses) - max(losses[0], losses[-1])

    # Fig 6-right: restart from the static solution
    resumed_static = train_mlp(method="static", sparsity=0.9, steps=steps, seed=1,
                               init_params=static.params, init_masks_override=static.masks)
    resumed_rigl = train_mlp(method="rigl", sparsity=0.9, steps=steps, seed=1,
                             init_params=static.params, init_masks_override=static.masks)
    return [{
        "name": "interpolation/static_to_rigl",
        "us_per_call": (time.time() - t0) * 1e6,
        "derived": {
            "loss_static": round(losses[0], 5),
            "loss_rigl": round(losses[-1], 5),
            "barrier_height": round(barrier, 5),
            "barrier_exists": barrier > 0.1 * max(losses[0], losses[-1]),
            "resume_static_loss": round(resumed_static.final_loss, 5),
            "resume_rigl_loss": round(resumed_rigl.final_loss, 5),
            "rigl_escapes_minimum": resumed_rigl.final_loss < resumed_static.final_loss,
        },
    }]

"""Kernel microbenchmarks: fused-mask and block-sparse matmul vs dense.

CPU wall-times are for the jnp reference path (interpret-mode pallas timing is
meaningless); the derived columns report the TPU-side traffic/FLOP model:
fused masking removes 3 HBM weight passes, block-sparsity scales both HBM
bytes and MXU FLOPs with block density.
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref
from repro.kernels.ops import block_sparse_linear, masked_linear


def _time(fn, *args, iters=20):
    fn(*args).block_until_ready()
    t0 = time.time()
    for _ in range(iters):
        out = fn(*args)
    out.block_until_ready()
    return (time.time() - t0) / iters * 1e6


def run(quick=True):
    M = K = N = 1024
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (M, K), jnp.float32)
    w = jax.random.normal(jax.random.fold_in(key, 1), (K, N), jnp.float32)
    rows = []
    dense_t = _time(jax.jit(lambda a, b: a @ b), x, w)
    rows.append({"name": "kernel/dense_matmul_ref", "us_per_call": dense_t,
                 "derived": {"hbm_bytes": 4 * (M * K + K * N + M * N)}})
    for density in (0.1, 0.25, 0.5):
        m = jax.random.uniform(jax.random.fold_in(key, 2), (K, N)) < density
        t = _time(jax.jit(ref.masked_matmul_ref), x, w, m)
        rows.append({
            "name": f"kernel/masked_matmul_d{density}",
            "us_per_call": t,
            "derived": {
                # fused kernel: w + 1-byte mask once; unfused: w read 2x + masked copy written
                "hbm_bytes_fused": int(4 * M * K + 4 * K * N + K * N + 4 * M * N),
                "hbm_bytes_unfused": int(4 * M * K + 3 * 4 * K * N + K * N + 4 * M * N),
                "weight_traffic_saving": round(
                    (3 * 4 * K * N) / (4 * K * N + K * N), 2),
            },
        })
        bm = jax.random.uniform(jax.random.fold_in(key, 3), (K // 128, N // 128)) < density
        t2 = _time(jax.jit(lambda a, b, mm: ref.block_sparse_matmul_ref(a, b, mm, 128, 128)), x, w, bm)
        d = float(bm.mean())
        rows.append({
            "name": f"kernel/block_sparse_d{density}",
            "us_per_call": t2,
            "derived": {
                "block_density": round(d, 3),
                "mxu_flops_fraction": round(d, 3),
                "hbm_weight_bytes_fraction": round(d, 3),
                "tpu_speedup_bound": round(1 / max(d, 1e-3), 2),
            },
        })
    return rows

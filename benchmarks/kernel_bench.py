"""Kernel microbenchmarks: fused-mask and block-sparse matmul vs dense,
now covering the FULL train step (fwd + bwd through the custom-VJP kernels).

CPU wall-times are for the jnp reference path (interpret-mode pallas timing is
meaningless); the derived columns report the TPU-side traffic/FLOP model:

  fwd          out = x @ (w⊙m)      — fused masking removes 3 HBM weight
                                      passes vs XLA's materialized w*m
  bwd dgrad    dx  = g @ (w⊙m)ᵀ     — same fusion on the N-contraction
  bwd wgrad    dw  = (xᵀ@g)⊙m       — mask fused at the store; block mode
                                      computes ONLY active (bk x bn) blocks

Block sparsity scales HBM weight bytes AND MXU FLOPs with block density d in
all three matmuls of a train step, so the fwd+bwd speedup bound is 1/d — the
paper's "fixed FLOPs throughout training" realized at the kernel level.

Attention rows (``kernel/flash_*``) extend the same accounting to the score
grid: AttnSchedule-driven flash attention (core/attn_sched.py) launches only
live KV blocks per q row, vs the padded baseline that @pl.when-guarded dead
blocks but still DMA'd them; grid/DMA fractions are recorded AND asserted
(tight grid fraction <= the @pl.when path's computed-block fraction, and
<= 0.5 at Sk=4096 with window=512).

``python -m benchmarks.kernel_bench`` additionally writes BENCH_kernels.json
(schema: {"rows": [...], "meta": {...}}) so the perf trajectory is tracked
across PRs from this one onward.
"""
import json
import pathlib
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref
from repro.kernels.ops import block_sparse_linear, masked_linear

F32 = 4  # bytes
MASK = 1  # 1-byte mask in HBM


def _time(fn, *args, iters=20):
    fn(*args).block_until_ready()
    t0 = time.time()
    for _ in range(iters):
        out = fn(*args)
    out.block_until_ready()
    return (time.time() - t0) / iters * 1e6


def _time_grad(fn, *args, iters=10):
    g = jax.jit(jax.grad(lambda *a: jnp.sum(fn(*a)), argnums=(0, 1)))
    jax.tree_util.tree_leaves(g(*args))[0].block_until_ready()
    t0 = time.time()
    for _ in range(iters):
        out = g(*args)
    jax.tree_util.tree_leaves(out)[0].block_until_ready()
    return (time.time() - t0) / iters * 1e6


def _masked_traffic(M, K, N):
    """HBM byte model for one fwd+bwd of a masked linear (f32)."""
    # fused: each matmul reads its operands once; the mask is 1 byte
    fwd_fused = F32 * (M * K + K * N + M * N) + MASK * K * N
    dgrad_fused = F32 * (M * N + K * N + M * K) + MASK * K * N
    wgrad_fused = F32 * (M * K + M * N + K * N) + MASK * K * N
    # unfused: + write w*m + re-read it, per pass that needs masked weights
    # (fwd and dgrad consume w*m; wgrad consumes the mask for g*m — charge
    # the same materialize+reread for its masked-grad copy)
    extra = 2 * F32 * K * N
    return {
        "fwd_bytes_fused": fwd_fused,
        "fwd_bytes_unfused": fwd_fused + extra,
        "bwd_bytes_fused": dgrad_fused + wgrad_fused,
        "bwd_bytes_unfused": dgrad_fused + wgrad_fused + 2 * extra,
        "weight_traffic_saving_fwd_bwd": round(
            3 * extra / (fwd_fused + dgrad_fused + wgrad_fused), 2
        ),
    }


def _tight_vs_padded_rows(key):
    """BENCH rows for host-packed (tight) vs traced-width (padded) grids."""
    from repro.kernels.block_sparse_matmul import pack_block_mask

    M, K, N, bk, bn = 128, 1024, 512, 128, 128
    nkb = K // bk
    x = jax.random.normal(jax.random.fold_in(key, 7), (M, K), jnp.float32)
    w = jax.random.normal(jax.random.fold_in(key, 8), (K, N), jnp.float32)
    rows = []
    for sparsity in (0.8, 0.9, 0.95):
        bm = np.array(  # owning copy: the fixup below writes into it
            jax.random.uniform(jax.random.fold_in(key, int(100 * sparsity)),
                               (nkb, N // bn)) < (1 - sparsity)
        )
        if bm.sum() == 0:  # degenerate draw: keep one block active
            bm[0, 0] = True
        tight = pack_block_mask(bm)  # width = true max count per column
        padded = pack_block_mask(bm, max_count=nkb)  # traced worst case
        f_tight = lambda a, b: block_sparse_linear(
            a, b, block=(128, bn, bk), pack=tight, interpret=True
        )
        f_padded = lambda a, b: block_sparse_linear(
            a, b, block=(128, bn, bk), pack=padded, interpret=True
        )
        t_tight = _time(f_tight, x, w, iters=3)
        t_padded = _time(f_padded, x, w, iters=3)
        width = int(tight[0].shape[1])
        rows.append({
            "name": f"kernel/block_sparse_tight_vs_padded_s{sparsity}",
            "us_per_call": t_tight,
            "derived": {
                "us_per_call_padded": t_padded,
                "grid_iters_tight": (M // 128) * (N // bn) * width,
                "grid_iters_padded": (M // 128) * (N // bn) * nkb,
                "grid_fraction": round(width / nkb, 3),
                "active_blocks": int(bm.sum()),
                "bit_identical": bool(
                    jnp.array_equal(f_tight(x, w), f_padded(x, w))
                ),
            },
        })
    return rows


def _ssm_rows(key):
    """SSM projection rows (hymba's in_proj/out_proj shapes, scaled down):
    dense vs masked vs block_sparse for the newly dispatched family."""
    M, d, d_in = 512, 256, 512  # in_proj: (d, 2*d_in) at d_in = 2*d
    K, N = d, 2 * d_in
    x = jax.random.normal(jax.random.fold_in(key, 20), (M, K), jnp.float32)
    w = jax.random.normal(jax.random.fold_in(key, 21), (K, N), jnp.float32)
    rows = []
    t_dense = _time(jax.jit(lambda a, b: a @ b), x, w, iters=10)
    t_dense_bwd = _time_grad(lambda a, b: a @ b, x, w, iters=5)
    rows.append({
        "name": "kernel/ssm_in_proj_dense",
        "us_per_call": t_dense + t_dense_bwd,
        "derived": {"hbm_bytes": 3 * F32 * (M * K + K * N + M * N)},
    })
    for density, mode in ((0.2, "masked"), (0.2, "block_sparse")):
        if mode == "masked":
            m = jax.random.uniform(jax.random.fold_in(key, 22), (K, N)) < density
            t = _time(jax.jit(ref.masked_matmul_ref), x, w, m, iters=10)
            t_bwd = _time_grad(
                lambda a, b: ref.masked_matmul_ref(a, b, m), x, w, iters=5
            )
            derived = _masked_traffic(M, K, N)
        else:
            bm = jax.random.uniform(
                jax.random.fold_in(key, 23), (K // 128, N // 128)
            ) < density
            t = _time(
                jax.jit(lambda a, b: ref.block_sparse_matmul_ref(a, b, bm, 128, 128)),
                x, w, iters=10,
            )
            t_bwd = _time_grad(
                lambda a, b: ref.block_sparse_matmul_ref(a, b, bm, 128, 128),
                x, w, iters=5,
            )
            dd = float(bm.mean())
            derived = {
                "block_density": round(dd, 3),
                "mxu_flops_fraction_fwd_bwd": round(dd, 3),
                "tpu_speedup_bound_fwd_bwd": round(1 / max(dd, 1e-3), 2),
            }
        rows.append({
            "name": f"kernel/ssm_in_proj_{mode}_d{density}",
            "us_per_call": t + t_bwd,
            "derived": derived,
        })
    return rows


def _moe_grouped_rows(key):
    """Grouped (per-expert, one-launch) rows for the MoE expert-bank einsum
    ecd,edf->ecf: dense vs masked vs block_sparse refs, interpret-mode parity
    for the grouped Pallas kernels, and grouped tight-vs-padded grids."""
    from repro.kernels.block_sparse_matmul import pack_group_mask
    from repro.kernels.ops import (
        grouped_block_sparse_linear,
        grouped_masked_linear,
    )

    E, C, d, f, bkn = 4, 128, 256, 256, 128
    x = jax.random.normal(jax.random.fold_in(key, 30), (E, C, d), jnp.float32)
    w = jax.random.normal(jax.random.fold_in(key, 31), (E, d, f), jnp.float32)
    rows = []
    eins = lambda a, b: jnp.einsum("ecd,edf->ecf", a, b)
    t_dense = _time(jax.jit(eins), x, w, iters=10)
    t_dense_bwd = _time_grad(eins, x, w, iters=5)
    rows.append({
        "name": "kernel/moe_grouped_dense",
        "us_per_call": t_dense + t_dense_bwd,
        "derived": {
            "experts": E,
            "hbm_bytes": 3 * F32 * E * (C * d + d * f + C * f),
        },
    })
    density = 0.25
    m = jax.random.uniform(jax.random.fold_in(key, 32), (E, d, f)) < density
    t = _time(jax.jit(ref.grouped_masked_matmul_ref), x, w, m, iters=10)
    t_bwd = _time_grad(
        lambda a, b: ref.grouped_masked_matmul_ref(a, b, m), x, w, iters=5
    )
    per = _masked_traffic(C, d, f)
    rows.append({
        "name": f"kernel/moe_grouped_masked_d{density}",
        "us_per_call": t + t_bwd,
        "derived": {
            "experts": E,
            "launches": 1,  # ONE grouped launch for the whole bank
            "fwd_bytes_fused": E * per["fwd_bytes_fused"],
            "bwd_bytes_fused": E * per["bwd_bytes_fused"],
            "weight_traffic_saving_fwd_bwd":
                per["weight_traffic_saving_fwd_bwd"],
        },
    })
    bm = jax.random.uniform(
        jax.random.fold_in(key, 33), (E, d // bkn, f // bkn)
    ) < density
    t2 = _time(
        jax.jit(lambda a, b: ref.grouped_block_sparse_matmul_ref(a, b, bm, bkn, bkn)),
        x, w, iters=10,
    )
    t2_bwd = _time_grad(
        lambda a, b: ref.grouped_block_sparse_matmul_ref(a, b, bm, bkn, bkn),
        x, w, iters=5,
    )
    dd = float(bm.mean())
    rows.append({
        "name": f"kernel/moe_grouped_block_sparse_d{density}",
        "us_per_call": t2 + t2_bwd,
        "derived": {
            "experts": E,
            "launches": 1,
            "block_density": round(dd, 3),
            "mxu_flops_fraction_fwd_bwd": round(dd, 3),
            "wgrad_blocks_computed": int(np.asarray(bm).sum()),
            "wgrad_blocks_total": int(bm.size),
            "tpu_speedup_bound_fwd_bwd": round(1 / max(dd, 1e-3), 2),
        },
    })
    # grouped tight-vs-padded grids (PackState grouped entries): same kernel,
    # same stacked topology — only the shared width differs.  Interpret-mode
    # wall-time RATIO tracks the launched-iteration ratio (see the 2-D rows).
    Eg, Mg, Kg, Ng, bg = 4, 128, 512, 256, 128
    nkb = Kg // bg
    xg = jax.random.normal(jax.random.fold_in(key, 34), (Eg, Mg, Kg), jnp.float32)
    wg = jax.random.normal(jax.random.fold_in(key, 35), (Eg, Kg, Ng), jnp.float32)
    for sparsity in (0.8, 0.9):
        bmg = np.array(np.asarray(
            jax.random.uniform(
                jax.random.fold_in(key, int(1000 * sparsity)),
                (Eg, nkb, Ng // bg),
            ) < (1 - sparsity)
        ))
        if bmg.sum() == 0:
            bmg[0, 0, 0] = True
        tight = pack_group_mask(bmg)
        padded = pack_group_mask(bmg, max_count=nkb)
        f_tight = lambda a, b: grouped_block_sparse_linear(
            a, b, block=(128, bg, bg), pack=tight, interpret=True
        )
        f_padded = lambda a, b: grouped_block_sparse_linear(
            a, b, block=(128, bg, bg), pack=padded, interpret=True
        )
        t_t = _time(f_tight, xg, wg, iters=2)
        t_p = _time(f_padded, xg, wg, iters=2)
        width = int(tight[0].shape[-1])
        rows.append({
            "name": f"kernel/moe_grouped_tight_vs_padded_s{sparsity}",
            "us_per_call": t_t,
            "derived": {
                "us_per_call_padded": t_p,
                "grid_iters_tight": Eg * (Mg // 128) * (Ng // bg) * width,
                "grid_iters_padded": Eg * (Mg // 128) * (Ng // bg) * nkb,
                "grid_fraction": round(width / nkb, 3),
                "active_blocks": int(bmg.sum()),
                "bit_identical": bool(
                    jnp.array_equal(f_tight(xg, wg), f_padded(xg, wg))
                ),
            },
        })
    # interpret-mode parity canaries for the grouped Pallas kernels
    xs = jax.random.normal(jax.random.fold_in(key, 36), (2, 64, 128), jnp.float32)
    ws = jax.random.normal(jax.random.fold_in(key, 37), (2, 128, 128), jnp.float32)
    ms = jax.random.uniform(jax.random.fold_in(key, 38), (2, 128, 128)) < 0.25
    err_m = float(jnp.max(jnp.abs(
        grouped_masked_linear(xs, ws, ms, interpret=True)
        - ref.grouped_masked_matmul_ref(xs, ws, ms)
    )))
    bms = jax.random.uniform(jax.random.fold_in(key, 39), (2, 1, 1)) < 0.5
    err_b = float(jnp.max(jnp.abs(
        grouped_block_sparse_linear(xs, ws, bms, block=(128, 128, 128), interpret=True)
        - ref.grouped_block_sparse_matmul_ref(xs, ws, bms, 128, 128)
    )))
    rows.append({
        "name": "kernel/grouped_pallas_parity_max_abs_err",
        "us_per_call": 0.0,
        "derived": {"grouped_masked": err_m, "grouped_block_sparse": err_b},
    })
    return rows


def _fused_epilogue_rows(key):
    """Fused wgrad->SGD epilogue rows (docs/kernels.md#fused-epilogue).

    The fused kernels fold m_new = mu*mom + dw + wd*w into the wgrad store
    while dw is still VMEM-resident, so the train step's HBM-BOUND elementwise
    epilogue shrinks from 5 grad-sized passes (read dw, mom, w; write w, mom)
    to 4 (read m_new, w; write w, mom): the momentum read is eliminated from
    the bandwidth-bound region.  The kernel's own extra mom/w streams ride the
    MXU-bound wgrad matmul (2*M*K*N flops vs K*N bytes), where they hide under
    compute — the point is moving passes OUT of the bandwidth-bound epilogue,
    not shrinking total bytes.  Parity canaries (sr=False): the fused VJP's
    weight cotangent must equal the unfused composition <= 1e-5.
    """
    from repro.kernels.ops import (
        fused_block_sparse_linear,
        fused_masked_linear,
    )

    M, K, N = 128, 256, 128
    mu, wd = 0.9, 1e-4
    x = jax.random.normal(jax.random.fold_in(key, 50), (M, K), jnp.float32)
    w = jax.random.normal(jax.random.fold_in(key, 51), (K, N), jnp.float32)
    g = jax.random.normal(jax.random.fold_in(key, 52), (M, N), jnp.float32)
    mask = jax.random.uniform(jax.random.fold_in(key, 53), (K, N)) < 0.25
    mom = (
        jax.random.normal(jax.random.fold_in(key, 54), (K, N), jnp.float32)
        * mask
    )
    seed = jnp.zeros((1,), jnp.int32)

    def cot_w(fn):
        _, vjp = jax.vjp(fn, w)
        return vjp(g)[0]

    m_fused = cot_w(lambda ww: fused_masked_linear(
        x, ww, mask, mom, seed, mu=mu, wd=wd, sr=False, interpret=True
    ))
    dw_ref = cot_w(lambda ww: masked_linear(x, ww, mask, interpret=True))
    m_ref = mu * mom + dw_ref + wd * (w * mask)
    err_m = float(jnp.max(jnp.abs(m_fused - m_ref)))

    bm = jax.random.uniform(jax.random.fold_in(key, 55), (K // 128, N // 128)) < 0.5
    if not bool(bm.any()):
        bm = bm.at[0, 0].set(True)
    wb = w * np.kron(np.asarray(bm), np.ones((128, 128), np.float32))[:K, :N]
    momb = jax.random.normal(
        jax.random.fold_in(key, 56), (K, N), jnp.float32
    ) * (wb != 0)
    mb_fused = cot_w(lambda ww: fused_block_sparse_linear(
        x, ww, momb, seed, mu=mu, wd=wd, sr=False,
        block=(128, 128, 128), block_mask=bm, interpret=True,
    ))
    dwb_ref = cot_w(lambda ww: block_sparse_linear(
        x, ww, bm, block=(128, 128, 128), interpret=True
    ))
    blk = jnp.kron(bm, jnp.ones((128, 128), jnp.float32))[:K, :N]
    mb_ref = (mu * momb + dwb_ref + wd * w) * blk
    err_b = float(jnp.max(jnp.abs(mb_fused - mb_ref)))
    assert err_m <= 1e-5 and err_b <= 1e-5, (err_m, err_b)

    grad_bytes = F32 * K * N
    epi_unfused = 5 * grad_bytes  # R dw, mom, w; W w, mom
    epi_fused = 4 * grad_bytes    # R m_new, w; W w, mom
    assert epi_fused < epi_unfused
    assert epi_unfused - epi_fused == grad_bytes  # exactly one grad pass
    return [{
        "name": "kernel/fused_epilogue_masked",
        "us_per_call": 0.0,  # accounting + parity row
        "derived": {
            "epilogue_hbm_bytes_unfused": epi_unfused,
            "epilogue_hbm_bytes_fused": epi_fused,
            "epilogue_passes_unfused": 5,
            "epilogue_passes_fused": 4,
            "grad_passes_removed": 1,
            "kernel_extra_streams_compute_shadowed": 2,  # mom + w reads
            "parity_max_abs_err": err_m,
        },
    }, {
        "name": "kernel/fused_epilogue_block_sparse",
        "us_per_call": 0.0,
        "derived": {
            "epilogue_hbm_bytes_unfused": epi_unfused,
            "epilogue_hbm_bytes_fused": epi_fused,
            "grad_passes_removed": 1,
            "parity_max_abs_err": err_b,
        },
    }]


def _gqa_softcap_rows(key):
    """GQA group folding + in-kernel logit softcap rows.

    Folded flash BlockSpecs read K/V row b // G straight from the UNREPEATED
    (BH/G, Sk, d) arrays, so the repeat materialization the old path needed
    (write the (BH, Sk, d) expansion, then DMA it back into the kernel) is
    gone: 2 full passes over the EXPANDED K/V bytes saved, and the
    HBM-resident K/V footprint drops G-fold.  Asserted analytically (the
    per-tile kernel DMA is unchanged — each grid row still gathers its
    group's K/V; the win is the eliminated expansion round-trip + footprint,
    not per-tile dedup).  Softcap: s = c*tanh(s/c) inside the flash kernels
    (fwd + VJP), parity vs the jnp oracle <= 1e-5.
    """
    from repro.kernels.flash_attention import flash_attention
    from repro.kernels.ref import flash_attention_ref

    BH, G, S, d = 8, 4, 128, 32
    q = jax.random.normal(jax.random.fold_in(key, 60), (BH, S, d), jnp.float32)
    kv = jax.random.normal(
        jax.random.fold_in(key, 61), (2, BH // G, S, d), jnp.float32
    )
    k, v = kv[0], kv[1]
    k_rep = jnp.repeat(k, G, axis=0)
    v_rep = jnp.repeat(v, G, axis=0)
    out_fold = flash_attention(
        q, k, v, causal=True, kv_groups=G, interpret=True
    )
    out_rep = flash_attention(q, k_rep, v_rep, causal=True, interpret=True)
    err_fold = float(jnp.max(jnp.abs(out_fold - out_rep)))
    g_fold = jax.grad(lambda a: jnp.sum(jnp.sin(flash_attention(
        a, k, v, causal=True, kv_groups=G, interpret=True
    ))))(q)
    g_rep = jax.grad(lambda a: jnp.sum(jnp.sin(flash_attention(
        a, k_rep, v_rep, causal=True, interpret=True
    ))))(q)
    err_fold_bwd = float(jnp.max(jnp.abs(g_fold - g_rep)))
    assert err_fold <= 1e-5 and err_fold_bwd <= 1e-5, (err_fold, err_fold_bwd)

    kv_bytes_folded = 2 * F32 * (BH // G) * S * d   # HBM-resident K/V
    kv_bytes_repeated = 2 * F32 * BH * S * d        # expanded copy
    # repeat path: write the expansion once + kernel reads it back
    repeat_roundtrip_bytes = 2 * kv_bytes_repeated
    assert kv_bytes_repeated == G * kv_bytes_folded  # G-fold footprint

    cap = 30.0
    err_cap = float(jnp.max(jnp.abs(
        flash_attention(q, k_rep, v_rep, causal=True, softcap=cap,
                        interpret=True)
        - flash_attention_ref(q, k_rep, v_rep, causal=True, softcap=cap)
    )))
    gc_k = jax.grad(lambda a: jnp.sum(jnp.sin(flash_attention(
        a, k_rep, v_rep, causal=True, softcap=cap, interpret=True
    ))))(q)
    gc_r = jax.grad(lambda a: jnp.sum(jnp.sin(flash_attention_ref(
        a, k_rep, v_rep, causal=True, softcap=cap
    ))))(q)
    err_cap_bwd = float(jnp.max(jnp.abs(gc_k - gc_r)))
    assert err_cap <= 1e-5 and err_cap_bwd <= 1e-5, (err_cap, err_cap_bwd)
    return [{
        "name": f"kernel/flash_gqa_folded_G{G}",
        "us_per_call": 0.0,
        "derived": {
            "kv_groups": G,
            "kv_hbm_bytes_folded": kv_bytes_folded,
            "kv_hbm_bytes_repeated": kv_bytes_repeated,
            "repeat_roundtrip_bytes_removed": repeat_roundtrip_bytes,
            "footprint_reduction": G,
            "parity_max_abs_err_fwd": err_fold,
            "parity_max_abs_err_bwd": err_fold_bwd,
        },
    }, {
        "name": f"kernel/flash_softcap_c{cap}",
        "us_per_call": 0.0,
        "derived": {
            "softcap": cap,
            "parity_max_abs_err_fwd": err_cap,
            "parity_max_abs_err_bwd": err_cap_bwd,
        },
    }]


def _attention_rows(key):
    """Flash-attention rows: tight (AttnSchedule) vs padded grids + the
    wasted-DMA accounting that motivated them.

    The original causal kernel launched the full Sk/bk grid and @pl.when-
    guarded dead blocks — skipping their MXU work but still DMAing K/V for
    every block (dma_fraction_plwhen = 1.0).  The schedule-driven kernels
    clamp padded slots' index_map to the last live block, so K/V DMA drops to
    the live-block fraction in BOTH modes, and tight mode additionally cuts
    launched iterations to width/n_k.  Recorded (and asserted) orderings:

      grid_fraction_tight <= compute_fraction_plwhen   (what @pl.when ran)
      grid_fraction_tight <= 0.5 at Sk=4096, window=512 (acceptance bound)
      live_fraction <= grid_fraction_tight             (width is a row max)
    """
    from repro.core.attn_sched import (
        attn_sched_stats,
        build_attn_schedule,
        live_block_mask,
    )
    from repro.kernels.flash_attention import flash_attention
    from repro.kernels.ref import flash_attention_ref

    rows = []
    # grid/DMA accounting at the serving shape the ISSUE pins: Sk=4096
    S, b = 4096, 128
    # NOTE: the repo's window semantics is lower-bound only (kpos > qpos -
    # window), so window WITHOUT causal barely clips — the accounting rows
    # are the two families that matter at serving time
    for name, causal, window in (
        ("causal", True, 0),
        ("causal_w512", True, 512),
    ):
        sched = build_attn_schedule(S, S, b, b, causal=causal, window=window)
        st = attn_sched_stats(sched)
        # blocks the old @pl.when path COMPUTED (it DMA'd all of them, plus
        # every dead block): the causal-only live set, or everything when
        # the family has no causal term to guard on
        plwhen_live = int(
            live_block_mask(S, S, b, b, causal=causal, window=0).sum()
        )
        compute_fraction_plwhen = plwhen_live / st["grid_iters_padded"]
        assert st["live_fraction"] <= st["grid_fraction"] + 1e-9
        # DMA always shrinks to the live fraction (the @pl.when path DMA'd
        # every block, fraction 1.0)
        assert st["live_fraction"] < 1.0
        if causal and window:
            # causal+window rows also clip ITERATIONS below what @pl.when
            # even computed, and below half the dense grid (acceptance
            # bound).  Pure causal is the known exception: its last q row
            # attends all n_k blocks, so width == n_k and only the DMA
            # shrinks.
            assert st["grid_fraction"] <= compute_fraction_plwhen + 1e-9, (
                name, st["grid_fraction"], compute_fraction_plwhen,
            )
            assert st["grid_fraction"] <= 0.5, (name, st["grid_fraction"])
        rows.append({
            "name": f"kernel/flash_sched_{name}_S{S}",
            "us_per_call": 0.0,  # accounting row: fractions are the payload
            "derived": {
                "grid_iters_tight": st["grid_iters_tight"],
                "grid_iters_padded": st["grid_iters_padded"],
                "grid_fraction_tight": round(st["grid_fraction"], 4),
                "live_blocks": st["live_blocks"],
                "live_fraction": round(st["live_fraction"], 4),
                "compute_fraction_plwhen": round(compute_fraction_plwhen, 4),
                "dma_fraction_plwhen": 1.0,  # the old kernel DMA'd every block
                "dma_fraction_sched": round(st["live_fraction"], 4),
            },
        })
    # interpret-mode wall time tight vs padded at a small windowed shape (one
    # python kernel body per grid cell => the RATIO tracks iterations), plus
    # fwd+bwd parity canaries vs the jnp oracle
    Sb, d, window = 1024, 64, 256
    q = jax.random.normal(jax.random.fold_in(key, 40), (1, Sb, d), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(key, 41), (1, Sb, d), jnp.float32)
    v = jax.random.normal(jax.random.fold_in(key, 42), (1, Sb, d), jnp.float32)
    f_tight = lambda a, b_, c: flash_attention(
        a, b_, c, causal=True, window=window, tight=True, interpret=True
    )
    f_padded = lambda a, b_, c: flash_attention(
        a, b_, c, causal=True, window=window, tight=False, interpret=True
    )
    t_tight = _time(f_tight, q, k, v, iters=3)
    t_padded = _time(f_padded, q, k, v, iters=3)
    out_t, out_p = f_tight(q, k, v), f_padded(q, k, v)
    expect = flash_attention_ref(q, k, v, causal=True, window=window)
    err_fwd = float(jnp.max(jnp.abs(out_t - expect)))
    g_t = jax.grad(lambda a: jnp.sum(jnp.sin(f_tight(a, k, v))))(q)
    g_r = jax.grad(
        lambda a: jnp.sum(jnp.sin(flash_attention_ref(
            a, k, v, causal=True, window=window
        )))
    )(q)
    err_bwd = float(jnp.max(jnp.abs(g_t - g_r)))
    assert err_fwd <= 1e-5 and err_bwd <= 1e-5, (err_fwd, err_bwd)
    st = attn_sched_stats(
        build_attn_schedule(Sb, Sb, 128, 128, causal=True, window=window)
    )
    rows.append({
        "name": f"kernel/flash_tight_vs_padded_w{window}_S{Sb}",
        "us_per_call": t_tight,
        "derived": {
            "us_per_call_padded": t_padded,
            "grid_iters_tight": st["grid_iters_tight"],
            "grid_iters_padded": st["grid_iters_padded"],
            "grid_fraction": round(st["grid_fraction"], 4),
            "bit_identical": bool(jnp.array_equal(out_t, out_p)),
            "parity_max_abs_err_fwd": err_fwd,
            "parity_max_abs_err_bwd": err_bwd,
        },
    })
    return rows


def run(quick=True):
    M = K = N = 1024
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (M, K), jnp.float32)
    w = jax.random.normal(jax.random.fold_in(key, 1), (K, N), jnp.float32)
    rows = []
    dense_t = _time(jax.jit(lambda a, b: a @ b), x, w)
    dense_bwd_t = _time_grad(lambda a, b: a @ b, x, w)
    rows.append({"name": "kernel/dense_matmul_ref", "us_per_call": dense_t,
                 "derived": {"hbm_bytes": F32 * (M * K + K * N + M * N)}})
    rows.append({"name": "kernel/dense_matmul_ref_fwd_bwd",
                 "us_per_call": dense_t + dense_bwd_t,
                 "derived": {
                     # 3 matmuls/step: fwd, dgrad, wgrad
                     "hbm_bytes": 3 * F32 * (M * K + K * N + M * N),
                     "mxu_flops": 3 * 2 * M * K * N,
                 }})
    for density in (0.1, 0.25, 0.5):
        m = jax.random.uniform(jax.random.fold_in(key, 2), (K, N)) < density
        t = _time(jax.jit(ref.masked_matmul_ref), x, w, m)
        t_bwd = _time_grad(lambda a, b: ref.masked_matmul_ref(a, b, m), x, w)
        traffic = _masked_traffic(M, K, N)
        rows.append({
            "name": f"kernel/masked_matmul_d{density}",
            "us_per_call": t,
            "derived": {
                "hbm_bytes_fused": traffic["fwd_bytes_fused"],
                "hbm_bytes_unfused": traffic["fwd_bytes_unfused"],
                "weight_traffic_saving": round(
                    (3 * F32 * K * N) / (F32 * K * N + MASK * K * N), 2),
            },
        })
        rows.append({
            "name": f"kernel/masked_matmul_fwd_bwd_d{density}",
            "us_per_call": t + t_bwd,
            "derived": traffic,
        })
        bm = jax.random.uniform(jax.random.fold_in(key, 3), (K // 128, N // 128)) < density
        t2 = _time(jax.jit(lambda a, b, mm: ref.block_sparse_matmul_ref(a, b, mm, 128, 128)), x, w, bm)
        t2_bwd = _time_grad(
            lambda a, b: ref.block_sparse_matmul_ref(a, b, bm, 128, 128), x, w
        )
        d = float(bm.mean())
        nact = int(np.asarray(bm).sum())
        rows.append({
            "name": f"kernel/block_sparse_d{density}",
            "us_per_call": t2,
            "derived": {
                "block_density": round(d, 3),
                "mxu_flops_fraction": round(d, 3),
                "hbm_weight_bytes_fraction": round(d, 3),
                "tpu_speedup_bound": round(1 / max(d, 1e-3), 2),
            },
        })
        rows.append({
            "name": f"kernel/block_sparse_fwd_bwd_d{density}",
            "us_per_call": t2 + t2_bwd,
            "derived": {
                "block_density": round(d, 3),
                # all three matmuls skip inactive blocks:
                #   fwd/dgrad touch d of the w blocks; wgrad computes only
                #   the nact packed (128x128) grad blocks
                "mxu_flops_fraction_fwd_bwd": round(d, 3),
                "dgrad_hbm_weight_bytes_fraction": round(d, 3),
                "wgrad_blocks_computed": nact,
                "wgrad_blocks_total": int(bm.size),
                "tpu_speedup_bound_fwd_bwd": round(1 / max(d, 1e-3), 2),
            },
        })
    # tight vs padded grids (PackState, core/pack.py) at serving sparsities:
    # same kernel, same topology — only the grid's third dim differs (the
    # host-packed true max active-block count vs the traced worst case K/bk).
    # Interpret mode executes one python kernel body per grid cell, so the
    # wall-time RATIO here directly tracks the launched-iteration ratio; on
    # TPU the padded slots are empty iterations (no DMA/FLOPs), so the win is
    # launch overhead, not bandwidth — outputs are bit-identical either way.
    rows.extend(_tight_vs_padded_rows(key))
    # newly dispatched families (total-dispatch PR): ssm projections and the
    # grouped per-expert MoE einsums — dense vs masked vs block_sparse, plus
    # grouped tight-vs-padded grids and grouped-kernel parity canaries.
    rows.extend(_ssm_rows(key))
    rows.extend(_moe_grouped_rows(key))
    # attention: schedule-driven tight grids vs the padded/@pl.when baseline
    # (grid + DMA fractions, tight-vs-padded wall time, fwd+bwd parity)
    rows.extend(_attention_rows(key))
    # fused wgrad->optimizer epilogue (HBM-pass accounting + parity) and
    # GQA group folding / in-kernel softcap (footprint accounting + parity)
    rows.extend(_fused_epilogue_rows(key))
    rows.extend(_gqa_softcap_rows(key))
    # interpret-mode correctness canaries for the Pallas path itself (cheap
    # shapes — wall time here is NOT meaningful, only parity is)
    xs = jax.random.normal(key, (128, 256), jnp.float32)
    ws = jax.random.normal(jax.random.fold_in(key, 4), (256, 128), jnp.float32)
    ms = jax.random.uniform(jax.random.fold_in(key, 5), (256, 128)) < 0.25
    err = float(jnp.max(jnp.abs(
        masked_linear(xs, ws, ms, interpret=True) - ref.masked_matmul_ref(xs, ws, ms)
    )))
    bms = jax.random.uniform(jax.random.fold_in(key, 6), (2, 1)) < 0.5
    err_b = float(jnp.max(jnp.abs(
        block_sparse_linear(xs, ws, bms, block=(128, 128, 128), interpret=True)
        - ref.block_sparse_matmul_ref(xs, ws, bms, 128, 128)
    )))
    rows.append({
        "name": "kernel/pallas_parity_max_abs_err",
        "us_per_call": 0.0,
        "derived": {"masked": err, "block_sparse": err_b},
    })
    return rows


def main():
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_kernels.json",
                    help="output path (make bench-kernels-smoke points this "
                         "at /tmp so verify runs don't churn the tracked file)")
    args = ap.parse_args()
    rows = run(quick=True)
    out = {
        "meta": {
            "backend": jax.default_backend(),
            "note": "wall-times are the jnp reference path on this host; "
                    "derived columns are the TPU traffic/FLOP model",
        },
        "rows": rows,
    }
    path = pathlib.Path(args.out)
    path.write_text(json.dumps(out, indent=1))
    print(f"wrote {path} ({len(rows)} rows)")
    for r in rows:
        print(f'{r["name"]},{r["us_per_call"]:.1f},{json.dumps(r["derived"])}')


if __name__ == "__main__":
    main()

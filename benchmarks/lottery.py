"""Paper Table 3 / Appendix E: (non)-existence of lottery tickets.

Take the topology found by RigL; retrain from the ORIGINAL init with (a) the
topology fixed (lottery-static) and (b) RigL. Compare with random-init RigL.
Paper: Lottery+Static << Lottery+RigL <= Random+RigL — no special tickets.
"""
import time

import jax

from ._mlp import _init, train_mlp


def run(quick=True):
    steps = 300 if quick else 1200
    t0 = time.time()
    first = train_mlp(method="rigl", sparsity=0.9, steps=steps, seed=0)
    init_params = jax.device_get(_init(jax.random.PRNGKey(0)))  # original init

    lottery_static = train_mlp(method="static", sparsity=0.9, steps=steps, seed=2,
                               init_params=init_params, init_masks_override=first.masks)
    lottery_rigl = train_mlp(method="rigl", sparsity=0.9, steps=steps, seed=2,
                             init_params=init_params, init_masks_override=first.masks)
    random_rigl = train_mlp(method="rigl", sparsity=0.9, steps=steps, seed=2)
    return [{
        "name": "lottery/table3",
        "us_per_call": (time.time() - t0) * 1e6,
        "derived": {
            "lottery_static_loss": round(lottery_static.final_loss, 5),
            "lottery_rigl_loss": round(lottery_rigl.final_loss, 5),
            "random_rigl_loss": round(random_rigl.final_loss, 5),
            "no_special_tickets": random_rigl.final_loss <= lottery_static.final_loss,
        },
    }]

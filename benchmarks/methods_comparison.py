"""Paper Fig 2-top-right: sparse-training methods at fixed FLOPs.

  PYTHONPATH=src python -m benchmarks.methods_comparison --smoke-bench --out /tmp/m.json

Planted-sparse-teacher task (ground-truth topology known). Expected ordering,
as in the paper: RigL <= SNFS < SET < Static ~ Small-Dense, with RigL at
sparse cost while SNFS pays dense-gradient cost and Top-KAST stays always
sparse (fwd at k, wgrad at k+Δ — see docs/training.md).

Each row also carries topology telemetry from core.topology: per-run drop/grow
totals and mean Jaccard / normalized-Hamming distance per mask update, plus
final-mask distances vs the RigL reference (cross_method_distances) — where do
the methods CONVERGE, not just how well do they score.
"""
import argparse
import json
import pathlib
import time

from repro.core import cross_method_distances

from ._mlp import train_mlp

METHODS = (
    "dense", "small_dense", "static", "snip", "set", "snfs", "rigl",
    "topkast", "pruning",
)


def run(quick=True, steps=None, delta_t=25):
    steps = steps if steps is not None else (300 if quick else 1500)
    rows = []
    final_masks = {}
    for m in METHODS:
        t0 = time.time()
        r = train_mlp(method=m, sparsity=0.9, steps=steps, delta_t=delta_t, seed=0)
        final_masks[m] = r.masks
        topo = r.topology
        rows.append({
            "name": f"methods/{m}",
            "us_per_call": (time.time() - t0) * 1e6 / steps,
            "derived": {
                "final_loss": round(r.final_loss, 5),
                "train_flops_mult": round(r.train_flops_mult, 4),
                "test_flops_mult": round(r.test_flops_mult, 4),
                "n_updates": topo["n_updates"],
                "dropped_total": topo["dropped_total"],
                "grown_total": topo["grown_total"],
                "jaccard_dist_mean": round(topo["jaccard_dist_mean"], 5),
                "nhd_mean": round(topo["nhd_mean"], 5),
                "graph_edit_dist_total": topo["graph_edit_dist_total"],
            },
        })
    vs_ref = cross_method_distances(final_masks, reference="rigl")
    for row in rows:
        m = row["name"].split("/", 1)[1]
        if m in vs_ref:
            row["derived"].update(
                {k: round(v, 5) for k, v in vs_ref[m].items()}
            )
    return rows


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--steps", type=int, default=300)
    p.add_argument("--delta-t", type=int, default=25)
    p.add_argument("--out", default="BENCH_methods.json")
    p.add_argument("--smoke-bench", action="store_true",
                   help="tiny run for make verify (2 mask updates per method)")
    args = p.parse_args()
    if args.smoke_bench:
        args.steps, args.delta_t = 60, 20  # updates at t=20, 40 (t_end=45)
    rows = run(steps=args.steps, delta_t=args.delta_t)
    out = {
        "meta": {
            "steps": args.steps,
            "delta_t": args.delta_t,
            "smoke_bench": bool(args.smoke_bench),
        },
        "rows": rows,
    }
    pathlib.Path(args.out).write_text(json.dumps(out, indent=1))
    for row in rows:
        d = row["derived"]
        print(f"{row['name']:24s} loss {d['final_loss']:9.5f}  "
              f"train x{d['train_flops_mult']:.3f}  "
              f"updates {d['n_updates']:2d}  "
              f"jaccard {d['jaccard_dist_mean']:.3f}  "
              f"nhd {d['nhd_mean']:.4f}")
    print(f"-> {args.out}")


if __name__ == "__main__":
    main()

"""Paper Fig 2-top-right: sparse-training methods at fixed FLOPs.

Planted-sparse-teacher task (ground-truth topology known). Expected ordering,
as in the paper: RigL <= SNFS < SET < Static ~ Small-Dense, with RigL at
sparse cost while SNFS pays dense-gradient cost.
"""
import time

from ._mlp import train_mlp

METHODS = ("dense", "small_dense", "static", "snip", "set", "snfs", "rigl", "pruning")


def run(quick=True):
    steps = 300 if quick else 1500
    rows = []
    for m in METHODS:
        t0 = time.time()
        r = train_mlp(method=m, sparsity=0.9, steps=steps, seed=0)
        rows.append({
            "name": f"methods/{m}",
            "us_per_call": (time.time() - t0) * 1e6 / steps,
            "derived": {
                "final_loss": round(r.final_loss, 5),
                "train_flops_mult": round(r.train_flops_mult, 4),
                "test_flops_mult": round(r.test_flops_mult, 4),
            },
        })
    return rows

"""Paper Table 2 + Fig 7: RigL as architecture search on an MLP.

Synthetic MNIST-analog: 784-dim inputs where only a central subset of
"pixels" is informative. RigL at (99%, 89%) layer sparsities; dead
input-pixels/neurons are removed from the final architecture, reporting
size/KFLOPs like Table 2.
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    LayerSpec,
    SparseAlgo,
    UpdateSchedule,
    apply_masks,
    dense_to_sparse_grad,
    init_masks,
    rigl_update,
)

D_IN, D_H1, D_H2, D_OUT = 784, 300, 100, 10


def _informative():
    grid = jnp.arange(784).reshape(28, 28)
    return grid[7:21, 7:21].reshape(-1)


_CENTROIDS = None


def _centroids():
    # fixed class centroids over the central 14x14 "pixels" (MNIST-like:
    # strong pixel-class correlations; border pixels are pure noise that
    # RigL should learn to disconnect — paper Fig 7)
    global _CENTROIDS
    if _CENTROIDS is None:
        _CENTROIDS = jax.random.normal(jax.random.PRNGKey(77), (D_OUT, 196))
    return _CENTROIDS


def _data(key, n=256):
    y = jax.random.randint(key, (n,), 0, D_OUT)
    x = jax.random.normal(jax.random.fold_in(key, 1), (n, D_IN))
    x = x.at[:, _informative()].add(1.5 * _centroids()[y])
    return x, y


def run(quick=True):
    steps = 400 if quick else 2000
    t0 = time.time()
    key = jax.random.PRNGKey(0)
    smap = {"w1": 0.99, "w2": 0.89, "w3": 0.0}
    # density-corrected init: preserve activation variance under the mask
    # (effective fan-in = fan_in * (1 - s)); without this the doubly-sparse
    # relu chain emits ~1e-3-scale logits and 400 steps cannot move the loss
    params = {
        "w1": jax.random.normal(key, (D_IN, D_H1)) / np.sqrt(D_IN * (1 - smap["w1"])),
        "w2": jax.random.normal(jax.random.fold_in(key, 1), (D_H1, D_H2))
        / np.sqrt(D_H1 * (1 - smap["w2"])),
        "w3": jax.random.normal(jax.random.fold_in(key, 2), (D_H2, D_OUT)) / np.sqrt(D_H2),
    }
    masks = init_masks(jax.random.fold_in(key, 3), params, smap)
    algo = SparseAlgo(method="rigl", schedule=UpdateSchedule(delta_t=25, t_end=int(0.75 * steps), alpha=0.3))
    mom = jax.tree_util.tree_map(jnp.zeros_like, params)

    def loss_fn(p, batch):
        x, y = batch
        h = jax.nn.relu(x @ p["w1"])
        h = jax.nn.relu(h @ p["w2"])
        logits = h @ p["w3"]
        lse = jax.nn.logsumexp(logits, -1)
        return jnp.mean(lse - jnp.take_along_axis(logits, y[:, None], 1)[:, 0])

    @jax.jit
    def step(params, masks, mom, batch):
        w = apply_masks(params, masks)
        loss, g = jax.value_and_grad(loss_fn)(w, batch)
        gs = dense_to_sparse_grad(g, masks)
        mom2 = jax.tree_util.tree_map(lambda m, gg: 0.9 * m + gg, mom, gs)
        params2 = jax.tree_util.tree_map(lambda p, m: p - 0.1 * m, params, mom2)
        return params2, mom2, loss

    @jax.jit
    def update(params, masks, mom, t, batch):
        w = apply_masks(params, masks)
        g = jax.grad(loss_fn)(w, batch)
        p2, m2, grown = rigl_update(params, masks, g, t, algo, jax.random.fold_in(key, t))
        mom2 = jax.tree_util.tree_map(lambda m, gr: jnp.where(gr, 0.0, m), mom, grown)
        return p2, m2, mom2

    initial_in_conn = np.asarray(jnp.sum(masks["w1"], axis=1))
    for t in range(steps):
        b = _data(jax.random.fold_in(key, 10_000 + t))
        if t > 0 and t % 25 == 0 and t < algo.schedule.t_end:
            params, masks, mom = update(params, masks, mom, t, b)
        else:
            params, mom, loss = step(params, masks, mom, b)

    xe, ye = _data(jax.random.fold_in(key, 999_999), n=2048)
    w = apply_masks(params, masks)
    h = jax.nn.relu(xe @ w["w1"])
    h = jax.nn.relu(h @ w["w2"])
    acc = float(jnp.mean(jnp.argmax(h @ w["w3"], -1) == ye))

    # final architecture: prune dead inputs/neurons (Table 2 protocol)
    in_conn = np.asarray(jnp.sum(masks["w1"], axis=1))
    h1_alive = int(np.sum(np.asarray(jnp.sum(masks["w1"], 0) * jnp.sum(masks["w2"], 1)) > 0))
    h2_alive = int(np.sum(np.asarray(jnp.sum(masks["w2"], 0) * jnp.sum(masks["w3"], 1)) > 0))
    alive_in = int(np.sum(in_conn > 0))
    nnz = int(sum(int(m.sum()) for m in masks.values()))
    size_bytes = nnz * 4 + sum(m.size for m in masks.values()) // 8
    kflops = 2 * nnz / 1000
    # Fig 7: connections concentrate on informative (central) pixels
    grid = np.arange(784).reshape(28, 28)
    central = np.zeros(784, bool)
    central[grid[7:21, 7:21].reshape(-1)] = True
    frac_central_final = float(in_conn[central].sum() / max(in_conn.sum(), 1))
    frac_central_init = float(initial_in_conn[central].sum() / max(initial_in_conn.sum(), 1))
    return [{
        "name": "mlp_compression/table2",
        "us_per_call": (time.time() - t0) * 1e6 / steps,
        "derived": {
            "accuracy": round(acc, 4),
            "final_architecture": f"{alive_in}-{h1_alive}-{h2_alive}",
            "size_bytes": size_bytes,
            "inference_kflops": round(kflops, 1),
            "frac_connections_on_informative_pixels_init": round(frac_central_init, 3),
            "frac_connections_on_informative_pixels_final": round(frac_central_final, 3),
        },
    }]

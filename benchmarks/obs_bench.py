"""Observability overhead gate + trace <-> fault-injector correlation.

  PYTHONPATH=src python -m benchmarks.obs_bench            # writes BENCH_obs.json
  PYTHONPATH=src python -m benchmarks.obs_bench --smoke-bench --out /tmp/o.json

Two claims from docs/observability.md, checked mechanically:

  overhead     the instrumented ServeEngine (obs=Observability(...)) serves
               the serve_bench staggered workload within ``--overhead-pct``
               (default 3%) of the bare engine's throughput, with
               bit-identical greedy token streams.  Bare and instrumented
               runs INTERLEAVE (bare, obs, bare, obs, ...) so a slow patch
               of a shared machine penalises both sides equally; each side
               reports its median-throughput run, and a failed gate retries
               with doubled repeats before giving up — instrumentation is
               host-side attribute adds, so a real >3% regression survives
               retries while container noise does not.
  correlation  a seeded chaos run (FaultInjector poisoning decode logits
               and one request's prefills, virtual clock) must produce a
               Perfetto-loadable Chrome trace whose ``quarantine`` instants
               EXACTLY mirror ``engine.quarantine_log``, and whose
               quarantines are EXACTLY the ones the injector's fired log
               predicts: every fired decode injection appears as a
               ``fault_injected`` instant (step + targeted slots), the
               union of their ``active`` hits is the decode quarantine set,
               and each fired prefill injection (rid, attempt) maps to one
               prefill quarantine.  A shed mini-storm checks ``shed``
               instants against the queue's books the same way.

The process EXITS NONZERO on any violation; results land in BENCH_obs.json.
``--smoke-bench`` shrinks the workload for make verify.
"""
from __future__ import annotations

import argparse
import copy
import json
import pathlib

from repro.configs import get_config
from repro.launch.serve import (
    configure_kernel,
    init_serving_state,
    staggered_requests,
)
from repro.obs import MetricsRegistry, Observability, median_by
from repro.serving import FaultInjector, ServeEngine, Status, burst_storm

TRACE_PH = {"X", "i", "C", "M"}


def _fresh_obs() -> Observability:
    """A private registry per run: accumulation across timed repeats must
    not make later runs cheaper (memoised series) or dirtier (old counts)."""
    return Observability(metrics=MetricsRegistry(), process_name="serve")


def _run(cfg, params, reqs, *, capacity, max_len, masks, pack, obs=None):
    engine = ServeEngine(cfg, params, capacity=capacity, max_len=max_len,
                         masks=masks, pack=pack, obs=obs)
    for r in copy.deepcopy(reqs):
        engine.submit(r)
    stats = engine.run()
    return stats, engine


def _streams(engine) -> dict[int, list[int]]:
    return {r.rid: list(r.generated) for r in engine.queue.done
            if r.status is Status.DONE}


def _drain(engine, *, dt: float = 1.0, max_steps: int = 10_000) -> float:
    now = 0.0
    steps = 0
    while len(engine.queue) or engine.active.any():
        engine.step(now)
        now += dt
        steps += 1
        if steps > max_steps:
            raise SystemExit("obs_bench: engine failed to drain (livelock?)")
    return now


def measure_overhead(cfg, params, reqs, *, capacity, max_len, masks, pack,
                     repeats) -> dict:
    """Interleaved bare/instrumented repeats; returns both sides' median
    runs, the throughput overhead, and the token-identity verdict."""
    kw = dict(capacity=capacity, max_len=max_len, masks=masks, pack=pack)
    # warm every jit on a throwaway pair (per-length prefills + decode step)
    _, bare_eng = _run(cfg, params, reqs, **kw)
    _, obs_eng = _run(cfg, params, reqs, obs=_fresh_obs(), **kw)
    token_identical = _streams(bare_eng) == _streams(obs_eng)

    bare_runs, obs_runs = [], []
    for _ in range(repeats):
        bare_runs.append(_run(cfg, params, reqs, **kw)[0])
        obs_runs.append(_run(cfg, params, reqs, obs=_fresh_obs(), **kw)[0])
    bare = median_by(bare_runs, "tok_per_s")
    inst = median_by(obs_runs, "tok_per_s")
    overhead = 1.0 - inst["tok_per_s"] / max(bare["tok_per_s"], 1e-9)
    return {
        "repeats": repeats,
        "bare": bare,
        "instrumented": inst,
        "overhead_pct": 100.0 * overhead,
        "token_identical": token_identical,
    }


def _validate_chrome(path) -> dict:
    """Perfetto-loadability by schema: top-level traceEvents list, every
    event a known phase with integer microsecond timestamps."""
    doc = json.loads(pathlib.Path(path).read_text())
    assert isinstance(doc.get("traceEvents"), list), "traceEvents missing"
    for ev in doc["traceEvents"]:
        assert ev["ph"] in TRACE_PH, f"unknown phase {ev['ph']!r}"
        assert isinstance(ev["name"], str) and ev["name"]
        assert isinstance(ev["pid"], int) and isinstance(ev["tid"], int)
        if ev["ph"] == "M":
            continue
        assert isinstance(ev["ts"], int) and ev["ts"] >= 0, ev
        if ev["ph"] == "X":
            assert isinstance(ev["dur"], int) and ev["dur"] >= 0, ev
    return {"n_events": len(doc["traceEvents"]), "valid": True}


def run_correlation(cfg, params, masks, pack, *, capacity, max_len,
                    n_requests, n_faults, seed, trace_path) -> dict:
    """Seeded chaos run; every expectation comes from the injector's FIRED
    log, every observation from the trace/engine — zero shared bookkeeping
    between the two sides, so agreement means the wiring is honest."""
    violations: list[str] = []

    inj = FaultInjector(seed)
    planned = inj.poison_random(n_faults, max_step=n_requests * 4,
                                capacity=capacity)
    poisoned_rid = 1  # every admission attempt of rid 1 fails its prefill
    inj.poison_prefill(poisoned_rid)

    obs = _fresh_obs()
    engine = ServeEngine(cfg, params, capacity=capacity, max_len=max_len,
                         masks=masks, pack=pack, faults=inj, max_retries=1,
                         obs=obs)
    for r in burst_storm(cfg, n_requests, prompt_len=8, max_new_tokens=8,
                         seed=seed):
        engine.submit(r)
    _drain(engine)
    obs.trace.to_chrome(trace_path)
    trace = _validate_chrome(trace_path)

    # 1. quarantine instants == engine.quarantine_log, field for field
    got = [
        (e["args"]["step"], e["args"]["rid"], e["args"]["slot"],
         e["args"]["attempt"], e["args"]["where"])
        for e in obs.trace.find("quarantine")
    ]
    book = [tuple(q) for q in engine.quarantine_log]
    if sorted(got) != sorted(book):
        violations.append(
            f"trace quarantine instants {sorted(got)} != engine "
            f"quarantine_log {sorted(book)}"
        )

    # 2. every fired decode injection surfaced as a fault_injected instant
    fired_decode = [e for e in inj.log if e[0] == "decode"]
    instants = obs.trace.find("fault_injected")
    seen = {(e["args"]["step"], tuple(e["args"]["targeted"]))
            for e in instants}
    want = {(step, tuple(sorted(plan))) for _, step, plan in fired_decode}
    if seen != want:
        violations.append(
            f"fault_injected instants {sorted(seen)} != fired decode "
            f"injections {sorted(want)}"
        )

    # 3. decode quarantines == the union of the instants' ACTIVE hits (an
    # injection on a parked slot fires in the log but quarantines nobody)
    expect_decode = sorted(
        (e["args"]["step"], h["rid"], h["slot"], h["attempt"], "decode")
        for e in instants for h in e["args"]["active"]
    )
    got_decode = sorted(q for q in book if q[4] == "decode")
    if got_decode != expect_decode:
        violations.append(
            f"decode quarantines {got_decode} != injector-predicted "
            f"{expect_decode}"
        )

    # 4. each fired prefill injection (rid, attempt) -> one prefill
    # quarantine with the same key
    fired_prefill = sorted((e[1], e[2]) for e in inj.log
                           if e[0] == "prefill")
    got_prefill = sorted((q[1], q[3]) for q in book if q[4] == "prefill")
    if got_prefill != fired_prefill:
        violations.append(
            f"prefill quarantines {got_prefill} != fired prefill "
            f"injections {fired_prefill}"
        )
    if not fired_prefill:
        violations.append("prefill poisoning never fired — scenario is vacuous")

    # 5. retry instants: one per requeue the engine counted
    n_retry = len(obs.trace.find("retry"))
    if n_retry != engine.n_retries_total:
        violations.append(
            f"{n_retry} retry instants != n_retries_total "
            f"{engine.n_retries_total}"
        )

    # shed mini-storm: instants vs the queue's books
    obs2 = _fresh_obs()
    eng2 = ServeEngine(cfg, params, capacity=capacity, max_len=max_len,
                       masks=masks, pack=pack, obs=obs2,
                       queue_limit=n_requests, deadline=3.0)
    for r in burst_storm(cfg, n_requests * 2, prompt_len=8, max_new_tokens=8,
                         seed=seed):
        eng2.submit(r)
    _drain(eng2)
    shed_rids = sorted(r.rid for r in eng2.queue.done
                       if r.status is Status.SHED)
    instant_rids = sorted(e["args"]["rid"] for e in obs2.trace.find("shed"))
    if shed_rids != instant_rids:
        violations.append(
            f"shed instants {instant_rids} != SHED requests {shed_rids}"
        )
    if not shed_rids:
        violations.append("shed storm shed nothing — scenario is vacuous")

    return {
        "requests": n_requests,
        "planned_decode_faults": len(planned),
        "fired_decode": len(fired_decode),
        "fired_prefill": len(fired_prefill),
        "quarantined": engine.n_quarantined,
        "retries": engine.n_retries_total,
        "shed": len(shed_rids),
        "trace": dict(trace, path=str(trace_path)),
        "violations": violations,
    }


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="h2o-danube-1.8b")
    p.add_argument("--capacity", type=int, default=4)
    p.add_argument("--requests", type=int, default=24)
    p.add_argument("--arrival-rate", type=float, default=100.0)
    p.add_argument("--max-len", type=int, default=128)
    p.add_argument("--repeats", type=int, default=3)
    p.add_argument("--overhead-pct", type=float, default=3.0,
                   help="fail if instrumented throughput lags bare by more")
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--kernel", default=None,
                   choices=["dense", "masked", "block_sparse"])
    p.add_argument("--block", type=int, default=16)
    p.add_argument("--out", default="BENCH_obs.json")
    p.add_argument("--trace-out", default=None,
                   help="chaos-run Chrome trace (default: <out>.trace.json)")
    p.add_argument("--smoke-bench", action="store_true",
                   help="tiny workload for make verify (seconds, not minutes)")
    args = p.parse_args()

    if args.smoke_bench:
        args.requests = min(args.requests, 6)
        args.repeats = min(args.repeats, 2)
        gen_lens, prompt_lens = (4, 8, 16), (8, 16)
    else:
        gen_lens, prompt_lens = (8, 16, 32, 64), (16, 32)
    trace_path = pathlib.Path(
        args.trace_out or str(args.out) + ".trace.json"
    )
    pathlib.Path(args.out).parent.mkdir(parents=True, exist_ok=True)

    cfg = configure_kernel(
        get_config(args.arch, smoke=True), kernel=args.kernel, block=args.block
    )
    params, masks, pack = init_serving_state(cfg)

    reqs = staggered_requests(
        cfg, args.requests, prompt_lens=prompt_lens, gen_lens=gen_lens,
        arrival_rate=args.arrival_rate, seed=args.seed,
    )
    kw = dict(capacity=args.capacity, max_len=args.max_len,
              masks=masks, pack=pack)

    # retry a failed gate with doubled repeats: medians over more interleaved
    # runs squeeze out container noise, not a real per-event regression
    attempts = []
    repeats = args.repeats
    for _ in range(3):
        attempts.append(measure_overhead(cfg, params, reqs, repeats=repeats,
                                         **kw))
        if attempts[-1]["overhead_pct"] <= args.overhead_pct:
            break
        repeats *= 2
    best = min(attempts, key=lambda a: a["overhead_pct"])

    chaos = run_correlation(
        cfg, params, masks, pack, capacity=3, max_len=32,
        n_requests=8, n_faults=3, seed=args.seed, trace_path=trace_path,
    )

    violations = list(chaos["violations"])
    if not best["token_identical"]:
        violations.append(
            "instrumentation changed greedy token streams — obs must be "
            "host-side only"
        )
    gate_failed = best["overhead_pct"] > args.overhead_pct
    if gate_failed:
        violations.append(
            f"instrumented engine overhead {best['overhead_pct']:.2f}% > "
            f"{args.overhead_pct:.1f}% after {len(attempts)} attempt(s)"
        )

    out = {
        "meta": {
            "arch": cfg.name,
            "kernel": cfg.sparse.kernel,
            "capacity": args.capacity,
            "requests": args.requests,
            "overhead_gate_pct": args.overhead_pct,
            "seed": args.seed,
            "smoke_bench": bool(args.smoke_bench),
        },
        "overhead": {"attempts": attempts, "best": best},
        "chaos": chaos,
        "ok": not violations,
    }
    pathlib.Path(args.out).write_text(json.dumps(out, indent=1))
    print(f"bare:         {best['bare']['tok_per_s']:8.1f} tok/s")
    print(f"instrumented: {best['instrumented']['tok_per_s']:8.1f} tok/s "
          f"({best['overhead_pct']:+.2f}% overhead, gate "
          f"{args.overhead_pct:.1f}%, {len(attempts)} attempt(s))")
    print(f"tokens identical under instrumentation: "
          f"{best['token_identical']}")
    print(f"chaos: {chaos['fired_decode']} decode + {chaos['fired_prefill']} "
          f"prefill injections fired -> {chaos['quarantined']} quarantines, "
          f"{chaos['retries']} retries, {chaos['shed']} sheds; trace "
          f"{chaos['trace']['n_events']} events -> {chaos['trace']['path']}")
    print(f"-> {args.out}")
    if violations:
        for v in violations:
            print(f"VIOLATION: {v}")
        raise SystemExit(
            f"obs_bench: {len(violations)} violation(s) — see above"
        )
    print("observability overhead gate + correlation invariants hold")


if __name__ == "__main__":
    main()

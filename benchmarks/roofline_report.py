"""Roofline table from the dry-run artifacts (EXPERIMENTS.md section Roofline)."""
import json
import pathlib
import time


def run(quick=True):
    rows = []
    art_dir = pathlib.Path("artifacts/dryrun")
    if not art_dir.exists():
        return [{"name": "roofline/no_artifacts", "us_per_call": 0,
                 "derived": {"note": "run python -m repro.launch.dryrun --all first"}}]
    for p in sorted(art_dir.glob("*.json")):
        art = json.loads(p.read_text())
        if art.get("skipped"):
            rows.append({"name": f"roofline/{p.stem}", "us_per_call": 0,
                         "derived": {"skipped": art["skipped"]}})
            continue
        rl = art["roofline"]
        rows.append({
            "name": f"roofline/{p.stem}",
            "us_per_call": rl["step_lower_bound_s"] * 1e6,
            "derived": {
                "dominant": rl["dominant"],
                "compute_s": f"{rl['compute_s']:.3e}",
                "memory_s": f"{rl['memory_s']:.3e}",
                "collective_s": f"{rl['collective_s']:.3e}",
                "mfu_upper_bound": round(rl.get("mfu_upper_bound", 0), 4),
                "useful_flop_ratio": round(rl.get("useful_flop_ratio", 0), 3),
            },
        })
    return rows

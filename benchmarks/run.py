"""Benchmark harness: one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--full] [--only name]

Prints ``name,us_per_call,derived`` CSV (derived is a compact JSON object).
"""
import argparse
import json
import sys
import traceback

MODULES = [
    "flops_table",        # Fig 2-left / Table 4 FLOPs columns
    "methods_comparison", # Fig 2-top-right
    "sparsity_sweep",     # Fig 2-bottom-right / Fig 4-right
    "char_lm",            # Fig 4-left (paper GRU, §4.2)
    "distribution_sweep", # Fig 5-left / Appendix C
    "schedule_sweep",     # Fig 5-right / Fig 9 / Appendix G
    "interpolation",      # Fig 6
    "lottery",            # Table 3 / Appendix E
    "mlp_compression",    # Table 2 / Fig 7
    "kernel_bench",       # kernels vs refs
    "roofline_report",    # EXPERIMENTS.md roofline table
]


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--full", action="store_true", help="long (paper-scale) runs")
    p.add_argument("--only", default=None)
    args = p.parse_args()

    import importlib

    print("name,us_per_call,derived")
    failures = 0
    for name in MODULES:
        if args.only and args.only != name:
            continue
        try:
            mod = importlib.import_module(f".{name}", __package__)
            rows = mod.run(quick=not args.full)
            for r in rows:
                derived = json.dumps(r["derived"], separators=(",", ":"))
                print(f'{r["name"]},{r["us_per_call"]:.1f},"{derived}"')
            sys.stdout.flush()
        except Exception:
            failures += 1
            print(f"{name},0,\"ERROR\"")
            traceback.print_exc(file=sys.stderr)
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()

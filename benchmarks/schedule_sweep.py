"""Paper Fig 5-right + Fig 9 + Appendix G: delta_t x alpha x annealing."""
import time

from ._mlp import train_mlp


def run(quick=True):
    steps = 300 if quick else 1200
    rows = []
    for dt in (10, 25, 100):
        for alpha in (0.1, 0.3, 0.5):
            t0 = time.time()
            r = train_mlp(method="rigl", sparsity=0.9, steps=steps, delta_t=dt, alpha=alpha)
            rows.append({
                "name": f"schedule/dt{dt}_a{alpha}",
                "us_per_call": (time.time() - t0) * 1e6 / steps,
                "derived": {"final_loss": round(r.final_loss, 5)},
            })
    for decay in ("cosine", "constant", "linear", "inverse_power"):
        t0 = time.time()
        r = train_mlp(method="rigl", sparsity=0.9, steps=steps, decay=decay)
        rows.append({
            "name": f"annealing/{decay}",
            "us_per_call": (time.time() - t0) * 1e6 / steps,
            "derived": {"final_loss": round(r.final_loss, 5)},
        })
    return rows

"""Serving throughput/latency: continuous-batching engine vs lockstep batch.

  PYTHONPATH=src python -m benchmarks.serve_bench            # writes BENCH_serve.json
  PYTHONPATH=src python -m benchmarks.serve_bench --smoke-bench --out /tmp/b.json

A Poisson stream of staggered-length requests (prompts cycle one set of
lengths, generation lengths another — the realistic multi-user mix) is
served two ways:

  lockstep   the legacy fixed-batch driver (launch/serve.py::serve_session):
             requests group into capacity-sized cohorts in arrival order,
             every cohort pads to its LONGEST prompt and decodes to its
             LONGEST generation — finished rows burn decode steps until the
             slowest row completes.  Cohort k starts when its last member
             has arrived and cohort k-1 is done (a serial GPU/TPU).
  engine     the continuous-batching ServeEngine (serving/engine.py):
             per-slot positions + slot recycling admit the next request the
             step a slot frees, so no decode step is spent on padding.

Both paths serve the SAME requests on the same weights; tokens are counted
as the per-request max_new_tokens (the lockstep cohorts' padded extra
tokens are overhead, not useful output — that is the point).  Jits are
warmed before timing in both paths.  Output: BENCH_serve.json with
throughput (useful tok/s), p50/p95 request latency, decode-step counts and
the engine/lockstep speedup — the headline row asserts the slot-recycling
win (>= 1.5x on the default workload).

A second scenario benchmarks the PAGED engine's copy-on-write prefix reuse
(docs/serving.md#paged-kv-cache): N requests sharing one long prompt
template (512 tokens; 64 under --smoke-bench) with short random suffixes,
served by the paged engine WITH a prefix cache (template prefilled once,
every later admission maps its pages refcount++ and runs only the suffix)
vs the same paged engine WITHOUT one (every request prefills the template
from scratch).  Useful tokens are identical by construction — greedy decode
token streams match bit-for-bit — so the throughput ratio isolates the
prefill work the sharing skipped; the gate asserts >= 1.3x on the default
workload, and the JSON records the mid-flight shared-page refcounts (> 1 on
every fully-shared page while several sharers are in flight) plus
prefix-hit/fork counters as evidence the reuse was real, not incidental.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import time

import numpy as np

from repro.configs import get_config
from repro.launch.serve import (
    configure_kernel,
    init_serving_state,
    serve_session,
    staggered_requests,
)
from repro.obs import median_by, percentile
from repro.serving import Request, ServeEngine


def _median_by_throughput(runs):
    """Median run by tok_per_s — one noisy-container run (CPU throttling
    bursts on shared machines) must not decide the headline number."""
    return median_by(runs, "tok_per_s")


def _lockstep_run(cfg, params, reqs, capacity, repeats, *, masks=None, pack=None):
    """Serve ``reqs`` in capacity-sized cohorts, padded to the cohort max.

    The timeline is simulated from measured per-cohort wall times: cohort k
    starts at max(end of cohort k-1, last member's arrival); a request's
    latency is its cohort's end minus its own arrival.  Runs ``repeats``
    times (jits warmed first); returns the median-throughput run.
    """
    cohorts = [reqs[i : i + capacity] for i in range(0, len(reqs), capacity)]
    shapes = sorted({
        (len(c), max(r.prompt_len for r in c), max(r.max_new_tokens for r in c))
        for c in cohorts
    })
    for batch, pl, gen in shapes:  # warm the jits, untimed
        serve_session(cfg, params, batch=batch, prompt_len=pl, gen=gen,
                      masks=masks, pack=pack)

    def one():
        now = 0.0
        latencies, compute_s, steps = [], 0.0, 0
        for cohort in cohorts:
            batch = len(cohort)
            pl = max(r.prompt_len for r in cohort)
            gen = max(r.max_new_tokens for r in cohort)
            t0 = time.monotonic()
            serve_session(cfg, params, batch=batch, prompt_len=pl, gen=gen,
                          masks=masks, pack=pack)
            dt = time.monotonic() - t0
            compute_s += dt
            steps += gen - 1
            now = max(now, max(r.arrival for r in cohort)) + dt
            latencies.extend(now - r.arrival for r in cohort)
        toks = sum(r.max_new_tokens for r in reqs)
        lat = np.asarray(latencies)
        return {
            "requests": len(reqs),
            "tokens": toks,
            "wall_s": now,
            "compute_s": compute_s,
            "tok_per_s": toks / max(now, 1e-9),
            "decode_steps": steps,
            "latency_p50_s": percentile(lat, 50),
            "latency_p95_s": percentile(lat, 95),
        }

    return _median_by_throughput([one() for _ in range(repeats)])


def _engine_run(cfg, params, reqs, capacity, max_len, repeats, *,
                masks=None, pack=None):
    import copy

    def one(requests):
        engine = ServeEngine(cfg, params, capacity=capacity, max_len=max_len,
                             masks=masks, pack=pack)
        for r in requests:
            engine.submit(r)
        return engine.run()

    # warm every jit (per-length prefills + the decode step) on a throwaway
    # engine over cloned requests, then run the timed engines fresh
    one(copy.deepcopy(reqs))
    return _median_by_throughput(
        [one(copy.deepcopy(reqs)) for _ in range(repeats)]
    )


def _prefix_requests(cfg, n, prefix_len, gen, seed, *, share):
    """``n`` requests over ONE shared prompt template + random suffixes;
    ``share`` toggles the declaration the prefix cache keys on."""
    rng = np.random.default_rng(seed)
    prefix = rng.integers(0, cfg.vocab_size, size=prefix_len).astype(np.int32)
    reqs = []
    for i in range(n):
        suffix = rng.integers(
            0, cfg.vocab_size, size=int(rng.integers(4, 12))
        ).astype(np.int32)
        reqs.append(Request(
            rid=i, tokens=np.concatenate([prefix, suffix]),
            max_new_tokens=gen,
            share_prefix_len=prefix_len if share else 0,
        ))
    return reqs


def _prefix_scenario(args):
    """Paged engine with vs without the COW prefix cache on a shared-template
    workload; returns the JSON block (both sides' stats + sharing evidence).

    All-global transformer config (the sharing eligibility class) — the
    headline ``--arch`` stays on the staggered scenario above.
    """
    import copy

    cfg = configure_kernel(
        get_config("mistral-large-123b", smoke=True), kernel=args.kernel,
        block=args.block, attn_kernel=args.attn_kernel,
    )
    params, masks, pack = init_serving_state(cfg)
    # max_len is deliberately OFF the 64-wide attention q-chunk grid (but on
    # the page grid): the engine now rounds capped prompt buckets down to the
    # q-chunk multiple itself (engine._chunk_capped_len), so the bench no
    # longer has to pick aligned deployment shapes to dodge ragged prefills
    if args.smoke_bench:
        n, prefix_len, gen, max_len = 4, 64, 4, 144
    else:
        n, prefix_len, gen, max_len = 8, 512, 32, 592
    page = 16
    mk = lambda share: _prefix_requests(
        cfg, n, prefix_len, gen, args.seed, share=share
    )

    def one(share):
        engine = ServeEngine(
            cfg, params, capacity=4, max_len=max_len, masks=masks, pack=pack,
            paged=True, page_size=page, prefix_cache=4 if share else 0,
        )
        for r in mk(share):
            engine.submit(r)
        return engine.run(), engine

    for share in (False, True):  # warm both sides' jits, untimed
        one(share)
    runs = {
        share: _median_by_throughput(
            [one(share)[0] for _ in range(args.repeats)]
        )
        for share in (False, True)
    }
    # token streams must be identical — sharing trades work, never output
    streams = {}
    for share in (False, True):
        _, eng = one(share)
        streams[share] = {
            r.rid: list(r.generated) for r in eng.queue.done
        }
    assert streams[False] == streams[True], (
        "prefix sharing changed greedy token streams"
    )
    # sharing evidence, captured MID-FLIGHT: admit the workload, step once,
    # and read the registered template pages' refcounts — cache hold + one
    # per in-flight sharer on every fully-shared page
    eng = ServeEngine(
        cfg, params, capacity=4, max_len=max_len, masks=masks, pack=pack,
        paged=True, page_size=page, prefix_cache=4,
    )
    for r in mk(True):
        eng.submit(r)
    eng.step(0.0)
    entry = next(iter(eng._prefix_entries.values()))
    refcounts = [int(eng.pools["global"].refcount[p]) for p in entry.pages]
    eng.check_pool_accounting()
    while len(eng.queue) or eng.active.any():
        eng.step(0.0)
    stats = eng.stats(0.0)

    speedup = (runs[True]["tok_per_s"]
               / max(runs[False]["tok_per_s"], 1e-9))
    return {
        "meta": {
            "arch": cfg.name,
            "requests": n,
            "prefix_len": prefix_len,
            "gen": gen,
            "page_size": page,
            "capacity": 4,
            "max_len": max_len,
            "repeats": args.repeats,
        },
        "paged_no_sharing": runs[False],
        "paged_sharing": runs[True],
        "throughput_speedup": speedup,
        "evidence": {
            "shared_page_refcounts_mid_flight": refcounts,
            "prefix_hits": stats["prefix_hits"],
            "prefix_misses": stats["prefix_misses"],
            "kv_forks": stats["kv_forks"],
        },
    }


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="h2o-danube-1.8b")
    p.add_argument("--capacity", type=int, default=4)
    p.add_argument("--requests", type=int, default=24)
    p.add_argument("--arrival-rate", type=float, default=100.0,
                   help="Poisson req/s (dense enough that arrivals are not "
                   "the bottleneck; latency still sees the queueing)")
    p.add_argument("--max-len", type=int, default=128)
    p.add_argument("--repeats", type=int, default=3,
                   help="timed repeats per side; the median-throughput run "
                   "is reported (noisy shared-CPU robustness)")
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--kernel", default=None,
                   choices=["dense", "masked", "block_sparse"])
    p.add_argument("--block", type=int, default=16)
    p.add_argument("--attn-kernel", default=None,
                   choices=["dense", "flash", "flash_tight"])
    p.add_argument("--out", default="BENCH_serve.json")
    p.add_argument("--smoke-bench", action="store_true",
                   help="tiny workload for make verify (seconds, not minutes)")
    args = p.parse_args()

    cfg = configure_kernel(
        get_config(args.arch, smoke=True), kernel=args.kernel,
        block=args.block, attn_kernel=args.attn_kernel,
    )

    if args.smoke_bench:
        args.requests = min(args.requests, 6)
        gen_lens, prompt_lens = (4, 8, 16), (8, 16)
    else:
        gen_lens, prompt_lens = (8, 16, 32, 64), (16, 32)

    params, masks, pack = init_serving_state(cfg)
    kw = dict(masks=masks, pack=pack)

    reqs = staggered_requests(
        cfg, args.requests, prompt_lens=prompt_lens, gen_lens=gen_lens,
        arrival_rate=args.arrival_rate, seed=args.seed,
    )
    lock = _lockstep_run(cfg, params, reqs, args.capacity, args.repeats, **kw)
    eng = _engine_run(cfg, params, reqs, args.capacity, args.max_len,
                      args.repeats, **kw)
    prefix = _prefix_scenario(args)

    speedup = eng["tok_per_s"] / max(lock["tok_per_s"], 1e-9)
    out = {
        "meta": {
            "arch": cfg.name,
            "kernel": cfg.sparse.kernel,
            "capacity": args.capacity,
            "requests": args.requests,
            "arrival_rate": args.arrival_rate,
            "prompt_lens": list(prompt_lens),
            "gen_lens": list(gen_lens),
            "repeats": args.repeats,
            "seed": args.seed,
            "smoke_bench": bool(args.smoke_bench),
            # engine decode now also reduces the per-slot finite flag in-jit
            # (NaN-slot quarantine, docs/serving.md#failure-model) — recorded
            # so regressions in this number can be attributed to it
            "finite_check": True,
        },
        "lockstep": lock,
        "engine": eng,
        "throughput_speedup": speedup,
        "shared_prefix": prefix,
    }
    pathlib.Path(args.out).write_text(json.dumps(out, indent=1))
    print(f"lockstep: {lock['tok_per_s']:8.1f} tok/s  "
          f"p50 {lock['latency_p50_s']*1e3:7.1f} ms  "
          f"p95 {lock['latency_p95_s']*1e3:7.1f} ms  "
          f"steps {lock['decode_steps']}")
    print(f"engine:   {eng['tok_per_s']:8.1f} tok/s  "
          f"p50 {eng['latency_p50_s']*1e3:7.1f} ms  "
          f"p95 {eng['latency_p95_s']*1e3:7.1f} ms  "
          f"steps {eng['decode_steps']}")
    print(f"throughput speedup: {speedup:.2f}x -> {args.out}")
    ps = prefix["throughput_speedup"]
    ev = prefix["evidence"]
    print(f"shared-prefix: {prefix['paged_no_sharing']['tok_per_s']:8.1f} -> "
          f"{prefix['paged_sharing']['tok_per_s']:8.1f} tok/s "
          f"({ps:.2f}x)  hits {ev['prefix_hits']}  forks {ev['kv_forks']}  "
          f"refcounts {ev['shared_page_refcounts_mid_flight']}")
    if not args.smoke_bench and speedup < 1.5:
        raise SystemExit(
            f"continuous batching speedup {speedup:.2f}x < 1.5x — slot "
            "recycling should beat padding-to-slowest on this workload"
        )
    if not args.smoke_bench and ps < 1.3:
        raise SystemExit(
            f"shared-prefix speedup {ps:.2f}x < 1.3x — COW prefix reuse "
            "should skip most of the template prefill on this workload"
        )


if __name__ == "__main__":
    main()

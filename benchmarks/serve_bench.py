"""Serving throughput/latency: continuous-batching engine vs lockstep batch.

  PYTHONPATH=src python -m benchmarks.serve_bench            # writes BENCH_serve.json
  PYTHONPATH=src python -m benchmarks.serve_bench --smoke-bench --out /tmp/b.json

A Poisson stream of staggered-length requests (prompts cycle one set of
lengths, generation lengths another — the realistic multi-user mix) is
served two ways:

  lockstep   the legacy fixed-batch driver (launch/serve.py::serve_session):
             requests group into capacity-sized cohorts in arrival order,
             every cohort pads to its LONGEST prompt and decodes to its
             LONGEST generation — finished rows burn decode steps until the
             slowest row completes.  Cohort k starts when its last member
             has arrived and cohort k-1 is done (a serial GPU/TPU).
  engine     the continuous-batching ServeEngine (serving/engine.py):
             per-slot positions + slot recycling admit the next request the
             step a slot frees, so no decode step is spent on padding.

Both paths serve the SAME requests on the same weights; tokens are counted
as the per-request max_new_tokens (the lockstep cohorts' padded extra
tokens are overhead, not useful output — that is the point).  Jits are
warmed before timing in both paths.  Output: BENCH_serve.json with
throughput (useful tok/s), p50/p95 request latency, decode-step counts and
the engine/lockstep speedup — the headline row asserts the slot-recycling
win (>= 1.5x on the default workload).
"""
from __future__ import annotations

import argparse
import json
import pathlib
import time

import numpy as np

from repro.configs import get_config
from repro.launch.serve import (
    configure_kernel,
    init_serving_state,
    serve_session,
    staggered_requests,
)
from repro.serving import ServeEngine


def _median_by_throughput(runs):
    """Median run by tok_per_s — one noisy-container run (CPU throttling
    bursts on shared machines) must not decide the headline number."""
    runs = sorted(runs, key=lambda r: r["tok_per_s"])
    return runs[len(runs) // 2]


def _lockstep_run(cfg, params, reqs, capacity, repeats, *, masks=None, pack=None):
    """Serve ``reqs`` in capacity-sized cohorts, padded to the cohort max.

    The timeline is simulated from measured per-cohort wall times: cohort k
    starts at max(end of cohort k-1, last member's arrival); a request's
    latency is its cohort's end minus its own arrival.  Runs ``repeats``
    times (jits warmed first); returns the median-throughput run.
    """
    cohorts = [reqs[i : i + capacity] for i in range(0, len(reqs), capacity)]
    shapes = sorted({
        (len(c), max(r.prompt_len for r in c), max(r.max_new_tokens for r in c))
        for c in cohorts
    })
    for batch, pl, gen in shapes:  # warm the jits, untimed
        serve_session(cfg, params, batch=batch, prompt_len=pl, gen=gen,
                      masks=masks, pack=pack)

    def one():
        now = 0.0
        latencies, compute_s, steps = [], 0.0, 0
        for cohort in cohorts:
            batch = len(cohort)
            pl = max(r.prompt_len for r in cohort)
            gen = max(r.max_new_tokens for r in cohort)
            t0 = time.monotonic()
            serve_session(cfg, params, batch=batch, prompt_len=pl, gen=gen,
                          masks=masks, pack=pack)
            dt = time.monotonic() - t0
            compute_s += dt
            steps += gen - 1
            now = max(now, max(r.arrival for r in cohort)) + dt
            latencies.extend(now - r.arrival for r in cohort)
        toks = sum(r.max_new_tokens for r in reqs)
        lat = np.asarray(latencies)
        return {
            "requests": len(reqs),
            "tokens": toks,
            "wall_s": now,
            "compute_s": compute_s,
            "tok_per_s": toks / max(now, 1e-9),
            "decode_steps": steps,
            "latency_p50_s": float(np.percentile(lat, 50)),
            "latency_p95_s": float(np.percentile(lat, 95)),
        }

    return _median_by_throughput([one() for _ in range(repeats)])


def _engine_run(cfg, params, reqs, capacity, max_len, repeats, *,
                masks=None, pack=None):
    import copy

    def one(requests):
        engine = ServeEngine(cfg, params, capacity=capacity, max_len=max_len,
                             masks=masks, pack=pack)
        for r in requests:
            engine.submit(r)
        return engine.run()

    # warm every jit (per-length prefills + the decode step) on a throwaway
    # engine over cloned requests, then run the timed engines fresh
    one(copy.deepcopy(reqs))
    return _median_by_throughput(
        [one(copy.deepcopy(reqs)) for _ in range(repeats)]
    )


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="h2o-danube-1.8b")
    p.add_argument("--capacity", type=int, default=4)
    p.add_argument("--requests", type=int, default=24)
    p.add_argument("--arrival-rate", type=float, default=100.0,
                   help="Poisson req/s (dense enough that arrivals are not "
                   "the bottleneck; latency still sees the queueing)")
    p.add_argument("--max-len", type=int, default=128)
    p.add_argument("--repeats", type=int, default=3,
                   help="timed repeats per side; the median-throughput run "
                   "is reported (noisy shared-CPU robustness)")
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--kernel", default=None,
                   choices=["dense", "masked", "block_sparse"])
    p.add_argument("--block", type=int, default=16)
    p.add_argument("--attn-kernel", default=None,
                   choices=["dense", "flash", "flash_tight"])
    p.add_argument("--out", default="BENCH_serve.json")
    p.add_argument("--smoke-bench", action="store_true",
                   help="tiny workload for make verify (seconds, not minutes)")
    args = p.parse_args()

    cfg = configure_kernel(
        get_config(args.arch, smoke=True), kernel=args.kernel,
        block=args.block, attn_kernel=args.attn_kernel,
    )

    if args.smoke_bench:
        args.requests = min(args.requests, 6)
        gen_lens, prompt_lens = (4, 8, 16), (8, 16)
    else:
        gen_lens, prompt_lens = (8, 16, 32, 64), (16, 32)

    params, masks, pack = init_serving_state(cfg)
    kw = dict(masks=masks, pack=pack)

    reqs = staggered_requests(
        cfg, args.requests, prompt_lens=prompt_lens, gen_lens=gen_lens,
        arrival_rate=args.arrival_rate, seed=args.seed,
    )
    lock = _lockstep_run(cfg, params, reqs, args.capacity, args.repeats, **kw)
    eng = _engine_run(cfg, params, reqs, args.capacity, args.max_len,
                      args.repeats, **kw)

    speedup = eng["tok_per_s"] / max(lock["tok_per_s"], 1e-9)
    out = {
        "meta": {
            "arch": cfg.name,
            "kernel": cfg.sparse.kernel,
            "capacity": args.capacity,
            "requests": args.requests,
            "arrival_rate": args.arrival_rate,
            "prompt_lens": list(prompt_lens),
            "gen_lens": list(gen_lens),
            "repeats": args.repeats,
            "seed": args.seed,
            "smoke_bench": bool(args.smoke_bench),
            # engine decode now also reduces the per-slot finite flag in-jit
            # (NaN-slot quarantine, docs/serving.md#failure-model) — recorded
            # so regressions in this number can be attributed to it
            "finite_check": True,
        },
        "lockstep": lock,
        "engine": eng,
        "throughput_speedup": speedup,
    }
    pathlib.Path(args.out).write_text(json.dumps(out, indent=1))
    print(f"lockstep: {lock['tok_per_s']:8.1f} tok/s  "
          f"p50 {lock['latency_p50_s']*1e3:7.1f} ms  "
          f"p95 {lock['latency_p95_s']*1e3:7.1f} ms  "
          f"steps {lock['decode_steps']}")
    print(f"engine:   {eng['tok_per_s']:8.1f} tok/s  "
          f"p50 {eng['latency_p50_s']*1e3:7.1f} ms  "
          f"p95 {eng['latency_p95_s']*1e3:7.1f} ms  "
          f"steps {eng['decode_steps']}")
    print(f"throughput speedup: {speedup:.2f}x -> {args.out}")
    if not args.smoke_bench and speedup < 1.5:
        raise SystemExit(
            f"continuous batching speedup {speedup:.2f}x < 1.5x — slot "
            "recycling should beat padding-to-slowest on this workload"
        )


if __name__ == "__main__":
    main()

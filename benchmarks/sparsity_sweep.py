"""Paper Fig 2-bottom-right / Fig 4-right: quality across sparsity levels."""
import time

from ._mlp import train_mlp


def run(quick=True):
    steps = 300 if quick else 1200
    rows = []
    for s in (0.5, 0.8, 0.9, 0.95):
        for m in ("rigl", "static", "pruning"):
            t0 = time.time()
            r = train_mlp(method=m, sparsity=s, steps=steps, seed=0)
            rows.append({
                "name": f"sparsity_sweep/{m}_s{s}",
                "us_per_call": (time.time() - t0) * 1e6 / steps,
                "derived": {"final_loss": round(r.final_loss, 5),
                            "train_flops_mult": round(r.train_flops_mult, 4)},
            })
    return rows

"""Elastic restart: checkpoint on one device topology, resume on another.

  PYTHONPATH=src python examples/elastic_restart.py

Phase 1 trains on 1 device and checkpoints. Phase 2 (a subprocess with 8
fake devices) restores the SAME checkpoint onto a 2x4 (data x model) mesh via
restore(shardings=...) and continues training — the cluster shrank/grew and
training just continues.
"""
import json
import os
import pathlib
import subprocess
import sys
import tempfile
import textwrap

workdir = pathlib.Path(tempfile.mkdtemp(prefix="elastic_"))
env = dict(os.environ)
env["PYTHONPATH"] = str(pathlib.Path(__file__).resolve().parents[1] / "src")

PHASE1 = textwrap.dedent("""
    import dataclasses, jax
    from repro.configs import get_config
    from repro.configs.base import SparseConfig
    from repro.launch.train import train_loop
    cfg = dataclasses.replace(get_config("h2o-danube-1.8b", smoke=True),
                              sparse=SparseConfig(sparsity=0.8, delta_t=20))
    train_loop(cfg, steps=40, batch=8, seq=64, workdir=r"%s", ckpt_every=20, log_every=20)
    print("phase1 devices:", len(jax.devices()))
""")

PHASE2 = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses, jax
    from repro.configs import get_config
    from repro.configs.base import SparseConfig
    from repro.checkpoint import restore
    from repro.data import batch_for
    from repro.launch.sharding import batch_shardings, state_shardings
    from repro.optim import LRSchedule, OptConfig
    from repro.training import init_train_state, make_train_step

    cfg = dataclasses.replace(get_config("h2o-danube-1.8b", smoke=True),
                              sparse=SparseConfig(sparsity=0.8, delta_t=20))
    opt = OptConfig(kind="adam", grad_clip=1.0, weight_decay=0.0)
    like, axes, _ = init_train_state(jax.random.PRNGKey(0), cfg, opt)
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    sh = state_shardings(like, axes, mesh)
    state, step = restore(like, r"%s/ckpt", shardings=sh)
    print(f"phase2: restored step {step} onto {len(jax.devices())} devices, mesh {dict(mesh.shape)}")
    fn = jax.jit(make_train_step(cfg, opt, LRSchedule(base_lr=1e-3)))
    for t in range(step, step + 10):
        b = jax.device_put(batch_for(cfg, t, 8, 64, learnable=True), batch_shardings(
            batch_for(cfg, t, 8, 64, learnable=True), mesh))
        state, m = fn(state, b)
    print(f"phase2: continued to step {int(state['step'])} loss {float(m['loss']):.4f}")
""")

for i, script in enumerate((PHASE1 % workdir, PHASE2 % workdir), 1):
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=900)
    if out.returncode != 0:
        print(out.stderr[-2000:])
        sys.exit(1)
    print("\n".join(l for l in out.stdout.splitlines() if "phase" in l or "train" in l))
print("elastic restart OK: 1 device -> 2x4 mesh")

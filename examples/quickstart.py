"""Quickstart: train a sparse LM with RigL in ~60 lines.

  PYTHONPATH=src python examples/quickstart.py

Shows the whole public API surface: config -> sparse state -> train/rigl
steps -> mask evolution -> serving through the same masks.
"""
import dataclasses

import jax

from repro.configs import get_config
from repro.configs.base import SparseConfig
from repro.core import apply_masks, mask_stats
from repro.data import batch_for
from repro.launch.serve import serve_session
from repro.optim import LRSchedule, OptConfig
from repro.training import init_train_state, make_algo, make_rigl_step, make_train_step

STEPS = 200

cfg = get_config("h2o-danube-1.8b", smoke=True)
cfg = dataclasses.replace(
    cfg, sparse=SparseConfig(sparsity=0.8, method="rigl", delta_t=20, alpha=0.3)
)
opt = OptConfig(kind="adam", grad_clip=1.0, weight_decay=0.0)
lr = LRSchedule(base_lr=3e-3, warmup_steps=20, total_steps=STEPS)
algo = make_algo(cfg, STEPS)

state, axes, flags = init_train_state(jax.random.PRNGKey(0), cfg, opt)
print(f"model: {cfg.name}  sparsity target: {cfg.sparse.sparsity}")
print(f"initial nnz: {mask_stats(state['masks'])['nnz']}")

train_step = jax.jit(make_train_step(cfg, opt, lr), donate_argnums=0)
rigl_step = jax.jit(make_rigl_step(cfg, algo, lr), donate_argnums=0)

masks0 = jax.tree_util.tree_map(
    lambda m: None if m is None else m.copy(), state["masks"],
    is_leaf=lambda x: x is None,
)
for t in range(STEPS):
    batch = batch_for(cfg, t, 8, 64, learnable=True)
    if t > 0 and t % cfg.sparse.delta_t == 0 and t < algo.schedule.t_end:
        state, m = rigl_step(state, batch)   # drop lowest |w|, grow highest |g|
    else:
        state, m = train_step(state, batch)  # masked SGD on active connections
    if t % 50 == 0 or t == STEPS - 1:
        print(f"step {t:4d} loss {float(m['loss']):.4f}")

stats = mask_stats(state["masks"])
changed = sum(
    int((a != b).sum())
    for a, b in zip(jax.tree_util.tree_leaves(masks0), jax.tree_util.tree_leaves(state["masks"]))
)
print(f"final sparsity {stats['sparsity']:.3f} (nnz preserved: {stats['nnz']})")
print(f"connections rewired by RigL: {changed}")

toks, sstats = serve_session(
    cfg, apply_masks(state["params"], state["masks"]), batch=2, prompt_len=32, gen=8
)
print(f"served {toks.shape} tokens at {sstats['tok_per_s']:.1f} tok/s")

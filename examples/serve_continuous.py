"""Continuous batching in ~40 lines: 8 staggered requests, 4 slots.

  PYTHONPATH=src python examples/serve_continuous.py --arch h2o-danube-1.8b

Eight requests with different prompt/generation lengths stream through a
capacity-4 ServeEngine: the first four admit immediately, the rest enter as
slots free up — no request waits for the slowest row of a fixed batch.  The
per-request latency print shows short requests finishing (and recycling
their slot) while long ones are still decoding.
"""
import argparse

import jax
import numpy as np

from repro.configs import get_config
from repro.core import apply_masks
from repro.optim import OptConfig
from repro.serving import Request, ServeEngine
from repro.training import init_train_state

p = argparse.ArgumentParser()
p.add_argument("--arch", default="h2o-danube-1.8b")
p.add_argument("--capacity", type=int, default=4)
args = p.parse_args()

cfg = get_config(args.arch, smoke=True)
state, _, _ = init_train_state(jax.random.PRNGKey(0), cfg, OptConfig())
weights = apply_masks(state["params"], state["masks"])  # serve THROUGH the masks

engine = ServeEngine(cfg, weights, capacity=args.capacity, max_len=96)
rng = np.random.default_rng(0)
shapes = [(4, 8), (12, 32), (6, 4), (20, 16), (8, 48), (16, 8), (5, 24), (10, 12)]
for rid, (prompt_len, gen) in enumerate(shapes):
    engine.submit(
        Request(
            rid=rid,
            tokens=rng.integers(0, cfg.vocab_size, size=prompt_len).astype(np.int32),
            max_new_tokens=gen,
        )
    )

stats = engine.run()
print(f"arch={cfg.name}  capacity={args.capacity}  "
      f"{stats['requests']} requests, {stats['tokens']} tokens in "
      f"{stats['wall_s']:.2f}s ({stats['tok_per_s']:.1f} tok/s, "
      f"{stats['decode_steps']} decode steps)")
for req in sorted(engine.queue.done, key=lambda r: r.rid):
    print(f"  req {req.rid}: prompt {req.prompt_len:2d} gen "
          f"{len(req.generated):2d}  latency {req.latency*1e3:7.1f} ms")

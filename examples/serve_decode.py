"""Batched sparse serving: prefill a prompt batch, decode with KV caches.

  PYTHONPATH=src python examples/serve_decode.py --arch hymba-1.5b

Windowed (SWA) and recurrent (xLSTM/SSM) caches demonstrate the long-context
decode path (the long_500k dry-run cells use exactly this code).
"""
import argparse

import jax

from repro.configs import get_config
from repro.core import apply_masks
from repro.launch.serve import serve_session
from repro.optim import OptConfig
from repro.training import init_train_state

p = argparse.ArgumentParser()
p.add_argument("--arch", default="hymba-1.5b")
p.add_argument("--batch", type=int, default=4)
p.add_argument("--prompt-len", type=int, default=48)
p.add_argument("--gen", type=int, default=24)
args = p.parse_args()

cfg = get_config(args.arch, smoke=True)
state, _, _ = init_train_state(jax.random.PRNGKey(0), cfg, OptConfig())
weights = apply_masks(state["params"], state["masks"])  # serve THROUGH the masks

toks, stats = serve_session(
    cfg, weights, batch=args.batch, prompt_len=args.prompt_len, gen=args.gen
)
print(f"arch={cfg.name} generated {toks.shape[1]} tokens x {toks.shape[0]} seqs")
print(f"prefill {stats['prefill_s']*1e3:.1f} ms | {stats['tok_per_s']:.1f} tok/s decode")

"""End-to-end driver: train a ~100M-parameter transformer with RigL.

  PYTHONPATH=src python examples/train_lm.py               # ~15M, fast demo
  PYTHONPATH=src python examples/train_lm.py --full        # ~100M, few hundred steps

Uses the production train loop (checkpointing, fault tolerance) on a real
byte-level corpus. The same config scales to the 16x16 pod via
launch/sharding (see launch/dryrun.py).
"""
import argparse
import dataclasses

import jax

from repro.configs.base import ModelConfig, SparseConfig
from repro.core import mask_stats
from repro.data import byte_corpus, text_batch
from repro.launch.train import train_loop
from repro.models import lm_loss
from repro.optim import LRSchedule, OptConfig

p = argparse.ArgumentParser()
p.add_argument("--full", action="store_true", help="~100M params, slower")
p.add_argument("--steps", type=int, default=None)
p.add_argument("--workdir", default="/tmp/repro_lm")
args = p.parse_args()

if args.full:  # ~100M params: 12L x d512 x ff2048, byte vocab
    cfg = ModelConfig(
        name="bytelm-100m", family="dense", n_layers=12, d_model=768,
        n_heads=12, n_kv_heads=4, head_dim=64, d_ff=3072, vocab_size=256,
        tie_embeddings=True, q_chunk=256, remat=False,
        sparse=SparseConfig(sparsity=0.8, method="rigl", delta_t=50),
    )
    steps = args.steps or 300
    batch, seq = 4, 256
else:
    cfg = ModelConfig(
        name="bytelm-15m", family="dense", n_layers=6, d_model=384,
        n_heads=6, n_kv_heads=2, head_dim=64, d_ff=1536, vocab_size=256,
        tie_embeddings=True, q_chunk=256, remat=False,
        sparse=SparseConfig(sparsity=0.8, method="rigl", delta_t=50),
    )
    steps = args.steps or 200
    batch, seq = 8, 128

corpus = byte_corpus(".")
print(f"corpus: {len(corpus):,} bytes")

import repro.data.synthetic as synth
_orig = synth.batch_for
def corpus_batches(cfg_, step, b, s, **kw):
    import jax.numpy as jnp
    d = text_batch(step, b, s, corpus=corpus)
    return {k: jnp.asarray(v) for k, v in d.items()}
import repro.launch.train as T
T.batch_for = corpus_batches  # route the driver to real text

state, log = train_loop(
    cfg, steps=steps, batch=batch, seq=seq, workdir=args.workdir,
    opt_cfg=OptConfig(kind="adam", grad_clip=1.0, weight_decay=1e-4),
    lr_sched=LRSchedule(base_lr=1e-3, warmup_steps=min(50, steps // 4),
                        total_steps=steps),
    ckpt_every=100, log_every=25,
)
n_params = sum(x.size for x in jax.tree_util.tree_leaves(state["params"]))
print(f"params: {n_params/1e6:.1f}M  final sparsity: {mask_stats(state['masks'])['sparsity']:.3f}")
print(f"loss: {log[0]['loss']:.3f} -> {log[-1]['loss']:.3f} (bits/byte {log[-1]['loss']/0.6931:.2f})")

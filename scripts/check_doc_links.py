#!/usr/bin/env python3
"""Doc-link check: every file referenced from README.md / docs/*.md exists.

Catches the classic docs-rot failure where a refactor moves or deletes a file
that the docs still point at.  Two kinds of references are checked:

  * markdown links ``[text](path)`` with a relative, non-URL target
    (resolved against the file containing the link; ``#anchors`` stripped);
  * backticked repo paths like ``src/repro/core/pack.py`` or ``tests/``.

Exits nonzero listing every missing target.  Run via ``make docs-check`` or
as part of ``make verify``.
"""
from __future__ import annotations

import pathlib
import re
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent

MD_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
# directory-qualified paths are root-relative; bare names are only treated as
# root files for doc-ish extensions (`ref.py` etc. are module mentions)
CODE_PATH = re.compile(
    r"`((?:src|tests|benchmarks|examples|docs|scripts)/[A-Za-z0-9_./-]*"
    r"|[A-Za-z0-9_.-]+\.(?:md|json|txt))`"
)


def doc_files():
    yield from sorted(ROOT.glob("*.md"))
    yield from sorted(ROOT.glob("docs/*.md"))


def check_file(md: pathlib.Path) -> list[str]:
    text = md.read_text()
    missing = []
    for target in MD_LINK.findall(text):
        if target.startswith(("http://", "https://", "mailto:", "#")):
            continue
        rel = target.split("#", 1)[0]
        if not rel:
            continue
        resolved = (md.parent / rel).resolve()
        if not resolved.exists():
            missing.append(f"{md.relative_to(ROOT)}: link target {target!r}")
    for target in CODE_PATH.findall(text):
        # backticked paths are repo-root relative by convention
        if not (ROOT / target).exists():
            missing.append(f"{md.relative_to(ROOT)}: code path `{target}`")
    return missing


def main() -> int:
    missing = []
    n = 0
    for md in doc_files():
        n += 1
        missing.extend(check_file(md))
    if missing:
        print(f"doc-link check FAILED ({len(missing)} missing targets):")
        for m in missing:
            print(f"  {m}")
        return 1
    print(f"doc-link check OK ({n} markdown files scanned)")
    return 0


if __name__ == "__main__":
    sys.exit(main())

#!/usr/bin/env python3
"""Doc-link check: every file/symbol referenced from *.md docs exists.

Catches the classic docs-rot failure where a refactor moves or deletes a file
(or renames a function) that the docs still point at.  Three kinds of
references are checked:

  * markdown links ``[text](path)`` with a relative, non-URL target
    (resolved against the file containing the link; ``#anchors`` stripped);
  * backticked repo paths like ``src/repro/core/pack.py`` or ``tests/``;
  * backticked code references ``path.py::symbol`` (e.g.
    ``training/steps.py::make_train_step``): the path resolves repo-root
    relative or ``src/repro``-relative, and ``symbol`` (its first dotted
    component) must be defined in that file as a ``def``, ``class`` or
    module-level assignment.  This is what keeps prose like the dispatch
    coverage matrix in docs/kernels.md from drifting away from refactors.

Exits nonzero listing every missing target.  Run via ``make docs-check`` or
as part of ``make verify``.
"""
from __future__ import annotations

import pathlib
import re
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent

MD_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
# directory-qualified paths are root-relative; bare names are only treated as
# root files for doc-ish extensions (`ref.py` etc. are module mentions)
CODE_PATH = re.compile(
    r"`((?:src|tests|benchmarks|examples|docs|scripts)/[A-Za-z0-9_./-]*"
    r"|[A-Za-z0-9_.-]+\.(?:md|json|txt))`"
)
# `path/to/file.py::symbol` (symbol may be dotted: Class.attr checks Class)
SYM_REF = re.compile(
    r"`([A-Za-z0-9_][A-Za-z0-9_./-]*\.py)::([A-Za-z_][A-Za-z0-9_.]*)`"
)


# transient per-PR task/review files, not repo docs — their prose may
# reference symbols loosely (e.g. nested closures) and must not gate verify
SKIP = {"ISSUE.md", "REVIEW.md"}


def doc_files():
    yield from (p for p in sorted(ROOT.glob("*.md")) if p.name not in SKIP)
    yield from sorted(ROOT.glob("docs/*.md"))


def _resolve_py(path: str) -> pathlib.Path | None:
    """Resolve a ::symbol path root-relative, then src/repro-relative."""
    for base in (ROOT, ROOT / "src" / "repro"):
        p = base / path
        if p.exists():
            return p
    return None


def _symbol_defined(py: pathlib.Path, symbol: str) -> bool:
    """True iff the file defines ``symbol``'s first dotted component at the
    top level (def/class/assignment — a regex heuristic, no import needed)."""
    head = re.escape(symbol.split(".")[0])
    text = py.read_text()
    pat = re.compile(
        rf"^(?:def\s+{head}\b|class\s+{head}\b|{head}\s*[:=])", re.M
    )
    return bool(pat.search(text))


def check_file(md: pathlib.Path) -> list[str]:
    text = md.read_text()
    missing = []
    for target in MD_LINK.findall(text):
        if target.startswith(("http://", "https://", "mailto:", "#")):
            continue
        rel = target.split("#", 1)[0]
        if not rel:
            continue
        resolved = (md.parent / rel).resolve()
        if not resolved.exists():
            missing.append(f"{md.relative_to(ROOT)}: link target {target!r}")
    for target in CODE_PATH.findall(text):
        # backticked paths are repo-root relative by convention
        # (`path::symbol` refs never match CODE_PATH — SYM_REF handles them)
        if not (ROOT / target).exists():
            missing.append(f"{md.relative_to(ROOT)}: code path `{target}`")
    for path, symbol in SYM_REF.findall(text):
        py = _resolve_py(path)
        if py is None:
            missing.append(
                f"{md.relative_to(ROOT)}: code ref `{path}::{symbol}` "
                "(file not found)"
            )
        elif not _symbol_defined(py, symbol):
            missing.append(
                f"{md.relative_to(ROOT)}: code ref `{path}::{symbol}` "
                f"(symbol not defined in {py.relative_to(ROOT)})"
            )
    return missing


def main() -> int:
    missing = []
    n = 0
    for md in doc_files():
        n += 1
        missing.extend(check_file(md))
    if missing:
        print(f"doc-link check FAILED ({len(missing)} missing targets):")
        for m in missing:
            print(f"  {m}")
        return 1
    print(f"doc-link check OK ({n} markdown files scanned)")
    return 0


if __name__ == "__main__":
    sys.exit(main())

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Hypothesis-driven perf iteration over the three selected cells.

Each experiment = (cell, cfg_overrides, hypothesis). Results are saved as
tagged artifacts next to the baselines; scripts in EXPERIMENTS.md §Perf cite
them. Run:  PYTHONPATH=src python scripts_hillclimb.py [exp_name ...]
"""
import json
import sys
import traceback

from repro.launch import dryrun_lib
from repro.launch.mesh import make_production_mesh

# (name, arch, shape, overrides, hypothesis)
EXPERIMENTS = [
    # --- cell A: mistral-large-123b x train_4k (worst roofline fraction) ---
    ("A1_bf16_grads", "mistral-large-123b", "train_4k",
     {"bf16_grads": True},
     "f32 cotangents dominate backward HBM+ICI traffic; one downcast of "
     "w_eff halves grad-path bytes => memory & collective terms drop ~25-45%"),
    ("A2_bf16_scores", "mistral-large-123b", "train_4k",
     {"bf16_grads": True, "attn_scores_dtype": "bfloat16"},
     "attention scores are fp32 2x(S^2) traffic per layer; bf16 halves it"),
    ("A3_remat_dots", "mistral-large-123b", "train_4k",
     {"bf16_grads": True, "attn_scores_dtype": "bfloat16", "remat_policy": "dots"},
     "remat recompute is ~1 extra fwd of matmul FLOPs; saving dot outputs "
     "cuts the compute term ~25% at bounded memory cost"),
    ("A4_seq_parallel", "mistral-large-123b", "train_4k",
     {"bf16_grads": True, "attn_scores_dtype": "bfloat16",
      "seq_shard_activations": True},
     "SP shards the residual stream over model=16: TP psums become "
     "reduce-scatter+all-gather (same bytes, but residual saves /16)"),
    # --- cell B: gemma3-4b x prefill_32k (most collective-bound) ---
    ("B1_seq_parallel", "gemma3-4b", "prefill_32k",
     {"seq_shard_activations": True},
     "prefill is collective-bound via TP psums of (B,32k,d) activations; "
     "SP halves per-hop bytes (reduce-scatter vs all-reduce)"),
    ("B2_bf16_scores", "gemma3-4b", "prefill_32k",
     {"attn_scores_dtype": "bfloat16"},
     "local-attention scores at 32k are the largest memory-term item"),
    ("B3_both", "gemma3-4b", "prefill_32k",
     {"seq_shard_activations": True, "attn_scores_dtype": "bfloat16"},
     "combined: collective AND memory terms drop together"),
    # --- cell C: h2o-danube-1.8b x train_4k (paper-representative) ---
    ("C1_bf16_grads", "h2o-danube-1.8b", "train_4k",
     {"bf16_grads": True},
     "same f32-cotangent diagnosis as A1 on the RigL-representative cell"),
    ("C2_bf16_scores", "h2o-danube-1.8b", "train_4k",
     {"bf16_grads": True, "attn_scores_dtype": "bfloat16"},
     "SWA scores still 4k x 4k per chunk; bf16 halves"),
    ("C3_more_microbatch", "h2o-danube-1.8b", "train_4k",
     {"bf16_grads": True, "attn_scores_dtype": "bfloat16", "microbatches": 8},
     "smaller live working set; HLO traffic roughly flat (weights re-read "
     "amortized by fsdp=off) — expect <5% change, memory-model peak down 2x"),
    # paper-faithful EXTRA: the amortized RigL update step itself
    ("C_rigl_update_step", "h2o-danube-1.8b", "train_4k",
     {"__step_kind__": "rigl_update"},
     "the every-delta_t drop/grow (incl. argsort ranking + dense grads) "
     "costs ~1 dense-ish step; amortized by delta_t=100 => <1% overhead"),
]


def main():
    only = set(sys.argv[1:])
    mesh = make_production_mesh()
    for name, arch, shape, overrides, hypothesis in EXPERIMENTS:
        if only and name not in only:
            continue
        step_kind = overrides.pop("__step_kind__", None) if "__step_kind__" in overrides else None
        print(f"\n=== {name}: {hypothesis[:100]}")
        try:
            art = dryrun_lib.run_cell(
                arch, shape, mesh,
                cfg_overrides=overrides or None,
                # cost terms only: the baseline already carries the
                # full-depth compile proof for the cell
                full_depth=False,
                tag=name,
                step_kind=step_kind,
            )
            rl = art["roofline"]
            print(f"    compute {rl['compute_s']:.3e}  memory {rl['memory_s']:.3e}"
                  f"  collective {rl['collective_s']:.3e}  dominant={rl['dominant']}"
                  f"  mfu_bound={rl.get('mfu_upper_bound', 0):.4f}")
        except Exception:
            traceback.print_exc()


if __name__ == "__main__":
    main()

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Hillclimb round 2: A1/A2 refuted => the memory term is dominated by FSDP
weight re-gathers, multiplied by the microbatch count (full bf16 weights are
re-gathered per layer per microbatch). Attack the multiplier: fewer
microbatches, with SP shrinking the residual saves to keep HBM fit.
"""
import sys
from repro.launch import dryrun_lib
from repro.launch.mesh import make_production_mesh

EXPERIMENTS = [
    ("A5_dots_mb4_sp", "mistral-large-123b", "train_4k",
     {"remat_policy": "dots", "microbatches": 4, "seq_shard_activations": True},
     "memory ~ mb x gathered-weight bytes: mb 16->4 cuts re-gather traffic "
     "4x; SP shards residual saves /16 so HBM still fits"),
    ("A6_dots_mb4", "mistral-large-123b", "train_4k",
     {"remat_policy": "dots", "microbatches": 4},
     "isolate mb effect without SP (residuals 4x larger: 8.9GB - borderline)"),
    ("A7_dots_mb2_sp", "mistral-large-123b", "train_4k",
     {"remat_policy": "dots", "microbatches": 2, "seq_shard_activations": True},
     "push further: mb=2"),
    ("B4_sp_mb2", "gemma3-4b", "prefill_32k",
     {"seq_shard_activations": True, "attn_scores_dtype": "bfloat16"},
     "retry B with SP now that mesh context is set during lowering"),
    ("C4_dots", "h2o-danube-1.8b", "train_4k",
     {"remat_policy": "dots", "microbatches": 1},
     "danube fits without microbatching at all: no re-gather multiplier, "
     "dots-remat removes recompute"),
]

def main():
    mesh = make_production_mesh()
    for name, arch, shape, overrides, hypothesis in EXPERIMENTS:
        print(f"\n=== {name}: {hypothesis[:110]}")
        try:
            art = dryrun_lib.run_cell(arch, shape, mesh, cfg_overrides=overrides,
                                      full_depth=False, tag=name)
            rl = art["roofline"]
            print(f"    compute {rl['compute_s']:.3e}  memory {rl['memory_s']:.3e}"
                  f"  collective {rl['collective_s']:.3e}  dominant={rl['dominant']}"
                  f"  mfu_bound={rl.get('mfu_upper_bound', 0):.4f}")
            mm = art["memory"].get("model", {})
            print(f"    hbm-model {mm.get('total',0)/2**30:.2f} GiB fits={art['memory'].get('fits_16g_hbm')}")
        except Exception:
            import traceback; traceback.print_exc()

if __name__ == "__main__":
    main()

"""Render EXPERIMENTS.md §Roofline table + §Dry-run summary from artifacts."""
import json
import pathlib
import re

ART = pathlib.Path("artifacts/dryrun")
EXP = pathlib.Path("EXPERIMENTS.md")

ARCHS = [
    "internvl2-1b", "h2o-danube-1.8b", "gemma3-4b", "mistral-large-123b",
    "command-r-plus-104b", "grok-1-314b", "qwen2-moe-a2.7b", "hubert-xlarge",
    "xlstm-1.3b", "hymba-1.5b",
]
SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def fmt(x, nd=3):
    if x is None:
        return "—"
    return f"{x:.2e}" if (x != 0 and (abs(x) < 1e-2 or abs(x) > 1e4)) else f"{x:.{nd}f}"


def main():
    rows = []
    multi_ok = skipped = failed = 0
    for arch in ARCHS:
        for shape in SHAPES:
            p = ART / f"{arch}__{shape}__data16xmodel16.json"
            pm = ART / f"{arch}__{shape}__pod2xdata16xmodel16.json"
            if not p.exists():
                rows.append(f"| {arch} | {shape} | MISSING | | | | | | | |")
                failed += 1
                continue
            a = json.loads(p.read_text())
            if a.get("skipped"):
                rows.append(f"| {arch} | {shape} | skipped: {a['skipped']} | | | | | | | |")
                skipped += 1
                continue
            rl = a["roofline"]
            mem = a["memory"]["model"]["total"] / 2**30
            fits = "yes" if a["memory"]["fits_16g_hbm"] else "NO"
            mp = "—"
            if pm.exists():
                am = json.loads(pm.read_text())
                mp = "ok" if not am.get("skipped") else "skip"
                if mp == "ok":
                    multi_ok += 1
            rows.append(
                f"| {arch} | {shape} | {fmt(rl['compute_s'])} | {fmt(rl['memory_s'])} |"
                f" {fmt(rl.get('memory_s_lower_bound'))} | {fmt(rl['collective_s'])} |"
                f" **{rl['dominant']}** | {rl.get('mfu_upper_bound', 0):.4f} |"
                f" {rl.get('useful_flop_ratio', 0):.3f} | {mem:.2f} ({fits}) | {mp} |"
            )
    header = (
        "| arch | shape | compute s | memory s (HLO) | memory s (min) | collective s |"
        " dominant | MFU bound | useful-FLOP ratio | HBM GiB/dev (fits 16G) | 2-pod |\n"
        "|---|---|---|---|---|---|---|---|---|---|---|\n"
    )
    table = header + "\n".join(rows) + (
        f"\n\nCells: {len(rows)} total, {skipped} skipped by design, {failed} missing."
        "\nMFU bound = MODEL_FLOPS / (dominant-term-seconds x chips x peak);"
        " useful-FLOP ratio = MODEL_FLOPS / total HLO FLOPs (dense-masked execution"
        " makes this ~ (1-S) x 1/remat-overhead by construction)."
    )
    text = EXP.read_text()
    if "<!-- ROOFLINE_TABLE -->" in text:
        text = text.replace("<!-- ROOFLINE_TABLE -->", table, 1)
    else:
        text = re.sub(r"\| arch \| shape \|.*?\n\nMFU bound.*?\n", table, text, flags=re.S)
    EXP.write_text(text)
    print(f"wrote table: {len(rows)} rows ({skipped} skipped, {failed} missing, {multi_ok} multi-pod ok)")


if __name__ == "__main__":
    main()

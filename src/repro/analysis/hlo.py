"""Parse collective-communication bytes out of optimized HLO text.

cost_analysis() does not report collective bytes, so we regex the compiled
module: every all-reduce / all-gather / reduce-scatter / all-to-all /
collective-permute instruction, summing *operand* bytes (operand types are
inlined in HLO text).  Numbers are per-partition (SPMD), matching
cost_analysis()'s per-device FLOPs/bytes convention.
"""
from __future__ import annotations

import re
from collections import defaultdict

__all__ = ["collective_bytes", "DTYPE_BYTES"]

DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8, "c64": 8,
    "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLL = r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
# e.g. "%all-gather.3 = bf16[8,128]{1,0} all-gather(f32[8,8]{1,0} %p.2, ...)"
# optimized-HLO operands are %name refs (no inline types) — parse the RESULT
# shape(s) and the replica group size, then derive operand bytes per kind:
#   all-reduce / all-to-all / collective-permute : operand == result
#   all-gather                                   : operand == result / group
#   reduce-scatter                               : operand == result * group
_INSTR = re.compile(
    rf"=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\]\S*)\s+{_COLL}(-start|-done)?\("
    r"[^)]*\)((?:, [a-z_]+=\S+| [a-z_]+=\S+)*)"
)
_SHAPE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(result: str) -> int:
    total = 0
    for sm in _SHAPE.finditer(result):
        dtype, dims = sm.group(1), sm.group(2)
        if dtype not in DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Per-collective-kind operand bytes (per partition) + 'total'."""
    out: dict[str, int] = defaultdict(int)
    for line in hlo_text.splitlines():
        m = _INSTR.search(line)
        if not m:
            continue
        result, kind, suffix = m.group(1), m.group(2), m.group(3)
        if suffix == "-done":  # paired with -start; count the pair once
            continue
        b = _shape_bytes(result)
        gm = _GROUPS.search(line)
        group = int(gm.group(2)) if gm else 1
        if kind == "all-gather":
            b = b // max(group, 1)
        elif kind == "reduce-scatter":
            b = b * group
        out[kind] += b
    out["total"] = sum(v for k, v in out.items() if k != "total")
    return dict(out)


def count_ops(hlo_text: str, opname: str) -> int:
    return len(re.findall(rf"\b{re.escape(opname)}\b", hlo_text))

"""Analytic per-device HBM model for the dry-run cells.

The CPU backend's memory_analysis() assigns every intermediate a distinct
buffer (no reuse, remat-blind — verified empirically, see EXPERIMENTS.md
§Dry-run methodology), so it wildly overstates TPU-side peaks.  This model
computes the standard itemized accounting instead:

  state      : params (f32) + optimizer slots + masks (1B) + dense grads (f32)
  residuals  : remat checkpoints, L x B_loc x S x d x 2B
  working set: max over (attention scores fp32 per q-chunk, qkv, mlp hidden,
               MoE dispatch buffers, SSM scan chunk) — one layer live at a time
  logits     : one loss chunk, fp32, vocab-sharded
  kv cache   : decode/prefill shapes

Exact terms (params/opt/grads/masks/cache) are exact; activation terms are
upper-ish estimates of the dominant buffers (2 live copies assumed).
"""
from __future__ import annotations

import numpy as np

__all__ = ["memory_model"]


def _dp_model(mesh_shape: dict) -> tuple[int, int]:
    dp = mesh_shape.get("pod", 1) * mesh_shape.get("data", 1)
    return dp, mesh_shape.get("model", 1)


def memory_model(cfg, shape, mesh_shape: dict, n_params_total: float,
                 n_sparsifiable: float, opt_slots: int = 1,
                 opt_state_bytes: int = 4) -> dict:
    dp, tp = _dp_model(mesh_shape)
    n_dev = dp * tp
    B, S = shape.global_batch, shape.seq_len
    mb = max(getattr(cfg, "microbatches", 1), 1)
    B_loc = max(B // dp, 1)
    B_mb = max(B_loc // mb, 1)  # per-microbatch live activations
    d = cfg.d_model
    fsdp_div = (mesh_shape.get("data", 1) if cfg.fsdp else 1) * tp

    out: dict[str, float] = {}
    train = shape.kind == "train"
    pbytes = 2.0 if cfg.param_dtype == "bfloat16" else 4.0
    acc_bytes = 2.0 if getattr(cfg, "grad_accum_dtype", "") == "bfloat16" else 4.0

    # ---- state (exact) ----
    psz = n_params_total / fsdp_div
    out["params"] = pbytes * psz
    if train:
        out["opt_state"] = opt_state_bytes * psz * opt_slots
        out["grads"] = pbytes * psz
        if mb > 1:
            out["grad_accum"] = acc_bytes * psz
        out["masks_bool"] = n_sparsifiable / fsdp_div
    else:
        out["params"] = 2.0 * psz  # serving uses bf16 weights

    # ---- activations ----
    if shape.kind != "decode":
        if train and cfg.remat:
            g = max(getattr(cfg, "remat_group", 1), 1)
            # sequence parallelism shards the saved residual stream over TP
            sp_div = tp if getattr(cfg, "seq_shard_activations", False) else 1
            out["residual_saves"] = (cfg.n_layers / g) * B_mb * S * d * 2.0 / sp_div
            # bwd of one checkpoint region keeps g layers' internals live
            region_mult = g
        else:
            region_mult = cfg.n_layers if train else 1
        heads_loc = max(cfg.n_heads // tp, 1) if cfg.n_heads % tp == 0 else cfg.n_heads
        if cfg.block_type != "xlstm":
            qlen = min(S, cfg.q_chunk)
            klen = min(S, cfg.window) if (cfg.attn_pattern == ("local",) and cfg.window) else S
            out["attn_scores_f32"] = 2.0 * B_mb * heads_loc * qlen * klen * 4.0 * region_mult
            out["qkv_bf16"] = 3.0 * B_mb * S * heads_loc * cfg.head_dim * 2.0 * region_mult
        if cfg.d_ff:
            ff_loc = cfg.d_ff // tp if cfg.d_ff % tp == 0 else cfg.d_ff
            out["mlp_hidden_bf16"] = 2.0 * B_mb * S * ff_loc * 2.0 * region_mult
        if cfg.n_experts:
            T_loc = B_mb * S
            C = int(np.ceil(T_loc * cfg.top_k / cfg.n_experts * cfg.moe_capacity_factor))
            e_loc = cfg.n_experts if cfg.n_experts % tp else cfg.n_experts // tp
            out["moe_buffers_bf16"] = 3.0 * e_loc * C * d * 2.0 * region_mult
        if cfg.ssm_d_inner:
            out["ssm_chunk_f32"] = (
                2.0 * B_mb * min(S, 1024) * cfg.ssm_d_inner * cfg.ssm_state * 4.0
            )
        pv = ((cfg.vocab_size + 255) // 256) * 256  # models.model.padded_vocab
        v_loc = pv // tp if pv % tp == 0 else pv
        if train:  # prefill emits last-position logits only
            out["logits_chunk_f32"] = (
                2.0 * B_mb * (S // max(cfg.loss_chunks, 1)) * v_loc * 4.0
            )
        else:
            out["logits_last_f32"] = 2.0 * B_loc * v_loc * 4.0

    # ---- kv / recurrent caches (exact) ----
    if shape.kind in ("decode", "prefill"):
        kv_bytes = 0.0
        for i in range(cfg.n_layers):
            if cfg.block_type == "xlstm":
                nh, hd = cfg.n_heads, d // cfg.n_heads
                kv_bytes += B_loc * nh * (hd * hd + 2 * hd + 1) * 4.0
                continue
            kind = cfg.layer_kind(i)
            size = min(cfg.window, S) if (kind == "local" and cfg.window) else S
            kvh = cfg.n_kv_heads
            shard = tp if kvh % tp == 0 else (tp if S % tp == 0 else 1)
            kv_bytes += 2.0 * B_loc * size * kvh * cfg.head_dim * 2.0 / shard
            if cfg.block_type == "hymba":
                kv_bytes += B_loc * cfg.ssm_d_inner * (cfg.ssm_state + 3) * 4.0
        out["kv_cache"] = kv_bytes

    out["total"] = sum(v for k, v in out.items() if k != "total")
    return out

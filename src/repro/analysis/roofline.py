"""Three-term roofline from dry-run artifacts (TPU v5e targets).

  compute   = per_device_HLO_FLOPs / 197e12           [bf16 MXU peak]
  memory    = per_device_HLO_bytes / 819e9             [HBM bandwidth]
  collective= per_device_collective_bytes / 50e9       [ICI per-link]

cost_analysis() reports PER-DEVICE flops/bytes after SPMD partitioning
(verified empirically), so no further division by chip count is needed.
MODEL_FLOPS = 6·N_active·D (2 fwd + 4 bwd) for train, 2·N_active per token
for decode; ratio MODEL_FLOPS/(HLO_FLOPs × chips) exposes remat/redundancy
overhead (ratio < 1 when remat recomputes, > 1 would flag undercounting).
"""
from __future__ import annotations

import dataclasses

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # bytes/s / chip
ICI_BW = 50e9  # bytes/s / link

__all__ = ["roofline_terms", "PEAK_FLOPS", "HBM_BW", "ICI_BW"]


def roofline_terms(
    flops_per_dev: float,
    bytes_per_dev: float,
    coll_bytes_per_dev: float,
    *,
    chips: int,
    model_flops_total: float | None = None,
) -> dict:
    t_c = flops_per_dev / PEAK_FLOPS
    t_m = bytes_per_dev / HBM_BW
    t_x = coll_bytes_per_dev / ICI_BW
    terms = {"compute_s": t_c, "memory_s": t_m, "collective_s": t_x}
    dominant = max(terms, key=terms.get)
    bound = max(t_c, t_m, t_x)
    out = {
        **terms,
        "dominant": dominant.replace("_s", ""),
        "step_lower_bound_s": bound,
        # fraction of the roofline-bound step actually spent at peak compute
        "roofline_fraction": (t_c / bound) if bound > 0 else 0.0,
        "chips": chips,
    }
    if model_flops_total:
        hlo_total = flops_per_dev * chips
        out["model_flops"] = model_flops_total
        out["useful_flop_ratio"] = model_flops_total / hlo_total if hlo_total else 0.0
        out["mfu_upper_bound"] = (
            model_flops_total / (bound * chips * PEAK_FLOPS) if bound else 0.0
        )
    return out

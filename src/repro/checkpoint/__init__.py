from .checkpoint import Checkpointer, latest_step, restore, save  # noqa: F401

"""Fault-tolerant checkpointing.

- Crash-atomic: write into <dir>/tmp-<step> staging, fsync every file AND
  the directory entries, then rename to <dir>/step-<n> — a crash at any
  instant leaves either the complete old set or the complete new set, never
  a half-written step dir visible under the final name.  The manifest is
  written LAST (after the array blob is durable) and records the blob's
  byte size, so a torn write is detectable, not just unlucky.
- Self-describing: one .npz of flattened (path -> array) leaves + manifest.
- Masks are bit-packed (np.packbits): 1 bit/connection on disk (8x smaller
  than bool, 32x smaller than f32 — the sparse topology is cheap to persist).
- keep_last_k garbage collection (also sweeps stray tmp-* staging dirs left
  by crashes); corrupted/partial/torn checkpoints are skipped on restore
  (``latest_step``/``restore`` fall back to the newest VALID one).
- Elastic restarts: restore() takes an optional tree of NamedShardings and
  device_puts every leaf with them — the same checkpoint reloads onto a
  different mesh/device count (checkpoints store *logical* arrays).
- Async: save(..., background=True) snapshots to host then writes off-thread.
"""
from __future__ import annotations

import json
import os
import pathlib
import shutil
import threading
import zipfile
from typing import Any, Optional

import jax
import numpy as np

from ..core.masks import path_name

__all__ = ["save", "restore", "latest_step", "Checkpointer"]

_MASK_PREFIX = "__packedmask__/"


def _flatten(tree) -> dict[str, Any]:
    flat, _ = jax.tree_util.tree_flatten_with_path(
        tree, is_leaf=lambda x: x is None
    )
    return {path_name(p): v for p, v in flat}


def save(state, ckpt_dir, step: int, *, keep_last_k: int = 3, background: bool = False):
    ckpt_dir = pathlib.Path(ckpt_dir)
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    flat = _flatten(state)
    host: dict[str, np.ndarray] = {}
    meta = {"step": int(step), "none_leaves": [], "mask_shapes": {}}
    for name, v in flat.items():
        if v is None:
            meta["none_leaves"].append(name)
            continue
        arr = np.asarray(jax.device_get(v))
        if arr.dtype == np.bool_ and name.startswith("masks/"):
            meta["mask_shapes"][name] = list(arr.shape)
            host[_MASK_PREFIX + name] = np.packbits(arr.reshape(-1))
        else:
            host[name.replace("/", "|")] = arr

    def _write():
        tmp = ckpt_dir / f"tmp-{step}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir()
        np.savez(tmp / "arrays.npz", **{k.replace("/", "|"): v for k, v in host.items()})
        _fsync_file(tmp / "arrays.npz")
        # manifest goes LAST, after the blob is durable, carrying the blob's
        # byte size — a manifest that exists and matches implies a complete
        # array file (restore/_valid check this)
        meta["arrays_bytes"] = (tmp / "arrays.npz").stat().st_size
        (tmp / "manifest.json").write_text(json.dumps(meta))
        _fsync_file(tmp / "manifest.json")
        _fsync_dir(tmp)
        final = ckpt_dir / f"step-{step:010d}"
        if final.exists():
            shutil.rmtree(final)
        tmp.rename(final)
        _fsync_dir(ckpt_dir)  # make the rename itself durable
        _gc(ckpt_dir, keep_last_k)

    if background:
        t = threading.Thread(target=_write, daemon=True)
        t.start()
        return t
    _write()
    return None


def _fsync_file(p: pathlib.Path) -> None:
    fd = os.open(p, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def _fsync_dir(p: pathlib.Path) -> None:
    try:
        fd = os.open(p, os.O_RDONLY | getattr(os, "O_DIRECTORY", 0))
    except OSError:
        return  # filesystems without directory fds (exotic mounts): best-effort
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def _gc(ckpt_dir: pathlib.Path, keep: int):
    steps = sorted(ckpt_dir.glob("step-*"))
    for old in steps[:-keep]:
        shutil.rmtree(old, ignore_errors=True)
    for stray in ckpt_dir.glob("tmp-*"):  # staging dirs orphaned by a crash
        shutil.rmtree(stray, ignore_errors=True)


def _valid(d: pathlib.Path) -> bool:
    """True iff ``d`` holds a COMPLETE checkpoint: manifest parses, and the
    array blob both exists and has the byte size the manifest recorded at
    write time (manifests predating the size field fall back to existence).
    Torn/partial dirs — crash mid-save, truncated copy — report False and
    are skipped by latest_step/restore."""
    man, blob = d / "manifest.json", d / "arrays.npz"
    if not (man.exists() and blob.exists()):
        return False
    try:
        meta = json.loads(man.read_text())
    except (json.JSONDecodeError, OSError):
        return False
    want = meta.get("arrays_bytes")
    if want is not None and blob.stat().st_size != want:
        return False
    return True


def latest_step(ckpt_dir) -> Optional[int]:
    ckpt_dir = pathlib.Path(ckpt_dir)
    if not ckpt_dir.exists():
        return None
    for d in sorted(ckpt_dir.glob("step-*"), reverse=True):
        if _valid(d):
            return int(d.name.split("-")[1])
    return None


def restore(like, ckpt_dir, *, step: Optional[int] = None, shardings=None):
    """Rebuild a state pytree shaped like ``like`` from disk.

    shardings: optional pytree (same structure) of NamedSharding — enables
    restoring onto a different mesh than the one that saved (elastic restart).

    With ``step=None`` this walks step dirs NEWEST-FIRST and skips any that
    are torn or unreadable (_valid size check, then zip/json decode errors
    at load time), so a crash during the most recent save costs one
    checkpoint interval, never the run.  An explicit ``step`` is a caller
    decision: errors propagate.
    """
    ckpt_dir = pathlib.Path(ckpt_dir)
    if step is not None:
        return _restore_dir(like, ckpt_dir / f"step-{step:010d}", shardings), step
    if ckpt_dir.exists():
        for d in sorted(ckpt_dir.glob("step-*"), reverse=True):
            if not _valid(d):
                continue
            try:
                got = _restore_dir(like, d, shardings)
            except (zipfile.BadZipFile, json.JSONDecodeError, OSError, ValueError):
                continue  # torn past the size check (e.g. corrupt zip member)
            return got, int(d.name.split("-")[1])
    raise FileNotFoundError(f"no valid checkpoint in {ckpt_dir}")


def _restore_dir(like, d: pathlib.Path, shardings):
    meta = json.loads((d / "manifest.json").read_text())
    data = np.load(d / "arrays.npz")
    arrays: dict[str, np.ndarray] = {}
    for k in data.files:
        name = k.replace("|", "/")
        if name.startswith(_MASK_PREFIX):
            real = name[len(_MASK_PREFIX):]
            shape = meta["mask_shapes"][real]
            n = int(np.prod(shape))
            arrays[real] = np.unpackbits(data[k])[:n].reshape(shape).astype(bool)
        else:
            arrays[name] = data[k]

    flat_like, treedef = jax.tree_util.tree_flatten_with_path(
        like, is_leaf=lambda x: x is None
    )
    flat_sh = (
        jax.tree_util.tree_leaves(shardings, is_leaf=lambda x: x is None)
        if shardings is not None
        else [None] * len(flat_like)
    )
    leaves = []
    for (path, leaf), sh in zip(flat_like, flat_sh):
        name = path_name(path)
        if leaf is None:
            leaves.append(None)
            continue
        arr = arrays.get(name)
        if arr is None:
            if name.startswith("pack/") or name == "nonfinite_steps":
                # pre-PackState / pre-guard checkpoint: the pack is derived
                # state (rebuildable from the masks — callers MUST
                # refresh_pack() after restoring, launch/train.py does) and
                # nonfinite_steps is a telemetry counter that restarts at
                # the template value; fall back to the template leaf.
                arr = leaf
            else:
                raise KeyError(f"checkpoint {d} is missing leaf {name!r}")
        if sh is not None:
            leaves.append(jax.device_put(arr, sh))
        else:
            leaves.append(jax.numpy.asarray(arr, dtype=leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)


class Checkpointer:
    """Convenience wrapper: periodic async save + restart-aware restore."""

    def __init__(self, ckpt_dir, every: int = 500, keep_last_k: int = 3):
        self.dir = pathlib.Path(ckpt_dir)
        self.every = every
        self.keep = keep_last_k
        self._thread: Optional[threading.Thread] = None

    def maybe_save(self, state, step: int, *, force: bool = False):
        if not force and (self.every <= 0 or step % self.every != 0):
            return
        self.wait()
        self._thread = save(
            state, self.dir, step, keep_last_k=self.keep, background=True
        )

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def restore_or_none(self, like, shardings=None):
        try:
            return restore(like, self.dir, shardings=shardings)
        except FileNotFoundError:
            return None, None

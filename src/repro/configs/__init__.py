"""Config registry: get_config(arch_id, smoke=False)."""
from . import base
from .base import ARCH_IDS, SHAPES, SKIPS, ModelConfig, ShapeConfig, SparseConfig, cells

_MODULES = {
    "internvl2-1b": "internvl2_1b",
    "h2o-danube-1.8b": "h2o_danube_1_8b",
    "gemma3-4b": "gemma3_4b",
    "mistral-large-123b": "mistral_large_123b",
    "command-r-plus-104b": "command_r_plus_104b",
    "grok-1-314b": "grok_1_314b",
    "qwen2-moe-a2.7b": "qwen2_moe_a2_7b",
    "hubert-xlarge": "hubert_xlarge",
    "xlstm-1.3b": "xlstm_1_3b",
    "hymba-1.5b": "hymba_1_5b",
}


def get_config(arch: str, smoke: bool = False) -> ModelConfig:
    import importlib

    mod = importlib.import_module(f".{_MODULES[arch]}", __package__)
    return mod.SMOKE if smoke else mod.CONFIG

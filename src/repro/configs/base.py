"""ModelConfig + input-shape registry for the 10 assigned architectures.

Every architecture exposes CONFIG (exact assigned dims) and SMOKE (reduced,
same family) — see per-arch files.  Shapes below are the assigned 4-shape set;
``cells()`` enumerates the 40 (arch x shape) grid with documented skips.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

__all__ = [
    "ModelConfig",
    "ShapeConfig",
    "SHAPES",
    "SparseConfig",
    "validate_sparse_kernel",
]


@dataclasses.dataclass(frozen=True)
class SparseConfig:
    """RigL settings attached to a model config (paper §3 + TPU execution).

    Topology / schedule (paper Algorithm 1):
      sparsity         target overall sparsity S in [0, 1) of the
                       sparsifiable weights (1 - density).
      distribution     how S is distributed across layers: 'uniform', 'er'
                       (Erdos-Renyi) or 'erk' (ER-kernel, paper default).
      method           'rigl' (grow by |dense grad|), 'set' (random grow),
                       'snfs' (grow by |dense momentum|), 'topkast' (forward
                       top-k, backward top-(k+Δ) superset — Jayakumar et al.;
                       always-sparse fwd AND bwd), 'static' (fixed topology).
                       Under kernel dispatch, rigl/snfs take their dense-side
                       grow scores from the Top-KAST backward superset
                       gradient instead of a dense backward (docs/training.md).
                       The drivers also accept 'snip' and 'pruning' via their
                       own code paths.
      backward_extra   Top-KAST superset breadth Δ as a fraction of each
                       layer's units (elements, or blocks in block mode):
                       |B| = min(total, |A| + ceil(backward_extra * total)).
                       Consumed whenever the state carries backward masks —
                       method='topkast', or rigl/snfs under a sparse kernel.
      delta_t          steps between topology updates (drop/grow cadence);
                       also the amortization window for every host-side
                       topology cost (dense backward, PackState repack).
      alpha            initial drop/grow fraction, cosine-annealed to 0.
      t_end_fraction   updates stop after this fraction of total steps.
      grow_init        init for grown connections: 'zeros' (paper default,
                       function-preserving), 'random', or 'gradient'.
      block_shape      (bk, bn) or None.  When set, drop/grow scores are
                       L1-pooled over aligned weight blocks (core/rigl.py), so
                       every mask stays block-aligned — REQUIRED for
                       kernel='block_sparse', where it must equal the kernel's
                       (bk, bn) tiles (validate_sparse_kernel enforces this).

    Execution path for sparsifiable matmuls (models/layers.py dispatch; the
    full path is documented in docs/kernels.md):
      kernel           'dense'        x @ (w*m); XLA materializes w*m in HBM
                                      (reference semantics, no Pallas).
                       'masked'       Pallas fused-mask matmul: any mask
                                      pattern; w*m only ever exists tile-wise
                                      in VMEM.
                       'block_sparse' Pallas block-skipping matmul: inactive
                                      (bk x bn) blocks are skipped entirely —
                                      HBM traffic and MXU work scale with
                                      block density in fwd AND bwd.  The
                                      train/serve state then carries a
                                      PackState (core/pack.py) so kernel
                                      grids are sized to the true
                                      active-block count (tight grids).
                       Both Pallas paths carry custom-VJP backward kernels
                       (kernels/masked_matmul.py, block_sparse_matmul.py).
      kernel_block     (bm, bn, bk) Pallas tile sizes: bm rows of the
                       flattened batch*seq dim, bn output columns, bk
                       contraction rows.  128-aligned tiles target TPU v5e;
                       for kernel='block_sparse', (bk, bn) doubles as the
                       weight-block granularity and must match block_shape.
      pack_width_slack width hysteresis for PackState refreshes (core/pack.py):
                       packed widths are rounded UP to the next multiple of
                       ``ceil(slack * worst_case_width)`` (and never shrink),
                       so drifting topologies re-trace the jitted step only
                       when a width crosses a slack step instead of on every
                       1-wide wiggle.  0.0 (default) keeps exact tight widths;
                       grouped banks benefit most (one lopsided expert widens
                       the whole bank's shared width).
      fused_epilogue   fuse the SGD grad-accum epilogue into the wgrad
                       kernels (docs/kernels.md#fused-epilogue): the weight
                       cotangent leaving the backward IS the new momentum
                       m_new = mu*mom + dw + wd*w, so the raw gradient never
                       round-trips HBM.  Requires kernel dispatch + plain SGD
                       (no nesterov/grad_clip, microbatches=1, method !=
                       'snfs', bf16_grads off) — training/steps.py raises
                       loudly on unsupported combinations.  With
                       OptConfig.state_dtype='bfloat16' the kernel also
                       stochastically rounds m_new onto the bf16 grid.

    Execution path for ATTENTION score blocks (independent of the weight
    kernels above; models/attention.py dispatch):
      attn_kernel      'dense'        pure-jnp chunked attention — scores
                                      materialize in HBM (reference path).
                       'flash'        Pallas flash attention, fwd + custom-VJP
                                      bwd, PADDED grid: the KV loop spans the
                                      full Sk/bk range with dead score blocks
                                      guarded off (baseline for parity).
                       'flash_tight'  same kernels on a host-built
                                      AttnSchedule (core/attn_sched.py): the
                                      grid walks only LIVE KV blocks per
                                      q-row, so causal/sliding-window layers
                                      skip dead blocks' DMA and iterations —
                                      the attention twin of tight PackState
                                      grids.
    """

    sparsity: float = 0.8
    distribution: str = "erk"  # uniform | er | erk
    method: str = "rigl"  # rigl | set | snfs | topkast | static
    backward_extra: float = 0.1  # Top-KAST superset Δ fraction
    delta_t: int = 100
    alpha: float = 0.3
    t_end_fraction: float = 0.75
    grow_init: str = "zeros"
    block_shape: Optional[tuple[int, int]] = None  # TPU block-sparse mode
    kernel: str = "dense"
    kernel_block: tuple[int, int, int] = (128, 128, 128)  # (bm, bn, bk) tiles
    pack_width_slack: float = 0.0  # width hysteresis (0 = exact tight widths)
    fused_epilogue: bool = False  # fuse SGD epilogue into the wgrad kernels
    attn_kernel: str = "dense"  # dense | flash | flash_tight


def validate_sparse_kernel(sp: SparseConfig) -> None:
    """Fail fast on inconsistent kernel-dispatch settings.

    block_sparse executes whole (bk x bn) weight blocks unmasked inside active
    blocks, so the elementwise mask MUST be block-aligned — which core.rigl
    guarantees exactly when block_shape matches the kernel's (bk, bn).
    """
    if sp.kernel not in ("dense", "masked", "block_sparse"):
        raise ValueError(f"unknown sparse.kernel {sp.kernel!r}")
    if getattr(sp, "attn_kernel", "dense") not in (
        "dense", "flash", "flash_tight"
    ):
        raise ValueError(f"unknown sparse.attn_kernel {sp.attn_kernel!r}")
    if not 0.0 <= getattr(sp, "backward_extra", 0.1) <= 1.0:
        raise ValueError(
            f"sparse.backward_extra must be in [0, 1] "
            f"(got {sp.backward_extra!r})"
        )
    if not 0.0 <= getattr(sp, "pack_width_slack", 0.0) <= 1.0:
        raise ValueError(
            f"sparse.pack_width_slack must be in [0, 1] "
            f"(got {sp.pack_width_slack!r})"
        )
    if getattr(sp, "fused_epilogue", False) and sp.kernel not in (
        "masked", "block_sparse"
    ):
        raise ValueError(
            "sparse.fused_epilogue fuses the optimizer epilogue into the "
            "Pallas wgrad kernels — it requires kernel='masked' or "
            f"'block_sparse' (got kernel={sp.kernel!r})"
        )
    if sp.kernel == "block_sparse":
        _, bn, bk = sp.kernel_block
        if sp.block_shape is None or tuple(sp.block_shape) != (bk, bn):
            raise ValueError(
                "sparse.kernel='block_sparse' needs block-aligned masks: set "
                f"sparse.block_shape=({bk}, {bn}) to match kernel_block "
                f"(got {sp.block_shape})"
            )


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | audio | vlm
    block_type: str = "transformer"  # transformer | xlstm | hymba
    n_layers: int = 2
    d_model: int = 128
    n_heads: int = 2
    n_kv_heads: int = 2
    head_dim: int = 64
    d_ff: int = 256
    vocab_size: int = 256
    mlp_kind: str = "swiglu"  # swiglu | geglu | gelu | none
    # attention pattern: cycle of 'global'/'local' applied per layer index,
    # plus optional explicit global layer ids (hymba: first/middle/last).
    attn_pattern: tuple[str, ...] = ("global",)
    global_layer_ids: tuple[int, ...] = ()
    window: int = 0
    qk_norm: bool = False
    logit_softcap: float = 0.0
    final_softcap: float = 0.0
    rope_theta: float = 1e4
    causal: bool = True  # False => encoder-only (hubert)
    parallel_block: bool = False  # command-r style attn || mlp
    post_norms: bool = False  # gemma-style sandwich norms
    # MoE
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    moe_d_ff: int = 0
    moe_capacity_factor: float = 1.25
    # SSM / xLSTM
    ssm_state: int = 0
    ssm_d_inner: int = 0
    slstm_every: int = 0  # xlstm: layer i is sLSTM if i % slstm_every == slstm_every-1
    # frontend stubs (vlm/audio): precomputed embeddings come in via input_specs
    frontend: str = "none"  # none | patch | frames
    frontend_dim: int = 0
    n_patches: int = 0
    # io / numerics
    tie_embeddings: bool = True
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    q_chunk: int = 4096
    loss_chunks: int = 1  # chunk the vocab-parallel xent over seq
    remat: bool = True
    remat_group: int = 1  # layers per checkpoint region (sqrt-style remat)
    remat_policy: str = "none"  # none | dots (save matmul outputs)
    bf16_grads: bool = False  # cast w_eff once -> bf16 grads & DP all-reduce
    attn_scores_dtype: str = "float32"  # bfloat16 halves score HBM traffic
    microbatches: int = 1  # gradient-accumulation chunks per step
    scan_microbatches: bool = False  # lax.scan over microbatches (small HLO)
    grad_accum_dtype: str = "float32"
    seq_shard_activations: bool = False  # Megatron-style sequence parallelism
    scan_layers: bool = False  # set by dryrun for the full-depth memory proof
    fsdp: bool = False  # shard weight embed-dims over the data axis
    sparse: SparseConfig = SparseConfig()

    def layer_kind(self, i: int) -> str:
        """'global' or 'local' attention for layer i."""
        if self.global_layer_ids:
            return "global" if i in self.global_layer_ids else "local"
        return self.attn_pattern[i % len(self.attn_pattern)]

    def is_slstm(self, i: int) -> bool:
        return self.slstm_every > 0 and (i % self.slstm_every == self.slstm_every - 1)

    @property
    def pattern_period(self) -> int:
        """Smallest repeating super-block (for cost extrapolation)."""
        if self.block_type == "xlstm" and self.slstm_every:
            return self.slstm_every
        if self.global_layer_ids:
            return 1  # irregular: treated per-layer (costed with local kind)
        return len(self.attn_pattern)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}

# (arch, shape) cells skipped, with reasons recorded in DESIGN.md §5 /
# EXPERIMENTS.md. Encoder-only archs have no decode; long_500k requires
# sub-quadratic attention (SWA / local:global / SSM / hybrid).
SKIPS: dict[tuple[str, str], str] = {
    ("hubert-xlarge", "decode_32k"): "encoder-only: no decode step",
    ("hubert-xlarge", "long_500k"): "encoder-only: no decode step",
    ("internvl2-1b", "long_500k"): "pure full attention (quadratic)",
    ("mistral-large-123b", "long_500k"): "pure full attention (quadratic)",
    ("command-r-plus-104b", "long_500k"): "pure full attention (quadratic)",
    ("grok-1-314b", "long_500k"): "pure full attention (quadratic)",
    ("qwen2-moe-a2.7b", "long_500k"): "pure full attention (quadratic)",
}

ARCH_IDS = (
    "internvl2-1b",
    "h2o-danube-1.8b",
    "gemma3-4b",
    "mistral-large-123b",
    "command-r-plus-104b",
    "grok-1-314b",
    "qwen2-moe-a2.7b",
    "hubert-xlarge",
    "xlstm-1.3b",
    "hymba-1.5b",
)


def cells():
    """All 40 (arch x shape) pairs with skip annotations."""
    out = []
    for a in ARCH_IDS:
        for s in SHAPES:
            out.append((a, s, SKIPS.get((a, s))))
    return out

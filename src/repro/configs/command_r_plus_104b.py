"""command-r-plus-104b [dense]: GQA, no-bias, PARALLEL attn||FFN block.

[hf:CohereForAI/c4ai-command-r-v01; unverified]. 64L d_model=12288 96H
(GQA kv=8) d_ff=33792 vocab=256000. Full attention; FSDP required.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="command-r-plus-104b", family="dense", n_layers=64, d_model=12288,
    n_heads=96, n_kv_heads=8, head_dim=128, d_ff=33792, vocab_size=256000,
    mlp_kind="swiglu", parallel_block=True, tie_embeddings=True, fsdp=True,
    loss_chunks=8, microbatches=16, remat_group=4,
)

SMOKE = ModelConfig(
    name="command-r-plus-104b-smoke", family="dense", n_layers=2, d_model=96,
    n_heads=6, n_kv_heads=2, head_dim=16, d_ff=192, vocab_size=256,
    mlp_kind="swiglu", parallel_block=True, tie_embeddings=True,
    q_chunk=64, remat=False,
)

"""gemma3-4b [dense]: 5:1 local:global interleaving, QK-norm, sandwich norms.

[hf:google/gemma-3-1b-pt; unverified]. 34L d_model=2560 8H (GQA kv=4)
d_ff=10240 vocab=262144. Local window 1024; every 6th layer global.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-4b", family="dense", n_layers=34, d_model=2560,
    n_heads=8, n_kv_heads=4, head_dim=256, d_ff=10240, vocab_size=262144,
    mlp_kind="geglu", attn_pattern=("local",) * 5 + ("global",), window=1024,
    qk_norm=True, post_norms=True, tie_embeddings=True, loss_chunks=8, microbatches=8,
)

SMOKE = ModelConfig(
    name="gemma3-4b-smoke", family="dense", n_layers=6, d_model=64,
    n_heads=2, n_kv_heads=1, head_dim=32, d_ff=128, vocab_size=256,
    mlp_kind="geglu", attn_pattern=("local",) * 5 + ("global",), window=16,
    qk_norm=True, post_norms=True, tie_embeddings=True, q_chunk=64, remat=False,
)

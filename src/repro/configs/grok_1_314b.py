"""grok-1-314b [moe]: 8 experts top-2, attention/final logit soft-capping.

[hf:xai-org/grok-1; unverified]. 64L d_model=6144 48H (GQA kv=8)
moe_d_ff=32768 vocab=131072. Pure-MoE FFN every layer; FSDP required.
8 experts on a 16-way model axis => intra-expert TP (see moe.py docstring).

attn_kernel='flash_tight': the flash kernels apply logit_softcap in-kernel
(fwd + VJP) and fold the kv=8 GQA groups into the BlockSpec index maps, so
the 48H/8kv attention reads each K/V group once instead of 6x — the tight
schedule-aware grid is the intended production path for this cell.
"""
from .base import ModelConfig, SparseConfig

_SP = SparseConfig(attn_kernel="flash_tight")

CONFIG = ModelConfig(
    name="grok-1-314b", family="moe", n_layers=64, d_model=6144,
    n_heads=48, n_kv_heads=8, head_dim=128, d_ff=0, vocab_size=131072,
    n_experts=8, top_k=2, moe_d_ff=32768, logit_softcap=30.0,
    final_softcap=50.0, tie_embeddings=False, fsdp=True, loss_chunks=4,
    microbatches=16, param_dtype="bfloat16", grad_accum_dtype="bfloat16",
    sparse=_SP,
)

SMOKE = ModelConfig(
    name="grok-1-314b-smoke", family="moe", n_layers=2, d_model=64,
    n_heads=4, n_kv_heads=2, head_dim=16, d_ff=0, vocab_size=128,
    n_experts=4, top_k=2, moe_d_ff=64, logit_softcap=30.0, final_softcap=50.0,
    tie_embeddings=False, q_chunk=64, remat=False, sparse=_SP,
)

"""h2o-danube-1.8b [dense]: llama+mistral mix with sliding-window attention.

[arXiv:2401.16818; hf]. 24L d_model=2560 32H (GQA kv=8) d_ff=6912 vocab=32000.
SWA window 4096 on every layer => windowed KV cache, long_500k eligible.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-1.8b", family="dense", n_layers=24, d_model=2560,
    n_heads=32, n_kv_heads=8, head_dim=80, d_ff=6912, vocab_size=32000,
    mlp_kind="swiglu", attn_pattern=("local",), window=4096,
    tie_embeddings=False, microbatches=4,
)

SMOKE = ModelConfig(
    name="h2o-danube-1.8b-smoke", family="dense", n_layers=2, d_model=64,
    n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128, vocab_size=128,
    mlp_kind="swiglu", attn_pattern=("local",), window=16,
    tie_embeddings=False, q_chunk=64, remat=False,
)

"""hubert-xlarge [audio]: encoder-only masked-prediction transformer.

[arXiv:2106.07447; unverified]. 48L d_model=1280 16H d_ff=5120 vocab=504.
The wav2vec2 conv stem is a STUB: input_specs supplies precomputed frame
embeddings (frontend_dim=512). Bidirectional => no decode shapes.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge", family="audio", n_layers=48, d_model=1280,
    n_heads=16, n_kv_heads=16, head_dim=80, d_ff=5120, vocab_size=504,
    mlp_kind="gelu", causal=False, frontend="frames", frontend_dim=512,
    tie_embeddings=False, microbatches=4, loss_chunks=4,
)

SMOKE = ModelConfig(
    name="hubert-xlarge-smoke", family="audio", n_layers=2, d_model=64,
    n_heads=4, n_kv_heads=4, head_dim=16, d_ff=128, vocab_size=32,
    mlp_kind="gelu", causal=False, frontend="frames", frontend_dim=16,
    tie_embeddings=False, q_chunk=64, remat=False,
)

"""hymba-1.5b [hybrid]: parallel attention + mamba heads in every block.

[arXiv:2411.13676; hf]. 32L d_model=1600 25H (GQA kv=5) d_ff=5504
vocab=32001, ssm_state=16. SWA (window 1024) everywhere except global
full-attention layers {first, middle, last} per the paper.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b", family="hybrid", block_type="hymba", n_layers=32,
    d_model=1600, n_heads=25, n_kv_heads=5, head_dim=64, d_ff=5504,
    vocab_size=32001, attn_pattern=("local",), global_layer_ids=(0, 15, 31),
    window=1024, ssm_state=16, ssm_d_inner=3200, tie_embeddings=True,
    microbatches=4, q_chunk=2048, loss_chunks=4,
)

SMOKE = ModelConfig(
    name="hymba-1.5b-smoke", family="hybrid", block_type="hymba", n_layers=4,
    d_model=64, n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128,
    vocab_size=128, attn_pattern=("local",), global_layer_ids=(0, 3),
    window=16, ssm_state=4, ssm_d_inner=128, tie_embeddings=True,
    q_chunk=64, remat=False,
)

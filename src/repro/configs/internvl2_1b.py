"""internvl2-1b [vlm]: InternViT frontend (STUB) + Qwen2-0.5B-family backbone.

[arXiv:2404.16821; hf]. 24L d_model=896 14H (GQA kv=2) d_ff=4864 vocab=151655.
Frontend supplies precomputed patch embeddings via input_specs (task spec).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-1b", family="vlm", n_layers=24, d_model=896,
    n_heads=14, n_kv_heads=2, head_dim=64, d_ff=4864, vocab_size=151655,
    mlp_kind="swiglu", frontend="patch", frontend_dim=1024, n_patches=256,
    tie_embeddings=True, microbatches=4, q_chunk=1024, loss_chunks=8,
)

SMOKE = ModelConfig(
    name="internvl2-1b-smoke", family="vlm", n_layers=2, d_model=64,
    n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128, vocab_size=128,
    mlp_kind="swiglu", frontend="patch", frontend_dim=32, n_patches=4,
    tie_embeddings=True, q_chunk=64, remat=False,
)

"""mistral-large-123b [dense]: [hf:mistralai/Mistral-Large-Instruct-2407; unverified].

88L d_model=12288 96H (GQA kv=8) d_ff=28672 vocab=32768. Full attention
(long_500k skipped). FSDP over the data axis is required to fit HBM.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="mistral-large-123b", family="dense", n_layers=88, d_model=12288,
    n_heads=96, n_kv_heads=8, head_dim=128, d_ff=28672, vocab_size=32768,
    mlp_kind="swiglu", tie_embeddings=False, fsdp=True,
    microbatches=16, remat_group=4, loss_chunks=4,
)

SMOKE = ModelConfig(
    name="mistral-large-123b-smoke", family="dense", n_layers=2, d_model=96,
    n_heads=6, n_kv_heads=2, head_dim=16, d_ff=192, vocab_size=128,
    mlp_kind="swiglu", tie_embeddings=False, q_chunk=64, remat=False,
)

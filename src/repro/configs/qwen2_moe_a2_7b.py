"""qwen2-moe-a2.7b [moe]: 4 shared + 60 routed experts, top-4.

[hf:Qwen/Qwen1.5-MoE-A2.7B; hf]. 24L d_model=2048 16H (GQA kv=16)
moe_d_ff=1408 vocab=151936. 60 experts on a 16-way axis => intra-expert TP.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b", family="moe", n_layers=24, d_model=2048,
    n_heads=16, n_kv_heads=16, head_dim=128, d_ff=0, vocab_size=151936,
    n_experts=60, top_k=4, n_shared_experts=4, moe_d_ff=1408,
    tie_embeddings=False, loss_chunks=4, microbatches=4, fsdp=True,
)

SMOKE = ModelConfig(
    name="qwen2-moe-a2.7b-smoke", family="moe", n_layers=2, d_model=64,
    n_heads=4, n_kv_heads=4, head_dim=16, d_ff=0, vocab_size=128,
    n_experts=6, top_k=2, n_shared_experts=1, moe_d_ff=32,
    tie_embeddings=False, q_chunk=64, remat=False,
)

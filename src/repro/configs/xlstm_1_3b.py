"""xlstm-1.3b [ssm]: mLSTM + sLSTM blocks, 7:1 ratio.

[arXiv:2405.04517; unverified]. 48L d_model=2048 4H vocab=50304, d_ff=0.
Every 8th block is sLSTM (true recurrence); rest mLSTM (matrix memory,
chunkwise-parallel training, O(1)-state decode => long_500k eligible).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b", family="ssm", block_type="xlstm", n_layers=48,
    d_model=2048, n_heads=4, n_kv_heads=4, head_dim=512, d_ff=0,
    vocab_size=50304, slstm_every=8, tie_embeddings=True, microbatches=8,
)

SMOKE = ModelConfig(
    name="xlstm-1.3b-smoke", family="ssm", block_type="xlstm", n_layers=4,
    d_model=64, n_heads=2, n_kv_heads=2, head_dim=32, d_ff=0,
    vocab_size=128, slstm_every=2, tie_embeddings=True, q_chunk=64, remat=False,
)

"""RigL core: the paper's contribution as composable JAX modules."""
from .distributions import (  # noqa: F401
    LayerSpec,
    erdos_renyi_distribution,
    get_distribution,
    sparsity_overall,
    uniform_distribution,
)
from .masks import (  # noqa: F401
    apply_masks,
    block_mask_of,
    init_masks,
    mask_stats,
    mask_subset,
    nnz,
    random_mask,
    tree_paths,
)
from .attn_sched import (  # noqa: F401
    attn_sched_stats,
    build_attn_schedule,
    sched_for,
)
from .pack import (  # noqa: F401
    PackIntegrityError,
    build_bwd_carrier,
    build_pack_state,
    is_pack_entry,
    pack_mismatch,
    pack_stats,
    publish_pack_gauges,
    refresh_pack_state,
    validate_pack,
)
from .pruning import PruningSchedule, prune_step, snip_masks  # noqa: F401
from .rigl import (  # noqa: F401
    SparseAlgo,
    dense_to_sparse_grad,
    rigl_update,
    rigl_update_layer,
    topkast_backward_masks,
)
from .topology import (  # noqa: F401
    TopologyTrace,
    cross_method_distances,
    drop_grow_counts,
    graph_edit_distance,
    jaccard_distance,
    normalized_hamming_distance,
    topology_delta,
)
from .schedules import UpdateSchedule, cosine_decay  # noqa: F401

"""AttnSchedule — host-built KV-block schedules for tight flash-attention grids.

The flash-attention kernel (kernels/flash_attention.py) tiles the score matrix
into (bq x bk) blocks.  For causal and sliding-window masks most of those
blocks are DEAD — every (q, k) position inside them is masked — yet a dense
grid still launches (and DMAs K/V for) all of them: at Sk = 32k with a 512
window, >90% of the score grid is dead work.  This module is the attention
twin of core/pack.py: the set of LIVE KV blocks per query-block row is known
STATICALLY (it depends only on shapes, block sizes and the mask family — never
on data), so it is rasterized host-side into a CSR-style schedule

  {"kv_idx": (n_q, width) int32,   # live KV-block ids per q-block, ascending
   "kv_cnt": (n_q,) int32,         #   -> drives the fwd and dq kernel grids
   "q_idx":  (n_k, q_width) int32, # reverse view: live q-blocks per KV-block
   "q_cnt":  (n_k,) int32,         #   -> drives the dk/dv kernel grid
   "n_live": () int32,             # total live score blocks
   "n_q/n_k/bq/bk/...": python ints/bools (static metadata, see below)}

and the kernel grid's third dimension becomes ``width`` (the max live count
over q rows) instead of the worst case n_k.  Padded slots clamp to the last
live id (no re-DMA) and are @pl.when-guarded, exactly like the block-sparse
weight packs.

Unlike PackState, a schedule is DERIVED state with no lifecycle: it never
refreshes (RigL moves weight topology, not mask geometry), it is not
checkpointed, and it can be (re)built at trace time for free — the arrays
depend only on static shapes, so they fold into jit constants.  ``sched_for``
memoizes builds per (Sq, Sk, bq, bk, causal, window, q_offset).

Position convention: key/value column c sits at absolute position c; query
row r sits at position ``q_offset + r``.  ``q_offset=None`` defaults to
Sk - Sq (decode-style right alignment: the last query sees every key), which
reduces to 0 for the ubiquitous Sq == Sk case.  This matches the offset
arithmetic of models/attention.py::_make_mask.
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import numpy as np

__all__ = [
    "live_block_mask",
    "rasterize_block_mask",
    "build_attn_schedule",
    "sched_for",
    "paged_prefix_schedule",
    "attn_sched_stats",
    "is_attn_sched",
]


def _cdiv(a: int, b: int) -> int:
    return -(-a // b)


def is_attn_sched(x) -> bool:
    """Leaf predicate for schedule pytrees (a schedule dict or None)."""
    return x is None or (isinstance(x, dict) and "kv_idx" in x and "kv_cnt" in x)


def live_block_mask(
    sq: int,
    sk: int,
    bq: int,
    bk: int,
    *,
    causal: bool,
    window: int = 0,
    q_offset: Optional[int] = None,
) -> np.ndarray:
    """(n_q, n_k) bool: block (i, j) is live iff ANY (q, k) inside it is
    unmasked.  Computed analytically from block position ranges — O(n_q*n_k),
    no (Sq, Sk) rasterization, so 500k-token schedules stay cheap.

    A block straddling the valid-key boundary (sk not a bk multiple) counts as
    live when its in-range columns are; columns >= sk are masked in-kernel.
    The brute-force elementwise rasterizer (``rasterize_block_mask``) is the
    test oracle for this function (tests/test_flash_attention.py).
    """
    if q_offset is None:
        q_offset = sk - sq
    n_q, n_k = _cdiv(sq, bq), _cdiv(sk, bk)
    i = np.arange(n_q)
    j = np.arange(n_k)
    # absolute position extremes of each block's VALID rows/cols
    q_lo = (q_offset + i * bq)[:, None]  # (n_q, 1)
    q_hi = (q_offset + np.minimum((i + 1) * bq, sq) - 1)[:, None]
    k_lo = (j * bk)[None, :]  # (1, n_k)
    k_hi = np.minimum((j + 1) * bk, sk)[None, :] - 1
    live = np.ones((n_q, n_k), bool)
    if causal:
        live &= k_lo <= q_hi  # some key at or below some query position
    if window:
        live &= k_hi > q_lo - window  # some key inside the oldest row's window
    return live


def rasterize_block_mask(
    sq: int,
    sk: int,
    bq: int,
    bk: int,
    *,
    causal: bool,
    window: int = 0,
    q_offset: Optional[int] = None,
) -> np.ndarray:
    """Brute-force oracle: build the full (sq, sk) elementwise mask and reduce
    per block.  O(sq*sk) — tests only; ``live_block_mask`` is the fast path."""
    if q_offset is None:
        q_offset = sk - sq
    qpos = q_offset + np.arange(sq)[:, None]
    kpos = np.arange(sk)[None, :]
    m = np.ones((sq, sk), bool)
    if causal:
        m &= kpos <= qpos
    if window:
        m &= kpos > qpos - window
    n_q, n_k = _cdiv(sq, bq), _cdiv(sk, bk)
    out = np.zeros((n_q, n_k), bool)
    for i in range(n_q):
        for j in range(n_k):
            out[i, j] = m[i * bq : (i + 1) * bq, j * bk : (j + 1) * bk].any()
    return out


def _pack_rows(live: np.ndarray):
    """(R, C) bool -> (idx (R, width) int32, cnt (R,) int32): per-row active
    column ids, ascending, padded slots 0.  Same stable-argsort packing as
    kernels/block_sparse_matmul.py::_pack_np, transposed to the row view."""
    cnt = live.sum(axis=1).astype(np.int32)
    width = max(int(cnt.max(initial=0)), 1)
    order = np.argsort(~live, axis=1, kind="stable")
    idx = order[:, :width].astype(np.int32)
    idx = np.where(np.arange(width)[None, :] < cnt[:, None], idx, 0)
    return idx, cnt


def build_attn_schedule(
    sq: int,
    sk: int,
    bq: int,
    bk: int,
    *,
    causal: bool,
    window: int = 0,
    q_offset: Optional[int] = None,
) -> dict[str, Any]:
    """Host-build the schedule dict for one (shape, mask-family) combination.

    ``kv_idx``/``kv_cnt`` drive the forward and dq grids (per q-block, its
    live KV blocks); ``q_idx``/``q_cnt`` are the transpose view driving the
    dk/dv grid (per KV-block, its live q blocks) — the same CSC/CSR duality as
    the weight packs in core/pack.py.  Static metadata (block sizes, mask
    family, offsets) rides along so the kernel wrapper never re-derives it.

    Degenerate inputs are first-class: window >= sk reduces to the pure-causal
    schedule, window < bk still keeps >= 1 live block per row (the diagonal),
    and sq = 1 (decode) yields the single-row schedule over the window's tail.
    """
    if q_offset is None:
        q_offset = sk - sq
    live = live_block_mask(
        sq, sk, bq, bk, causal=causal, window=window, q_offset=q_offset
    )
    kv_idx, kv_cnt = _pack_rows(live)
    q_idx, q_cnt = _pack_rows(live.T)
    # NUMPY leaves on purpose: ``sched_for`` memoizes across traces, and a
    # jnp.asarray issued INSIDE a jit trace is a tracer — caching it would
    # leak it into later traces.  Consumers hand these to jit/pallas_call,
    # which fold them into per-trace constants.
    return {
        "kv_idx": kv_idx,
        "kv_cnt": kv_cnt,
        "q_idx": q_idx,
        "q_cnt": q_cnt,
        "n_live": int(live.sum()),
        # static metadata (python scalars — hashable, never traced)
        "sq": sq,
        "sk": sk,
        "bq": bq,
        "bk": bk,
        "causal": bool(causal),
        "window": int(window),
        "q_offset": int(q_offset),
    }


@functools.lru_cache(maxsize=256)
def sched_for(
    sq: int,
    sk: int,
    bq: int,
    bk: int,
    causal: bool,
    window: int = 0,
    q_offset: Optional[int] = None,
):
    """Memoized ``build_attn_schedule`` — the lazy trace-time entry point.

    Schedules are pure functions of static shapes, so models/attention.py can
    call this inside a jit trace (numpy on static ints) and the arrays fold
    into constants; the cache keeps retraces from re-rasterizing.  Callers
    that want explicit threading (launch/serve.py builds once per session)
    call this up front and pass the dict down.
    """
    return build_attn_schedule(
        sq, sk, bq, bk, causal=causal, window=window, q_offset=q_offset
    )


@functools.lru_cache(maxsize=256)
def paged_prefix_schedule(sq: int, n_pages: int, bq: int, page_size: int):
    """Grid layout for the paged-prefix flash phase (shared-prefix prefill).

    The paged kernel (kernels/flash_attention.py::flash_attention_paged)
    walks a slot's block table instead of a contiguous K/V row: grid step s
    of q row qb visits logical page ``kv_idx[qb, s]``, and the BlockSpec
    index map sends it through the scalar-prefetched table to a PHYSICAL
    pool page — the block table is literally one more prefetched index map
    composed onto the schedule walk.  Unlike the static mask families of
    ``build_attn_schedule``, page liveness here is DYNAMIC (the valid
    prefix length ``ctx`` is a traced per-row scalar), so the host-side
    schedule cannot clip the walk: ``kv_idx`` is the identity walk over all
    ``n_pages`` table entries and the kernel clips in-flight against
    ``ceil(ctx / page_size)`` via @pl.when — the paged analog of kv_cnt.
    """
    n_q = _cdiv(sq, bq)
    kv_idx = np.broadcast_to(
        np.arange(n_pages, dtype=np.int32)[None, :], (n_q, n_pages)
    ).copy()
    return {
        "sq": sq,
        "n_pages": n_pages,
        "bq": bq,
        "page_size": page_size,
        "width": n_pages,
        "kv_idx": kv_idx,
    }


def attn_sched_stats(sched) -> dict[str, Any]:
    """Bookkeeping: tight grid length vs the padded worst case vs live blocks.

    ``grid_fraction`` (launched tight iterations / dense grid) is >=
    ``live_fraction`` (live blocks / dense grid) by construction — width is a
    per-row MAX — and both are far below the dense-DMA fraction the padded
    @pl.when path pays; benchmarks/kernel_bench.py records and asserts the
    ordering.
    """
    kv_idx = np.asarray(sched["kv_idx"])
    n_q, width = kv_idx.shape
    n_k = int(np.asarray(sched["q_cnt"]).shape[0])
    live = int(np.asarray(sched["n_live"]))
    total = n_q * n_k
    return {
        "n_q": n_q,
        "n_k": n_k,
        "width": width,
        "grid_iters_tight": n_q * width,
        "grid_iters_padded": total,
        "grid_fraction": n_q * width / total,
        "live_blocks": live,
        "live_fraction": live / total,
    }

"""Sparsity distributions: Uniform, Erdos-Renyi (ER), Erdos-Renyi-Kernel (ERK).

Given a target *overall* sparsity S and the shapes of the sparsifiable layers,
produce per-layer sparsities s_l with  sum_l s_l * N_l / sum_l N_l == S.

ER/ERK follow Mocanu et al. (2018) / Evci et al. (2020): layer l keeps a density
proportional to (sum of its dims)/(prod of its dims) — kernel dims included for
ERK.  The scale factor eps is solved exactly with the iterative capping scheme
used in google-research/rigl: layers whose implied density would exceed 1 are
pinned dense and eps re-solved over the rest.
"""
from __future__ import annotations

import dataclasses
from typing import Mapping, Sequence

import numpy as np

__all__ = [
    "LayerSpec",
    "uniform_distribution",
    "erdos_renyi_distribution",
    "sparsity_overall",
    "validate_distribution",
]


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    """A sparsifiable layer as seen by the distribution solver.

    shape: full weight shape.  For dense (matmul) layers this is (n_in, n_out)
      or any rank — the last two dims are treated as (in, out) fan dims and
      any leading dims (conv kernel h/w, experts, stacked layers) as "kernel"
      dims included only by ERK.
    dense: if True the layer is excluded from sparsification (kept dense).
    """

    name: str
    shape: tuple[int, ...]
    dense: bool = False

    @property
    def size(self) -> int:
        return int(np.prod(self.shape))

    def er_raw(self, kernel_aware: bool) -> float:
        """Unit-eps density: (n_in+n_out[+kernel dims]) / prod(dims)."""
        *kernel, n_in, n_out = self.shape
        num = n_in + n_out + (sum(kernel) if kernel_aware else 0)
        den = n_in * n_out * (int(np.prod(kernel)) if kernel else 1)
        if kernel and not kernel_aware:
            # plain ER on a conv-like layer: treat kernel dims as part of fan-in
            den = self.size
        return num / den


def uniform_distribution(
    layers: Sequence[LayerSpec], sparsity: float, dense_first: bool = True
) -> dict[str, float]:
    """Uniform: every sparsifiable layer gets s_l = S.

    Per the paper, the first sparsifiable layer may be kept dense
    (``dense_first``); unlike ER/ERK no re-normalization is applied (the
    paper's uniform numbers also report overall sparsity slightly below S).
    """
    out: dict[str, float] = {}
    first = True
    for l in layers:
        if l.dense or (dense_first and first and not l.dense):
            out[l.name] = 0.0
            if not l.dense:
                first = False
            continue
        out[l.name] = float(sparsity)
    return out


def erdos_renyi_distribution(
    layers: Sequence[LayerSpec],
    sparsity: float,
    kernel_aware: bool = True,
) -> dict[str, float]:
    """ER (kernel_aware=False) / ERK (kernel_aware=True) distribution.

    Solves for eps such that total nnz matches the target, capping layers at
    density 1.0 (iteratively, as in the official implementation).
    """
    if not 0.0 <= sparsity < 1.0:
        raise ValueError(f"sparsity must be in [0,1), got {sparsity}")
    sizes = {l.name: l.size for l in layers}
    target_nnz = (1.0 - sparsity) * sum(s for s in sizes.values())

    dense_names = {l.name for l in layers if l.dense}
    raw = {l.name: l.er_raw(kernel_aware) for l in layers if not l.dense}

    # Iteratively pin layers that would exceed density 1.
    pinned = set(dense_names)
    while True:
        pinned_nnz = sum(sizes[n] for n in pinned)
        free = [l for l in layers if l.name not in pinned]
        if not free:
            break
        denom = sum(raw[l.name] * sizes[l.name] for l in free)
        if denom <= 0:
            break
        eps = (target_nnz - pinned_nnz) / denom
        over = [l.name for l in free if eps * raw[l.name] > 1.0]
        if not over:
            break
        pinned.update(over)

    out: dict[str, float] = {}
    for l in layers:
        if l.name in pinned:
            out[l.name] = 0.0
        else:
            density = min(1.0, max(0.0, eps * raw[l.name]))
            out[l.name] = float(1.0 - density)
    return out


def sparsity_overall(
    layers: Sequence[LayerSpec], sparsities: Mapping[str, float]
) -> float:
    total = sum(l.size for l in layers)
    nnz = sum(l.size * (1.0 - sparsities[l.name]) for l in layers)
    return 1.0 - nnz / total


def validate_distribution(sparsities: Mapping[str, float]) -> None:
    for name, s in sparsities.items():
        if not (0.0 <= s < 1.0):
            raise ValueError(f"layer {name}: sparsity {s} outside [0,1)")


def get_distribution(
    kind: str,
    layers: Sequence[LayerSpec],
    sparsity: float,
    dense_first: bool = True,
) -> dict[str, float]:
    """kind in {uniform, er, erk}."""
    if sparsity == 0.0:
        return {l.name: 0.0 for l in layers}
    if kind == "uniform":
        d = uniform_distribution(layers, sparsity, dense_first=dense_first)
    elif kind == "er":
        d = erdos_renyi_distribution(layers, sparsity, kernel_aware=False)
    elif kind == "erk":
        d = erdos_renyi_distribution(layers, sparsity, kernel_aware=True)
    else:
        raise ValueError(f"unknown distribution kind: {kind!r}")
    validate_distribution(d)
    return d

"""FLOP accounting exactly as paper Appendix H.

forward = sum over layers of 2 * (output elements) * (fan-in)  [mul+add],
backward = 2x forward.  Method costs per averaged step (per sample):

  Dense / Small-Dense : 3 * f_D
  Static / SNIP / SET : 3 * f_S
  SNFS                : 2 * f_S + f_D      (dense grads every step)
  RigL                : (3*f_S*dT + 2*f_S + f_D) / (dT + 1)
  Pruning             : E_t[ 3 * f_D * (1 - s_t) ]   (Zhu & Gupta ramp)

f_S is computed layer-by-layer from a sparsity distribution, which is what
makes ERK cost ~2x uniform (paper Fig 2-left).  The ResNet-50 layer table
below lets the test suite validate our accounting against the paper's
published multipliers (0.23x/0.10x train @ 80/90% uniform, 0.42x/0.24x ERK).
"""
from __future__ import annotations

import dataclasses
from typing import Mapping, Sequence

import numpy as np

from .distributions import LayerSpec, get_distribution
from .pruning import PruningSchedule

__all__ = [
    "ConvSpec",
    "DenseSpec",
    "layer_fwd_flops",
    "model_fwd_flops",
    "sparse_fwd_flops",
    "method_train_flops",
    "resnet50_layers",
    "lm_param_count",
]


@dataclasses.dataclass(frozen=True)
class ConvSpec:
    name: str
    kh: int
    kw: int
    cin: int
    cout: int
    hout: int
    wout: int
    dense: bool = False

    @property
    def weight_shape(self):
        return (self.kh, self.kw, self.cin, self.cout)

    @property
    def size(self):
        return self.kh * self.kw * self.cin * self.cout

    def fwd_flops(self) -> float:
        return 2.0 * self.hout * self.wout * self.size

    def layer_spec(self) -> LayerSpec:
        return LayerSpec(self.name, self.weight_shape, dense=self.dense)


@dataclasses.dataclass(frozen=True)
class DenseSpec:
    name: str
    nin: int
    nout: int
    dense: bool = False

    @property
    def size(self):
        return self.nin * self.nout

    def fwd_flops(self) -> float:
        return 2.0 * self.size

    def layer_spec(self) -> LayerSpec:
        return LayerSpec(self.name, (self.nin, self.nout), dense=self.dense)


Layer = ConvSpec | DenseSpec


def layer_fwd_flops(layer: Layer, sparsity: float = 0.0) -> float:
    return layer.fwd_flops() * (1.0 - sparsity)


def model_fwd_flops(layers: Sequence[Layer]) -> float:
    return sum(l.fwd_flops() for l in layers)


def sparse_fwd_flops(
    layers: Sequence[Layer], sparsities: Mapping[str, float]
) -> float:
    return sum(layer_fwd_flops(l, sparsities.get(l.name, 0.0)) for l in layers)


def method_train_flops(
    method: str,
    f_dense: float,
    f_sparse: float,
    delta_t: int = 100,
    pruning_schedule: PruningSchedule | None = None,
    total_steps: int | None = None,
    f_sparse_bwd: float | None = None,
) -> float:
    """Average per-step per-sample training FLOPs (Appendix H).

    f_sparse_bwd: per-sample FLOPs of a backward pass at the Top-KAST
    superset density (k+Δ active) — defaults to f_sparse (Δ = 0).  Only
    'topkast' consumes it: fwd + dgrad run at forward density (2*f_sparse),
    wgrad at superset density, every step, no dense terms anywhere.
    """
    if method in ("dense", "small_dense"):
        return 3.0 * f_dense
    if method in ("static", "snip", "set"):
        return 3.0 * f_sparse
    if method == "topkast":
        return 2.0 * f_sparse + (
            f_sparse if f_sparse_bwd is None else f_sparse_bwd
        )
    if method == "snfs":
        return 2.0 * f_sparse + f_dense
    if method == "rigl":
        return (3.0 * f_sparse * delta_t + 2.0 * f_sparse + f_dense) / (delta_t + 1)
    if method == "pruning":
        assert pruning_schedule is not None and total_steps is not None
        ts = np.arange(total_steps)
        s_t = np.asarray(pruning_schedule.target(ts))
        return float(np.mean(3.0 * f_dense * (1.0 - s_t)))
    raise ValueError(method)


# --------------------------------------------------------------------------
# ResNet-50 (v1, 224x224) layer table — for validating against paper numbers.
# --------------------------------------------------------------------------

def resnet50_layers() -> list[Layer]:
    layers: list[Layer] = [ConvSpec("conv1", 7, 7, 3, 64, 112, 112)]
    stage_cfg = [  # (blocks, c_in_first, c_mid, c_out, spatial_out)
        (3, 64, 64, 256, 56),
        (4, 256, 128, 512, 28),
        (6, 512, 256, 1024, 14),
        (3, 1024, 512, 2048, 7),
    ]
    for si, (blocks, cin0, cmid, cout, hw) in enumerate(stage_cfg):
        cin = cin0
        for b in range(blocks):
            pre = f"s{si}b{b}"
            layers += [
                ConvSpec(f"{pre}_c1", 1, 1, cin, cmid, hw, hw),
                ConvSpec(f"{pre}_c2", 3, 3, cmid, cmid, hw, hw),
                ConvSpec(f"{pre}_c3", 1, 1, cmid, cout, hw, hw),
            ]
            if b == 0:
                layers.append(ConvSpec(f"{pre}_down", 1, 1, cin, cout, hw, hw))
            cin = cout
    layers.append(DenseSpec("fc", 2048, 1000))
    return layers


def resnet50_flop_multipliers(
    sparsity: float, distribution: str = "uniform", delta_t: int = 100
) -> dict[str, dict[str, float]]:
    """Reproduce paper Fig 2-left FLOPs columns analytically.

    Returns {method: {train: x, test: x}} normalized to dense.
    """
    layers = resnet50_layers()
    specs = [l.layer_spec() for l in layers]
    sp = get_distribution(distribution, specs, sparsity)
    f_d = model_fwd_flops(layers)
    f_s = sparse_fwd_flops(layers, sp)
    out = {}
    prune = PruningSchedule(sparsity, begin_step=8000, end_step=24000, prune_every=1000)
    for method in ("dense", "static", "snip", "set", "snfs", "rigl", "pruning"):
        train = method_train_flops(
            method, f_d, f_s, delta_t=delta_t, pruning_schedule=prune, total_steps=32000
        )
        test = f_d if method == "dense" else f_s
        out[method] = {
            "train": train / (3.0 * f_d),
            "test": test / f_d,
        }
    return out


# --------------------------------------------------------------------------
# LM analytic model FLOPs (roofline MODEL_FLOPS = 6*N*D; MoE uses N_active).
# --------------------------------------------------------------------------

def lm_param_count(cfg) -> dict[str, float]:
    """Analytic parameter counts from a ModelConfig (total + active)."""
    d = cfg.d_model
    hd = cfg.head_dim
    attn = d * cfg.n_heads * hd + 2 * d * cfg.n_kv_heads * hd + cfg.n_heads * hd * d
    if cfg.block_type == "xlstm":
        # mLSTM qkv + out + gates (approximation documented in DESIGN.md)
        attn = 4 * d * d + 4 * d
    mlp_mult = 3 if cfg.mlp_kind in ("swiglu", "geglu") else 2
    ff = cfg.d_ff * d * mlp_mult if cfg.d_ff else 0
    moe_total = moe_active = 0.0
    if cfg.n_experts:
        per_exp = cfg.moe_d_ff * d * mlp_mult
        moe_total = cfg.n_experts * per_exp + cfg.n_shared_experts * per_exp
        moe_active = cfg.top_k * per_exp + cfg.n_shared_experts * per_exp
        ff = 0
    ssm = 0
    if cfg.block_type in ("hymba",):
        d_in = cfg.ssm_d_inner
        ssm = 2 * d * d_in + d_in * d + d_in * (2 * cfg.ssm_state + 2)
    per_layer = attn + ff + ssm
    embed = cfg.vocab_size * d
    total = cfg.n_layers * (per_layer + moe_total) + embed * (1 if cfg.tie_embeddings else 2)
    active = cfg.n_layers * (per_layer + moe_active) + embed * (1 if cfg.tie_embeddings else 2)
    return {"total": float(total), "active": float(active)}


def lm_model_flops(cfg, n_tokens: int, train: bool = True) -> float:
    """MODEL_FLOPS = 6 * N_active * D (2 fwd + 4 bwd per param per token)."""
    n = lm_param_count(cfg)["active"]
    mult = 6.0 if train else 2.0
    return mult * n * n_tokens

"""Mask pytrees: random sparsification, application, bookkeeping.

Masks mirror the params pytree; a leaf is either a bool array (sparsifiable
weight) or ``None`` (dense parameter — biases, norms, embeddings by default).
``None`` leaves vanish from pytree flattening, so masks cost nothing for dense
layers.
"""
from __future__ import annotations

from typing import Any, Mapping

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "path_name",
    "tree_paths",
    "random_mask",
    "random_block_mask",
    "block_mask_of",
    "init_masks",
    "apply_masks",
    "mask_subset",
    "mask_stats",
    "nnz",
]


def path_name(path) -> str:
    """KeyPath -> 'a/b/c' string."""
    parts = []
    for k in path:
        if isinstance(k, jax.tree_util.DictKey):
            parts.append(str(k.key))
        elif isinstance(k, jax.tree_util.SequenceKey):
            parts.append(str(k.idx))
        elif isinstance(k, jax.tree_util.GetAttrKey):
            parts.append(str(k.name))
        else:
            parts.append(str(k))
    return "/".join(parts)


def tree_paths(tree) -> dict[str, Any]:
    """Flatten a pytree into {path_string: leaf}."""
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return {path_name(p): v for p, v in flat}


def random_mask(key, shape, sparsity: float, dtype=jnp.bool_):
    """Random mask with EXACTLY round((1-sparsity)*N) nonzeros."""
    n = int(np.prod(shape))
    k = int(round((1.0 - sparsity) * n))
    scores = jax.random.uniform(key, (n,))
    # rank < k  <=>  among the k largest scores; stable & exact count.
    rank = jnp.argsort(jnp.argsort(-scores))
    return (rank < k).reshape(shape).astype(dtype)


def random_block_mask(key, shape, sparsity: float, block_shape, dtype=jnp.bool_):
    """Block-aligned random mask: EXACT count of active (bm, bn) blocks.

    Required when the topology executes through the block-sparse kernel from
    step 0 — elementwise random masks are not block-aligned until the first
    block-mode RigL update, and the kernel runs whole active blocks unmasked.
    3-D shapes (grouped weight banks: MoE experts (E, d, ff), xLSTM per-head
    recurrences (nh, hd, 4hd)) draw per-group block masks over the TRAILING
    two dims — the grouped kernels' block granularity.  Falls back to
    elementwise masks when the block doesn't tile the (trailing) shape (such
    layers must not be dispatched to the block kernel; init_train_state
    rejects them loudly in block_sparse mode).
    """
    bm_, bn_ = block_shape
    if (
        len(shape) not in (2, 3)
        or shape[-2] % bm_
        or shape[-1] % bn_
    ):
        return random_mask(key, shape, sparsity, dtype)
    blk = random_mask(
        key, (*shape[:-2], shape[-2] // bm_, shape[-1] // bn_), sparsity
    )
    return (
        jnp.repeat(jnp.repeat(blk, bm_, axis=-2), bn_, axis=-1).astype(dtype)
    )


def block_mask_of(mask, block_shape):
    """Elementwise (..., K, N) mask -> (..., K/bk, N/bn) block-activity mask.

    A block is active iff ANY of its elements is active.  Works on both numpy
    (host-side PackState builds, core/pack.py) and jnp (traced consistency
    checks) arrays, returning the same kind.  block_shape is (bk, bn) — the
    kernel's (K-tile, N-tile), i.e. ``cfg.sparse.block_shape``.  A leading
    group dim (3-D weight banks) passes through: blocks tile the trailing two
    dims per group, matching the grouped kernels.
    """
    bk, bn = block_shape
    *lead, K, N = mask.shape
    assert K % bk == 0 and N % bn == 0, (mask.shape, block_shape)
    return mask.reshape(*lead, K // bk, bk, N // bn, bn).any(axis=(-3, -1))


def init_masks(key, params, sparsities: Mapping[str, float], block_shape=None):
    """Build the mask pytree.

    sparsities maps param-path -> sparsity; paths not present (or with
    sparsity exactly 0 and marked dense upstream) get mask ``None``.
    block_shape: draw block-aligned masks (TPU block-sparse mode) so the
    topology is kernel-executable from the very first step.
    """
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    masks = []
    for path, leaf in flat:
        name = path_name(path)
        s = sparsities.get(name)
        if s is None:
            masks.append(None)
            continue
        key, sub = jax.random.split(key)
        if block_shape is not None:
            masks.append(random_block_mask(sub, leaf.shape, s, block_shape))
        else:
            masks.append(random_mask(sub, leaf.shape, s))
    return jax.tree_util.tree_unflatten(treedef, masks)


def apply_masks(params, masks):
    """Effective weights w_eff = w * m (dense leaves pass through).

    Differentiating the loss w.r.t. the OUTPUT of this function yields the
    paper's dense gradient; multiplying that by the mask gives the sparse
    (optimizer) gradient.
    """
    def _apply(w, m):
        if m is None:
            return w
        return w * m.astype(w.dtype)

    return jax.tree_util.tree_map(
        _apply, params, masks, is_leaf=lambda x: x is None
    )


def mask_subset(inner, outer) -> bool:
    """True iff every active leaf edge of ``inner`` is active in ``outer``.

    The forward ⊆ backward containment of a Top-KAST mask pair (core/rigl.py
    ``topkast_backward_masks``); pack builds and the topology test tier check
    it with this one definition.  None leaves must agree (both dense).
    """
    fa = jax.tree_util.tree_flatten(inner, is_leaf=lambda x: x is None)[0]
    fb = jax.tree_util.tree_flatten(outer, is_leaf=lambda x: x is None)[0]
    if len(fa) != len(fb):
        return False
    for a, b in zip(fa, fb):
        if a is None and b is None:
            continue
        if a is None or b is None:
            return False
        if bool(np.any(np.asarray(a, bool) & ~np.asarray(b, bool))):
            return False
    return True


def nnz(masks) -> int:
    leaves = [l for l in jax.tree_util.tree_leaves(masks) if l is not None]
    return int(sum(jnp.sum(l) for l in leaves)) if leaves else 0


def mask_stats(masks) -> dict[str, Any]:
    """Per-layer and overall sparsity bookkeeping (host-side)."""
    out: dict[str, Any] = {"layers": {}}
    total = 0
    active = 0
    for name, m in tree_paths(masks).items():
        if m is None:
            continue
        size = int(np.prod(m.shape))
        a = int(jnp.sum(m))
        out["layers"][name] = {
            "size": size,
            "nnz": a,
            "sparsity": 1.0 - a / size,
        }
        total += size
        active += a
    out["total"] = total
    out["nnz"] = active
    out["sparsity"] = 1.0 - active / total if total else 0.0
    return out

"""PackState — host-packed block topology carried in train/serve state.

The block-sparse kernels (kernels/block_sparse_matmul.py) are driven by a CSC
packing of the block-activity mask: per N-block column, the ids of its active
K-blocks (``idx (N/bn, width) int32``) and how many are real (``cnt (N/bn,)``).
The kernel grid's third dimension is ``width`` — every padded slot is a
launched-but-skipped grid iteration.  Inside jit the mask is a tracer, so the
trace-safe pack must pad ``width`` to the STATIC worst case (K/bk), which makes
every grid as expensive (in iterations) as a dense one.

PackState fixes that: the packing is computed HOST-SIDE (numpy, tight width)
from the concrete masks, stored in the train/serve state as a pytree mirroring
the mask tree, and threaded through the model into
``ops.block_sparse_linear(pack=...)``.  Both the train step and prefill/decode
then launch grids sized to the true active-block count.  RigL only changes the
topology every ``delta_t`` steps, so the pack is refreshed exactly there —
the host repack is amortized over >= delta_t matmuls (paper Appendix H
cost-structure argument, applied to grid shape instead of gradient cost).

Lifecycle (documented end-to-end in docs/kernels.md):

  init      training/steps.py::init_train_state builds ``state["pack"]`` when
            cfg.sparse.kernel == 'block_sparse'
  train     training/steps.py::make_train_step threads state["pack"] into the
            loss (models/model.py -> layers.linear -> ops.block_sparse_linear)
  update    launch/train.py refreshes the pack right after every rigl_step —
            a rigl_step WITHOUT a refresh leaves the pack stale, which the
            ``pack_stale`` train-step metric (pack_mismatch below) surfaces
  ckpt      the pack is ordinary int32 leaves in the state pytree, so
            checkpoint/ persists and restores it with everything else
  serve     launch/serve.py threads the serve state's pack (built by
            init_train_state, or restored with a checkpoint) into every
            prefill/decode call — packed once per topology, reused per token

Entry layout (one per packable mask leaf, ``None`` elsewhere):

  {"idx":  (N/bn, width) int32,   # active K-block ids per N-block, CSC —
   "cnt":  (N/bn,) int32,         #   drives the fwd and wgrad kernel grids
   "ridx": (K/bk, row_width) i32, # active N-block ids per K-block, CSR —
   "rcnt": (K/bk,) int32,         #   drives the custom-VJP dgrad grid
   "nnz":  () int32,              # total active blocks (bookkeeping/bench)
   "nkb":  () int32}              # K/bk — the CSC padded worst-case width

Grouped weight banks (3-D masks: MoE per-expert (E, d, ff), xLSTM per-head
(nh, hd, 4hd)) carry the same entry with a leading group dim on idx/cnt/
ridx/rcnt — per-group CSC/CSR at ONE shared width, consumed by the grouped
kernels in a single launch (docs/kernels.md#grouped-packs).

Top-KAST backward-superset pair (docs/training.md#topkast): when the state
carries backward masks B ⊇ A (method='topkast', or rigl/snfs under kernel
dispatch — core/rigl.py ``topkast_backward_masks``), every entry additionally
packs B's CSC as a SECOND, wider view:

  {"bidx": (N/bn, bwidth) int32,  # superset K-block ids — drives the wgrad
   "bcnt": (N/bn,) int32,         #   grid, so dw covers the whole (k+Δ) set
   "bnnz": () int32}              # superset active blocks

The forward/dgrad grids keep running on the tight idx/ridx views; only wgrad
widens to bidx — ops.block_sparse_linear routes to the Top-KAST custom VJP
exactly when these fields are present.  ``pack_entry`` refuses a superset
that does not contain the forward topology (the containment is what makes
the superset gradient exact on B's support).  With kernel='masked' the
analogous carrier entry is just ``{"bwd_mask": bool (K, N)}``
(``build_bwd_carrier``): the masked kernels take elementwise masks directly,
no packing needed.

Width policy: ``width = max_j cnt[j]`` (tight; same for ``row_width`` over
``rcnt``), but never below the width of ``prev`` when refreshing — widths only
ever grow within a run, so jit retraces on topology updates are bounded by the
drift toward the worst case instead of happening on every shrink/grow wiggle.
``SparseConfig.pack_width_slack`` adds hysteresis on top: widths round UP to
the next multiple of ``ceil(slack * worst_case)`` (never down), so a topology
whose per-column max wiggles by a block or two per refresh stays on ONE packed
shape — a few padded (empty) grid iterations bought against a jit retrace per
update.  Grouped banks feel this most: their shared width is the max over ALL
experts/heads, so any one lopsided group used to widen (and retrace) the whole
bank.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .masks import block_mask_of, path_name

__all__ = [
    "build_pack_state",
    "build_bwd_carrier",
    "refresh_pack_state",
    "pack_entry",
    "pack_mismatch",
    "pack_stats",
    "publish_pack_gauges",
    "is_pack_entry",
    "slack_width",
    "validate_pack",
    "PackIntegrityError",
]


class PackIntegrityError(ValueError):
    """A PackState entry violates its CSC/CSR structural invariants.

    Raised by ``validate_pack`` — a corrupted pack (truncated rows,
    out-of-range block ids, count/nnz drift) would otherwise make the
    block-sparse kernels silently execute the WRONG topology: wrong answers
    with no error, the exact failure the serving engine's integrity guard
    (docs/serving.md#failure-model) exists to make loud.
    """


def is_pack_entry(x) -> bool:
    """Leaf predicate for pack pytrees (an entry dict or a None leaf).

    Covers the block-sparse CSC/CSR entries, the masked-kernel
    backward-superset carrier (``{"bwd_mask": ...}``, build_bwd_carrier), and
    the fused-epilogue entries the train step builds per-trace by merging
    ``{"mom", "seed", "mu", "wd", "sr"}`` into either of the above
    (training/steps.py — layers.linear routes on the ``mom`` key).
    """
    return x is None or (
        isinstance(x, dict)
        and (("idx" in x and "cnt" in x) or "bwd_mask" in x or "mom" in x)
    )


# Param subtrees whose 2-D weight einsums dispatch through layers.linear /
# layers.grouped_linear and therefore consume packs (models/).  Since the
# total-dispatch PR this covers EVERY model family: transformer attention +
# MLP, hymba's SSM projections, xLSTM's mLSTM/sLSTM projections (incl. the
# grouped per-head recurrence), and MoE expert banks + shared experts
# (grouped per-expert CSC/CSR — see docs/kernels.md#grouped-packs).  The
# remaining non-matmul leaves (scan carries, gates, convs, routers) are dense
# and never masked, so they have no entries by construction.
DISPATCHED_SUBTREES = ("attn", "mlp", "ssm", "slstm", "mlstm", "moe")


def _dispatched(name: str) -> bool:
    return any(part in DISPATCHED_SUBTREES for part in name.split("/"))


def _packable(m, block_shape) -> bool:
    bk, bn = block_shape
    return (
        m is not None
        and m.ndim in (2, 3)
        and m.shape[-2] % bk == 0
        and m.shape[-1] % bn == 0
    )


def slack_width(width: int, worst: int, slack: float) -> int:
    """Round a packed width UP to the next hysteresis step, capped at worst.

    The step is ``ceil(slack * worst)`` (worst = the padded worst-case width,
    K/bk): slack=0 keeps the exact tight width; slack=0.25 quantizes widths to
    quarters of the dense grid, so a refresh only changes the packed SHAPE
    (and thus retraces the jitted step) when the true width crosses a quarter
    boundary.  Never rounds down — composing with the never-shrink floor.
    """
    if slack <= 0.0 or width >= worst:
        return min(width, worst)
    step = max(int(np.ceil(slack * worst)), 1)
    return min(-(-width // step) * step, worst)


def pack_entry(
    mask, block_shape, *, min_width: int = 0, min_row_width: int = 0,
    slack: float = 0.0, name: str = "?", bwd_mask=None, min_bwd_width: int = 0,
):
    """Host-pack ONE mask leaf into a PackState entry (CSC + CSR views).

    2-D masks pack as before; 3-D masks (grouped weight banks — MoE experts,
    xLSTM per-head recurrences) pack PER GROUP over the trailing two dims,
    stacked at one shared width (``idx (G, N/bn, width)`` etc.) so the
    grouped kernels execute the whole bank in one launch.

    Raises loudly (rather than packing an all-zero topology) when the layer
    has no active blocks at all: the block-sparse forward would silently
    output zeros for the whole layer, which is never what a sparsity
    distribution intends — see docs/kernels.md#empty-columns-and-dead-layers.
    Individual all-zero COLUMNS are fine (the kernel writes zeros for them),
    and so is an all-zero GROUP of a grouped bank: a dead expert/head outputs
    zeros, which is semantically well-defined under MoE routing — only the
    bank-level all-zero case raises.

    bwd_mask: the layer's Top-KAST backward superset B ⊇ A — packed as a
    second CSC view (``bidx``/``bcnt``/``bnnz``) driving the wgrad grid.
    Raises PackIntegrityError when B does not contain the forward mask at
    block granularity: a forward-active block missing from the wgrad grid
    would silently zero that block's gradient (the exact silent-wrong-answer
    class validate_pack exists to make loud).
    """
    from ..kernels.block_sparse_matmul import (
        pack_block_mask,
        pack_block_mask_rows,
        pack_group_mask,
        pack_group_mask_rows,
    )

    bm = np.asarray(block_mask_of(np.asarray(mask, bool), block_shape))
    grouped = bm.ndim == 3
    nkb, nnb = bm.shape[-2], bm.shape[-1]
    total = int(bm.sum())
    if total == 0:
        raise ValueError(
            f"PackState: layer {name!r} has ZERO active blocks — the "
            "block-sparse kernel would output all-zeros for it. This almost "
            "always means the sparsity distribution assigned (near-)1.0 "
            "sparsity to a layer smaller than one block; see "
            "docs/kernels.md#empty-columns-and-dead-layers"
        )
    width = slack_width(
        max(int(bm.sum(axis=-2).max()), 1, min_width), nkb, slack
    )
    row_width = slack_width(
        max(int(bm.sum(axis=-1).max()), 1, min_row_width), nnb, slack
    )
    if grouped:
        idx, cnt = pack_group_mask(bm, max_count=width)
        ridx, rcnt = pack_group_mask_rows(bm, max_count=row_width)
    else:
        idx, cnt = pack_block_mask(bm, max_count=width)
        ridx, rcnt = pack_block_mask_rows(bm, max_count=row_width)
    entry = {
        "idx": idx,
        "cnt": cnt,
        "ridx": ridx,
        "rcnt": rcnt,
        "nnz": jnp.int32(total),
        "nkb": jnp.int32(nkb),
    }
    if bwd_mask is not None:
        bbm = np.asarray(block_mask_of(np.asarray(bwd_mask, bool), block_shape))
        if np.any(bm & ~bbm):
            raise PackIntegrityError(
                f"PackState: layer {name!r} backward superset does not "
                "contain its forward topology — wgrad would silently zero "
                "forward-active blocks; the superset must be rebuilt from "
                "the CURRENT masks (core/rigl.py::topkast_backward_masks)"
            )
        bwidth = slack_width(
            max(int(bbm.sum(axis=-2).max()), 1, min_bwd_width), nkb, slack
        )
        if grouped:
            bidx, bcnt = pack_group_mask(bbm, max_count=bwidth)
        else:
            bidx, bcnt = pack_block_mask(bbm, max_count=bwidth)
        entry |= {"bidx": bidx, "bcnt": bcnt, "bnnz": jnp.int32(int(bbm.sum()))}
    return entry


def build_pack_state(
    masks, block_shape, *, prev=None, slack: float = 0.0, bwd_masks=None
):
    """Masks pytree -> PackState pytree (same structure; entry or None leaves).

    masks must be CONCRETE (host) arrays — this runs outside jit, on the
    amortized topology-update path, never in the per-step hot loop.
    prev: a previous PackState; per-layer widths are kept >= prev's widths so
    the packed shapes (and thus the jitted train step) stay stable when a
    topology update shrinks some column's count.
    slack: width hysteresis (SparseConfig.pack_width_slack) — widths round up
    to the next ``slack_width`` step so drifting topologies retrace less.
    bwd_masks: Top-KAST backward supersets mirroring masks; packed entries
    additionally carry the superset CSC (``bidx``/``bcnt``/``bnnz``) driving
    the wgrad grid (docs/training.md#topkast).
    """
    flat, treedef = jax.tree_util.tree_flatten_with_path(
        masks, is_leaf=lambda x: x is None
    )
    flat_b = (
        jax.tree_util.tree_flatten(bwd_masks, is_leaf=lambda x: x is None)[0]
        if bwd_masks is not None
        else [None] * len(flat)
    )
    prev_leaves = (
        jax.tree_util.tree_leaves(prev, is_leaf=is_pack_entry)
        if prev is not None
        else [None] * len(flat)
    )
    entries = []
    for (path, m), bw, pe in zip(flat, flat_b, prev_leaves):
        name = path_name(path)
        if not _packable(m, block_shape) or not _dispatched(name):
            entries.append(None)
            continue
        min_w = int(pe["idx"].shape[-1]) if pe is not None else 0
        min_rw = (
            int(pe["ridx"].shape[-1]) if pe is not None and "ridx" in pe else 0
        )
        min_bw = (
            int(pe["bidx"].shape[-1]) if pe is not None and "bidx" in pe else 0
        )
        entries.append(
            pack_entry(
                m, block_shape, min_width=min_w, min_row_width=min_rw,
                slack=slack, name=name, bwd_mask=bw, min_bwd_width=min_bw,
            )
        )
    return jax.tree_util.tree_unflatten(treedef, entries)


def build_bwd_carrier(bwd_masks):
    """Backward supersets -> masked-kernel carrier pack (docs/training.md).

    kernel='masked' takes elementwise masks directly, so the Top-KAST
    superset needs no CSC packing — each dispatched leaf just rides along as
    ``{"bwd_mask": bool (..., K, N)}``; layers.linear routes to the Top-KAST
    masked VJP when it sees this entry.  Leaves outside the dispatched
    subtrees (or dense ``None`` leaves) carry ``None``, mirroring
    ``build_pack_state``'s gating.
    """
    flat, treedef = jax.tree_util.tree_flatten_with_path(
        bwd_masks, is_leaf=lambda x: x is None
    )
    entries = []
    for path, m in flat:
        if m is None or not _dispatched(path_name(path)):
            entries.append(None)
            continue
        entries.append({"bwd_mask": jnp.asarray(m, bool)})
    return jax.tree_util.tree_unflatten(treedef, entries)


def refresh_pack_state(
    masks, block_shape, *, prev, slack: float = 0.0, bwd_masks=None
):
    """Re-pack after a topology update (call right after every rigl_step).

    Same as build_pack_state but prev is required — refreshing without the
    previous pack would let widths shrink and retrigger jit compilation on
    every update.
    """
    return build_pack_state(
        masks, block_shape, prev=prev, slack=slack, bwd_masks=bwd_masks
    )


def pack_mismatch(masks, pack, block_shape, bwd_masks=None):
    """Traced-safe exact staleness check: #blocks where pack != masks.

    Returns an int32 scalar, 0 iff every pack entry encodes exactly the block
    mask of its layer (the entry is scattered back to a block mask via
    kernels.block_sparse_matmul.unpack_block_mask — the same reconstruction
    the VJP's CSR fallback uses).  Cost: one elementwise any-reduce over each
    mask (O(#sparsifiable params), no batch/seq factor) plus tiny block-grid
    compares — the train step already does O(#params) elementwise mask work
    every step (dense_to_sparse_grad), so reporting this as the per-step
    ``pack_stale`` metric is noise next to the M-scaled matmuls.  A nonzero
    value means a rigl_step ran without refresh_pack_state and the kernels
    are executing a stale topology (docs/kernels.md#staleness).

    bwd_masks: when given (Top-KAST superset pairs), entries carrying a
    ``bidx`` view are also checked against the block mask of their backward
    superset — a stale wgrad grid is just as silently wrong as a stale
    forward grid.
    """
    from ..kernels.block_sparse_matmul import unpack_block_mask

    flat_m = jax.tree_util.tree_flatten(masks, is_leaf=lambda x: x is None)[0]
    flat_b = (
        jax.tree_util.tree_flatten(bwd_masks, is_leaf=lambda x: x is None)[0]
        if bwd_masks is not None
        else [None] * len(flat_m)
    )
    flat_e = jax.tree_util.tree_leaves(pack, is_leaf=is_pack_entry)
    total = jnp.int32(0)

    def _recount(idx, cnt, bm):
        if idx.ndim == 3:  # grouped bank: per-group reconstruction
            rec = jax.vmap(
                lambda i_, c_: unpack_block_mask(i_, c_, bm.shape[-2])
            )(idx, cnt)
        else:
            rec = unpack_block_mask(idx, cnt, bm.shape[0])
        return jnp.sum(rec != bm).astype(jnp.int32)

    for m, bw, e in zip(flat_m, flat_b, flat_e):
        if e is None or not _packable(m, block_shape):
            continue
        total = total + _recount(e["idx"], e["cnt"], block_mask_of(m, block_shape))
        if bw is not None and "bidx" in e:
            total = total + _recount(
                e["bidx"], e["bcnt"], block_mask_of(bw, block_shape)
            )
    return total


def validate_pack(pack, *, where: str = "pack") -> int:
    """Host-side CSC/CSR integrity check over every PackState entry.

    Verifies, per packed leaf (2-D and grouped 3-D entries alike):

      * shape coherence — ``cnt`` matches ``idx`` minus its width dim, same
        for ``rcnt``/``ridx``, and the CSR view has one row per K-block
        (``ridx.shape[-2] == nkb``);
      * counts within capacity — ``0 <= cnt <= width`` and
        ``0 <= rcnt <= row_width`` (a truncated pack shows up as a count
        claiming more slots than the index rows hold);
      * live indices in range — every index slot BELOW its column's count
        holds a block id inside the grid (``idx`` in ``[0, nkb)``, ``ridx``
        in ``[0, nnb)``); padded slots beyond the count are ignored;
      * nnz consistency — ``sum(cnt) == nnz == sum(rcnt)`` (the CSC and CSR
        views must describe the SAME topology).

    Raises ``PackIntegrityError`` naming the layer and the violated
    invariant; returns the number of entries checked.  Cost is O(block
    grid) numpy on the host — nothing per-token: callers run it at engine
    construction and after every ``refresh_pack`` (training/steps.py), the
    same amortized points that build packs in the first place.
    """
    if pack is None:
        return 0
    flat, _ = jax.tree_util.tree_flatten_with_path(pack, is_leaf=is_pack_entry)
    checked = 0
    for path, e in flat:
        if e is None:
            continue
        name = f"{where}:{path_name(path)}"

        def fail(msg):
            raise PackIntegrityError(
                f"PackState integrity violation at {name}: {msg} — the "
                "block-sparse kernels would execute a corrupted topology "
                "(silent wrong answers); see docs/serving.md#failure-model"
            )

        if "bwd_mask" in e:  # masked-kernel superset carrier — no CSC fields
            if np.asarray(e["bwd_mask"]).dtype != np.bool_:
                fail("bwd_mask carrier is not a bool array")
            checked += 1
            continue
        for k in ("idx", "cnt", "ridx", "rcnt", "nnz", "nkb"):
            if k not in e:
                fail(f"entry is missing field {k!r}")
        idx = np.asarray(e["idx"])
        cnt = np.asarray(e["cnt"])
        ridx = np.asarray(e["ridx"])
        rcnt = np.asarray(e["rcnt"])
        nnz = int(e["nnz"])
        nkb = int(e["nkb"])
        if idx.shape[:-1] != cnt.shape:
            fail(f"idx {idx.shape} does not extend cnt {cnt.shape}")
        if ridx.shape[:-1] != rcnt.shape:
            fail(f"ridx {ridx.shape} does not extend rcnt {rcnt.shape}")
        if ridx.shape[-2] != nkb:
            fail(f"CSR has {ridx.shape[-2]} rows, expected nkb={nkb}")
        width, row_width = idx.shape[-1], ridx.shape[-1]
        nnb = cnt.shape[-1]
        if cnt.size and (cnt.min() < 0 or cnt.max() > width):
            fail(
                f"cnt out of range [0, width={width}] "
                f"(max {int(cnt.max())} — truncated pack?)"
            )
        if rcnt.size and (rcnt.min() < 0 or rcnt.max() > row_width):
            fail(
                f"rcnt out of range [0, row_width={row_width}] "
                f"(max {int(rcnt.max())} — truncated pack?)"
            )
        live = np.arange(width) < cnt[..., None]
        if np.any(live & ((idx < 0) | (idx >= nkb))):
            fail(f"live CSC index outside the K-block grid [0, {nkb})")
        rlive = np.arange(row_width) < rcnt[..., None]
        if np.any(rlive & ((ridx < 0) | (ridx >= nnb))):
            fail(f"live CSR index outside the N-block grid [0, {nnb})")
        csum, rsum = int(cnt.sum()), int(rcnt.sum())
        if csum != nnz or rsum != nnz:
            fail(
                f"nnz inconsistency: sum(cnt)={csum}, sum(rcnt)={rsum}, "
                f"recorded nnz={nnz}"
            )
        if "bidx" in e:  # Top-KAST superset CSC — same invariants, wider view
            bidx = np.asarray(e["bidx"])
            bcnt = np.asarray(e["bcnt"])
            bnnz = int(e["bnnz"])
            bwidth = bidx.shape[-1]
            if bidx.shape[:-1] != bcnt.shape:
                fail(f"bidx {bidx.shape} does not extend bcnt {bcnt.shape}")
            if bcnt.size and (bcnt.min() < 0 or bcnt.max() > bwidth):
                fail(
                    f"bcnt out of range [0, bwidth={bwidth}] "
                    f"(max {int(bcnt.max())} — truncated superset pack?)"
                )
            blive = np.arange(bwidth) < bcnt[..., None]
            if np.any(blive & ((bidx < 0) | (bidx >= nkb))):
                fail(f"live superset index outside the K-block grid [0, {nkb})")
            if int(bcnt.sum()) != bnnz:
                fail(
                    f"superset nnz inconsistency: sum(bcnt)={int(bcnt.sum())}, "
                    f"recorded bnnz={bnnz}"
                )
            if bnnz < nnz:
                fail(
                    f"superset smaller than forward topology (bnnz={bnnz} < "
                    f"nnz={nnz}) — B must contain A"
                )
            # Containment: every forward-active block must appear live in the
            # superset CSC, else wgrad silently zeros it.  Padded slots
            # scatter into a dummy trailing column so they can't clobber
            # block 0.
            fwd = np.zeros((*cnt.shape, nkb + 1), bool)
            np.put_along_axis(fwd, np.where(live, idx, nkb), live, axis=-1)
            fwd = fwd[..., :nkb]
            sup = np.zeros((*bcnt.shape, nkb + 1), bool)
            np.put_along_axis(sup, np.where(blive, bidx, nkb), blive, axis=-1)
            sup = sup[..., :nkb]
            if np.any(fwd & ~sup):
                fail(
                    "forward-active block missing from the backward superset "
                    "CSC — B does not contain A"
                )
        checked += 1
    return checked


def pack_stats(pack) -> dict[str, Any]:
    """Host-side bookkeeping: per-layer grid width vs the padded worst case,
    plus block-grid densities — ``density`` is live forward blocks over the
    full (nkb x cols x groups) block grid, ``superset_density`` the same for
    the Top-KAST backward superset B (None when the entry carries no
    superset).  These feed the live ``kernel_*`` gauges
    (docs/observability.md#metric-catalog), so the tight-grid win and the
    B-vs-A overhead are visible during a run, not only in kernel_bench."""
    out: dict[str, Any] = {"layers": {}}
    tight = padded = 0
    nnz_total = bnnz_total = cells_total = bcells_total = 0
    flat, _ = jax.tree_util.tree_flatten_with_path(pack, is_leaf=is_pack_entry)
    for path, e in flat:
        if e is None:
            continue
        name = path_name(path)
        width = int(e["idx"].shape[-1])
        nkb = int(e["nkb"])
        groups = int(e["idx"].shape[0]) if e["idx"].ndim == 3 else 1
        cols = int(e["cnt"].shape[-1])
        nnz = int(e["nnz"])
        cells = nkb * cols * groups
        bnnz = int(e["bnnz"]) if "bidx" in e else None
        out["layers"][name] = {
            "width": width,
            "worst_case": nkb,
            "grid_fraction": width / nkb,
            "row_width": int(e["ridx"].shape[-1]) if "ridx" in e else None,
            "nnz_blocks": nnz,
            "cols": cols,
            "groups": groups,
            "density": nnz / cells if cells else 0.0,
            "superset_density": (
                bnnz / cells if bnnz is not None and cells else None
            ),
        }
        tight += width * groups
        padded += nkb * groups
        nnz_total += nnz
        cells_total += cells
        if bnnz is not None:
            bnnz_total += bnnz
            bcells_total += cells
    out["grid_iters_tight"] = tight
    out["grid_iters_padded"] = padded
    out["grid_fraction"] = tight / padded if padded else 1.0
    out["density"] = nnz_total / cells_total if cells_total else 0.0
    out["superset_density"] = (
        bnnz_total / bcells_total if bcells_total else None
    )
    return out


def publish_pack_gauges(metrics, pack) -> None:
    """Set the kernel_* gauges on a metrics registry (repro.obs duck-typed —
    no import, so core stays obs-free) from ``pack_stats``: runtime grid
    fraction plus forward/superset block densities, per layer and under the
    ``_total`` aggregate label.  Both the serving engine (construction — its
    pack is engine-lifetime constant) and the trainer (every refresh_pack)
    publish through this one helper, so the catalog names stay identical
    across the two paths (docs/observability.md#metric-catalog)."""
    if pack is None:
        return
    st = pack_stats(pack)
    gf = metrics.gauge("kernel_grid_fraction",
                       "packed grid width / padded worst case",
                       labels=("layer",))
    dn = metrics.gauge("kernel_block_density",
                       "live forward blocks / full block grid",
                       labels=("layer",))
    sd = metrics.gauge("kernel_superset_density",
                       "Top-KAST backward-superset blocks / full block grid",
                       labels=("layer",))
    gf.labels("_total").set(st["grid_fraction"])
    dn.labels("_total").set(st["density"])
    if st["superset_density"] is not None:
        sd.labels("_total").set(st["superset_density"])
    for name, ls in st["layers"].items():
        gf.labels(name).set(ls["grid_fraction"])
        dn.labels(name).set(ls["density"])
        if ls["superset_density"] is not None:
            sd.labels(name).set(ls["superset_density"])

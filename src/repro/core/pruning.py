"""Dense-to-sparse baselines the paper compares against.

- Gradual magnitude pruning (Zhu & Gupta 2018): sparsity ramps
  s_t = s_f * (1 - (1 - (t - t0)/(t1 - t0))^3) between t0 and t1, pruning the
  lowest-|w| weights every ``prune_every`` steps.  Pruned connections never
  return (masks are monotone).
- SNIP (Lee et al. 2019): one-shot mask at init by saliency |theta * grad|
  (paper Appendix M bug #3: gradient-magnitude-only is catastrophically bad —
  we implement the corrected saliency and test both orderings).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .rigl import _rank_desc

__all__ = ["PruningSchedule", "pruning_target_sparsity", "prune_step", "snip_masks"]


@dataclasses.dataclass(frozen=True)
class PruningSchedule:
    final_sparsity: float
    begin_step: int
    end_step: int
    prune_every: int = 1000
    initial_sparsity: float = 0.0

    def target(self, t):
        """Zhu & Gupta cubic ramp, traceable in t."""
        t = jnp.asarray(t, jnp.float32)
        span = max(self.end_step - self.begin_step, 1)
        p = jnp.clip((t - self.begin_step) / span, 0.0, 1.0)
        sf, si = self.final_sparsity, self.initial_sparsity
        return sf + (si - sf) * (1.0 - p) ** 3

    def is_prune_step(self, t):
        t = jnp.asarray(t)
        return (
            (t >= self.begin_step)
            & (t <= self.end_step)
            & ((t - self.begin_step) % self.prune_every == 0)
        )


def pruning_target_sparsity(sched: PruningSchedule, t):
    return sched.target(t)


def _prune_layer(w, m, target_sparsity):
    """Keep the (1-s)*N largest-|w| among currently-active (monotone)."""
    n = w.size
    n_keep = jnp.round((1.0 - target_sparsity) * n).astype(jnp.int32)
    mag = jnp.where(m.reshape(-1).astype(bool), jnp.abs(w).reshape(-1).astype(jnp.float32), -jnp.inf)
    kept = _rank_desc(mag) < n_keep
    new_m = kept.reshape(w.shape)
    return new_m.astype(m.dtype), w * new_m.astype(w.dtype)


def prune_step(params, masks, t, sched: PruningSchedule):
    """Apply gradual pruning to every masked layer (uniform per-layer target)."""
    s_t = sched.target(t)

    def _f(w, m):
        if m is None:
            return w, None
        return _prune_layer(w, m, s_t)

    flat_p, treedef = jax.tree_util.tree_flatten_with_path(params)
    flat_m = jax.tree_util.tree_flatten(masks, is_leaf=lambda x: x is None)[0]
    new_p, new_m = [], []
    for (path, w), m in zip(flat_p, flat_m):
        nw_nm = _f(w, m)
        if m is None:
            new_p.append(w)
            new_m.append(None)
        else:
            new_m.append(nw_nm[0])
            new_p.append(nw_nm[1])
    unflat = lambda ls: jax.tree_util.tree_unflatten(treedef, ls)
    return unflat(new_p), unflat(new_m)


def snip_masks(params, dense_grads, sparsities, saliency: str = "weight_times_grad"):
    """One-shot SNIP masks: keep top-(1-s_l) by saliency per layer.

    saliency: 'weight_times_grad' (correct, |theta * grad|) or 'grad'
    (the Appendix-M bug #3 variant, kept for the ablation benchmark).
    """
    from .masks import path_name

    flat_p, treedef = jax.tree_util.tree_flatten_with_path(params)
    flat_g = jax.tree_util.tree_flatten(dense_grads)[0]
    out = []
    for (path, w), g in zip(flat_p, flat_g):
        name = path_name(path)
        s = sparsities.get(name)
        if s is None:
            out.append(None)
            continue
        if saliency == "weight_times_grad":
            score = jnp.abs(w * g).reshape(-1).astype(jnp.float32)
        elif saliency == "grad":
            score = jnp.abs(g).reshape(-1).astype(jnp.float32)
        else:
            raise ValueError(saliency)
        n_keep = int(round((1.0 - s) * w.size))
        kept = _rank_desc(score) < n_keep
        out.append(kept.reshape(w.shape))
    return jax.tree_util.tree_unflatten(treedef, out)

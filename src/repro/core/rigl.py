"""RigL connectivity updates (paper §3, Algorithm 1) + SET/SNFS growers.

Drop:  remove the k lowest-|w| active connections per layer,
       k = f_decay(t) * n_active_l  (exact count, dynamic in t).
Grow:  activate the k highest-score inactive connections, where score is
         rigl -> |dense gradient|        (the paper's contribution)
         snfs -> |dense momentum|        (Dettmers & Zettlemoyer 2019)
         set  -> uniform random          (Mocanu et al. 2018)
       Freshly-dropped connections are eligible for regrowth, matching the
       official google-research/rigl code.  Grown connections are initialized
       to ZERO (paper default) so the network function is unchanged at the
       update step, and their optimizer state is reset.

Dynamic-k with static shapes: XLA requires static shapes, but k depends on the
traced step t.  We rank scores with a stable double-argsort (unique ranks, ties
broken by index) and compare ranks against the traced scalar k — exact counts,
bit-deterministic, nnz preserved exactly (property-tested).

Block mode (TPU-native): with block_shape=(bm, bn), drop/grow scores are pooled
(L1) over aligned blocks of the last two dims, so the resulting mask is block
sparse and can be executed by kernels/block_sparse_matmul.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from .schedules import UpdateSchedule

__all__ = ["SparseAlgo", "rigl_update_layer", "rigl_update", "dense_to_sparse_grad"]


@dataclasses.dataclass(frozen=True)
class SparseAlgo:
    """Which sparse-training method is in effect."""

    method: str = "rigl"  # rigl | set | snfs | static
    schedule: UpdateSchedule = UpdateSchedule()
    grow_init: str = "zeros"  # zeros | random | gradient  (paper tried all three)
    block_shape: Optional[tuple[int, int]] = None  # TPU block-sparse mode


def _rank_desc(x):
    """Unique descending ranks (0 = largest); stable, deterministic."""
    order = jnp.argsort(-x, stable=True)
    return jnp.argsort(order, stable=True)


def _pool_blocks(x, block_shape):
    """Sum |x| over (bm, bn) blocks of the last two dims -> block scores."""
    bm, bn = block_shape
    *lead, m, n = x.shape
    assert m % bm == 0 and n % bn == 0, (x.shape, block_shape)
    xb = x.reshape(*lead, m // bm, bm, n // bn, bn)
    return jnp.sum(xb, axis=(-3, -1))


def _expand_blocks(xb, block_shape, shape):
    bm, bn = block_shape
    *lead, m, n = shape
    x = jnp.broadcast_to(
        xb[..., :, None, :, None], (*lead, m // bm, bm, n // bn, bn)
    )
    return x.reshape(shape)


def rigl_update_layer(
    w,
    mask,
    grow_score,
    fraction,
    *,
    grow_init: str = "zeros",
    key=None,
    block_shape=None,
    lr: float = 0.0,
    grad=None,
):
    """One layer's drop/grow.  Returns (new_mask, new_w, grown_mask).

    grow_score: dense score used for growth (|g| for rigl, |momentum| for
      snfs, uniform random for set) — same shape as w.
    fraction: traced scalar f_decay(t).
    """
    f32 = jnp.float32
    m_bool = mask.astype(bool)

    if block_shape is not None:
        mag = _pool_blocks(jnp.abs(w).astype(f32), block_shape)
        score = _pool_blocks(jnp.abs(grow_score).astype(f32), block_shape)
        m_blk = _pool_blocks(m_bool.astype(f32), block_shape) > 0
        new_blk, grown_blk = _drop_grow(mag, score, m_blk, fraction)
        new_mask = _expand_blocks(new_blk, block_shape, w.shape)
        grown = _expand_blocks(grown_blk, block_shape, w.shape)
    else:
        mag = jnp.abs(w).astype(f32)
        score = jnp.abs(grow_score).astype(f32)
        new_mask, grown = _drop_grow(mag, score, m_bool, fraction)

    if grow_init == "zeros":
        init_val = jnp.zeros_like(w)
    elif grow_init == "random":
        assert key is not None
        init_val = 0.01 * jax.random.normal(key, w.shape, w.dtype)
    elif grow_init == "gradient":
        assert grad is not None
        init_val = (-lr * grad).astype(w.dtype)
    else:
        raise ValueError(grow_init)

    new_w = jnp.where(grown, init_val, w)
    return new_mask.astype(mask.dtype), new_w, grown


def _drop_grow(mag, score, m_bool, fraction):
    """Core exact-count drop/grow on flattened scores."""
    shape = mag.shape
    mag = mag.reshape(-1)
    score = score.reshape(-1)
    m = m_bool.reshape(-1)

    n_active = jnp.sum(m.astype(jnp.int32))
    k = jnp.floor(fraction * n_active).astype(jnp.int32)
    n_keep = n_active - k

    neg_inf = jnp.float32(-jnp.inf)
    # DROP: keep the n_keep largest |w| among active.
    drop_scores = jnp.where(m, mag, neg_inf)
    kept = _rank_desc(drop_scores) < n_keep

    # GROW: k largest grow-scores among everything not kept
    # (inactive ∪ freshly dropped — official-code semantics).
    grow_scores = jnp.where(kept, neg_inf, score)
    grown = _rank_desc(grow_scores) < k

    new_mask = kept | grown
    return new_mask.reshape(shape), grown.reshape(shape)


def rigl_update(
    params,
    masks,
    dense_grads,
    t,
    algo: SparseAlgo,
    key,
    dense_momentum=None,
    lr: float = 0.0,
):
    """Apply the connectivity update to every masked layer.

    Returns (new_params, new_masks, grown_masks).  grown_masks is used by the
    optimizer to reset per-connection state (momentum) of newly-activated
    connections.  For method == 'static' this is an identity.

    NOTE: callers gate this on ``algo.schedule.is_update_step(t)`` — by design
    this lives in a SEPARATE jitted function from the hot train_step so the
    per-step roofline stays honest and the dense-gradient work is visibly
    amortized (paper Appendix H).
    """
    if algo.method == "static":
        zeros = jax.tree_util.tree_map(
            lambda m: None if m is None else jnp.zeros_like(m, bool),
            masks,
            is_leaf=lambda x: x is None,
        )
        return params, masks, zeros

    fraction = algo.schedule.fraction(t)
    flat_p, treedef = jax.tree_util.tree_flatten_with_path(params)
    flat_m = jax.tree_util.tree_flatten(masks, is_leaf=lambda x: x is None)[0]
    flat_g = jax.tree_util.tree_flatten(dense_grads)[0]
    flat_mom = (
        jax.tree_util.tree_flatten(dense_momentum)[0]
        if dense_momentum is not None
        else [None] * len(flat_p)
    )

    new_p, new_m, grown_l = [], [], []
    for i, ((path, w), m, g, mom) in enumerate(
        zip(flat_p, flat_m, flat_g, flat_mom)
    ):
        if m is None:
            new_p.append(w)
            new_m.append(None)
            grown_l.append(None)
            continue
        sub = jax.random.fold_in(key, i)
        if algo.method == "rigl":
            score = g
        elif algo.method == "snfs":
            assert mom is not None, "snfs needs dense momentum"
            score = mom
        elif algo.method == "set":
            score = jax.random.uniform(sub, w.shape)
        else:
            raise ValueError(algo.method)
        nm, nw, grown = rigl_update_layer(
            w,
            m,
            score,
            fraction,
            grow_init=algo.grow_init,
            key=sub,
            block_shape=algo.block_shape,
            lr=lr,
            grad=g,
        )
        new_p.append(nw)
        new_m.append(nm)
        grown_l.append(grown)

    unflatten = lambda leaves: jax.tree_util.tree_unflatten(treedef, leaves)
    return unflatten(new_p), unflatten(new_m), unflatten(grown_l)


def dense_to_sparse_grad(dense_grads, masks):
    """g_sparse = g_dense * m  (paper: optimizer only sees active connections)."""
    def _mul(g, m):
        if m is None:
            return g
        return g * m.astype(g.dtype)

    return jax.tree_util.tree_map(
        _mul, dense_grads, masks, is_leaf=lambda x: x is None
    )


def dsr_update(params, masks, t, algo: SparseAlgo, key):
    """Dynamic Sparse Reparameterization (Mostafa & Wang 2019) — the paper's
    Fig 2-left "DSR" row: drop by a GLOBAL magnitude threshold (per-layer
    budgets shift), grow at random across all layers.  Total nnz is
    preserved but per-layer sparsity is free to move — which is why DSR
    cannot target a fixed FLOP budget (paper Table 1 "Selectable FLOPs: no").
    """
    fraction = algo.schedule.fraction(t)
    flat_p, treedef = jax.tree_util.tree_flatten_with_path(params)
    flat_m = jax.tree_util.tree_flatten(masks, is_leaf=lambda x: x is None)[0]

    mags, actives, sizes = [], [], []
    for (path, w), m in zip(flat_p, flat_m):
        if m is None:
            continue
        mags.append(jnp.abs(w).astype(jnp.float32).reshape(-1))
        actives.append(m.reshape(-1).astype(bool))
        sizes.append(w.size)
    all_mag = jnp.concatenate(mags)
    all_act = jnp.concatenate(actives)
    n_active = jnp.sum(all_act.astype(jnp.int32))
    k = jnp.floor(fraction * n_active).astype(jnp.int32)

    drop_scores = jnp.where(all_act, all_mag, -jnp.inf)
    kept = _rank_desc(drop_scores) < (n_active - k)
    grow_scores = jnp.where(kept, -jnp.inf, jax.random.uniform(key, all_mag.shape))
    grown = _rank_desc(grow_scores) < k
    new_all = kept | grown

    new_p, new_m, grown_l = [], [], []
    off = 0
    i = 0
    for (path, w), m in zip(flat_p, flat_m):
        if m is None:
            new_p.append(w)
            new_m.append(None)
            grown_l.append(None)
            continue
        sl = slice(off, off + sizes[i])
        nm = new_all[sl].reshape(w.shape)
        gr = grown[sl].reshape(w.shape)
        new_p.append(jnp.where(gr, jnp.zeros_like(w), w))
        new_m.append(nm.astype(m.dtype))
        grown_l.append(gr)
        off += sizes[i]
        i += 1
    unflatten = lambda ls: jax.tree_util.tree_unflatten(treedef, ls)
    return unflatten(new_p), unflatten(new_m), unflatten(grown_l)

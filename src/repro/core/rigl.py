"""RigL connectivity updates (paper §3, Algorithm 1) + SET/SNFS growers.

Drop:  remove the k lowest-|w| active connections per layer,
       k = f_decay(t) * n_active_l  (exact count, dynamic in t).
Grow:  activate the k highest-score inactive connections, where score is
         rigl    -> |dense gradient|     (the paper's contribution)
         snfs    -> |dense momentum|     (Dettmers & Zettlemoyer 2019)
         set     -> uniform random       (Mocanu et al. 2018)
         topkast -> |w| on the backward superset (Jayakumar et al. 2020)
       Freshly-dropped connections are eligible for regrowth, matching the
       official google-research/rigl code.  Grown connections are initialized
       to ZERO (paper default) so the network function is unchanged at the
       update step, and their optimizer state is reset.

Top-KAST (always-sparse backward): each layer additionally carries a backward
mask B = A ∪ exploration — the forward top-k set A plus the Δ next-best
candidates (``topkast_backward_masks``).  The exploration set B\\A receives
gradient (and optimizer updates) but never contributes to forward compute, so
the wgrad restricted to B is EXACTLY the dense gradient on B's support: it
doubles as the dense-gradient side-channel that rigl/snfs grow scores need,
which is what lets every method stay on the sparse Pallas kernels end-to-end
(training/steps.py).  For ``method='topkast'`` the drop/grow itself is
magnitude-driven: drop the lowest-|w| of A, grow the highest-|w| candidates
inside B — entering weights that were already trained in B\\A KEEP their
values (the point of Top-KAST); only never-trained entries (outside B) are
zero, and only those are flagged ``grown`` for optimizer-state reset.

Dynamic-k with static shapes: XLA requires static shapes, but k depends on the
traced step t.  We rank scores with a stable double-argsort (unique ranks, ties
broken by index) and compare ranks against the traced scalar k — exact counts,
bit-deterministic, nnz preserved exactly (property-tested).

Block mode (TPU-native): with block_shape=(bm, bn), drop/grow scores are pooled
(L1) over aligned blocks of the last two dims, so the resulting mask is block
sparse and can be executed by kernels/block_sparse_matmul.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .schedules import UpdateSchedule

__all__ = [
    "SparseAlgo",
    "rigl_update_layer",
    "rigl_update",
    "dense_to_sparse_grad",
    "topkast_backward_masks",
]


@dataclasses.dataclass(frozen=True)
class SparseAlgo:
    """Which sparse-training method is in effect."""

    method: str = "rigl"  # rigl | set | snfs | topkast | static
    schedule: UpdateSchedule = UpdateSchedule()
    grow_init: str = "zeros"  # zeros | random | gradient  (paper tried all three)
    block_shape: Optional[tuple[int, int]] = None  # TPU block-sparse mode
    # Δ of the top-(k+Δ) Top-KAST backward superset, as a fraction of each
    # layer's units (elements, or blocks in block mode); also the exploration
    # breadth of the superset-gradient side-channel rigl/snfs use under
    # kernel dispatch.  |B| = min(total, |A| + ceil(backward_extra * total)).
    backward_extra: float = 0.1


def _rank_desc(x):
    """Unique descending ranks (0 = largest); stable, deterministic."""
    order = jnp.argsort(-x, stable=True)
    return jnp.argsort(order, stable=True)


def _pool_blocks(x, block_shape):
    """Sum |x| over (bm, bn) blocks of the last two dims -> block scores."""
    bm, bn = block_shape
    *lead, m, n = x.shape
    assert m % bm == 0 and n % bn == 0, (x.shape, block_shape)
    xb = x.reshape(*lead, m // bm, bm, n // bn, bn)
    return jnp.sum(xb, axis=(-3, -1))


def _expand_blocks(xb, block_shape, shape):
    bm, bn = block_shape
    *lead, m, n = shape
    x = jnp.broadcast_to(
        xb[..., :, None, :, None], (*lead, m // bm, bm, n // bn, bn)
    )
    return x.reshape(shape)


def _exploration_score(w, m_bool, key, block_shape=None):
    """Ranking score for backward-superset candidates (higher = join B first).

    Active slots rank above everything (B must contain A); then nonzero
    inactive weights by |w| (Top-KAST's trained exploration set keeps its
    standing); zero weights last, in random order (fresh exploration —
    deterministic under a fixed key).  The +1.0 shift keeps every nonzero
    |w| strictly above the [0, 1) random tiebreak of the zeros.
    """
    f32 = jnp.float32
    mag = jnp.abs(w).astype(f32)
    if block_shape is not None:
        mag = _pool_blocks(mag, block_shape)
        m_bool = _pool_blocks(m_bool.astype(f32), block_shape) > 0
    tie = jax.random.uniform(key, mag.shape, f32)
    score = jnp.where(mag > 0, mag + 1.0, tie)
    return jnp.where(m_bool, jnp.inf, score), m_bool


def topkast_superset_layer(w, mask, extra, key, *, block_shape=None):
    """One layer's backward mask B ⊇ A with |B| = min(total, |A| + Δ).

    Δ = ceil(extra * units) where units = elements (or blocks in block mode).
    Selection: A first, then the Δ best exploration candidates by
    ``_exploration_score``.  Deterministic under a fixed key; exact counts via
    the same stable double-argsort as drop/grow.
    """
    m_bool = mask.astype(bool)
    score, m_unit = _exploration_score(w, m_bool, key, block_shape)
    total = m_unit.size
    delta = int(np.ceil(float(extra) * total)) if extra else 0
    k_fwd = jnp.sum(m_unit.reshape(-1).astype(jnp.int32))
    k_bwd = jnp.minimum(k_fwd + delta, total)
    bwd_unit = (_rank_desc(score.reshape(-1)) < k_bwd).reshape(m_unit.shape)
    if block_shape is not None:
        return _expand_blocks(bwd_unit, block_shape, mask.shape).astype(
            mask.dtype
        )
    return bwd_unit.astype(mask.dtype)


def topkast_backward_masks(params, masks, extra, key, *, block_shape=None):
    """Backward-superset pytree: per layer, B = A ∪ top-Δ exploration.

    Mirrors the mask pytree (None leaves pass through).  Refreshed at init
    and after every topology update (training/steps.py::refresh_pack) so the
    superset always brackets the CURRENT forward mask; the next update's grow
    step then only ever activates coordinates that received gradient.
    """
    flat_p, treedef = jax.tree_util.tree_flatten_with_path(params)
    flat_m = jax.tree_util.tree_flatten(masks, is_leaf=lambda x: x is None)[0]
    out = []
    for i, ((path, w), m) in enumerate(zip(flat_p, flat_m)):
        if m is None:
            out.append(None)
            continue
        sub = jax.random.fold_in(key, i)
        out.append(
            topkast_superset_layer(w, m, extra, sub, block_shape=block_shape)
        )
    return jax.tree_util.tree_unflatten(treedef, out)


def rigl_update_layer(
    w,
    mask,
    grow_score,
    fraction,
    *,
    grow_init: str = "zeros",
    key=None,
    block_shape=None,
    lr: float = 0.0,
    grad=None,
):
    """One layer's drop/grow.  Returns (new_mask, new_w, grown_mask).

    grow_score: dense score used for growth (|g| for rigl, |momentum| for
      snfs, uniform random for set) — same shape as w.
    fraction: traced scalar f_decay(t).
    """
    f32 = jnp.float32
    m_bool = mask.astype(bool)

    if block_shape is not None:
        mag = _pool_blocks(jnp.abs(w).astype(f32), block_shape)
        score = _pool_blocks(jnp.abs(grow_score).astype(f32), block_shape)
        m_blk = _pool_blocks(m_bool.astype(f32), block_shape) > 0
        new_blk, grown_blk = _drop_grow(mag, score, m_blk, fraction)
        new_mask = _expand_blocks(new_blk, block_shape, w.shape)
        grown = _expand_blocks(grown_blk, block_shape, w.shape)
    else:
        mag = jnp.abs(w).astype(f32)
        score = jnp.abs(grow_score).astype(f32)
        new_mask, grown = _drop_grow(mag, score, m_bool, fraction)

    if grow_init == "zeros":
        init_val = jnp.zeros_like(w)
    elif grow_init == "random":
        assert key is not None
        init_val = 0.01 * jax.random.normal(key, w.shape, w.dtype)
    elif grow_init == "gradient":
        assert grad is not None
        init_val = (-lr * grad).astype(w.dtype)
    else:
        raise ValueError(grow_init)

    new_w = jnp.where(grown, init_val, w)
    return new_mask.astype(mask.dtype), new_w, grown


def _topkast_update_layer(w, mask, bwd_mask, fraction, key, block_shape=None):
    """Top-KAST drop/grow: magnitude top-k restricted to the backward superset.

    Drop the lowest-|w| actives (same exact-count machinery as rigl); grow the
    highest-|w| candidates INSIDE the superset B (zero weights tie-broken at
    random below every trained weight).  Candidates outside B score -inf and
    can never win — B\\kept always holds at least k candidates, so cardinality
    is exactly conserved.  Weights are NOT reinitialized: a connection entering
    A from the trained exploration set B\\A keeps the value (and optimizer
    state) it earned there — the whole point of training the superset.  The
    returned ``grown`` flags only never-trained entries (outside B, zero by
    construction), so optimizer-state resets stay correct for every method.
    """
    f32 = jnp.float32
    m_bool = mask.astype(bool)
    b_bool = bwd_mask.astype(bool)
    mag = jnp.abs(w).astype(f32)
    if block_shape is not None:
        mag = _pool_blocks(mag, block_shape)
        m_u = _pool_blocks(m_bool.astype(f32), block_shape) > 0
        b_u = _pool_blocks(b_bool.astype(f32), block_shape) > 0
    else:
        m_u, b_u = m_bool, b_bool
    tie = jax.random.uniform(key, mag.shape, f32)
    score = jnp.where(mag > 0, mag + 1.0, tie)
    score = jnp.where(b_u, score, -jnp.inf)
    new_u, _ = _drop_grow(mag, score, m_u, fraction)
    if block_shape is not None:
        new_mask = _expand_blocks(new_u, block_shape, w.shape)
    else:
        new_mask = new_u
    grown = new_mask & ~m_bool & ~b_bool
    return new_mask.astype(mask.dtype), w, grown


def _drop_grow(mag, score, m_bool, fraction):
    """Core exact-count drop/grow on flattened scores."""
    shape = mag.shape
    mag = mag.reshape(-1)
    score = score.reshape(-1)
    m = m_bool.reshape(-1)

    n_active = jnp.sum(m.astype(jnp.int32))
    k = jnp.floor(fraction * n_active).astype(jnp.int32)
    n_keep = n_active - k

    neg_inf = jnp.float32(-jnp.inf)
    # DROP: keep the n_keep largest |w| among active.
    drop_scores = jnp.where(m, mag, neg_inf)
    kept = _rank_desc(drop_scores) < n_keep

    # GROW: k largest grow-scores among everything not kept
    # (inactive ∪ freshly dropped — official-code semantics).
    grow_scores = jnp.where(kept, neg_inf, score)
    grown = _rank_desc(grow_scores) < k

    new_mask = kept | grown
    return new_mask.reshape(shape), grown.reshape(shape)


def rigl_update(
    params,
    masks,
    dense_grads,
    t,
    algo: SparseAlgo,
    key,
    dense_momentum=None,
    lr: float = 0.0,
    bwd_masks=None,
):
    """Apply the connectivity update to every masked layer.

    Returns (new_params, new_masks, grown_masks).  grown_masks is used by the
    optimizer to reset per-connection state (momentum) of newly-activated
    connections.  For method == 'static' this is an identity.

    bwd_masks: the Top-KAST backward-superset pytree — REQUIRED for
    method='topkast' (its grow candidates live inside the superset).  For
    rigl/snfs under kernel dispatch the gradients/momentum arriving here are
    already superset-supported (zero elsewhere), so no explicit restriction is
    needed — the score does it.

    NOTE: callers gate this on ``algo.schedule.is_update_step(t)`` — by design
    this lives in a SEPARATE jitted function from the hot train_step so the
    per-step roofline stays honest and the dense-gradient work is visibly
    amortized (paper Appendix H).
    """
    if algo.method == "static":
        zeros = jax.tree_util.tree_map(
            lambda m: None if m is None else jnp.zeros_like(m, bool),
            masks,
            is_leaf=lambda x: x is None,
        )
        return params, masks, zeros

    fraction = algo.schedule.fraction(t)
    flat_p, treedef = jax.tree_util.tree_flatten_with_path(params)
    flat_m = jax.tree_util.tree_flatten(masks, is_leaf=lambda x: x is None)[0]
    flat_g = jax.tree_util.tree_flatten(dense_grads)[0]
    flat_mom = (
        jax.tree_util.tree_flatten(dense_momentum)[0]
        if dense_momentum is not None
        else [None] * len(flat_p)
    )
    flat_b = (
        jax.tree_util.tree_flatten(bwd_masks, is_leaf=lambda x: x is None)[0]
        if bwd_masks is not None
        else [None] * len(flat_p)
    )

    from .masks import path_name

    new_p, new_m, grown_l = [], [], []
    for i, ((path, w), m, g, mom, bw) in enumerate(
        zip(flat_p, flat_m, flat_g, flat_mom, flat_b)
    ):
        if m is None:
            new_p.append(w)
            new_m.append(None)
            grown_l.append(None)
            continue
        sub = jax.random.fold_in(key, i)
        if algo.method == "topkast":
            if bw is None:
                raise ValueError(
                    "method='topkast' needs the backward-superset masks: "
                    f"bwd_masks is missing for leaf {path_name(path)!r} — "
                    "pass state['bwd_masks'] (built by "
                    "training/steps.py::init_train_state, refreshed by "
                    "refresh_pack) into rigl_update(bwd_masks=...)"
                )
            nm, nw, grown = _topkast_update_layer(
                w, m, bw, fraction, sub, algo.block_shape
            )
            new_p.append(nw)
            new_m.append(nm)
            grown_l.append(grown)
            continue
        if algo.method == "rigl":
            score = g
        elif algo.method == "snfs":
            if mom is None:
                raise ValueError(
                    "method='snfs' grows by |dense momentum| but the state "
                    f"leaf dense_momentum is missing for {path_name(path)!r} "
                    "— pass state['dense_mom'] (tracked by "
                    "training/steps.py::make_train_step) into "
                    "rigl_update(dense_momentum=...)"
                )
            score = mom
        elif algo.method == "set":
            score = jax.random.uniform(sub, w.shape)
        else:
            raise ValueError(algo.method)
        nm, nw, grown = rigl_update_layer(
            w,
            m,
            score,
            fraction,
            grow_init=algo.grow_init,
            key=sub,
            block_shape=algo.block_shape,
            lr=lr,
            grad=g,
        )
        new_p.append(nw)
        new_m.append(nm)
        grown_l.append(grown)

    unflatten = lambda leaves: jax.tree_util.tree_unflatten(treedef, leaves)
    return unflatten(new_p), unflatten(new_m), unflatten(grown_l)


def dense_to_sparse_grad(dense_grads, masks):
    """g_sparse = g_dense * m  (paper: optimizer only sees active connections)."""
    def _mul(g, m):
        if m is None:
            return g
        return g * m.astype(g.dtype)

    return jax.tree_util.tree_map(
        _mul, dense_grads, masks, is_leaf=lambda x: x is None
    )


def dsr_update(params, masks, t, algo: SparseAlgo, key):
    """Dynamic Sparse Reparameterization (Mostafa & Wang 2019) — the paper's
    Fig 2-left "DSR" row: drop by a GLOBAL magnitude threshold (per-layer
    budgets shift), grow at random across all layers.  Total nnz is
    preserved but per-layer sparsity is free to move — which is why DSR
    cannot target a fixed FLOP budget (paper Table 1 "Selectable FLOPs: no").
    """
    fraction = algo.schedule.fraction(t)
    flat_p, treedef = jax.tree_util.tree_flatten_with_path(params)
    flat_m = jax.tree_util.tree_flatten(masks, is_leaf=lambda x: x is None)[0]

    mags, actives, sizes = [], [], []
    for (path, w), m in zip(flat_p, flat_m):
        if m is None:
            continue
        mags.append(jnp.abs(w).astype(jnp.float32).reshape(-1))
        actives.append(m.reshape(-1).astype(bool))
        sizes.append(w.size)
    all_mag = jnp.concatenate(mags)
    all_act = jnp.concatenate(actives)
    n_active = jnp.sum(all_act.astype(jnp.int32))
    k = jnp.floor(fraction * n_active).astype(jnp.int32)

    drop_scores = jnp.where(all_act, all_mag, -jnp.inf)
    kept = _rank_desc(drop_scores) < (n_active - k)
    grow_scores = jnp.where(kept, -jnp.inf, jax.random.uniform(key, all_mag.shape))
    grown = _rank_desc(grow_scores) < k
    new_all = kept | grown

    new_p, new_m, grown_l = [], [], []
    off = 0
    i = 0
    for (path, w), m in zip(flat_p, flat_m):
        if m is None:
            new_p.append(w)
            new_m.append(None)
            grown_l.append(None)
            continue
        sl = slice(off, off + sizes[i])
        nm = new_all[sl].reshape(w.shape)
        gr = grown[sl].reshape(w.shape)
        new_p.append(jnp.where(gr, jnp.zeros_like(w), w))
        new_m.append(nm.astype(m.dtype))
        grown_l.append(gr)
        off += sizes[i]
        i += 1
    unflatten = lambda ls: jax.tree_util.tree_unflatten(treedef, ls)
    return unflatten(new_p), unflatten(new_m), unflatten(grown_l)

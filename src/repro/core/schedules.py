"""Mask-update schedules (paper §3.2 + Appendix G).

``f_decay(t)`` gives the fraction of each layer's connections updated at step t.
All functions are jnp-traceable (used inside jitted update steps) and also work
on python ints/floats.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax.numpy as jnp

__all__ = ["UpdateSchedule", "cosine_decay", "constant_decay", "inverse_power_decay"]


def cosine_decay(t, alpha: float, t_end: int):
    """f_decay(t) = alpha/2 * (1 + cos(t*pi/T_end))   (paper eq., default)."""
    return 0.5 * alpha * (1.0 + jnp.cos(jnp.pi * t / t_end))


def constant_decay(t, alpha: float, t_end: int):
    return alpha * jnp.ones_like(jnp.asarray(t, jnp.float32))


def inverse_power_decay(t, alpha: float, t_end: int, k: int = 3):
    """alpha * (1 - t/T_end)^k;  k=1 is the linear schedule (Appendix G)."""
    return alpha * (1.0 - jnp.asarray(t, jnp.float32) / t_end) ** k


_DECAYS: dict[str, Callable] = {
    "cosine": cosine_decay,
    "constant": constant_decay,
    "linear": lambda t, a, te: inverse_power_decay(t, a, te, k=1),
    "inverse_power": inverse_power_decay,
}


@dataclasses.dataclass(frozen=True)
class UpdateSchedule:
    """Paper defaults: delta_t=100, alpha=0.3, t_end = 3/4 of training."""

    delta_t: int = 100
    t_end: int = 25_000
    alpha: float = 0.3
    decay: str = "cosine"

    def fraction(self, t):
        return _DECAYS[self.decay](t, self.alpha, self.t_end)

    def is_update_step(self, t):
        """Traceable predicate: t % delta_t == 0 and t < t_end (and t > 0)."""
        t = jnp.asarray(t)
        return (t % self.delta_t == 0) & (t < self.t_end) & (t > 0)

"""Graph-distance telemetry for sparse topologies.

"Topological Insights into Sparse Neural Networks" (Liu et al., PAPERS.md)
shows that sparse-training methods which reach the SAME loss can sit on very
different topologies, and that the distance between successive masks is a
useful fingerprint of a method's exploration behaviour.  This module provides
the distances the paper's analysis builds on, specialized to index-matched
mask pytrees (successive masks of one network, or final masks of two methods
trained from the same init — same shapes, same neuron ordering, so no graph
matching step is needed):

  drop/grow counts        edges removed / added by one update
  Jaccard distance        1 - |A∩B| / |A∪B| over the active edge sets
  graph-edit distance     edge insertions + deletions = Hamming count (the
                          minimal edit script between two same-shape masks)
  NHD                     normalized Hamming distance, Hamming / #edges — the
                          per-edge form of the paper's neuron-wise distance
                          (their NNSTD greedily matches neurons first; with
                          index-matched layers that matching is the identity)

Everything here is host-side numpy over CONCRETE masks and runs at topology-
update cadence (every delta_t steps) — never in the jitted hot loop.  The
train driver (launch/train.py) records one ``topology_delta`` per update into
its metrics log, and ``benchmarks/methods_comparison.py`` reports the
``TopologyTrace`` summary plus cross-method final-mask distances next to the
paper's loss/FLOPs columns.
"""
from __future__ import annotations

from typing import Any, Mapping, Optional

import jax
import numpy as np

__all__ = [
    "drop_grow_counts",
    "jaccard_distance",
    "graph_edit_distance",
    "normalized_hamming_distance",
    "topology_delta",
    "TopologyTrace",
    "cross_method_distances",
]


def _mask_pairs(a, b):
    """Aligned concrete bool leaves of two mask pytrees (None leaves skipped)."""
    fa = jax.tree_util.tree_flatten(a, is_leaf=lambda x: x is None)[0]
    fb = jax.tree_util.tree_flatten(b, is_leaf=lambda x: x is None)[0]
    if len(fa) != len(fb):
        raise ValueError(
            f"mask pytrees differ in structure: {len(fa)} vs {len(fb)} leaves"
        )
    out = []
    for ma, mb in zip(fa, fb):
        if ma is None and mb is None:
            continue
        if ma is None or mb is None:
            raise ValueError("mask pytrees disagree on which leaves are dense")
        na, nb = np.asarray(ma, bool), np.asarray(mb, bool)
        if na.shape != nb.shape:
            raise ValueError(f"mask shapes differ: {na.shape} vs {nb.shape}")
        out.append((na, nb))
    return out


def drop_grow_counts(prev, new) -> tuple[int, int]:
    """(#edges dropped, #edges grown) between two masks of one network.

    dropped = active before, inactive after; grown = the reverse.  Disjoint
    by construction (dropped lives outside the new mask, grown inside it) —
    the drop∩grow=∅ invariant the topology test tier pins.
    """
    dropped = grown = 0
    for a, b in _mask_pairs(prev, new):
        dropped += int(np.sum(a & ~b))
        grown += int(np.sum(~a & b))
    return dropped, grown


def jaccard_distance(a, b) -> float:
    """1 - |A∩B| / |A∪B| over the pooled active edge sets (0 = identical)."""
    inter = union = 0
    for ma, mb in _mask_pairs(a, b):
        inter += int(np.sum(ma & mb))
        union += int(np.sum(ma | mb))
    return 1.0 - inter / union if union else 0.0


def graph_edit_distance(a, b) -> int:
    """Minimal edit script between same-shape masks: insertions + deletions.

    For index-matched graphs every edit is an edge toggle, so this is exactly
    the Hamming count — an integer, monotone under composition of updates.
    """
    return int(sum(np.sum(ma != mb) for ma, mb in _mask_pairs(a, b)))


def normalized_hamming_distance(a, b) -> float:
    """Hamming count / total edges, in [0, 1] (0 = identical topology).

    The per-edge normalization of the Topological Insights neuron-wise
    distance; with index-matched layers the paper's greedy neuron matching is
    the identity, so this is the exact layer distance, size-weighted across
    layers.
    """
    diff = total = 0
    for ma, mb in _mask_pairs(a, b):
        diff += int(np.sum(ma != mb))
        total += ma.size
    return diff / total if total else 0.0


def topology_delta(prev, new, *, step: Optional[int] = None) -> dict[str, Any]:
    """One update's telemetry record (host-side, amortized cadence)."""
    dropped, grown = drop_grow_counts(prev, new)
    rec = {
        "dropped": dropped,
        "grown": grown,
        "jaccard_dist": jaccard_distance(prev, new),
        "graph_edit_dist": graph_edit_distance(prev, new),
        "nhd": normalized_hamming_distance(prev, new),
    }
    if step is not None:
        rec["step"] = int(step)
    return rec


class TopologyTrace:
    """Accumulates per-update ``topology_delta`` records for one training run.

    Usage (launch/train.py, benchmarks/_mlp.py): snapshot the masks before a
    topology update, ``record`` after it, read ``summary()`` at the end.  The
    summary is always finite — a run with zero updates (static/dense) reports
    zero distances rather than NaNs, so report columns stay comparable.
    """

    def __init__(self):
        self.events: list[dict[str, Any]] = []

    def snapshot(self, masks):
        """Concrete host copy of the masks (cheap: bool arrays)."""
        return jax.tree_util.tree_map(
            lambda m: None if m is None else np.asarray(m, bool),
            masks,
            is_leaf=lambda x: x is None,
        )

    def record(self, prev, new, *, step: Optional[int] = None) -> dict[str, Any]:
        rec = topology_delta(prev, new, step=step)
        self.events.append(rec)
        return rec

    def summary(self) -> dict[str, Any]:
        n = len(self.events)
        if n == 0:
            return {
                "n_updates": 0,
                "dropped_total": 0,
                "grown_total": 0,
                "jaccard_dist_mean": 0.0,
                "graph_edit_dist_total": 0,
                "nhd_mean": 0.0,
            }
        return {
            "n_updates": n,
            "dropped_total": int(sum(e["dropped"] for e in self.events)),
            "grown_total": int(sum(e["grown"] for e in self.events)),
            "jaccard_dist_mean": float(
                np.mean([e["jaccard_dist"] for e in self.events])
            ),
            "graph_edit_dist_total": int(
                sum(e["graph_edit_dist"] for e in self.events)
            ),
            "nhd_mean": float(np.mean([e["nhd"] for e in self.events])),
        }


def cross_method_distances(
    masks_by_method: Mapping[str, Any], *, reference: str = "rigl"
) -> dict[str, dict[str, float]]:
    """Where do methods CONVERGE? Final-mask distances vs a reference method.

    Only methods whose mask pytrees are shape-compatible with the reference
    are compared (small_dense trains a narrower net — skipped, not faked).
    Returns {method: {jaccard_dist_vs_ref, nhd_vs_ref}}.
    """
    out: dict[str, dict[str, float]] = {}
    ref = masks_by_method.get(reference)
    if ref is None:
        return out
    for name, masks in masks_by_method.items():
        try:
            out[name] = {
                f"jaccard_dist_vs_{reference}": jaccard_distance(ref, masks),
                f"nhd_vs_{reference}": normalized_hamming_distance(ref, masks),
            }
        except ValueError:
            continue  # incompatible shapes (e.g. small_dense) — no column
    return out

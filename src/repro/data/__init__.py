from .synthetic import batch_for, lm_batch, affine_lm_batch  # noqa: F401
from .teacher import make_teacher, teacher_batch  # noqa: F401
from .text import byte_corpus, text_batch  # noqa: F401

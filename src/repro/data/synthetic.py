"""Deterministic synthetic data streams.

Every batch is a pure function of (step, host_id, n_hosts) via stateless
threefry — any host can recompute any shard (straggler/elastic recovery:
a restarted or replacement host needs no data-state handoff, just the step).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["lm_batch", "affine_lm_batch", "vlm_batch", "frames_batch"]


def _key(seed: int, step: int, host_id: int):
    return jax.random.fold_in(jax.random.fold_in(jax.random.PRNGKey(seed), step), host_id)


def lm_batch(cfg, step: int, batch: int, seq: int, *, seed: int = 17, host_id: int = 0):
    """Random tokens + random targets (shape/throughput work only)."""
    k = _key(seed, step, host_id)
    toks = jax.random.randint(k, (batch, seq), 0, cfg.vocab_size)
    tgts = jax.random.randint(jax.random.fold_in(k, 1), (batch, seq), 0, cfg.vocab_size)
    return {"tokens": toks, "targets": tgts}


def affine_lm_batch(cfg, step: int, batch: int, seq: int, *, seed: int = 17, host_id: int = 0):
    """Learnable task: target = (a*token + b) mod V — used by smoke benchmarks."""
    k = _key(seed, step, host_id)
    toks = jax.random.randint(k, (batch, seq), 0, cfg.vocab_size)
    return {"tokens": toks, "targets": (toks * 3 + 7) % cfg.vocab_size}


def vlm_batch(cfg, step: int, batch: int, seq: int, **kw):
    b = affine_lm_batch(cfg, step, batch, seq - cfg.n_patches, **kw)
    k = _key(kw.get("seed", 17) + 1, step, kw.get("host_id", 0))
    b["patches"] = jax.random.normal(k, (batch, cfg.n_patches, cfg.frontend_dim), jnp.float32)
    return b


def frames_batch(cfg, step: int, batch: int, seq: int, *, seed: int = 17, host_id: int = 0):
    k = _key(seed, step, host_id)
    frames = jax.random.normal(k, (batch, seq, cfg.frontend_dim), jnp.float32)
    # learnable: class = sign structure of the frame energy
    tgts = (jnp.sum(frames**2, -1) * 7).astype(jnp.int32) % cfg.vocab_size
    return {"frames": frames, "targets": tgts}


def batch_for(cfg, step: int, batch: int, seq: int, *, learnable: bool = False, **kw):
    if cfg.frontend == "patch":
        return vlm_batch(cfg, step, batch, seq, **kw)
    if cfg.frontend == "frames":
        return frames_batch(cfg, step, batch, seq, **kw)
    fn = affine_lm_batch if learnable else lm_batch
    return fn(cfg, step, batch, seq, **kw)

"""Planted sparse-teacher tasks: ground-truth sparse topology is KNOWN.

A fixed random sparse teacher network generates targets; a student of the
same architecture trained at matched sparsity probes whether the grow
criterion can find useful topology — a sharper test of RigL's mechanism than
any natural dataset (benchmarks/methods_comparison.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["make_teacher", "teacher_batch"]


def make_teacher(key, d_in=32, d_hidden=128, d_out=16, sparsity=0.9):
    k1, k2, k3 = jax.random.split(key, 3)
    w1 = jax.random.normal(k1, (d_in, d_hidden)) / jnp.sqrt(d_in)
    w2 = jax.random.normal(k2, (d_hidden, d_out)) / jnp.sqrt(d_hidden)
    m1 = jax.random.uniform(k3, w1.shape) > sparsity
    m2 = jax.random.uniform(jax.random.fold_in(k3, 1), w2.shape) > sparsity
    return {"w1": w1 * m1, "w2": w2 * m2}


def teacher_batch(teacher, step: int, batch: int = 256, *, seed: int = 5, noise=0.01):
    k = jax.random.fold_in(jax.random.PRNGKey(seed), step)
    x = jax.random.normal(k, (batch, teacher["w1"].shape[0]))
    h = jax.nn.relu(x @ teacher["w1"])
    y = h @ teacher["w2"]
    y = y + noise * jax.random.normal(jax.random.fold_in(k, 1), y.shape)
    return x, y

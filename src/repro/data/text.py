"""Byte-level text corpus from local files (offline-available real text).

Used by the paper-reproduction char-LM benchmark (§4.2 analog): WikiText-103
is not available offline, so we build a byte corpus from this repository's
own source/docs — real, structured text with byte vocab 256, deterministic
windows keyed by (step, index).
"""
from __future__ import annotations

import functools
import pathlib

import numpy as np

__all__ = ["byte_corpus", "text_batch"]


@functools.lru_cache(maxsize=4)
def byte_corpus(root: str = ".", exts: tuple[str, ...] = (".py", ".md")) -> np.ndarray:
    chunks = []
    for p in sorted(pathlib.Path(root).rglob("*")):
        if p.suffix in exts and p.is_file() and "node_modules" not in str(p):
            try:
                chunks.append(p.read_bytes())
            except OSError:
                continue
    data = b"\n".join(chunks)
    assert len(data) > 10_000, "corpus too small"
    return np.frombuffer(data, dtype=np.uint8)


def text_batch(step: int, batch: int, seq: int, *, corpus=None, seed: int = 23,
               host_id: int = 0, split: str = "train"):
    corpus = byte_corpus() if corpus is None else corpus
    n = len(corpus) - seq - 1
    cut = int(n * 0.95)
    rng = np.random.default_rng(seed * 1_000_003 + step * 613 + host_id)
    if split == "train":
        starts = rng.integers(0, cut, size=batch)
    else:
        starts = rng.integers(cut, n, size=batch)
    idx = starts[:, None] + np.arange(seq + 1)[None, :]
    windows = corpus[idx]
    return {
        "tokens": windows[:, :-1].astype(np.int32),
        "targets": windows[:, 1:].astype(np.int32),
    }

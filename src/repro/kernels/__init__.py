"""Pallas TPU kernels for the RigL hot path (fwd + custom-VJP bwd).

Public API re-exported from ops.py (padding + interpret auto-select); the
per-kernel modules hold the pallas_call plumbing and backward kernels.
"""
from .ops import (  # noqa: F401
    auto_interpret,
    block_sparse_linear,
    fused_block_sparse_linear,
    fused_grouped_block_sparse_linear,
    fused_grouped_masked_linear,
    fused_masked_linear,
    grouped_block_sparse_linear,
    grouped_masked_linear,
    masked_linear,
    topk_threshold,
    topkast_grouped_masked_linear,
    topkast_masked_linear,
)

__all__ = [
    "auto_interpret",
    "block_sparse_linear",
    "fused_block_sparse_linear",
    "fused_grouped_block_sparse_linear",
    "fused_grouped_masked_linear",
    "fused_masked_linear",
    "grouped_block_sparse_linear",
    "grouped_masked_linear",
    "masked_linear",
    "topk_threshold",
    "topkast_grouped_masked_linear",
    "topkast_masked_linear",
]

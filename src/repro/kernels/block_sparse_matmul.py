"""Block-sparse matmul — the TPU-native execution of RigL sparsity.

Unstructured sparsity cannot skip work on a 128x128 systolic MXU, so the TPU
adaptation constrains RigL's drop/grow to (bk x bn)-aligned weight blocks
(core.rigl block_shape mode).  This kernel then *skips inactive blocks
entirely*: for every output column-block j we precompute the list of active
K-blocks (a CSC-style index set, padded to the max count), pass it via scalar
prefetch, and let the BlockSpec index_map DMA only active w-tiles from HBM.

HBM traffic and MXU work both scale with (1 - block_sparsity) — this is the
"sparse primitives" scenario (3) of the paper's Discussion, realized for TPU.

Grid: (M/bm, N/bn, max_active_k); zero-padding contributes nothing because
padded slots re-load an arbitrary valid block but are masked by @pl.when.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

__all__ = ["block_sparse_matmul", "pack_block_mask"]


def pack_block_mask(block_mask):
    """block_mask: (K/bk, N/bn) bool -> (indices (N/bn, max_k), counts (N/bn,)).

    Static (host-side) packing: RigL updates the topology every delta_t >= 100
    steps, so the packing is amortized over >= 100 matmuls.
    """
    bm = np.asarray(block_mask)
    nkb, nnb = bm.shape
    counts = bm.sum(axis=0).astype(np.int32)
    max_k = max(int(counts.max()), 1)
    idx = np.zeros((nnb, max_k), np.int32)
    for j in range(nnb):
        act = np.nonzero(bm[:, j])[0]
        idx[j, : len(act)] = act
    return jnp.asarray(idx), jnp.asarray(counts)


def _kernel(idx_ref, cnt_ref, x_ref, w_ref, o_ref, acc_ref, *, n_k: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    j = pl.program_id(1)

    @pl.when(k < cnt_ref[j])
    def _accum():
        acc_ref[...] += jnp.dot(
            x_ref[...], w_ref[...], preferred_element_type=jnp.float32
        )

    @pl.when(k == n_k - 1)
    def _store():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def block_sparse_matmul(
    x,
    w,
    block_idx,
    block_cnt,
    *,
    bm: int = 128,
    bn: int = 128,
    bk: int = 128,
    interpret: bool = False,
):
    """x: (M, K) @ block-sparse w: (K, N) -> (M, N).

    block_idx: (N/bn, max_k) int32 — active K-block ids per N-block (packed).
    block_cnt: (N/bn,) int32 — number of active K-blocks per N-block.
    """
    from jax.experimental.pallas import tpu as pltpu

    M, K = x.shape
    K2, N = w.shape
    assert K == K2 and N % bn == 0 and K % bk == 0 and M % bm == 0
    max_k = block_idx.shape[1]
    grid = (M // bm, N // bn, max_k)

    def x_map(m, n, k, idx_ref, cnt_ref):
        return (m, idx_ref[n, jnp.minimum(k, cnt_ref[n] - 1)])

    def w_map(m, n, k, idx_ref, cnt_ref):
        return (idx_ref[n, jnp.minimum(k, cnt_ref[n] - 1)], n)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), x_map),
            pl.BlockSpec((bk, bn), w_map),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda m, n, k, *_: (m, n)),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
    )
    return pl.pallas_call(
        functools.partial(_kernel, n_k=max_k),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        interpret=interpret,
    )(block_idx, block_cnt, x, w)

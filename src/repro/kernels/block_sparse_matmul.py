"""Block-sparse matmul — the TPU-native execution of RigL sparsity (fwd+bwd).

Unstructured sparsity cannot skip work on a 128x128 systolic MXU, so the TPU
adaptation constrains RigL's drop/grow to (bk x bn)-aligned weight blocks
(core.rigl block_shape mode).  These kernels then *skip inactive blocks
entirely* in every pass of training:

  forward  out = x @ w_bs        CSC packing: per N-block, its active K-blocks
                                 (scalar-prefetched; BlockSpec index_map DMAs
                                 only active w-tiles from HBM)
  dgrad    dx  = g @ w_bsᵀ       CSR packing: per K-block, its active N-blocks
                                 — inactive N-blocks are skipped, so the
                                 backward input-grad is as sparse as the fwd
  wgrad    dw  = xᵀ @ g          computed ONLY for active (bk x bn) blocks,
                                 emitted PACKED as (nnb*max_k, bk, bn); the
                                 VJP wrapper scatters the packed blocks into
                                 the dense (K, N) cotangent (zeros outside the
                                 topology) that the RigL-side optimizer sees.

HBM traffic and MXU work in fwd AND bwd all scale with (1 - block_sparsity) —
the "sparse primitives" scenario (3) of the paper's Discussion, realized for
TPU for the full train step, not just inference.

Packing comes in two flavours:
  * ``pack_block_mask`` / ``pack_block_mask_rows`` — host-side numpy,
    vectorized (argsort-based), tight max-count; amortized over delta_t >= 100
    steps per topology update.
  * ``pack_block_mask_traced`` / ``pack_block_mask_rows_traced`` — jnp,
    jit-safe with a STATIC padded count (worst case: the full block-grid dim).
    Padded grid slots clamp their index_map to the last active block, so they
    re-DMA nothing and @pl.when skips their compute; the only cost is empty
    grid iterations.

Grid: (M/bm, N/bn, max_active_k); zero-count columns clamp to block 0 and are
fully masked by @pl.when (the clamp keeps indices non-negative — see _clamp).

Grouped variant (``grouped_block_sparse_matmul``): a leading group dim G is
prepended to everything — x (G, M, K), w (G, K, N), stacked per-group packs
(idx (G, N/bn, width), shared width = max over groups) — and the grid grows a
leading G dimension, so ALL groups execute in ONE kernel launch.  This is how
MoE's per-expert ``ecd,edf->ecf`` expert banks and xLSTM's per-head
``bnh,nhk->bnk`` recurrent projections run block-sparse (models/moe.py,
models/xlstm.py via layers.grouped_linear): no per-expert launch loop, no
concatenated block-diagonal weights.  Same custom-VJP structure (grouped
dgrad/wgrad kernels + per-group scatter).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from .masked_matmul import sr_to_bf16

__all__ = [
    "block_sparse_matmul",
    "grouped_block_sparse_matmul",
    "topkast_block_sparse_matmul",
    "topkast_grouped_block_sparse_matmul",
    "fused_block_sparse_matmul",
    "fused_grouped_block_sparse_matmul",
    "pack_block_mask",
    "pack_block_mask_rows",
    "pack_block_mask_traced",
    "pack_block_mask_rows_traced",
    "pack_group_mask",
    "pack_group_mask_rows",
    "pack_group_mask_traced",
    "pack_group_mask_rows_traced",
    "unpack_block_mask",
]


# ---------------------------------------------------------------------------
# packing (CSC for fwd/wgrad, CSR for dgrad)
# ---------------------------------------------------------------------------

def _pack_np(bm, max_count=None):
    """Per-COLUMN active row ids of a bool matrix, argsort-vectorized.

    bm: (R, C) bool -> (idx (C, max_count) int32, counts (C,) int32).
    Slots beyond a column's count are 0 (consumers mask on counts).
    """
    bm = np.asarray(bm, bool)
    counts = bm.sum(axis=0).astype(np.int32)
    if max_count is None:
        max_count = max(int(counts.max(initial=0)), 1)
    elif int(counts.max(initial=0)) > max_count:
        # truncating would SILENTLY drop active blocks from the matmul —
        # the output would be wrong with no runtime signal, so fail loudly
        raise ValueError(
            f"pack_block_mask: max_count={max_count} < max active blocks per "
            f"column ({int(counts.max())}). Truncating the pack would drop "
            "active blocks from the matmul and corrupt the output. Repack "
            "with a wider max_count (PackState does this automatically on "
            "refresh — see docs/kernels.md#packing-and-truncation)"
        )
    # stable ascending argsort of ~bm puts active rows first, in row order
    order = np.argsort(~bm, axis=0, kind="stable")
    idx = order[:max_count].T.astype(np.int32)
    idx = np.where(np.arange(max_count)[None, :] < counts[:, None], idx, 0)
    return idx, counts


def _pack_jnp(bm, max_count):
    """Trace-safe twin of _pack_np (max_count must be static)."""
    counts = jnp.sum(bm, axis=0).astype(jnp.int32)
    order = jnp.argsort(~bm, axis=0, stable=True)
    idx = order[:max_count].T.astype(jnp.int32)
    idx = jnp.where(jnp.arange(max_count)[None, :] < counts[:, None], idx, 0)
    return idx, counts


def pack_block_mask(block_mask, max_count=None):
    """block_mask: (K/bk, N/bn) bool -> CSC (indices (N/bn, max_k), counts).

    Static (host-side) packing: RigL updates the topology every delta_t >= 100
    steps, so the packing is amortized over >= 100 matmuls.  ``max_count``
    pins the padded width (pass a fixed bound to avoid retraces when the
    per-column max drifts across topology updates).
    """
    idx, cnt = _pack_np(block_mask, max_count)
    return jnp.asarray(idx), jnp.asarray(cnt)


def pack_block_mask_rows(block_mask, max_count=None):
    """block_mask: (K/bk, N/bn) bool -> CSR (indices (K/bk, max_n), counts).

    The dgrad kernel's view: per K-block row, the active N-blocks to visit.
    """
    idx, cnt = _pack_np(np.asarray(block_mask).T, max_count)
    return jnp.asarray(idx), jnp.asarray(cnt)


def pack_block_mask_traced(block_mask):
    """jit-safe CSC pack; padded width = K/bk (static worst case)."""
    return _pack_jnp(block_mask, block_mask.shape[0])


def pack_block_mask_rows_traced(block_mask):
    """jit-safe CSR pack; padded width = N/bn (static worst case)."""
    return _pack_jnp(block_mask.T, block_mask.shape[1])


def pack_group_mask(block_masks, max_count=None):
    """Stacked per-group CSC pack of a (G, K/bk, N/bn) bool block-mask stack.

    Returns (idx (G, N/bn, width) int32, cnt (G, N/bn) int32) with ONE shared
    ``width`` (``max_count`` or the max active-K count over all groups and
    columns) so a single grouped kernel grid covers every group.  Groups with
    no active blocks at all are legal here — their counts are all zero and the
    grouped kernel writes zeros for them (a dead MoE expert behaves like an
    empty column, see docs/kernels.md#empty-columns-and-dead-layers); the
    bank-level dead check lives in core.pack.pack_entry.  Like
    ``pack_block_mask``, a ``max_count`` below some column's true count raises
    rather than silently truncating the matmul.
    """
    bms = np.asarray(block_masks, bool)
    assert bms.ndim == 3, bms.shape
    if max_count is None:
        max_count = max(int(bms.sum(axis=1).max(initial=0)), 1)
    packed = [_pack_np(b, max_count) for b in bms]
    idx = np.stack([i for i, _ in packed])
    cnt = np.stack([c for _, c in packed])
    return jnp.asarray(idx), jnp.asarray(cnt)


def pack_group_mask_rows(block_masks, max_count=None):
    """Stacked per-group CSR pack — the grouped dgrad kernel's view."""
    return pack_group_mask(
        np.asarray(block_masks).transpose(0, 2, 1), max_count
    )


def pack_group_mask_traced(block_masks):
    """jit-safe stacked CSC pack; padded width = K/bk (static worst case)."""
    return jax.vmap(lambda b: _pack_jnp(b, b.shape[0]))(block_masks)


def pack_group_mask_rows_traced(block_masks):
    """jit-safe stacked CSR pack; padded width = N/bn (static worst case)."""
    return jax.vmap(lambda b: _pack_jnp(b.T, b.shape[1]))(block_masks)


def unpack_block_mask(block_idx, block_cnt, n_rows: int):
    """CSC ``(idx, cnt)`` -> (n_rows, n_cols) bool block mask (traced-safe).

    Inverse of pack_block_mask (padded slots contribute nothing).  Shared by
    the VJP's CSR fallback derivation below and PackState's staleness check
    (core/pack.py) — one reconstruction definition, kept in sync by
    construction.
    """
    n_cols, width = block_idx.shape
    valid = jnp.arange(width)[None, :] < block_cnt[:, None]
    cols = jnp.broadcast_to(jnp.arange(n_cols)[:, None], block_idx.shape)
    return jnp.zeros((n_rows, n_cols), bool).at[block_idx, cols].max(valid)


def _clamp(idx_ref, cnt_ref, row, s):
    """Active-block id for slot s of packed row `row`, clamped non-negative.

    Padded slots (s >= cnt) clamp to the LAST active id, so consecutive grid
    steps see an unchanged index and Pallas skips the re-DMA; cnt == 0 rows
    clamp to 0 (guarded off by @pl.when in the kernel body).
    """
    return idx_ref[row, jnp.maximum(jnp.minimum(s, cnt_ref[row] - 1), 0)]


def _gclamp(idx_ref, cnt_ref, g, row, s):
    """_clamp for stacked (G, rows, width) packs: group g's row/slot lookup."""
    return idx_ref[
        g, row, jnp.maximum(jnp.minimum(s, cnt_ref[g, row] - 1), 0)
    ]


# ---------------------------------------------------------------------------
# kernels
# ---------------------------------------------------------------------------

def _fwd_kernel(idx_ref, cnt_ref, x_ref, w_ref, o_ref, acc_ref, *, n_k: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    j = pl.program_id(1)

    @pl.when(k < cnt_ref[j])
    def _accum():
        acc_ref[...] += jnp.dot(
            x_ref[...], w_ref[...], preferred_element_type=jnp.float32
        )

    @pl.when(k == n_k - 1)
    def _store():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _dx_kernel(ridx_ref, rcnt_ref, g_ref, w_ref, o_ref, acc_ref, *, n_s: int):
    """dx (bm, bk) += g (bm, bn) @ w (bk, bn)ᵀ over ACTIVE N-blocks only."""
    s = pl.program_id(2)

    @pl.when(s == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    k = pl.program_id(1)

    @pl.when(s < rcnt_ref[k])
    def _accum():
        acc_ref[...] += jax.lax.dot_general(
            g_ref[...], w_ref[...], (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )

    @pl.when(s == n_s - 1)
    def _store():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _dw_kernel(idx_ref, cnt_ref, x_ref, g_ref, o_ref, acc_ref, *, n_m: int):
    """Packed wgrad: slot (j, s) holds xᵀ @ g for active block (idx[j,s], j).

    Inactive/padded slots store zeros (their x-tile is a clamped re-load of an
    arbitrary valid block, so the accumulate is guarded off too).
    """
    i = pl.program_id(2)

    @pl.when(i == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    j, s = pl.program_id(0), pl.program_id(1)

    @pl.when(s < cnt_ref[j])
    def _accum():
        acc_ref[...] += jax.lax.dot_general(
            x_ref[...], g_ref[...], (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )

    @pl.when(i == n_m - 1)
    def _store():
        o_ref[...] = jnp.where(
            s < cnt_ref[j], acc_ref[...], jnp.zeros_like(acc_ref)
        ).astype(o_ref.dtype)[None]


# ---------------------------------------------------------------------------
# pallas_call wrappers
# ---------------------------------------------------------------------------

def _fwd_call(x, w, block_idx, block_cnt, bm, bn, bk, interpret):
    from jax.experimental.pallas import tpu as pltpu

    M, K = x.shape
    N = w.shape[1]
    max_k = block_idx.shape[1]
    grid = (M // bm, N // bn, max_k)

    def x_map(m, n, k, idx_ref, cnt_ref):
        return (m, _clamp(idx_ref, cnt_ref, n, k))

    def w_map(m, n, k, idx_ref, cnt_ref):
        return (_clamp(idx_ref, cnt_ref, n, k), n)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), x_map),
            pl.BlockSpec((bk, bn), w_map),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda m, n, k, *_: (m, n)),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
    )
    return pl.pallas_call(
        functools.partial(_fwd_kernel, n_k=max_k),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        interpret=interpret,
    )(block_idx, block_cnt, x, w)


def _dx_call(g, w, row_idx, row_cnt, bm, bn, bk, interpret, out_dtype):
    from jax.experimental.pallas import tpu as pltpu

    M, N = g.shape
    K = w.shape[0]
    max_n = row_idx.shape[1]
    grid = (M // bm, K // bk, max_n)

    def g_map(m, k, s, ridx_ref, rcnt_ref):
        return (m, _clamp(ridx_ref, rcnt_ref, k, s))

    def w_map(m, k, s, ridx_ref, rcnt_ref):
        return (k, _clamp(ridx_ref, rcnt_ref, k, s))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bn), g_map),
            pl.BlockSpec((bk, bn), w_map),
        ],
        out_specs=pl.BlockSpec((bm, bk), lambda m, k, s, *_: (m, k)),
        scratch_shapes=[pltpu.VMEM((bm, bk), jnp.float32)],
    )
    return pl.pallas_call(
        functools.partial(_dx_kernel, n_s=max_n),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((M, K), out_dtype),
        interpret=interpret,
    )(row_idx, row_cnt, g, w)


def _dw_call(x, g, block_idx, block_cnt, bm, bn, bk, interpret):
    from jax.experimental.pallas import tpu as pltpu

    M, K = x.shape
    N = g.shape[1]
    nnb = N // bn
    max_k = block_idx.shape[1]
    n_m = M // bm
    grid = (nnb, max_k, n_m)

    def x_map(j, s, i, idx_ref, cnt_ref):
        return (i, _clamp(idx_ref, cnt_ref, j, s))

    def g_map(j, s, i, idx_ref, cnt_ref):
        return (i, j)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), x_map),
            pl.BlockSpec((bm, bn), g_map),
        ],
        out_specs=pl.BlockSpec(
            (1, bk, bn), lambda j, s, i, *_: (j * max_k + s, 0, 0)
        ),
        scratch_shapes=[pltpu.VMEM((bk, bn), jnp.float32)],
    )
    return pl.pallas_call(
        functools.partial(_dw_kernel, n_m=n_m),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((nnb * max_k, bk, bn), jnp.float32),
        interpret=interpret,
    )(block_idx, block_cnt, x, g)


def _scatter_packed_dw(packed, block_idx, block_cnt, nkb, bk, bn, dtype):
    """Packed (nnb*max_k, bk, bn) wgrad blocks -> dense (K, N) cotangent.

    This is the "scatter on the RigL-update side": the kernel only ever
    computes/stores active blocks; the dense layout (zeros outside the
    topology) is materialized here, where the optimizer consumes it.
    """
    nnb, max_k = block_idx.shape
    packed = packed.reshape(nnb, max_k, bk, bn)
    valid = (jnp.arange(max_k)[None, :] < block_cnt[:, None])[..., None, None]
    packed = jnp.where(valid, packed, 0.0)
    cols = jnp.broadcast_to(jnp.arange(nnb)[:, None], block_idx.shape)
    # .add (not .set): padded slots alias block (0, j) but are already zeroed
    grid_ = jnp.zeros((nkb, nnb, bk, bn), packed.dtype)
    grid_ = grid_.at[block_idx, cols].add(packed)
    return grid_.transpose(0, 2, 1, 3).reshape(nkb * bk, nnb * bn).astype(dtype)


# ---------------------------------------------------------------------------
# custom VJP
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(6, 7, 8, 9))
def _block_sparse_matmul(
    x, w, block_idx, block_cnt, row_idx, row_cnt, bm, bn, bk, interpret
):
    return _fwd_call(x, w, block_idx, block_cnt, bm, bn, bk, interpret)


def _bs_fwd(x, w, block_idx, block_cnt, row_idx, row_cnt, bm, bn, bk, interpret):
    out = _fwd_call(x, w, block_idx, block_cnt, bm, bn, bk, interpret)
    return out, (x, w, block_idx, block_cnt, row_idx, row_cnt)


def _bs_bwd(bm, bn, bk, interpret, res, g):
    x, w, block_idx, block_cnt, row_idx, row_cnt = res
    K, N = w.shape
    nkb = K // bk

    dx = _dx_call(g, w, row_idx, row_cnt, bm, bn, bk, interpret, x.dtype)
    packed = _dw_call(x, g, block_idx, block_cnt, bm, bn, bk, interpret)
    dw = _scatter_packed_dw(packed, block_idx, block_cnt, nkb, bk, bn, w.dtype)

    z = lambda a: np.zeros(a.shape, jax.dtypes.float0)
    return dx, dw, z(block_idx), z(block_cnt), z(row_idx), z(row_cnt)


_block_sparse_matmul.defvjp(_bs_fwd, _bs_bwd)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def block_sparse_matmul(
    x,
    w,
    block_idx,
    block_cnt,
    row_idx=None,
    row_cnt=None,
    *,
    bm: int = 128,
    bn: int = 128,
    bk: int = 128,
    interpret: bool = False,
):
    """x: (M, K) @ block-sparse w: (K, N) -> (M, N).

    block_idx: (N/bn, max_k) int32 — active K-block ids per N-block (CSC).
    block_cnt: (N/bn,) int32 — number of active K-blocks per N-block.
    row_idx/row_cnt: optional CSR view ((K/bk, max_n) / (K/bk,)) consumed by
    the dgrad kernel.  Pass the host-packed (tight) CSR from a PackState
    entry so the backward dx grid is also sized to the true active count;
    when omitted, it is derived here from the CSC pack at the static
    worst-case width N/bn (padded dgrad grid — correct, just longer).  The
    derivation is dead-code-eliminated whenever the call is not
    differentiated (e.g. serving).

    Differentiable: jax.grad routes through the CSR dgrad kernel (skips
    inactive N-blocks) and the packed-active-block wgrad kernel.
    """
    M, K = x.shape
    K2, N = w.shape
    assert K == K2 and N % bn == 0 and K % bk == 0 and M % bm == 0
    if row_idx is None:
        bmask = unpack_block_mask(block_idx, block_cnt, K // bk)
        row_idx, row_cnt = _pack_jnp(bmask.T, N // bn)
    return _block_sparse_matmul(
        x, w, block_idx, block_cnt, row_idx, row_cnt, bm, bn, bk, interpret
    )


# ---------------------------------------------------------------------------
# grouped kernels: one grid launch for a whole (G, K, N) weight bank
# ---------------------------------------------------------------------------

def _g_fwd_kernel(idx_ref, cnt_ref, x_ref, w_ref, o_ref, acc_ref, *, n_k: int):
    k = pl.program_id(3)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    g, j = pl.program_id(0), pl.program_id(2)

    @pl.when(k < cnt_ref[g, j])
    def _accum():
        acc_ref[...] += jnp.dot(
            x_ref[0], w_ref[0], preferred_element_type=jnp.float32
        )

    @pl.when(k == n_k - 1)
    def _store():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)[None]


def _g_dx_kernel(ridx_ref, rcnt_ref, g_ref, w_ref, o_ref, acc_ref, *, n_s: int):
    """Grouped dgrad: dx[g] (bm, bk) += g[g] (bm, bn) @ w[g] (bk, bn)ᵀ."""
    s = pl.program_id(3)

    @pl.when(s == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    g, k = pl.program_id(0), pl.program_id(2)

    @pl.when(s < rcnt_ref[g, k])
    def _accum():
        acc_ref[...] += jax.lax.dot_general(
            g_ref[0], w_ref[0], (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )

    @pl.when(s == n_s - 1)
    def _store():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)[None]


def _g_dw_kernel(idx_ref, cnt_ref, x_ref, g_ref, o_ref, acc_ref, *, n_m: int):
    """Grouped packed wgrad: slot (g, j, s) holds x[g]ᵀ @ g[g] for active
    block (idx[g, j, s], j) of group g; padded slots store zeros."""
    i = pl.program_id(3)

    @pl.when(i == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    g, j, s = pl.program_id(0), pl.program_id(1), pl.program_id(2)

    @pl.when(s < cnt_ref[g, j])
    def _accum():
        acc_ref[...] += jax.lax.dot_general(
            x_ref[0], g_ref[0], (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )

    @pl.when(i == n_m - 1)
    def _store():
        o_ref[...] = jnp.where(
            s < cnt_ref[g, j], acc_ref[...], jnp.zeros_like(acc_ref)
        ).astype(o_ref.dtype)[None, None]


def _g_fwd_call(x, w, block_idx, block_cnt, bm, bn, bk, interpret):
    from jax.experimental.pallas import tpu as pltpu

    G, M, K = x.shape
    N = w.shape[2]
    max_k = block_idx.shape[2]
    grid = (G, M // bm, N // bn, max_k)

    def x_map(g, m, n, k, idx_ref, cnt_ref):
        return (g, m, _gclamp(idx_ref, cnt_ref, g, n, k))

    def w_map(g, m, n, k, idx_ref, cnt_ref):
        return (g, _gclamp(idx_ref, cnt_ref, g, n, k), n)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bm, bk), x_map),
            pl.BlockSpec((1, bk, bn), w_map),
        ],
        out_specs=pl.BlockSpec((1, bm, bn), lambda g, m, n, k, *_: (g, m, n)),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
    )
    return pl.pallas_call(
        functools.partial(_g_fwd_kernel, n_k=max_k),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((G, M, N), x.dtype),
        interpret=interpret,
    )(block_idx, block_cnt, x, w)


def _g_dx_call(g_, w, row_idx, row_cnt, bm, bn, bk, interpret, out_dtype):
    from jax.experimental.pallas import tpu as pltpu

    G, M, N = g_.shape
    K = w.shape[1]
    max_n = row_idx.shape[2]
    grid = (G, M // bm, K // bk, max_n)

    def g_map(g, m, k, s, ridx_ref, rcnt_ref):
        return (g, m, _gclamp(ridx_ref, rcnt_ref, g, k, s))

    def w_map(g, m, k, s, ridx_ref, rcnt_ref):
        return (g, k, _gclamp(ridx_ref, rcnt_ref, g, k, s))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bm, bn), g_map),
            pl.BlockSpec((1, bk, bn), w_map),
        ],
        out_specs=pl.BlockSpec((1, bm, bk), lambda g, m, k, s, *_: (g, m, k)),
        scratch_shapes=[pltpu.VMEM((bm, bk), jnp.float32)],
    )
    return pl.pallas_call(
        functools.partial(_g_dx_kernel, n_s=max_n),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((G, M, K), out_dtype),
        interpret=interpret,
    )(row_idx, row_cnt, g_, w)


def _g_dw_call(x, g_, block_idx, block_cnt, bm, bn, bk, interpret):
    from jax.experimental.pallas import tpu as pltpu

    G, M, K = x.shape
    N = g_.shape[2]
    nnb = N // bn
    max_k = block_idx.shape[2]
    n_m = M // bm
    grid = (G, nnb, max_k, n_m)

    def x_map(g, j, s, i, idx_ref, cnt_ref):
        return (g, i, _gclamp(idx_ref, cnt_ref, g, j, s))

    def g_map(g, j, s, i, idx_ref, cnt_ref):
        return (g, i, j)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bm, bk), x_map),
            pl.BlockSpec((1, bm, bn), g_map),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, bk, bn), lambda g, j, s, i, *_: (g, j * max_k + s, 0, 0)
        ),
        scratch_shapes=[pltpu.VMEM((bk, bn), jnp.float32)],
    )
    return pl.pallas_call(
        functools.partial(_g_dw_kernel, n_m=n_m),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((G, nnb * max_k, bk, bn), jnp.float32),
        interpret=interpret,
    )(block_idx, block_cnt, x, g_)


@functools.partial(jax.custom_vjp, nondiff_argnums=(6, 7, 8, 9))
def _grouped_block_sparse_matmul(
    x, w, block_idx, block_cnt, row_idx, row_cnt, bm, bn, bk, interpret
):
    return _g_fwd_call(x, w, block_idx, block_cnt, bm, bn, bk, interpret)


def _gbs_fwd(x, w, block_idx, block_cnt, row_idx, row_cnt, bm, bn, bk, interpret):
    out = _g_fwd_call(x, w, block_idx, block_cnt, bm, bn, bk, interpret)
    return out, (x, w, block_idx, block_cnt, row_idx, row_cnt)


def _gbs_bwd(bm, bn, bk, interpret, res, g):
    x, w, block_idx, block_cnt, row_idx, row_cnt = res
    K, N = w.shape[1], w.shape[2]
    nkb = K // bk

    dx = _g_dx_call(g, w, row_idx, row_cnt, bm, bn, bk, interpret, x.dtype)
    packed = _g_dw_call(x, g, block_idx, block_cnt, bm, bn, bk, interpret)
    dw = jax.vmap(
        lambda p_, i_, c_: _scatter_packed_dw(p_, i_, c_, nkb, bk, bn, w.dtype)
    )(packed, block_idx, block_cnt)

    z = lambda a: np.zeros(a.shape, jax.dtypes.float0)
    return dx, dw, z(block_idx), z(block_cnt), z(row_idx), z(row_cnt)


_grouped_block_sparse_matmul.defvjp(_gbs_fwd, _gbs_bwd)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def grouped_block_sparse_matmul(
    x,
    w,
    block_idx,
    block_cnt,
    row_idx=None,
    row_cnt=None,
    *,
    bm: int = 128,
    bn: int = 128,
    bk: int = 128,
    interpret: bool = False,
):
    """Grouped x: (G, M, K) @ block-sparse w: (G, K, N) -> (G, M, N).

    The grouped twin of ``block_sparse_matmul``: one kernel launch executes
    every group's block-sparse matmul (grid gains a leading G dim), driven by
    STACKED packs — ``block_idx (G, N/bn, width)`` / ``block_cnt (G, N/bn)``
    from ``pack_group_mask`` (shared width = max over groups).  This is the
    execution path for MoE expert banks (``ecd,edf->ecf``) and xLSTM per-head
    recurrent projections (``bnh,nhk->bnk`` after moving heads to the group
    dim) — see layers.grouped_linear.

    row_idx/row_cnt: optional stacked CSR ((G, K/bk, row_width) / (G, K/bk))
    for a tight grouped dgrad grid; derived at the worst-case width N/bn when
    omitted (dead-code-eliminated if never differentiated).

    Differentiable: grouped custom-VJP dgrad/wgrad kernels; the packed wgrad
    blocks are scattered per group into the dense (G, K, N) cotangent.
    """
    G, M, K = x.shape
    G2, K2, N = w.shape
    assert G == G2 and K == K2, (x.shape, w.shape)
    assert N % bn == 0 and K % bk == 0 and M % bm == 0, (M, K, N, bm, bn, bk)
    if row_idx is None:
        bmask = jax.vmap(
            lambda i_, c_: unpack_block_mask(i_, c_, K // bk)
        )(block_idx, block_cnt)
        row_idx, row_cnt = pack_group_mask_rows_traced(bmask)
    return _grouped_block_sparse_matmul(
        x, w, block_idx, block_cnt, row_idx, row_cnt, bm, bn, bk, interpret
    )


# ---------------------------------------------------------------------------
# Top-KAST split-topology VJP: forward/dgrad on the tight k-grid,
# wgrad on the top-(k+delta) backward-superset grid (docs/training.md#topkast)
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(8, 9, 10, 11))
def _topkast_block_sparse_matmul(
    x, w, block_idx, block_cnt, row_idx, row_cnt, bwd_idx, bwd_cnt,
    bm, bn, bk, interpret,
):
    return _fwd_call(x, w, block_idx, block_cnt, bm, bn, bk, interpret)


def _tk_fwd(
    x, w, block_idx, block_cnt, row_idx, row_cnt, bwd_idx, bwd_cnt,
    bm, bn, bk, interpret,
):
    out = _fwd_call(x, w, block_idx, block_cnt, bm, bn, bk, interpret)
    return out, (x, w, block_idx, block_cnt, row_idx, row_cnt, bwd_idx, bwd_cnt)


def _tk_bwd(bm, bn, bk, interpret, res, g):
    x, w, block_idx, block_cnt, row_idx, row_cnt, bwd_idx, bwd_cnt = res
    K, N = w.shape
    nkb = K // bk

    # dx on the FORWARD topology (y only saw w ⊙ A), wgrad on the SUPERSET:
    # dw is exactly the dense gradient restricted to B's support, the
    # side-channel the rigl/snfs grow scores consume.
    dx = _dx_call(g, w, row_idx, row_cnt, bm, bn, bk, interpret, x.dtype)
    packed = _dw_call(x, g, bwd_idx, bwd_cnt, bm, bn, bk, interpret)
    dw = _scatter_packed_dw(packed, bwd_idx, bwd_cnt, nkb, bk, bn, w.dtype)

    z = lambda a: np.zeros(a.shape, jax.dtypes.float0)
    return (
        dx, dw, z(block_idx), z(block_cnt), z(row_idx), z(row_cnt),
        z(bwd_idx), z(bwd_cnt),
    )


_topkast_block_sparse_matmul.defvjp(_tk_fwd, _tk_bwd)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def topkast_block_sparse_matmul(
    x,
    w,
    block_idx,
    block_cnt,
    bwd_idx,
    bwd_cnt,
    row_idx=None,
    row_cnt=None,
    *,
    bm: int = 128,
    bn: int = 128,
    bk: int = 128,
    interpret: bool = False,
):
    """Top-KAST matmul: forward on A's CSC, weight gradient on B ⊇ A's CSC.

    Same kernels as ``block_sparse_matmul`` — the split is purely in which
    pack drives the wgrad grid.  bwd_idx/bwd_cnt are the superset CSC view of
    a PackState entry (``bidx``/``bcnt``, core/pack.py); forward and dgrad
    keep the tight idx/ridx views, so the per-step cost of the exploration
    set is ONE wider wgrad grid, nothing else.  dw is dense-laid-out but
    supported only on B — zero dense-gradient materialization.
    """
    M, K = x.shape
    K2, N = w.shape
    assert K == K2 and N % bn == 0 and K % bk == 0 and M % bm == 0
    if row_idx is None:
        bmask = unpack_block_mask(block_idx, block_cnt, K // bk)
        row_idx, row_cnt = _pack_jnp(bmask.T, N // bn)
    return _topkast_block_sparse_matmul(
        x, w, block_idx, block_cnt, row_idx, row_cnt, bwd_idx, bwd_cnt,
        bm, bn, bk, interpret,
    )


@functools.partial(jax.custom_vjp, nondiff_argnums=(8, 9, 10, 11))
def _topkast_grouped_block_sparse_matmul(
    x, w, block_idx, block_cnt, row_idx, row_cnt, bwd_idx, bwd_cnt,
    bm, bn, bk, interpret,
):
    return _g_fwd_call(x, w, block_idx, block_cnt, bm, bn, bk, interpret)


def _gtk_fwd(
    x, w, block_idx, block_cnt, row_idx, row_cnt, bwd_idx, bwd_cnt,
    bm, bn, bk, interpret,
):
    out = _g_fwd_call(x, w, block_idx, block_cnt, bm, bn, bk, interpret)
    return out, (x, w, block_idx, block_cnt, row_idx, row_cnt, bwd_idx, bwd_cnt)


def _gtk_bwd(bm, bn, bk, interpret, res, g):
    x, w, block_idx, block_cnt, row_idx, row_cnt, bwd_idx, bwd_cnt = res
    K, N = w.shape[1], w.shape[2]
    nkb = K // bk

    dx = _g_dx_call(g, w, row_idx, row_cnt, bm, bn, bk, interpret, x.dtype)
    packed = _g_dw_call(x, g, bwd_idx, bwd_cnt, bm, bn, bk, interpret)
    dw = jax.vmap(
        lambda p_, i_, c_: _scatter_packed_dw(p_, i_, c_, nkb, bk, bn, w.dtype)
    )(packed, bwd_idx, bwd_cnt)

    z = lambda a: np.zeros(a.shape, jax.dtypes.float0)
    return (
        dx, dw, z(block_idx), z(block_cnt), z(row_idx), z(row_cnt),
        z(bwd_idx), z(bwd_cnt),
    )


_topkast_grouped_block_sparse_matmul.defvjp(_gtk_fwd, _gtk_bwd)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def topkast_grouped_block_sparse_matmul(
    x,
    w,
    block_idx,
    block_cnt,
    bwd_idx,
    bwd_cnt,
    row_idx=None,
    row_cnt=None,
    *,
    bm: int = 128,
    bn: int = 128,
    bk: int = 128,
    interpret: bool = False,
):
    """Grouped Top-KAST matmul: per-group forward on A, wgrad on B ⊇ A.

    The grouped twin of ``topkast_block_sparse_matmul`` for MoE expert banks
    and xLSTM per-head recurrences — stacked packs, one launch, wgrad driven
    by the stacked superset CSC (``bidx (G, N/bn, bwidth)``).
    """
    G, M, K = x.shape
    G2, K2, N = w.shape
    assert G == G2 and K == K2, (x.shape, w.shape)
    assert N % bn == 0 and K % bk == 0 and M % bm == 0, (M, K, N, bm, bn, bk)
    if row_idx is None:
        bmask = jax.vmap(
            lambda i_, c_: unpack_block_mask(i_, c_, K // bk)
        )(block_idx, block_cnt)
        row_idx, row_cnt = pack_group_mask_rows_traced(bmask)
    return _topkast_grouped_block_sparse_matmul(
        x, w, block_idx, block_cnt, row_idx, row_cnt, bwd_idx, bwd_cnt,
        bm, bn, bk, interpret,
    )


# ---------------------------------------------------------------------------
# fused wgrad -> optimizer epilogue (docs/kernels.md#fused-epilogue)
#
# Block-sparse twin of masked_matmul.fused_masked_matmul: the packed wgrad
# kernel DMAs the matching w/mom tiles alongside x/g and stores
# m_new = mu*mom + xᵀg + wd*w per active block — the packed blocks leaving
# the kernel ARE the new SGD momentum (optionally stochastically rounded onto
# the bf16 grid), scattered into the dense (K, N) cotangent layout the
# optimizer consumes.  The raw dw never round-trips HBM.  One custom-VJP
# covers plain AND Top-KAST: the wgrad grid is driven by whichever pack the
# wrapper selects (tight CSC, or the B ⊇ A superset ``bidx``/``bcnt``).
# ---------------------------------------------------------------------------

def _dw_fused_kernel(
    idx_ref, cnt_ref, seed_ref, x_ref, g_ref, w_ref, mom_ref, o_ref, acc_ref,
    *, n_m: int, ncols: int, mu: float, wd: float, sr: bool,
):
    i = pl.program_id(2)
    j, s = pl.program_id(0), pl.program_id(1)
    # block row id for the sr element-coordinate hash; read at top level
    # (program_id/scalar reads inside a pl.when branch fail interpret lowering)
    kb = _clamp(idx_ref, cnt_ref, j, s)

    @pl.when(i == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(s < cnt_ref[j])
    def _accum():
        acc_ref[...] += jax.lax.dot_general(
            x_ref[...], g_ref[...], (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )

    @pl.when(i == n_m - 1)
    def _store():
        m_new = (
            mu * mom_ref[...].astype(jnp.float32)
            + acc_ref[...]
            + wd * w_ref[...].astype(jnp.float32)
        )
        # padded slots alias a clamped block's w/mom tiles — zero them BEFORE
        # sr (sr_to_bf16(0) == 0 exactly, so zeros stay zeros)
        m_new = jnp.where(s < cnt_ref[j], m_new, jnp.zeros_like(m_new))
        if sr:
            bkk, bnn = m_new.shape
            rows = jax.lax.broadcasted_iota(jnp.uint32, m_new.shape, 0)
            cols = jax.lax.broadcasted_iota(jnp.uint32, m_new.shape, 1)
            ku, ju = jnp.uint32(kb), jnp.uint32(j)
            gid = (ku * bkk + rows) * jnp.uint32(ncols) + (ju * bnn + cols)
            m_new = sr_to_bf16(m_new, seed_ref[0], gid)
        o_ref[...] = m_new.astype(o_ref.dtype)[None]


def _dw_fused_call(
    x, g, wg_idx, wg_cnt, w, mom, seed, mu, wd, sr, bm, bn, bk, interpret
):
    from jax.experimental.pallas import tpu as pltpu

    M, K = x.shape
    N = g.shape[1]
    nnb = N // bn
    max_k = wg_idx.shape[1]
    n_m = M // bm
    grid = (nnb, max_k, n_m)

    def x_map(j, s, i, idx_ref, cnt_ref, seed_ref):
        return (i, _clamp(idx_ref, cnt_ref, j, s))

    def g_map(j, s, i, idx_ref, cnt_ref, seed_ref):
        return (i, j)

    def wm_map(j, s, i, idx_ref, cnt_ref, seed_ref):
        return (_clamp(idx_ref, cnt_ref, j, s), j)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), x_map),
            pl.BlockSpec((bm, bn), g_map),
            pl.BlockSpec((bk, bn), wm_map),
            pl.BlockSpec((bk, bn), wm_map),
        ],
        out_specs=pl.BlockSpec(
            (1, bk, bn), lambda j, s, i, *_: (j * max_k + s, 0, 0)
        ),
        scratch_shapes=[pltpu.VMEM((bk, bn), jnp.float32)],
    )
    return pl.pallas_call(
        functools.partial(
            _dw_fused_kernel, n_m=n_m, ncols=N, mu=mu, wd=wd, sr=sr
        ),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((nnb * max_k, bk, bn), jnp.float32),
        interpret=interpret,
    )(wg_idx, wg_cnt, seed, x, g, w, mom)


@functools.partial(
    jax.custom_vjp, nondiff_argnums=(10, 11, 12, 13, 14, 15, 16)
)
def _fused_block_sparse_matmul(
    x, w, block_idx, block_cnt, row_idx, row_cnt, wg_idx, wg_cnt, mom, seed,
    mu, wd, sr, bm, bn, bk, interpret,
):
    return _fwd_call(x, w, block_idx, block_cnt, bm, bn, bk, interpret)


def _fbs_fwd(
    x, w, block_idx, block_cnt, row_idx, row_cnt, wg_idx, wg_cnt, mom, seed,
    mu, wd, sr, bm, bn, bk, interpret,
):
    out = _fwd_call(x, w, block_idx, block_cnt, bm, bn, bk, interpret)
    return out, (
        x, w, block_idx, block_cnt, row_idx, row_cnt, wg_idx, wg_cnt, mom, seed
    )


def _fbs_bwd(mu, wd, sr, bm, bn, bk, interpret, res, g):
    (
        x, w, block_idx, block_cnt, row_idx, row_cnt, wg_idx, wg_cnt, mom, seed
    ) = res
    K = w.shape[0]
    nkb = K // bk

    dx = _dx_call(g, w, row_idx, row_cnt, bm, bn, bk, interpret, x.dtype)
    packed = _dw_fused_call(
        x, g, wg_idx, wg_cnt, w, mom, seed, mu, wd, sr, bm, bn, bk, interpret
    )
    m_new = _scatter_packed_dw(packed, wg_idx, wg_cnt, nkb, bk, bn, w.dtype)

    z = lambda a: np.zeros(a.shape, jax.dtypes.float0)
    return (
        dx, m_new, z(block_idx), z(block_cnt), z(row_idx), z(row_cnt),
        z(wg_idx), z(wg_cnt), jnp.zeros_like(mom), z(seed),
    )


_fused_block_sparse_matmul.defvjp(_fbs_fwd, _fbs_bwd)


@functools.partial(
    jax.jit, static_argnames=("mu", "wd", "sr", "bm", "bn", "bk", "interpret")
)
def fused_block_sparse_matmul(
    x,
    w,
    block_idx,
    block_cnt,
    mom,
    seed,
    bwd_idx=None,
    bwd_cnt=None,
    row_idx=None,
    row_cnt=None,
    *,
    mu: float,
    wd: float,
    sr: bool,
    bm: int = 128,
    bn: int = 128,
    bk: int = 128,
    interpret: bool = False,
):
    """``block_sparse_matmul`` whose weight COTANGENT is the new SGD momentum.

    Forward/dgrad identical to ``block_sparse_matmul``.  The packed wgrad
    kernel stores m_new = mu*mom + xᵀg + wd*w per active block of the wgrad
    pack — ``bwd_idx``/``bwd_cnt`` (Top-KAST superset B) when given, else the
    forward CSC — scattered to the dense (K, N) layout (zeros off-support;
    momentum there is pinned to zero, the documented fused semantic).  seed:
    (1,) int32 per-leaf counter; sr=True stochastically rounds m_new onto the
    bf16 grid in-kernel (masked_matmul.sr_to_bf16).  Consumed via
    ops.fused_block_sparse_linear + optim.apply_opt_fused.
    """
    M, K = x.shape
    K2, N = w.shape
    assert K == K2 and N % bn == 0 and K % bk == 0 and M % bm == 0
    assert mom.shape == w.shape, (mom.shape, w.shape)
    if row_idx is None:
        bmask = unpack_block_mask(block_idx, block_cnt, K // bk)
        row_idx, row_cnt = _pack_jnp(bmask.T, N // bn)
    if bwd_idx is None:
        bwd_idx, bwd_cnt = block_idx, block_cnt
    return _fused_block_sparse_matmul(
        x, w, block_idx, block_cnt, row_idx, row_cnt, bwd_idx, bwd_cnt,
        mom, seed, mu, wd, sr, bm, bn, bk, interpret,
    )


def _g_dw_fused_kernel(
    idx_ref, cnt_ref, seed_ref, x_ref, g_ref, w_ref, mom_ref, o_ref, acc_ref,
    *, n_m: int, nrows: int, ncols: int, mu: float, wd: float, sr: bool,
):
    i = pl.program_id(3)
    g, j, s = pl.program_id(0), pl.program_id(1), pl.program_id(2)
    kb = _gclamp(idx_ref, cnt_ref, g, j, s)

    @pl.when(i == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(s < cnt_ref[g, j])
    def _accum():
        acc_ref[...] += jax.lax.dot_general(
            x_ref[0], g_ref[0], (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )

    @pl.when(i == n_m - 1)
    def _store():
        m_new = (
            mu * mom_ref[0].astype(jnp.float32)
            + acc_ref[...]
            + wd * w_ref[0].astype(jnp.float32)
        )
        m_new = jnp.where(s < cnt_ref[g, j], m_new, jnp.zeros_like(m_new))
        if sr:
            bkk, bnn = m_new.shape
            rows = jax.lax.broadcasted_iota(jnp.uint32, m_new.shape, 0)
            cols = jax.lax.broadcasted_iota(jnp.uint32, m_new.shape, 1)
            gu, ku, ju = jnp.uint32(g), jnp.uint32(kb), jnp.uint32(j)
            gid = (gu * nrows + ku * bkk + rows) * jnp.uint32(ncols) + (
                ju * bnn + cols
            )
            m_new = sr_to_bf16(m_new, seed_ref[0], gid)
        o_ref[...] = m_new.astype(o_ref.dtype)[None, None]


def _g_dw_fused_call(
    x, g_, wg_idx, wg_cnt, w, mom, seed, mu, wd, sr, bm, bn, bk, interpret
):
    from jax.experimental.pallas import tpu as pltpu

    G, M, K = x.shape
    N = g_.shape[2]
    nnb = N // bn
    max_k = wg_idx.shape[2]
    n_m = M // bm
    grid = (G, nnb, max_k, n_m)

    def x_map(g, j, s, i, idx_ref, cnt_ref, seed_ref):
        return (g, i, _gclamp(idx_ref, cnt_ref, g, j, s))

    def g_map(g, j, s, i, idx_ref, cnt_ref, seed_ref):
        return (g, i, j)

    def wm_map(g, j, s, i, idx_ref, cnt_ref, seed_ref):
        return (g, _gclamp(idx_ref, cnt_ref, g, j, s), j)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bm, bk), x_map),
            pl.BlockSpec((1, bm, bn), g_map),
            pl.BlockSpec((1, bk, bn), wm_map),
            pl.BlockSpec((1, bk, bn), wm_map),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, bk, bn), lambda g, j, s, i, *_: (g, j * max_k + s, 0, 0)
        ),
        scratch_shapes=[pltpu.VMEM((bk, bn), jnp.float32)],
    )
    return pl.pallas_call(
        functools.partial(
            _g_dw_fused_kernel, n_m=n_m, nrows=K, ncols=N, mu=mu, wd=wd, sr=sr
        ),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((G, nnb * max_k, bk, bn), jnp.float32),
        interpret=interpret,
    )(wg_idx, wg_cnt, seed, x, g_, w, mom)


@functools.partial(
    jax.custom_vjp, nondiff_argnums=(10, 11, 12, 13, 14, 15, 16)
)
def _fused_grouped_block_sparse_matmul(
    x, w, block_idx, block_cnt, row_idx, row_cnt, wg_idx, wg_cnt, mom, seed,
    mu, wd, sr, bm, bn, bk, interpret,
):
    return _g_fwd_call(x, w, block_idx, block_cnt, bm, bn, bk, interpret)


def _gfbs_fwd(
    x, w, block_idx, block_cnt, row_idx, row_cnt, wg_idx, wg_cnt, mom, seed,
    mu, wd, sr, bm, bn, bk, interpret,
):
    out = _g_fwd_call(x, w, block_idx, block_cnt, bm, bn, bk, interpret)
    return out, (
        x, w, block_idx, block_cnt, row_idx, row_cnt, wg_idx, wg_cnt, mom, seed
    )


def _gfbs_bwd(mu, wd, sr, bm, bn, bk, interpret, res, g):
    (
        x, w, block_idx, block_cnt, row_idx, row_cnt, wg_idx, wg_cnt, mom, seed
    ) = res
    K = w.shape[1]
    nkb = K // bk

    dx = _g_dx_call(g, w, row_idx, row_cnt, bm, bn, bk, interpret, x.dtype)
    packed = _g_dw_fused_call(
        x, g, wg_idx, wg_cnt, w, mom, seed, mu, wd, sr, bm, bn, bk, interpret
    )
    m_new = jax.vmap(
        lambda p_, i_, c_: _scatter_packed_dw(p_, i_, c_, nkb, bk, bn, w.dtype)
    )(packed, wg_idx, wg_cnt)

    z = lambda a: np.zeros(a.shape, jax.dtypes.float0)
    return (
        dx, m_new, z(block_idx), z(block_cnt), z(row_idx), z(row_cnt),
        z(wg_idx), z(wg_cnt), jnp.zeros_like(mom), z(seed),
    )


_fused_grouped_block_sparse_matmul.defvjp(_gfbs_fwd, _gfbs_bwd)


@functools.partial(
    jax.jit, static_argnames=("mu", "wd", "sr", "bm", "bn", "bk", "interpret")
)
def fused_grouped_block_sparse_matmul(
    x,
    w,
    block_idx,
    block_cnt,
    mom,
    seed,
    bwd_idx=None,
    bwd_cnt=None,
    row_idx=None,
    row_cnt=None,
    *,
    mu: float,
    wd: float,
    sr: bool,
    bm: int = 128,
    bn: int = 128,
    bk: int = 128,
    interpret: bool = False,
):
    """Grouped ``fused_block_sparse_matmul`` (MoE banks / xLSTM heads)."""
    G, M, K = x.shape
    G2, K2, N = w.shape
    assert G == G2 and K == K2, (x.shape, w.shape)
    assert N % bn == 0 and K % bk == 0 and M % bm == 0, (M, K, N, bm, bn, bk)
    assert mom.shape == w.shape, (mom.shape, w.shape)
    if row_idx is None:
        bmask = jax.vmap(
            lambda i_, c_: unpack_block_mask(i_, c_, K // bk)
        )(block_idx, block_cnt)
        row_idx, row_cnt = pack_group_mask_rows_traced(bmask)
    if bwd_idx is None:
        bwd_idx, bwd_cnt = block_idx, block_cnt
    return _fused_grouped_block_sparse_matmul(
        x, w, block_idx, block_cnt, row_idx, row_cnt, bwd_idx, bwd_cnt,
        mom, seed, mu, wd, sr, bm, bn, bk, interpret,
    )

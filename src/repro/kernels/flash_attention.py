"""Flash attention (fwd + custom-VJP bwd) on tight, schedule-driven grids.

Online-softmax tiling (Dao et al., adapted to TPU): grid (batch*heads, Sq/bq,
width) with the KV loop innermost; running (max, sum, acc) live in VMEM
scratch across KV steps.  ``width`` is the grid-clipping piece: instead of
launching the full Sk/bk KV range and @pl.when-guarding dead blocks (which
still DMAs K/V for them — the wasted-DMA note of the original kernel), the
third grid dimension walks a host-built AttnSchedule (core/attn_sched.py):
per q-block row, only its LIVE KV blocks, scalar-prefetched so the BlockSpec
index_map DMAs exactly the K/V tiles the mask family admits.  Causal,
sliding-window and causal+window masks at long context thus skip both the
grid iterations AND the DMA of dead score blocks — the same tight-grid
machinery the weight kernels get from core/pack.py.

Backward is a custom-VJP Pallas kernel pair reusing the same schedule:

  dq     grid (BH, n_q, width)       — the forward schedule (per-q live KV)
  dk/dv  grid (B*KV, n_k, G, q_width) — the TRANSPOSED schedule (per-KV live
                                    q), one kernel producing both cotangents;
                                    the G axis sums each KV tile's cotangent
                                    over its GQA query-group members

GQA is folded into the BlockSpec index maps (``kv_groups``): K/V stay at
their true KV-head count and q row b reads KV row b // G, so no repeated
K/V copy is ever materialized.  ``logit_softcap`` (gemma/grok) is applied
inside the online softmax, fwd and bwd.

with the standard flash backward recomputation: p = exp(s - lse) from the
saved per-row logsumexp, delta = rowsum(do * o) precomputed in jnp.  Training
therefore no longer falls back to the pure-jnp chunked attention path —
scores never visit HBM in the forward OR the backward.

The padded variant (``tight=False``) runs the SAME kernels on a schedule
whose width is padded to the dense worst case Sk/bk — bit-identical outputs,
longer grid — mirroring the tight-vs-padded weight-pack duality.  ``ref.py``'s
``flash_attention_ref`` is the jnp oracle for all mask families.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..core.attn_sched import paged_prefix_schedule, sched_for
from .block_sparse_matmul import _clamp

__all__ = ["flash_attention", "flash_attention_paged", "effective_blocks"]

NEG_INF = -1e30
EPS = 1e-30


def _round_up(n: int, mult: int) -> int:
    return ((n + mult - 1) // mult) * mult


def effective_blocks(
    sq: int, sk: int, bq: int = 128, bk: int = 128
) -> tuple[int, int]:
    """The (bq, bk) ``flash_attention`` will actually run for these lengths
    (tiles clamp to the 16-padded length for short sequences).  Schedule
    builders must use THIS so a pre-built sched matches the kernel's grid."""
    return min(bq, _round_up(sq, 16)), min(bk, _round_up(sk, 16))


def _capped(u, softcap):
    """Gemma/grok-style logit soft-capping s = c * tanh(u / c), applied to the
    RAW scaled scores BEFORE the mask clamp (a NEG_INF-clamped score must stay
    NEG_INF, not saturate to ±c).  softcap == 0.0 disables (python-static, so
    uncapped kernels compile without the tanh).  Returns (s, t) with
    t = tanh(u / c) — the backward reuses t for ds/du = 1 - t²."""
    if not softcap:
        return u, None
    t = jnp.tanh(u / softcap)
    return softcap * t, t


def _score_mask(qb, kb, *, bq, bk, causal, window, q_offset, sk):
    """(bq, bk) bool mask for score block (qb, kb), or None when every
    position is live (interior full-attention block on aligned shapes)."""
    if not causal and not window and sk % bk == 0:
        return None
    qpos = q_offset + qb * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kpos = kb * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), jnp.bool_)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    if sk % bk:  # zero-padded tail keys must never win the softmax
        mask &= kpos < sk
    return mask


# ---------------------------------------------------------------------------
# kernels
# ---------------------------------------------------------------------------

def _fwd_kernel(
    kv_idx_ref, kv_cnt_ref, q_ref, k_ref, v_ref, o_ref, lse_ref,
    m_ref, l_ref, acc_ref, *, width, bq, bk, causal, window, q_offset, sk,
    scale, softcap,
):
    s_id = pl.program_id(2)

    @pl.when(s_id == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    qb = pl.program_id(1)
    kb = _clamp(kv_idx_ref, kv_cnt_ref, qb, s_id)

    @pl.when(s_id < kv_cnt_ref[qb])
    def _step():
        q = q_ref[0]  # (bq, d)
        k = k_ref[0]  # (bk, d)
        v = v_ref[0]
        s, _ = _capped(
            jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale,
            softcap,
        )
        mask = _score_mask(
            qb, kb, bq=bq, bk=bk, causal=causal, window=window,
            q_offset=q_offset, sk=sk,
        )
        if mask is not None:
            s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        if mask is not None:
            # a fully-masked ROW of a live block has s == m_new == NEG_INF,
            # where exp(s - m_new) = 1 would corrupt l; zero masked slots so
            # dead rows keep l == 0 (and thus output zeros, see _finish)
            p = jnp.where(mask, p, 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jnp.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new

    @pl.when(s_id == width - 1)
    def _finish():
        l_raw = l_ref[...]
        l = jnp.maximum(l_raw, EPS)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)
        # per-row logsumexp residual for the backward recomputation; rows
        # with NO live key get +1e30 so the backward's exp(s - lse) is
        # exactly zero for them instead of overflowing
        lse = jnp.where(l_raw > 0.0, m_ref[...] + jnp.log(l), -NEG_INF)
        lse_ref[0, :] = lse[:, 0]


def _dq_kernel(
    kv_idx_ref, kv_cnt_ref, q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
    dq_ref, acc_ref, *, width, bq, bk, causal, window, q_offset, sk, scale,
    softcap,
):
    """dq (bq, d) += (p * (do@vT - delta)) @ k * scale over live KV blocks.
    With softcap, ds additionally carries the cap's chain factor 1 - t²."""
    s_id = pl.program_id(2)

    @pl.when(s_id == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    qb = pl.program_id(1)
    kb = _clamp(kv_idx_ref, kv_cnt_ref, qb, s_id)

    @pl.when(s_id < kv_cnt_ref[qb])
    def _step():
        q = q_ref[0]
        k = k_ref[0]
        v = v_ref[0]
        do = do_ref[0]
        s, t = _capped(
            jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale,
            softcap,
        )
        mask = _score_mask(
            qb, kb, bq=bq, bk=bk, causal=causal, window=window,
            q_offset=q_offset, sk=sk,
        )
        if mask is not None:
            s = jnp.where(mask, s, NEG_INF)
        p = jnp.exp(s - lse_ref[0, :][:, None])  # masked slots: exp(-inf) = 0
        dp = jnp.dot(do, v.T, preferred_element_type=jnp.float32)
        ds = p * (dp - delta_ref[0, :][:, None]) * scale
        if t is not None:
            ds = ds * (1.0 - t * t)
        acc_ref[...] += jnp.dot(
            ds.astype(k.dtype), k, preferred_element_type=jnp.float32
        )

    @pl.when(s_id == width - 1)
    def _finish():
        dq_ref[0] = acc_ref[...].astype(dq_ref.dtype)


def _dkv_kernel(
    q_idx_ref, q_cnt_ref, q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
    dk_ref, dv_ref, dk_acc, dv_acc, *, q_width, groups, bq, bk, causal,
    window, q_offset, sk, scale, softcap,
):
    """One kernel for both KV cotangents, walking the TRANSPOSED schedule:
    dv (bk, d) += pT @ do;  dk (bk, d) += dsT @ q * scale.

    Grid (B*KV, n_k, G, q_width): under GQA folding a KV tile's cotangent is
    the SUM over its G query-group members, so the group dim is one more
    accumulated grid axis — the (bk, d) K/V tile and the dk/dv accumulators
    stay resident across the (gm, s) inner loops while the q-side tiles walk
    row b*G + gm of the folded (BH, ...) layout.  G == 1 recovers the plain
    MHA backward exactly."""
    gm = pl.program_id(2)
    s_id = pl.program_id(3)

    @pl.when((gm == 0) & (s_id == 0))
    def _init():
        dk_acc[...] = jnp.zeros_like(dk_acc)
        dv_acc[...] = jnp.zeros_like(dv_acc)

    kb = pl.program_id(1)
    qb = _clamp(q_idx_ref, q_cnt_ref, kb, s_id)

    @pl.when(s_id < q_cnt_ref[kb])
    def _step():
        q = q_ref[0]
        k = k_ref[0]
        v = v_ref[0]
        do = do_ref[0]
        s, t = _capped(
            jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale,
            softcap,
        )
        mask = _score_mask(
            qb, kb, bq=bq, bk=bk, causal=causal, window=window,
            q_offset=q_offset, sk=sk,
        )
        if mask is not None:
            s = jnp.where(mask, s, NEG_INF)
        p = jnp.exp(s - lse_ref[0, :][:, None])
        dv_acc[...] += jnp.dot(
            p.T.astype(do.dtype), do, preferred_element_type=jnp.float32
        )
        dp = jnp.dot(do, v.T, preferred_element_type=jnp.float32)
        ds = p * (dp - delta_ref[0, :][:, None]) * scale
        if t is not None:
            ds = ds * (1.0 - t * t)
        dk_acc[...] += jnp.dot(
            ds.T.astype(q.dtype), q, preferred_element_type=jnp.float32
        )

    @pl.when((gm == groups - 1) & (s_id == q_width - 1))
    def _finish():
        dk_ref[0] = dk_acc[...].astype(dk_ref.dtype)
        dv_ref[0] = dv_acc[...].astype(dv_ref.dtype)


# ---------------------------------------------------------------------------
# pallas_call wrappers
# ---------------------------------------------------------------------------

def _fwd_call(q, k, v, kv_idx, kv_cnt, bq, bk, causal, window, q_offset, sk,
              scale, softcap, kv_groups, interpret):
    BH, Sqp, d = q.shape
    width = kv_idx.shape[1]
    n_q = Sqp // bq
    grid = (BH, n_q, width)

    def kv_map(b, qb, s, idx_ref, cnt_ref):
        # GQA fold: query row b of the (B*H, ...) layout reads KV row
        # b // G of the UNREPEATED (B*KV, ...) layout — the G query heads of
        # a group share the same physical tiles, so the G-fold repeated K/V
        # copy (and its HBM write + re-read) never exists
        return (b // kv_groups, _clamp(idx_ref, cnt_ref, qb, s), 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, qb, s, *_: (b, qb, 0)),
            pl.BlockSpec((1, bk, d), kv_map),
            pl.BlockSpec((1, bk, d), kv_map),
        ],
        out_specs=[
            pl.BlockSpec((1, bq, d), lambda b, qb, s, *_: (b, qb, 0)),
            pl.BlockSpec((1, bq), lambda b, qb, s, *_: (b, qb)),
        ],
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
    )
    return pl.pallas_call(
        functools.partial(
            _fwd_kernel, width=width, bq=bq, bk=bk, causal=causal,
            window=window, q_offset=q_offset, sk=sk, scale=scale,
            softcap=softcap,
        ),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((BH, Sqp, d), q.dtype),
            jax.ShapeDtypeStruct((BH, Sqp), jnp.float32),
        ],
        interpret=interpret,
    )(kv_idx, kv_cnt, q, k, v)


def _paged_kernel(
    kv_idx_ref, table_ref, ctx_ref, q_ref, k_ref, v_ref, o_ref, lse_ref,
    m_ref, l_ref, acc_ref, *, n_pages, bq, bs, scale, softcap,
):
    """Prefix phase of suffix-only prefill over a PAGED KV cache.

    Grid (B, H, n_q, n_pages): step s of q row qb visits logical prefix
    page kv_idx[qb, s]; the BlockSpec index map routes it through the
    scalar-prefetched block table to a physical pool page (GQA folded:
    kv head = h // G in the map, no K/V repeat).  Liveness is dynamic —
    only ceil(ctx[b] / bs) leading pages hold valid prefix keys — so the
    walk clips in-flight via @pl.when, and within the boundary page
    kpos >= ctx masks to NEG_INF.  Every prefix key precedes every suffix
    query, so there is no causal masking here; rows with ctx == 0 emit
    zeros with lse = NEG_INF (NOT the fwd kernel's +1e30 sentinel: the
    logsumexp MERGE with the self phase needs exp(lse - m) to underflow
    to exactly 0 for the empty phase).
    """
    b = pl.program_id(0)
    s_id = pl.program_id(3)

    @pl.when(s_id == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    qb = pl.program_id(2)
    j = kv_idx_ref[qb, s_id]  # logical page index (kpos = j * bs + lane)
    ctx = ctx_ref[b]
    n_live = (ctx + bs - 1) // bs

    @pl.when(s_id < n_live)
    def _step():
        q = q_ref[0, 0]  # (bq, d)
        k = k_ref[0, 0]  # (bs, d)
        v = v_ref[0, 0]
        s, _ = _capped(
            jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale,
            softcap,
        )
        kpos = j * bs + jax.lax.broadcasted_iota(jnp.int32, (bq, bs), 1)
        mask = kpos < ctx
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        p = jnp.where(mask, p, 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jnp.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new

    @pl.when(s_id == n_pages - 1)
    def _finish():
        l_raw = l_ref[...]
        l = jnp.maximum(l_raw, EPS)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)
        lse = jnp.where(l_raw > 0.0, m_ref[...] + jnp.log(l), NEG_INF)
        lse_ref[0, 0, :] = lse[:, 0]


def _paged_call(q, pk, pv, kv_idx, table, ctx, bq, scale, softcap, interpret):
    """q: (B, H, Sqp, d); pk/pv: pool TRANSPOSED to (N, KV, bs, d) so each
    grid step DMAs one (bs, d) page tile; table: (B, T); ctx: (B,)."""
    B, H, Sqp, d = q.shape
    N, KV, bs, _ = pk.shape
    G = H // KV
    n_pages = kv_idx.shape[1]
    grid = (B, H, Sqp // bq, n_pages)

    def q_map(b, h, qb, s, *_):
        return (b, h, qb, 0)

    def kv_map(b, h, qb, s, idx_ref, tab_ref, ctx_ref):
        # padded steps (s >= live count) re-see the last live page: index
        # unchanged => Pallas skips the re-DMA (same idiom as _clamp); the
        # min() guards the n_blocks SENTINEL on unowned table entries
        n_live = (ctx_ref[b] + bs - 1) // bs
        j = idx_ref[qb, jnp.maximum(jnp.minimum(s, n_live - 1), 0)]
        return (jnp.minimum(tab_ref[b, j], N - 1), h // G, 0, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), q_map),
            pl.BlockSpec((1, 1, bs, d), kv_map),
            pl.BlockSpec((1, 1, bs, d), kv_map),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, bq, d), q_map),
            pl.BlockSpec((1, 1, bq), lambda b, h, qb, s, *_: (b, h, qb)),
        ],
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
    )
    return pl.pallas_call(
        functools.partial(
            _paged_kernel, n_pages=n_pages, bq=bq, bs=bs, scale=scale,
            softcap=softcap,
        ),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((B, H, Sqp, d), q.dtype),
            jax.ShapeDtypeStruct((B, H, Sqp), jnp.float32),
        ],
        interpret=interpret,
    )(kv_idx, table, ctx, q, pk, pv)


@functools.partial(
    jax.jit, static_argnames=("bq", "scale", "softcap", "interpret")
)
def _paged_jit(q, pk, pv, kv_idx, table, ctx, *, bq, scale, softcap,
               interpret):
    return _paged_call(
        q, pk, pv, kv_idx, table, ctx, bq, scale, softcap, interpret
    )


def flash_attention_paged(
    q, pool_k, pool_v, table, ctx, *, bq: int = 128, softcap: float = 0.0,
    interpret=None,
):
    """Suffix queries attending a paged KV prefix through a block table.

    q: (B, H, Sq, hd) roped suffix queries; pool_k/pool_v: (N, bs, KV, hd)
    paged caches (models/attention.py::init_kv_pool); table: (B, T) int32
    physical page ids (the sentinel id N marks unowned entries — never
    live, clamped in the index map); ctx: (B,) int32 valid prefix lengths.
    Returns (o: (B, H, Sq, hd), lse: (B, H, Sq) f32) — the PREFIX phase of
    shared-prefix suffix prefill; models/attention.py merges it with the
    causal self phase by logsumexp.  Rows with ctx == 0 return zeros with
    lse = -1e30 (weight exactly 0 in the merge).  Forward-only: serving
    prefill never differentiates.
    """
    from .ops import auto_interpret

    interpret = auto_interpret() if interpret is None else interpret
    B, H, Sq, d = q.shape
    bs = pool_k.shape[1]
    bq = min(bq, _round_up(Sq, 16))
    Sqp = _round_up(Sq, bq)
    if Sqp != Sq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, Sqp - Sq), (0, 0)))
    sched = paged_prefix_schedule(Sqp, int(table.shape[1]), bq, int(bs))
    o, lse = _paged_jit(
        q,
        pool_k.transpose(0, 2, 1, 3),
        pool_v.transpose(0, 2, 1, 3),
        jnp.asarray(sched["kv_idx"]),
        jnp.asarray(table, jnp.int32),
        jnp.asarray(ctx, jnp.int32),
        bq=bq,
        scale=float(1.0 / np.sqrt(d)),
        softcap=float(softcap),
        interpret=interpret,
    )
    return o[:, :, :Sq], lse[:, :, :Sq]


def _dq_call(q, k, v, do, lse, delta, kv_idx, kv_cnt, bq, bk, causal, window,
             q_offset, sk, scale, softcap, kv_groups, interpret):
    BH, Sqp, d = q.shape
    width = kv_idx.shape[1]
    grid = (BH, Sqp // bq, width)

    def q_map(b, qb, s, *_):
        return (b, qb, 0)

    def row_map(b, qb, s, *_):
        return (b, qb)

    def kv_map(b, qb, s, idx_ref, cnt_ref):
        # same GQA fold as the forward: K/V stay at their true KV-head count
        return (b // kv_groups, _clamp(idx_ref, cnt_ref, qb, s), 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), q_map),
            pl.BlockSpec((1, bk, d), kv_map),
            pl.BlockSpec((1, bk, d), kv_map),
            pl.BlockSpec((1, bq, d), q_map),
            pl.BlockSpec((1, bq), row_map),
            pl.BlockSpec((1, bq), row_map),
        ],
        out_specs=pl.BlockSpec((1, bq, d), q_map),
        scratch_shapes=[pltpu.VMEM((bq, d), jnp.float32)],
    )
    return pl.pallas_call(
        functools.partial(
            _dq_kernel, width=width, bq=bq, bk=bk, causal=causal,
            window=window, q_offset=q_offset, sk=sk, scale=scale,
            softcap=softcap,
        ),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((BH, Sqp, d), q.dtype),
        interpret=interpret,
    )(kv_idx, kv_cnt, q, k, v, do, lse, delta)


def _dkv_call(q, k, v, do, lse, delta, q_idx, q_cnt, bq, bk, causal, window,
              q_offset, sk, scale, softcap, kv_groups, interpret):
    # k/v (and dk/dv) live at the true KV-head count B*KV = BH // G; the
    # grid grows a GROUP axis between the KV-block and schedule dims so each
    # KV tile's cotangent accumulates over its G query-group members while
    # the (bk, d) tile and both accumulators stay VMEM-resident
    BKV, Skp, d = k.shape
    q_width = q_idx.shape[1]
    grid = (BKV, Skp // bk, kv_groups, q_width)

    def q_map(b, kb, gm, s, idx_ref, cnt_ref):
        return (b * kv_groups + gm, _clamp(idx_ref, cnt_ref, kb, s), 0)

    def row_map(b, kb, gm, s, idx_ref, cnt_ref):
        return (b * kv_groups + gm, _clamp(idx_ref, cnt_ref, kb, s))

    def kv_map(b, kb, gm, s, *_):
        return (b, kb, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), q_map),
            pl.BlockSpec((1, bk, d), kv_map),
            pl.BlockSpec((1, bk, d), kv_map),
            pl.BlockSpec((1, bq, d), q_map),
            pl.BlockSpec((1, bq), row_map),
            pl.BlockSpec((1, bq), row_map),
        ],
        out_specs=[
            pl.BlockSpec((1, bk, d), kv_map),
            pl.BlockSpec((1, bk, d), kv_map),
        ],
        scratch_shapes=[
            pltpu.VMEM((bk, d), jnp.float32),
            pltpu.VMEM((bk, d), jnp.float32),
        ],
    )
    return pl.pallas_call(
        functools.partial(
            _dkv_kernel, q_width=q_width, groups=kv_groups, bq=bq, bk=bk,
            causal=causal, window=window, q_offset=q_offset, sk=sk,
            scale=scale, softcap=softcap,
        ),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((BKV, Skp, d), k.dtype),
            jax.ShapeDtypeStruct((BKV, Skp, d), v.dtype),
        ],
        interpret=interpret,
    )(q_idx, q_cnt, q, k, v, do, lse, delta)


# ---------------------------------------------------------------------------
# custom VJP
# ---------------------------------------------------------------------------

@functools.partial(
    jax.custom_vjp, nondiff_argnums=(7, 8, 9, 10, 11, 12, 13, 14, 15, 16)
)
def _flash(q, k, v, kv_idx, kv_cnt, q_idx, q_cnt, bq, bk, causal, window,
           q_offset, sk, scale, softcap, kv_groups, interpret):
    out, _ = _fwd_call(
        q, k, v, kv_idx, kv_cnt, bq, bk, causal, window, q_offset, sk, scale,
        softcap, kv_groups, interpret,
    )
    return out


def _flash_fwd(q, k, v, kv_idx, kv_cnt, q_idx, q_cnt, bq, bk, causal, window,
               q_offset, sk, scale, softcap, kv_groups, interpret):
    out, lse = _fwd_call(
        q, k, v, kv_idx, kv_cnt, bq, bk, causal, window, q_offset, sk, scale,
        softcap, kv_groups, interpret,
    )
    return out, (q, k, v, out, lse, kv_idx, kv_cnt, q_idx, q_cnt)


def _flash_bwd(bq, bk, causal, window, q_offset, sk, scale, softcap,
               kv_groups, interpret, res, do):
    q, k, v, out, lse, kv_idx, kv_cnt, q_idx, q_cnt = res
    # delta_i = sum_j p_ij * dp_ij = rowsum(do * o): O(S*d) in jnp, f32
    delta = jnp.sum(
        do.astype(jnp.float32) * out.astype(jnp.float32), axis=-1
    )
    dq = _dq_call(
        q, k, v, do, lse, delta, kv_idx, kv_cnt, bq, bk, causal, window,
        q_offset, sk, scale, softcap, kv_groups, interpret,
    )
    dk, dv = _dkv_call(
        q, k, v, do, lse, delta, q_idx, q_cnt, bq, bk, causal, window,
        q_offset, sk, scale, softcap, kv_groups, interpret,
    )
    z = lambda a: np.zeros(a.shape, jax.dtypes.float0)
    return dq, dk, dv, z(kv_idx), z(kv_cnt), z(q_idx), z(q_cnt)


_flash.defvjp(_flash_fwd, _flash_bwd)


@functools.partial(
    jax.jit,
    static_argnames=(
        "bq", "bk", "causal", "window", "q_offset", "sk", "scale", "softcap",
        "kv_groups", "interpret",
    ),
)
def _flash_jit(q, k, v, kv_idx, kv_cnt, q_idx, q_cnt, *, bq, bk, causal,
               window, q_offset, sk, scale, softcap, kv_groups, interpret):
    return _flash(
        q, k, v, kv_idx, kv_cnt, q_idx, q_cnt, bq, bk, causal, window,
        q_offset, sk, scale, softcap, kv_groups, interpret,
    )


def _pad_width(idx: jnp.ndarray, to: int) -> jnp.ndarray:
    """Pad a schedule's width up to the dense worst case (padded-grid mode).
    Slots beyond cnt are clamped by the kernels, so the fill value is inert."""
    pad = to - idx.shape[1]
    if pad <= 0:
        return idx
    return jnp.pad(idx, ((0, 0), (0, pad)))


@functools.partial(
    jax.jit,
    static_argnames=(
        "bq", "bk", "causal", "window", "q_offset", "sk", "scale", "softcap",
        "kv_groups", "interpret",
    ),
)
def _fwd_jit(q, k, v, kv_idx, kv_cnt, *, bq, bk, causal, window, q_offset,
             sk, scale, softcap, kv_groups, interpret):
    return _fwd_call(
        q, k, v, kv_idx, kv_cnt, bq, bk, causal, window, q_offset, sk, scale,
        softcap, kv_groups, interpret,
    )


def flash_attention(
    q, k, v, *, causal: bool = True, window: int = 0, sched=None,
    tight: bool = True, bq: int = 128, bk: int = 128, softcap: float = 0.0,
    kv_groups: int = 1, interpret=None, return_lse: bool = False,
):
    """q: (BH, Sq, d); k, v: (BH/kv_groups, Sk, d) -> (BH, Sq, d).
    Differentiable.

    Softmax attention with scores only ever materialized tile-wise in VMEM,
    fwd and bwd (custom-VJP Pallas kernel pair).  The mask family is
    (causal, window) with models/attention.py::_make_mask semantics: query
    row r sits at absolute position ``Sk - Sq + r`` (right-aligned — 0 offset
    for the ubiquitous Sq == Sk), keys at their column index; ``window`` masks
    keys at or below ``qpos - window``.  A row with no live key (possible
    only in degenerate window-family shapes) outputs zeros, NOT the
    uniform-softmax artifact the NEG_INF-clamped jnp reference produces.

    sched: an AttnSchedule (core/attn_sched.py) built for EXACTLY this
    (Sq, Sk, bq, bk, causal, window); None builds one lazily (memoized,
    trace-time — schedules are static-shape-derived, so this is free).
    tight=True launches the schedule's tight grid (width = max live KV blocks
    per q row); tight=False pads the width to the dense worst case Sk/bk —
    bit-identical output, every slot beyond a row's count an empty iteration
    (the old @pl.when-only behaviour, kept as the padded baseline).

    softcap: gemma/grok-style logit soft-capping c*tanh(s/c) applied to the
    scaled scores inside the online softmax (0.0 disables).  Exact in the
    custom VJP too — ds carries the cap's 1 - tanh² chain factor — so capped
    configs train on the flash path with no dense fallback.

    kv_groups: GQA group fold.  G > 1 takes k/v at their TRUE KV-head count
    (BH/G, Sk, d) — q row b reads KV row b // G via the BlockSpec index maps,
    so the G-fold repeated K/V copy `_flash_attend` used to materialize (and
    its HBM write + re-read) never exists.  dk/dv grow a group grid axis and
    accumulate each KV tile's cotangent over its G group members in VMEM —
    the repeat-path's G-fold dk/dv output plus jnp segment-sum disappears
    too.  G == 1 is the plain MHA layout, bit-identical to before.

    Non-aligned Sq/Sk are zero-padded up to the (clamped) block sizes and
    trimmed after; padded keys are masked in-kernel, padded query rows cost
    dead rows in the boundary block only.  interpret=None auto-selects
    (compiled on TPU, interpret elsewhere).

    return_lse=True additionally returns the per-row logsumexp (BH, Sq) f32
    (+1e30 on rows with no live key) for phase-merging with another
    attention partial (flash_attention_paged) — FORWARD-ONLY: this path
    bypasses the custom VJP, so don't differentiate through it.
    """
    from .ops import auto_interpret

    interpret = auto_interpret() if interpret is None else interpret
    BH, Sq, d = q.shape
    Sk = k.shape[1]
    kv_groups = int(kv_groups)
    if BH % kv_groups or k.shape[0] != BH // kv_groups:
        raise ValueError(
            f"flash_attention: q has {BH} batch*head rows but k/v have "
            f"{k.shape[0]} with kv_groups={kv_groups} — expected "
            "k.shape[0] == q.shape[0] // kv_groups (UNREPEATED KV heads)"
        )
    bq, bk = effective_blocks(Sq, Sk, bq, bk)
    Sqp, Skp = _round_up(Sq, bq), _round_up(Sk, bk)
    q_offset = Sk - Sq
    if sched is None:
        sched = sched_for(Sq, Sk, bq, bk, causal, window, q_offset)
    else:
        got = (sched["sq"], sched["sk"], sched["bq"], sched["bk"],
               sched["causal"], sched["window"], sched["q_offset"])
        want = (Sq, Sk, bq, bk, bool(causal), int(window), q_offset)
        if got != want:
            raise ValueError(
                f"flash_attention: sched built for {got} but called with "
                f"{want} — schedules are per (shape, blocks, mask family); "
                "see docs/kernels.md#attention-schedules"
            )
    kv_idx, kv_cnt = sched["kv_idx"], sched["kv_cnt"]
    q_idx, q_cnt = sched["q_idx"], sched["q_cnt"]
    if not tight:  # padded baseline: dense-worst-case grid, same schedule
        kv_idx = _pad_width(kv_idx, Skp // bk)
        q_idx = _pad_width(q_idx, Sqp // bq)
    if Sqp != Sq:
        q = jnp.pad(q, ((0, 0), (0, Sqp - Sq), (0, 0)))
    if Skp != Sk:
        k = jnp.pad(k, ((0, 0), (0, Skp - Sk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, Skp - Sk), (0, 0)))
    if return_lse:
        out, lse = _fwd_jit(
            q, k, v, kv_idx, kv_cnt, bq=bq, bk=bk, causal=bool(causal),
            window=int(window), q_offset=q_offset, sk=Sk,
            scale=float(1.0 / np.sqrt(d)), softcap=float(softcap),
            kv_groups=kv_groups, interpret=interpret,
        )
        return out[:, :Sq], lse[:, :Sq]
    out = _flash_jit(
        q, k, v, kv_idx, kv_cnt, q_idx, q_cnt, bq=bq, bk=bk,
        causal=bool(causal), window=int(window), q_offset=q_offset, sk=Sk,
        scale=float(1.0 / np.sqrt(d)), softcap=float(softcap),
        kv_groups=kv_groups, interpret=interpret,
    )
    return out[:, :Sq]

"""Flash attention (forward) — the structural fix for the dominant roofline
term found in EXPERIMENTS.md §Perf: attention scores never visit HBM.

Online-softmax tiling (Dao et al., adapted to TPU): grid (batch*heads, Sq/bq,
Sk/bk) with the KV loop innermost; running (max, sum, acc) live in VMEM
scratch across KV steps. Causal blocks above the diagonal are skipped with
@pl.when (their DMA is cheap relative to the saved MXU work; a production
variant would also clip the grid per q-row).

Used as the serving-path attention on TPU; the dry-run path keeps the
pure-jnp chunked attention (pallas cannot lower for TPU on a CPU host), with
the HBM saving quantified analytically in EXPERIMENTS.md.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_attention"]

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *, n_k, bq, bk, causal, scale):
    kb = pl.program_id(2)

    @pl.when(kb == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    qb = pl.program_id(1)

    should_run = True
    if causal:
        # skip blocks strictly above the diagonal
        should_run = kb * bk < (qb + 1) * bq

    @pl.when(should_run)
    def _step():
        q = q_ref[0]  # (bq, d)
        k = k_ref[0]  # (bk, d)
        v = v_ref[0]
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        if causal:
            qpos = qb * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            kpos = kb * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(kpos <= qpos, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jnp.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new

    @pl.when(kb == n_k - 1)
    def _finish():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "bq", "bk", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, bq: int = 128, bk: int = 128,
                    interpret: bool = False):
    """q, k, v: (BH, S, d) -> (BH, S, d). Scores never materialize in HBM."""
    BH, S, d = q.shape
    bq, bk = min(bq, S), min(bk, S)
    assert S % bq == 0 and S % bk == 0
    n_q, n_k = S // bq, S // bk
    scale = float(1.0 / np.sqrt(d))
    grid = (BH, n_q, n_k)
    return pl.pallas_call(
        functools.partial(
            _kernel, n_k=n_k, bq=bq, bk=bk, causal=causal, scale=scale
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, qb, kb: (b, qb, 0)),
            pl.BlockSpec((1, bk, d), lambda b, qb, kb: (b, kb, 0)),
            pl.BlockSpec((1, bk, d), lambda b, qb, kb: (b, kb, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda b, qb, kb: (b, qb, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)

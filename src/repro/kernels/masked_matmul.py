"""Fused masked matmul with full training semantics (fwd + custom-VJP bwd).

The RigL hot path executes every linear layer as x @ (w ⊙ m).  Naively XLA
materializes the masked copy w⊙m in HBM (read w + read m + write w⊙m + read
w⊙m = 3 extra HBM passes over the weights *per step*).  These kernels fuse the
mask multiply into the matmul's VMEM pipeline: w-tile and 1-byte mask-tile are
DMA'd to VMEM, multiplied in-register, and fed straight to the MXU — the
masked weight never exists in HBM, in the forward OR the backward pass:

  forward   out = x @ (w ⊙ m)          (_fwd_kernel)
  dgrad     dx  = g @ (w ⊙ m)ᵀ         (_dx_kernel — mask fused in-pipeline)
  wgrad     dw  = (xᵀ @ g) ⊙ m         (_dw_kernel — mask fused at the store,
                                         so the cotangent leaving the kernel is
                                         already the paper's SPARSE gradient)

``masked_matmul`` is wrapped in ``jax.custom_vjp`` so ``jax.grad`` of a model
routed through it never falls back to dense XLA matmuls; the mask input gets a
symbolic-zero (float0) cotangent.  Since d/dw [x@(w⊙m)] = (xᵀg)⊙m, the wgrad
this kernel emits equals g_dense * m — exactly what the optimizer consumes
(training/steps.py), with no separate dense_to_sparse_grad traffic needed.

Tiling: grid (M/bm, N/bn, K/bk), MXU-aligned (128x128 default), fp32
accumulator scratch in VMEM, contraction dim innermost so the accumulator tile
stays resident across it.

``grouped_masked_matmul`` is the batched-weight twin: x (G, M, K), w/mask
(G, K, N), grid (G, M/bm, N/bn, K/bk) — one launch covers a whole weight bank
(MoE experts, xLSTM per-head recurrences; see layers.grouped_linear), with the
same fused-mask semantics and a grouped custom VJP.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = [
    "masked_matmul",
    "grouped_masked_matmul",
    "topkast_masked_matmul",
    "topkast_grouped_masked_matmul",
    "fused_masked_matmul",
    "fused_grouped_masked_matmul",
]


def sr_to_bf16(v, seed, gid):
    """Stochastically round f32 values onto the bf16 grid (f32 carrier).

    Counter-based (reproducible, no RNG state): a murmur-style finalizer of
    ``gid ^ seed`` supplies 16 uniform bits that are added below the bf16
    mantissa cut of the f32 bit pattern; truncating to the top 16 bits then
    lands on the lower/upper bf16 neighbour with probability equal to the
    fractional distance — unbiased, so momentum doesn't drift under repeated
    rounding (the reason bf16 optimizer state needs SR at all).  The result
    stays an f32 array whose values are exactly bf16-representable: the
    caller's ``astype(bfloat16)`` is then lossless.  Non-finite values pass
    through untouched (the train step's finite guard decides their fate).
    gid: per-element uint32 ids, unique per (leaf, element); mantissa-carry
    into the exponent is the correct round-up to the next binade.
    """
    h = gid ^ seed.astype(jnp.uint32)
    h = h ^ (h >> 16)
    h = h * jnp.uint32(0x7FEB352D)
    h = h ^ (h >> 15)
    h = h * jnp.uint32(0x846CA68B)
    h = h ^ (h >> 16)
    bits = jax.lax.bitcast_convert_type(v.astype(jnp.float32), jnp.uint32)
    r = (bits + (h & jnp.uint32(0xFFFF))) & jnp.uint32(0xFFFF0000)
    return jnp.where(
        jnp.isfinite(v), jax.lax.bitcast_convert_type(r, jnp.float32), v
    )


def _fwd_kernel(x_ref, w_ref, m_ref, o_ref, acc_ref, *, n_k: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    w = w_ref[...] * m_ref[...].astype(w_ref.dtype)
    acc_ref[...] += jnp.dot(
        x_ref[...], w, preferred_element_type=jnp.float32
    )

    @pl.when(k == n_k - 1)
    def _store():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _dx_kernel(g_ref, w_ref, m_ref, o_ref, acc_ref, *, n_n: int):
    """dx-tile (bm, bk) += g (bm, bn) @ (w ⊙ m)ᵀ (bn, bk); N innermost."""
    n = pl.program_id(2)

    @pl.when(n == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    w = w_ref[...] * m_ref[...].astype(w_ref.dtype)
    acc_ref[...] += jax.lax.dot_general(
        g_ref[...], w, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(n == n_n - 1)
    def _store():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _dw_kernel(x_ref, g_ref, m_ref, o_ref, acc_ref, *, n_m: int):
    """dw-tile (bk, bn) += xᵀ (bk, bm) @ g (bm, bn); mask applied at store."""
    i = pl.program_id(2)

    @pl.when(i == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[...], g_ref[...], (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(i == n_m - 1)
    def _store():
        o_ref[...] = (
            acc_ref[...] * m_ref[...].astype(jnp.float32)
        ).astype(o_ref.dtype)


def _fwd_call(x, w, mask, bm, bn, bk, interpret):
    M, K = x.shape
    N = w.shape[1]
    n_k = K // bk
    grid = (M // bm, N // bn, n_k)
    return pl.pallas_call(
        functools.partial(_fwd_kernel, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda m, n, k: (m, k)),
            pl.BlockSpec((bk, bn), lambda m, n, k: (k, n)),
            pl.BlockSpec((bk, bn), lambda m, n, k: (k, n)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda m, n, k: (m, n)),
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, w, mask)


def _dx_call(g, w, mask, bm, bn, bk, interpret, out_dtype):
    M, N = g.shape
    K = w.shape[0]
    n_n = N // bn
    grid = (M // bm, K // bk, n_n)
    return pl.pallas_call(
        functools.partial(_dx_kernel, n_n=n_n),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bn), lambda m, k, n: (m, n)),
            pl.BlockSpec((bk, bn), lambda m, k, n: (k, n)),
            pl.BlockSpec((bk, bn), lambda m, k, n: (k, n)),
        ],
        out_specs=pl.BlockSpec((bm, bk), lambda m, k, n: (m, k)),
        out_shape=jax.ShapeDtypeStruct((M, K), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bk), jnp.float32)],
        interpret=interpret,
    )(g, w, mask)


def _dw_call(x, g, mask, bm, bn, bk, interpret, out_dtype):
    M, K = x.shape
    N = g.shape[1]
    n_m = M // bm
    grid = (K // bk, N // bn, n_m)
    return pl.pallas_call(
        functools.partial(_dw_kernel, n_m=n_m),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda k, n, i: (i, k)),
            pl.BlockSpec((bm, bn), lambda k, n, i: (i, n)),
            pl.BlockSpec((bk, bn), lambda k, n, i: (k, n)),
        ],
        out_specs=pl.BlockSpec((bk, bn), lambda k, n, i: (k, n)),
        out_shape=jax.ShapeDtypeStruct((K, N), out_dtype),
        scratch_shapes=[pltpu.VMEM((bk, bn), jnp.float32)],
        interpret=interpret,
    )(x, g, mask)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def _masked_matmul(x, w, mask, bm, bn, bk, interpret):
    return _fwd_call(x, w, mask, bm, bn, bk, interpret)


def _mm_fwd(x, w, mask, bm, bn, bk, interpret):
    return _fwd_call(x, w, mask, bm, bn, bk, interpret), (x, w, mask)


def _mm_bwd(bm, bn, bk, interpret, res, g):
    x, w, mask = res
    dx = _dx_call(g, w, mask, bm, bn, bk, interpret, x.dtype)
    dw = _dw_call(x, g, mask, bm, bn, bk, interpret, w.dtype)
    # bool mask: symbolic-zero cotangent (float0), per the custom_vjp contract
    dmask = np.zeros(mask.shape, jax.dtypes.float0)
    return dx, dw, dmask


_masked_matmul.defvjp(_mm_fwd, _mm_bwd)


@functools.partial(
    jax.jit, static_argnames=("bm", "bn", "bk", "interpret")
)
def masked_matmul(
    x, w, mask, *, bm: int = 128, bn: int = 128, bk: int = 128, interpret: bool = False
):
    """x: (M, K); w: (K, N); mask: (K, N) bool/int8 -> (M, N) in x.dtype.

    Differentiable: jax.grad routes through the fused Pallas dgrad/wgrad
    kernels above (never a dense XLA matmul over unmasked weights).
    """
    M, K = x.shape
    K2, N = w.shape
    assert K == K2 and mask.shape == w.shape, (x.shape, w.shape, mask.shape)
    bm, bn, bk = min(bm, M), min(bn, N), min(bk, K)
    assert M % bm == 0 and N % bn == 0 and K % bk == 0, (M, N, K, bm, bn, bk)
    return _masked_matmul(x, w, mask, bm, bn, bk, interpret)


# ---------------------------------------------------------------------------
# grouped kernels: one launch over a whole (G, K, N) masked weight bank
# ---------------------------------------------------------------------------

def _g_fwd_kernel(x_ref, w_ref, m_ref, o_ref, acc_ref, *, n_k: int):
    k = pl.program_id(3)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    w = w_ref[0] * m_ref[0].astype(w_ref.dtype)
    acc_ref[...] += jnp.dot(x_ref[0], w, preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _store():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)[None]


def _g_dx_kernel(g_ref, w_ref, m_ref, o_ref, acc_ref, *, n_n: int):
    n = pl.program_id(3)

    @pl.when(n == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    w = w_ref[0] * m_ref[0].astype(w_ref.dtype)
    acc_ref[...] += jax.lax.dot_general(
        g_ref[0], w, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(n == n_n - 1)
    def _store():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)[None]


def _g_dw_kernel(x_ref, g_ref, m_ref, o_ref, acc_ref, *, n_m: int):
    i = pl.program_id(3)

    @pl.when(i == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[0], g_ref[0], (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(i == n_m - 1)
    def _store():
        o_ref[...] = (
            acc_ref[...] * m_ref[0].astype(jnp.float32)
        ).astype(o_ref.dtype)[None]


def _g_fwd_call(x, w, mask, bm, bn, bk, interpret):
    G, M, K = x.shape
    N = w.shape[2]
    n_k = K // bk
    grid = (G, M // bm, N // bn, n_k)
    return pl.pallas_call(
        functools.partial(_g_fwd_kernel, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bm, bk), lambda g, m, n, k: (g, m, k)),
            pl.BlockSpec((1, bk, bn), lambda g, m, n, k: (g, k, n)),
            pl.BlockSpec((1, bk, bn), lambda g, m, n, k: (g, k, n)),
        ],
        out_specs=pl.BlockSpec((1, bm, bn), lambda g, m, n, k: (g, m, n)),
        out_shape=jax.ShapeDtypeStruct((G, M, N), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, w, mask)


def _g_dx_call(g_, w, mask, bm, bn, bk, interpret, out_dtype):
    G, M, N = g_.shape
    K = w.shape[1]
    n_n = N // bn
    grid = (G, M // bm, K // bk, n_n)
    return pl.pallas_call(
        functools.partial(_g_dx_kernel, n_n=n_n),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bm, bn), lambda g, m, k, n: (g, m, n)),
            pl.BlockSpec((1, bk, bn), lambda g, m, k, n: (g, k, n)),
            pl.BlockSpec((1, bk, bn), lambda g, m, k, n: (g, k, n)),
        ],
        out_specs=pl.BlockSpec((1, bm, bk), lambda g, m, k, n: (g, m, k)),
        out_shape=jax.ShapeDtypeStruct((G, M, K), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bk), jnp.float32)],
        interpret=interpret,
    )(g_, w, mask)


def _g_dw_call(x, g_, mask, bm, bn, bk, interpret, out_dtype):
    G, M, K = x.shape
    N = g_.shape[2]
    n_m = M // bm
    grid = (G, K // bk, N // bn, n_m)
    return pl.pallas_call(
        functools.partial(_g_dw_kernel, n_m=n_m),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bm, bk), lambda g, k, n, i: (g, i, k)),
            pl.BlockSpec((1, bm, bn), lambda g, k, n, i: (g, i, n)),
            pl.BlockSpec((1, bk, bn), lambda g, k, n, i: (g, k, n)),
        ],
        out_specs=pl.BlockSpec((1, bk, bn), lambda g, k, n, i: (g, k, n)),
        out_shape=jax.ShapeDtypeStruct((G, K, N), out_dtype),
        scratch_shapes=[pltpu.VMEM((bk, bn), jnp.float32)],
        interpret=interpret,
    )(x, g_, mask)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def _grouped_masked_matmul(x, w, mask, bm, bn, bk, interpret):
    return _g_fwd_call(x, w, mask, bm, bn, bk, interpret)


def _gmm_fwd(x, w, mask, bm, bn, bk, interpret):
    return _g_fwd_call(x, w, mask, bm, bn, bk, interpret), (x, w, mask)


def _gmm_bwd(bm, bn, bk, interpret, res, g):
    x, w, mask = res
    dx = _g_dx_call(g, w, mask, bm, bn, bk, interpret, x.dtype)
    dw = _g_dw_call(x, g, mask, bm, bn, bk, interpret, w.dtype)
    dmask = np.zeros(mask.shape, jax.dtypes.float0)
    return dx, dw, dmask


_grouped_masked_matmul.defvjp(_gmm_fwd, _gmm_bwd)


@functools.partial(
    jax.jit, static_argnames=("bm", "bn", "bk", "interpret")
)
def grouped_masked_matmul(
    x, w, mask, *, bm: int = 128, bn: int = 128, bk: int = 128,
    interpret: bool = False,
):
    """x: (G, M, K); w, mask: (G, K, N) -> (G, M, N) in x.dtype.

    One kernel launch executes every group's fused-mask matmul (MoE expert
    banks, xLSTM per-head recurrences).  Differentiable via the grouped
    custom-VJP dgrad/wgrad kernels above — per-group cotangents off-mask are
    exactly zero, same as the 2-D ``masked_matmul`` contract.
    """
    G, M, K = x.shape
    G2, K2, N = w.shape
    assert G == G2 and K == K2 and mask.shape == w.shape, (
        x.shape, w.shape, mask.shape,
    )
    bm, bn, bk = min(bm, M), min(bn, N), min(bk, K)
    assert M % bm == 0 and N % bn == 0 and K % bk == 0, (M, N, K, bm, bn, bk)
    return _grouped_masked_matmul(x, w, mask, bm, bn, bk, interpret)


# ---------------------------------------------------------------------------
# Top-KAST split-topology VJP: forward/dgrad on mask A, wgrad on the backward
# superset B ⊇ A (docs/training.md#topkast)
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6, 7))
def _topkast_masked_matmul(x, w, mask, bwd_mask, bm, bn, bk, interpret):
    return _fwd_call(x, w, mask, bm, bn, bk, interpret)


def _tkm_fwd(x, w, mask, bwd_mask, bm, bn, bk, interpret):
    return _fwd_call(x, w, mask, bm, bn, bk, interpret), (x, w, mask, bwd_mask)


def _tkm_bwd(bm, bn, bk, interpret, res, g):
    x, w, mask, bwd_mask = res
    # dx on the FORWARD mask (y only saw w ⊙ A); dw on the superset B — the
    # dense gradient restricted to B's support, no dense materialization.
    dx = _dx_call(g, w, mask, bm, bn, bk, interpret, x.dtype)
    dw = _dw_call(x, g, bwd_mask, bm, bn, bk, interpret, w.dtype)
    z = lambda a: np.zeros(a.shape, jax.dtypes.float0)
    return dx, dw, z(mask), z(bwd_mask)


_topkast_masked_matmul.defvjp(_tkm_fwd, _tkm_bwd)


@functools.partial(
    jax.jit, static_argnames=("bm", "bn", "bk", "interpret")
)
def topkast_masked_matmul(
    x, w, mask, bwd_mask, *, bm: int = 128, bn: int = 128, bk: int = 128,
    interpret: bool = False,
):
    """Top-KAST masked matmul: forward ⊙ A, weight gradient ⊙ B ⊇ A.

    Same fused kernels as ``masked_matmul`` — the split is purely in which
    mask the wgrad kernel fuses.  The exploration set B\\A receives gradient
    but never contributes to forward compute; callers guarantee A ⊆ B
    (core/masks.py::mask_subset, checked at pack-build time).
    """
    M, K = x.shape
    K2, N = w.shape
    assert K == K2 and mask.shape == w.shape == bwd_mask.shape, (
        x.shape, w.shape, mask.shape, bwd_mask.shape,
    )
    bm, bn, bk = min(bm, M), min(bn, N), min(bk, K)
    assert M % bm == 0 and N % bn == 0 and K % bk == 0, (M, N, K, bm, bn, bk)
    return _topkast_masked_matmul(x, w, mask, bwd_mask, bm, bn, bk, interpret)


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6, 7))
def _topkast_grouped_masked_matmul(x, w, mask, bwd_mask, bm, bn, bk, interpret):
    return _g_fwd_call(x, w, mask, bm, bn, bk, interpret)


def _gtkm_fwd(x, w, mask, bwd_mask, bm, bn, bk, interpret):
    return _g_fwd_call(x, w, mask, bm, bn, bk, interpret), (x, w, mask, bwd_mask)


def _gtkm_bwd(bm, bn, bk, interpret, res, g):
    x, w, mask, bwd_mask = res
    dx = _g_dx_call(g, w, mask, bm, bn, bk, interpret, x.dtype)
    dw = _g_dw_call(x, g, bwd_mask, bm, bn, bk, interpret, w.dtype)
    z = lambda a: np.zeros(a.shape, jax.dtypes.float0)
    return dx, dw, z(mask), z(bwd_mask)


_topkast_grouped_masked_matmul.defvjp(_gtkm_fwd, _gtkm_bwd)


@functools.partial(
    jax.jit, static_argnames=("bm", "bn", "bk", "interpret")
)
def topkast_grouped_masked_matmul(
    x, w, mask, bwd_mask, *, bm: int = 128, bn: int = 128, bk: int = 128,
    interpret: bool = False,
):
    """Grouped Top-KAST masked matmul: per-group forward ⊙ A, wgrad ⊙ B."""
    G, M, K = x.shape
    G2, K2, N = w.shape
    assert G == G2 and K == K2 and mask.shape == w.shape == bwd_mask.shape, (
        x.shape, w.shape, mask.shape, bwd_mask.shape,
    )
    bm, bn, bk = min(bm, M), min(bn, N), min(bk, K)
    assert M % bm == 0 and N % bn == 0 and K % bk == 0, (M, N, K, bm, bn, bk)
    return _topkast_grouped_masked_matmul(
        x, w, mask, bwd_mask, bm, bn, bk, interpret
    )


# ---------------------------------------------------------------------------
# fused wgrad -> optimizer epilogue (docs/kernels.md#fused-epilogue)
#
# The SGD-momentum epilogue m_new = mu*mom + (dw + wd*w)*m_wgrad is computed
# INSIDE the wgrad kernel's store step: the mom/w tiles ride the same VMEM
# pipeline as the x/g tiles, so the raw dw never exists in HBM — the weight
# cotangent leaving the VJP *is* the new momentum (optionally stochastically
# rounded onto the bf16 grid in-register).  apply_opt_fused (optim/) then
# only does p -= lr*g and momentum := g — one full HBM pass over the weight
# gradient (write + re-read) is gone per train step.
# ---------------------------------------------------------------------------

def _dw_fused_kernel(
    seed_ref, x_ref, g_ref, m_ref, w_ref, mom_ref, o_ref, acc_ref,
    *, n_m: int, ncols: int, mu: float, wd: float, sr: bool,
):
    """dw-tile accumulate as _dw_kernel; epilogue folded into the store."""
    i = pl.program_id(2)
    # program_id must be read at kernel top level (a pl.when branch body is a
    # cond jaxpr, where it fails to lower in interpret mode)
    k, n = pl.program_id(0), pl.program_id(1)

    @pl.when(i == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[...], g_ref[...], (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(i == n_m - 1)
    def _store():
        mk = m_ref[...].astype(jnp.float32)
        m_new = (
            mu * mom_ref[...].astype(jnp.float32)
            + acc_ref[...]
            + wd * w_ref[...].astype(jnp.float32)
        ) * mk  # momentum off the wgrad support is pinned to zero (documented)
        if sr:
            bkk, bnn = m_new.shape
            rows = jax.lax.broadcasted_iota(jnp.uint32, m_new.shape, 0)
            cols = jax.lax.broadcasted_iota(jnp.uint32, m_new.shape, 1)
            ku, nu = jnp.uint32(k), jnp.uint32(n)
            gid = (ku * bkk + rows) * jnp.uint32(ncols) + (nu * bnn + cols)
            m_new = sr_to_bf16(m_new, seed_ref[0], gid)
        o_ref[...] = m_new.astype(o_ref.dtype)


def _dw_fused_call(x, g, wgm, w, mom, seed, mu, wd, sr, bm, bn, bk, interpret):
    M, K = x.shape
    N = g.shape[1]
    n_m = M // bm
    grid = (K // bk, N // bn, n_m)
    kn = lambda k, n, i, *_: (k, n)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda k, n, i, *_: (i, k)),
            pl.BlockSpec((bm, bn), lambda k, n, i, *_: (i, n)),
            pl.BlockSpec((bk, bn), kn),
            pl.BlockSpec((bk, bn), kn),
            pl.BlockSpec((bk, bn), kn),
        ],
        out_specs=pl.BlockSpec((bk, bn), kn),
        scratch_shapes=[pltpu.VMEM((bk, bn), jnp.float32)],
    )
    return pl.pallas_call(
        functools.partial(
            _dw_fused_kernel, n_m=n_m, ncols=N, mu=mu, wd=wd, sr=sr
        ),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((K, N), w.dtype),
        interpret=interpret,
    )(seed, x, g, wgm, w, mom)


@functools.partial(jax.custom_vjp, nondiff_argnums=(6, 7, 8, 9, 10, 11, 12))
def _fused_masked_matmul(x, w, mask, wgm, mom, seed, mu, wd, sr, bm, bn, bk, interpret):
    return _fwd_call(x, w, mask, bm, bn, bk, interpret)


def _fmm_fwd(x, w, mask, wgm, mom, seed, mu, wd, sr, bm, bn, bk, interpret):
    out = _fwd_call(x, w, mask, bm, bn, bk, interpret)
    return out, (x, w, mask, wgm, mom, seed)


def _fmm_bwd(mu, wd, sr, bm, bn, bk, interpret, res, g):
    x, w, mask, wgm, mom, seed = res
    dx = _dx_call(g, w, mask, bm, bn, bk, interpret, x.dtype)
    m_new = _dw_fused_call(
        x, g, wgm, w, mom, seed, mu, wd, sr, bm, bn, bk, interpret
    )
    z = lambda a: np.zeros(a.shape, jax.dtypes.float0)
    return dx, m_new, z(mask), z(wgm), jnp.zeros_like(mom), z(seed)


_fused_masked_matmul.defvjp(_fmm_fwd, _fmm_bwd)


@functools.partial(
    jax.jit, static_argnames=("mu", "wd", "sr", "bm", "bn", "bk", "interpret")
)
def fused_masked_matmul(
    x, w, mask, wgrad_mask, mom, seed, *, mu: float, wd: float, sr: bool,
    bm: int = 128, bn: int = 128, bk: int = 128, interpret: bool = False,
):
    """``masked_matmul`` whose weight COTANGENT is the new SGD momentum.

    Forward/dgrad identical to ``masked_matmul`` (mask fused in-pipeline).
    The wgrad kernel stores m_new = (mu*mom + xᵀg + wd*w) ⊙ wgrad_mask —
    the optimizer epilogue fused at the tile store, so the raw gradient
    never round-trips HBM.  wgrad_mask is the Top-KAST superset B when the
    pack carries one, else the forward mask.  seed: (1,) int32 per-leaf
    counter (train step supplies step*P + leaf_index); sr=True additionally
    stochastically rounds m_new onto the bf16 grid (see sr_to_bf16).
    mom's own cotangent is a discarded zero (nothing differentiates w.r.t.
    momentum).  Consumed via ops.fused_masked_linear + optim.apply_opt_fused.
    """
    M, K = x.shape
    K2, N = w.shape
    assert K == K2 and mask.shape == w.shape == wgrad_mask.shape == mom.shape
    bm, bn, bk = min(bm, M), min(bn, N), min(bk, K)
    assert M % bm == 0 and N % bn == 0 and K % bk == 0, (M, N, K, bm, bn, bk)
    return _fused_masked_matmul(
        x, w, mask, wgrad_mask, mom, seed, mu, wd, sr, bm, bn, bk, interpret
    )


def _g_dw_fused_kernel(
    seed_ref, x_ref, g_ref, m_ref, w_ref, mom_ref, o_ref, acc_ref,
    *, n_m: int, nrows: int, ncols: int, mu: float, wd: float, sr: bool,
):
    i = pl.program_id(3)
    g_, k, n = pl.program_id(0), pl.program_id(1), pl.program_id(2)

    @pl.when(i == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[0], g_ref[0], (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(i == n_m - 1)
    def _store():
        mk = m_ref[0].astype(jnp.float32)
        m_new = (
            mu * mom_ref[0].astype(jnp.float32)
            + acc_ref[...]
            + wd * w_ref[0].astype(jnp.float32)
        ) * mk
        if sr:
            bkk, bnn = m_new.shape
            rows = jax.lax.broadcasted_iota(jnp.uint32, m_new.shape, 0)
            cols = jax.lax.broadcasted_iota(jnp.uint32, m_new.shape, 1)
            gu, ku, nu = jnp.uint32(g_), jnp.uint32(k), jnp.uint32(n)
            gid = (gu * nrows + ku * bkk + rows) * jnp.uint32(ncols) + (
                nu * bnn + cols
            )
            m_new = sr_to_bf16(m_new, seed_ref[0], gid)
        o_ref[...] = m_new.astype(o_ref.dtype)[None]


def _g_dw_fused_call(x, g, wgm, w, mom, seed, mu, wd, sr, bm, bn, bk, interpret):
    G, M, K = x.shape
    N = g.shape[2]
    n_m = M // bm
    grid = (G, K // bk, N // bn, n_m)
    gkn = lambda g_, k, n, i, *_: (g_, k, n)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bm, bk), lambda g_, k, n, i, *_: (g_, i, k)),
            pl.BlockSpec((1, bm, bn), lambda g_, k, n, i, *_: (g_, i, n)),
            pl.BlockSpec((1, bk, bn), gkn),
            pl.BlockSpec((1, bk, bn), gkn),
            pl.BlockSpec((1, bk, bn), gkn),
        ],
        out_specs=pl.BlockSpec((1, bk, bn), gkn),
        scratch_shapes=[pltpu.VMEM((bk, bn), jnp.float32)],
    )
    return pl.pallas_call(
        functools.partial(
            _g_dw_fused_kernel, n_m=n_m, nrows=K, ncols=N, mu=mu, wd=wd, sr=sr
        ),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((G, K, N), w.dtype),
        interpret=interpret,
    )(seed, x, g, wgm, w, mom)


@functools.partial(jax.custom_vjp, nondiff_argnums=(6, 7, 8, 9, 10, 11, 12))
def _fused_grouped_masked_matmul(
    x, w, mask, wgm, mom, seed, mu, wd, sr, bm, bn, bk, interpret
):
    return _g_fwd_call(x, w, mask, bm, bn, bk, interpret)


def _gfmm_fwd(x, w, mask, wgm, mom, seed, mu, wd, sr, bm, bn, bk, interpret):
    out = _g_fwd_call(x, w, mask, bm, bn, bk, interpret)
    return out, (x, w, mask, wgm, mom, seed)


def _gfmm_bwd(mu, wd, sr, bm, bn, bk, interpret, res, g):
    x, w, mask, wgm, mom, seed = res
    dx = _g_dx_call(g, w, mask, bm, bn, bk, interpret, x.dtype)
    m_new = _g_dw_fused_call(
        x, g, wgm, w, mom, seed, mu, wd, sr, bm, bn, bk, interpret
    )
    z = lambda a: np.zeros(a.shape, jax.dtypes.float0)
    return dx, m_new, z(mask), z(wgm), jnp.zeros_like(mom), z(seed)


_fused_grouped_masked_matmul.defvjp(_gfmm_fwd, _gfmm_bwd)


@functools.partial(
    jax.jit, static_argnames=("mu", "wd", "sr", "bm", "bn", "bk", "interpret")
)
def fused_grouped_masked_matmul(
    x, w, mask, wgrad_mask, mom, seed, *, mu: float, wd: float, sr: bool,
    bm: int = 128, bn: int = 128, bk: int = 128, interpret: bool = False,
):
    """Grouped ``fused_masked_matmul``: per-group wgrad -> epilogue fusion."""
    G, M, K = x.shape
    G2, K2, N = w.shape
    assert G == G2 and K == K2
    assert mask.shape == w.shape == wgrad_mask.shape == mom.shape
    bm, bn, bk = min(bm, M), min(bn, N), min(bk, K)
    assert M % bm == 0 and N % bn == 0 and K % bk == 0, (M, N, K, bm, bn, bk)
    return _fused_grouped_masked_matmul(
        x, w, mask, wgrad_mask, mom, seed, mu, wd, sr, bm, bn, bk, interpret
    )

"""Fused masked matmul with full training semantics (fwd + custom-VJP bwd).

The RigL hot path executes every linear layer as x @ (w ⊙ m).  Naively XLA
materializes the masked copy w⊙m in HBM (read w + read m + write w⊙m + read
w⊙m = 3 extra HBM passes over the weights *per step*).  These kernels fuse the
mask multiply into the matmul's VMEM pipeline: w-tile and 1-byte mask-tile are
DMA'd to VMEM, multiplied in-register, and fed straight to the MXU — the
masked weight never exists in HBM, in the forward OR the backward pass:

  forward   out = x @ (w ⊙ m)          (_fwd_kernel)
  dgrad     dx  = g @ (w ⊙ m)ᵀ         (_dx_kernel — mask fused in-pipeline)
  wgrad     dw  = (xᵀ @ g) ⊙ m         (_dw_kernel — mask fused at the store,
                                         so the cotangent leaving the kernel is
                                         already the paper's SPARSE gradient)

``masked_matmul`` is wrapped in ``jax.custom_vjp`` so ``jax.grad`` of a model
routed through it never falls back to dense XLA matmuls; the mask input gets a
symbolic-zero (float0) cotangent.  Since d/dw [x@(w⊙m)] = (xᵀg)⊙m, the wgrad
this kernel emits equals g_dense * m — exactly what the optimizer consumes
(training/steps.py), with no separate dense_to_sparse_grad traffic needed.

Tiling: grid (M/bm, N/bn, K/bk), MXU-aligned (128x128 default), fp32
accumulator scratch in VMEM, contraction dim innermost so the accumulator tile
stays resident across it.

``grouped_masked_matmul`` is the batched-weight twin: x (G, M, K), w/mask
(G, K, N), grid (G, M/bm, N/bn, K/bk) — one launch covers a whole weight bank
(MoE experts, xLSTM per-head recurrences; see layers.grouped_linear), with the
same fused-mask semantics and a grouped custom VJP.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = [
    "masked_matmul",
    "grouped_masked_matmul",
    "topkast_masked_matmul",
    "topkast_grouped_masked_matmul",
]


def _fwd_kernel(x_ref, w_ref, m_ref, o_ref, acc_ref, *, n_k: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    w = w_ref[...] * m_ref[...].astype(w_ref.dtype)
    acc_ref[...] += jnp.dot(
        x_ref[...], w, preferred_element_type=jnp.float32
    )

    @pl.when(k == n_k - 1)
    def _store():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _dx_kernel(g_ref, w_ref, m_ref, o_ref, acc_ref, *, n_n: int):
    """dx-tile (bm, bk) += g (bm, bn) @ (w ⊙ m)ᵀ (bn, bk); N innermost."""
    n = pl.program_id(2)

    @pl.when(n == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    w = w_ref[...] * m_ref[...].astype(w_ref.dtype)
    acc_ref[...] += jax.lax.dot_general(
        g_ref[...], w, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(n == n_n - 1)
    def _store():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _dw_kernel(x_ref, g_ref, m_ref, o_ref, acc_ref, *, n_m: int):
    """dw-tile (bk, bn) += xᵀ (bk, bm) @ g (bm, bn); mask applied at store."""
    i = pl.program_id(2)

    @pl.when(i == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[...], g_ref[...], (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(i == n_m - 1)
    def _store():
        o_ref[...] = (
            acc_ref[...] * m_ref[...].astype(jnp.float32)
        ).astype(o_ref.dtype)


def _fwd_call(x, w, mask, bm, bn, bk, interpret):
    M, K = x.shape
    N = w.shape[1]
    n_k = K // bk
    grid = (M // bm, N // bn, n_k)
    return pl.pallas_call(
        functools.partial(_fwd_kernel, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda m, n, k: (m, k)),
            pl.BlockSpec((bk, bn), lambda m, n, k: (k, n)),
            pl.BlockSpec((bk, bn), lambda m, n, k: (k, n)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda m, n, k: (m, n)),
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, w, mask)


def _dx_call(g, w, mask, bm, bn, bk, interpret, out_dtype):
    M, N = g.shape
    K = w.shape[0]
    n_n = N // bn
    grid = (M // bm, K // bk, n_n)
    return pl.pallas_call(
        functools.partial(_dx_kernel, n_n=n_n),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bn), lambda m, k, n: (m, n)),
            pl.BlockSpec((bk, bn), lambda m, k, n: (k, n)),
            pl.BlockSpec((bk, bn), lambda m, k, n: (k, n)),
        ],
        out_specs=pl.BlockSpec((bm, bk), lambda m, k, n: (m, k)),
        out_shape=jax.ShapeDtypeStruct((M, K), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bk), jnp.float32)],
        interpret=interpret,
    )(g, w, mask)


def _dw_call(x, g, mask, bm, bn, bk, interpret, out_dtype):
    M, K = x.shape
    N = g.shape[1]
    n_m = M // bm
    grid = (K // bk, N // bn, n_m)
    return pl.pallas_call(
        functools.partial(_dw_kernel, n_m=n_m),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda k, n, i: (i, k)),
            pl.BlockSpec((bm, bn), lambda k, n, i: (i, n)),
            pl.BlockSpec((bk, bn), lambda k, n, i: (k, n)),
        ],
        out_specs=pl.BlockSpec((bk, bn), lambda k, n, i: (k, n)),
        out_shape=jax.ShapeDtypeStruct((K, N), out_dtype),
        scratch_shapes=[pltpu.VMEM((bk, bn), jnp.float32)],
        interpret=interpret,
    )(x, g, mask)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def _masked_matmul(x, w, mask, bm, bn, bk, interpret):
    return _fwd_call(x, w, mask, bm, bn, bk, interpret)


def _mm_fwd(x, w, mask, bm, bn, bk, interpret):
    return _fwd_call(x, w, mask, bm, bn, bk, interpret), (x, w, mask)


def _mm_bwd(bm, bn, bk, interpret, res, g):
    x, w, mask = res
    dx = _dx_call(g, w, mask, bm, bn, bk, interpret, x.dtype)
    dw = _dw_call(x, g, mask, bm, bn, bk, interpret, w.dtype)
    # bool mask: symbolic-zero cotangent (float0), per the custom_vjp contract
    dmask = np.zeros(mask.shape, jax.dtypes.float0)
    return dx, dw, dmask


_masked_matmul.defvjp(_mm_fwd, _mm_bwd)


@functools.partial(
    jax.jit, static_argnames=("bm", "bn", "bk", "interpret")
)
def masked_matmul(
    x, w, mask, *, bm: int = 128, bn: int = 128, bk: int = 128, interpret: bool = False
):
    """x: (M, K); w: (K, N); mask: (K, N) bool/int8 -> (M, N) in x.dtype.

    Differentiable: jax.grad routes through the fused Pallas dgrad/wgrad
    kernels above (never a dense XLA matmul over unmasked weights).
    """
    M, K = x.shape
    K2, N = w.shape
    assert K == K2 and mask.shape == w.shape, (x.shape, w.shape, mask.shape)
    bm, bn, bk = min(bm, M), min(bn, N), min(bk, K)
    assert M % bm == 0 and N % bn == 0 and K % bk == 0, (M, N, K, bm, bn, bk)
    return _masked_matmul(x, w, mask, bm, bn, bk, interpret)


# ---------------------------------------------------------------------------
# grouped kernels: one launch over a whole (G, K, N) masked weight bank
# ---------------------------------------------------------------------------

def _g_fwd_kernel(x_ref, w_ref, m_ref, o_ref, acc_ref, *, n_k: int):
    k = pl.program_id(3)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    w = w_ref[0] * m_ref[0].astype(w_ref.dtype)
    acc_ref[...] += jnp.dot(x_ref[0], w, preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _store():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)[None]


def _g_dx_kernel(g_ref, w_ref, m_ref, o_ref, acc_ref, *, n_n: int):
    n = pl.program_id(3)

    @pl.when(n == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    w = w_ref[0] * m_ref[0].astype(w_ref.dtype)
    acc_ref[...] += jax.lax.dot_general(
        g_ref[0], w, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(n == n_n - 1)
    def _store():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)[None]


def _g_dw_kernel(x_ref, g_ref, m_ref, o_ref, acc_ref, *, n_m: int):
    i = pl.program_id(3)

    @pl.when(i == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[0], g_ref[0], (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(i == n_m - 1)
    def _store():
        o_ref[...] = (
            acc_ref[...] * m_ref[0].astype(jnp.float32)
        ).astype(o_ref.dtype)[None]


def _g_fwd_call(x, w, mask, bm, bn, bk, interpret):
    G, M, K = x.shape
    N = w.shape[2]
    n_k = K // bk
    grid = (G, M // bm, N // bn, n_k)
    return pl.pallas_call(
        functools.partial(_g_fwd_kernel, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bm, bk), lambda g, m, n, k: (g, m, k)),
            pl.BlockSpec((1, bk, bn), lambda g, m, n, k: (g, k, n)),
            pl.BlockSpec((1, bk, bn), lambda g, m, n, k: (g, k, n)),
        ],
        out_specs=pl.BlockSpec((1, bm, bn), lambda g, m, n, k: (g, m, n)),
        out_shape=jax.ShapeDtypeStruct((G, M, N), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, w, mask)


def _g_dx_call(g_, w, mask, bm, bn, bk, interpret, out_dtype):
    G, M, N = g_.shape
    K = w.shape[1]
    n_n = N // bn
    grid = (G, M // bm, K // bk, n_n)
    return pl.pallas_call(
        functools.partial(_g_dx_kernel, n_n=n_n),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bm, bn), lambda g, m, k, n: (g, m, n)),
            pl.BlockSpec((1, bk, bn), lambda g, m, k, n: (g, k, n)),
            pl.BlockSpec((1, bk, bn), lambda g, m, k, n: (g, k, n)),
        ],
        out_specs=pl.BlockSpec((1, bm, bk), lambda g, m, k, n: (g, m, k)),
        out_shape=jax.ShapeDtypeStruct((G, M, K), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bk), jnp.float32)],
        interpret=interpret,
    )(g_, w, mask)


def _g_dw_call(x, g_, mask, bm, bn, bk, interpret, out_dtype):
    G, M, K = x.shape
    N = g_.shape[2]
    n_m = M // bm
    grid = (G, K // bk, N // bn, n_m)
    return pl.pallas_call(
        functools.partial(_g_dw_kernel, n_m=n_m),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bm, bk), lambda g, k, n, i: (g, i, k)),
            pl.BlockSpec((1, bm, bn), lambda g, k, n, i: (g, i, n)),
            pl.BlockSpec((1, bk, bn), lambda g, k, n, i: (g, k, n)),
        ],
        out_specs=pl.BlockSpec((1, bk, bn), lambda g, k, n, i: (g, k, n)),
        out_shape=jax.ShapeDtypeStruct((G, K, N), out_dtype),
        scratch_shapes=[pltpu.VMEM((bk, bn), jnp.float32)],
        interpret=interpret,
    )(x, g_, mask)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def _grouped_masked_matmul(x, w, mask, bm, bn, bk, interpret):
    return _g_fwd_call(x, w, mask, bm, bn, bk, interpret)


def _gmm_fwd(x, w, mask, bm, bn, bk, interpret):
    return _g_fwd_call(x, w, mask, bm, bn, bk, interpret), (x, w, mask)


def _gmm_bwd(bm, bn, bk, interpret, res, g):
    x, w, mask = res
    dx = _g_dx_call(g, w, mask, bm, bn, bk, interpret, x.dtype)
    dw = _g_dw_call(x, g, mask, bm, bn, bk, interpret, w.dtype)
    dmask = np.zeros(mask.shape, jax.dtypes.float0)
    return dx, dw, dmask


_grouped_masked_matmul.defvjp(_gmm_fwd, _gmm_bwd)


@functools.partial(
    jax.jit, static_argnames=("bm", "bn", "bk", "interpret")
)
def grouped_masked_matmul(
    x, w, mask, *, bm: int = 128, bn: int = 128, bk: int = 128,
    interpret: bool = False,
):
    """x: (G, M, K); w, mask: (G, K, N) -> (G, M, N) in x.dtype.

    One kernel launch executes every group's fused-mask matmul (MoE expert
    banks, xLSTM per-head recurrences).  Differentiable via the grouped
    custom-VJP dgrad/wgrad kernels above — per-group cotangents off-mask are
    exactly zero, same as the 2-D ``masked_matmul`` contract.
    """
    G, M, K = x.shape
    G2, K2, N = w.shape
    assert G == G2 and K == K2 and mask.shape == w.shape, (
        x.shape, w.shape, mask.shape,
    )
    bm, bn, bk = min(bm, M), min(bn, N), min(bk, K)
    assert M % bm == 0 and N % bn == 0 and K % bk == 0, (M, N, K, bm, bn, bk)
    return _grouped_masked_matmul(x, w, mask, bm, bn, bk, interpret)


# ---------------------------------------------------------------------------
# Top-KAST split-topology VJP: forward/dgrad on mask A, wgrad on the backward
# superset B ⊇ A (docs/training.md#topkast)
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6, 7))
def _topkast_masked_matmul(x, w, mask, bwd_mask, bm, bn, bk, interpret):
    return _fwd_call(x, w, mask, bm, bn, bk, interpret)


def _tkm_fwd(x, w, mask, bwd_mask, bm, bn, bk, interpret):
    return _fwd_call(x, w, mask, bm, bn, bk, interpret), (x, w, mask, bwd_mask)


def _tkm_bwd(bm, bn, bk, interpret, res, g):
    x, w, mask, bwd_mask = res
    # dx on the FORWARD mask (y only saw w ⊙ A); dw on the superset B — the
    # dense gradient restricted to B's support, no dense materialization.
    dx = _dx_call(g, w, mask, bm, bn, bk, interpret, x.dtype)
    dw = _dw_call(x, g, bwd_mask, bm, bn, bk, interpret, w.dtype)
    z = lambda a: np.zeros(a.shape, jax.dtypes.float0)
    return dx, dw, z(mask), z(bwd_mask)


_topkast_masked_matmul.defvjp(_tkm_fwd, _tkm_bwd)


@functools.partial(
    jax.jit, static_argnames=("bm", "bn", "bk", "interpret")
)
def topkast_masked_matmul(
    x, w, mask, bwd_mask, *, bm: int = 128, bn: int = 128, bk: int = 128,
    interpret: bool = False,
):
    """Top-KAST masked matmul: forward ⊙ A, weight gradient ⊙ B ⊇ A.

    Same fused kernels as ``masked_matmul`` — the split is purely in which
    mask the wgrad kernel fuses.  The exploration set B\\A receives gradient
    but never contributes to forward compute; callers guarantee A ⊆ B
    (core/masks.py::mask_subset, checked at pack-build time).
    """
    M, K = x.shape
    K2, N = w.shape
    assert K == K2 and mask.shape == w.shape == bwd_mask.shape, (
        x.shape, w.shape, mask.shape, bwd_mask.shape,
    )
    bm, bn, bk = min(bm, M), min(bn, N), min(bk, K)
    assert M % bm == 0 and N % bn == 0 and K % bk == 0, (M, N, K, bm, bn, bk)
    return _topkast_masked_matmul(x, w, mask, bwd_mask, bm, bn, bk, interpret)


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6, 7))
def _topkast_grouped_masked_matmul(x, w, mask, bwd_mask, bm, bn, bk, interpret):
    return _g_fwd_call(x, w, mask, bm, bn, bk, interpret)


def _gtkm_fwd(x, w, mask, bwd_mask, bm, bn, bk, interpret):
    return _g_fwd_call(x, w, mask, bm, bn, bk, interpret), (x, w, mask, bwd_mask)


def _gtkm_bwd(bm, bn, bk, interpret, res, g):
    x, w, mask, bwd_mask = res
    dx = _g_dx_call(g, w, mask, bm, bn, bk, interpret, x.dtype)
    dw = _g_dw_call(x, g, bwd_mask, bm, bn, bk, interpret, w.dtype)
    z = lambda a: np.zeros(a.shape, jax.dtypes.float0)
    return dx, dw, z(mask), z(bwd_mask)


_topkast_grouped_masked_matmul.defvjp(_gtkm_fwd, _gtkm_bwd)


@functools.partial(
    jax.jit, static_argnames=("bm", "bn", "bk", "interpret")
)
def topkast_grouped_masked_matmul(
    x, w, mask, bwd_mask, *, bm: int = 128, bn: int = 128, bk: int = 128,
    interpret: bool = False,
):
    """Grouped Top-KAST masked matmul: per-group forward ⊙ A, wgrad ⊙ B."""
    G, M, K = x.shape
    G2, K2, N = w.shape
    assert G == G2 and K == K2 and mask.shape == w.shape == bwd_mask.shape, (
        x.shape, w.shape, mask.shape, bwd_mask.shape,
    )
    bm, bn, bk = min(bm, M), min(bn, N), min(bk, K)
    assert M % bm == 0 and N % bn == 0 and K % bk == 0, (M, N, K, bm, bn, bk)
    return _topkast_grouped_masked_matmul(
        x, w, mask, bwd_mask, bm, bn, bk, interpret
    )

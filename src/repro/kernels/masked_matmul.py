"""Fused masked matmul:  out = x @ (w * mask).

The RigL hot path executes every linear layer as (w ⊙ m) @ x.  Naively XLA
materializes the masked copy w⊙m in HBM (read w + read m + write w⊙m + read
w⊙m = 3 extra HBM passes over the weights *per step*).  This kernel fuses the
mask multiply into the matmul's VMEM pipeline: w-tile and 1-byte mask-tile are
DMA'd to VMEM, multiplied in-register, and fed straight to the MXU — the
masked weight never exists in HBM.

Tiling: grid (M/bm, N/bn, K/bk), MXU-aligned (128x128 default), fp32
accumulator scratch in VMEM, K innermost so the accumulator tile stays
resident across the contraction.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["masked_matmul"]


def _kernel(x_ref, w_ref, m_ref, o_ref, acc_ref, *, n_k: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    w = w_ref[...] * m_ref[...].astype(w_ref.dtype)
    acc_ref[...] += jnp.dot(
        x_ref[...], w, preferred_element_type=jnp.float32
    )

    @pl.when(k == n_k - 1)
    def _store():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("bm", "bn", "bk", "interpret")
)
def masked_matmul(
    x, w, mask, *, bm: int = 128, bn: int = 128, bk: int = 128, interpret: bool = False
):
    """x: (M, K); w: (K, N); mask: (K, N) bool/int8 -> (M, N) in x.dtype."""
    M, K = x.shape
    K2, N = w.shape
    assert K == K2 and mask.shape == w.shape, (x.shape, w.shape, mask.shape)
    bm, bn, bk = min(bm, M), min(bn, N), min(bk, K)
    assert M % bm == 0 and N % bn == 0 and K % bk == 0, (M, N, K, bm, bn, bk)
    n_k = K // bk
    grid = (M // bm, N // bn, n_k)
    return pl.pallas_call(
        functools.partial(_kernel, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda m, n, k: (m, k)),
            pl.BlockSpec((bk, bn), lambda m, n, k: (k, n)),
            pl.BlockSpec((bk, bn), lambda m, n, k: (k, n)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda m, n, k: (m, n)),
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, w, mask)

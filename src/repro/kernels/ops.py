"""Jitted public wrappers around the Pallas kernels.

``interpret=None`` auto-selects: compiled on TPU, interpret (python-executed
kernel bodies) elsewhere — the CPU CI validates kernel semantics against
ref.py; the BlockSpec tiling targets TPU v5e VMEM (128-aligned tiles).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from .block_sparse_matmul import block_sparse_matmul, pack_block_mask
from .masked_matmul import masked_matmul
from .topk_threshold import N_BINS, histogram_abs

__all__ = [
    "masked_linear",
    "block_sparse_linear",
    "topk_threshold",
    "auto_interpret",
]


def auto_interpret() -> bool:
    return jax.default_backend() != "tpu"


def masked_linear(x, w, mask, *, block=(128, 128, 128), interpret=None):
    """out = x @ (w*mask) with the mask fused into the matmul pipeline."""
    interpret = auto_interpret() if interpret is None else interpret
    bm, bn, bk = block
    *lead, K = x.shape
    x2 = x.reshape(-1, K)
    out = masked_matmul(x2, w, mask, bm=bm, bn=bn, bk=bk, interpret=interpret)
    return out.reshape(*lead, w.shape[1])


def block_sparse_linear(x, w, block_mask, *, block=(128, 128, 128), interpret=None):
    """out = x @ w_blocksparse, skipping inactive (bk x bn) blocks entirely."""
    interpret = auto_interpret() if interpret is None else interpret
    bm, bn, bk = block
    idx, cnt = pack_block_mask(block_mask)
    *lead, K = x.shape
    x2 = x.reshape(-1, K)
    out = block_sparse_matmul(
        x2, w, idx, cnt, bm=bm, bn=bn, bk=bk, interpret=interpret
    )
    return out.reshape(*lead, w.shape[1])


def topk_threshold(x, k: int, *, refine: bool = True, interpret=None):
    """Threshold t s.t. |{i: |x_i| >= t}| ~= k, via streaming histogram.

    One pass + optional one refinement pass over the bracketing bin;
    |count - k| <= occupancy of one (refined) bin.
    """
    interpret = auto_interpret() if interpret is None else interpret
    hi = jnp.max(jnp.abs(x)).astype(jnp.float32) + 1e-12
    hist = histogram_abs(x, hi, interpret=interpret)[0]
    # cumulative count from the TOP bin down
    desc = jnp.cumsum(hist[::-1])
    bin_from_top = jnp.argmax(desc >= k)  # first bin where count >= k
    lo_edge = (N_BINS - 1 - bin_from_top) * (hi / N_BINS)
    if not refine:
        return lo_edge
    # refinement: histogram only the bracketing bin's range
    upper = lo_edge + hi / N_BINS
    in_above = jnp.sum(jnp.abs(x.astype(jnp.float32)) >= upper)
    sub = jnp.where(
        (jnp.abs(x.astype(jnp.float32)) >= lo_edge)
        & (jnp.abs(x.astype(jnp.float32)) < upper),
        jnp.abs(x.astype(jnp.float32)) - lo_edge,
        -1.0,
    )
    hist2 = histogram_abs(
        jnp.where(sub >= 0, sub, 2 * hi), hi / N_BINS, interpret=interpret
    )[0]
    need = k - in_above
    desc2 = jnp.cumsum(hist2[::-1])
    b2 = jnp.argmax(desc2 >= need)
    return lo_edge + (N_BINS - 1 - b2) * (hi / N_BINS / N_BINS)

"""Jitted public wrappers around the Pallas kernels.

``interpret=None`` auto-selects: compiled on TPU, interpret (python-executed
kernel bodies) elsewhere — the CPU CI validates kernel semantics against
ref.py; the BlockSpec tiling targets TPU v5e VMEM (128-aligned tiles).

Both linear wrappers are fully differentiable (the underlying kernels carry
custom-VJP Pallas backward passes) and accept NON-ALIGNED leading dims: the
flattened batch*seq rows are zero-padded up to the M tile and trimmed after,
so odd shapes (e.g. decode with batch 4, or batch*seq not a 128 multiple)
dispatch without caller-side padding.  ``masked_linear`` additionally pads
K/N when they don't divide the tile; ``block_sparse_linear`` requires aligned
K/N because the block mask's grid is defined by them.

``block_sparse_linear`` accepts its topology three ways, in priority order:
a precomputed ``pack=(idx, cnt)`` (tight grid, zero per-call packing cost —
this is what PackState in the train/serve state provides, core/pack.py); a
concrete block mask (host-side numpy packing, tight max-count — eval /
one-off calls); or a traced block mask (jit-safe jnp packing with a static
worst-case count — correct anywhere, but every grid is padded to K/bk with
empty iterations).  docs/kernels.md documents the whole path end-to-end.

The ``grouped_*`` wrappers are the weight-BANK twins (leading group dim G,
one launch for all groups): MoE per-expert einsums and xLSTM per-head
recurrences dispatch through them (layers.grouped_linear), with the same
three topology sources (grouped PackState entry / concrete / traced mask).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from .block_sparse_matmul import (
    block_sparse_matmul,
    fused_block_sparse_matmul,
    fused_grouped_block_sparse_matmul,
    grouped_block_sparse_matmul,
    pack_block_mask,
    pack_block_mask_rows,
    pack_block_mask_rows_traced,
    pack_block_mask_traced,
    pack_group_mask,
    pack_group_mask_rows,
    pack_group_mask_rows_traced,
    pack_group_mask_traced,
    topkast_block_sparse_matmul,
    topkast_grouped_block_sparse_matmul,
)
from .masked_matmul import (
    fused_grouped_masked_matmul,
    fused_masked_matmul,
    grouped_masked_matmul,
    masked_matmul,
    topkast_grouped_masked_matmul,
    topkast_masked_matmul,
)
from .topk_threshold import N_BINS, histogram_abs

__all__ = [
    "masked_linear",
    "block_sparse_linear",
    "grouped_masked_linear",
    "grouped_block_sparse_linear",
    "topkast_masked_linear",
    "topkast_grouped_masked_linear",
    "fused_masked_linear",
    "fused_grouped_masked_linear",
    "fused_block_sparse_linear",
    "fused_grouped_block_sparse_linear",
    "topk_threshold",
    "auto_interpret",
]


def auto_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _round_up(n: int, mult: int) -> int:
    return ((n + mult - 1) // mult) * mult


def _row_tile(M: int, bm: int) -> tuple[int, int]:
    """(effective row tile, padded M).  Rows below one tile shrink the tile to
    the 16-padded row count (16 = bf16 sublane min) instead of padding a tiny
    batch all the way to bm."""
    bm_eff = min(bm, _round_up(M, 16))
    return bm_eff, _round_up(M, bm_eff)


def _pad_rows(x2, Mp: int):
    M = x2.shape[0]
    return x2 if Mp == M else jnp.pad(x2, ((0, Mp - M), (0, 0)))


def masked_linear(x, w, mask, *, block=(128, 128, 128), interpret=None):
    """out = x @ (w*mask) with the mask fused into the matmul pipeline.

    mask: (K, N) bool, ANY sparsity pattern (no block alignment needed) —
    the mask is applied to each weight tile inside VMEM, so the masked weight
    copy w*m is never written to (or re-read from) HBM.  Differentiable: the
    custom-VJP backward fuses the mask into dgrad (dx = g @ (w*m)T) and wgrad
    (dw = (xT @ g) * m), so cotangents off-mask are exactly zero.
    block: (bm, bn, bk) VMEM tile sizes; non-aligned M/K/N are zero-padded up
    to the (clamped) tiles and trimmed after.  interpret=None auto-selects
    compiled-on-TPU / interpret-elsewhere.
    """
    interpret = auto_interpret() if interpret is None else interpret
    bm, bn, bk = block
    *lead, K = x.shape
    N = w.shape[1]
    x2 = x.reshape(-1, K)
    M = x2.shape[0]
    bm_eff, Mp = _row_tile(M, bm)
    x2 = _pad_rows(x2, Mp)
    # pad K/N up to their (clamped) tiles; zero pad-weights contribute nothing
    Kp = _round_up(K, min(bk, K))
    Np = _round_up(N, min(bn, N))
    if Kp != K:
        x2 = jnp.pad(x2, ((0, 0), (0, Kp - K)))
    if (Kp, Np) != (K, N):
        w = jnp.pad(w, ((0, Kp - K), (0, Np - N)))
        mask = jnp.pad(mask, ((0, Kp - K), (0, Np - N)))
    out = masked_matmul(
        x2, w, mask, bm=bm_eff, bn=bn, bk=bk, interpret=interpret
    )
    return out[:M, :N].reshape(*lead, N)


def topkast_masked_linear(
    x, w, mask, bwd_mask, *, block=(128, 128, 128), interpret=None
):
    """out = x @ (w*mask), weight gradient masked by bwd_mask ⊇ mask.

    The Top-KAST split of ``masked_linear`` (docs/training.md#topkast): the
    forward and dgrad fuse the tight mask A; the wgrad kernel fuses the
    backward superset B, so dw is the dense gradient restricted to B with no
    dense matmul anywhere.  Padding/trimming identical to ``masked_linear``
    (both masks are padded with zeros, preserving A ⊆ B).
    """
    interpret = auto_interpret() if interpret is None else interpret
    bm, bn, bk = block
    *lead, K = x.shape
    N = w.shape[1]
    x2 = x.reshape(-1, K)
    M = x2.shape[0]
    bm_eff, Mp = _row_tile(M, bm)
    x2 = _pad_rows(x2, Mp)
    Kp = _round_up(K, min(bk, K))
    Np = _round_up(N, min(bn, N))
    if Kp != K:
        x2 = jnp.pad(x2, ((0, 0), (0, Kp - K)))
    if (Kp, Np) != (K, N):
        w = jnp.pad(w, ((0, Kp - K), (0, Np - N)))
        mask = jnp.pad(mask, ((0, Kp - K), (0, Np - N)))
        bwd_mask = jnp.pad(bwd_mask, ((0, Kp - K), (0, Np - N)))
    out = topkast_masked_matmul(
        x2, w, mask, bwd_mask, bm=bm_eff, bn=bn, bk=bk, interpret=interpret
    )
    return out[:M, :N].reshape(*lead, N)


def topkast_grouped_masked_linear(
    x, w, mask, bwd_mask, *, block=(128, 128, 128), interpret=None
):
    """Grouped Top-KAST masked linear: per-group forward ⊙ A, wgrad ⊙ B."""
    interpret = auto_interpret() if interpret is None else interpret
    bm, bn, bk = block
    G, M, K = x.shape
    N = w.shape[2]
    bm_eff, Mp = _row_tile(M, bm)
    if Mp != M:
        x = jnp.pad(x, ((0, 0), (0, Mp - M), (0, 0)))
    Kp = _round_up(K, min(bk, K))
    Np = _round_up(N, min(bn, N))
    if Kp != K:
        x = jnp.pad(x, ((0, 0), (0, 0), (0, Kp - K)))
    if (Kp, Np) != (K, N):
        w = jnp.pad(w, ((0, 0), (0, Kp - K), (0, Np - N)))
        mask = jnp.pad(mask, ((0, 0), (0, Kp - K), (0, Np - N)))
        bwd_mask = jnp.pad(bwd_mask, ((0, 0), (0, Kp - K), (0, Np - N)))
    out = topkast_grouped_masked_matmul(
        x, w, mask, bwd_mask, bm=bm_eff, bn=bn, bk=bk, interpret=interpret
    )
    return out[:, :M, :N]


def block_sparse_linear(
    x, w, block_mask=None, *, block=(128, 128, 128), interpret=None, pack=None
):
    """out = x @ w_blocksparse, skipping inactive (bk x bn) weight blocks.

    Exactly one topology source must be usable:

    pack: precomputed packing — a PackState entry dict (core/pack.py,
        ``{"idx", "cnt", "ridx", "rcnt", ...}``) or a bare ``(idx, cnt)``
        CSC tuple from ``pack_block_mask``.  This is the TIGHT-GRID path:
        the forward/wgrad grid's third dim is ``idx.shape[1]`` (the true max
        active-block count), not the worst case, and an entry's host-packed
        CSR (``ridx``/``rcnt``) makes the dgrad grid tight too (a bare CSC
        tuple falls back to a worst-case-width derived CSR for dgrad).
        Train/serve state carries these packs and refreshes them only on
        RigL topology updates, so the per-call cost is zero.  ``block_mask``
        is ignored.
    block_mask: (K/bk, N/bn) bool fallback when no pack is given —
        concrete (host-side numpy packing, tight width: eval/one-off calls) or
        traced (jit-safe jnp packing, STATIC worst-case width K/bk: correct
        anywhere, but pads the grid with empty iterations).

    The padded and tight paths are bit-identical: both visit the active blocks
    of each column in ascending K-block order, and padded slots neither DMA
    nor accumulate (see docs/kernels.md#tight-vs-padded-grids).

    Differentiable (custom-VJP dgrad/wgrad kernels); leading dims of ``x`` are
    flattened and zero-padded to the M tile; K and N must be tile-aligned.
    """
    interpret = auto_interpret() if interpret is None else interpret
    bm, bn, bk = block
    *lead, K = x.shape
    bk, bn = min(bk, K), min(bn, w.shape[1])
    ridx = rcnt = bidx = bcnt = None
    if pack is not None:
        if isinstance(pack, dict):
            idx, cnt = pack["idx"], pack["cnt"]
            ridx, rcnt = pack.get("ridx"), pack.get("rcnt")
            bidx, bcnt = pack.get("bidx"), pack.get("bcnt")
        else:
            idx, cnt = pack
    elif block_mask is None:
        raise ValueError(
            "block_sparse_linear needs a topology: pass block_mask= or a "
            "precomputed pack=(idx, cnt) — see docs/kernels.md#packing"
        )
    elif isinstance(block_mask, jax.core.Tracer):
        idx, cnt = pack_block_mask_traced(block_mask)
        ridx, rcnt = pack_block_mask_rows_traced(block_mask)
    else:
        idx, cnt = pack_block_mask(np.asarray(block_mask))
        ridx, rcnt = pack_block_mask_rows(np.asarray(block_mask))
    x2 = x.reshape(-1, K)
    M = x2.shape[0]
    bm_eff, Mp = _row_tile(M, bm)
    x2 = _pad_rows(x2, Mp)
    if bidx is not None:
        # Top-KAST superset pack: wgrad runs on the wider (k+Δ) CSC view.
        out = topkast_block_sparse_matmul(
            x2, w, idx, cnt, bidx, bcnt, ridx, rcnt,
            bm=bm_eff, bn=bn, bk=bk, interpret=interpret,
        )
    else:
        out = block_sparse_matmul(
            x2, w, idx, cnt, ridx, rcnt, bm=bm_eff, bn=bn, bk=bk,
            interpret=interpret,
        )
    return out[:M].reshape(*lead, w.shape[1])


def grouped_masked_linear(x, w, mask, *, block=(128, 128, 128), interpret=None):
    """out[g] = x[g] @ (w[g]*mask[g]) for every group g, ONE kernel launch.

    x: (G, M, K); w, mask: (G, K, N) -> (G, M, N).  The grouped twin of
    ``masked_linear`` for weight BANKS — MoE per-expert ``ecd,edf->ecf``
    einsums (G = experts) and xLSTM per-head ``bnh,nhk->bnk`` recurrences
    (G = heads, after layers.grouped_linear's reshape shim).  Any mask
    pattern; per-group w*m only ever exists tile-wise in VMEM.
    Differentiable (grouped custom-VJP dgrad/wgrad kernels); M is padded to
    the (clamped) row tile and K/N to their tiles, exactly like
    ``masked_linear``.
    """
    interpret = auto_interpret() if interpret is None else interpret
    bm, bn, bk = block
    G, M, K = x.shape
    N = w.shape[2]
    bm_eff, Mp = _row_tile(M, bm)
    if Mp != M:
        x = jnp.pad(x, ((0, 0), (0, Mp - M), (0, 0)))
    Kp = _round_up(K, min(bk, K))
    Np = _round_up(N, min(bn, N))
    if Kp != K:
        x = jnp.pad(x, ((0, 0), (0, 0), (0, Kp - K)))
    if (Kp, Np) != (K, N):
        w = jnp.pad(w, ((0, 0), (0, Kp - K), (0, Np - N)))
        mask = jnp.pad(mask, ((0, 0), (0, Kp - K), (0, Np - N)))
    out = grouped_masked_matmul(
        x, w, mask, bm=bm_eff, bn=bn, bk=bk, interpret=interpret
    )
    return out[:, :M, :N]


def grouped_block_sparse_linear(
    x, w, block_mask=None, *, block=(128, 128, 128), interpret=None, pack=None
):
    """out[g] = x[g] @ w_blocksparse[g], one launch over the whole bank.

    x: (G, M, K); w: (G, K, N) -> (G, M, N).  Topology sources mirror
    ``block_sparse_linear``, stacked over the group dim:

    pack: a grouped PackState entry (core/pack.py — ``idx (G, N/bn, width)``
        etc., per-expert CSC + CSR at one shared width) or a bare stacked
        ``(idx, cnt)`` tuple from ``pack_group_mask``.  Tight grids, zero
        per-call packing cost — the hot path.
    block_mask: (G, K/bk, N/bn) bool fallback — concrete (host numpy pack,
        tight shared width) or traced (jit-safe, worst-case width K/bk).

    A group with zero active blocks outputs zeros (a dead expert behaves like
    an empty column — docs/kernels.md#empty-columns-and-dead-layers).
    Differentiable; M is padded to the row tile; K and N must be
    tile-aligned.
    """
    interpret = auto_interpret() if interpret is None else interpret
    bm, bn, bk = block
    G, M, K = x.shape
    N = w.shape[2]
    bk, bn = min(bk, K), min(bn, N)
    ridx = rcnt = bidx = bcnt = None
    if pack is not None:
        if isinstance(pack, dict):
            idx, cnt = pack["idx"], pack["cnt"]
            ridx, rcnt = pack.get("ridx"), pack.get("rcnt")
            bidx, bcnt = pack.get("bidx"), pack.get("bcnt")
        else:
            idx, cnt = pack
    elif block_mask is None:
        raise ValueError(
            "grouped_block_sparse_linear needs a topology: pass block_mask= "
            "or a precomputed stacked pack=(idx, cnt) — see "
            "docs/kernels.md#packing"
        )
    elif isinstance(block_mask, jax.core.Tracer):
        idx, cnt = pack_group_mask_traced(block_mask)
        ridx, rcnt = pack_group_mask_rows_traced(block_mask)
    else:
        idx, cnt = pack_group_mask(np.asarray(block_mask))
        ridx, rcnt = pack_group_mask_rows(np.asarray(block_mask))
    bm_eff, Mp = _row_tile(M, bm)
    if Mp != M:
        x = jnp.pad(x, ((0, 0), (0, Mp - M), (0, 0)))
    if bidx is not None:
        out = topkast_grouped_block_sparse_matmul(
            x, w, idx, cnt, bidx, bcnt, ridx, rcnt,
            bm=bm_eff, bn=bn, bk=bk, interpret=interpret,
        )
    else:
        out = grouped_block_sparse_matmul(
            x, w, idx, cnt, ridx, rcnt, bm=bm_eff, bn=bn, bk=bk,
            interpret=interpret,
        )
    return out[:, :M]


def fused_masked_linear(
    x, w, mask, mom, seed, *, mu, wd, sr, bwd_mask=None,
    block=(128, 128, 128), interpret=None,
):
    """``masked_linear`` whose weight cotangent is the new SGD momentum.

    The fused-epilogue hot path (docs/kernels.md#fused-epilogue): identical
    forward/dgrad to ``masked_linear``/``topkast_masked_linear``, but the
    wgrad kernel stores m_new = (mu*mom + xᵀg + wd*w) ⊙ wgrad_mask, where
    wgrad_mask is ``bwd_mask`` (Top-KAST superset B) when given, else
    ``mask``.  mom rides the same pad/trim as w (zero-padded; the pad VJP
    trims the cotangent back to (K, N)).  sr=True stochastically rounds the
    emitted momentum onto the bf16 grid in-kernel.
    """
    interpret = auto_interpret() if interpret is None else interpret
    bm, bn, bk = block
    *lead, K = x.shape
    N = w.shape[1]
    wgm = mask if bwd_mask is None else bwd_mask
    x2 = x.reshape(-1, K)
    M = x2.shape[0]
    bm_eff, Mp = _row_tile(M, bm)
    x2 = _pad_rows(x2, Mp)
    Kp = _round_up(K, min(bk, K))
    Np = _round_up(N, min(bn, N))
    if Kp != K:
        x2 = jnp.pad(x2, ((0, 0), (0, Kp - K)))
    if (Kp, Np) != (K, N):
        pad2 = lambda a: jnp.pad(a, ((0, Kp - K), (0, Np - N)))
        w, mask, wgm, mom = pad2(w), pad2(mask), pad2(wgm), pad2(mom)
    out = fused_masked_matmul(
        x2, w, mask, wgm, mom, seed, mu=mu, wd=wd, sr=sr,
        bm=bm_eff, bn=bn, bk=bk, interpret=interpret,
    )
    return out[:M, :N].reshape(*lead, N)


def fused_grouped_masked_linear(
    x, w, mask, mom, seed, *, mu, wd, sr, bwd_mask=None,
    block=(128, 128, 128), interpret=None,
):
    """Grouped ``fused_masked_linear`` (weight banks, one launch)."""
    interpret = auto_interpret() if interpret is None else interpret
    bm, bn, bk = block
    G, M, K = x.shape
    N = w.shape[2]
    wgm = mask if bwd_mask is None else bwd_mask
    bm_eff, Mp = _row_tile(M, bm)
    if Mp != M:
        x = jnp.pad(x, ((0, 0), (0, Mp - M), (0, 0)))
    Kp = _round_up(K, min(bk, K))
    Np = _round_up(N, min(bn, N))
    if Kp != K:
        x = jnp.pad(x, ((0, 0), (0, 0), (0, Kp - K)))
    if (Kp, Np) != (K, N):
        pad3 = lambda a: jnp.pad(a, ((0, 0), (0, Kp - K), (0, Np - N)))
        w, mask, wgm, mom = pad3(w), pad3(mask), pad3(wgm), pad3(mom)
    out = fused_grouped_masked_matmul(
        x, w, mask, wgm, mom, seed, mu=mu, wd=wd, sr=sr,
        bm=bm_eff, bn=bn, bk=bk, interpret=interpret,
    )
    return out[:, :M, :N]


def fused_block_sparse_linear(
    x, w, mom, seed, *, mu, wd, sr, block=(128, 128, 128), interpret=None,
    pack=None, block_mask=None,
):
    """``block_sparse_linear`` whose weight cotangent is the new SGD momentum.

    Topology sources mirror ``block_sparse_linear`` (PackState entry dict /
    bare (idx, cnt) / block_mask); an entry carrying ``bidx``/``bcnt`` runs
    the wgrad-epilogue on the Top-KAST superset B, exactly like the unfused
    topkast route.  mom: dense-laid-out (K, N) momentum (supported on the
    wgrad topology); K/N must be tile-aligned.
    """
    interpret = auto_interpret() if interpret is None else interpret
    bm, bn, bk = block
    *lead, K = x.shape
    bk, bn = min(bk, K), min(bn, w.shape[1])
    ridx = rcnt = bidx = bcnt = None
    if pack is not None:
        if isinstance(pack, dict):
            idx, cnt = pack["idx"], pack["cnt"]
            ridx, rcnt = pack.get("ridx"), pack.get("rcnt")
            bidx, bcnt = pack.get("bidx"), pack.get("bcnt")
        else:
            idx, cnt = pack
    elif block_mask is None:
        raise ValueError(
            "fused_block_sparse_linear needs a topology: pass block_mask= or "
            "a precomputed pack=(idx, cnt) — see docs/kernels.md#packing"
        )
    elif isinstance(block_mask, jax.core.Tracer):
        idx, cnt = pack_block_mask_traced(block_mask)
        ridx, rcnt = pack_block_mask_rows_traced(block_mask)
    else:
        idx, cnt = pack_block_mask(np.asarray(block_mask))
        ridx, rcnt = pack_block_mask_rows(np.asarray(block_mask))
    x2 = x.reshape(-1, K)
    M = x2.shape[0]
    bm_eff, Mp = _row_tile(M, bm)
    x2 = _pad_rows(x2, Mp)
    out = fused_block_sparse_matmul(
        x2, w, idx, cnt, mom, seed, bwd_idx=bidx, bwd_cnt=bcnt,
        row_idx=ridx, row_cnt=rcnt, mu=mu, wd=wd, sr=sr,
        bm=bm_eff, bn=bn, bk=bk, interpret=interpret,
    )
    return out[:M].reshape(*lead, w.shape[1])


def fused_grouped_block_sparse_linear(
    x, w, mom, seed, *, mu, wd, sr, block=(128, 128, 128), interpret=None,
    pack=None, block_mask=None,
):
    """Grouped ``fused_block_sparse_linear`` (MoE banks / xLSTM heads)."""
    interpret = auto_interpret() if interpret is None else interpret
    bm, bn, bk = block
    G, M, K = x.shape
    N = w.shape[2]
    bk, bn = min(bk, K), min(bn, N)
    ridx = rcnt = bidx = bcnt = None
    if pack is not None:
        if isinstance(pack, dict):
            idx, cnt = pack["idx"], pack["cnt"]
            ridx, rcnt = pack.get("ridx"), pack.get("rcnt")
            bidx, bcnt = pack.get("bidx"), pack.get("bcnt")
        else:
            idx, cnt = pack
    elif block_mask is None:
        raise ValueError(
            "fused_grouped_block_sparse_linear needs a topology: pass "
            "block_mask= or a precomputed stacked pack=(idx, cnt) — see "
            "docs/kernels.md#packing"
        )
    elif isinstance(block_mask, jax.core.Tracer):
        idx, cnt = pack_group_mask_traced(block_mask)
        ridx, rcnt = pack_group_mask_rows_traced(block_mask)
    else:
        idx, cnt = pack_group_mask(np.asarray(block_mask))
        ridx, rcnt = pack_group_mask_rows(np.asarray(block_mask))
    bm_eff, Mp = _row_tile(M, bm)
    if Mp != M:
        x = jnp.pad(x, ((0, 0), (0, Mp - M), (0, 0)))
    out = fused_grouped_block_sparse_matmul(
        x, w, idx, cnt, mom, seed, bwd_idx=bidx, bwd_cnt=bcnt,
        row_idx=ridx, row_cnt=rcnt, mu=mu, wd=wd, sr=sr,
        bm=bm_eff, bn=bn, bk=bk, interpret=interpret,
    )
    return out[:, :M]


def topk_threshold(x, k: int, *, refine: bool = True, interpret=None):
    """Threshold t s.t. |{i: |x_i| >= t}| ~= k, via streaming histogram.

    One pass + optional one refinement pass over the bracketing bin;
    |count - k| <= occupancy of one (refined) bin.
    """
    interpret = auto_interpret() if interpret is None else interpret
    hi = jnp.max(jnp.abs(x)).astype(jnp.float32) + 1e-12
    hist = histogram_abs(x, hi, interpret=interpret)[0]
    # cumulative count from the TOP bin down
    desc = jnp.cumsum(hist[::-1])
    bin_from_top = jnp.argmax(desc >= k)  # first bin where count >= k
    lo_edge = (N_BINS - 1 - bin_from_top) * (hi / N_BINS)
    if not refine:
        return lo_edge
    # refinement: histogram only the bracketing bin's range
    upper = lo_edge + hi / N_BINS
    in_above = jnp.sum(jnp.abs(x.astype(jnp.float32)) >= upper)
    sub = jnp.where(
        (jnp.abs(x.astype(jnp.float32)) >= lo_edge)
        & (jnp.abs(x.astype(jnp.float32)) < upper),
        jnp.abs(x.astype(jnp.float32)) - lo_edge,
        -1.0,
    )
    hist2 = histogram_abs(
        jnp.where(sub >= 0, sub, 2 * hi), hi / N_BINS, interpret=interpret
    )[0]
    need = k - in_above
    desc2 = jnp.cumsum(hist2[::-1])
    b2 = jnp.argmax(desc2 >= need)
    return lo_edge + (N_BINS - 1 - b2) * (hi / N_BINS / N_BINS)

"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "masked_matmul_ref",
    "block_sparse_matmul_ref",
    "grouped_masked_matmul_ref",
    "grouped_block_sparse_matmul_ref",
    "histogram_abs_ref",
    "kth_value_ref",
]


def masked_matmul_ref(x, w, mask):
    return (x @ (w * mask.astype(w.dtype))).astype(x.dtype)


def block_sparse_matmul_ref(x, w, block_mask, bk: int, bn: int):
    """block_mask: (K/bk, N/bn) bool expanded over (bk, bn) tiles."""
    K, N = w.shape
    dense_mask = jnp.repeat(jnp.repeat(block_mask, bk, axis=0), bn, axis=1)
    return (x @ (w * dense_mask.astype(w.dtype))).astype(x.dtype)


def grouped_masked_matmul_ref(x, w, mask):
    """x: (G, M, K); w, mask: (G, K, N) — per-group fused-mask matmul."""
    return jnp.einsum(
        "gmk,gkn->gmn", x, w * mask.astype(w.dtype)
    ).astype(x.dtype)


def grouped_block_sparse_matmul_ref(x, w, block_mask, bk: int, bn: int):
    """block_mask: (G, K/bk, N/bn) bool expanded over (bk, bn) tiles."""
    dense_mask = jnp.repeat(jnp.repeat(block_mask, bk, axis=1), bn, axis=2)
    return jnp.einsum(
        "gmk,gkn->gmn", x, w * dense_mask.astype(w.dtype)
    ).astype(x.dtype)


def histogram_abs_ref(x, hi, n_bins: int = 512):
    a = jnp.abs(x.reshape(-1).astype(jnp.float32))
    scaled = jnp.clip(a / hi, 0.0, 1.0 - 1e-7) * n_bins
    return jnp.histogram(scaled, bins=n_bins, range=(0, n_bins))[0].astype(
        jnp.float32
    )[None, :]


def kth_value_ref(x, k: int):
    """Exact k-th largest |x| (the threshold RigL's drop step needs)."""
    a = jnp.sort(jnp.abs(x.reshape(-1).astype(jnp.float32)))[::-1]
    return a[k - 1]


def flash_attention_ref(q, k, v, causal: bool = True):
    """(BH, S, d) standard softmax attention oracle."""
    import numpy as _np

    d = q.shape[-1]
    s = jnp.einsum("bqd,bkd->bqk", q, k, preferred_element_type=jnp.float32)
    s = s / _np.sqrt(d)
    if causal:
        S = q.shape[1]
        mask = jnp.tril(jnp.ones((S, S), bool))
        s = jnp.where(mask, s, -1e30)
    w = jax.nn.softmax(s, axis=-1).astype(v.dtype)
    return jnp.einsum("bqk,bkd->bqd", w, v)

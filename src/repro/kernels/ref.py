"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "masked_matmul_ref",
    "block_sparse_matmul_ref",
    "grouped_masked_matmul_ref",
    "grouped_block_sparse_matmul_ref",
    "histogram_abs_ref",
    "kth_value_ref",
    "flash_attention_ref",
]


def masked_matmul_ref(x, w, mask):
    return (x @ (w * mask.astype(w.dtype))).astype(x.dtype)


def block_sparse_matmul_ref(x, w, block_mask, bk: int, bn: int):
    """block_mask: (K/bk, N/bn) bool expanded over (bk, bn) tiles."""
    K, N = w.shape
    dense_mask = jnp.repeat(jnp.repeat(block_mask, bk, axis=0), bn, axis=1)
    return (x @ (w * dense_mask.astype(w.dtype))).astype(x.dtype)


def grouped_masked_matmul_ref(x, w, mask):
    """x: (G, M, K); w, mask: (G, K, N) — per-group fused-mask matmul."""
    return jnp.einsum(
        "gmk,gkn->gmn", x, w * mask.astype(w.dtype)
    ).astype(x.dtype)


def grouped_block_sparse_matmul_ref(x, w, block_mask, bk: int, bn: int):
    """block_mask: (G, K/bk, N/bn) bool expanded over (bk, bn) tiles."""
    dense_mask = jnp.repeat(jnp.repeat(block_mask, bk, axis=1), bn, axis=2)
    return jnp.einsum(
        "gmk,gkn->gmn", x, w * dense_mask.astype(w.dtype)
    ).astype(x.dtype)


def histogram_abs_ref(x, hi, n_bins: int = 512):
    a = jnp.abs(x.reshape(-1).astype(jnp.float32))
    scaled = jnp.clip(a / hi, 0.0, 1.0 - 1e-7) * n_bins
    return jnp.histogram(scaled, bins=n_bins, range=(0, n_bins))[0].astype(
        jnp.float32
    )[None, :]


def kth_value_ref(x, k: int):
    """Exact k-th largest |x| (the threshold RigL's drop step needs)."""
    a = jnp.sort(jnp.abs(x.reshape(-1).astype(jnp.float32)))[::-1]
    return a[k - 1]


def flash_attention_ref(q, k, v, causal: bool = True, window: int = 0,
                        q_offset=None, softcap: float = 0.0):
    """q: (BH, Sq, d); k, v: (BH, Sk, d) softmax-attention oracle.

    Mask semantics match models/attention.py::_make_mask and the Pallas
    kernel: query row r sits at absolute position ``q_offset + r`` (default
    ``Sk - Sq`` — right-aligned, 0 when Sq == Sk), keys at their column
    index; causal keeps ``kpos <= qpos``, window keeps ``kpos > qpos -
    window``.  Rows with NO live key are zeroed (the kernel's convention)
    rather than left as the uniform-softmax artifact of the -1e30 clamp.
    ``softcap`` caps the scaled scores c*tanh(s/c) BEFORE masking, matching
    the kernels and models/attention.py::_scores.
    """
    sq, sk = q.shape[1], k.shape[1]
    if q_offset is None:
        q_offset = sk - sq
    d = q.shape[-1]
    s = jnp.einsum("bqd,bkd->bqk", q, k, preferred_element_type=jnp.float32)
    s = s / np.sqrt(d)
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    qpos = q_offset + jnp.arange(sq)[:, None]
    kpos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, -1e30)
    w = jax.nn.softmax(s, axis=-1).astype(v.dtype)
    w = jnp.where(mask.any(axis=-1, keepdims=True), w, 0).astype(v.dtype)
    return jnp.einsum("bqk,bkd->bqd", w, v)

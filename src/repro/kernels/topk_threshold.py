"""Histogram k-th-value selection for RigL drop/grow on huge layers.

Exact top-k on a 10^8-element weight tensor needs a full sort (O(N log N),
multiple HBM passes).  RigL only needs a *threshold* separating the top k
magnitudes — this kernel computes a 512-bin histogram of |x| in ONE streaming
HBM pass (grid over tiles, accumulating into a VMEM histogram via the
revisited-output pattern); the k-th-value bracket then falls out of a tiny
cumsum on host/XLA.  Paper §3(4): "gradients can be calculated in an online
manner and only the top-k values stored" — this is that, TPU-style.

The returned threshold is exact up to one bin width; callers either accept
|selected| within (k ± bin occupancy) — RigL is robust to that — or refine
with a second pass over the bracketing bin (kernels.ops.topk_threshold does
one refinement).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["histogram_abs"]

N_BINS = 512


def _kernel(x_ref, lim_ref, hist_ref, *, n_tiles: int):
    t = pl.program_id(0)

    @pl.when(t == 0)
    def _init():
        hist_ref[...] = jnp.zeros_like(hist_ref)

    x = jnp.abs(x_ref[...].astype(jnp.float32)).reshape(-1)
    hi = lim_ref[0, 0]
    scaled = jnp.clip(x / hi, 0.0, 1.0 - 1e-7) * N_BINS
    bins = scaled.astype(jnp.int32)
    # one-hot accumulate: (tile, N_BINS) matmul-free histogram
    onehot = (bins[:, None] == jnp.arange(N_BINS)[None, :]).astype(jnp.float32)
    hist_ref[...] += jnp.sum(onehot, axis=0, keepdims=True)


@functools.partial(jax.jit, static_argnames=("tile", "interpret"))
def histogram_abs(x, hi, *, tile: int = 65536, interpret: bool = False):
    """x: any shape; hi: scalar upper bound (e.g. max|x|).

    Returns (1, N_BINS) float32 histogram of |x| over [0, hi).
    """
    flat = x.reshape(-1)
    n = flat.shape[0]
    tile = min(tile, n)
    pad = (-n) % tile
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
    n_tiles = flat.shape[0] // tile
    lim = jnp.asarray(hi, jnp.float32).reshape(1, 1)
    hist = pl.pallas_call(
        functools.partial(_kernel, n_tiles=n_tiles),
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec((1, tile), lambda t: (0, t)),
            pl.BlockSpec((1, 1), lambda t: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, N_BINS), lambda t: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, N_BINS), jnp.float32),
        interpret=interpret,
    )(flat.reshape(1, -1), lim)
    if pad:  # remove the padding zeros from bin 0
        hist = hist.at[0, 0].add(-float(pad))
    return hist

from .mesh import make_local_mesh, make_production_mesh  # noqa: F401
from .sharding import batch_shardings, param_shardings, resolve_spec  # noqa: F401

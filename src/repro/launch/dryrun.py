import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run entry point (sets 512 host devices BEFORE any jax import).

  PYTHONPATH=src python -m repro.launch.dryrun --arch h2o-danube-1.8b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both

Lowers + compiles every (architecture x input-shape) cell on the production
meshes (16x16 single-pod, 2x16x16 multi-pod), printing memory_analysis() and
cost_analysis(), and writes roofline artifacts to artifacts/dryrun/.
"""
import argparse  # noqa: E402
import json  # noqa: E402
import sys  # noqa: E402
import traceback  # noqa: E402

from ..configs import ARCH_IDS, SHAPES, SKIPS  # noqa: E402
from .mesh import make_production_mesh  # noqa: E402
from . import dryrun_lib  # noqa: E402


def run_one(arch, shape, mesh, mesh_name, args):
    skip = SKIPS.get((arch, shape))
    if skip:
        print(f"[dryrun] SKIP {arch} x {shape}: {skip}")
        dryrun_lib.run_cell(arch, shape, mesh, tag=args.tag)
        return True
    try:
        art = dryrun_lib.run_cell(
            arch,
            shape,
            mesh,
            full_depth=not args.no_full_depth,
            proof_only=args.proof_only,
            tag=args.tag,
        )
        rl = art["roofline"]
        mem = art.get("memory", {})
        model_gib = mem.get("model", {}).get("total", 0) / 2**30
        print(
            f"[dryrun] OK {arch} x {shape} x {mesh_name}: "
            f"compute {rl['compute_s']:.3e}s memory {rl['memory_s']:.3e}s "
            f"collective {rl['collective_s']:.3e}s dominant={rl['dominant']} "
            f"hbm-model {model_gib:.2f} GiB/device fits16G={mem.get('fits_16g_hbm')} "
            f"(wall {art['wall_s']:.0f}s)"
        )
        return True
    except Exception:
        print(f"[dryrun] FAIL {arch} x {shape} x {mesh_name}")
        traceback.print_exc()
        return False


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default=None)
    p.add_argument("--shape", default=None)
    p.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    p.add_argument("--all", action="store_true")
    p.add_argument("--no-full-depth", action="store_true",
                   help="skip the full-depth memory-proof compile (cost terms only)")
    p.add_argument("--proof-only", action="store_true",
                   help="full-depth compile proof only (no roofline lowerings)")
    p.add_argument("--tag", default="")
    args = p.parse_args()

    archs = ARCH_IDS if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    ok = True
    for multi in meshes:
        mesh = make_production_mesh(multi_pod=multi)
        name = "2x16x16" if multi else "16x16"
        for arch in archs:
            for shape in shapes:
                ok &= run_one(arch, shape, mesh, name, args)
    sys.exit(0 if ok else 1)


if __name__ == "__main__":
    main()

"""Dry-run machinery: lower + compile every (arch x shape x mesh) cell and
extract memory / cost / collective statistics for the roofline analysis.

No jax device state is touched at import time — launch/dryrun.py (the CLI
entry) sets XLA_FLAGS for 512 host devices before importing anything.

Methodology (DESIGN.md §8): XLA cost_analysis counts lax.scan bodies once and
is reported per-device, so per-layer costs come from *unrolled* depth-(1,2)
lowerings per layer-kind (exact for python-loop models), extrapolated
linearly: total = base + sum_k count_k * delta_k.  The full-depth compile
provides the memory proof + shardability guarantee for every cell.
"""
from __future__ import annotations

import dataclasses
import json
import pathlib
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from ..analysis.hlo import collective_bytes
from ..analysis.roofline import roofline_terms
from ..configs import SHAPES, SKIPS, get_config
from ..core import tree_paths
from ..models import init_caches, init_lm, lm_decode, lm_prefill
from ..optim import LRSchedule, OptConfig
from ..training import init_train_state, make_train_step, make_rigl_step, make_algo, sparsity_map
from .mesh import dp_axes
from .sharding import batch_shardings, cache_axes, param_shardings, state_shardings

__all__ = ["input_specs", "run_cell", "layer_kind_counts"]

ARTIFACTS = pathlib.Path("artifacts/dryrun")


# ---------------------------------------------------------------------------
# abstract inputs
# ---------------------------------------------------------------------------

def input_specs(cfg, shape) -> dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input of a shape cell."""
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    sds = jax.ShapeDtypeStruct
    if cfg.frontend == "frames":
        b = {"frames": sds((B, S, cfg.frontend_dim), jnp.bfloat16)}
        if shape.kind == "train":
            b["targets"] = sds((B, S), i32)
        return b
    s_text = S - (cfg.n_patches if cfg.frontend == "patch" else 0)
    b = {"tokens": sds((B, s_text), i32)}
    if cfg.frontend == "patch":
        b["patches"] = sds((B, cfg.n_patches, cfg.frontend_dim), jnp.bfloat16)
    if shape.kind == "train":
        b["targets"] = sds((B, s_text), i32)
    return b


def _probe_cfg(cfg):
    """Same structure, tiny dims — for extracting the logical-axes tree."""
    return dataclasses.replace(
        cfg,
        d_model=cfg.n_heads * 4,
        head_dim=4,
        d_ff=8 if cfg.d_ff else 0,
        moe_d_ff=8 if cfg.moe_d_ff else 0,
        vocab_size=64,
        frontend_dim=8 if cfg.frontend_dim else 0,
        ssm_d_inner=16 if cfg.ssm_d_inner else 0,
        ssm_state=2 if cfg.ssm_state else 0,
        remat=False,
    )


def get_axes(cfg):
    _, axes, flags = init_lm(jax.random.PRNGKey(0), _probe_cfg(cfg))
    return axes, flags


def abstract_state(cfg, opt_cfg: OptConfig):
    key = jax.random.PRNGKey(0)
    return jax.eval_shape(lambda: init_train_state(key, cfg, opt_cfg)[0])


def active_param_count(cfg, state_abs) -> dict[str, float]:
    """Exact N_total / N_active (per-token) from shapes + the sparsity map."""
    params = state_abs["params"]
    flat = tree_paths(params)
    _, flags = get_axes(cfg)
    flat_flags = tree_paths(flags)
    smap = sparsity_map(cfg, params, flags) if cfg.sparse.sparsity else {}
    total = active = everything = sparsifiable = 0.0
    for name, leaf in flat.items():
        size = float(np.prod(leaf.shape))
        everything += size
        if flat_flags.get(name):
            sparsifiable += size
        if name == "embed/table":
            continue  # lookup, not matmul (6ND convention)
        nnz = size * (1.0 - smap.get(name, 0.0))
        frac = 1.0
        if "/moe/" in name and ("wi/" in name or "wg/" in name or "wo/" in name) and "shared" not in name:
            frac = cfg.top_k / cfg.n_experts  # routed experts: top_k of E active
        total += size
        active += nnz * frac
    if cfg.tie_embeddings and cfg.frontend != "frames":
        d = cfg.d_model
        total += d * cfg.vocab_size
        active += d * cfg.vocab_size
    return {
        "total": total,
        "active": active,
        "all_leaves": everything,
        "sparsifiable": sparsifiable,
    }


# ---------------------------------------------------------------------------
# layer-kind decomposition for cost extrapolation
# ---------------------------------------------------------------------------

def layer_kind_counts(cfg) -> dict[str, int]:
    counts: dict[str, int] = {}
    for i in range(cfg.n_layers):
        if cfg.block_type == "xlstm":
            k = "slstm" if cfg.is_slstm(i) else "mlstm"
        else:
            k = cfg.layer_kind(i)
        counts[k] = counts.get(k, 0) + 1
    return counts


def _kind_cfg(cfg, kind: str, n_layers: int):
    """A config with n_layers layers, all of the given kind."""
    kw: dict[str, Any] = {"n_layers": n_layers}
    if cfg.block_type == "xlstm":
        kw["slstm_every"] = 1 if kind == "slstm" else 0
    else:
        kw["attn_pattern"] = (kind,)
        kw["global_layer_ids"] = ()
    return dataclasses.replace(cfg, **kw)


# ---------------------------------------------------------------------------
# step builders per shape kind
# ---------------------------------------------------------------------------

def _train_setup(cfg, shape, mesh, opt_cfg):
    state_abs = abstract_state(cfg, opt_cfg)
    axes, _ = get_axes(cfg)
    st_sh = state_shardings(state_abs, axes, mesh, fsdp=cfg.fsdp)
    batch_abs = input_specs(cfg, shape)
    b_sh = batch_shardings(batch_abs, mesh)
    lr = LRSchedule(base_lr=0.1, warmup_steps=100, total_steps=32000)
    step = make_train_step(cfg, opt_cfg, lr)
    jitted = jax.jit(step, in_shardings=(st_sh, b_sh), donate_argnums=0)
    return jitted, (state_abs, batch_abs)


def _rigl_setup(cfg, shape, mesh, opt_cfg):
    """The every-delta_t connectivity-update step (drop/grow incl. ranking)."""
    state_abs = abstract_state(cfg, opt_cfg)
    axes, _ = get_axes(cfg)
    st_sh = state_shardings(state_abs, axes, mesh, fsdp=cfg.fsdp)
    batch_abs = input_specs(cfg, shape)
    b_sh = batch_shardings(batch_abs, mesh)
    lr = LRSchedule(base_lr=0.1, warmup_steps=100, total_steps=32000)
    algo = make_algo(cfg, 32000)
    step = make_rigl_step(cfg, algo, lr)
    jitted = jax.jit(step, in_shardings=(st_sh, b_sh), donate_argnums=0)
    return jitted, (state_abs, batch_abs)


def _decode_setup(cfg, shape, mesh, opt_cfg):
    params_abs = jax.eval_shape(
        lambda: init_lm(jax.random.PRNGKey(0), cfg)[0]
    )
    axes, _ = get_axes(cfg)
    p_shapes = jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params_abs
    )
    p_sh = param_shardings(axes, p_shapes, mesh, fsdp=cfg.fsdp)
    B, S = shape.global_batch, shape.seq_len
    caches_abs = jax.eval_shape(lambda: init_caches(cfg, B, S))
    c_axes = cache_axes(cfg)
    c_sh = param_shardings(c_axes, caches_abs, mesh, fsdp=False)
    tok_abs = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    tok_sh = batch_shardings(tok_abs, mesh)
    pos_abs = jax.ShapeDtypeStruct((), jnp.int32)
    from jax.sharding import NamedSharding, PartitionSpec

    rep = NamedSharding(mesh, PartitionSpec())

    def serve_step(params, caches, tok, pos):
        return lm_decode(params, cfg, caches, tok, pos)

    jitted = jax.jit(
        serve_step, in_shardings=(p_sh, c_sh, tok_sh, rep), donate_argnums=1
    )
    return jitted, (params_abs, caches_abs, tok_abs, pos_abs)


def _prefill_setup(cfg, shape, mesh, opt_cfg):
    params_abs = jax.eval_shape(lambda: init_lm(jax.random.PRNGKey(0), cfg)[0])
    axes, _ = get_axes(cfg)
    p_shapes = jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params_abs
    )
    p_sh = param_shardings(axes, p_shapes, mesh, fsdp=cfg.fsdp)
    batch_abs = input_specs(cfg, shape)
    b_sh = batch_shardings(batch_abs, mesh)

    if cfg.causal:
        def prefill_step(params, batch):
            return lm_prefill(params, cfg, batch, max_len=shape.seq_len)
    else:
        # encoder-only (hubert): "prefill" = full bidirectional inference
        from ..models import lm_forward
        from ..models.model import _logits

        def prefill_step(params, batch):
            h, _, _ = lm_forward(params, cfg, batch)
            return _logits(params, cfg, h)

    jitted = jax.jit(prefill_step, in_shardings=(p_sh, b_sh))
    return jitted, (params_abs, batch_abs)


_SETUPS = {
    "train": _train_setup,
    "decode": _decode_setup,
    "prefill": _prefill_setup,
    "rigl_update": _rigl_setup,
}


def _lower_cost(cfg, shape, mesh, opt_cfg, kind: str | None = None):
    """(flops, bytes, coll_bytes) per device for this exact cfg."""
    setup = _SETUPS[kind or shape.kind]
    jitted, abstract = setup(cfg, shape, mesh, opt_cfg)
    # ambient mesh for in-model SP constraints; jax<0.5 has no set_mesh but
    # Mesh itself is a context manager with the same effect there
    ambient = jax.set_mesh(mesh) if hasattr(jax, "set_mesh") else mesh
    with ambient:
        lowered = jitted.lower(*abstract)
        compiled = lowered.compile()
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):  # jax<0.5: list of per-device dicts
        ca = ca[0] if ca else {}
    coll = collective_bytes(compiled.as_text())
    return {
        "flops": float(ca.get("flops", 0.0)),
        "bytes": float(ca.get("bytes accessed", 0.0)),
        "coll": coll,
        "compiled": compiled,
    }


# ---------------------------------------------------------------------------
# the cell runner
# ---------------------------------------------------------------------------

def run_cell(
    arch: str,
    shape_name: str,
    mesh,
    *,
    opt_cfg: OptConfig | None = None,
    full_depth: bool = True,
    proof_only: bool = False,
    cfg_overrides: dict | None = None,
    save: bool = True,
    tag: str = "",
    step_kind: str | None = None,  # e.g. "rigl_update" on a train shape
) -> dict:
    shape = SHAPES[shape_name]
    skip = SKIPS.get((arch, shape_name))
    if skip:
        art = {"arch": arch, "shape": shape_name, "skipped": skip}
        if save:
            desc = "x".join(f"{k}{v}" for k, v in mesh.shape.items())
            _save(art, arch, shape_name, desc, tag)
        return art

    cfg = get_config(arch)
    if cfg_overrides:
        cfg = dataclasses.replace(cfg, **cfg_overrides)
    opt_cfg = opt_cfg or OptConfig(
        kind="sgd",
        momentum=0.9,
        weight_decay=1e-4,
        # bf16-weight models also keep bf16 momentum (grok-1 HBM budget)
        state_dtype="bfloat16" if cfg.param_dtype == "bfloat16" else "float32",
    )
    mesh_desc = "x".join(f"{k}{v}" for k, v in mesh.shape.items())
    chips = int(np.prod(list(mesh.shape.values())))
    t_start = time.time()

    # --- per-layer-kind cost deltas (unrolled depth 1 vs 2) ---
    counts = layer_kind_counts(cfg)
    base = None
    per_kind: dict[str, dict] = {}
    if proof_only:
        counts = {}
        base = {"flops": 0.0, "bytes": 0.0, "coll": 0}
    for kind in counts:
        c1 = _lower_cost(_kind_cfg(cfg, kind, 1), shape, mesh, opt_cfg, kind=step_kind)
        c2 = _lower_cost(_kind_cfg(cfg, kind, 2), shape, mesh, opt_cfg, kind=step_kind)
        delta = {
            "flops": c2["flops"] - c1["flops"],
            "bytes": c2["bytes"] - c1["bytes"],
            "coll": c2["coll"].get("total", 0) - c1["coll"].get("total", 0),
        }
        per_kind[kind] = delta
        if base is None:
            base = {
                "flops": c1["flops"] - delta["flops"],
                "bytes": c1["bytes"] - delta["bytes"],
                "coll": c1["coll"].get("total", 0) - delta["coll"],
                "coll_breakdown_l2": c2["coll"],
            }

    tot = {
        k: base[k] + sum(per_kind[kd][k] * counts[kd] for kd in counts)
        for k in ("flops", "bytes", "coll")
    }

    # --- full-depth compile: shardability proof + collective schedule ---
    mem = {}
    full_coll = {}
    compile_s = None
    if full_depth:
        t0 = time.time()
        cfg_full = dataclasses.replace(cfg, scan_microbatches=True)
        full = _lower_cost(cfg_full, shape, mesh, opt_cfg, kind=step_kind)
        compile_s = time.time() - t0
        ma = full["compiled"].memory_analysis()
        # NOTE: CPU-backend temp bytes assume NO buffer reuse (remat-blind);
        # treated as an upper bound only — see the analytic model below.
        mem = {
            "argument_bytes": int(ma.argument_size_in_bytes),
            "output_bytes": int(ma.output_size_in_bytes),
            "temp_bytes_noreuse_upper_bound": int(ma.temp_size_in_bytes),
            "alias_bytes": int(ma.alias_size_in_bytes),
        }
        full_coll = full["coll"]

    # --- model flops + memory model + roofline ---
    state_abs = abstract_state(cfg, opt_cfg)
    n = active_param_count(cfg, state_abs)
    from ..analysis.memory_model import memory_model

    mem["model"] = memory_model(
        cfg,
        shape,
        dict(mesh.shape),
        n["all_leaves"],
        n["sparsifiable"],
        opt_slots=2 if opt_cfg.kind == "adam" else 1,
        opt_state_bytes=2 if opt_cfg.state_dtype == "bfloat16" else 4,
    )
    mem["fits_16g_hbm"] = mem["model"]["total"] < 16 * 2**30
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        model_flops = 6.0 * n["active"] * tokens
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        model_flops = 2.0 * n["active"] * tokens
    else:
        model_flops = 2.0 * n["active"] * shape.global_batch

    rl = roofline_terms(
        tot["flops"], tot["bytes"], tot["coll"], chips=chips,
        model_flops_total=model_flops,
    )
    # HLO "bytes accessed" counts every (unfused-on-CPU) op's operands; a
    # fused TPU execution touches far less HBM. Bracket with an analytic
    # minimum: params+opt traffic once per step, residual stream 3x (fwd,
    # bwd, remat), weights re-read per microbatch under fsdp gathers.
    if shape.kind == "train":
        mbs = max(cfg.microbatches, 1)
        pbytes = 2.0 if cfg.param_dtype == "bfloat16" else 4.0
        fsdp_div = (mesh.shape.get("data", 1) if cfg.fsdp else 1) * mesh.shape.get("model", 1)
        dpn = chips // mesh.shape.get("model", 1)
        toks_dev = shape.global_batch * shape.seq_len / dpn
        traffic_min = (
            n["all_leaves"] / fsdp_div * (3 * pbytes + 4.0)  # w read(xmb amortized w/ cache)+grad+opt
            + n["all_leaves"] / fsdp_div * 2.0 * (mbs - 1)  # bf16 regathers per extra microbatch
            + 6.0 * cfg.n_layers * toks_dev * cfg.d_model * 2.0
        )
        rl["memory_s_lower_bound"] = traffic_min / 819e9
        rl["hbm_traffic_min_bytes"] = traffic_min

    art = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_desc,
        "chips": chips,
        "kind": shape.kind,
        "counts": counts,
        "per_kind_deltas": per_kind,
        "base": {k: base[k] for k in ("flops", "bytes", "coll")},
        "per_device": tot,
        "collectives_full": full_coll,
        "memory": mem,
        "params": n,
        "roofline": rl,
        "full_compile_s": compile_s,
        "wall_s": time.time() - t_start,
        "tag": tag,
    }
    if save:
        _save(art, arch, shape_name, mesh_desc, tag)
    return art


def _save(art, arch, shape_name, mesh_desc, tag=""):
    ARTIFACTS.mkdir(parents=True, exist_ok=True)
    suffix = f"__{tag}" if tag else ""
    path = ARTIFACTS / f"{arch}__{shape_name}__{mesh_desc}{suffix}.json"
    path.write_text(json.dumps(art, indent=1, default=str))
    return path

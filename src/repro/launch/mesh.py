"""Production meshes.

Single pod: (data=16, model=16) = 256 chips (TPU v5e pod).
Multi-pod:  (pod=2, data=16, model=16) = 512 chips; the batch is additionally
sharded over the slow inter-pod axis, while TP and FSDP stay *intra-pod* so
every weight collective rides the fast ICI links.

Defined as functions (never module-level constants) so importing this module
never touches jax device state — smoke tests see 1 CPU device, the dry-run
sets XLA_FLAGS=--xla_force_host_platform_device_count=512 before first init.
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_local_mesh", "dp_axes"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(n_data: int = 1, n_model: int = 1):
    """Small mesh over whatever devices exist (tests / CPU runs)."""
    return jax.make_mesh((n_data, n_model), ("data", "model"))


def dp_axes(mesh) -> tuple[str, ...]:
    """Mesh axes carrying the batch dimension."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)

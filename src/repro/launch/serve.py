"""Batched decode driver: prefill a batch of prompts, stream decode steps.

  PYTHONPATH=src python -m repro.launch.serve --arch h2o-danube-1.8b --smoke \
      --batch 4 --prompt-len 48 --gen 32 --kernel block_sparse

The sparse model serves through the SAME masks it was trained with — test
FLOPs scale with (1-S) exactly as the paper's Figure 2 test columns.

With ``--kernel`` (or cfg.sparse.kernel) set, prefill and every decode step
route the projections/MLPs through the Pallas sparse kernels instead of
pre-materializing w*m: decode is weight-bound, so block_sparse's skipped
blocks translate ~1:1 into HBM-traffic (and so latency) savings at the
kernel level.  block_sparse additionally threads the serve state's PackState
(host-packed (idx, cnt), core/pack.py) through every call, so the kernel
grids launch the TRUE active-block count — packed once, reused per token.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from ..configs import get_config
from ..core import apply_masks
from ..data import batch_for
from ..models import attn_schedules, init_caches, init_lm, lm_decode, lm_prefill
from ..training import init_train_state
from ..optim import OptConfig

__all__ = ["serve_session", "main"]


def serve_session(
    cfg,
    params,
    *,
    batch: int,
    prompt_len: int,
    gen: int,
    max_len: int | None = None,
    masks=None,
    pack=None,
):
    """Greedy batched generation. Returns (tokens (B, prompt+gen), stats).

    masks=None expects pre-masked params (legacy).  With masks, params are
    raw and serving dispatches through cfg.sparse.kernel (see lm_decode).
    pack: PackState (core/pack.py) — the serve state's host-packed block
    topology.  Packed ONCE per topology, threaded into prefill and reused by
    every decode step, so block_sparse grids launch the true active-block
    count instead of the in-jit padded worst case.
    With cfg.sparse.attn_kernel='flash_tight', the session also builds its
    AttnSchedules ONCE for the prompt length (models/attention.py::
    attn_schedules) and threads them into prefill — prefill's attention
    launches only live KV blocks.  Decode takes no schedule: the per-token
    step is a matvec over the ring-bounded cache (nothing block-shaped to
    skip).
    """
    max_len = max_len or (prompt_len + gen)
    prompt = batch_for(cfg, 0, batch, prompt_len + 1, learnable=True)
    prompt = {k: v for k, v in prompt.items() if k != "targets"}
    if "tokens" in prompt:
        prompt["tokens"] = prompt["tokens"][:, :prompt_len]

    # prefill sequence length as the model actually embeds it (mirrors
    # models/model.py::_embed_inputs: VLM prompts prepend their patch
    # embeddings to the text tokens; frames replace tokens outright)
    if "tokens" in prompt:
        s_prefill = prompt["tokens"].shape[1] + (
            cfg.n_patches if "patches" in prompt else 0
        )
    else:
        s_prefill = prompt["frames"].shape[1]
    sched = attn_schedules(cfg, s_prefill)

    prefill = jax.jit(
        lambda p, m, pk, b: lm_prefill(
            p, cfg, b, max_len=max_len, masks=m, pack=pk, attn_sched=sched
        )
    )
    decode = jax.jit(
        lambda p, m, pk, c, t, pos: lm_decode(p, cfg, c, t, pos, masks=m, pack=pk),
        donate_argnums=(3,),
    )

    t0 = time.time()
    logits, caches = prefill(params, masks, pack, prompt)
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0

    tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    out = [tok]
    n_patches = cfg.n_patches if cfg.frontend == "patch" else 0
    t0 = time.time()
    for i in range(gen - 1):
        logits, caches = decode(params, masks, pack, caches, tok, prompt_len + n_patches + i)
        tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
        out.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.time() - t0
    toks = jnp.concatenate(out, axis=1)
    return toks, {
        "prefill_s": t_prefill,
        "decode_s_per_tok": t_decode / max(gen - 1, 1),
        "tok_per_s": batch * (gen - 1) / max(t_decode, 1e-9),
    }


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="h2o-danube-1.8b")
    p.add_argument("--smoke", action="store_true")
    p.add_argument("--batch", type=int, default=4)
    p.add_argument("--prompt-len", type=int, default=48)
    p.add_argument("--gen", type=int, default=32)
    p.add_argument(
        "--kernel", default=None, choices=["dense", "masked", "block_sparse"],
        help="override cfg.sparse.kernel for serving",
    )
    p.add_argument(
        "--block", type=int, default=None,
        help="block edge for --kernel block_sparse (sets block_shape + tiles)",
    )
    p.add_argument(
        "--attn-kernel", default=None,
        choices=["dense", "flash", "flash_tight"],
        help="override cfg.sparse.attn_kernel: prefill attention via the "
        "Pallas flash kernels (flash_tight = live-KV-block grids)",
    )
    args = p.parse_args()
    cfg = get_config(args.arch, smoke=args.smoke)
    if args.kernel is not None or args.attn_kernel is not None:
        import dataclasses

        sp = cfg.sparse
        if args.kernel == "block_sparse":
            e = args.block or sp.kernel_block[2]
            sp = dataclasses.replace(
                sp, kernel="block_sparse", block_shape=(e, e),
                kernel_block=(sp.kernel_block[0], e, e),
            )
        elif args.kernel is not None:
            sp = dataclasses.replace(sp, kernel=args.kernel)
        if args.attn_kernel is not None:
            sp = dataclasses.replace(sp, attn_kernel=args.attn_kernel)
        cfg = dataclasses.replace(cfg, sparse=sp)
    state, _, _ = init_train_state(jax.random.PRNGKey(0), cfg, OptConfig())
    if cfg.sparse.kernel in ("masked", "block_sparse"):
        # kernel dispatch: serve RAW weights + masks; w*m never materialized.
        # block_sparse also serves the host-packed tight-grid topology
        # (init_train_state already built state["pack"]; a restored
        # checkpoint carries its own).
        toks, stats = serve_session(
            cfg, state["params"], batch=args.batch,
            prompt_len=args.prompt_len, gen=args.gen, masks=state["masks"],
            pack=state.get("pack"),
        )
    else:
        w_eff = apply_masks(state["params"], state["masks"])
        toks, stats = serve_session(
            cfg, w_eff, batch=args.batch, prompt_len=args.prompt_len, gen=args.gen
        )
    print(
        f"kernel={cfg.sparse.kernel}  attn_kernel={cfg.sparse.attn_kernel}  "
        f"generated shape: {toks.shape}"
    )
    for k, v in stats.items():
        print(f"  {k}: {v:.4f}")


if __name__ == "__main__":
    main()

"""Serving driver: continuous-batching engine (default) or lockstep baseline.

  PYTHONPATH=src python -m repro.launch.serve --arch h2o-danube-1.8b --smoke \
      --capacity 4 --requests 16 --arrival-rate 50 --kernel block_sparse

The sparse model serves through the SAME masks it was trained with — test
FLOPs scale with (1-S) exactly as the paper's Figure 2 test columns.

``main`` drives the continuous-batching ``ServeEngine``
(serving/engine.py): a Poisson stream of staggered-length requests admitted
into a fixed slot pool, per-slot decode, slot recycling — so throughput is
not bottlenecked on the slowest request of a fixed batch.  ``--lockstep``
runs the legacy fixed-batch ``serve_session`` instead (the baseline
benchmarks/serve_bench.py compares against).

With ``--kernel`` (or cfg.sparse.kernel) set, prefill and every decode step
route the projections/MLPs through the Pallas sparse kernels instead of
pre-materializing w*m: decode is weight-bound, so block_sparse's skipped
blocks translate ~1:1 into HBM-traffic (and so latency) savings at the
kernel level.  block_sparse additionally threads the serve state's PackState
(host-packed (idx, cnt), core/pack.py) through every call, so the kernel
grids launch the TRUE active-block count — for the engine that means packed
ONCE at construction, reused by every prefill and decode step.
"""
from __future__ import annotations

import argparse
import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config
from ..core import apply_masks
from ..data import batch_for
from ..models import attn_schedules, init_caches, init_lm, lm_decode, lm_prefill
from ..training import init_train_state
from ..optim import OptConfig

__all__ = [
    "serve_session",
    "staggered_requests",
    "configure_kernel",
    "init_serving_state",
    "main",
]


@functools.lru_cache(maxsize=None)
def _session_fns(cfg, max_len: int, s_prefill: int):
    """Jitted (prefill, decode) for one (config, shape) — cached at module
    level (ModelConfig is a frozen, hashable dataclass) so REPEATED sessions
    of the same shape reuse the compiled executables instead of re-tracing
    per call.  The AttnSchedule is likewise built once per shape."""
    sched = attn_schedules(cfg, s_prefill)
    prefill = jax.jit(
        lambda p, m, pk, b: lm_prefill(
            p, cfg, b, max_len=max_len, masks=m, pack=pk, attn_sched=sched
        )
    )
    decode = jax.jit(
        lambda p, m, pk, c, t, pos: lm_decode(p, cfg, c, t, pos, masks=m, pack=pk),
        donate_argnums=(3,),
    )
    return prefill, decode


def serve_session(
    cfg,
    params,
    *,
    batch: int,
    prompt_len: int,
    gen: int,
    max_len: int | None = None,
    masks=None,
    pack=None,
):
    """Greedy batched generation. Returns (tokens (B, prompt+gen), stats).

    masks=None expects pre-masked params (legacy).  With masks, params are
    raw and serving dispatches through cfg.sparse.kernel (see lm_decode).
    pack: PackState (core/pack.py) — the serve state's host-packed block
    topology.  Packed ONCE per topology, threaded into prefill and reused by
    every decode step, so block_sparse grids launch the true active-block
    count instead of the in-jit padded worst case.
    With cfg.sparse.attn_kernel='flash_tight', the session also builds its
    AttnSchedules ONCE for the prompt length (models/attention.py::
    attn_schedules) and threads them into prefill — prefill's attention
    launches only live KV blocks.  Decode takes no schedule: the per-token
    step is a matvec over the ring-bounded cache (nothing block-shaped to
    skip).
    """
    max_len = max_len or (prompt_len + gen)
    prompt = batch_for(cfg, 0, batch, prompt_len + 1, learnable=True)
    prompt = {k: v for k, v in prompt.items() if k != "targets"}
    if "tokens" in prompt:
        prompt["tokens"] = prompt["tokens"][:, :prompt_len]

    # prefill sequence length as the model actually embeds it (mirrors
    # models/model.py::_embed_inputs: VLM prompts prepend their patch
    # embeddings to the text tokens; frames replace tokens outright)
    if "tokens" in prompt:
        s_prefill = prompt["tokens"].shape[1] + (
            cfg.n_patches if "patches" in prompt else 0
        )
    else:
        s_prefill = prompt["frames"].shape[1]
    prefill, decode = _session_fns(cfg, max_len, s_prefill)

    t0 = time.time()
    logits, caches = prefill(params, masks, pack, prompt)
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0

    tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    out = [tok]
    n_patches = cfg.n_patches if cfg.frontend == "patch" else 0
    t0 = time.time()
    for i in range(gen - 1):
        logits, caches = decode(params, masks, pack, caches, tok, prompt_len + n_patches + i)
        tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
        out.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.time() - t0
    toks = jnp.concatenate(out, axis=1)
    # tok_per_s counts ALL gen generated tokens — the first one is produced
    # from the prefill logits (argmax above), so the prefill time that bought
    # it is in the denominator; gen-1 decode steps produce the rest.
    return toks, {
        "prefill_s": t_prefill,
        "decode_s_per_tok": t_decode / max(gen - 1, 1),
        "tok_per_s": batch * gen / max(t_prefill + t_decode, 1e-9),
    }


def staggered_requests(cfg, n: int, *, prompt_lens=(16, 32), gen_lens=(8, 16, 32, 64),
                       arrival_rate: float = 0.0, seed: int = 0,
                       temperature: float = 0.0, top_k: int = 0):
    """Synthetic staggered-length workload for the continuous-batching engine.

    Request i cycles through ``prompt_lens``/``gen_lens`` (deliberately
    mismatched cycle lengths => a staggered mix) with Poisson arrival offsets
    at ``arrival_rate`` req/s (0 => burst at t=0).  Shared by the serve CLI,
    benchmarks/serve_bench.py and examples/serve_continuous.py.
    """
    from ..serving import Request, poisson_arrivals

    rng = np.random.default_rng(seed)
    arrivals = poisson_arrivals(n, arrival_rate, seed)
    reqs = []
    for i in range(n):
        L = int(prompt_lens[i % len(prompt_lens)])
        kw = {}
        if cfg.frontend == "patch":
            kw["patches"] = rng.standard_normal(
                (cfg.n_patches, cfg.frontend_dim)
            ).astype(np.float32)
        reqs.append(
            Request(
                rid=i,
                tokens=rng.integers(0, cfg.vocab_size, size=L).astype(np.int32),
                max_new_tokens=int(gen_lens[i % len(gen_lens)]),
                temperature=temperature, top_k=top_k, seed=seed + i,
                arrival=float(arrivals[i]), **kw,
            )
        )
    return reqs


def configure_kernel(cfg, *, kernel=None, block=None, attn_kernel=None):
    """Apply CLI kernel overrides to cfg.sparse (the one definition shared
    by the serve CLI and benchmarks/serve_bench.py — block_sparse couples
    block_shape to the kernel tiles, which must never be spelled twice)."""
    if kernel is None and attn_kernel is None:
        return cfg
    import dataclasses

    sp = cfg.sparse
    if kernel == "block_sparse":
        e = block or sp.kernel_block[2]
        sp = dataclasses.replace(
            sp, kernel="block_sparse", block_shape=(e, e),
            kernel_block=(sp.kernel_block[0], e, e),
        )
    elif kernel is not None:
        sp = dataclasses.replace(sp, kernel=kernel)
    if attn_kernel is not None:
        sp = dataclasses.replace(sp, attn_kernel=attn_kernel)
    return dataclasses.replace(cfg, sparse=sp)


def init_serving_state(cfg, seed: int = 0):
    """Fresh weights ready to serve -> (params, masks, pack).

    Kernel-dispatch modes serve RAW weights + masks (w*m never materialized;
    block_sparse also carries the host-packed tight-grid topology built by
    init_train_state — a restored checkpoint carries its own).  Dense mode
    pre-masks once and serves effective weights (masks/pack None).
    """
    state, _, _ = init_train_state(jax.random.PRNGKey(seed), cfg, OptConfig())
    if cfg.sparse.kernel in ("masked", "block_sparse"):
        return state["params"], state["masks"], state.get("pack")
    return apply_masks(state["params"], state["masks"]), None, None


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="h2o-danube-1.8b")
    p.add_argument("--smoke", action="store_true")
    # continuous-batching engine (default mode)
    p.add_argument("--capacity", type=int, default=4,
                   help="engine slot-pool size (the decode batch)")
    p.add_argument("--requests", type=int, default=16,
                   help="number of staggered-length requests to serve")
    p.add_argument("--arrival-rate", type=float, default=0.0,
                   help="Poisson arrival rate, req/s (0 = burst at t=0)")
    p.add_argument("--max-len", type=int, default=128,
                   help="per-slot cache length (prompt + generation bound)")
    # fault-tolerance knobs (docs/serving.md#failure-model)
    p.add_argument("--queue-limit", type=int, default=None,
                   help="max queued requests before submit sheds (backpressure; "
                   "default unbounded)")
    p.add_argument("--deadline", type=float, default=None,
                   help="admission deadline in seconds from arrival; requests "
                   "still queued past it are SHED (default none)")
    p.add_argument("--max-retries", type=int, default=0,
                   help="quarantine-retry budget per request: non-finite slots "
                   "re-queue with backoff this many times before FAILED")
    # paged KV cache (docs/serving.md#paged-kv-cache)
    p.add_argument("--paged", action="store_true",
                   help="page the KV caches: per-slot block tables over "
                   "fixed-size KV pools (serving/block_pool.py)")
    p.add_argument("--page-size", type=int, default=16,
                   help="tokens per KV page (must divide --max-len and each "
                   "local ring length)")
    p.add_argument("--n-blocks", type=int, default=None,
                   help="global page-pool size (default: capacity * max_len "
                   "/ page_size, i.e. no oversubscription)")
    p.add_argument("--prefix-cache", type=int, default=0,
                   help="max LRU-registered shared prefixes for COW prefix "
                   "reuse (0 = off; needs --paged and an all-global "
                   "transformer config)")
    # lockstep baseline (legacy fixed-batch driver)
    p.add_argument("--lockstep", action="store_true",
                   help="run the fixed-batch serve_session baseline instead")
    p.add_argument("--batch", type=int, default=4)
    p.add_argument("--prompt-len", type=int, default=48)
    p.add_argument("--gen", type=int, default=32)
    p.add_argument(
        "--kernel", default=None, choices=["dense", "masked", "block_sparse"],
        help="override cfg.sparse.kernel for serving",
    )
    p.add_argument(
        "--block", type=int, default=None,
        help="block edge for --kernel block_sparse (sets block_shape + tiles)",
    )
    p.add_argument(
        "--attn-kernel", default=None,
        choices=["dense", "flash", "flash_tight"],
        help="override cfg.sparse.attn_kernel: prefill attention via the "
        "Pallas flash kernels (flash_tight = live-KV-block grids)",
    )
    # observability exports (docs/observability.md)
    p.add_argument(
        "--trace-out", default=None, metavar="PATH",
        help="write the engine's Chrome-trace JSON here (open in Perfetto / "
        "chrome://tracing; docs/observability.md)",
    )
    p.add_argument(
        "--metrics-out", default=None, metavar="PATH",
        help="write Prometheus text-exposition metrics here after the run",
    )
    args = p.parse_args()
    cfg = configure_kernel(
        get_config(args.arch, smoke=args.smoke), kernel=args.kernel,
        block=args.block, attn_kernel=args.attn_kernel,
    )
    params, masks, pack = init_serving_state(cfg)

    if args.lockstep:
        toks, stats = serve_session(
            cfg, params, batch=args.batch, prompt_len=args.prompt_len,
            gen=args.gen, masks=masks, pack=pack,
        )
        print(
            f"lockstep  kernel={cfg.sparse.kernel}  "
            f"attn_kernel={cfg.sparse.attn_kernel}  "
            f"generated shape: {toks.shape}"
        )
        for k, v in stats.items():
            print(f"  {k}: {v:.4f}")
        return

    from ..serving import ServeEngine

    obs = None
    if args.trace_out or args.metrics_out:
        from ..obs import Observability

        obs = Observability(process_name="serve")
    engine = ServeEngine(
        cfg, params, capacity=args.capacity, max_len=args.max_len,
        masks=masks, pack=pack, queue_limit=args.queue_limit,
        deadline=args.deadline, max_retries=args.max_retries,
        paged=args.paged, page_size=args.page_size, n_blocks=args.n_blocks,
        prefix_cache=args.prefix_cache, obs=obs,
    )
    n_shed_at_submit = 0
    for req in staggered_requests(
        cfg, args.requests, arrival_rate=args.arrival_rate
    ):
        if not engine.submit(req):
            n_shed_at_submit += 1  # backpressure: bounded queue said no
    if n_shed_at_submit:
        print(f"backpressure: {n_shed_at_submit} requests shed at submit "
              f"(--queue-limit {args.queue_limit})")
    stats = engine.run()
    if obs is not None:
        flusher = obs.flusher(
            metrics_path=args.metrics_out, trace_path=args.trace_out,
        )
        flusher.close(stats["wall_s"])
        if args.trace_out:
            print(f"trace written to {args.trace_out}")
        if args.metrics_out:
            print(f"metrics written to {args.metrics_out}")
    print(
        f"engine  kernel={cfg.sparse.kernel}  "
        f"attn_kernel={cfg.sparse.attn_kernel}  capacity={args.capacity}"
    )
    for k, v in stats.items():
        print(f"  {k}: {v:.4f}" if isinstance(v, float) else f"  {k}: {v}")


if __name__ == "__main__":
    main()

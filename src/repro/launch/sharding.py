"""Logical-axis -> mesh sharding resolution with divisibility fallback.

Every param/activation dim carries a logical axis name (models/*.py).  The
resolver walks a priority list, assigning mesh axes greedily:

  - a mesh axis is used at most once per array;
  - an assignment is skipped unless the dim is exactly divisible;
  - first-fit in PRIORITY order, so e.g. MoE expert banks put "model" on the
    experts dim when E divides it (EP) and otherwise fall through to the ff
    dim (intra-expert TP) — this single rule makes every assigned arch
    (14-head GQA, 8-expert grok, 60-expert qwen, ...) compile on a 16-way
    model axis.

FSDP: weight "embed" dims additionally shard over the *data* axis (intra-pod
only — inter-pod links never carry weight all-gathers).
"""
from __future__ import annotations

import numpy as np
import jax
from jax.sharding import NamedSharding, PartitionSpec

from .mesh import dp_axes

__all__ = ["resolve_spec", "param_shardings", "batch_shardings", "cache_axes"]

# Logical axis -> candidate mesh axes, tried in order.
MODEL_AXES = ("experts", "heads", "kv_heads", "mlp", "moe_mlp", "vocab")
# resolution priority within one array (first match wins the mesh axis)
PRIORITY = [
    "experts",
    "heads",
    "kv_heads",
    "moe_mlp",
    "mlp",
    "vocab",
    "act_batch",  # batch first; KV-seq sharding picks up whatever is idle
    "act_kv_seq",  # decode KV fallback: flash-decoding style seq sharding
    "embed",  # FSDP (data axis), weights only
]


def _rules(mesh, *, fsdp: bool):
    dp = dp_axes(mesh)
    r: dict[str, tuple[tuple[str, ...], ...]] = {
        name: (("model",),) for name in MODEL_AXES
    }
    # decode KV-seq: grab every axis the (possibly tiny) batch left idle —
    # long_500k (batch=1) gets 256/512-way flash-decoding-style seq sharding
    r["act_kv_seq"] = ((*dp, "model"), ("data", "model"), ("model",))
    r["act_batch"] = (dp,)
    if fsdp:
        r["embed"] = (("data",),)
    return r


def resolve_spec(axes, shape, mesh, *, fsdp: bool = False, min_fsdp_size: int = 2**16):
    """axes: tuple of logical names (or None) per dim -> PartitionSpec."""
    rules = _rules(mesh, fsdp=fsdp)
    spec: list = [None] * len(shape)
    used: set[str] = set()
    order = sorted(
        [i for i, a in enumerate(axes) if a in rules],
        key=lambda i: PRIORITY.index(axes[i]) if axes[i] in PRIORITY else 99,
    )
    size = int(np.prod(shape)) if len(shape) else 0
    for i in order:
        if axes[i] == "embed" and size < min_fsdp_size:
            continue  # don't FSDP-shard tiny vectors (norm scales, biases)
        for cand in rules[axes[i]]:
            cand = tuple(c for c in cand if c in mesh.axis_names)
            if not cand or any(c in used for c in cand):
                continue
            n = int(np.prod([mesh.shape[c] for c in cand]))
            if shape[i] % n != 0:
                continue
            spec[i] = cand if len(cand) > 1 else cand[0]
            used.update(cand)
            break
    return PartitionSpec(*spec)


def param_shardings(axes_tree, shapes_tree, mesh, *, fsdp: bool = False):
    """Trees of logical axes + ShapeDtypeStructs -> tree of NamedSharding."""
    def f(axes, shp):
        return NamedSharding(mesh, resolve_spec(axes, shp.shape, mesh, fsdp=fsdp))

    return jax.tree_util.tree_map(
        f, axes_tree, shapes_tree, is_leaf=lambda x: isinstance(x, tuple)
    )


def state_shardings(state, axes_tree, mesh, *, fsdp: bool = False):
    """Sharding tree for a full TrainState (params/masks/opt/scalars).

    Optimizer per-connection state (momentum / m / v, SNFS dense_mom) inherits
    the exact param shardings — with fsdp=True this is ZeRO-style sharded
    optimizer state for free.
    """
    rep = NamedSharding(mesh, PartitionSpec())
    shapes = jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state["params"]
    )
    p_sh = param_shardings(axes_tree, shapes, mesh, fsdp=fsdp)
    m_sh = jax.tree_util.tree_map(
        lambda m, s: s if m is not None else None,
        state["masks"],
        p_sh,
        is_leaf=lambda x: x is None,
    )
    opt_sh = {
        k: (p_sh if k in ("momentum", "m", "v") else rep) for k in state["opt"]
    }
    out = {
        "step": rep,
        "params": p_sh,
        "masks": m_sh,
        "opt": opt_sh,
        "rng": rep,
    }
    if "nonfinite_steps" in state:
        out["nonfinite_steps"] = rep
    if "dense_mom" in state:
        out["dense_mom"] = p_sh
    return out


def batch_shardings(batch_tree, mesh):
    """Inputs: batch dim over all DP axes (divisibility permitting)."""
    dp = dp_axes(mesh)
    n_dp = int(np.prod([mesh.shape[a] for a in dp]))

    def f(x):
        spec = [None] * len(x.shape)
        if len(x.shape) and x.shape[0] % n_dp == 0:
            spec[0] = dp if len(dp) > 1 else dp[0]
        return NamedSharding(mesh, PartitionSpec(*spec))

    return jax.tree_util.tree_map(f, batch_tree)


# logical axes for cache leaves (mirrors models.model.init_caches structure)
KV_AXES = ("act_batch", "act_kv_seq", "kv_heads", "head_dim")
SSM_AXES = {"h": ("act_batch", "mlp", None), "conv": ("act_batch", None, "mlp")}
MLSTM_AXES = {
    "C": ("act_batch", "heads", None, None),
    "n": ("act_batch", "heads", None),
    "m": ("act_batch", "heads"),
}
SLSTM_AXES = {k: ("act_batch", "heads", None) for k in ("c", "n", "h", "m")}


def cache_axes(cfg):
    """Axes tree matching init_caches(cfg, ...)."""
    out = []
    for i in range(cfg.n_layers):
        if cfg.block_type == "xlstm":
            out.append(
                {"slstm": dict(SLSTM_AXES)}
                if cfg.is_slstm(i)
                else {"mlstm": dict(MLSTM_AXES)}
            )
            continue
        c = {"kv": {"k": KV_AXES, "v": KV_AXES}}
        if cfg.block_type == "hymba":
            c["ssm"] = dict(SSM_AXES)
        out.append(c)
    return out

"""Fault-tolerant training driver.

  PYTHONPATH=src python -m repro.launch.train --arch h2o-danube-1.8b --smoke \
      --steps 300 --method rigl --sparsity 0.8 --workdir /tmp/run

Fault tolerance model (designed for 1000+ preemptible nodes):
  - the outer loop survives worker exceptions: on failure it restores the
    newest valid checkpoint and resumes (``--max-restarts``);
  - checkpoints are atomic + bit-packed masks + async (checkpoint/);
  - data is stateless (pure function of step) — no data-state to recover and
    any replacement host can serve any shard => stragglers can be replaced
    mid-run without a pipeline rewind;
  - ``--preempt-at`` kills the process mid-run once (integration tests assert
    bitwise-identical resume);
  - elastic restarts: restore() reshards onto whatever mesh exists now.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import pathlib
import time

import jax
import numpy as np

from ..configs import get_config
from ..configs.base import SparseConfig
from ..core import TopologyTrace, mask_stats, publish_pack_gauges
from ..core.pruning import PruningSchedule
from ..obs import Observability, jit_retraces
from ..checkpoint.checkpoint import Checkpointer
from ..data import batch_for
from ..optim import LRSchedule, OptConfig
from ..training import (
    init_train_state,
    make_algo,
    make_prune_fn,
    make_rigl_step,
    make_train_step,
    refresh_pack,
    snip_init,
)

__all__ = ["train_loop", "main"]


class SimulatedPreemption(RuntimeError):
    pass


def train_loop(
    cfg,
    *,
    steps: int,
    batch: int,
    seq: int,
    workdir: str,
    opt_cfg: OptConfig | None = None,
    lr_sched: LRSchedule | None = None,
    ckpt_every: int = 100,
    preempt_at: int | None = None,
    learnable: bool = True,
    log_every: int = 50,
    seed: int = 0,
    obs=None,
    flusher=None,
):
    """One worker attempt. Raises on (simulated) failure; restartable.

    ``obs`` (optional repro.obs.Observability) turns on the training side
    of the observability layer (docs/observability.md): per-step train_step
    spans + a loss/gnorm counter track on the tracer, train_* gauges/
    histograms and topology-distance series in the metrics registry, and
    kernel_* pack gauges re-published after every refresh_pack.  ``flusher``
    (repro.obs.PeriodicFlusher, usually ``obs.flusher(...)`` — built by
    main() from --trace-out/--metrics-out) is pumped at log cadence and
    force-flushed before return, so a live run's files stay current.
    """
    workdir = pathlib.Path(workdir)
    opt_cfg = opt_cfg or OptConfig(kind="adam", weight_decay=0.0, grad_clip=1.0)
    lr_sched = lr_sched or LRSchedule(
        kind="warmup_cosine", base_lr=3e-3, warmup_steps=min(100, steps // 10 + 1),
        total_steps=steps,
    )
    algo = make_algo(cfg, steps)
    state, axes, flags = init_train_state(jax.random.PRNGKey(seed), cfg, opt_cfg)

    ckpt = Checkpointer(workdir / "ckpt", every=ckpt_every)
    restored, rstep = ckpt.restore_or_none(state)
    if restored is not None:
        state = restored
        # re-pack against the RESTORED masks: covers pre-PackState
        # checkpoints (restore falls back to the template pack) and any
        # width drift between the fresh-init template and the saved run
        state = refresh_pack(state, cfg)
        print(f"[train] restored checkpoint at step {rstep}")

    train_step = jax.jit(make_train_step(cfg, opt_cfg, lr_sched), donate_argnums=0)
    rigl_step = jax.jit(make_rigl_step(cfg, algo, lr_sched), donate_argnums=0)
    prune_sched = PruningSchedule(
        cfg.sparse.sparsity, begin_step=steps // 8, end_step=int(steps * 0.75),
        prune_every=max(cfg.sparse.delta_t * 10, 1),
    )
    prune_fn = jax.jit(make_prune_fn(cfg, prune_sched)) if cfg.sparse.method == "pruning" else None

    sp = cfg.sparse
    if sp.method == "snip" and int(state["step"]) == 0:
        state = snip_init(state, cfg, batch_for(cfg, 0, batch, seq, learnable=learnable))
        state = refresh_pack(state, cfg)  # snip replaced the masks

    metrics_log = []
    topo_log = []  # per-update records, kept apart from the loss log
    topo_trace = TopologyTrace()  # graph-distance telemetry (core/topology.py)
    om = None
    if obs is not None:
        m = obs.metrics
        obs.trace.thread_name(0, "train")
        om = {
            "loss": m.gauge("train_loss", "last logged training loss"),
            "lr": m.gauge("train_lr", "current learning rate"),
            "gnorm": m.gauge("train_grad_norm", "last logged gradient norm"),
            "stale": m.gauge("train_pack_stale",
                             "pack blocks differing from the masks (must be 0)"),
            "nonfinite": m.gauge("train_nonfinite_steps",
                                 "skipped non-finite optimizer updates"),
            "steps": m.counter("train_steps_total", "optimizer steps run"),
            "topo": m.counter("train_topology_updates_total",
                              "drop/grow topology updates applied"),
            "step_s": m.histogram("train_step_seconds",
                                  "host-side step dispatch time"),
            "dist": m.gauge("train_topology_distance",
                            "last topology-update distance by metric",
                            labels=("metric",)),
            "retraces": m.gauge("train_retraces",
                                "jit retraces of the train/update steps"),
        }
        publish_pack_gauges(m, state.get("pack"))
    t0 = time.time()
    step = int(state["step"])
    while step < steps:
        ts0 = time.time()
        b = batch_for(cfg, step, batch, seq, learnable=learnable)
        is_update = (
            sp.method in ("rigl", "set", "snfs", "topkast")
            and step > 0
            and step % sp.delta_t == 0
            and step < algo.schedule.t_end
        )
        if is_update:
            prev_masks = topo_trace.snapshot(state["masks"])
            state, m = rigl_step(state, b)
            # topology changed: re-pack the tight-grid block topology NOW so
            # the next delta_t train/serve steps run grids sized to the new
            # active counts (host-side, amortized — see core/pack.py)
            state = refresh_pack(state, cfg)
            rec = topo_trace.record(prev_masks, state["masks"], step=step)
            topo_log.append({"step": step, "topology": rec})
            if om is not None:
                om["topo"].inc()
                for k in ("jaccard_dist", "graph_edit_dist", "nhd"):
                    om["dist"].labels(k).set(rec[k])
                obs.trace.instant(
                    "topology_update", time.time() - t0, tid=0, cat="train",
                    args={"step": step, **{k: rec[k] for k in
                          ("dropped", "grown", "jaccard_dist", "nhd")}},
                )
                # the drop/grow moved blocks: re-publish the pack gauges
                publish_pack_gauges(obs.metrics, state.get("pack"))
        else:
            state, m = train_step(state, b)
        if prune_fn is not None and step % prune_sched.prune_every == 0:
            state = prune_fn(state)
            state = refresh_pack(state, cfg)  # pruning moved the masks too
            if om is not None:
                publish_pack_gauges(obs.metrics, state.get("pack"))
        step = int(state["step"])
        if om is not None:
            # host-side dispatch slice (jax is async: the log-cadence block
            # below is where queued work drains — visible as long spans
            # there, exactly the truth of where the host waited)
            ts1 = time.time()
            obs.trace.span(
                "topology_update_step" if is_update else "train_step",
                ts0 - t0, ts1 - t0, tid=0, cat="train", args={"step": step},
            )
            om["step_s"].observe(ts1 - ts0)
            om["steps"].inc()
        if preempt_at is not None and step == preempt_at:
            ckpt.maybe_save(state, step, force=True)
            ckpt.wait()
            raise SimulatedPreemption(f"preempted at step {step}")
        if step % log_every == 0 or step == steps:
            loss = float(m["loss"])
            rec = {"step": step, "loss": loss}
            if "lr" in m:  # topology-update steps report loss only
                rec["lr"] = float(m["lr"])
                rec["grad_norm"] = float(m["grad_norm"])
            # compile-counter: growth during steady state (after the first
            # log interval) is the pack-width-hysteresis regression signal
            rec["n_retraces"] = jit_retraces(train_step, rigl_step)
            if om is not None:
                tnow = time.time() - t0
                om["loss"].set(loss)
                om["retraces"].set(rec["n_retraces"])
                track = {"loss": loss}
                if "lr" in m:
                    om["lr"].set(float(m["lr"]))
                    om["gnorm"].set(float(m["grad_norm"]))
                    track["grad_norm"] = float(m["grad_norm"])
                if "nonfinite_steps" in m:
                    om["nonfinite"].set(int(m["nonfinite_steps"]))
                obs.trace.counter("train", tnow, track, tid=0)
                if flusher is not None:
                    flusher.maybe_flush(tnow)
            if "pack_stale" in m:
                # staleness is sticky until the next refresh, so checking at
                # log cadence (not every step) still catches a missed
                # refresh_pack — and a nonzero value means the kernels are
                # executing the WRONG topology: fail fast, don't mistrain
                rec["pack_stale"] = stale = int(m["pack_stale"])
                if om is not None:
                    om["stale"].set(stale)
                if stale:
                    raise RuntimeError(
                        f"PackState is stale ({stale} blocks differ from the "
                        f"masks) at step {step} — a topology update ran "
                        "without refresh_pack(); see docs/kernels.md#staleness"
                    )
            metrics_log.append(rec)
            print(f"[train] step {step:6d} loss {loss:.4f} ({(time.time()-t0):.1f}s)")
        ckpt.maybe_save(state, step)
    ckpt.maybe_save(state, step, force=True)
    ckpt.wait()
    if flusher is not None:
        flusher.close(time.time() - t0)
    stats = mask_stats(state["masks"])
    (workdir / "result.json").write_text(
        json.dumps({
            "metrics": metrics_log,
            "sparsity": stats["sparsity"],
            "nnz": stats["nnz"],
            "topology": topo_trace.summary(),
            "topology_updates": topo_log,
        })
    )
    return state, metrics_log


def run_with_restarts(max_restarts: int = 3, **kw):
    """The fault-tolerance wrapper a cluster scheduler would drive."""
    attempt = 0
    while True:
        try:
            return train_loop(**kw)
        except SimulatedPreemption as e:
            attempt += 1
            print(f"[train] {e}; restart {attempt}/{max_restarts}")
            kw["preempt_at"] = None  # only preempt once in tests
            if attempt > max_restarts:
                raise


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="h2o-danube-1.8b")
    p.add_argument("--smoke", action="store_true")
    p.add_argument("--steps", type=int, default=300)
    p.add_argument("--batch", type=int, default=8)
    p.add_argument("--seq", type=int, default=64)
    p.add_argument("--method", default="rigl",
                   choices=["rigl", "set", "snfs", "topkast", "static", "snip",
                            "pruning", "dense"])
    p.add_argument("--sparsity", type=float, default=0.8)
    p.add_argument("--distribution", default="erk", choices=["uniform", "er", "erk"])
    p.add_argument("--delta-t", type=int, default=100)
    p.add_argument("--alpha", type=float, default=0.3)
    p.add_argument(
        "--kernel", default="dense", choices=["dense", "masked", "block_sparse"],
        help="execution path for sparsifiable matmuls (Pallas sparse kernels)",
    )
    p.add_argument(
        "--block", type=int, default=128,
        help="block edge for --kernel block_sparse (sets block_shape + tiles)",
    )
    p.add_argument("--workdir", default="/tmp/repro_train")
    p.add_argument("--preempt-at", type=int, default=None)
    p.add_argument("--max-restarts", type=int, default=3)
    p.add_argument(
        "--trace-out", default=None, metavar="PATH",
        help="write a Chrome-trace JSON here (open in Perfetto / "
             "chrome://tracing; docs/observability.md)",
    )
    p.add_argument(
        "--metrics-out", default=None, metavar="PATH",
        help="write Prometheus text-exposition metrics here "
             "(rewritten at log cadence)",
    )
    args = p.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    method = args.method
    sparsity = 0.0 if method == "dense" else args.sparsity
    if method == "dense":
        method = "static"
    sparse_kw = dict(
        sparsity=sparsity, method=method,
        distribution=args.distribution, delta_t=args.delta_t, alpha=args.alpha,
        kernel=args.kernel,
    )
    if args.kernel == "block_sparse":
        # block-sparse execution needs a block-aligned topology (core.rigl
        # block mode) matching the kernel tiles
        sparse_kw["block_shape"] = (args.block, args.block)
        sparse_kw["kernel_block"] = (128, args.block, args.block)
    cfg = dataclasses.replace(cfg, sparse=SparseConfig(**sparse_kw))
    obs = flusher = None
    if args.trace_out or args.metrics_out:
        obs = Observability(pid=1, process_name="train")
        flusher = obs.flusher(
            metrics_path=args.metrics_out, trace_path=args.trace_out,
        )
    run_with_restarts(
        max_restarts=args.max_restarts,
        cfg=cfg,
        steps=args.steps,
        batch=args.batch,
        seq=args.seq,
        workdir=args.workdir,
        preempt_at=args.preempt_at,
        obs=obs,
        flusher=flusher,
    )


if __name__ == "__main__":
    main()

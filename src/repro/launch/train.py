"""Fault-tolerant training driver.

  PYTHONPATH=src python -m repro.launch.train --arch h2o-danube-1.8b --smoke \
      --steps 300 --method rigl --sparsity 0.8 --workdir /tmp/run

Fault tolerance model (designed for 1000+ preemptible nodes):
  - the outer loop survives worker exceptions: on failure it restores the
    newest valid checkpoint and resumes (``--max-restarts``);
  - checkpoints are atomic + bit-packed masks + async (checkpoint/);
  - data is stateless (pure function of step) — no data-state to recover and
    any replacement host can serve any shard => stragglers can be replaced
    mid-run without a pipeline rewind;
  - ``--preempt-at`` kills the process mid-run once (integration tests assert
    bitwise-identical resume);
  - elastic restarts: restore() reshards onto whatever mesh exists now.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import pathlib
import time

import jax
import numpy as np

from ..configs import get_config
from ..configs.base import SparseConfig
from ..core import TopologyTrace, mask_stats
from ..core.pruning import PruningSchedule
from ..checkpoint.checkpoint import Checkpointer
from ..data import batch_for
from ..optim import LRSchedule, OptConfig
from ..training import (
    init_train_state,
    make_algo,
    make_prune_fn,
    make_rigl_step,
    make_train_step,
    refresh_pack,
    snip_init,
)

__all__ = ["train_loop", "main"]


class SimulatedPreemption(RuntimeError):
    pass


def train_loop(
    cfg,
    *,
    steps: int,
    batch: int,
    seq: int,
    workdir: str,
    opt_cfg: OptConfig | None = None,
    lr_sched: LRSchedule | None = None,
    ckpt_every: int = 100,
    preempt_at: int | None = None,
    learnable: bool = True,
    log_every: int = 50,
    seed: int = 0,
):
    """One worker attempt. Raises on (simulated) failure; restartable."""
    workdir = pathlib.Path(workdir)
    opt_cfg = opt_cfg or OptConfig(kind="adam", weight_decay=0.0, grad_clip=1.0)
    lr_sched = lr_sched or LRSchedule(
        kind="warmup_cosine", base_lr=3e-3, warmup_steps=min(100, steps // 10 + 1),
        total_steps=steps,
    )
    algo = make_algo(cfg, steps)
    state, axes, flags = init_train_state(jax.random.PRNGKey(seed), cfg, opt_cfg)

    ckpt = Checkpointer(workdir / "ckpt", every=ckpt_every)
    restored, rstep = ckpt.restore_or_none(state)
    if restored is not None:
        state = restored
        # re-pack against the RESTORED masks: covers pre-PackState
        # checkpoints (restore falls back to the template pack) and any
        # width drift between the fresh-init template and the saved run
        state = refresh_pack(state, cfg)
        print(f"[train] restored checkpoint at step {rstep}")

    train_step = jax.jit(make_train_step(cfg, opt_cfg, lr_sched), donate_argnums=0)
    rigl_step = jax.jit(make_rigl_step(cfg, algo, lr_sched), donate_argnums=0)
    prune_sched = PruningSchedule(
        cfg.sparse.sparsity, begin_step=steps // 8, end_step=int(steps * 0.75),
        prune_every=max(cfg.sparse.delta_t * 10, 1),
    )
    prune_fn = jax.jit(make_prune_fn(cfg, prune_sched)) if cfg.sparse.method == "pruning" else None

    sp = cfg.sparse
    if sp.method == "snip" and int(state["step"]) == 0:
        state = snip_init(state, cfg, batch_for(cfg, 0, batch, seq, learnable=learnable))
        state = refresh_pack(state, cfg)  # snip replaced the masks

    metrics_log = []
    topo_log = []  # per-update records, kept apart from the loss log
    topo_trace = TopologyTrace()  # graph-distance telemetry (core/topology.py)
    t0 = time.time()
    step = int(state["step"])
    while step < steps:
        b = batch_for(cfg, step, batch, seq, learnable=learnable)
        is_update = (
            sp.method in ("rigl", "set", "snfs", "topkast")
            and step > 0
            and step % sp.delta_t == 0
            and step < algo.schedule.t_end
        )
        if is_update:
            prev_masks = topo_trace.snapshot(state["masks"])
            state, m = rigl_step(state, b)
            # topology changed: re-pack the tight-grid block topology NOW so
            # the next delta_t train/serve steps run grids sized to the new
            # active counts (host-side, amortized — see core/pack.py)
            state = refresh_pack(state, cfg)
            rec = topo_trace.record(prev_masks, state["masks"], step=step)
            topo_log.append({"step": step, "topology": rec})
        else:
            state, m = train_step(state, b)
        if prune_fn is not None and step % prune_sched.prune_every == 0:
            state = prune_fn(state)
            state = refresh_pack(state, cfg)  # pruning moved the masks too
        step = int(state["step"])
        if preempt_at is not None and step == preempt_at:
            ckpt.maybe_save(state, step, force=True)
            ckpt.wait()
            raise SimulatedPreemption(f"preempted at step {step}")
        if step % log_every == 0 or step == steps:
            loss = float(m["loss"])
            rec = {"step": step, "loss": loss}
            if "pack_stale" in m:
                # staleness is sticky until the next refresh, so checking at
                # log cadence (not every step) still catches a missed
                # refresh_pack — and a nonzero value means the kernels are
                # executing the WRONG topology: fail fast, don't mistrain
                rec["pack_stale"] = stale = int(m["pack_stale"])
                if stale:
                    raise RuntimeError(
                        f"PackState is stale ({stale} blocks differ from the "
                        f"masks) at step {step} — a topology update ran "
                        "without refresh_pack(); see docs/kernels.md#staleness"
                    )
            metrics_log.append(rec)
            print(f"[train] step {step:6d} loss {loss:.4f} ({(time.time()-t0):.1f}s)")
        ckpt.maybe_save(state, step)
    ckpt.maybe_save(state, step, force=True)
    ckpt.wait()
    stats = mask_stats(state["masks"])
    (workdir / "result.json").write_text(
        json.dumps({
            "metrics": metrics_log,
            "sparsity": stats["sparsity"],
            "nnz": stats["nnz"],
            "topology": topo_trace.summary(),
            "topology_updates": topo_log,
        })
    )
    return state, metrics_log


def run_with_restarts(max_restarts: int = 3, **kw):
    """The fault-tolerance wrapper a cluster scheduler would drive."""
    attempt = 0
    while True:
        try:
            return train_loop(**kw)
        except SimulatedPreemption as e:
            attempt += 1
            print(f"[train] {e}; restart {attempt}/{max_restarts}")
            kw["preempt_at"] = None  # only preempt once in tests
            if attempt > max_restarts:
                raise


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="h2o-danube-1.8b")
    p.add_argument("--smoke", action="store_true")
    p.add_argument("--steps", type=int, default=300)
    p.add_argument("--batch", type=int, default=8)
    p.add_argument("--seq", type=int, default=64)
    p.add_argument("--method", default="rigl",
                   choices=["rigl", "set", "snfs", "topkast", "static", "snip",
                            "pruning", "dense"])
    p.add_argument("--sparsity", type=float, default=0.8)
    p.add_argument("--distribution", default="erk", choices=["uniform", "er", "erk"])
    p.add_argument("--delta-t", type=int, default=100)
    p.add_argument("--alpha", type=float, default=0.3)
    p.add_argument(
        "--kernel", default="dense", choices=["dense", "masked", "block_sparse"],
        help="execution path for sparsifiable matmuls (Pallas sparse kernels)",
    )
    p.add_argument(
        "--block", type=int, default=128,
        help="block edge for --kernel block_sparse (sets block_shape + tiles)",
    )
    p.add_argument("--workdir", default="/tmp/repro_train")
    p.add_argument("--preempt-at", type=int, default=None)
    p.add_argument("--max-restarts", type=int, default=3)
    args = p.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    method = args.method
    sparsity = 0.0 if method == "dense" else args.sparsity
    if method == "dense":
        method = "static"
    sparse_kw = dict(
        sparsity=sparsity, method=method,
        distribution=args.distribution, delta_t=args.delta_t, alpha=args.alpha,
        kernel=args.kernel,
    )
    if args.kernel == "block_sparse":
        # block-sparse execution needs a block-aligned topology (core.rigl
        # block mode) matching the kernel tiles
        sparse_kw["block_shape"] = (args.block, args.block)
        sparse_kw["kernel_block"] = (128, args.block, args.block)
    cfg = dataclasses.replace(cfg, sparse=SparseConfig(**sparse_kw))
    run_with_restarts(
        max_restarts=args.max_restarts,
        cfg=cfg,
        steps=args.steps,
        batch=args.batch,
        seq=args.seq,
        workdir=args.workdir,
        preempt_at=args.preempt_at,
    )


if __name__ == "__main__":
    main()

"""Pure-functional JAX model zoo with RigL-sparsifiable weights."""
from .attention import attn_schedules  # noqa: F401
from .layers import P, split_params  # noqa: F401
from .model import (  # noqa: F401
    cache_group,
    init_caches,
    init_lm,
    init_paged_caches,
    lm_decode,
    lm_forward,
    lm_loss,
    lm_prefill,
    lm_prefill_into,
    lm_prefill_suffix,
    logits_all_finite,
)

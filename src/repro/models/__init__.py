"""Pure-functional JAX model zoo with RigL-sparsifiable weights."""
from .attention import attn_schedules  # noqa: F401
from .layers import P, split_params  # noqa: F401
from .model import (  # noqa: F401
    init_caches,
    init_lm,
    lm_decode,
    lm_forward,
    lm_loss,
    lm_prefill,
    lm_prefill_into,
    logits_all_finite,
)

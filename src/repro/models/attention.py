"""GQA attention: causal / bidirectional / sliding-window / local:global,
RoPE, QK-norm, logit soft-capping, chunked long-sequence form, KV-cache decode.

Chunking is done with a *python* loop over query blocks so (a) local-attention
layers statically slice only the KV they need (real FLOP savings at 32k+), and
(b) XLA cost_analysis counts every chunk (lax.scan bodies are counted once —
see DESIGN.md §8).
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .layers import P, linear, linear_init, rmsnorm, rmsnorm_init

__all__ = [
    "attn_init",
    "attention",
    "attn_decode",
    "attn_schedules",
    "init_kv_cache",
    "init_kv_pool",
    "fill_kv_pool",
    "fill_kv_pool_suffix",
    "gather_kv_pool",
]

NEG_INF = -1e30  # large-negative instead of -inf: keeps softmax NaN-free in bf16


def attn_init(key, cfg, *, sparse: bool = True):
    d, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": {
            "w": P(
                _fan_in(ks[0], (d, H * hd)),
                ("embed", "heads"),
                sparse,
            )
        },
        "wk": {"w": P(_fan_in(ks[1], (d, KV * hd)), ("embed", "kv_heads"), sparse)},
        "wv": {"w": P(_fan_in(ks[2], (d, KV * hd)), ("embed", "kv_heads"), sparse)},
        "wo": {"w": P(_fan_in(ks[3], (H * hd, d)), ("heads", "embed"), sparse)},
    }
    if cfg.qk_norm:
        p["q_norm"] = rmsnorm_init(hd, axes=("head_dim",))
        p["k_norm"] = rmsnorm_init(hd, axes=("head_dim",))
    return p


def _fan_in(key, shape):
    return (jax.random.normal(key, shape) / np.sqrt(shape[0])).astype(jnp.float32)


def rope(x, positions, theta: float = 1e4):
    """x: (..., S, n, hd); positions: (S,) or (B, S)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = 1.0 / (theta ** (np.arange(0, half) / half))
    ang = jnp.asarray(positions, jnp.float32)[..., None] * freqs  # (..., S, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    # broadcast over head dim: (..., S, 1, half)
    cos, sin = cos[..., None, :], sin[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def _mask_of(masks, name):
    """Mask (or PackState entry) leaf for one projection (None when
    undispatched/legacy — both trees mirror the params structure)."""
    return None if masks is None else masks[name]["w"]


def _linear_kw(cfg, masks, name, pack=None):
    return dict(
        mask=_mask_of(masks, name),
        kernel=cfg.sparse.kernel,
        block=cfg.sparse.kernel_block,
        pack=_mask_of(pack, name),
    )


def _qkv(p, x, cfg, masks=None, pack=None):
    B, S, _ = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    q = linear(p["wq"], x, dt, **_linear_kw(cfg, masks, "wq", pack)).reshape(B, S, H, hd)
    k = linear(p["wk"], x, dt, **_linear_kw(cfg, masks, "wk", pack)).reshape(B, S, KV, hd)
    v = linear(p["wv"], x, dt, **_linear_kw(cfg, masks, "wv", pack)).reshape(B, S, KV, hd)
    if cfg.qk_norm:
        q = rmsnorm(p["q_norm"], q)
        k = rmsnorm(p["k_norm"], k)
    return q, k, v


def _scores(q, k, cfg):
    """q: (B, Sq, KV, G, hd); k: (B, Sk, KV, hd) -> (B, KV, G, Sq, Sk).

    fp32 by default; cfg.attn_scores_dtype="bfloat16" halves score HBM
    traffic (perf lever — quality validated at smoke scale in tests).
    """
    dt = (
        jnp.bfloat16
        if getattr(cfg, "attn_scores_dtype", "float32") == "bfloat16"
        else jnp.float32
    )
    q = q * float(1.0 / np.sqrt(cfg.head_dim))  # python float: weak-typed
    s = jnp.einsum("bqkgh,bskh->bkgqs", q, k, preferred_element_type=dt)
    if cfg.logit_softcap:
        c = cfg.logit_softcap
        s = c * jnp.tanh(s / c)
    return s


def _attend_block(q, k, v, mask, cfg):
    """One (q-block, kv-block) attention. mask: broadcastable (Sq, Sk) bool."""
    B, Sq, H, hd = q.shape
    KV = cfg.n_kv_heads
    G = H // KV
    qg = q.reshape(B, Sq, KV, G, hd)
    s = _scores(qg, k, cfg)
    if mask is not None:
        s = jnp.where(mask, s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1).astype(v.dtype)
    o = jnp.einsum("bkgqs,bskh->bqkgh", w, v)
    return o.reshape(B, Sq, H, hd)


def attn_schedules(cfg, S: int):
    """Host-build the per-layer-kind AttnSchedules for a length-S forward.

    Returns {kind: sched} for the attention kinds the layer stack uses
    ('global' and/or 'local'), or None when cfg.sparse.attn_kernel doesn't
    consume schedules.  Schedules are static-shape-derived (core/attn_sched),
    so this is a trace-time constant build — `serve_session` calls it once
    per session for explicitness; `attention` builds lazily when not given
    one.  Block sizes MUST match what the kernel will run, hence
    ``effective_blocks``.
    """
    if getattr(cfg.sparse, "attn_kernel", "dense") != "flash_tight":
        return None
    if cfg.block_type == "xlstm":
        return None  # no attention layers in the stack
    from ..core.attn_sched import sched_for
    from ..kernels.flash_attention import effective_blocks

    kinds = {cfg.layer_kind(i) for i in range(cfg.n_layers)}
    bq, bk = effective_blocks(S, S)
    return {
        kind: sched_for(
            S, S, bq, bk, cfg.causal, cfg.window if kind == "local" else 0, 0
        )
        for kind in kinds
    }


def _flash_attend(q, k, v, cfg, *, causal, window, tight, sched=None):
    """(B, S, H, hd) GQA heads -> flash kernel layout (B*H, S, hd) and back.

    Q heads fold into the kernel's batch dim; K/V fold to their UNREPEATED
    (B*KV, S, hd) layout and the kernels' index maps read row ``b // G``
    (``kv_groups``), so the G-fold repeated K/V copy the old dispatch
    materialized in HBM (G·S·d bytes written + re-read) never exists, and
    dk/dv come back already group-summed.  Scores exist only tile-wise in
    VMEM, fwd AND bwd (custom VJP), so ``attn_scores_dtype`` is moot on
    this path — the kernel accumulates f32.  ``cfg.logit_softcap`` caps the
    scaled scores inside the online softmax (fwd + VJP chain factor).
    """
    B, S, H, hd = q.shape
    KV = k.shape[2]
    fold = lambda t: t.transpose(0, 2, 1, 3).reshape(B * t.shape[2], S, hd)
    from ..kernels.flash_attention import flash_attention

    o = flash_attention(
        fold(q), fold(k), fold(v), causal=causal, window=window, sched=sched,
        tight=tight, softcap=float(cfg.logit_softcap or 0.0),
        kv_groups=H // KV,
    )
    return o.reshape(B, H, S, hd).transpose(0, 2, 1, 3)


def attention(
    p,
    x,
    cfg,
    *,
    kind: str = "global",
    positions=None,
    q_chunk: int = 4096,
    masks=None,
    pack=None,
    sched=None,
    history=None,
):
    """Full-sequence attention (train / prefill). Returns (out, (k, v)).

    kind: 'global' (full) or 'local' (sliding window cfg.window).
    Causality from cfg.causal (False => encoder, e.g. hubert).
    masks: the layer's attn mask subtree — routes wq/wk/wv/wo through the
    Pallas sparse kernels per cfg.sparse.kernel (None => legacy dense path).
    pack: matching PackState subtree — tight block_sparse grids (core/pack.py).
    sched: this kind's AttnSchedule (core/attn_sched.py) when
    cfg.sparse.attn_kernel == 'flash_tight'; None builds one lazily from the
    static shapes.  With attn_kernel in {'flash', 'flash_tight'} the score
    loop runs the Pallas flash kernels (fwd + custom-VJP bwd) instead of the
    chunked jnp path — tight mode launches only live KV blocks per q row.
    history: suffix-only prefill over a paged prefix (shared-prefix reuse,
    serving/engine.py): {"pool": init_kv_pool leaves, "table": (B, Hp) int32
    page ids, "ctx": (B,) traced valid-history lengths}.  ``x`` is then the
    SUFFIX only (``positions`` must carry its absolute offsets ctx..) and
    every query also attends the first ``ctx`` cached positions gathered
    through the table.  Global causal layers only.
    """
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.arange(S)
    q, k, v = _qkv(p, x, cfg, masks, pack)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)

    window = cfg.window if kind == "local" else 0
    attn_kernel = getattr(cfg.sparse, "attn_kernel", "dense")
    if attn_kernel not in ("dense", "flash", "flash_tight"):
        # validate at the point of use, not just validate_sparse_kernel
        # (which the drivers only reach when the WEIGHT kernel is non-dense):
        # a typo'd attn_kernel must never silently run the dense path
        raise ValueError(f"unknown sparse.attn_kernel {attn_kernel!r}")
    if history is not None:
        if kind != "global" or window or not cfg.causal:
            raise ValueError(
                "attention: history (shared-prefix suffix prefill) supports "
                "global causal layers only — the engine gates sharing to "
                "all-global configs (docs/serving.md#paged-kv-cache)"
            )
        o = _attend_with_history(
            q, k, v, history, cfg, flash=attn_kernel != "dense"
        )
    elif attn_kernel in ("flash", "flash_tight"):
        o = _flash_attend(
            q, k, v, cfg, causal=cfg.causal, window=window,
            tight=attn_kernel == "flash_tight", sched=sched,
        )
    elif S <= q_chunk:
        mask = _make_mask(S, 0, S, 0, cfg.causal, window)
        o = _attend_block(q, k, v, mask, cfg)
    else:
        assert S % q_chunk == 0, (S, q_chunk)
        outs = []
        for qs in range(0, S, q_chunk):
            qe = qs + q_chunk
            if cfg.causal:
                ks_ = max(0, qs - window + 1) if window else 0
                ke = qe
            else:
                ks_, ke = 0, S
            mask = _make_mask(q_chunk, qs, ke - ks_, ks_, cfg.causal, window)
            outs.append(
                _attend_block(
                    q[:, qs:qe], k[:, ks_:ke], v[:, ks_:ke], mask, cfg
                )
            )
        o = jnp.concatenate(outs, axis=1)
    out = linear(p["wo"], o.reshape(B, S, -1), **_linear_kw(cfg, masks, "wo", pack))
    return out, (k, v)


def _attend_with_history(q, k, v, history, cfg, *, flash):
    """Suffix-only prefill attention: paged prefix + causal self block.

    q/k/v: (B, S, H|KV, hd) for the SUFFIX positions ctx..ctx+S-1.  Each
    query attends [prefix keys gathered through the block table, live iff
    kpos < ctx] ++ [suffix keys, relative causal j <= i] — exactly the
    live-key set a full prefill's rows ctx.. see, so the downstream cached
    K/V and the last hidden state match a full prefill over prefix+suffix.

    dense: one concatenated ``_attend_block`` with a (B, 1, 1, S, Hlen+S)
    mask.  flash: ``flash_attention_paged`` walks the prefix pages through
    the scalar-prefetched table (prefix keys all precede every suffix
    query, so only the ctx clip masks), the existing causal flash kernel
    handles the self block, and the two phases merge by logsumexp — the
    paged phase emits lse = NEG_INF for rows with no live prefix key, so
    its weight underflows to exactly 0 in the merge.
    """
    B, S, H, hd = q.shape
    pool, table, ctx = history["pool"], history["table"], history["ctx"]
    ctx = jnp.asarray(ctx)
    if ctx.ndim == 0:
        ctx = jnp.full((B,), ctx)
    bs = pool["k"].shape[1]
    Hlen = table.shape[1] * bs
    if not flash:
        view = gather_kv_pool(pool, table)
        hk = view["k"].astype(k.dtype)
        hv = view["v"].astype(v.dtype)
        hist_m = jnp.arange(Hlen)[None, :] < ctx[:, None]  # (B, Hlen)
        self_m = jnp.arange(S)[:, None] >= jnp.arange(S)[None, :]  # (S, S)
        mask = jnp.concatenate(
            [
                jnp.broadcast_to(hist_m[:, None, :], (B, S, Hlen)),
                jnp.broadcast_to(self_m[None], (B, S, S)),
            ],
            axis=-1,
        )[:, None, None]  # broadcasts over scores (B, KV, G, S, Hlen + S)
        return _attend_block(
            q,
            jnp.concatenate([hk, k], axis=1),
            jnp.concatenate([hv, v], axis=1),
            mask,
            cfg,
        )
    from ..kernels.flash_attention import flash_attention, flash_attention_paged

    softcap = float(cfg.logit_softcap or 0.0)
    KV = k.shape[2]
    o_hist, l_hist = flash_attention_paged(
        q.transpose(0, 2, 1, 3), pool["k"], pool["v"], table, ctx,
        softcap=softcap,
    )  # (B, H, S, hd), (B, H, S)
    fold = lambda t: t.transpose(0, 2, 1, 3).reshape(B * t.shape[2], S, hd)
    o_self, l_self = flash_attention(
        fold(q), fold(k), fold(v), causal=True, window=0, return_lse=True,
        softcap=softcap, kv_groups=H // KV,
    )
    o_self = o_self.reshape(B, H, S, hd)
    l_self = l_self.reshape(B, H, S)  # finite: every row attends itself
    m = jnp.maximum(l_hist, l_self)
    w1 = jnp.exp(l_hist - m)[..., None]
    w2 = jnp.exp(l_self - m)[..., None]
    o = (w1 * o_hist.astype(jnp.float32) + w2 * o_self.astype(jnp.float32)) / (
        w1 + w2
    )
    return o.transpose(0, 2, 1, 3).astype(q.dtype)


def _make_mask(sq, q0, sk, k0, causal, window):
    if not causal and not window:
        return None
    qpos = q0 + jnp.arange(sq)[:, None]
    kpos = k0 + jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    return mask


# ---------------------------------------------------------------------------
# KV cache + decode
# ---------------------------------------------------------------------------

def init_kv_cache(cfg, kind: str, batch: int, max_len: int, dtype=jnp.bfloat16):
    """Cache shapes: local layers keep only a ring buffer of cfg.window."""
    size = min(cfg.window, max_len) if (kind == "local" and cfg.window) else max_len
    shape = (batch, size, cfg.n_kv_heads, cfg.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


# ---------------------------------------------------------------------------
# paged KV cache (pool + block-table addressing — serving/block_pool.py)
# ---------------------------------------------------------------------------

def init_kv_pool(cfg, n_blocks: int, page_size: int, dtype=jnp.bfloat16):
    """One layer's paged cache: ``n_blocks`` fixed-size KV pages.

    The contiguous (batch, size, KV, hd) row cache becomes a pool
    (n_blocks, page_size, KV, hd) shared by EVERY slot; a slot addresses
    position p through its block table as (table[p // page_size],
    p % page_size) — block-relative ring addressing, see ``attn_decode``.
    Physical page ids are group-wide (serving/block_pool.py): the same
    table row indexes the same page slice in every layer of the group.
    """
    shape = (n_blocks, page_size, cfg.n_kv_heads, cfg.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def gather_kv_pool(pool, table):
    """Materialize a slot-major contiguous view of paged caches.

    pool: {"k"/"v": (N, bs, KV, hd)}; table: (B, T) int32 page ids (the
    sentinel id N marks unowned entries — the gather CLIPS it, producing
    junk lanes that every consumer masks via its validity mask, exactly
    like the stale positions of a recycled contiguous slot).  Returns
    {"k"/"v": (B, T * bs, KV, hd)} — bit-identical to the contiguous
    cache the same writes would have produced, which is what makes the
    paged decode path token-identical to the contiguous one.
    """
    B, T = table.shape
    N, bs = pool["k"].shape[:2]
    tab = jnp.minimum(table, N - 1)  # clip the sentinel explicitly

    def g(leaf):
        return leaf[tab].reshape(B, T * bs, *leaf.shape[2:])

    return {"k": g(pool["k"]), "v": g(pool["v"])}


def fill_kv_pool(pool, row, table):
    """Scatter one prefilled contiguous cache ROW into the pool via a table.

    row: {"k"/"v": (1, size, KV, hd)} from the B=1 ``lm_prefill`` (ring
    alignment, bucketing and recurrent recompute all already handled by
    that battle-tested path); table: (T,) int32 with T * page_size == size.
    Unowned entries carry the sentinel id N and their pages are DROPPED
    (mode='drop'), so a partially-allocated table (short request in a long
    row) never clobbers page 0.  Owned entries are distinct pages, so the
    scatter has no duplicate indices.
    """
    N, bs = pool["k"].shape[:2]
    T = table.shape[0]

    def s(dst, src):
        src = src.reshape(T, bs, *src.shape[1:]).astype(dst.dtype)
        return dst.at[table].set(src, mode="drop")

    return {"k": s(pool["k"], row["k"][0]), "v": s(pool["v"], row["v"][0])}


def fill_kv_pool_suffix(pool, k, v, table, start, n_valid):
    """Scatter suffix K/V (already roped) at positions start..start+S-1.

    The block-relative generalization of ``fill_kv_cache``'s start-0 fill:
    position p lands at (table[p // bs], p % bs), so a suffix beginning at
    a traced ``start`` (shared-prefix admission, serving/engine.py) writes
    through the SAME table geometry decode uses.  Positions >= n_valid are
    bucket padding — their writes drop (sentinel page).  Global (linear)
    caches only: start + S <= table span, no ring wrap (the engine gates
    prefix sharing to all-global configs).
    """
    N, bs = pool["k"].shape[:2]
    S = k.shape[1]
    posv = start + jnp.arange(S)
    pg = table[jnp.minimum(posv // bs, table.shape[0] - 1)]
    pg = jnp.where(jnp.arange(S) < n_valid, pg, N)  # pad writes: drop
    off = posv % bs
    ck = pool["k"].at[pg, off].set(k[0].astype(pool["k"].dtype), mode="drop")
    cv = pool["v"].at[pg, off].set(v[0].astype(pool["v"].dtype), mode="drop")
    return {"k": ck, "v": cv}


def fill_kv_cache(cache, k, v, start: int = 0, n_valid=None):
    """Prefill: write computed k/v (already roped) into the cache.

    Windowed (ring) caches store position p at slot p % size, matching
    attn_decode's ring addressing — the kept tail is rolled accordingly.

    ``n_valid`` (traced int, requires start=0): positions >= n_valid are
    prompt PADDING (the serving engine buckets prompt lengths to bound the
    prefill trace count — serving/engine.py).  Pad writes must be dropped,
    not just masked later: on a wrapped ring a pad position p >= n_valid
    would land on slot p % size and clobber the still-needed K/V of true
    position p - size.  Each slot instead gathers the LATEST valid position
    that owns it (p ≡ slot mod size, p < n_valid), so the ring holds exactly
    the last `size` TRUE positions — identical to an exact-length fill.
    Slots no valid position reaches keep their prior (zero-init) contents,
    unreachable under attn_decode's `slot <= pos` validity mask.
    """
    S = k.shape[1]
    size = cache["k"].shape[1]
    if n_valid is not None:
        assert start == 0, "n_valid fill assumes a fresh prefill at start=0"
        W = min(S, size)
        s_idx = jnp.arange(W)
        lap = jnp.maximum((n_valid - 1 - s_idx) // size, 0)
        src = s_idx + size * lap  # latest valid position landing on slot s
        has = s_idx < n_valid  # n_valid >= size wraps: every slot is owned
        m = has[None, :, None, None]
        ck = cache["k"].at[:, :W].set(
            jnp.where(m, k[:, src].astype(cache["k"].dtype), cache["k"][:, :W])
        )
        cv = cache["v"].at[:, :W].set(
            jnp.where(m, v[:, src].astype(cache["v"].dtype), cache["v"][:, :W])
        )
        return {"k": ck, "v": cv}
    if S >= size:  # windowed cache: keep the last `size` positions, ring-aligned
        k, v = k[:, S - size :], v[:, S - size :]
        shift = (start + S - size) % size
        k = jnp.roll(k, shift, axis=1)
        v = jnp.roll(v, shift, axis=1)
        start = 0
    ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k.astype(cache["k"].dtype), start, 1)
    cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v.astype(cache["v"].dtype), start, 1)
    return {"k": ck, "v": cv}


def attn_decode(
    p, x_t, cache, pos, cfg, *, kind: str = "global", masks=None, pack=None,
    active=None, table=None,
):
    """One decode step.  x_t: (B, 1, d); pos: traced scalar OR (B,) vector.

    ``table`` (B, T) int32 switches to PAGED addressing: ``cache`` is then
    a pool {"k"/"v": (N, page_size, KV, hd)} (init_kv_pool) and position p
    writes at (table[b, slot // bs], slot % bs) where ``slot`` is the same
    ring/linear slot the contiguous path uses — ring addressing generalized
    to block-relative offsets.  Attention then runs on the table-gathered
    contiguous view (gather_kv_pool), whose bytes equal the contiguous
    cache's exactly, so paged decode is bit-identical to contiguous decode.
    Requires per-slot ``pos``; dead slots write to the sentinel page (drop).

    Windowed caches use ring addressing (softmax is permutation invariant —
    absolute positions are baked into the stored, roped keys).
    Returns (out (B,1,d), new_cache).  With ``masks``, the projections decode
    through the sparse kernels (serve path: weight-bound, so skipped blocks
    translate directly to HBM-traffic savings).  ``pack`` (PackState subtree)
    additionally shrinks each block_sparse grid to the true active count — it
    is packed once per topology and reused by every decode step.

    Per-slot decode (the continuous-batching engine, serving/engine.py): a
    ``pos`` VECTOR gives every batch row its own position — RoPE, the cache
    write slot (ring or linear) and the validity mask are all computed
    per-row, so rows at staggered depths step together in ONE launch.
    ``active`` (B,) bool then marks live slots: inactive rows' cache writes
    are dropped entirely (their k/v scatter targets an out-of-bounds slot,
    jnp ``mode='drop'``), making a dead slot's step a provable no-op on the
    cache — its (garbage) output is simply never read by the engine.
    ``active`` requires vector ``pos``; the scalar form keeps the exact
    legacy lockstep semantics (all rows share one position).
    """
    B = x_t.shape[0]
    pos = jnp.asarray(pos)
    per_slot = pos.ndim == 1
    if active is not None and not per_slot:
        raise ValueError("attn_decode: active-slot mask requires pos: (B,)")
    q, k, v = _qkv(p, x_t, cfg, masks, pack)
    posv = pos[:, None] if per_slot else jnp.full((1,), pos)
    q = rope(q, posv, cfg.rope_theta)
    k = rope(k, posv, cfg.rope_theta)

    ring = kind == "local" and cfg.window
    if table is not None:
        if not per_slot:
            raise ValueError("attn_decode: paged cache requires pos: (B,)")
        N, bs = cache["k"].shape[:2]
        size = table.shape[1] * bs
        slots = jnp.mod(pos, size) if ring else pos
        b_idx = jnp.arange(B)
        pg = table[b_idx, jnp.minimum(slots // bs, table.shape[1] - 1)]
        if active is not None:
            # dead slots write to the sentinel page -> dropped (pool untouched)
            pg = jnp.where(active, pg, N)
        off = slots % bs
        pool = {
            "k": cache["k"].at[pg, off].set(
                k[:, 0].astype(cache["k"].dtype), mode="drop"
            ),
            "v": cache["v"].at[pg, off].set(
                v[:, 0].astype(cache["v"].dtype), mode="drop"
            ),
        }
        view = gather_kv_pool(pool, table)
        ck, cv = view["k"], view["v"]
        new_cache = pool
        valid = jnp.arange(size)[None, :] <= pos[:, None]  # (B, size)
    elif per_slot:
        size = cache["k"].shape[1]
        slots = jnp.mod(pos, size) if ring else pos
        if active is not None:
            # dead slots write out of bounds -> dropped (cache rows untouched)
            slots = jnp.where(active, slots, size)
        b_idx = jnp.arange(B)
        ck = cache["k"].at[b_idx, slots].set(
            k[:, 0].astype(cache["k"].dtype), mode="drop"
        )
        cv = cache["v"].at[b_idx, slots].set(
            v[:, 0].astype(cache["v"].dtype), mode="drop"
        )
        new_cache = {"k": ck, "v": cv}
        valid = jnp.arange(size)[None, :] <= pos[:, None]  # (B, size)
    else:
        size = cache["k"].shape[1]
        slot = jnp.mod(pos, size) if ring else pos
        ck = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype), (0, slot, 0, 0))
        cv = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype), (0, slot, 0, 0))
        new_cache = {"k": ck, "v": cv}
        valid = (jnp.arange(size) <= pos)[None, :]  # ring: all valid once pos >= size

    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    qg = q.reshape(B, 1, KV, H // KV, hd)
    s = _scores(qg, ck, cfg)  # (B, KV, G, 1, size)
    s = jnp.where(valid[:, None, None, None, :], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1).astype(cv.dtype)
    o = jnp.einsum("bkgqs,bskh->bqkgh", w, cv).reshape(B, 1, H * hd)
    out = linear(p["wo"], o, **_linear_kw(cfg, masks, "wo", pack))
    return out, new_cache

"""The paper's §4.2 character-LM: embed(128) -> GRU(512) -> 256 -> 128 -> vocab.

GRU kernels and readout layers are RigL-sparsifiable (the paper sparsifies
these to 75%).  Used by benchmarks/char_lm.py to reproduce Figure 4-left.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .layers import P, linear, linear_init, split_params

__all__ = ["gru_lm_init", "gru_lm_apply"]


def gru_init(key, n_in: int, n_state: int, *, sparse: bool = True):
    k1, k2 = jax.random.split(key)
    return {
        "wx": _p(k1, (n_in, 3 * n_state), sparse),
        "wh": _p(k2, (n_state, 3 * n_state), sparse),
        "b": P(jnp.zeros((3 * n_state,)), (None,), False),
    }


def _p(key, shape, sparse):
    return {
        "w": P(
            (jax.random.normal(key, shape) / np.sqrt(shape[0])).astype(jnp.float32),
            ("embed", "mlp"),
            sparse,
        )
    }


def gru_apply(p, x, h0=None):
    """x: (B, S, n_in) -> (B, S, n_state)."""
    B, S, _ = x.shape
    n_state = p["wh"]["w"].shape[0]
    wx = linear(p["wx"], x, jnp.float32) + p["b"]  # (B,S,3n)
    if h0 is None:
        h0 = jnp.zeros((B, n_state), jnp.float32)
    wh_w = p["wh"]["w"]

    def step(h, wx_t):
        rz_h = h @ wh_w[:, : 2 * n_state]
        r = jax.nn.sigmoid(wx_t[:, :n_state] + rz_h[:, :n_state])
        z = jax.nn.sigmoid(wx_t[:, n_state : 2 * n_state] + rz_h[:, n_state:])
        c = jnp.tanh(wx_t[:, 2 * n_state :] + (r * h) @ wh_w[:, 2 * n_state :])
        h_new = (1 - z) * c + z * h
        return h_new, h_new

    h, hs = jax.lax.scan(step, h0, jnp.swapaxes(wx, 0, 1))
    return jnp.swapaxes(hs, 0, 1), h


def gru_lm_init(key, vocab: int = 256, d_embed: int = 128, d_state: int = 512):
    """Exact paper architecture (Appendix I)."""
    ks = jax.random.split(key, 5)
    tree = {
        "embed": {
            "table": P(
                (0.02 * jax.random.normal(ks[0], (vocab, d_embed))).astype(jnp.float32),
                ("vocab", "embed"),
                False,
            )
        },
        "gru": gru_init(ks[1], d_embed, d_state),
        "ro1": linear_init(ks[2], d_state, 256, ("embed", "mlp")),
        "ro2": linear_init(ks[3], 256, 128, ("embed", "mlp")),
        "head": linear_init(ks[4], 128, vocab, ("embed", "vocab")),
    }
    return split_params(tree)


def gru_lm_apply(params, tokens):
    """tokens: (B, S) -> logits (B, S, vocab)."""
    x = params["embed"]["table"][tokens]
    hs, _ = gru_apply(params["gru"], x)
    h = jax.nn.relu(linear(params["ro1"], hs, jnp.float32))
    h = jax.nn.relu(linear(params["ro2"], h, jnp.float32))
    return linear(params["head"], h, jnp.float32)

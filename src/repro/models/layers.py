"""Parameter primitives for the pure-functional model zoo.

Params are nested dicts of arrays.  At init time every leaf is a ``P`` bundle
carrying (value, logical_axes, sparsifiable); ``split_params`` separates the
three parallel trees.  Logical axes drive sharding (launch/sharding.py) and
``sparsifiable`` marks the weights RigL operates on.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "P",
    "split_params",
    "linear_init",
    "linear",
    "grouped_linear",
    "dispatch_kw",
    "assert_total_dispatch",
    "rmsnorm_init",
    "rmsnorm",
    "layernorm_init",
    "layernorm",
    "embed_init",
    "conv1d_causal_init",
    "conv1d_causal",
]


@dataclasses.dataclass
class P:
    """Init-time parameter bundle (NOT a pytree leaf in the final params)."""

    value: Any
    axes: tuple[str | None, ...]
    sparse: bool = False


def _is_p(x):
    return isinstance(x, P)


def split_params(tree):
    """Tree of P -> (params, axes, sparse_flags) with identical structure."""
    params = jax.tree_util.tree_map(lambda p: p.value, tree, is_leaf=_is_p)
    axes = jax.tree_util.tree_map(lambda p: p.axes, tree, is_leaf=_is_p)
    sparse = jax.tree_util.tree_map(lambda p: p.sparse, tree, is_leaf=_is_p)
    return params, axes, sparse


def truncated_normal_init(key, shape, scale, dtype):
    """Fan-in scaled init (matches the paper's conv/dense init spirit)."""
    stddev = scale / np.sqrt(max(shape[-2] if len(shape) >= 2 else shape[-1], 1))
    return (stddev * jax.random.truncated_normal(key, -2.0, 2.0, shape)).astype(dtype)


def linear_init(
    key,
    n_in: int,
    n_out: int,
    axes: tuple[str | None, ...] = ("embed", "mlp"),
    *,
    sparse: bool = True,
    scale: float = 1.0,
    dtype=jnp.float32,
    bias: bool = False,
):
    w = P(truncated_normal_init(key, (n_in, n_out), scale, dtype), axes, sparse)
    if not bias:
        return {"w": w}
    return {"w": w, "b": P(jnp.zeros((n_out,), dtype), (axes[-1],), False)}


def _block_mask(mask, bk: int, bn: int):
    """Elementwise (..., K, N) mask -> (..., K/bk, N/bn) block-activity mask.

    Same reduction as the host-side PackState build (one definition, so the
    traced fallback and the packed topology can never diverge); this wrapper
    just clamps the tiles to small layer dims.  A leading group dim (3-D
    weight banks) passes through, matching the grouped kernels.
    """
    from ..core.masks import block_mask_of

    *_, K, N = mask.shape
    return block_mask_of(mask, (min(bk, K), min(bn, N)))


def linear(
    p, x, compute_dtype=None, *, mask=None, kernel=None, block=(128, 128, 128),
    pack=None,
):
    """compute_dtype=None inherits x.dtype (the model's compute dtype flows
    from the embedding; f32 configs stay f32 end-to-end).

    Kernel dispatch (cfg.sparse.kernel): with ``mask`` given, the matmul is
    routed to the Pallas sparse kernels instead of materializing w*m in HBM —
      kernel='masked'        x @ (w⊙m) with the mask fused in-pipeline
      kernel='block_sparse'  skips inactive (bk x bn) blocks entirely (the
                             mask must be block-aligned; core.rigl block mode)
    Both carry custom-VJP Pallas backward kernels, so jax.grad of a dispatched
    layer stays sparse too.  mask=None or kernel='dense'/None falls back to
    the jnp reference path (w*m materialized — legacy behaviour).

    pack: this layer's PackState entry ({"idx", "cnt", ...} — core/pack.py).
    Consumed by kernel='block_sparse': the kernel grid is then sized to
    the entry's tight active-block count instead of the worst-case padded
    width the in-jit traced pack must assume.  The entry MUST describe the
    same topology as ``mask`` (the train/serve drivers refresh it on every
    RigL update; the pack_stale metric guards the invariant).  Entries
    carrying a Top-KAST backward superset route to the split-topology VJP:
    a ``bidx`` CSC view (block_sparse) or a ``{"bwd_mask": ...}`` carrier
    (masked — core/pack.py::build_bwd_carrier) widens ONLY the wgrad to the
    (k+Δ) superset; forward/dgrad stay on the tight mask.
    """
    dt = compute_dtype or x.dtype
    w = p["w"].astype(dt)
    if mask is not None and kernel in ("masked", "block_sparse"):
        from ..kernels import (
            block_sparse_linear,
            fused_block_sparse_linear,
            fused_masked_linear,
            masked_linear,
            topkast_masked_linear,
        )

        xc = x.astype(dt)
        fused = isinstance(pack, dict) and "mom" in pack
        if kernel == "masked":
            if fused:
                # fused wgrad->optimizer epilogue: the weight cotangent of
                # this call IS the new SGD momentum (docs/kernels.md)
                y = fused_masked_linear(
                    xc, w, mask, pack["mom"], pack["seed"],
                    mu=pack["mu"], wd=pack["wd"], sr=pack["sr"],
                    bwd_mask=pack.get("bwd_mask"), block=block,
                )
            elif isinstance(pack, dict) and "bwd_mask" in pack:
                y = topkast_masked_linear(
                    xc, w, mask, pack["bwd_mask"], block=block
                )
            else:
                y = masked_linear(xc, w, mask, block=block)
        elif fused:
            y = fused_block_sparse_linear(
                xc, w, pack["mom"], pack["seed"],
                mu=pack["mu"], wd=pack["wd"], sr=pack["sr"],
                block=block, pack=pack,
            )
        elif pack is not None:
            # full PackState entry: tight CSC for fwd/wgrad AND tight CSR
            # for the custom-VJP dgrad grid
            y = block_sparse_linear(xc, w, block=block, pack=pack)
        else:
            bm, bn, bk = block
            y = block_sparse_linear(
                xc, w, _block_mask(mask, bk, bn), block=block
            )
    else:
        if mask is not None:
            w = w * mask.astype(dt)
        y = x.astype(dt) @ w
    if "b" in p:
        y = y + p["b"].astype(dt)
    return y


def grouped_linear(
    w, x, compute_dtype=None, *, mask=None, kernel=None,
    block=(128, 128, 128), pack=None,
):
    """Grouped matmul dispatch: x (G, M, K) @ w (G, K, N) -> (G, M, N).

    The weight-BANK twin of ``linear`` — the single choke point for every
    grouped sparsifiable einsum: MoE per-expert ``ecd,edf->ecf`` (G = experts,
    models/moe.py) and xLSTM's per-head recurrent ``bnh,nhk->bnk`` (G = heads;
    the caller moves the group dim leading — models/xlstm.py).  ``w`` is the
    raw (G, K, N) weight array (some banks, e.g. sLSTM's ``r``, are bare
    leaves without a {"w": ...} bundle).

    Dispatch mirrors ``linear`` exactly:
      kernel='masked'        per-group fused-mask matmul, one launch
                             (ops.grouped_masked_linear)
      kernel='block_sparse'  per-group block skipping, stacked CSC/CSR packs
                             (ops.grouped_block_sparse_linear); ``pack`` is
                             this bank's grouped PackState entry
                             (idx (G, N/bn, width), ... — core/pack.py)
      else / mask=None       jnp.einsum("gmk,gkn->gmn") on w*m (legacy path)
    """
    dt = compute_dtype or x.dtype
    w = w.astype(dt)
    if mask is not None and kernel in ("masked", "block_sparse"):
        from ..kernels import (
            fused_grouped_block_sparse_linear,
            fused_grouped_masked_linear,
            grouped_block_sparse_linear,
            grouped_masked_linear,
            topkast_grouped_masked_linear,
        )

        xc = x.astype(dt)
        fused = isinstance(pack, dict) and "mom" in pack
        if kernel == "masked":
            if fused:
                return fused_grouped_masked_linear(
                    xc, w, mask, pack["mom"], pack["seed"],
                    mu=pack["mu"], wd=pack["wd"], sr=pack["sr"],
                    bwd_mask=pack.get("bwd_mask"), block=block,
                )
            if isinstance(pack, dict) and "bwd_mask" in pack:
                return topkast_grouped_masked_linear(
                    xc, w, mask, pack["bwd_mask"], block=block
                )
            return grouped_masked_linear(xc, w, mask, block=block)
        if fused:
            return fused_grouped_block_sparse_linear(
                xc, w, pack["mom"], pack["seed"],
                mu=pack["mu"], wd=pack["wd"], sr=pack["sr"],
                block=block, pack=pack,
            )
        if pack is not None:
            return grouped_block_sparse_linear(xc, w, block=block, pack=pack)
        bm, bn, bk = block
        return grouped_block_sparse_linear(
            xc, w, _block_mask(mask, bk, bn), block=block
        )
    if mask is not None:
        w = w * mask.astype(dt)
    return jnp.einsum("gmk,gkn->gmn", x.astype(dt), w)


def dispatch_kw(cfg, masks, name, pack=None):
    """Kernel-dispatch kwargs for one sparsifiable projection/bank.

    The shared helper behind every submodule's mask threading (ssm/xlstm/moe):
    looks up the ``{"w": ...}``-bundled mask and pack leaves for ``name`` and
    pairs them with the config's kernel selection — the exact keyword set
    ``linear``/``grouped_linear`` dispatch on, so a new dispatch knob only
    needs adding here.
    """
    return dict(
        mask=None if masks is None else masks[name]["w"],
        kernel=cfg.sparse.kernel,
        block=cfg.sparse.kernel_block,
        pack=None if pack is None else pack[name]["w"],
    )


def assert_total_dispatch(masks, consumed: tuple[str, ...], *, kernel=None,
                          where: str = "?", pack=None,
                          require_bwd: bool = False):
    """Loud guard against silent dense fallbacks (trace-time, free at run).

    In kernel-dispatch mode (``kernel`` in {'masked', 'block_sparse'}) every
    non-None mask leaf of a submodule's mask subtree must be consumed by a
    kernel-dispatching matmul (``linear``/``grouped_linear``).  A leftover
    leaf means the submodule would fall back to materializing w*m in HBM —
    the exact failure mode the total-dispatch contract forbids — so this
    raises instead of silently degrading.  ``consumed`` lists the subtree
    keys the caller routes through the kernels; mask structure is static, so
    the check runs once per trace and costs nothing per step.

    require_bwd (Top-KAST / SNFS-under-dispatch steps): additionally verify
    that every dispatched mask leaf's ``pack`` entry carries the backward-
    superset view (``bidx`` for block_sparse, ``bwd_mask`` carrier for
    masked) — i.e. the step's weight gradient runs on the (k+Δ) sparse grid
    and NO layer materializes a dense gradient.  In this mode ``masks`` is
    the full dispatched mask pytree (the ``consumed`` subtree check is
    skipped; the model's per-submodule calls already enforce it).
    """
    if masks is None or kernel in (None, "dense"):
        return
    flat, _ = jax.tree_util.tree_flatten_with_path(
        masks, is_leaf=lambda x: x is None
    )
    if require_bwd:
        from ..core.pack import is_pack_entry

        flat_e = jax.tree_util.tree_leaves(pack, is_leaf=is_pack_entry)
        missing = sorted(
            "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in p)
            for (p, m), e in zip(flat, flat_e)
            if m is not None
            and not (isinstance(e, dict) and ("bidx" in e or "bwd_mask" in e))
        )
        if missing:
            raise RuntimeError(
                f"{where}: mask leaves {missing} have no backward-superset "
                "pack view (bidx/bwd_mask) — their weight gradient would "
                "fall back to the forward topology or a dense matmul instead "
                "of the (k+Δ) superset grid; rebuild the pack with "
                "bwd_masks= (core/pack.py) — see docs/training.md#topkast"
            )
        return
    leftovers = sorted(
        {
            "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in p)
            for p, m in flat
            if m is not None
            and str(getattr(p[0], "key", getattr(p[0], "idx", p[0])))
            not in consumed
        }
    )
    if leftovers:
        raise RuntimeError(
            f"{where}: mask leaves {leftovers} have no kernel-dispatched "
            "consumer — they would silently fall back to dense w*m. Route "
            "them through layers.linear/grouped_linear or keep the weights "
            "dense; see docs/kernels.md#dispatch-coverage"
        )


def rmsnorm_init(d: int, axes=("embed",), dtype=jnp.float32):
    return {"scale": P(jnp.ones((d,), dtype), axes, False)}


def rmsnorm(p, x, eps: float = 1e-6):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)


def layernorm_init(d: int, axes=("embed",), dtype=jnp.float32):
    return {
        "scale": P(jnp.ones((d,), dtype), axes, False),
        "bias": P(jnp.zeros((d,), dtype), axes, False),
    }


def layernorm(p, x, eps: float = 1e-6):
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"] + p["bias"]).astype(x.dtype)


def embed_init(key, vocab: int, d: int, dtype=jnp.float32, sparse: bool = False):
    # Paper keeps embeddings dense (they scale with neurons, not connections).
    val = (jax.random.normal(key, (vocab, d)) * 0.02).astype(dtype)
    return {"table": P(val, ("vocab", "embed"), sparse)}


def embed_lookup(p, ids, compute_dtype=jnp.bfloat16):
    return p["table"].astype(compute_dtype)[ids]


def embed_logits(p, x, compute_dtype=jnp.bfloat16):
    """Tied read-out: x @ table.T (vocab-parallel under TP)."""
    return x.astype(compute_dtype) @ p["table"].astype(compute_dtype).T


def conv1d_causal_init(key, d: int, width: int, axes=("conv_k", "mlp"), dtype=jnp.float32):
    """Depthwise causal conv (mamba/mLSTM front conv). Kept dense (tiny)."""
    val = (jax.random.normal(key, (width, d)) / np.sqrt(width)).astype(dtype)
    return {"w": P(val, axes, False), "b": P(jnp.zeros((d,), dtype), (axes[-1],), False)}


def conv1d_causal(p, x, compute_dtype=None):
    """x: (B, S, d) depthwise causal conv along S."""
    compute_dtype = compute_dtype or x.dtype
    w = p["w"].astype(compute_dtype)  # (K, d)
    k = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    y = sum(pad[:, i : i + x.shape[1], :] * w[i] for i in range(k))
    return y + p["b"].astype(compute_dtype)


def conv1d_causal_step(p, state, x_t, compute_dtype=None):
    """Decode step: state (B, K-1, d) holds the last K-1 inputs."""
    compute_dtype = compute_dtype or x_t.dtype
    w = p["w"].astype(compute_dtype)
    k = w.shape[0]
    window = jnp.concatenate([state, x_t[:, None, :]], axis=1)  # (B, K, d)
    y = jnp.einsum("bkd,kd->bd", window, w) + p["b"].astype(compute_dtype)
    return window[:, 1:, :], y


# ---------------------------------------------------------------------------
# Masked 2D conv (paper's CNN experiments — WRN/CIFAR benchmark).
# ---------------------------------------------------------------------------

def conv2d_init(key, kh, kw, cin, cout, *, sparse=True, dtype=jnp.float32):
    fan_in = kh * kw * cin
    val = (jax.random.normal(key, (kh, kw, cin, cout)) / np.sqrt(fan_in)).astype(dtype)
    return {"w": P(val, ("conv_k", "conv_k", "embed", "mlp"), sparse)}


def conv2d(p, x, stride: int = 1, compute_dtype=None):
    """x: (B, H, W, C) -> (B, H', W', C'). SAME padding."""
    compute_dtype = compute_dtype or x.dtype
    return jax.lax.conv_general_dilated(
        x.astype(compute_dtype),
        p["w"].astype(compute_dtype),
        window_strides=(stride, stride),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )

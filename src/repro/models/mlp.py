"""Feed-forward blocks: SwiGLU / GeGLU / GELU — all RigL-sparsifiable.

When ``masks`` is given (kernel-dispatch mode, cfg.sparse.kernel != 'dense'),
each linear routes through the Pallas sparse kernels with its mask leaf; the
masked weights are never materialized in HBM (layers.linear dispatch).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import linear, linear_init

__all__ = ["mlp_init", "mlp"]


def mlp_init(key, d: int, d_ff: int, kind: str = "swiglu", *, sparse: bool = True):
    k1, k2, k3 = jax.random.split(key, 3)
    p = {}
    if kind in ("swiglu", "geglu"):
        p["wi"] = linear_init(k1, d, d_ff, ("embed", "mlp"), sparse=sparse)
        p["wg"] = linear_init(k2, d, d_ff, ("embed", "mlp"), sparse=sparse)
    else:
        p["wi"] = linear_init(k1, d, d_ff, ("embed", "mlp"), sparse=sparse)
    p["wo"] = linear_init(k3, d_ff, d, ("mlp", "embed"), sparse=sparse)
    return p


def _m(masks, name):
    return None if masks is None else masks[name]["w"]


def mlp(
    p, x, kind: str = "swiglu", *, masks=None, kernel=None,
    block=(128, 128, 128), pack=None,
):
    """pack: this MLP's PackState subtree (mirrors ``masks``) — sizes the
    block_sparse kernel grids to the true active-block count (core/pack.py)."""
    def kw(name):
        return dict(kernel=kernel, block=block, mask=_m(masks, name),
                    pack=_m(pack, name))

    h = linear(p["wi"], x, **kw("wi"))
    if kind == "swiglu":
        h = jax.nn.silu(linear(p["wg"], x, **kw("wg"))) * h
    elif kind == "geglu":
        h = jax.nn.gelu(linear(p["wg"], x, **kw("wg"))) * h
    elif kind == "gelu":
        h = jax.nn.gelu(h)
    elif kind == "relu":
        h = jax.nn.relu(h)
    else:
        raise ValueError(kind)
    return linear(p["wo"], h, **kw("wo"))

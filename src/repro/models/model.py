"""Unified LM: block composer + train forward / prefill / decode.

Supports the assigned families:
  transformer (dense GQA, SWA, local:global, parallel-block, MoE, encoder-only,
  VLM/audio frontend stubs), xlstm (mLSTM/sLSTM mix), hymba (parallel attn+SSM).

Params are nested dicts; ``init_lm`` returns (params, logical_axes, sparse_flags)
— axes drive sharding, sparse_flags mark RigL-managed weights.  The layer stack
is a python list (unrolled at trace time — exact cost_analysis); ``scan_layers``
switches to a stacked lax.scan for the full-depth memory proof on homogeneous
stacks.

Sparse-kernel dispatch is TOTAL: ``lm_forward``/``lm_loss``/``lm_prefill``/
``lm_decode`` take an optional ``masks`` pytree mirroring params.  When given,
EVERY sparsifiable weight einsum in EVERY family — transformer attention +
MLP, hymba SSM projections, xLSTM mLSTM/sLSTM projections (incl. the grouped
per-head recurrence), MoE expert banks + shared experts — routes through the
Pallas sparse kernels selected by ``cfg.sparse.kernel`` ('masked' fused-mask
matmul, 'block_sparse' block skipping; grouped variants for weight banks)
with custom-VJP backward kernels — masked weights are never materialized in
HBM, fwd or bwd.  The only non-dispatched params are genuinely non-matmul
leaves (scan carries, gates, convs, routers), which are dense and unmasked by
construction; ``layers.assert_total_dispatch`` turns any silent w*m fallback
into a loud error (see docs/kernels.md#dispatch-coverage).  masks=None keeps
the legacy contract (callers pre-mask via core.apply_masks).

All four entry points also take ``pack`` — a PackState pytree (core/pack.py)
mirroring the masks — which sizes every block_sparse kernel grid to the TRUE
active-block count instead of the in-jit worst case.  The train/serve drivers
carry it in state and refresh it only on RigL topology updates; see
docs/kernels.md for the end-to-end lifecycle.
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core.masks import apply_masks
from . import attention as A
from . import ssm as S
from . import xlstm as X
from .layers import P, linear, linear_init, rmsnorm, rmsnorm_init, split_params
from .mlp import mlp, mlp_init
from .moe import moe, moe_init

__all__ = [
    "init_lm",
    "lm_forward",
    "lm_loss",
    "init_caches",
    "init_paged_caches",
    "cache_group",
    "lm_prefill",
    "lm_prefill_into",
    "lm_prefill_suffix",
    "lm_decode",
    "logits_all_finite",
    "stack_layer_params",
]


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def padded_vocab(cfg) -> int:
    """Vocab padded to a multiple of 256 so the vocab dim always shards on a
    16-way model axis (MaxText-style). Pad logits are masked to -inf in
    _logits, so the model is mathematically identical to the exact vocab."""
    return ((cfg.vocab_size + 255) // 256) * 256


def _layer_init(key, cfg, i):
    ks = jax.random.split(key, 4)
    p: dict[str, Any] = {}
    if cfg.block_type == "xlstm":
        p["ln1"] = rmsnorm_init(cfg.d_model)
        if cfg.is_slstm(i):
            p["slstm"] = X.slstm_init(ks[0], cfg)
        else:
            p["mlstm"] = X.mlstm_init(ks[0], cfg)
        return p

    p["ln1"] = rmsnorm_init(cfg.d_model)
    p["attn"] = A.attn_init(ks[0], cfg)
    if cfg.block_type == "hymba":
        p["ssm"] = S.ssm_init(ks[1], cfg)
        p["attn_norm"] = rmsnorm_init(cfg.d_model)
        p["ssm_norm"] = rmsnorm_init(cfg.d_model)
    if not cfg.parallel_block:
        p["ln2"] = rmsnorm_init(cfg.d_model)
    if cfg.post_norms:
        p["ln1_post"] = rmsnorm_init(cfg.d_model)
        p["ln2_post"] = rmsnorm_init(cfg.d_model)
    if cfg.n_experts:
        p["moe"] = moe_init(ks[2], cfg)
    elif cfg.d_ff:
        p["mlp"] = mlp_init(ks[2], cfg.d_model, cfg.d_ff, cfg.mlp_kind)
    return p


def init_lm(key, cfg, *, return_bundles: bool = False):
    """Returns (params, axes, sparse_flags) trees."""
    ks = jax.random.split(key, cfg.n_layers + 4)
    tree: dict[str, Any] = {}
    d = cfg.d_model
    pv = padded_vocab(cfg)
    if cfg.frontend == "none":
        tree["embed"] = {
            "table": P(
                (0.02 * jax.random.normal(ks[-1], (pv, d))).astype(jnp.float32),
                ("vocab", "embed"),
                False,
            )
        }
    else:
        # frontend stub: precomputed patch/frame embeddings -> linear proj
        tree["frontend_proj"] = linear_init(
            ks[-2], cfg.frontend_dim, d, ("frontend", "embed"), sparse=False
        )
        if cfg.frontend == "patch":  # VLM also embeds text tokens
            tree["embed"] = {
                "table": P(
                    (0.02 * jax.random.normal(ks[-1], (pv, d))).astype(jnp.float32),
                    ("vocab", "embed"),
                    False,
                )
            }
    tree["layers"] = [_layer_init(ks[i], cfg, i) for i in range(cfg.n_layers)]
    tree["ln_f"] = rmsnorm_init(d)
    if not cfg.tie_embeddings or cfg.frontend == "frames":
        tree["head"] = linear_init(
            ks[-3], d, pv, ("embed", "vocab"), sparse=False
        )
    if return_bundles:
        return tree
    return split_params(tree)


def stack_layer_params(layers: list):
    """List of per-layer trees -> single tree stacked on a leading 'layers' dim."""
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *layers)


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def _sub(masks, key):
    """Mask subtree lookup tolerating masks=None (legacy pre-masked path)."""
    return None if masks is None else masks[key]


def _local_masked(p, masks, key, *, kernel):
    # NOTE: kernel is REQUIRED (no default) so the pre-total-dispatch call
    # shape `_local_masked(p, masks, key)` is a TypeError, not a silent
    # guard bypass.
    """Materialize w*m for a sparse submodule WITHOUT kernel dispatch.

    Since the total-dispatch PR, every matmul-bearing subtree (attn/mlp/ssm/
    xlstm/moe) threads masks into its own ``layers.linear``/``grouped_linear``
    calls, so this helper only remains for genuinely non-matmul leaves (scan
    carries, gates, convs — all dense and unmasked by construction) and as the
    loud guard: in kernel mode, routing a subtree that still carries mask
    leaves through here would silently fall back to dense w*m in HBM — the
    exact failure the total-dispatch contract forbids — so it raises instead.
    """
    if masks is None:
        return p[key]
    m = masks[key]
    if kernel in ("masked", "block_sparse") and any(
        l is not None
        for l in jax.tree_util.tree_leaves(m, is_leaf=lambda x: x is None)
    ):
        raise RuntimeError(
            f"_local_masked({key!r}): subtree carries mask leaves but "
            "cfg.sparse.kernel is set — this would silently materialize w*m "
            "instead of dispatching to the Pallas kernels. Thread masks= "
            "into the submodule (see docs/kernels.md#dispatch-coverage)"
        )
    return apply_masks(p[key], m)


def _block(p, x, cfg, i, *, positions=None, masks=None, pack=None,
           attn_sched=None, history=None):
    """Full-sequence block (train/prefill). Returns (x, kv_or_state, moe_aux).

    masks: this layer's mask subtree.  None => legacy behaviour (params are
    already w*m).  Given => EVERY sparsifiable matmul of the block —
    attention, MLP, SSM, mLSTM/sLSTM (grouped recurrence) and MoE banks —
    dispatches to the Pallas sparse kernels (cfg.sparse.kernel) and never
    materializes masked weights.
    pack: this layer's PackState subtree (mirrors masks) — block_sparse grids
    run at the true active-block count instead of the padded worst case.
    attn_sched: {kind: AttnSchedule} for cfg.sparse.attn_kernel='flash_tight'
    (models/attention.py::attn_schedules) — shared across layers of the same
    kind; None lets the attention build its schedule lazily at trace time.
    history: this layer's paged-prefix dict for suffix-only prefill
    (models/attention.py::attention ``history``) — shared-prefix serving.
    """
    aux = jnp.float32(0.0)
    if cfg.block_type == "xlstm":
        h = rmsnorm(p["ln1"], x, cfg.norm_eps)
        if cfg.is_slstm(i):
            o, state = X.slstm(
                p["slstm"], h, cfg,
                masks=_sub(masks, "slstm"), pack=_sub(pack, "slstm"),
            )
        else:
            o, state = X.mlstm(
                p["mlstm"], h, cfg, chunk=cfg.q_chunk,
                masks=_sub(masks, "mlstm"), pack=_sub(pack, "mlstm"),
            )
        return x + o, state, aux

    kind = cfg.layer_kind(i)
    h = rmsnorm(p["ln1"], x, cfg.norm_eps)
    attn_out, kv = A.attention(
        p["attn"], h, cfg, kind=kind, positions=positions, q_chunk=cfg.q_chunk,
        masks=_sub(masks, "attn"), pack=_sub(pack, "attn"),
        sched=None if attn_sched is None else attn_sched.get(kind),
        history=history,
    )
    state: Any = kv
    if cfg.block_type == "hymba":
        ssm_out, ssm_h = S.ssm(
            p["ssm"], h, cfg, chunk=cfg.q_chunk,
            masks=_sub(masks, "ssm"), pack=_sub(pack, "ssm"),
        )
        attn_out = 0.5 * (
            rmsnorm(p["attn_norm"], attn_out, cfg.norm_eps)
            + rmsnorm(p["ssm_norm"], ssm_out, cfg.norm_eps)
        )
        state = (kv, ssm_h, h)  # h tail needed for the conv state at prefill

    if cfg.post_norms:
        attn_out = rmsnorm(p["ln1_post"], attn_out, cfg.norm_eps)

    if cfg.parallel_block:
        ff_in = h
    else:
        x = x + attn_out
        ff_in = rmsnorm(p["ln2"], x, cfg.norm_eps)

    if cfg.n_experts:
        ff_out, aux = moe(
            p["moe"], ff_in, cfg,
            masks=_sub(masks, "moe"), pack=_sub(pack, "moe"),
        )
    elif cfg.d_ff:
        ff_out = mlp(
            p["mlp"], ff_in, cfg.mlp_kind, masks=_sub(masks, "mlp"),
            kernel=cfg.sparse.kernel, block=cfg.sparse.kernel_block,
            pack=_sub(pack, "mlp"),
        )
    else:
        ff_out = 0.0
    if cfg.post_norms and cfg.d_ff:
        ff_out = rmsnorm(p["ln2_post"], ff_out, cfg.norm_eps)

    if cfg.parallel_block:
        return x + attn_out + ff_out, state, aux
    return x + ff_out, state, aux


def _sp_constraint(x, cfg):
    """Megatron-style sequence parallelism: shard the residual stream's seq
    dim over the model axis between layers.  GSPMD then turns the TP psums
    into reduce-scatter + all-gather pairs (half the ICI bytes) and the remat
    residual saves shrink by the TP degree.  Needs an ambient mesh
    (jax.sharding.use_mesh) — the dry-run/train drivers provide one."""
    if not getattr(cfg, "seq_shard_activations", False):
        return x
    from jax.sharding import PartitionSpec as P

    U = P.UNCONSTRAINED
    try:
        return jax.lax.with_sharding_constraint(x, P(U, "model", U))
    except Exception:
        return x  # no ambient mesh: constraint unavailable, stay unsharded


def _embed_inputs(params, cfg, batch):
    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    if cfg.frontend == "frames":
        return linear(params["frontend_proj"], batch["frames"].astype(dt))
    x = params["embed"]["table"].astype(dt)[batch["tokens"]]
    x = x * np.sqrt(cfg.d_model)
    if cfg.frontend == "patch" and "patches" in batch:
        # decode steps omit "patches": the prompt's patch KV lives in the cache
        pe = linear(params["frontend_proj"], batch["patches"].astype(dt))
        x = jnp.concatenate([pe, x], axis=1)
    return x


def _logits(params, cfg, h):
    dt = h.dtype
    if "head" in params:
        out = linear(params["head"], h, dt)
    else:
        out = h @ params["embed"]["table"].astype(dt).T
    out = out.astype(jnp.float32)
    if cfg.final_softcap:
        c = cfg.final_softcap
        out = c * jnp.tanh(out / c)
    if out.shape[-1] != cfg.vocab_size:  # mask vocab-padding slots
        pad = out.shape[-1] - cfg.vocab_size
        neg = jnp.full((pad,), -1e30, out.dtype)
        out = jnp.concatenate(
            [out[..., : cfg.vocab_size], jnp.broadcast_to(neg, (*out.shape[:-1], pad))],
            axis=-1,
        )
    return out


def lm_forward(
    params, cfg, batch, *, collect_states: bool = False, masks=None, pack=None,
    attn_sched=None, positions=None, histories=None,
):
    """Full-sequence forward -> (hidden (B,S,d), states per layer, moe_aux).

    masks: mask pytree mirroring params (kernel-dispatch mode).  None keeps
    the legacy contract: callers pass pre-masked effective weights.
    pack: PackState pytree mirroring masks (core/pack.py) — block_sparse
    kernel grids are sized to the true active-block count (tight grids).
    attn_sched: {kind: AttnSchedule} for attn_kernel='flash_tight' (see
    models/attention.py::attn_schedules).  Unlike pack, schedules are
    STATIC-shape-derived, so None just builds them lazily at trace time —
    passing them is for explicit per-session threading (launch/serve.py).
    positions: absolute RoPE positions ((S,) or (B, S)); None = arange(S).
    histories: per-layer paged-prefix dicts for suffix-only prefill
    (lm_prefill_suffix) — ``batch`` is then the SUFFIX and ``positions``
    must carry its absolute offsets.  Unrolled collect_states path only.
    """
    if histories is not None:
        assert collect_states and not cfg.scan_layers, (
            "histories (suffix prefill) runs the unrolled collect_states path"
        )
        if attn_sched is None:
            attn_sched = {}  # self-phase flash scheds build lazily per shape
    x = _embed_inputs(params, cfg, batch)
    S_ = x.shape[1]
    if attn_sched is None:
        attn_sched = A.attn_schedules(cfg, S_)
    if positions is None:
        positions = jnp.arange(S_)
    aux_total = jnp.float32(0.0)
    states = []

    def _per_layer(tree):
        return tree["layers"] if tree is not None else [None] * cfg.n_layers

    if cfg.scan_layers:
        assert masks is None and pack is None, (
            "scan_layers (dry-run memory proof) does not thread masks/pack; "
            "pre-mask the stacked params instead"
        )
        x, states, aux_total = _forward_scanned(params, cfg, x, positions)
    elif cfg.remat and not collect_states:
        # checkpoint REGIONS of remat_group layers (sqrt-style remat): only
        # the region inputs are saved; kv/ssm states stay internal so they
        # are not forced live (outputs of a checkpoint are always saved).
        g = max(cfg.remat_group, 1)
        layer_ps = params["layers"]
        layer_ms = _per_layer(masks)
        layer_pk = _per_layer(pack)
        policy = (
            jax.checkpoint_policies.checkpoint_dots
            if getattr(cfg, "remat_policy", "none") == "dots"
            else None
        )

        def region(i0, ps, ms, pks, x_):
            aux_ = jnp.float32(0.0)
            for j, (p, m, pk) in enumerate(zip(ps, ms, pks)):
                x_, _, a = _block(
                    p, x_, cfg, i0 + j, positions=positions, masks=m, pack=pk,
                    attn_sched=attn_sched,
                )
                aux_ = aux_ + a
            return x_, aux_

        for i0 in range(0, cfg.n_layers, g):
            ps = layer_ps[i0 : i0 + g]
            ms = layer_ms[i0 : i0 + g]
            pks = layer_pk[i0 : i0 + g]
            x = _sp_constraint(x, cfg)
            x, aux = jax.checkpoint(
                functools.partial(region, i0), policy=policy
            )(ps, ms, pks, x)
            aux_total = aux_total + aux
    else:
        layer_ms = _per_layer(masks)
        layer_pk = _per_layer(pack)
        for i, p in enumerate(params["layers"]):
            x = _sp_constraint(x, cfg)
            x, st, aux = _block(
                p, x, cfg, i, positions=positions, masks=layer_ms[i],
                pack=layer_pk[i], attn_sched=attn_sched,
                history=None if histories is None else histories[i],
            )
            aux_total = aux_total + aux
            if collect_states:
                states.append(st)
    h = rmsnorm(params["ln_f"], x, cfg.norm_eps)
    return h, states, aux_total


def _forward_scanned(params, cfg, x, positions):
    """Homogeneous stacks only: lax.scan over stacked layer params."""
    assert cfg.pattern_period == 1 and cfg.block_type == "transformer", (
        "scan_layers requires a homogeneous transformer stack"
    )
    stacked = params["layers_stacked"]

    def body(carry, layer_p):
        x, aux = carry
        x, _, a = _block(layer_p, x, cfg, 0, positions=positions)
        return (x, aux + a), None

    if cfg.remat:
        body = jax.checkpoint(body)
    (x, aux), _ = jax.lax.scan(body, (x, jnp.float32(0.0)), stacked)
    return x, [], aux


def lm_loss(params, cfg, batch, masks=None, pack=None, attn_sched=None):
    """Mean next-token xent (chunked over seq to bound the logits buffer).

    masks != None => kernel-dispatch mode: params are RAW (unmasked) and the
    sparse topology is enforced inside the matmul kernels; jax.grad of this
    w.r.t. params then yields the paper's SPARSE gradient directly (the
    custom-VJP wgrad kernels fuse the g⊙m product).
    pack: PackState pytree (core/pack.py) — tight block_sparse grids in both
    the forward and the custom-VJP backward kernels.
    attn_sched: flash_tight KV-block schedules ({kind: sched}); None builds
    lazily — training with attn_kernel set runs flash fwd AND bwd (the loss
    is differentiated through the attention custom VJP, no jnp fallback).
    """
    h, _, aux = lm_forward(
        params, cfg, batch, masks=masks, pack=pack, attn_sched=attn_sched
    )
    targets = batch["targets"]
    # frontend==patch: loss only over the text positions (last T slots)
    if cfg.frontend == "patch":
        h = h[:, -targets.shape[1] :]
    B, S_, _ = h.shape
    n_chunks = max(1, cfg.loss_chunks)
    assert S_ % n_chunks == 0
    step = S_ // n_chunks
    total = jnp.float32(0.0)
    for s in range(0, S_, step):
        logits = _logits(params, cfg, h[:, s : s + step])
        lse = jax.nn.logsumexp(logits, axis=-1)
        tgt = targets[:, s : s + step]
        picked = jnp.take_along_axis(logits, tgt[..., None], axis=-1)[..., 0]
        total = total + jnp.sum(lse - picked)
    loss = total / (B * S_)
    return loss + 0.01 * aux


# ---------------------------------------------------------------------------
# serving: prefill + decode
# ---------------------------------------------------------------------------

def init_caches(cfg, batch: int, max_len: int):
    """Per-layer cache pytree (shapes differ per layer kind — unrolled only)."""
    caches = []
    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    for i in range(cfg.n_layers):
        if cfg.block_type == "xlstm":
            if cfg.is_slstm(i):
                caches.append({"slstm": X.init_slstm_state(cfg, batch)})
            else:
                caches.append({"mlstm": X.init_mlstm_state(cfg, batch)})
            continue
        kind = cfg.layer_kind(i)
        c: dict[str, Any] = {"kv": A.init_kv_cache(cfg, kind, batch, max_len, dt)}
        if cfg.block_type == "hymba":
            c["ssm"] = S.init_ssm_state(cfg, batch)
        caches.append(c)
    return caches


def cache_group(cfg, i: int) -> str:
    """Which page-pool GROUP layer i's KV cache belongs to ('global' at size
    max_len, 'local' ring at min(window, max_len)) — layers sharing a cache
    geometry share one physical page id space (serving/block_pool.py)."""
    return (
        "local"
        if (cfg.layer_kind(i) == "local" and cfg.window)
        else "global"
    )


def init_paged_caches(cfg, batch: int, max_len: int, n_blocks: dict,
                      page_size: int):
    """Paged variant of ``init_caches``: KV leaves become page POOLS.

    n_blocks: {'global': N, 'local': N} physical pages per cache group —
    every layer of a group addresses the same id space through the group's
    block table (serving/engine.py owns the tables; this is just storage).
    Recurrent per-slot states (hymba SSM, xLSTM carries) have no
    positional axis to page, so they stay slot-batched exactly as in
    ``init_caches`` — only position-indexed KV is pooled.
    """
    caches = []
    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    for i in range(cfg.n_layers):
        if cfg.block_type == "xlstm":
            if cfg.is_slstm(i):
                caches.append({"slstm": X.init_slstm_state(cfg, batch)})
            else:
                caches.append({"mlstm": X.init_mlstm_state(cfg, batch)})
            continue
        c: dict[str, Any] = {
            "kv": A.init_kv_pool(cfg, n_blocks[cache_group(cfg, i)],
                                 page_size, dt)
        }
        if cfg.block_type == "hymba":
            c["ssm"] = S.init_ssm_state(cfg, batch)
        caches.append(c)
    return caches


def lm_prefill(params, cfg, batch, max_len: int, *, masks=None, pack=None,
               attn_sched=None, n_valid=None):
    """Run the prompt, return (last-position logits, filled caches).

    pack: PackState pytree — prefill's block_sparse projections/MLPs run
    tight grids (see lm_decode for the per-token decode counterpart).
    attn_sched: flash_tight KV-block schedules for the prompt length
    ({kind: sched}, models/attention.py::attn_schedules) — serve threads one
    per session; None builds lazily.  Decode does NOT take schedules: a
    single-query step is a matvec over the (already window-bounded ring)
    cache — there is no dead score BLOCK to skip, so attn_decode stays on
    the jnp path by design (docs/kernels.md#attention-schedules).

    n_valid (traced int): sequence positions >= n_valid are END-PADDING —
    the serving engine buckets prompt lengths so one jitted trace serves a
    range of lengths (serving/engine.py).  Padding is exact for causal
    attention-only stacks: pads are strictly FUTURE positions (causal masks
    keep them out of every true query's softmax), their K/V writes are
    dropped by the masked fill (attention.py::fill_kv_cache — on a wrapped
    ring a pad write would clobber still-needed true K/V), and the returned
    logits come from position n_valid - 1, not the padded tail.  It is NOT
    exact for recurrent carries (hymba SSM h, xLSTM states — the final carry
    would include pad steps) or MoE routing (pad tokens would consume expert
    capacity), so the engine only buckets plain-transformer non-MoE configs;
    passing n_valid == S is exact for every family (and is how the engine's
    unbucketed configs exercise this path).
    """
    assert cfg.causal, "prefill/decode undefined for encoder-only models"
    h, states, _ = lm_forward(
        params, cfg, batch, collect_states=True, masks=masks, pack=pack,
        attn_sched=attn_sched,
    )
    B = h.shape[0]
    S_ = h.shape[1]
    caches = init_caches(cfg, B, max_len)
    layer_ms = masks["layers"] if masks is not None else [None] * cfg.n_layers
    layer_pk = pack["layers"] if pack is not None else [None] * cfg.n_layers
    for i, st in enumerate(states):
        if cfg.block_type == "xlstm":
            key = "slstm" if cfg.is_slstm(i) else "mlstm"
            caches[i][key] = st
            continue
        if cfg.block_type == "hymba":
            kv, ssm_h, pre = st
            caches[i]["ssm"]["h"] = ssm_h
            # conv state: last 3 *pre-conv* inner activations — the in_proj
            # recompute dispatches like any other sparse matmul
            ssm_p = params["layers"][i]["ssm"]
            m_ssm = _sub(layer_ms[i], "ssm")
            pk_ssm = _sub(layer_pk[i], "ssm")
            u_raw = linear(
                ssm_p["in_proj"], pre,
                mask=None if m_ssm is None else m_ssm["in_proj"]["w"],
                kernel=cfg.sparse.kernel, block=cfg.sparse.kernel_block,
                pack=None if pk_ssm is None else pk_ssm["in_proj"]["w"],
            )[..., : cfg.ssm_d_inner]
            conv_src = (
                u_raw[:, -3:, :] if n_valid is None
                else jax.lax.dynamic_slice_in_dim(u_raw, n_valid - 3, 3, 1)
            )
            caches[i]["ssm"]["conv"] = conv_src.astype(
                caches[i]["ssm"]["conv"].dtype
            )
        else:
            kv = st
        k, v = kv
        caches[i]["kv"] = A.fill_kv_cache(caches[i]["kv"], k, v, 0,
                                          n_valid=n_valid)
    h_last = (
        h[:, -1:] if n_valid is None
        else jax.lax.dynamic_slice_in_dim(h, n_valid - 1, 1, 1)
    )
    logits = _logits(params, cfg, h_last)
    return logits, caches


def lm_prefill_into(params, cfg, caches, batch, slot, max_len: int, *,
                    masks=None, pack=None, attn_sched=None, n_valid=None,
                    tables=None):
    """Prefill ONE prompt and scatter its state into batched caches at ``slot``.

    The continuous-batching admission path (serving/engine.py): ``caches`` is
    the engine's capacity-sized cache pytree (init_caches(cfg, capacity,
    max_len)), ``batch`` a single-prompt batch (B=1 tokens, optional patches),
    ``slot`` a traced int32 — one jitted trace per prompt LENGTH serves every
    slot.  Runs the ordinary ``lm_prefill`` at B=1 (so ring alignment, the
    hymba conv-state recompute and the xLSTM carries are all the battle-tested
    code path), then row-scatters every cache leaf into ``slot`` with a
    dynamic_update_slice — overwriting whatever the slot's previous (finished)
    request left behind.  Stale positions BEYOND the new prompt are not
    cleared: attn_decode's per-row validity mask (``arange(size) <= pos``)
    guarantees a position is never attended before the ring write that owns
    it, so recycled slots are reuse-safe by construction (tested in
    tests/test_serving_engine.py).

    Returns (last-position logits (1, 1, V), updated caches) — the logits
    produce the request's FIRST generated token, so a gen-N request costs
    exactly N-1 decode steps.

    ``n_valid``: traced count of TRUE (non-padding) sequence positions —
    the engine pads prompts up to a length bucket so one trace serves a
    range of lengths (see lm_prefill for exactness conditions and
    serving/engine.py for the bucketing policy).

    ``tables``: {'global'/'local': (T_g,) int32} page tables for THIS
    request's row — switches ``caches`` to the paged layout
    (init_paged_caches): KV leaves scatter page-wise through the table
    (attention.py::fill_kv_pool — unowned sentinel entries drop), recurrent
    leaves still row-scatter at ``slot``.  The interior prefill is the SAME
    B=1 contiguous-row pass either way, so ring alignment, bucketed-pad
    drops and recurrent recomputes are identical to the contiguous engine —
    which is what makes paged admission token-identical to contiguous.
    """
    logits, row = lm_prefill(
        params, cfg, batch, max_len=max_len, masks=masks, pack=pack,
        attn_sched=attn_sched, n_valid=n_valid,
    )

    def scatter(dst, src):
        return jax.lax.dynamic_update_slice(
            dst, src.astype(dst.dtype), (slot,) + (0,) * (dst.ndim - 1)
        )

    if tables is None:
        return logits, jax.tree_util.tree_map(scatter, caches, row)
    new = []
    for i, (c, r) in enumerate(zip(caches, row)):
        c = dict(c)
        for key in c:
            if key == "kv":
                c["kv"] = A.fill_kv_pool(
                    c["kv"], r["kv"], tables[cache_group(cfg, i)]
                )
            else:
                c[key] = jax.tree_util.tree_map(scatter, c[key], r[key])
        new.append(c)
    return logits, new


def lm_prefill_suffix(params, cfg, caches, batch, table, ctx, *, masks=None,
                      pack=None, n_valid=None):
    """Prefill only the SUFFIX of a prompt whose first ``ctx`` positions are
    already cached in the paged pools (shared-prefix admission,
    serving/engine.py): the whole point of prefix sharing is that the shared
    pages' K/V are never recomputed.

    caches: paged (init_paged_caches); table: (T_g,) int32 — the request's
    GLOBAL-group page table (shared/forked prefix pages first, fresh pages
    after; unowned tail = sentinel); ctx: traced int32 valid cached prefix
    length; batch: B=1 suffix tokens starting at absolute position ctx
    (bucket-padded — ``n_valid`` true suffix count).  Suffix queries attend
    [table-gathered prefix, causal self] (attention.py::
    _attend_with_history) with RoPE at ctx + arange(S), then the suffix K/V
    scatter block-relative at positions ctx.. (fill_kv_pool_suffix).
    Returns (logits at suffix position n_valid - 1, new caches).

    All-global causal transformer stacks only — no recurrent carries to
    replay and no MoE routing over pad tokens; the engine gates prefix
    sharing to exactly these configs.
    """
    assert cfg.causal and cfg.block_type == "transformer", (
        "suffix prefill: all-global causal transformer stacks only"
    )
    tokens = batch["tokens"]
    S_ = tokens.shape[1]
    positions = ctx + jnp.arange(S_)
    histories = [
        {"pool": caches[i]["kv"], "table": table[None], "ctx": ctx}
        for i in range(cfg.n_layers)
    ]
    h, states, _ = lm_forward(
        params, cfg, batch, collect_states=True, masks=masks, pack=pack,
        positions=positions, histories=histories,
    )
    new = []
    for i, st in enumerate(states):
        k, v = st
        new.append({
            "kv": A.fill_kv_pool_suffix(
                caches[i]["kv"], k, v, table, ctx,
                S_ if n_valid is None else n_valid,
            )
        })
    h_last = (
        h[:, -1:] if n_valid is None
        else jax.lax.dynamic_slice_in_dim(h, n_valid - 1, 1, 1)
    )
    logits = _logits(params, cfg, h_last)
    return logits, new


def logits_all_finite(logits):
    """Per-row all-finite reduction over a step's logits — the serving
    engine's in-flight failure detector (docs/serving.md#failure-model).

    logits: (B, V) or (B, 1, V) float.  Returns (B,) bool — True iff every
    logit of the row is finite.  Vocab-padding slots are masked to the
    FINITE sentinel -1e30 by ``_logits`` (never -inf), so a healthy forward
    is all-finite by construction and any NaN/Inf in a row is a real
    numerical fault on that slot.  Computed INSIDE the engine's jitted
    decode/prefill (serving/engine.py::_decode_fn) so the fast path stays
    one dispatch; the host reads one extra (B,) bool per step.
    """
    return jnp.all(jnp.isfinite(logits), axis=tuple(range(1, logits.ndim)))


def _gate_rows(active, new, old):
    """Freeze inactive batch rows of a recurrent-state pytree.

    ``active``: (B,) bool (None => passthrough).  Selects ``new`` rows where
    active, ``old`` rows where not — the recurrent twin of attn_decode's
    dropped cache writes, so a dead slot's decode step is a no-op on EVERY
    piece of per-slot state (KV cache, SSM h/conv, m/sLSTM carries).
    """
    if active is None:
        return new

    def sel(n, o):
        a = active.reshape((active.shape[0],) + (1,) * (n.ndim - 1))
        return jnp.where(a, n, o)

    return jax.tree_util.tree_map(sel, new, old)


def lm_decode(params, cfg, caches, tokens, pos, *, masks=None, pack=None,
              active=None, tables=None):
    """One decode step. tokens: (B, 1) int32; pos: traced scalar OR (B,).

    ``tables``: {'global'/'local': (B, T_g) int32} per-slot block tables —
    switches ``caches`` to the PAGED layout (init_paged_caches): each
    layer's KV step scatter-writes through its group's table and attends
    the table-gathered contiguous view, bit-identical to the contiguous
    cache (attention.py::attn_decode).  Requires per-slot ``pos``.

    Returns (logits (B,1,V), new caches).  With ``masks``, projections and
    MLPs decode through the Pallas sparse kernels (cfg.sparse.kernel) — the
    serve path is weight-bound, so block skipping cuts HBM traffic by the
    block density directly.  ``pack`` (PackState, core/pack.py) additionally
    sizes every block_sparse grid to the true active count; it is computed
    once per topology on the host and REUSED by every decode step — decode
    never re-packs.

    Per-slot decode (serving/engine.py): ``pos`` as a (B,) VECTOR steps every
    batch row at its own depth in one launch (per-row RoPE, ring slots and
    validity masks — see attention.py::attn_decode); ``active`` (B,) bool
    marks live slots — inactive rows' KV writes are dropped, their
    recurrent states (SSM/xLSTM) frozen, and their tokens excluded from MoE
    routing (a stale token must not consume per-expert capacity and perturb
    active rows' logits — moe.py), so a parked slot is bit-untouched AND
    side-effect-free until a new request is admitted into it
    (lm_prefill_into).  The scalar form is the legacy lockstep contract,
    unchanged.
    """
    assert cfg.causal
    x = _embed_inputs(params, cfg, {"tokens": tokens})
    new_caches = []
    layer_ms = masks["layers"] if masks is not None else [None] * cfg.n_layers
    layer_pk = pack["layers"] if pack is not None else [None] * cfg.n_layers
    for i, p in enumerate(params["layers"]):
        m = layer_ms[i]
        pk = layer_pk[i]
        c = dict(caches[i])
        if cfg.block_type == "xlstm":
            h = rmsnorm(p["ln1"], x, cfg.norm_eps)
            if cfg.is_slstm(i):
                o, new_st = X.slstm_decode(
                    p["slstm"], h, c["slstm"], cfg,
                    masks=_sub(m, "slstm"), pack=_sub(pk, "slstm"),
                )
                c["slstm"] = _gate_rows(active, new_st, c["slstm"])
            else:
                o, new_st = X.mlstm_decode(
                    p["mlstm"], h, c["mlstm"], cfg,
                    masks=_sub(m, "mlstm"), pack=_sub(pk, "mlstm"),
                )
                c["mlstm"] = _gate_rows(active, new_st, c["mlstm"])
            x = x + o
            new_caches.append(c)
            continue

        kind = cfg.layer_kind(i)
        h = rmsnorm(p["ln1"], x, cfg.norm_eps)
        attn_out, c["kv"] = A.attn_decode(
            p["attn"], h, c["kv"], pos, cfg, kind=kind, masks=_sub(m, "attn"),
            pack=_sub(pk, "attn"), active=active,
            table=None if tables is None else tables[cache_group(cfg, i)],
        )
        if cfg.block_type == "hymba":
            ssm_out, new_ssm = S.ssm_decode(
                p["ssm"], h, c["ssm"], cfg,
                masks=_sub(m, "ssm"), pack=_sub(pk, "ssm"),
            )
            c["ssm"] = _gate_rows(active, new_ssm, c["ssm"])
            attn_out = 0.5 * (
                rmsnorm(p["attn_norm"], attn_out, cfg.norm_eps)
                + rmsnorm(p["ssm_norm"], ssm_out, cfg.norm_eps)
            )
        if cfg.post_norms:
            attn_out = rmsnorm(p["ln1_post"], attn_out, cfg.norm_eps)
        if cfg.parallel_block:
            ff_in = h
        else:
            x = x + attn_out
            ff_in = rmsnorm(p["ln2"], x, cfg.norm_eps)
        if cfg.n_experts:
            # active threads into routing: a dead slot's stale token must not
            # consume per-expert capacity C (cross-token state — see moe.py)
            ff_out, _ = moe(
                p["moe"], ff_in, cfg, masks=_sub(m, "moe"),
                pack=_sub(pk, "moe"), active=active,
            )
        elif cfg.d_ff:
            ff_out = mlp(
                p["mlp"], ff_in, cfg.mlp_kind, masks=_sub(m, "mlp"),
                kernel=cfg.sparse.kernel, block=cfg.sparse.kernel_block,
                pack=_sub(pk, "mlp"),
            )
        else:
            ff_out = 0.0
        if cfg.post_norms and cfg.d_ff:
            ff_out = rmsnorm(p["ln2_post"], ff_out, cfg.norm_eps)
        x = (x + attn_out + ff_out) if cfg.parallel_block else (x + ff_out)
        new_caches.append(c)

    h = rmsnorm(params["ln_f"], x, cfg.norm_eps)
    return _logits(params, cfg, h), new_caches

"""Top-k routed Mixture-of-Experts with optional shared experts.

Sort-based "dropping" dispatch (MegaBlocks/MaxText style), all static shapes:
  1. router logits -> top_k experts + renormalized gates per token
  2. flatten (token, slot) assignments, rank them within each expert
     (argsort by expert id; stable => deterministic)
  3. scatter tokens into an (E, C, d) buffer (capacity C, overflow dropped)
  4. batched expert GEMMs  (E, C, d) x (E, d, ff)
  5. gather back + gate-weighted combine.

Expert weights carry logical axes ("experts", "embed", "moe_mlp"): the greedy
sharding resolver puts the mesh "model" axis on the experts dim when E
divides it (EP), otherwise on the ff dim (intra-expert TP) — grok-1 (8e on a
16-way axis) gets TP, qwen2-moe (60e) gets TP, a 16e config would get EP.

RigL treats each expert's weight matrices as sparsifiable layers; ER/ERK
budgets are computed from the full (E, d, ff) shapes.

Sparse-kernel dispatch: the three expert banks ``wi``/``wg``/``wo`` are
(E, d, ff)-shaped GROUPED weights — their per-expert ``ecd,edf->ecf`` einsums
route through ``layers.grouped_linear`` onto the grouped Pallas kernels (one
launch for all experts, stacked per-expert CSC/CSR packs in block_sparse
mode; see docs/kernels.md#grouped-packs).  The shared experts are an ordinary
MLP and dispatch through ``models/mlp.py``.  The router stays dense (tiny,
routing-critical).  A fully-dead expert (all blocks dropped) outputs zeros —
well-defined under routing; the pack build only rejects an all-zero BANK.
``assert_total_dispatch`` makes any silent w*m fallback loud.  SNFS cannot
run under dispatch — enforced in training/steps.py::make_train_step.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .layers import P, assert_total_dispatch, dispatch_kw as _bank_kw, grouped_linear
from .mlp import mlp, mlp_init

__all__ = ["moe_init", "moe"]

# sparse leaves routed through the kernels: the grouped expert banks plus the
# shared-expert MLP (dispatched inside models/mlp.py)
_DISPATCHED = ("wi", "wg", "wo", "shared")


def moe_init(key, cfg, *, sparse: bool = True):
    d, E, ff = cfg.d_model, cfg.n_experts, cfg.moe_d_ff
    ks = jax.random.split(key, 5)

    def bank(k, shape, axes):
        return {
            "w": P(
                (jax.random.normal(k, shape) / np.sqrt(shape[-2])).astype(jnp.float32),
                axes,
                sparse,
            )
        }

    p = {
        "router": {
            "w": P(
                (jax.random.normal(ks[0], (d, E)) / np.sqrt(d)).astype(jnp.float32),
                ("embed", None),
                False,  # router stays dense (tiny, routing-critical)
            )
        },
        "wi": bank(ks[1], (E, d, ff), ("experts", "embed", "moe_mlp")),
        "wg": bank(ks[2], (E, d, ff), ("experts", "embed", "moe_mlp")),
        "wo": bank(ks[3], (E, ff, d), ("experts", "moe_mlp", "embed")),
    }
    if cfg.n_shared_experts:
        p["shared"] = mlp_init(
            ks[4], d, ff * cfg.n_shared_experts, kind="swiglu", sparse=sparse
        )
    return p


def moe(p, x, cfg, *, masks=None, pack=None, active=None):
    """Routed-MoE forward.  x: (B, S, d) -> ((B, S, d), aux_loss).

    masks: this MoE's mask subtree (mirrors ``p``) — the expert banks
    ``wi``/``wg``/``wo`` dispatch as GROUPED kernels (one launch over all
    experts, per-expert topology) and the shared MLP through the 2-D kernels;
    None keeps the legacy pre-masked contract.  pack: matching PackState
    subtree — the banks' entries are grouped (leading expert dim, shared
    tight width; core/pack.py), the shared MLP's are plain 2-D entries.

    active: optional (B,) bool — the continuous-batching live-slot mask
    (models/model.py::lm_decode).  Routing has cross-token state: every
    (token, slot) assignment competes for the finite per-expert capacity C,
    rank priority going to lower row indices.  Without masking, a PARKED
    slot's stale token could push an active request's token out of capacity
    and silently change the active request's logits.  With ``active``,
    inactive rows' assignments are relabeled to the sentinel expert id E
    before the stable rank sort — they order after every real expert run
    (active tokens' ranks are exactly what they would be in an
    active-tokens-only batch) and are force-dropped, so dead slots are
    routing no-ops and contribute zero output.
    """
    assert_total_dispatch(
        masks, _DISPATCHED, kernel=cfg.sparse.kernel, where="moe"
    )
    B, S, d = x.shape
    E, K = cfg.n_experts, cfg.top_k
    capacity_factor = cfg.moe_capacity_factor
    T = B * S
    xt = x.reshape(T, d)
    dt = xt.dtype

    logits = jnp.einsum(
        "td,de->te", xt, p["router"]["w"].astype(dt), preferred_element_type=jnp.float32
    )
    probs = jax.nn.softmax(logits, axis=-1)
    gates, eidx = jax.lax.top_k(probs, K)  # (T, K)
    gates = gates / jnp.clip(jnp.sum(gates, -1, keepdims=True), 1e-9)

    # floor keeps single-token decode batches from starving an expert
    C = max(int(np.ceil(T * K / E * capacity_factor)), min(T, 4))
    # Rank each (token, slot) within its expert: stable argsort of expert ids.
    flat_e = eidx.reshape(-1)  # (T*K,)
    if active is not None:
        # dead slots route to the sentinel expert E: sorted past every real
        # run (no capacity consumed) and force-dropped below
        tok_act = jnp.broadcast_to(active[:, None], (B, S)).reshape(T)
        flat_e = jnp.where(jnp.repeat(tok_act, K), flat_e, E)
    order = jnp.argsort(flat_e, stable=True)
    # position within the sorted run of each expert id:
    sorted_e = flat_e[order]
    run_start = jnp.searchsorted(sorted_e, jnp.arange(E))  # (E,)
    pos_in_sorted = jnp.arange(T * K)
    rank_sorted = pos_in_sorted - run_start[sorted_e]
    rank = jnp.zeros_like(rank_sorted).at[order].set(rank_sorted)  # (T*K,)

    keep = (rank < C) & (flat_e < E)  # flat_e == E: inactive-row sentinel
    dest = jnp.where(keep, flat_e * C + rank, E * C)  # overflow -> scratch row
    buf = jnp.zeros((E * C + 1, d), dt).at[dest].set(
        jnp.repeat(xt, K, axis=0), mode="drop"
    )
    buf = buf[: E * C].reshape(E, C, d)

    # batched expert GEMMs — ONE grouped launch per bank in kernel mode
    h = grouped_linear(p["wi"]["w"], buf, dt, **_bank_kw(cfg, masks, "wi", pack))
    g = grouped_linear(p["wg"]["w"], buf, dt, **_bank_kw(cfg, masks, "wg", pack))
    h = jax.nn.silu(g) * h
    out_buf = grouped_linear(
        p["wo"]["w"], h, dt, **_bank_kw(cfg, masks, "wo", pack)
    )

    out_flat = out_buf.reshape(E * C, d)
    gathered = jnp.where(
        keep[:, None], out_flat[jnp.clip(dest, 0, E * C - 1)], 0.0
    )  # (T*K, d)
    combined = jnp.einsum(
        "tkd,tk->td", gathered.reshape(T, K, d), gates.astype(dt)
    )

    if "shared" in p:
        combined = combined + mlp(
            p["shared"], xt, kind="swiglu",
            masks=None if masks is None else masks["shared"],
            kernel=cfg.sparse.kernel, block=cfg.sparse.kernel_block,
            pack=None if pack is None else pack["shared"],
        )

    # load-balancing auxiliary loss (Switch-style), returned for training
    me = jnp.mean(jax.nn.one_hot(eidx, E, dtype=jnp.float32).sum(1), axis=0)
    ce = jnp.mean(probs, axis=0)
    aux = E * jnp.sum(me * ce) / K
    return combined.reshape(B, S, d), aux

"""Mamba-style selective SSM head (Hymba's parallel-SSM branch).

Chunked prefix-scan: a python loop over sequence chunks carries the state
(h: B, d_in, N) across chunks; *within* a chunk the linear recurrence
h_t = a_t * h_{t-1} + b_t is evaluated with jax.lax.associative_scan (log-depth
DAG — counted correctly by cost_analysis, unlike while-loops).  Decode is the
single-step recurrence (O(1) state — this is what makes long_500k decode
feasible for the hybrid archs).

Sparse-kernel dispatch: the two RigL-sparsifiable weights — ``in_proj``
(d, 2*d_in) and ``out_proj`` (d_in, d) — route through ``layers.linear`` with
their mask leaves, so with ``cfg.sparse.kernel`` in {'masked', 'block_sparse'}
they execute on the Pallas kernels (fwd AND custom-VJP bwd) and w*m never
materializes in HBM.  The selective-scan internals (``w_bc``, ``w_dt``, conv,
gates, the recurrence itself) are dense by design (tiny, routing-critical) and
carry no masks.  ``assert_total_dispatch`` makes any future silent fallback
loud.  SNFS cannot run under dispatch (it needs a dense gradient every step);
training/steps.py::make_train_step enforces that restriction for every family.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .layers import (
    P,
    assert_total_dispatch,
    conv1d_causal,
    conv1d_causal_init,
    conv1d_causal_step,
    dispatch_kw as _kw,
    linear,
)

__all__ = ["ssm_init", "ssm", "ssm_decode", "init_ssm_state"]

# sparse matmul leaves routed through layers.linear (the dispatch contract
# checked by assert_total_dispatch below)
_DISPATCHED = ("in_proj", "out_proj")


def ssm_init(key, cfg, *, sparse: bool = True):
    d, d_in, N = cfg.d_model, cfg.ssm_d_inner, cfg.ssm_state
    ks = jax.random.split(key, 6)

    def lin(k, nin, nout, axes, sp):
        return {
            "w": P(
                (jax.random.normal(k, (nin, nout)) / np.sqrt(nin)).astype(jnp.float32),
                axes,
                sp,
            )
        }

    a_init = -jnp.exp(
        jax.random.uniform(ks[4], (d_in, N), minval=np.log(0.5), maxval=np.log(8.0))
    )
    return {
        "in_proj": lin(ks[0], d, 2 * d_in, ("embed", "mlp"), sparse),
        "conv": conv1d_causal_init(ks[5], d_in, 4),
        "w_bc": lin(ks[1], d_in, 2 * N, ("mlp", None), False),
        "w_dt": lin(ks[2], d_in, d_in, ("mlp", "mlp2"), False),
        "a_log": P(jnp.log(-a_init), ("mlp", "state"), False),
        "d_skip": P(jnp.ones((d_in,)), ("mlp",), False),
        "dt_bias": P(jnp.zeros((d_in,)), ("mlp",), False),
        "out_proj": lin(ks[3], d_in, d, ("mlp", "embed"), sparse),
    }


def _gates(p, x, cfg, masks=None, pack=None):
    """Project input -> (u, z)."""
    d_in = cfg.ssm_d_inner
    uz = linear(p["in_proj"], x, **_kw(cfg, masks, "in_proj", pack))
    u, z = uz[..., :d_in], uz[..., d_in:]
    return u, z


def _selective(p, u, cfg):
    N = cfg.ssm_state
    bc = linear(p["w_bc"], u)
    Bt, Ct = bc[..., :N], bc[..., N:]
    dt = jax.nn.softplus(
        linear(p["w_dt"], u) + p["dt_bias"].astype(u.dtype)
    )  # (B,S,d_in)
    A = -jnp.exp(p["a_log"]).astype(jnp.float32)  # (d_in, N)
    a = jnp.exp(dt.astype(jnp.float32)[..., None] * A)  # (B,S,d_in,N)
    b = (dt * u).astype(jnp.float32)[..., None] * Bt.astype(jnp.float32)[..., None, :]
    return a, b, Ct


def _scan_chunk(a, b, h0):
    """h_t = a_t h_{t-1} + b_t within a chunk; h0: (B, d_in, N)."""
    def combine(l, r):
        return (l[0] * r[0], r[0] * l[1] + r[1])

    cum_a, acc = jax.lax.associative_scan(combine, (a, b), axis=1)
    h = cum_a * h0[:, None] + acc
    return h, h[:, -1]


def ssm(p, x, cfg, *, chunk: int = 1024, h0=None, masks=None, pack=None):
    """Selective-SSM forward.  x: (B, S, d) -> (out (B,S,d), state (B,d_in,N)).

    masks: this SSM's mask subtree (mirrors ``p``) — ``in_proj``/``out_proj``
    dispatch to the Pallas sparse kernels per ``cfg.sparse.kernel``; None
    keeps the legacy contract (params already pre-masked by the caller).
    pack: matching PackState subtree (core/pack.py) — tight block_sparse
    grids for both projections, fwd and custom-VJP bwd.
    """
    assert_total_dispatch(
        masks, _DISPATCHED, kernel=cfg.sparse.kernel, where="ssm"
    )
    B, S, _ = x.shape
    d_in, N = cfg.ssm_d_inner, cfg.ssm_state
    u, z = _gates(p, x, cfg, masks, pack)
    u = jax.nn.silu(conv1d_causal(p["conv"], u))
    a, b, Ct = _selective(p, u, cfg)

    if h0 is None:
        h0 = jnp.zeros((B, d_in, N), jnp.float32)
    hs = []
    for s in range(0, S, chunk):
        e = min(s + chunk, S)
        h_chunk, h0 = _scan_chunk(a[:, s:e], b[:, s:e], h0)
        hs.append(h_chunk)
    h = jnp.concatenate(hs, axis=1)  # (B,S,d_in,N)

    y = jnp.einsum("bsdn,bsn->bsd", h, Ct.astype(jnp.float32)).astype(x.dtype)
    y = y + u * p["d_skip"].astype(u.dtype)
    y = y * jax.nn.silu(z)
    return linear(p["out_proj"], y, **_kw(cfg, masks, "out_proj", pack)), h0


def init_ssm_state(cfg, batch: int):
    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    return {
        "h": jnp.zeros((batch, cfg.ssm_d_inner, cfg.ssm_state), jnp.float32),
        "conv": jnp.zeros((batch, 3, cfg.ssm_d_inner), dt),
    }


def ssm_decode(p, x_t, state, cfg, *, masks=None, pack=None):
    """Single-token step. x_t: (B, 1, d); state: {'h', 'conv'}.

    With ``masks``, ``in_proj``/``out_proj`` decode through the Pallas sparse
    kernels — the decode path is weight-bound, so skipped blocks translate
    directly into HBM-traffic savings.  ``pack`` is packed once per topology
    and reused every step (see models/model.py::lm_decode).
    """
    assert_total_dispatch(
        masks, _DISPATCHED, kernel=cfg.sparse.kernel, where="ssm_decode"
    )
    u, z = _gates(p, x_t, cfg, masks, pack)
    conv_state, u1 = conv1d_causal_step(p["conv"], state["conv"], u[:, 0])
    u = jax.nn.silu(u1)[:, None, :]
    a, b, Ct = _selective(p, u, cfg)
    h = a[:, 0] * state["h"] + b[:, 0]
    y = jnp.einsum("bdn,bn->bd", h, Ct[:, 0].astype(jnp.float32)).astype(x_t.dtype)
    y = y + u[:, 0] * p["d_skip"].astype(u.dtype)
    y = (y * jax.nn.silu(z[:, 0]))[:, None, :]
    out = linear(p["out_proj"], y, **_kw(cfg, masks, "out_proj", pack))
    return out, {"h": h, "conv": conv_state}

"""xLSTM blocks (Beck et al. 2024, arXiv:2405.04517): mLSTM + sLSTM.

mLSTM: matrix memory C (hd x hd per head) with exponential gating.  Training
uses the *chunkwise* stabilized parallel form (quadratic within a chunk,
recurrent across chunks — python loop so cost_analysis counts every chunk);
decode is the O(1) recurrence, giving constant-memory 500k-token decoding.

sLSTM: scalar memory with recurrent (block-diagonal per-head) weights — a true
nonlinear recurrence, evaluated with lax.scan over time (roofline FLOPs for
these layers are corrected analytically; see EXPERIMENTS.md).

Parameter-shape adaptation vs the official code is documented in DESIGN.md §5
(qkv are d->d; projection factor moved into the z-gate), keeping the assigned
48L/d2048/4H config at ~1.3B params.

Sparse-kernel dispatch: every RigL-sparsifiable weight here is a matmul and
routes through the Pallas kernels when ``cfg.sparse.kernel`` != 'dense' —
mLSTM's ``wq``/``wk``/``wv``/``wz``/``wo`` and sLSTM's ``w_in``/``wo``
through ``layers.linear``, and sLSTM's per-head recurrent bank ``r``
(nh, hd, 4hd) through ``layers.grouped_linear`` (the ``bnh,nhk->bnk`` einsum
becomes one GROUPED kernel launch per scan step after moving the head dim
leading).  Gates (``w_if``) and norms stay dense.  ``assert_total_dispatch``
makes any silent w*m fallback loud.  SNFS cannot run under dispatch (dense
gradient needed every step) — enforced in training/steps.py::make_train_step.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .layers import (
    P,
    assert_total_dispatch,
    dispatch_kw as _kw,
    grouped_linear,
    linear,
    rmsnorm,
    rmsnorm_init,
)

__all__ = [
    "mlstm_init",
    "mlstm",
    "mlstm_decode",
    "init_mlstm_state",
    "slstm_init",
    "slstm",
    "slstm_decode",
    "init_slstm_state",
]

# sparse matmul leaves routed through the kernels (assert_total_dispatch)
_MLSTM_DISPATCHED = ("wq", "wk", "wv", "wz", "wo")
_SLSTM_DISPATCHED = ("w_in", "r", "wo")


def _lin(k, nin, nout, axes, sparse):
    return {
        "w": P(
            (jax.random.normal(k, (nin, nout)) / np.sqrt(nin)).astype(jnp.float32),
            axes,
            sparse,
        )
    }


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def mlstm_init(key, cfg, *, sparse: bool = True):
    d, nh = cfg.d_model, cfg.n_heads
    ks = jax.random.split(key, 6)
    return {
        "wq": _lin(ks[0], d, d, ("embed", "heads"), sparse),
        "wk": _lin(ks[1], d, d, ("embed", "heads"), sparse),
        "wv": _lin(ks[2], d, d, ("embed", "heads"), sparse),
        "w_if": _lin(ks[3], d, 2 * nh, ("embed", None), False),
        "wz": _lin(ks[4], d, d, ("embed", "heads"), sparse),
        "wo": _lin(ks[5], d, d, ("heads", "embed"), sparse),
        "norm": rmsnorm_init(d // nh, axes=("head_dim",)),
    }


def _mlstm_qkv(p, x, cfg, masks=None, pack=None):
    B, S, d = x.shape
    nh = cfg.n_heads
    hd = d // nh
    q = linear(p["wq"], x, **_kw(cfg, masks, "wq", pack)).reshape(B, S, nh, hd)
    k = linear(p["wk"], x, **_kw(cfg, masks, "wk", pack)).reshape(
        B, S, nh, hd
    ) / np.sqrt(hd)
    v = linear(p["wv"], x, **_kw(cfg, masks, "wv", pack)).reshape(B, S, nh, hd)
    gif = linear(p["w_if"], x).astype(jnp.float32)  # (B,S,2nh)
    i_pre, f_pre = gif[..., :nh], gif[..., nh:]
    logf = jax.nn.log_sigmoid(f_pre)  # (B,S,nh)
    return q, k, v, i_pre, logf


def init_mlstm_state(cfg, batch: int):
    nh = cfg.n_heads
    hd = cfg.d_model // nh
    return {
        "C": jnp.zeros((batch, nh, hd, hd), jnp.float32),
        "n": jnp.zeros((batch, nh, hd), jnp.float32),
        "m": jnp.full((batch, nh), -1e30, jnp.float32),
    }


def mlstm(p, x, cfg, *, chunk: int = 1024, state=None, masks=None, pack=None):
    """Chunkwise parallel mLSTM. Returns (out (B,S,d), final_state).

    masks: this block's mask subtree — ``wq``/``wk``/``wv``/``wz``/``wo``
    dispatch to the Pallas sparse kernels per ``cfg.sparse.kernel`` (None =>
    legacy pre-masked params).  pack: matching PackState subtree
    (core/pack.py) — tight block_sparse grids, fwd and custom-VJP bwd.
    """
    assert_total_dispatch(
        masks, _MLSTM_DISPATCHED, kernel=cfg.sparse.kernel, where="mlstm"
    )
    B, S, d = x.shape
    nh = cfg.n_heads
    hd = d // nh
    q, k, v, i_pre, logf = _mlstm_qkv(p, x, cfg, masks, pack)
    if state is None:
        state = init_mlstm_state(cfg, B)
    C, n, m = state["C"], state["n"], state["m"]

    outs = []
    for s in range(0, S, min(chunk, S)):
        e = min(s + chunk, S)
        L = e - s
        qc, kc, vc = q[:, s:e], k[:, s:e], v[:, s:e]
        ic, fc = i_pre[:, s:e], logf[:, s:e]

        F = jnp.cumsum(fc, axis=1)  # (B,L,nh) cumulative logf within chunk
        # intra-chunk log decay D[t, u] = F_t - F_u + i_u  (u <= t)
        D = F[:, :, None, :] - F[:, None, :, :] + ic[:, None, :, :]  # (B,t,u,nh)
        tril = jnp.tril(jnp.ones((L, L), bool))
        D = jnp.where(tril[None, :, :, None], D, -jnp.inf)
        m_intra = jnp.max(D, axis=2)  # (B,L,nh)
        m_t = jnp.maximum(F + m[:, None, :], m_intra)  # (B,L,nh)

        scores = jnp.einsum("blnh,bunh->blun", qc, kc, preferred_element_type=jnp.float32)
        w = scores * jnp.exp(D - m_t[:, :, None, :])
        num_intra = jnp.einsum("blun,bunh->blnh", w.astype(vc.dtype), vc).astype(jnp.float32)
        den_intra = jnp.sum(w, axis=2)  # (B,L,nh)

        inter_scale = jnp.exp(F + m[:, None, :] - m_t)  # (B,L,nh)
        qC = jnp.einsum("blnh,bnhv->blnv", qc.astype(jnp.float32), C)
        qn = jnp.einsum("blnh,bnh->bln", qc.astype(jnp.float32), n)
        num = num_intra + inter_scale[..., None] * qC
        den = den_intra + inter_scale * qn
        denom = jnp.maximum(jnp.abs(den), jnp.exp(-m_t))
        h = (num / denom[..., None]).astype(x.dtype)  # (B,L,nh,hd)
        outs.append(h)

        # state update to end of chunk
        F_L = F[:, -1]  # (B,nh)
        m_new = jnp.maximum(
            F_L + m, jnp.max(F_L[:, None] - F + ic, axis=1)
        )  # (B,nh)
        wgt = jnp.exp(F_L[:, None] - F + ic - m_new[:, None])  # (B,L,nh)
        C = jnp.exp(F_L + m - m_new)[:, :, None, None] * C + jnp.einsum(
            "bunh,bunv,bun->bnhv",
            kc.astype(jnp.float32),
            vc.astype(jnp.float32),
            wgt,
        )
        n = jnp.exp(F_L + m - m_new)[:, :, None] * n + jnp.einsum(
            "bunh,bun->bnh", kc.astype(jnp.float32), wgt
        )
        m = m_new

    h = jnp.concatenate(outs, axis=1)  # (B,S,nh,hd)
    h = rmsnorm(p["norm"], h)
    h = h.reshape(B, S, d) * jax.nn.silu(
        linear(p["wz"], x, **_kw(cfg, masks, "wz", pack))
    )
    out = linear(p["wo"], h, **_kw(cfg, masks, "wo", pack))
    return out, {"C": C, "n": n, "m": m}


def mlstm_decode(p, x_t, state, cfg, *, masks=None, pack=None):
    """Single-step recurrence. x_t: (B,1,d).

    With ``masks``, the five projections decode through the Pallas sparse
    kernels (weight-bound path — skipped blocks cut HBM traffic directly);
    ``pack`` is packed once per topology and reused by every decode step.
    """
    assert_total_dispatch(
        masks, _MLSTM_DISPATCHED, kernel=cfg.sparse.kernel, where="mlstm_decode"
    )
    B, _, d = x_t.shape
    nh = cfg.n_heads
    hd = d // nh
    q, k, v, i_pre, logf = _mlstm_qkv(p, x_t, cfg, masks, pack)
    q, k, v = q[:, 0], k[:, 0], v[:, 0]
    i_pre, logf = i_pre[:, 0], logf[:, 0]  # (B,nh)

    C, n, m = state["C"], state["n"], state["m"]
    m_new = jnp.maximum(logf + m, i_pre)
    f_s = jnp.exp(logf + m - m_new)[:, :, None, None]
    i_s = jnp.exp(i_pre - m_new)[:, :, None, None]
    C = f_s * C + i_s * jnp.einsum(
        "bnh,bnv->bnhv", k.astype(jnp.float32), v.astype(jnp.float32)
    )
    n = f_s[..., 0] * n + i_s[..., 0] * k.astype(jnp.float32)
    num = jnp.einsum("bnh,bnhv->bnv", q.astype(jnp.float32), C)
    den = jnp.einsum("bnh,bnh->bn", q.astype(jnp.float32), n)
    denom = jnp.maximum(jnp.abs(den), jnp.exp(-m_new))
    h = (num / denom[..., None]).astype(x_t.dtype)[:, None]  # (B,1,nh,hd)
    h = rmsnorm(p["norm"], h).reshape(B, 1, d) * jax.nn.silu(
        linear(p["wz"], x_t, **_kw(cfg, masks, "wz", pack))
    )
    out = linear(p["wo"], h, **_kw(cfg, masks, "wo", pack))
    return out, {"C": C, "n": n, "m": m_new}


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def slstm_init(key, cfg, *, sparse: bool = True):
    d, nh = cfg.d_model, cfg.n_heads
    hd = d // nh
    ks = jax.random.split(key, 3)
    return {
        "w_in": _lin(ks[0], d, 4 * d, ("embed", "heads"), sparse),
        "r": P(
            (jax.random.normal(ks[1], (nh, hd, 4 * hd)) / np.sqrt(hd)).astype(
                jnp.float32
            ),
            ("kv_heads", "head_dim", None),
            sparse,
        ),
        "wo": _lin(ks[2], d, d, ("heads", "embed"), sparse),
        "norm": rmsnorm_init(hd, axes=("head_dim",)),
    }


def init_slstm_state(cfg, batch: int):
    nh = cfg.n_heads
    hd = cfg.d_model // nh
    z = lambda: jnp.zeros((batch, nh, hd), jnp.float32)
    return {"c": z(), "n": z(), "h": z(), "m": jnp.full((batch, nh, hd), -1e30)}


def _recurrent(p, h, cfg, masks=None, pack=None):
    """Per-head recurrent projection: ``bnh,nhk->bnk`` on the (nh, hd, 4hd)
    bank ``r`` — the grouped-kernel reshape shim.

    The head dim moves leading ((B, nh, hd) -> (nh, B, hd)) so the einsum is
    a grouped matmul: group g computes h[:, g] @ r[g].  One grouped Pallas
    launch covers all heads (layers.grouped_linear -> kernels/ops.py); the
    dense fallback is the identical einsum.  Runs once per scan step — the
    recurrence is sequential in time, but sparse in weights.
    """
    rec = grouped_linear(
        p["r"],
        jnp.swapaxes(h, 0, 1),
        jnp.float32,
        mask=None if masks is None else masks["r"],
        kernel=cfg.sparse.kernel,
        block=cfg.sparse.kernel_block,
        pack=None if pack is None else pack["r"],
    )
    return jnp.swapaxes(rec, 0, 1)  # (B, nh, 4hd)


def _slstm_cell(p, state, wx_t, cfg, masks=None, pack=None):
    """wx_t: (B, 4d) input contribution at step t."""
    nh = cfg.n_heads
    hd = cfg.d_model // nh
    B = wx_t.shape[0]
    c, n, h, m = state["c"], state["n"], state["h"], state["m"]
    rec = _recurrent(p, h, cfg, masks, pack)  # (B,nh,4hd)
    g = wx_t.reshape(B, nh, 4 * hd).astype(jnp.float32) + rec
    z_pre, i_pre, f_pre, o_pre = jnp.split(g, 4, axis=-1)  # each (B,nh,hd)
    logf = jax.nn.log_sigmoid(f_pre)
    m_new = jnp.maximum(logf + m, i_pre)
    i_s = jnp.exp(i_pre - m_new)
    f_s = jnp.exp(logf + m - m_new)
    z = jnp.tanh(z_pre)
    c = f_s * c + i_s * z
    n = f_s * n + i_s
    h_new = jax.nn.sigmoid(o_pre) * c / jnp.maximum(n, 1e-6)
    return {"c": c, "n": n, "h": h_new, "m": m_new}


def slstm(p, x, cfg, *, state=None, masks=None, pack=None):
    """sLSTM forward.  x: (B,S,d) -> (out, final_state); lax.scan over time.

    masks: this block's mask subtree — ``w_in``/``wo`` dispatch through
    ``layers.linear`` and the per-head recurrent bank ``r`` through
    ``layers.grouped_linear`` (grouped kernels, one launch per step).  None
    keeps the legacy pre-masked contract.  pack: matching PackState subtree;
    ``r``'s entry is GROUPED (leading head dim — core/pack.py).
    """
    assert_total_dispatch(
        masks, _SLSTM_DISPATCHED, kernel=cfg.sparse.kernel, where="slstm"
    )
    B, S, d = x.shape
    nh = cfg.n_heads
    hd = d // nh
    wx = linear(p["w_in"], x, **_kw(cfg, masks, "w_in", pack))  # (B,S,4d)
    if state is None:
        state = init_slstm_state(cfg, B)

    def step(carry, wx_t):
        new = _slstm_cell(p, carry, wx_t, cfg, masks, pack)
        return new, new["h"]

    state, hs = jax.lax.scan(step, state, jnp.swapaxes(wx, 0, 1))
    h = jnp.swapaxes(hs, 0, 1).astype(x.dtype)  # (B,S,nh,hd)
    h = rmsnorm(p["norm"], h).reshape(B, S, d)
    return linear(p["wo"], h, **_kw(cfg, masks, "wo", pack)), state


def slstm_decode(p, x_t, state, cfg, *, masks=None, pack=None):
    """One decode step; same dispatch contract as ``slstm`` (pack reused)."""
    assert_total_dispatch(
        masks, _SLSTM_DISPATCHED, kernel=cfg.sparse.kernel, where="slstm_decode"
    )
    B, _, d = x_t.shape
    nh = cfg.n_heads
    hd = d // nh
    wx = linear(p["w_in"], x_t, **_kw(cfg, masks, "w_in", pack))[:, 0]
    state = _slstm_cell(p, state, wx, cfg, masks, pack)
    h = state["h"][:, None].astype(x_t.dtype)  # (B,1,nh,hd)
    h = rmsnorm(p["norm"], h).reshape(B, 1, d)
    return linear(p["wo"], h, **_kw(cfg, masks, "wo", pack)), state

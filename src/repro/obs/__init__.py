"""Unified observability: metrics registry + span tracing + exporters.

The layer every subsystem reports through (docs/observability.md):

  * :mod:`repro.obs.metrics` — Counter/Gauge/Histogram families in a
    process-wide registry, cheap enough for host-side hot loops;
  * :mod:`repro.obs.trace` — bounded-ring span tracer emitting Chrome
    Trace Event Format JSON (Perfetto / chrome://tracing);
  * :mod:`repro.obs.export` — Prometheus text exposition, JSONL sink,
    periodic flusher;
  * :mod:`repro.obs.stats_util` — empty-safe percentile/summary helpers
    shared by ``ServeEngine.stats()`` and the benches.

``Observability`` bundles one registry + one tracer so instrumented
subsystems (``ServeEngine(obs=...)``, ``train_loop(obs=...)``) take a
single handle, and the launch CLIs build one from ``--trace-out`` /
``--metrics-out`` flags.
"""
from __future__ import annotations

from typing import Optional

from .export import JsonlSink, PeriodicFlusher, parse_prometheus_text, prometheus_text
from .metrics import (
    DEFAULT_BUCKETS,
    REGISTRY,
    Counter,
    Family,
    Gauge,
    Histogram,
    MetricsRegistry,
    exponential_buckets,
    jit_retraces,
)
from .stats_util import median, median_by, percentile, summarize
from .trace import SpanTracer

__all__ = [
    "Observability",
    "MetricsRegistry",
    "Counter",
    "Gauge",
    "Histogram",
    "Family",
    "REGISTRY",
    "DEFAULT_BUCKETS",
    "exponential_buckets",
    "jit_retraces",
    "SpanTracer",
    "prometheus_text",
    "parse_prometheus_text",
    "JsonlSink",
    "PeriodicFlusher",
    "percentile",
    "median",
    "median_by",
    "summarize",
]


class Observability:
    """One registry + one tracer, passed as a single handle.

    ``metrics=None`` uses the process-wide :data:`REGISTRY` (the CLI
    default — one exposition file covers everything in the process);
    tests and benches pass a fresh ``MetricsRegistry()`` to isolate.
    """

    def __init__(self, *, metrics: Optional[MetricsRegistry] = None,
                 trace_capacity: int = 65536, pid: int = 0,
                 process_name: Optional[str] = None):
        self.metrics = metrics if metrics is not None else REGISTRY
        self.trace = SpanTracer(
            capacity=trace_capacity, pid=pid, process_name=process_name
        )

    def flusher(self, *, metrics_path=None, trace_path=None,
                events_path=None, interval: float = 5.0) -> PeriodicFlusher:
        """A PeriodicFlusher wired to this bundle's registry and tracer."""
        return PeriodicFlusher(
            registry=self.metrics, tracer=self.trace,
            metrics_path=metrics_path, trace_path=trace_path,
            events_path=events_path, interval=interval,
        )

"""Exporters: Prometheus text exposition, JSONL event sink, periodic flusher.

The registry (obs/metrics.py) and tracer (obs/trace.py) accumulate in
memory; this module is the only place telemetry touches bytes:

  * ``prometheus_text`` renders a ``MetricsRegistry.snapshot()`` in the
    Prometheus text exposition format (``# HELP`` / ``# TYPE`` headers,
    ``name{label="v"} value`` samples, cumulative ``_bucket{le=...}`` +
    ``_sum``/``_count`` for histograms) — point any Prometheus scraper's
    textfile collector at the flushed file, or diff two snapshots directly;
  * ``parse_prometheus_text`` is the matching minimal parser — it exists so
    the exposition is ROUND-TRIP TESTED (tests/test_obs.py): every sample
    rendered must parse back to the exact value the registry held, which
    pins the format against quoting/float-formatting rot;
  * ``JsonlSink`` appends events (one JSON object per line) — the
    machine-readable stream for offline analysis, complementing the
    Perfetto trace (obs/trace.py::SpanTracer.to_chrome) meant for eyes;
  * ``PeriodicFlusher`` ties them together: call ``maybe_flush(now)`` from
    any loop and it rewrites the metrics/trace files and appends NEW trace
    events to the JSONL sink at most once per ``interval`` — observability
    of a live run without a background thread (explicit clocks again, so
    virtual-clock tests can drive flushes deterministically).
"""
from __future__ import annotations

import json
import math
import pathlib
from typing import Any, Optional

__all__ = [
    "prometheus_text",
    "parse_prometheus_text",
    "JsonlSink",
    "PeriodicFlusher",
]


def _fmt(v: float) -> str:
    """Prometheus sample value: shortest float repr that round-trips
    (integers render bare — '3' not '3.0' is what real exporters emit)."""
    if v != v:
        return "NaN"
    if v == math.inf:
        return "+Inf"
    if v == -math.inf:
        return "-Inf"
    if float(v).is_integer() and abs(v) < 1e15:
        return str(int(v))
    return repr(float(v))


def _escape(v: str) -> str:
    return v.replace("\\", "\\\\").replace('"', '\\"').replace("\n", "\\n")


def _labelstr(labels: dict, extra: Optional[tuple] = None) -> str:
    items = list(labels.items())
    if extra is not None:
        items.append(extra)
    if not items:
        return ""
    inner = ",".join(f'{k}="{_escape(str(v))}"' for k, v in items)
    return "{" + inner + "}"


def prometheus_text(snapshot: dict) -> str:
    """Render a MetricsRegistry.snapshot() as text exposition format."""
    lines: list[str] = []
    for name, fam in snapshot.items():
        if fam.get("help"):
            lines.append(f"# HELP {name} {_escape(fam['help'])}")
        lines.append(f"# TYPE {name} {fam['kind']}")
        for s in fam["series"]:
            labels = s["labels"]
            if fam["kind"] == "histogram":
                acc = 0
                for le, c in zip(s["bounds"], s["counts"]):
                    acc += c
                    lines.append(
                        f"{name}_bucket{_labelstr(labels, ('le', _fmt(le)))} {acc}"
                    )
                total = acc + s["counts"][-1]
                lines.append(
                    f"{name}_bucket{_labelstr(labels, ('le', '+Inf'))} {total}"
                )
                lines.append(f"{name}_sum{_labelstr(labels)} {_fmt(s['sum'])}")
                lines.append(f"{name}_count{_labelstr(labels)} {total}")
            else:
                lines.append(f"{name}{_labelstr(labels)} {_fmt(s['value'])}")
    return "\n".join(lines) + "\n"


def _parse_value(tok: str) -> float:
    return {"+Inf": math.inf, "-Inf": -math.inf, "NaN": math.nan}.get(
        tok, None
    ) if tok in ("+Inf", "-Inf", "NaN") else float(tok)


def parse_prometheus_text(text: str) -> dict:
    """Minimal exposition parser for round-trip testing.

    Returns {sample_name: {frozenset(label_items): value}} plus a ``#types``
    entry mapping family name -> declared type.  Handles exactly what
    ``prometheus_text`` emits (escaped label values included) — it is a
    test oracle, not a general scraper.
    """
    samples: dict[str, dict] = {}
    types: dict[str, str] = {}
    for line in text.splitlines():
        line = line.strip()
        if not line:
            continue
        if line.startswith("# TYPE "):
            _, _, name, kind = line.split(None, 3)
            types[name] = kind
            continue
        if line.startswith("#"):
            continue
        # name{labels} value  |  name value
        if "{" in line:
            name, rest = line.split("{", 1)
            labelpart, valpart = rest.rsplit("}", 1)
            labels = {}
            # split on '",' boundaries so escaped quotes inside values survive
            for item in labelpart.split('",'):
                item = item.rstrip('"')
                k, v = item.split('="', 1)
                labels[k] = (
                    v.replace("\\n", "\n").replace('\\"', '"')
                    .replace("\\\\", "\\")
                )
            value = valpart.strip()
        else:
            name, value = line.rsplit(None, 1)
            labels = {}
        v = _parse_value(value)
        if v is None:
            v = float(value)
        samples.setdefault(name, {})[frozenset(labels.items())] = v
    samples["#types"] = types
    return samples


class JsonlSink:
    """Append-only JSON-lines event stream (one object per line, flushed per
    write so a crashed run keeps everything already emitted)."""

    def __init__(self, path):
        self.path = pathlib.Path(path)
        self.path.parent.mkdir(parents=True, exist_ok=True)
        self._f = open(self.path, "a")
        self.n_written = 0

    def write(self, obj: Any) -> None:
        self._f.write(json.dumps(obj) + "\n")
        self._f.flush()
        self.n_written += 1

    def close(self) -> None:
        if self._f is not None:
            self._f.close()
            self._f = None

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()


class PeriodicFlusher:
    """Rate-limited telemetry writer for live loops.

    Call ``maybe_flush(now)`` wherever convenient (per step, per log line);
    files rewrite at most once per ``interval`` seconds of the CALLER'S
    clock.  ``close()`` force-flushes, so short runs still export.

      metrics_path   Prometheus text file (rewritten whole each flush)
      trace_path     Chrome trace JSON (rewritten whole — the ring is the
                     retention policy, the file is a view of it)
      events_path    JSONL sink appending only the trace events emitted
                     since the previous flush (ring eviction cannot lose
                     events for the sink unless more than ``capacity``
                     events arrive within one interval — ``n_dropped``
                     on the tracer says if that ever happened)
    """

    def __init__(self, *, registry=None, tracer=None, metrics_path=None,
                 trace_path=None, events_path=None, interval: float = 5.0):
        self.registry = registry
        self.tracer = tracer
        self.metrics_path = metrics_path
        self.trace_path = trace_path
        for p in (metrics_path, trace_path):
            if p:
                pathlib.Path(p).parent.mkdir(parents=True, exist_ok=True)
        self.sink = JsonlSink(events_path) if events_path else None
        self.interval = interval
        self._last: Optional[float] = None
        self._seen = 0  # tracer.n_emitted at the previous flush
        self.n_flushes = 0

    def maybe_flush(self, now: float, force: bool = False) -> bool:
        if (
            not force
            and self._last is not None
            and now - self._last < self.interval
        ):
            return False
        self._last = now
        if self.registry is not None and self.metrics_path:
            pathlib.Path(self.metrics_path).write_text(
                prometheus_text(self.registry.snapshot())
            )
        if self.tracer is not None:
            if self.trace_path:
                self.tracer.to_chrome(self.trace_path)
            if self.sink is not None:
                new = self.tracer.n_emitted - self._seen
                if new > 0:
                    ring = self.tracer.events
                    for ev in list(ring)[-min(new, len(ring)):]:
                        self.sink.write(ev)
                self._seen = self.tracer.n_emitted
        self.n_flushes += 1
        return True

    def close(self, now: float = 0.0) -> None:
        self.maybe_flush(now, force=True)
        if self.sink is not None:
            self.sink.close()

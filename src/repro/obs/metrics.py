"""Process-wide metrics registry: Counter / Gauge / Histogram families.

The measurement spine of the observability layer (docs/observability.md):
everything the trainer and the serving engine want to report continuously —
slot occupancy, pool pages, loss, pack grid fractions, step latencies — lands
in one ``MetricsRegistry`` as a *family* of labeled series, cheap enough to
update from the host side of a hot loop:

  * a Counter/Gauge update is one python attribute add/store (no locks, no
    string formatting, no allocation — the label resolution happens ONCE when
    the caller binds the child via ``Family.labels`` and keeps the handle);
  * a Histogram observe is one ``bisect`` over its (static, pre-validated)
    bucket bounds plus two adds — the exponential default
    (``exponential_buckets``) spans 100 µs → ~100 s in 18 buckets, wide
    enough for queue waits and train steps alike;
  * ``snapshot()`` is the only walk over everything, taken at flush cadence
    (obs/export.py), never per event.

Zero new dependencies: stdlib only.  Updates are deterministic — two
identical seeded runs produce bit-identical snapshots (the ``obs`` test tier
pins this), which is what makes metrics usable as a regression oracle and
not just a dashboard feed.

The module-level ``REGISTRY`` is the process-wide default (Prometheus-style);
subsystems accept an explicit registry so tests and benches can isolate.
``jit_retraces`` is the compile-counter helper both the trainer and
``ServeEngine.stats()`` use to surface ``n_retraces`` (it reads
``functools.lru_cache`` wrapper stats AND ``jax.jit`` cache sizes, so one
helper covers the engine's lru-cached step builders and the trainer's
directly-jitted steps).
"""
from __future__ import annotations

import bisect
import math
import re
from typing import Any, Optional, Sequence

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "Family",
    "MetricsRegistry",
    "REGISTRY",
    "exponential_buckets",
    "jit_retraces",
]

_NAME_RE = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")
_LABEL_RE = re.compile(r"^[a-zA-Z_][a-zA-Z0-9_]*$")


def exponential_buckets(start: float, factor: float, count: int) -> tuple:
    """``count`` upper bounds ``start * factor**i`` — the Prometheus-style
    exponential ladder.  start > 0, factor > 1, count >= 1 (validated here so
    a bad ladder fails at registration, not at the first observe)."""
    if start <= 0:
        raise ValueError(f"exponential_buckets: start must be > 0, got {start}")
    if factor <= 1:
        raise ValueError(f"exponential_buckets: factor must be > 1, got {factor}")
    if count < 1:
        raise ValueError(f"exponential_buckets: count must be >= 1, got {count}")
    return tuple(start * factor**i for i in range(count))


#: default histogram ladder: 100 µs .. ~107 s in 18 powers of 2 — covers a
#: single decode-step dispatch and a multi-second cold prefill in one ladder
DEFAULT_BUCKETS = exponential_buckets(1e-4, 2.0, 18)


class Counter:
    """Monotone accumulator.  ``inc`` rejects negative deltas — a counter
    that can go down is a gauge wearing the wrong type string, and the
    Prometheus exposition (obs/export.py) would mislead rate() queries."""

    kind = "counter"
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def inc(self, n: float = 1.0) -> None:
        if n < 0:
            raise ValueError(f"Counter.inc of negative delta {n}")
        self.value += n


class Gauge:
    """Point-in-time value (set wins, no history)."""

    kind = "gauge"
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)

    def inc(self, n: float = 1.0) -> None:
        self.value += n

    def dec(self, n: float = 1.0) -> None:
        self.value -= n


class Histogram:
    """Cumulative-bucket histogram over static upper bounds.

    ``observe`` uses ``le`` (<=) bucket semantics exactly as the Prometheus
    text exposition declares them, so the round-trip test can compare
    emitted cumulative counts against a reference prefix-sum without any
    off-by-one fudging.  Bounds must be finite and strictly increasing; the
    implicit +Inf bucket is the trailing ``counts`` slot.
    """

    kind = "histogram"
    __slots__ = ("bounds", "counts", "sum", "count")

    def __init__(self, bounds: Sequence[float] = DEFAULT_BUCKETS):
        b = tuple(float(x) for x in bounds)
        if not b:
            raise ValueError("Histogram needs at least one bucket bound")
        if any(not math.isfinite(x) for x in b):
            raise ValueError(f"Histogram bounds must be finite, got {b}")
        if any(b[i] >= b[i + 1] for i in range(len(b) - 1)):
            raise ValueError(f"Histogram bounds must strictly increase: {b}")
        self.bounds = b
        self.counts = [0] * (len(b) + 1)  # trailing slot = (+last, +Inf]
        self.sum = 0.0
        self.count = 0

    def observe(self, v: float) -> None:
        v = float(v)
        # first bound >= v  <=>  the smallest bucket with v <= le
        self.counts[bisect.bisect_left(self.bounds, v)] += 1
        self.sum += v
        self.count += 1

    def cumulative(self) -> list[tuple[float, int]]:
        """[(le, cumulative_count), ..., (inf, total)] — the exposition form."""
        out, acc = [], 0
        for le, c in zip(self.bounds, self.counts):
            acc += c
            out.append((le, acc))
        out.append((math.inf, acc + self.counts[-1]))
        return out


_KINDS = {"counter": Counter, "gauge": Gauge, "histogram": Histogram}


class Family:
    """One named metric with a fixed label schema and one child series per
    label-value tuple.  ``labels(*values)`` resolves (and memoizes) the
    child; a label-free family proxies ``inc``/``set``/``observe`` straight
    to its single default child so call sites stay one line."""

    __slots__ = ("name", "help", "kind", "labelnames", "_children", "_buckets")

    def __init__(self, name: str, kind: str, help: str = "",
                 labelnames: Sequence[str] = (), buckets=None):
        if not _NAME_RE.match(name):
            raise ValueError(f"invalid metric name {name!r}")
        for ln in labelnames:
            if not _LABEL_RE.match(ln):
                raise ValueError(f"invalid label name {ln!r} on {name!r}")
        if kind not in _KINDS:
            raise ValueError(f"unknown metric kind {kind!r}")
        self.name = name
        self.kind = kind
        self.help = help
        self.labelnames = tuple(labelnames)
        self._children: dict[tuple, Any] = {}
        self._buckets = tuple(buckets) if buckets is not None else None
        if not self.labelnames:
            self.labels()  # materialize the default child eagerly

    def labels(self, *values):
        """Child series for one label-value tuple (created on first use).
        Values are stringified — label values are identity, not data."""
        if len(values) != len(self.labelnames):
            raise ValueError(
                f"{self.name}: got {len(values)} label values for "
                f"labels {self.labelnames}"
            )
        key = tuple(str(v) for v in values)
        child = self._children.get(key)
        if child is None:
            cls = _KINDS[self.kind]
            child = (
                cls(self._buckets) if self.kind == "histogram" and self._buckets
                else cls()
            )
            self._children[key] = child
        return child

    # label-free ergonomic proxies (guarded: labeled families must bind first)
    def _default(self):
        if self.labelnames:
            raise ValueError(
                f"{self.name} has labels {self.labelnames}; call .labels(...)"
            )
        return self._children[()]

    def inc(self, n: float = 1.0) -> None:
        self._default().inc(n)

    def set(self, v: float) -> None:
        self._default().set(v)

    def observe(self, v: float) -> None:
        self._default().observe(v)

    def series(self):
        """(label_values_tuple, child) pairs in creation order — snapshot
        iteration is deterministic because dicts preserve insertion order."""
        return self._children.items()


class MetricsRegistry:
    """Name -> Family map with idempotent registration.

    ``counter``/``gauge``/``histogram`` are get-or-create: a subsystem can be
    constructed twice against the same registry (two engines in one bench
    process) and share series instead of colliding.  Re-registering with a
    DIFFERENT kind or label schema is a loud error — that is always a bug.
    """

    def __init__(self):
        self._families: dict[str, Family] = {}

    def _register(self, name: str, kind: str, help: str,
                  labels: Sequence[str], buckets=None) -> Family:
        fam = self._families.get(name)
        if fam is not None:
            if fam.kind != kind or fam.labelnames != tuple(labels):
                raise ValueError(
                    f"metric {name!r} already registered as {fam.kind} with "
                    f"labels {fam.labelnames}; asked for {kind} with "
                    f"labels {tuple(labels)}"
                )
            return fam
        fam = Family(name, kind, help, labels, buckets)
        self._families[name] = fam
        return fam

    def counter(self, name: str, help: str = "",
                labels: Sequence[str] = ()) -> Family:
        return self._register(name, "counter", help, labels)

    def gauge(self, name: str, help: str = "",
              labels: Sequence[str] = ()) -> Family:
        return self._register(name, "gauge", help, labels)

    def histogram(self, name: str, help: str = "",
                  labels: Sequence[str] = (), buckets=None) -> Family:
        return self._register(name, "histogram", help, labels, buckets)

    def get(self, name: str) -> Optional[Family]:
        return self._families.get(name)

    def snapshot(self) -> dict:
        """Deterministic point-in-time view of every series:
        {name: {kind, help, labelnames, series: [{labels, ...values}]}}.
        Histogram series carry (bounds, counts, sum, count) — enough to
        rebuild the cumulative exposition exactly (obs/export.py)."""
        out: dict[str, Any] = {}
        for name, fam in self._families.items():
            series = []
            for key, child in fam.series():
                s: dict[str, Any] = {
                    "labels": dict(zip(fam.labelnames, key))
                }
                if fam.kind == "histogram":
                    s["bounds"] = list(child.bounds)
                    s["counts"] = list(child.counts)
                    s["sum"] = child.sum
                    s["count"] = child.count
                else:
                    s["value"] = child.value
                series.append(s)
            out[name] = {
                "kind": fam.kind,
                "help": fam.help,
                "labelnames": list(fam.labelnames),
                "series": series,
            }
        return out


#: the process-wide default registry (subsystems take ``registry=`` overrides
#: so tests and benches can isolate; the CLIs use this one)
REGISTRY = MetricsRegistry()


def jit_retraces(*fns) -> int:
    """Total distinct compiled/traced variants across heterogeneous caches.

    Accepts both cache shapes this repo builds jitted steps through:
      * ``functools.lru_cache`` wrappers (the serving engine's module-level
        ``_decode_fn``/``_prefill_fn``/``_suffix_prefill_fn`` and the
        lockstep ``_session_fns``) — counts ``cache_info().misses``, i.e.
        every time a NEW (config, shape-bucket, variant) jit was built;
      * ``jax.jit`` wrappers (the trainer's ``train_step``/``rigl_step``) —
        counts ``_cache_size()``, i.e. every retrace (a pack-width growth
        retraces the SAME wrapper, which lru stats would never see).

    This is the ``n_retraces`` feed in train metrics and
    ``ServeEngine.stats()`` — a pack-width-hysteresis regression shows up as
    this number climbing during steady-state traffic instead of staying flat
    after warmup (docs/observability.md#retraces).
    """
    n = 0
    for f in fns:
        info = getattr(f, "cache_info", None)
        if info is not None:
            n += info().misses
            continue
        size = getattr(f, "_cache_size", None)
        if size is not None:
            n += int(size())
    return n

"""Shared summary-statistics helpers (empty-population-safe).

One implementation of the percentile/summary lambdas that were previously
copy-pasted across ``serving/engine.py::stats()``, ``benchmarks/
serve_bench.py`` and ``benchmarks/chaos_bench.py``.  Every helper tolerates
an empty population (returns 0.0 / empty summary) because serve stats get
queried before the first request completes and chaos runs can shed 100% of
a stream — ``np.percentile([])`` raising mid-``stats()`` was a live bug
class all three call sites defended against separately.
"""
from __future__ import annotations

from typing import Iterable, Optional, Sequence

import numpy as np

__all__ = ["percentile", "summarize", "median", "median_by"]


def percentile(values: Sequence[float], q: float) -> float:
    """q-th percentile (0..100) of ``values``; 0.0 for an empty population."""
    vals = np.asarray(values, dtype=np.float64)
    if vals.size == 0:
        return 0.0
    return float(np.percentile(vals, q))


def median(values: Sequence[float]) -> float:
    return percentile(values, 50)


def summarize(values: Sequence[float],
              qs: Iterable[float] = (50, 95, 99)) -> dict:
    """{mean, min, max, n, p<q>...} — the one summary shape every bench
    writes into its BENCH_*.json.  Empty population -> all zeros, n=0."""
    vals = np.asarray(values, dtype=np.float64)
    out = {
        "n": int(vals.size),
        "mean": float(vals.mean()) if vals.size else 0.0,
        "min": float(vals.min()) if vals.size else 0.0,
        "max": float(vals.max()) if vals.size else 0.0,
    }
    for q in qs:
        key = f"p{int(q) if float(q).is_integer() else q}"
        out[key] = percentile(vals, q)
    return out


def median_by(runs: Sequence[dict], key: str) -> Optional[dict]:
    """The run dict whose ``key`` value is the median of the population
    (upper-middle for even counts, matching the previous serve_bench
    ``_median_by_throughput`` semantics).  None for an empty population."""
    if not runs:
        return None
    ordered = sorted(runs, key=lambda r: r[key])
    return ordered[len(ordered) // 2]

"""Span tracer emitting Chrome Trace Event Format (Perfetto-loadable) JSON.

One ``SpanTracer`` collects the per-request / per-step timeline the aggregate
counters cannot show: where a request's lifetime went (queue wait vs prefill
vs decode), which step a quarantine fired on, when the trainer's topology
updates landed.  The output is the Chrome Trace Event Format's JSON-object
form — ``{"traceEvents": [...], "displayTimeUnit": "ms"}`` — which both
Perfetto (ui.perfetto.dev, drag-and-drop) and chrome://tracing open directly
(docs/observability.md#opening-a-trace).

Design constraints, in order:

  * **explicit clocks** — every emit takes caller-provided timestamps in
    SECONDS.  The serving engine runs under a virtual clock in tests and the
    wall clock in production (serving/engine.py::ServeEngine.step passes its
    ``now``/``clock`` straight through), so the tracer must never read time
    itself: two identical seeded virtual-clock runs emit bit-identical
    traces, which is what makes traces assertable (tests/test_obs.py) and
    not just viewable.  Timestamps are stored as integer microseconds (the
    format's native unit).
  * **bounded memory** — events land in a ring buffer (``capacity`` events);
    a week-long serve loop cannot OOM the host through its own telemetry.
    Evictions are COUNTED (``n_dropped``) and oldest-first, so a truncated
    trace is still a correct suffix of the run.  Process/thread-name
    metadata events live OUTSIDE the ring: truncation never drops the
    labels that make the remaining events readable.
  * **cheap emits** — an emit is one small dict build + deque append; no
    string formatting, no I/O.  Serialization happens only at flush/export
    time (obs/export.py), never on the hot path.

Event vocabulary used by this repo's instrumentation (the span taxonomy
table in docs/observability.md#span-taxonomy): ``ph="X"`` complete spans
(queue_wait / prefill / decode / decode_step / train_step), ``ph="i"``
instants (quarantine / shed / fault_injected / topology_update), ``ph="C"``
counter tracks (loss, slot occupancy) and ``ph="M"`` metadata names.
"""
from __future__ import annotations

import json
import pathlib
from collections import deque
from typing import Any, Optional

__all__ = ["SpanTracer"]


def _us(t: float) -> int:
    """Seconds -> integer microseconds (the trace format's time unit)."""
    return int(round(t * 1e6))


class SpanTracer:
    """Bounded ring of Chrome trace events with explicit-clock emits.

    capacity       ring size in events; the oldest event is dropped (and
                   ``n_dropped`` incremented) once full
    pid            process id stamped on every event — instrumented
                   subsystems in one process use distinct pids so Perfetto
                   groups their tracks (serve=0 by convention, train=1)
    process_name   optional ``process_name`` metadata row
    """

    def __init__(self, capacity: int = 65536, pid: int = 0,
                 process_name: Optional[str] = None):
        if capacity < 1:
            raise ValueError(f"SpanTracer capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self.pid = pid
        self.events: deque = deque(maxlen=capacity)
        self._meta: list[dict] = []  # name metadata, exempt from the ring
        self.n_emitted = 0  # lifetime emits (ring length + n_dropped)
        self.n_dropped = 0
        self._named_tids: set[int] = set()
        if process_name is not None:
            self._meta.append({
                "ph": "M", "name": "process_name", "pid": pid, "tid": 0,
                "args": {"name": process_name},
            })

    # -- naming ------------------------------------------------------------

    def thread_name(self, tid: int, name: str) -> None:
        """Label a tid's track (idempotent per tid — first name wins, so hot
        paths may call this unconditionally)."""
        if tid in self._named_tids:
            return
        self._named_tids.add(tid)
        self._meta.append({
            "ph": "M", "name": "thread_name", "pid": self.pid, "tid": tid,
            "args": {"name": name},
        })

    # -- emits (hot path: one dict + one append) ---------------------------

    def _push(self, ev: dict) -> None:
        if len(self.events) == self.capacity:
            self.n_dropped += 1
        self.events.append(ev)
        self.n_emitted += 1

    def span(self, name: str, t0: float, t1: float, *, tid: int = 0,
             cat: str = "", args: Optional[dict] = None) -> None:
        """Complete span [t0, t1] (seconds) — ``ph="X"`` with a duration, the
        cheapest span form (no begin/end pairing for the viewer to repair)."""
        ev: dict[str, Any] = {
            "ph": "X", "name": name, "cat": cat, "pid": self.pid, "tid": tid,
            "ts": _us(t0), "dur": max(_us(t1) - _us(t0), 0),
        }
        if args:
            ev["args"] = args
        self._push(ev)

    def instant(self, name: str, ts: float, *, tid: int = 0, cat: str = "",
                args: Optional[dict] = None) -> None:
        """Thread-scoped instant marker (``ph="i"``) — annotations like
        quarantine/shed that have a moment, not an extent."""
        ev: dict[str, Any] = {
            "ph": "i", "s": "t", "name": name, "cat": cat, "pid": self.pid,
            "tid": tid, "ts": _us(ts),
        }
        if args:
            ev["args"] = args
        self._push(ev)

    def counter(self, name: str, ts: float, values: dict, *,
                tid: int = 0) -> None:
        """Counter-track sample (``ph="C"``): Perfetto renders each key of
        ``values`` as a stacked series — the live loss / occupancy strips."""
        self._push({
            "ph": "C", "name": name, "pid": self.pid, "tid": tid,
            "ts": _us(ts), "args": dict(values),
        })

    # -- export ------------------------------------------------------------

    def chrome_events(self) -> list[dict]:
        """Metadata + ring contents, oldest first (metadata leads so viewers
        see names before the events that use them)."""
        return self._meta + list(self.events)

    def to_chrome(self, path) -> None:
        """Write the JSON-object trace form Perfetto/chrome://tracing load."""
        path = pathlib.Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        with open(path, "w") as f:
            json.dump(
                {"traceEvents": self.chrome_events(),
                 "displayTimeUnit": "ms"},
                f,
            )

    def find(self, name: str) -> list[dict]:
        """Events (ring order) with a given name — the test/bench helper for
        cross-checking emitted annotations against ground truth (e.g.
        quarantine instants vs FaultInjector.log)."""
        return [e for e in self.events if e.get("name") == name]

from .lr import LRSchedule  # noqa: F401
from .optimizers import (  # noqa: F401
    OptConfig,
    apply_opt,
    apply_opt_fused,
    init_opt,
    reset_connections,
    reset_new_connections,
)

from .lr import LRSchedule  # noqa: F401
from .optimizers import OptConfig, apply_opt, init_opt, reset_connections, reset_new_connections  # noqa: F401

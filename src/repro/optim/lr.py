"""LR schedules: the paper's recipes + warmup-cosine for the LM zoo."""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

__all__ = ["LRSchedule"]


@dataclasses.dataclass(frozen=True)
class LRSchedule:
    kind: str = "warmup_cosine"  # warmup_cosine | step_drops | constant
    base_lr: float = 1e-3
    warmup_steps: int = 100
    total_steps: int = 10_000
    # step_drops (paper ImageNet: x0.1 at epochs 30/70/90 after 5-epoch warmup)
    drop_steps: tuple[int, ...] = ()
    drop_factor: float = 0.1

    def __call__(self, step):
        s = jnp.asarray(step, jnp.float32)
        warm = jnp.minimum(1.0, s / jnp.maximum(self.warmup_steps, 1))
        if self.kind == "constant":
            return self.base_lr * warm
        if self.kind == "warmup_cosine":
            t = jnp.clip(
                (s - self.warmup_steps) / max(self.total_steps - self.warmup_steps, 1),
                0.0,
                1.0,
            )
            return self.base_lr * warm * 0.5 * (1.0 + jnp.cos(jnp.pi * t))
        if self.kind == "step_drops":
            drops = sum(jnp.where(s >= d, 1.0, 0.0) for d in self.drop_steps)
            return self.base_lr * warm * self.drop_factor**drops
        raise ValueError(self.kind)

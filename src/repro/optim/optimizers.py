"""Optimizers (pure JAX): SGD+momentum (paper's vision recipe) and Adam
(paper's char-LM recipe), both sparse-aware:

- the optimizer only ever sees MASKED gradients (g_dense * mask);
- ``reset_new_connections`` zeroes per-connection state (momentum / m / v)
  for freshly grown connections after a RigL update (official-code semantics);
- optional dense-momentum accumulator for the SNFS baseline (its grow
  criterion needs momentum of the *dense* gradient — the reason SNFS costs
  dense FLOPs, paper Table 1).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

__all__ = [
    "OptConfig",
    "init_opt",
    "apply_opt",
    "apply_opt_fused",
    "reset_connections",
    "reset_new_connections",
]


@dataclasses.dataclass(frozen=True)
class OptConfig:
    kind: str = "sgd"  # sgd | adam
    momentum: float = 0.9
    nesterov: bool = False
    weight_decay: float = 1e-4
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    grad_clip: float = 0.0  # global-norm clip (paper char-LM uses 10.0)
    state_dtype: str = "float32"  # bfloat16 halves momentum HBM (grok-1)


def init_opt(cfg: OptConfig, params):
    dt = jnp.bfloat16 if cfg.state_dtype == "bfloat16" else jnp.float32
    z = lambda: jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, dt), params
    )
    if cfg.kind == "sgd":
        return {"momentum": z()}
    if cfg.kind == "adam":
        return {"m": z(), "v": z(), "count": jnp.zeros((), jnp.int32)}
    raise ValueError(cfg.kind)


def _clip(cfg, grads):
    if not cfg.grad_clip:
        return grads
    gnorm = jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree_util.tree_leaves(grads))
    )
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    return jax.tree_util.tree_map(lambda g: g * scale, grads)


def apply_opt(cfg: OptConfig, grads, opt_state, params, lr):
    """Returns (new_params, new_opt_state). grads are the MASKED gradients."""
    grads = _clip(cfg, grads)
    if cfg.kind == "sgd":
        mom = opt_state["momentum"]

        def upd(g, m, p):
            g = g.astype(jnp.float32) + cfg.weight_decay * p.astype(jnp.float32)
            m_new = cfg.momentum * m + g
            step = (g + cfg.momentum * m_new) if cfg.nesterov else m_new
            return (p - lr * step).astype(p.dtype), m_new.astype(m.dtype)

        out = jax.tree_util.tree_map(upd, grads, mom, params)
        new_params = jax.tree_util.tree_map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
        new_mom = jax.tree_util.tree_map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
        return new_params, {"momentum": new_mom}

    if cfg.kind == "adam":
        count = opt_state["count"] + 1
        b1c = 1.0 - cfg.b1 ** count.astype(jnp.float32)
        b2c = 1.0 - cfg.b2 ** count.astype(jnp.float32)

        def upd(g, m, v, p):
            g = g.astype(jnp.float32)
            m_new = cfg.b1 * m + (1 - cfg.b1) * g
            v_new = cfg.b2 * v + (1 - cfg.b2) * g * g
            step = (m_new / b1c) / (jnp.sqrt(v_new / b2c) + cfg.eps)
            step = step + cfg.weight_decay * p.astype(jnp.float32)
            return (p - lr * step).astype(p.dtype), m_new, v_new

        out = jax.tree_util.tree_map(upd, grads, opt_state["m"], opt_state["v"], params)
        g0 = lambda i: jax.tree_util.tree_map(lambda t: t[i], out, is_leaf=lambda x: isinstance(x, tuple))
        return g0(0), {"m": g0(1), "v": g0(2), "count": count}

    raise ValueError(cfg.kind)


def apply_opt_fused(cfg: OptConfig, grads, opt_state, params, lr, fused_flags):
    """SGD epilogue for the fused wgrad->optimizer path (docs/kernels.md).

    ``fused_flags`` is a pytree of python bools mirroring ``grads``.  Leaves
    flagged fused arrive as m_new = mu*mom + dw + wd*w (the weight cotangent
    the fused kernels emit, re-masked to the optimizer support by the train
    step), so the update collapses to ``p -= lr*g; momentum := g`` — no
    second read-modify-write pass over the gradient.  Plain leaves
    (embeddings, norms, anything not kernel-dispatched) get the standard
    SGD+momentum update, bit-identical to ``apply_opt``.  Restricted to
    plain SGD (the gating in training/steps.py enforces kind=='sgd',
    nesterov=False, grad_clip=0).
    """
    assert cfg.kind == "sgd" and not cfg.nesterov and not cfg.grad_clip
    mom = opt_state["momentum"]

    def upd(g, m, p, fused):
        g32 = g.astype(jnp.float32)
        if fused:
            m_new = g32
        else:
            g32 = g32 + cfg.weight_decay * p.astype(jnp.float32)
            m_new = cfg.momentum * m + g32
        return (p - lr * m_new).astype(p.dtype), m_new.astype(m.dtype)

    out = jax.tree_util.tree_map(upd, grads, mom, params, fused_flags)
    is_t = lambda x: isinstance(x, tuple)
    new_params = jax.tree_util.tree_map(lambda t: t[0], out, is_leaf=is_t)
    new_mom = jax.tree_util.tree_map(lambda t: t[1], out, is_leaf=is_t)
    return new_params, {"momentum": new_mom}


def reset_connections(opt_state, where_masks):
    """Zero per-connection optimizer state wherever ``where_masks`` is True.

    Used after RigL updates (grown connections start with fresh state,
    official-code semantics) and after Top-KAST superset refreshes (state of
    connections leaving the backward set must not leak back if the
    coordinate later rejoins) — one primitive, two call sites.
    """
    def reset_tree(tree):
        def f(x, where):
            if where is None or x.ndim == 0:
                return x
            return jnp.where(where, jnp.zeros_like(x), x)

        return jax.tree_util.tree_map(f, tree, where_masks, is_leaf=lambda v: v is None)

    out = dict(opt_state)
    for k in ("momentum", "m", "v"):
        if k in out:
            out[k] = reset_tree(out[k])
    return out


def reset_new_connections(opt_state, grown_masks):
    """Zero per-connection optimizer state where a connection was just grown."""
    return reset_connections(opt_state, grown_masks)

"""Continuous-batching serving: slot-pool engine, scheduler, per-slot sampling.

Entry point: ``ServeEngine`` (engine.py) — admits queued ``Request``s
(queue.py) into recycled KV-cache slots and decodes all active slots in one
jitted per-slot step.  Failure edges (deadline shedding, NaN-slot
quarantine, bounded retries) and the deterministic chaos harness
(``FaultInjector``, faults.py) are documented in
docs/serving.md#failure-model.  See docs/serving.md for the end-to-end tour.
With ``paged=True`` the engine's KV caches become page pools managed by
``BlockPool`` (block_pool.py) — fixed-size KV blocks, per-slot block tables,
refcounted copy-on-write prefix sharing (docs/serving.md#paged-kv-cache).
"""
from .block_pool import BlockPool  # noqa: F401
from .engine import ServeEngine  # noqa: F401
from .faults import FaultInjector, burst_storm, truncate_pack  # noqa: F401
from .queue import Request, RequestQueue, Status, poisson_arrivals  # noqa: F401
from .sampler import request_key, sample_tokens, step_keys  # noqa: F401

"""BlockPool — host-side allocator for the paged KV cache.

The contiguous engine gives every slot a private `max_len` cache row
(models/attention.py::init_kv_cache): a request can never outlive its row,
and a short request strands the rest of the row's HBM for its whole
lifetime.  The paged engine instead carves each layer's cache into
fixed-size KV BLOCKS (pages) of ``page_size`` positions and gives every
slot a BLOCK TABLE mapping logical page index -> physical page id
(vLLM-style).  This module is the allocator behind those tables:

  * one ``BlockPool`` per cache GROUP — layers sharing a cache geometry
    ('global' layers at size max_len, 'local' ring layers at size
    min(window, max_len)) share one id space, so a single table row
    addresses the same physical page slice in EVERY layer of the group;
  * a free list + per-page REFCOUNTS: pages referenced by several tables
    (shared prompt prefixes, serving/engine.py) are freed only when the
    last reference drops;
  * ``fork`` — the copy-on-write edge: a slot about to WRITE into a page
    it shares drops its shared reference and gets a fresh exclusive page
    (the device-side content copy is the caller's job — the pool only
    manages ids).  A fork never mutates the shared page: the other
    holders keep reading the original bits.

Everything here is plain numpy/python host state: allocation decisions
happen on the scheduler thread, OUTSIDE jit; the jitted decode/prefill
only ever sees the resulting int32 tables (scalar-prefetched into the
flash kernels, gathered in the jnp paths).  ``check`` is the invariant
audit the property tests (tests/test_block_pool.py) and the chaos leak
test (tests/test_serving_faults.py) call after every operation sequence:
the free list and the live (refcount > 0) pages must exactly partition
the pool, and refcounts must match the references the caller declares.
"""
from __future__ import annotations

import numpy as np

__all__ = ["BlockPool"]


class BlockPool:
    """Fixed-capacity page allocator with refcounts and COW fork.

    n_blocks: physical pages in the pool (page ids are 0..n_blocks-1; the
    id ``n_blocks`` itself is the out-of-bounds SENTINEL unowned table
    entries carry — scatters to it drop, gathers clip into masked lanes).
    page_size: positions per page (bookkeeping only; the pool never
    touches tensor data).
    """

    def __init__(self, n_blocks: int, page_size: int):
        if n_blocks < 1:
            raise ValueError(f"BlockPool needs n_blocks >= 1 (got {n_blocks})")
        if page_size < 1:
            raise ValueError(f"BlockPool needs page_size >= 1 (got {page_size})")
        self.n_blocks = int(n_blocks)
        self.page_size = int(page_size)
        self.refcount = np.zeros(self.n_blocks, np.int32)
        # LIFO free list: most-recently-freed pages are re-issued first
        # (their content is hottest in HBM-adjacent caches; order is
        # otherwise irrelevant to correctness)
        self._free: list[int] = list(range(self.n_blocks - 1, -1, -1))
        self.n_forks = 0

    # -- queries -----------------------------------------------------------

    @property
    def sentinel(self) -> int:
        """Table-entry value for 'no page': one past the last valid id."""
        return self.n_blocks

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_live(self) -> int:
        return int((self.refcount > 0).sum())

    def can_alloc(self, n: int) -> bool:
        return n <= len(self._free)

    # -- alloc / free / share ---------------------------------------------

    def alloc(self, n: int) -> list[int]:
        """``n`` fresh exclusive pages (refcount 1 each).  Raises MemoryError
        when the pool cannot satisfy the request — callers gate admissions
        on ``can_alloc`` so this firing means a scheduler accounting bug."""
        if n > len(self._free):
            raise MemoryError(
                f"BlockPool: {n} pages requested, {len(self._free)} free "
                f"of {self.n_blocks}"
            )
        out = [self._free.pop() for _ in range(n)]
        for b in out:
            assert self.refcount[b] == 0
            self.refcount[b] = 1
        return out

    def incref(self, blocks) -> None:
        """Add one reference per listed page (prefix sharing: a new table
        row pointing at already-live pages).  Increffing a FREE page is a
        use-after-free — rejected loudly."""
        for b in blocks:
            b = int(b)
            if not (0 <= b < self.n_blocks) or self.refcount[b] == 0:
                raise ValueError(f"BlockPool.incref: page {b} is not live")
            self.refcount[b] += 1

    def free(self, blocks) -> None:
        """Drop one reference per listed page; pages reaching refcount 0
        return to the free list.  Freeing an already-free page (double
        free) is rejected loudly — the no-double-free invariant."""
        for b in blocks:
            b = int(b)
            if not (0 <= b < self.n_blocks) or self.refcount[b] == 0:
                raise ValueError(f"BlockPool.free: double free of page {b}")
            self.refcount[b] -= 1
            if self.refcount[b] == 0:
                self._free.append(b)

    def fork(self, block: int) -> int:
        """Copy-on-write: trade one SHARED reference on ``block`` for a
        fresh exclusive page.  The shared page's other references — and its
        bits — are untouched; the caller copies the device content into the
        returned page before writing.  Forking an exclusively-held page is
        rejected (it would be a pointless copy — write in place instead)."""
        block = int(block)
        if not (0 <= block < self.n_blocks) or self.refcount[block] == 0:
            raise ValueError(f"BlockPool.fork: page {block} is not live")
        if self.refcount[block] < 2:
            raise ValueError(
                f"BlockPool.fork: page {block} is exclusively held "
                "(refcount 1) — write in place, don't fork"
            )
        new = self.alloc(1)[0]
        self.refcount[block] -= 1  # cannot hit 0: refcount was >= 2
        self.n_forks += 1
        return new

    # -- invariant audit ---------------------------------------------------

    def check(self, expected_refs=None) -> None:
        """Assert the pool invariants; raises AssertionError on violation.

        * free list and live (refcount > 0) pages PARTITION the pool:
          no page is both free and live, none is neither, no duplicates;
        * with ``expected_refs`` (iterable of page ids, one entry per
          outstanding reference the caller believes exists — table entries
          plus prefix-cache holds), refcounts must match it exactly.
        """
        free = list(self._free)
        assert len(set(free)) == len(free), "free list holds duplicates"
        for b in free:
            assert 0 <= b < self.n_blocks, f"free-list id {b} out of range"
            assert self.refcount[b] == 0, f"page {b} free but refcount > 0"
        live = np.nonzero(self.refcount > 0)[0]
        assert len(free) + len(live) == self.n_blocks, (
            f"free ({len(free)}) + live ({len(live)}) != {self.n_blocks}: "
            "pages leaked or double-tracked"
        )
        assert (self.refcount >= 0).all(), "negative refcount"
        if expected_refs is not None:
            want = np.zeros(self.n_blocks, np.int32)
            for b in expected_refs:
                want[int(b)] += 1
            if not (want == self.refcount).all():
                bad = np.nonzero(want != self.refcount)[0]
                raise AssertionError(
                    f"refcount mismatch on pages {bad.tolist()}: "
                    f"pool has {self.refcount[bad].tolist()}, caller "
                    f"references imply {want[bad].tolist()}"
                )

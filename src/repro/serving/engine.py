"""Continuous-batching serving engine: per-slot decode over recycled KV slots.

The lockstep driver (launch/serve.py::serve_session) steps one fixed batch
with a single shared position — a finished row parks its KV-cache slot until
the SLOWEST row in the batch finishes, so staggered-length traffic wastes
decode steps on padding.  This engine removes that barrier:

  * a fixed-capacity SLOT POOL owns one batched cache pytree
    (models/model.py::init_caches at batch=capacity) for the engine's
    lifetime — no per-request allocation, ever;
  * queued requests are admitted into freed slots by scattering a B=1
    prefill into the slot row (models/model.py::lm_prefill_into, one jitted
    trace per prompt-length BUCKET — lengths pad to the next power of two
    where exact, so arbitrary-length traffic compiles O(log max_len) traces,
    not one per distinct length) — the prefill logits produce the request's
    first token, so a gen-N request costs exactly N-1 decode steps;
  * ALL active slots step together in ONE jitted decode
    (models/model.py::lm_decode with per-slot ``pos: (B,)`` + ``active``
    mask): each row ropes, ring-addresses and masks at its own depth, dead
    slots are provable no-ops on the cache;
  * sampling (greedy / temperature / top-k, per-request PRNG streams —
    serving/sampler.py) happens inside the same jit, so a step is exactly
    one dispatch + one (capacity,) token fetch; steps where every active
    slot is greedy dispatch an argmax-only variant (no sort, no sampler);
  * sparse-kernel state threads once: ``masks`` and the host-packed
    PackState (core/pack.py) are engine-level arguments passed to every
    jitted call — packed once per engine, reused by every prefill and every
    decode step, exactly the train-time tight-grid contract.

The step loop is a small state machine with explicit FAILURE edges, not a
happy path (docs/serving.md#failure-model):

  * **backpressure** — the queue is depth-bounded (``queue_limit``) and
    ``submit`` returns False (request SHED) instead of growing without
    bound; queued requests carry admission deadlines (``deadline`` / per-
    request ``ttl``) and are shed IN-QUEUE the step they expire — a
    structured terminal status, never an exception;
  * **in-flight detection & quarantine** — every jitted decode/prefill also
    returns a per-slot ``finite`` flag (models/model.py::logits_all_finite,
    reduced in-jit so the fast path stays one dispatch).  A non-finite row
    quarantines ONLY that request: its garbage token is discarded, its slot
    scrubbed (freed — the next admission's lm_prefill_into overwrites the
    full cache row) and the request either re-queues with exponential
    backoff (bounded ``max_retries``) or lands FAILED.  Every other slot's
    stream is bit-identical to a fault-free run (the chaos isolation
    invariant, enforced by benchmarks/chaos_bench.py);
  * **topology integrity** — a PackState passed at construction is checked
    against its CSC/CSR invariants (core/pack.py::validate_pack) so a
    corrupted pack is a loud PackIntegrityError, not silent wrong answers;
  * **fault injection** — an optional serving/faults.py::FaultInjector
    corrupts chosen (step, slot) logits in-jit and delays prefills, so the
    failure edges above are exercised deterministically by chaos tests.

Lifecycle and slot/cache layout are documented in docs/serving.md; request
states live in serving/queue.py.
"""
from __future__ import annotations

import functools
import time
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

import hashlib

from ..core.pack import publish_pack_gauges, validate_pack
from ..obs.metrics import jit_retraces
from ..obs.stats_util import percentile
from ..models import (
    attn_schedules,
    cache_group,
    init_caches,
    init_paged_caches,
    lm_decode,
    lm_prefill_into,
    lm_prefill_suffix,
    logits_all_finite,
)
from .block_pool import BlockPool
from .faults import FaultInjector
from .queue import Request, RequestQueue, Status
from .sampler import request_key, sample_tokens, step_keys

__all__ = ["ServeEngine", "QuarantineRecord"]


class QuarantineRecord(NamedTuple):
    """One quarantine event, keyed for exact FaultInjector correlation.

    ``step`` is the engine decode-step counter AT detection time — the same
    key ``FaultInjector.decode_fault`` logs, so a decode quarantine joins
    its fired injection on (step, slot).  ``attempt`` is the request's retry
    ordinal when the fault hit (0 = first admission), matching the attempt
    the injector logs for prefill faults — a retried-then-quarantined rid
    appears once PER ATTEMPT, unambiguously.  A NamedTuple compares equal
    to the plain tuple form, so existing ``== [(step, rid, slot, ...)]``
    assertions stay literal.
    """

    step: int
    rid: int
    slot: int
    attempt: int
    where: str  # "decode" | "prefill"


@functools.lru_cache(maxsize=None)
def _decode_fn(cfg, greedy: bool, faulty: bool = False):
    """The engine's jitted decode-step: per-slot lm_decode + in-jit finite
    flag + in-jit sampling + in-jit slot-state advance.  Cached per
    (config, greedy, faulty) at module level (ModelConfig is frozen and
    hashable), so every engine instance for the same config — including the
    bench's warmup/timed pairs — shares one compiled executable.

    ``greedy``: when every ACTIVE slot is greedy (temperature <= 0, the CLI
    default) the step picks tokens with a plain argmax — no (B, V) sort, no
    categorical draw whose result jnp.where would discard.  The engine
    chooses the variant per step from its host temp mirror, so all-greedy
    traffic never pays the O(V log V) sampler; one stochastic slot in the
    batch selects the full sampler for everyone (the per-row is_greedy
    select inside sample_tokens keeps greedy rows exact).

    ``faulty``: chaos-only variant taking (fault_mask (B,), fault_val (B,))
    and overwriting masked rows' logits BEFORE the finite reduction and the
    sampler — fault injection sees exactly the path a real non-finite
    forward would take, and fault-free engines never compile it.

    The per-slot ``finite`` flag (models/model.py::logits_all_finite) is
    reduced in-jit over each slot's logits row, so failure detection costs
    no extra dispatch — the host reads one extra (capacity,) bool.

    The per-slot carry (tok, pos, gen_idx) advances INSIDE the jit (active
    rows only) and is returned device-resident: between admissions a step
    uploads nothing and downloads one (capacity,) token vector — the host's
    only per-step work is finish/quarantine detection.

    ``tables`` ({group: (capacity, T_g) int32} block tables, or None for the
    contiguous layout) switches lm_decode to PAGED cache addressing —
    re-uploaded only when an admission/release rewrites a table row, like
    the rest of the carry.
    """

    def _decode(params, masks, pack, caches, tok, pos, active, base_keys,
                gen_idx, temp, topk, tables=None, *fault):
        logits, caches = lm_decode(
            params, cfg, caches, tok, pos, masks=masks, pack=pack,
            active=active, tables=tables,
        )
        last = logits[:, -1]
        if faulty:
            fmask, fval = fault
            last = jnp.where(fmask[:, None], fval[:, None], last)
        finite = logits_all_finite(last)
        if greedy:
            nxt = jnp.argmax(last, axis=-1).astype(jnp.int32)
        else:
            keys = step_keys(base_keys, gen_idx)
            nxt = sample_tokens(last, keys, temp, topk)
        tok = jnp.where(active[:, None], nxt[:, None], tok)
        pos = pos + active
        gen_idx = gen_idx + active
        return nxt, finite, caches, tok, pos, gen_idx

    return jax.jit(_decode, donate_argnums=(3, 4, 5, 8))


def _bucket_len(n: int, floor: int = 8) -> int:
    """Next power of two >= n (with a small floor): the prefill trace-cache
    key, so arbitrary real-traffic prompt lengths compile O(log max_len)
    traces instead of one per distinct length."""
    return max(floor, 1 << (n - 1).bit_length())


def _chunk_capped_len(bucket: int, cap: int, length: int, q_chunk: int) -> int:
    """min(bucket, cap), except a CAPPING cap is rounded down to the flash
    q-chunk multiple when that still covers ``length``.

    Power-of-two buckets below the cap chunk evenly by construction, but the
    cap itself (max_len minus patch rows) lands wherever the deployment put
    it — and a padded prefill length off the q-chunk grid hands the flash
    kernels a ragged final q tile (models/attention.py pads it per call,
    wasting a partial chunk of attention FLOPs/DMA on EVERY capped prefill
    and splitting the trace cache between ragged and even shapes).  Rounding
    down is only legal when the prompt still fits; otherwise the raw cap is
    the only length that does."""
    if bucket <= cap:
        return bucket
    if q_chunk:
        aligned = (cap // q_chunk) * q_chunk
        if aligned >= length:
            return aligned
    return cap


@functools.lru_cache(maxsize=None)
def _prefill_fn(cfg, max_len: int, prompt_len: int, n_patches: int,
                greedy: bool, faulty: bool = False):
    """Jitted prefill-into-slot + first-token sample, one trace per prompt
    length BUCKET (the slot index and the true length n_valid, like every
    per-request scalar, are traced arguments); module-level cache as for
    ``_decode_fn``.  ``prompt_len`` here is the PADDED token count — the
    engine buckets lengths to the next power of two where padding is exact
    (ServeEngine._prefill_for), bounding both the number of XLA compiles and
    this cache's growth under arbitrary-length traffic.  ``greedy`` requests
    skip the sampler exactly as in ``_decode_fn``.  Also returns the
    request's scalar ``finite`` flag (and, with ``faulty``, applies the
    injected corruption first) — see ``_decode_fn``.

    ``tables`` ({group: (T_g,) int32} page-table ROW for the admitted
    request, or None) switches the post-prefill scatter to the paged pools
    (lm_prefill_into) — the interior B=1 prefill is identical either way,
    so one trace structure covers a given bucket per layout."""
    sched = attn_schedules(cfg, prompt_len + n_patches)

    def _prefill(params, masks, pack, caches, batch, slot, n_valid, base_key,
                 temp, topk, tables=None, *fault):
        logits, caches = lm_prefill_into(
            params, cfg, caches, batch, slot, max_len, masks=masks,
            pack=pack, attn_sched=sched, n_valid=n_valid, tables=tables,
        )
        last = logits[:, -1]
        if faulty:
            last = jnp.where(fault[0], fault[1], last)
        finite = logits_all_finite(last)[0]
        if greedy:
            tok = jnp.argmax(last[0]).astype(jnp.int32)
        else:
            keys = step_keys(base_key[None], jnp.zeros((1,), jnp.int32))
            tok = sample_tokens(last, keys, temp[None], topk[None])[0]
        return tok, finite, caches

    return jax.jit(_prefill, donate_argnums=(3,))


@functools.lru_cache(maxsize=None)
def _suffix_prefill_fn(cfg, suffix_len: int, greedy: bool,
                       faulty: bool = False):
    """Jitted SUFFIX prefill + first-token sample for shared-prefix
    admissions (paged engines with ``prefix_cache > 0``): the request's
    first ``ctx`` positions are already cached in the paged pools, so only
    the suffix runs through the model (models/model.py::lm_prefill_suffix —
    suffix queries attend [table-gathered prefix, causal self]).  One trace
    per suffix-length BUCKET, exactly like ``_prefill_fn``; ``table`` is the
    request's global-group page row, ``ctx`` the traced cached-prefix
    length.  Sampling / finite flag / fault injection as in ``_prefill_fn``.
    """

    def _prefill(params, masks, pack, caches, batch, table, ctx, n_valid,
                 base_key, temp, topk, *fault):
        logits, caches = lm_prefill_suffix(
            params, cfg, caches, batch, table, ctx, masks=masks, pack=pack,
            n_valid=n_valid,
        )
        last = logits[:, -1]
        if faulty:
            last = jnp.where(fault[0], fault[1], last)
        finite = logits_all_finite(last)[0]
        if greedy:
            tok = jnp.argmax(last[0]).astype(jnp.int32)
        else:
            keys = step_keys(base_key[None], jnp.zeros((1,), jnp.int32))
            tok = sample_tokens(last, keys, temp[None], topk[None])[0]
        return tok, finite, caches

    return jax.jit(_prefill, donate_argnums=(3,))


class _PrefixEntry:
    """One registered shared prefix: its page-aligned token count and the
    global-pool page ids the cache itself holds a reference on (refcount++
    at registration, refcount-- at LRU eviction)."""

    __slots__ = ("plen", "pages")

    def __init__(self, plen: int, pages: list):
        self.plen = plen
        self.pages = pages


class ServeEngine:
    """Fixed-capacity continuous-batching engine over one cache pytree.

    cfg/params as for serve_session; ``capacity`` is the slot count (the
    decode batch), ``max_len`` the per-slot cache length (every request must
    satisfy prompt_len [+ n_patches] + max_new_tokens <= max_len).  masks/
    pack follow the kernel-dispatch contract (launch/serve.py): masks=None
    expects pre-masked params; with masks, params are raw and every matmul
    dispatches through cfg.sparse.kernel, pack carrying the tight-grid
    topology (validated at construction — core/pack.py::validate_pack).

    Fault-tolerance knobs (docs/serving.md#failure-model):
      queue_limit    max queued (un-admitted) requests; submit on a full
                     queue sheds (returns False) instead of growing
      deadline       default admission TTL (seconds from arrival) applied
                     to requests that did not set their own ``ttl``
      max_retries    default quarantine-retry budget for requests that did
                     not set their own ``max_retries``
      faults         optional serving/faults.py::FaultInjector — chaos hooks

    Paged-cache knobs (docs/serving.md#paged-kv-cache):
      paged          KV caches become page POOLS (init_paged_caches) and
                     every slot addresses them through a per-slot block
                     table (serving/block_pool.py) — token-identical to the
                     contiguous layout, but slot memory is allocated in
                     ``page_size`` chunks at admission and returned at
                     release, so the GLOBAL pool can be sized for the
                     traffic's true footprint instead of capacity * max_len
      page_size      tokens per KV page (must divide max_len and each local
                     ring length)
      n_blocks       global-group pool size in pages (None = the
                     no-oversubscription default capacity * max_len /
                     page_size; local ring pools are always fully
                     provisioned — a ring is dense by construction)
      prefix_cache   max LRU-registered shared prefixes (0 = off).  With
                     ``prefix_cache > 0``, admissions whose request declares
                     ``share_prefix_len`` probe a prefix-hash table: a hit
                     maps the leading pages copy-on-write (refcount++, a
                     partially-shared boundary page FORKS) and prefills only
                     the suffix.  All-global causal transformer configs
                     only (no recurrent carry to replay, no MoE routing).

    Observability (docs/observability.md):
      obs            optional repro.obs.Observability bundle.  When set, the
                     engine emits per-request spans (queue_wait / prefill /
                     decode, one trace track per slot), quarantine / retry /
                     shed / fault_injected instants, and updates the serve_*
                     metric families each step.  All instrumentation is
                     host-side — the jitted executables and their arguments
                     are IDENTICAL with and without ``obs``, so decode
                     streams are token-identical by construction
                     (benchmarks/obs_bench.py asserts it anyway).
    """

    def __init__(self, cfg, params, *, capacity: int, max_len: int,
                 masks=None, pack=None, queue_limit: Optional[int] = None,
                 deadline: Optional[float] = None, max_retries: int = 0,
                 faults: Optional[FaultInjector] = None, paged: bool = False,
                 page_size: int = 16, n_blocks: Optional[int] = None,
                 prefix_cache: int = 0, obs=None):
        if not cfg.causal:
            raise ValueError("ServeEngine needs a causal config (no decode "
                             "path for encoder-only models)")
        if cfg.frontend == "frames":
            raise ValueError("frontend='frames' has no token decode loop")
        self.cfg = cfg
        self.params = params
        self.masks = masks
        self.pack = pack
        # integrity guard: a corrupted pack would make every kernel of every
        # request execute the wrong topology — fail at construction, loudly
        validate_pack(pack, where="ServeEngine.pack")
        self.capacity = capacity
        self.max_len = max_len
        self.deadline = deadline
        self.max_retries = max_retries
        self.faults = faults
        self._n_patches = cfg.n_patches if cfg.frontend == "patch" else 0

        # prompt-length bucketing is exact only where end-padding cannot
        # leak into state: causal attention treats pads as never-attended
        # future positions and the masked fill drops their K/V writes, but
        # recurrent carries (hymba SSM h, xLSTM) would integrate pad steps
        # and MoE routing would let pad tokens consume expert capacity —
        # those families trace per exact length (see lm_prefill)
        self._pad_prompts = cfg.block_type == "transformer" and not cfg.n_experts

        self.queue = RequestQueue(max_depth=queue_limit)
        self.paged = paged
        self.page_size = page_size
        self.prefix_cache = prefix_cache
        # prefix sharing replays NOTHING: it needs every layer's cache to be
        # pure position-indexed KV (no recurrent carry, no ring wrap) and
        # admission to be routing-free (no MoE capacity over suffix pads)
        self._share_ok = (
            cfg.block_type == "transformer" and not cfg.n_experts
            and cfg.frontend == "none"
            and all(cache_group(cfg, i) == "global"
                    for i in range(cfg.n_layers))
        )
        if prefix_cache and not paged:
            raise ValueError("prefix_cache needs paged=True (sharing is a "
                             "property of the page tables)")
        if prefix_cache and not self._share_ok:
            raise ValueError(
                "prefix_cache requires an all-global causal transformer "
                "config (no recurrent carries, no MoE, frontend='none') — "
                f"got block_type={cfg.block_type!r}"
            )
        if paged:
            # one pool + one table per cache GROUP (models/model.py::
            # cache_group): all global layers share a page id space sized in
            # max_len-worth rows, local ring layers share a (dense) ring pool
            spans: dict[str, int] = {}
            if cfg.block_type != "xlstm":  # xlstm has no KV to page
                for i in range(cfg.n_layers):
                    g = cache_group(cfg, i)
                    spans[g] = (
                        min(cfg.window, max_len) if g == "local" else max_len
                    )
            for g, span in spans.items():
                if span % page_size:
                    raise ValueError(
                        f"page_size {page_size} must divide the {g} cache "
                        f"length {span}"
                    )
            self._spans = spans
            self.pools: dict[str, BlockPool] = {}
            self.tables: dict[str, np.ndarray] = {}
            n_pages: dict[str, int] = {}
            for g, span in spans.items():
                t = span // page_size
                n = (capacity * t if g == "local" or n_blocks is None
                     else n_blocks)
                self.pools[g] = BlockPool(n, page_size)
                n_pages[g] = n
                self.tables[g] = np.full((capacity, t),
                                         self.pools[g].sentinel, np.int32)
            self.caches = init_paged_caches(cfg, capacity, max_len,
                                            n_pages, page_size)
            self.slot_pages: list[dict[str, list]] = [
                {} for _ in range(capacity)
            ]
            self._device_tables: Optional[dict] = None  # None => dirty
            self._prefix_entries: dict[bytes, _PrefixEntry] = {}
        else:
            self.caches = init_caches(cfg, capacity, max_len)
            self._spans = {}
            self.pools = {}
            self.tables = {}
            self.slot_pages = []
            self._device_tables = None
            self._prefix_entries = {}
        self.n_prefix_hits = 0
        self.n_prefix_misses = 0
        # per-slot host state (the scheduler's view of the pool); the decode
        # step consumes device-resident copies, re-uploaded only when an
        # admission/release dirties the mirrors (steady-state steps upload
        # nothing — the carry advances in-jit)
        self.active = np.zeros(capacity, bool)
        self.pos = np.zeros(capacity, np.int32)
        self.cur_tok = np.zeros(capacity, np.int32)
        self.base_keys = np.zeros((capacity, 2), np.uint32)
        self.gen_idx = np.zeros(capacity, np.int32)
        self.temp = np.zeros(capacity, np.float32)
        self.topk = np.zeros(capacity, np.int32)
        self.slot_req: list[Optional[Request]] = [None] * capacity
        self._device_state: Optional[tuple] = None  # None => mirrors dirty
        # counters (benchmarks/serve_bench.py + chaos_bench.py read these)
        self.n_steps = 0
        self.n_greedy_steps = 0  # steps that took the argmax-only fast path
        self.n_prefills = 0
        self.n_quarantined = 0   # non-finite detections (decode + prefill)
        self.n_retries_total = 0
        self.slot_history: list[tuple[int, int]] = []  # (rid, slot) admissions
        self.quarantine_log: list[QuarantineRecord] = []
        # retrace baseline: stats() reports compiles that happened DURING
        # this engine's lifetime (module-level lru caches are shared across
        # engines, so the absolute miss count includes other instances)
        self._retrace_base = jit_retraces(
            _decode_fn, _prefill_fn, _suffix_prefill_fn
        )
        self.obs = obs
        self._init_obs()
        # both sampler variants bound once: the per-step dispatch is a dict
        # lookup, not a ModelConfig re-hash through the lru_cache (the chaos
        # ``faulty`` variants are looked up lazily — fault-free engines never
        # compile them)
        self._decode = {g: _decode_fn(cfg, g) for g in (False, True)}

    # -- observability (docs/observability.md) -----------------------------

    def _init_obs(self) -> None:
        """Bind one metric-series handle per event kind, ONCE: the hot-path
        cost of an enabled engine is then an attribute add per event — no
        name/label resolution inside the step loop.  Trace tids: 0 is the
        engine/scheduler track, slot ``s`` traces on tid ``s + 1``."""
        if self.obs is None:
            self._m = None
            return
        m = self.obs.metrics
        tr = self.obs.trace
        tr.thread_name(0, "engine")
        for s in range(self.capacity):
            tr.thread_name(s + 1, f"slot{s}")
        req = m.counter("serve_requests_total",
                        "terminal requests by status", labels=("status",))
        pre = m.counter("serve_prefills_total",
                        "admissions by prefill variant", labels=("variant",))
        quar = m.counter("serve_quarantine_total",
                         "non-finite quarantines by phase", labels=("where",))
        self._m = {
            "done": req.labels("DONE"),
            "shed": req.labels("SHED"),
            "failed": req.labels("FAILED"),
            "tokens": m.counter("serve_tokens_total",
                                "tokens generated by DONE requests"),
            "steps": m.counter("serve_decode_steps_total",
                               "engine decode steps dispatched"),
            "prefill_full": pre.labels("full"),
            "prefill_suffix": pre.labels("suffix"),
            "quar_decode": quar.labels("decode"),
            "quar_prefill": quar.labels("prefill"),
            "retries": m.counter("serve_retries_total",
                                 "quarantine retries re-queued"),
            "queue_wait": m.histogram("serve_queue_wait_seconds",
                                      "ready -> admission wait"),
            "prefill_s": m.histogram("serve_prefill_seconds",
                                     "prefill dispatch wall time"),
            "step_s": m.histogram("serve_decode_step_seconds",
                                  "decode-step dispatch wall time"),
            "latency": m.histogram("serve_request_latency_seconds",
                                   "arrival -> DONE latency"),
            "slots": m.gauge("serve_slots_active", "active decode slots"),
            "depth": m.gauge("serve_queue_depth",
                             "waiting (un-admitted) requests"),
            "hit_rate": m.gauge("serve_prefix_hit_rate",
                                "prefix-cache hit fraction of probes"),
            "retraces": m.gauge(
                "serve_retraces",
                "jit variants compiled during this engine's lifetime"),
        }
        if self.paged and self.pools:
            for nm, help_ in (("free", "free pages"), ("live", "live pages"),
                              ("forks", "copy-on-write page forks")):
                fam = m.gauge(f"serve_pool_pages_{nm}" if nm != "forks"
                              else "serve_pool_forks",
                              f"block-pool {help_}", labels=("group",))
                for g in self.pools:
                    self._m[f"pool_{nm}_{g}"] = fam.labels(g)
        # tight-grid kernel telemetry: the pack is engine-lifetime constant,
        # so set-once at construction is the steady-state truth
        publish_pack_gauges(m, self.pack)

    def _obs_gauges(self) -> None:
        """Per-step gauge refresh (enabled engines only): occupancy, queue
        depth, pool pages, prefix hit rate, retraces."""
        mm = self._m
        mm["slots"].set(int(self.active.sum()))
        mm["depth"].set(len(self.queue))
        probes = self.n_prefix_hits + self.n_prefix_misses
        if probes:
            mm["hit_rate"].set(self.n_prefix_hits / probes)
        mm["retraces"].set(
            jit_retraces(_decode_fn, _prefill_fn, _suffix_prefill_fn)
            - self._retrace_base
        )
        for g, pool in self.pools.items():
            mm[f"pool_free_{g}"].set(pool.n_free)
            mm[f"pool_live_{g}"].set(pool.n_live)
            mm[f"pool_forks_{g}"].set(pool.n_forks)

    def _obs_shed(self, reqs, now: float) -> None:
        """Shed annotations (instant + counter) for queue-expired or
        backpressure-dropped requests."""
        if self._m is None or not reqs:
            return
        for r in reqs:
            self._m["shed"].inc()
            self.obs.trace.instant(
                "shed", now, tid=0, cat="serve",
                args={"rid": r.rid, "reason": r.error},
            )

    # -- admission ---------------------------------------------------------

    def _padded_len(self, prompt_len: int) -> int:
        """Token count the prefill trace is compiled for: the next power of
        two where padding is exact (bounding compiles under arbitrary-length
        traffic), the exact length otherwise; always capped so the padded
        sequence still fits the cache rows.  A capping cap is rounded down
        to the flash q-chunk grid when the prompt still fits
        (_chunk_capped_len) so capped prefills chunk evenly."""
        if not self._pad_prompts:
            return prompt_len
        return _chunk_capped_len(
            _bucket_len(prompt_len), self.max_len - self._n_patches,
            prompt_len, getattr(self.cfg, "q_chunk", 0),
        )

    def _prefill_for(self, prompt_len: int, greedy: bool, faulty: bool = False):
        return _prefill_fn(self.cfg, self.max_len, self._padded_len(prompt_len),
                           self._n_patches, greedy, faulty)

    def submit(self, req: Request) -> bool:
        """Enqueue a request.  Returns True if accepted; False if the queue
        is at its depth limit (the request is SHED — structured
        backpressure, not an exception).  Invalid requests (oversize,
        missing patches, max_new_tokens < 1) still raise: those are caller
        bugs, not load."""
        need = req.prompt_len + self._n_patches + req.max_new_tokens
        if need > self.max_len:
            raise ValueError(
                f"request {req.rid}: prompt {req.prompt_len} (+{self._n_patches} "
                f"patches) + max_new_tokens {req.max_new_tokens} needs "
                f"{need} > max_len {self.max_len}"
            )
        if self.paged and "global" in self.pools:
            # the real paged bound is PAGES, not the row span: a request is
            # admissible iff its worst-case footprint ceil(need / page_size)
            # can ever come out of the global pool (an undersized n_blocks
            # makes this tighter than the max_len row bound above — reject
            # at submit, not deadlock at admission)
            pages = -(-need // self.page_size)
            if pages > self.pools["global"].n_blocks:
                raise ValueError(
                    f"request {req.rid}: needs {pages} KV pages "
                    f"(page_size {self.page_size}) but the global block "
                    f"pool only has {self.pools['global'].n_blocks}"
                )
        if self.cfg.frontend == "patch" and req.patches is None:
            raise ValueError(
                f"request {req.rid}: frontend='patch' configs need patches"
            )
        if req.ttl is None:
            req.ttl = self.deadline  # engine-wide default admission deadline
        ok = self.queue.submit(req)
        if not ok:
            # backpressure shed carries no clock (docs/serving.md): annotate
            # at the request's own arrival time
            self._obs_shed([req], req.arrival)
        return ok

    # -- paged-pool bookkeeping (host-side; serving/block_pool.py) ---------

    def _prefix_key(self, req: Request):
        """(key, plen) for an eligible shared-prefix probe, (None, 0) when
        the request shares nothing page-aligned: ``plen`` is the declared
        prefix floored to a page multiple, the key its content hash (the
        table is keyed by TOKENS, so two templates of the same length never
        collide onto each other's pages)."""
        bs = self.page_size
        if not (self.prefix_cache and req.share_prefix_len >= bs):
            return None, 0
        plen = (min(req.share_prefix_len, req.prompt_len) // bs) * bs
        if plen < bs:
            return None, 0
        key = hashlib.sha1(
            np.ascontiguousarray(req.tokens[:plen], np.int32).tobytes()
        ).digest()
        return key, plen

    def _evict_prefix(self) -> None:
        """Drop the least-recently-used registered prefix: the cache's page
        references go away; pages still referenced by live slots stay."""
        key = next(iter(self._prefix_entries))
        entry = self._prefix_entries.pop(key)
        self.pools["global"].free(entry.pages)

    def _ensure_free(self, want: dict) -> bool:
        """True once every group can allocate its ``want`` page count,
        evicting LRU prefix entries (global-pool pressure) as needed."""
        ok = lambda: all(self.pools[g].can_alloc(n) for g, n in want.items())
        while not ok() and self._prefix_entries:
            self._evict_prefix()
        return ok()

    def _copy_page(self, src: int, dst: int) -> None:
        """Device-side COW copy: duplicate one global page's K/V bits into a
        freshly forked page, in every layer (sharing is all-global, so every
        layer's pool carries the page)."""
        for c in self.caches:
            c["kv"] = {
                n: leaf.at[dst].set(leaf[src]) for n, leaf in c["kv"].items()
            }

    def _alloc_pages(self, req: Request, s: int) -> Optional[int]:
        """Allocate slot ``s``'s pages for ``req`` and write its table rows.

        Returns the shared-prefix length ``ctx`` (0 = no sharing; the
        request prefills in full) or None when the pools cannot satisfy the
        request even after LRU prefix eviction — the caller re-queues (a
        release will free pages; submit's pool-capacity bound guarantees
        the request is admissible on a drained pool).

        On a prefix HIT the leading ``ctx // page_size`` pages are mapped
        copy-on-write (refcount++); a partially-shared boundary page is
        forked and device-copied (or written in place when this slot holds
        the only reference — eviction raced the admission), and only
        ``ceil((need - ctx) / page_size)`` pages are newly allocated.
        """
        bs = self.page_size
        need = req.prompt_len + self._n_patches + req.max_new_tokens
        want = {
            g: -(-min(span, need) // bs) for g, span in self._spans.items()
        }
        key, _ = self._prefix_key(req)
        entry = self._prefix_entries.get(key) if key is not None else None
        pool = self.pools.get("global")
        if entry is None:
            if key is not None:
                self.n_prefix_misses += 1
            if not self._ensure_free(want):
                return None
            rows = {g: self.pools[g].alloc(n) for g, n in want.items()}
            ctx = 0
        else:
            # never admit a zero-token suffix: the prefill logits must come
            # from a real forward, so at least the last prompt token reruns
            ctx = min(entry.plen, req.prompt_len - 1)
            n_keep = ctx // bs
            boundary = ctx % bs != 0
            self._prefix_entries[key] = self._prefix_entries.pop(key)  # LRU
            shared = [int(p) for p in entry.pages[: n_keep + boundary]]
            pool.incref(shared)  # hold the pages before any eviction below
            if not self._ensure_free({"global": want["global"] - n_keep}):
                pool.free(shared)
                return None
            row = shared[:n_keep]
            n_fresh = want["global"] - n_keep
            if boundary:
                bp = shared[-1]
                if pool.refcount[bp] >= 2:  # still shared: fork + copy
                    new_bp = pool.fork(bp)
                    self._copy_page(bp, new_bp)
                    row.append(new_bp)
                else:  # eviction raced us: the page is exclusively ours
                    row.append(bp)
                n_fresh -= 1
            row += pool.alloc(n_fresh)
            rows = {"global": row}
            self.n_prefix_hits += 1
        for g, pages in rows.items():
            self.tables[g][s] = self.pools[g].sentinel
            self.tables[g][s, : len(pages)] = pages
        self.slot_pages[s] = rows
        self._device_tables = None
        return ctx

    def _free_slot_pages(self, s: int) -> None:
        """Return slot ``s``'s page references to the pools (shared pages
        outlive it via the prefix cache's / other slots' references)."""
        for g, pages in self.slot_pages[s].items():
            self.pools[g].free(pages)
            self.tables[g][s] = self.pools[g].sentinel
        if self.slot_pages[s]:
            self.slot_pages[s] = {}
            self._device_tables = None

    def _register_prefix(self, req: Request, s: int) -> None:
        """After a successful FULL prefill: publish the request's leading
        page-aligned prefix pages into the prefix-hash table (the cache
        takes its own references), evicting LRU entries past the limit."""
        key, plen = self._prefix_key(req)
        if key is None or key in self._prefix_entries:
            return
        pages = [int(p) for p in self.tables["global"][s][: plen // self.page_size]]
        self.pools["global"].incref(pages)
        self._prefix_entries[key] = _PrefixEntry(plen, pages)
        while len(self._prefix_entries) > self.prefix_cache:
            self._evict_prefix()

    def check_pool_accounting(self) -> None:
        """Audit every pool against the scheduler's books: live pages must
        be EXACTLY the slot-table references plus the prefix-cache holds
        (serving/block_pool.py::check) — the chaos leak test's invariant."""
        for g, pool in self.pools.items():
            refs = [p for sp in self.slot_pages for p in sp.get(g, ())]
            if g == "global":
                for e in self._prefix_entries.values():
                    refs.extend(e.pages)
            pool.check(refs)

    def _admit(self, now: float, finished: list, clock=None) -> None:
        while True:
            free = np.nonzero(~self.active)[0]
            if len(free) == 0:
                return
            req = self.queue.pop_ready(now)
            if req is None:
                return
            s = int(free[0])
            req.status = Status.PREFILL
            ctx = 0
            if self.paged and self.pools:
                got = self._alloc_pages(req, s)
                if got is None:
                    # pools exhausted (outstanding slots hold the pages):
                    # hand the request back; a release frees pages and the
                    # next step retries — structured deferral, not an error
                    self.queue.requeue(req)
                    return
                ctx = got
            base = request_key(req.seed)
            fval = (
                self.faults.prefill_fault(req.rid, req.n_retries)
                if self.faults else None
            )
            if self.faults and clock is not None:
                delay = self.faults.prefill_delay(req.rid)
                if delay > 0:
                    time.sleep(delay)  # wall-clock chaos only (run())
            t0 = clock() if clock is not None else now
            if ctx:
                # shared-prefix hit: run ONLY the suffix through the model
                slen = req.prompt_len - ctx
                padded = _chunk_capped_len(
                    _bucket_len(slen), self.max_len, slen,
                    getattr(self.cfg, "q_chunk", 0),
                )
                toks = np.zeros(padded, np.int32)
                toks[:slen] = np.asarray(req.tokens[ctx:], np.int32)
                batch = {"tokens": jnp.asarray(toks)[None]}
                args = (
                    self.params, self.masks, self.pack, self.caches, batch,
                    jnp.asarray(self.tables["global"][s]), jnp.int32(ctx),
                    jnp.int32(slen), jnp.asarray(base),
                    jnp.float32(req.temperature), jnp.int32(req.top_k),
                )
                if fval is not None:
                    args = args + (jnp.bool_(True), jnp.float32(fval))
                tok, fin, self.caches = _suffix_prefill_fn(
                    self.cfg, padded, req.temperature <= 0.0, fval is not None
                )(*args)
            else:
                toks = np.zeros(self._padded_len(req.prompt_len), np.int32)
                toks[: req.prompt_len] = np.asarray(req.tokens, np.int32)
                batch = {"tokens": jnp.asarray(toks)[None]}
                if req.patches is not None:
                    batch["patches"] = jnp.asarray(req.patches)[None]
                tables = (
                    {g: jnp.asarray(self.tables[g][s]) for g in self.tables}
                    if self.paged and self.pools else None
                )
                args = (
                    self.params, self.masks, self.pack, self.caches, batch,
                    jnp.int32(s), jnp.int32(req.prompt_len + self._n_patches),
                    jnp.asarray(base), jnp.float32(req.temperature),
                    jnp.int32(req.top_k), tables,
                )
                if fval is not None:
                    args = args + (jnp.bool_(True), jnp.float32(fval))
                tok, fin, self.caches = self._prefill_for(
                    req.prompt_len, req.temperature <= 0.0, fval is not None
                )(*args)
            self.n_prefills += 1
            tok = int(tok)  # blocks on the prefill -> post-compute timestamps
            t = clock() if clock is not None else now
            if self._m is not None:
                tid = s + 1
                self.obs.trace.span(
                    "queue_wait", req.ready_at, t0, tid=tid, cat="serve",
                    args={"rid": req.rid, "attempt": req.n_retries},
                )
                self.obs.trace.span(
                    "prefill", t0, t, tid=tid, cat="serve",
                    args={"rid": req.rid, "attempt": req.n_retries,
                          "variant": "suffix" if ctx else "full",
                          "padded_len": len(toks), "slot": int(s)},
                )
                self._m["queue_wait"].observe(max(t0 - req.ready_at, 0.0))
                self._m["prefill_s"].observe(max(t - t0, 0.0))
                self._m["prefill_suffix" if ctx else "prefill_full"].inc()
            if not bool(fin):
                # prefill produced non-finite logits: the slot was written
                # but never activated — quarantine before the request exists
                # anywhere but the queue's books
                self._quarantine(req, s, t, finished, where="prefill")
                continue
            if self.paged and self.pools and not ctx:
                # publish the (now finite-verified) prefix pages for reuse —
                # a quarantined prefill's garbage pages are never registered
                self._register_prefix(req, s)
            req.generated.append(tok)
            req.slot = s
            req.status = Status.DECODE
            req.t_admitted = t
            self.slot_history.append((req.rid, s))
            self.slot_req[s] = req
            self.active[s] = True
            self.pos[s] = req.prompt_len + self._n_patches
            self.cur_tok[s] = tok
            self.base_keys[s] = base
            self.gen_idx[s] = 1
            self.temp[s] = req.temperature
            self.topk[s] = req.top_k
            self._device_state = None
            if self._is_finished(req, tok):
                self._release(req, t)
                finished.append(req)

    def _is_finished(self, req: Request, tok: int) -> bool:
        return len(req.generated) >= req.max_new_tokens or (
            req.eos_id is not None and tok == req.eos_id
        )

    def _release(self, req: Request, now: float) -> None:
        s = req.slot
        self.queue.finish(req, now)
        if self.paged and self.pools:
            self._free_slot_pages(s)
        self.active[s] = False
        self.slot_req[s] = None
        self._device_state = None
        if self._m is not None:
            self._m["done"].inc()
            self._m["tokens"].inc(len(req.generated))
            if req.latency is not None:
                self._m["latency"].observe(req.latency)
            # the request's decode residency on its slot's track: first
            # token (t_admitted) -> terminal
            self.obs.trace.span(
                "decode", req.t_admitted, now, tid=s + 1, cat="serve",
                args={"rid": req.rid, "n_tokens": len(req.generated)},
            )

    def _quarantine(self, req: Request, slot: int, now: float,
                    finished: list, *, where: str) -> None:
        """Non-finite logits on ``req``'s slot: discard the garbage token,
        scrub and recycle the slot (the row is fully overwritten by the next
        admission's lm_prefill_into, so nothing stale survives), then either
        re-queue with exponential backoff or land the request FAILED.  Every
        OTHER slot is untouched — quarantine is per-request by construction.
        """
        self.n_quarantined += 1
        # attempt = the retry ordinal that FAILED (0 = first admission):
        # the same value the FaultInjector logged for a prefill fault, and
        # (with the step key) the unambiguous join against decode entries
        self.quarantine_log.append(
            QuarantineRecord(self.n_steps, req.rid, slot, req.n_retries, where)
        )
        if self._m is not None:
            self._m["quar_decode" if where == "decode"
                    else "quar_prefill"].inc()
            self.obs.trace.instant(
                "quarantine", now, tid=slot + 1, cat="chaos",
                args={"step": self.n_steps, "rid": req.rid, "slot": slot,
                      "attempt": req.n_retries, "where": where},
            )
        if self.paged and self.pools:
            self._free_slot_pages(slot)  # scrub = return the pages too
        self.active[slot] = False
        self.slot_req[slot] = None
        self._device_state = None
        limit = self.max_retries if req.max_retries is None else req.max_retries
        if req.n_retries < limit:
            req.n_retries += 1
            self.n_retries_total += 1
            req.generated = []  # the retry restarts the stream from scratch
            req.slot = None
            req.t_admitted = None
            req.retry_at = now + req.retry_backoff * (2 ** (req.n_retries - 1))
            self.queue.requeue(req)
            if self._m is not None:
                self._m["retries"].inc()
                self.obs.trace.instant(
                    "retry", now, tid=0, cat="chaos",
                    args={"rid": req.rid, "attempt": req.n_retries,
                          "retry_at": req.retry_at},
                )
        else:
            self.queue.fail(
                req, now,
                f"non-finite logits during {where} "
                f"(after {req.n_retries} retries)",
            )
            finished.append(req)
            if self._m is not None:
                self._m["failed"].inc()

    # -- stepping ----------------------------------------------------------

    def step(self, now: float = 0.0, clock=None) -> list[Request]:
        """Shed expired queue entries, admit what fits, then decode one
        token on every active slot.  Returns the requests that reached a
        TERMINAL status (DONE, SHED or FAILED) during this step.  Never
        raises on in-flight faults: non-finite rows quarantine, expired
        requests shed — failure is data, not control flow.

        ``now`` gates arrivals (virtual-clock friendly for tests); ``clock``,
        when given (run() passes the wall clock), re-samples time AFTER the
        blocking prefill/decode computes so t_admitted/t_done include the
        work that produced them — otherwise latencies would be short by up
        to a full step.
        """
        finished: list[Request] = []
        shed = self.queue.shed_expired(now)
        finished.extend(shed)
        self._obs_shed(shed, now)
        self._admit(now, finished, clock)
        if not self.active.any():
            if self._m is not None:
                self._obs_gauges()
            return finished
        t0 = clock() if clock is not None else now
        if self._device_state is None:  # mirrors changed: re-upload the carry
            self._device_state = (
                jnp.asarray(self.cur_tok[:, None]), jnp.asarray(self.pos),
                jnp.asarray(self.active), jnp.asarray(self.base_keys),
                jnp.asarray(self.gen_idx), jnp.asarray(self.temp),
                jnp.asarray(self.topk),
            )
        tok_d, pos_d, act_d, keys_d, gen_d, temp_d, topk_d = self._device_state
        # all-greedy steps skip the sampler entirely (argmax, no (B, V) sort)
        greedy = not bool(np.any(self.temp[self.active] > 0.0))
        fault = (
            self.faults.decode_fault(self.n_steps, self.capacity)
            if self.faults else None
        )
        if fault is None:
            fn, extra = self._decode[greedy], ()
        else:
            fn = _decode_fn(self.cfg, greedy, True)
            extra = (jnp.asarray(fault[0]), jnp.asarray(fault[1]))
            if self._m is not None:
                # record which TARGETED slots were active (with the request
                # each held): injections on parked slots are no-ops, so this
                # is the exact expected-quarantine set for this step — the
                # trace <-> FaultInjector.log join obs_bench verifies
                hit = [
                    {"slot": int(s2), "rid": self.slot_req[s2].rid,
                     "attempt": self.slot_req[s2].n_retries}
                    for s2 in np.nonzero(fault[0])[0] if self.active[s2]
                ]
                self.obs.trace.instant(
                    "fault_injected", now, tid=0, cat="chaos",
                    args={"step": self.n_steps,
                          "targeted": [int(x) for x in np.nonzero(fault[0])[0]],
                          "active": hit},
                )
        tabs = None
        if self.paged and self.pools:
            if self._device_tables is None:  # a table row changed: re-upload
                self._device_tables = {
                    g: jnp.asarray(t) for g, t in self.tables.items()
                }
            tabs = self._device_tables
        nxt, finite, self.caches, tok_d, pos_d, gen_d = fn(
            self.params, self.masks, self.pack, self.caches,
            tok_d, pos_d, act_d, keys_d, gen_d, temp_d, topk_d, tabs, *extra,
        )
        self._device_state = (tok_d, pos_d, act_d, keys_d, gen_d, temp_d, topk_d)
        nxt = np.asarray(nxt)  # blocks on the decode -> post-compute timestamp
        finite = np.asarray(finite)
        t = clock() if clock is not None else now
        if self._m is not None:
            self.obs.trace.span(
                "decode_step", t0, t, tid=0, cat="serve",
                args={"step": self.n_steps,
                      "n_active": int(self.active.sum()),
                      "greedy": bool(greedy)},
            )
            self._m["step_s"].observe(max(t - t0, 0.0))
            self._m["steps"].inc()
        for s in np.nonzero(self.active)[0]:
            req = self.slot_req[s]
            if not finite[s]:
                self._quarantine(req, int(s), t, finished, where="decode")
                continue
            tok = int(nxt[s])
            req.generated.append(tok)
            self.pos[s] += 1
            self.gen_idx[s] += 1
            self.cur_tok[s] = tok
            if self._is_finished(req, tok):
                self._release(req, t)
                finished.append(req)
        # counted AFTER the host loop so quarantine_log records the SAME
        # step index the FaultInjector keys on (the pre-increment counter
        # the fault lookup above used)
        self.n_steps += 1
        self.n_greedy_steps += greedy
        if self._m is not None:
            self._obs_gauges()
        return finished

    def run(self) -> dict:
        """Drive until the queue drains; wall-clock arrivals (request
        ``arrival`` values are offsets from this call).  Returns summary
        stats — ``wall_s`` is stamped even when every request was shed
        before admission and the loop never ran; per-request timings live
        on the Request objects (queue.done)."""
        t0 = time.monotonic()
        clock = lambda: time.monotonic() - t0
        while len(self.queue) or self.active.any():
            self.step(clock(), clock)
            if not self.active.any() and len(self.queue):
                nxt = self.queue.next_arrival()
                if nxt is not None:
                    wait = nxt - clock()
                    if wait > 0:
                        time.sleep(wait)
        return self.stats(clock())

    def stats(self, wall_s: float) -> dict:
        """Aggregate summary.  Safe on EMPTY populations: zero completed /
        all-shed runs report 0.0 percentiles instead of indexing empty
        arrays, and ``wall_s`` is whatever the caller measured (run()
        stamps it unconditionally)."""
        by = lambda st: [r for r in self.queue.done if r.status is st]
        done = by(Status.DONE)
        shed = by(Status.SHED)
        failed = by(Status.FAILED)
        toks = sum(len(r.generated) for r in done)
        lat = np.asarray(
            [r.latency for r in done if r.latency is not None], np.float64
        )
        waits = np.asarray(
            [r.t_admitted - r.arrival for r in self.queue.done
             if r.t_admitted is not None], np.float64
        )
        out = {
            "requests": len(done),
            "shed": len(shed),
            "failed": len(failed),
            "quarantined": self.n_quarantined,
            "retries": self.n_retries_total,
            "tokens": toks,
            "wall_s": wall_s,
            "tok_per_s": toks / max(wall_s, 1e-9),
            "decode_steps": self.n_steps,
            "prefills": self.n_prefills,
            "latency_p50_s": percentile(lat, 50),
            "latency_p95_s": percentile(lat, 95),
            "queue_wait_p50_s": percentile(waits, 50),
            "queue_wait_p95_s": percentile(waits, 95),
            # jit variants compiled during THIS engine's lifetime (the
            # module-level caches are shared, hence the construction-time
            # baseline): nonzero growth during steady-state traffic is the
            # pack-width-hysteresis / bucket-churn regression signal
            "n_retraces": jit_retraces(
                _decode_fn, _prefill_fn, _suffix_prefill_fn
            ) - self._retrace_base,
        }
        if self.paged and self.pools:
            out["prefix_hits"] = self.n_prefix_hits
            out["prefix_misses"] = self.n_prefix_misses
            out["prefix_entries"] = len(self._prefix_entries)
            out["kv_forks"] = sum(p.n_forks for p in self.pools.values())
            out["pages_free"] = {g: p.n_free for g, p in self.pools.items()}
            out["pages_live"] = {g: p.n_live for g, p in self.pools.items()}
        return out

"""Deterministic fault injection for chaos-testing the serving engine.

``FaultInjector`` is the single knob the chaos tests and
benchmarks/chaos_bench.py turn: it schedules faults ahead of time (seeded,
reproducible — same plan, same run) and the ``ServeEngine`` consults it at
its two hook points:

  * **decode logits corruption** — ``poison_logits(step, slot)`` marks a
    (decode-step, slot) pair; at that step the engine dispatches the faulty
    decode variant, which overwrites that slot's logits row with NaN/Inf
    IN-JIT, *before* the finite-flag reduction and the sampler (so the
    detection path sees exactly what a real non-finite forward would
    produce).  Every other slot's logits are bit-untouched — the injection
    is a per-row ``jnp.where``, which is what makes the chaos isolation
    invariant testable: unaffected requests must be token-identical to a
    fault-free run.
  * **prefill corruption / delay** — ``poison_prefill(rid)`` corrupts the
    prefill logits of every admission attempt of that request (exercising
    retry exhaustion); ``delay_prefill(rid, seconds)`` sleeps the host
    before the prefill (wall-clock runs only), building queue backlog so
    deadline shedding triggers under test.

Pack corruption (``truncate_pack``) and burst arrival storms
(``burst_storm``) are module functions rather than engine hooks: the pack
guard fires at engine CONSTRUCTION (core/pack.py::validate_pack), and a
storm is just a workload.

The injector never reaches inside jit except through the explicit fault
arguments of the faulty step variants — a fault-free engine compiles and
runs the exact same executables as an engine with no injector attached.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from .queue import Request

__all__ = ["FaultInjector", "truncate_pack", "burst_storm"]

NAN = float("nan")
INF = float("inf")


class FaultInjector:
    """Seeded, pre-planned fault schedule consumed by ``ServeEngine`` hooks.

    All scheduling is host-side and deterministic: the engine's decode-step
    counter (``ServeEngine.n_steps``) keys decode faults, request rids key
    prefill faults — under a virtual clock the same workload replays the
    same faults at the same points, which the isolation tests rely on.
    """

    def __init__(self, seed: int = 0):
        self.rng = np.random.default_rng(seed)
        self._decode: dict[int, dict[int, float]] = {}   # step -> {slot: val}
        self._prefill: dict[int, float] = {}             # rid -> value
        self._delays: dict[int, float] = {}              # rid -> seconds
        self.log: list[tuple] = []  # (kind, key, detail) of FIRED injections

    # -- planning ----------------------------------------------------------

    def poison_logits(self, step: int, slot: int, value: float = NAN) -> "FaultInjector":
        """Corrupt ``slot``'s logits row to ``value`` at decode step ``step``
        (engine-global step counter).  A pair targeting an inactive slot is
        a no-op (parked slots' logits are garbage by design and never read).
        Returns self for chaining."""
        self._decode.setdefault(int(step), {})[int(slot)] = float(value)
        return self

    def poison_random(self, n: int, *, max_step: int, capacity: int,
                      value: float = NAN) -> list[tuple[int, int]]:
        """Schedule ``n`` seeded-random (step, slot) poisonings; returns the
        chosen pairs so tests/benches know what was planned."""
        pairs = []
        while len(pairs) < n:
            step = int(self.rng.integers(0, max_step))
            slot = int(self.rng.integers(0, capacity))
            if self._decode.get(step, {}).get(slot) is None:
                self.poison_logits(step, slot, value)
                pairs.append((step, slot))
        return pairs

    def poison_prefill(self, rid: int, value: float = NAN) -> "FaultInjector":
        """Corrupt the prefill logits of EVERY admission attempt of request
        ``rid`` — the way to drive a request through retry exhaustion."""
        self._prefill[int(rid)] = float(value)
        return self

    def delay_prefill(self, rid: int, seconds: float) -> "FaultInjector":
        """Host-sleep before ``rid``'s prefill (wall-clock runs only —
        virtual-clock tests model delay by advancing ``now`` instead)."""
        self._delays[int(rid)] = float(seconds)
        return self

    # -- engine-facing hooks ----------------------------------------------

    def decode_fault(self, step: int, capacity: int):
        """(mask (B,) bool, values (B,) f32) for this step, or None."""
        plan = self._decode.get(int(step))
        if not plan:
            return None
        mask = np.zeros(capacity, bool)
        vals = np.zeros(capacity, np.float32)
        for slot, v in plan.items():
            if 0 <= slot < capacity:
                mask[slot] = True
                vals[slot] = v
        if not mask.any():
            return None
        self.log.append(("decode", int(step), tuple(sorted(plan))))
        return mask, vals

    def prefill_fault(self, rid: int, attempt: int = 0) -> Optional[float]:
        """``attempt`` is the request's retry ordinal (0 = first admission):
        logged alongside the rid so a retried-then-poisoned-again request's
        fired entries are distinguishable — the engine's quarantine records
        carry the same (rid, attempt) pair, making the trace <-> injector
        correlation exact (benchmarks/obs_bench.py cross-checks it)."""
        v = self._prefill.get(int(rid))
        if v is not None:
            self.log.append(("prefill", int(rid), int(attempt), v))
        return v

    def prefill_delay(self, rid: int) -> float:
        return self._delays.get(int(rid), 0.0)


def truncate_pack(pack, *, mode: str = "truncate", seed: int = 0):
    """Return a corrupted deep copy of a PackState pytree (core/pack.py).

    Corruption lands on the first packed entry (deterministic; ``seed``
    picks the column for multi-column modes):

      truncate   chop the trailing CSC width column while leaving ``cnt``
                 claiming the old width — the kernel would read past the
                 packed index rows
      oob        write an out-of-range K-block id into a live CSC slot —
                 the kernel would DMA a block that does not exist
      nnz        break the count/nnz consistency (cnt sum no longer equals
                 the recorded total) — silent topology drift

    Used with ``core/pack.py::validate_pack`` to assert the integrity guard
    turns each of these silent wrong-answer states into a loud
    PackIntegrityError.
    """
    import jax

    from ..core.pack import is_pack_entry

    # entry-level deep copy (np.array copies) so the caller's pack is never
    # mutated; None (unpacked) leaves stay None
    pack = jax.tree_util.tree_map(
        lambda e: None if e is None else {k: np.array(v) for k, v in e.items()},
        pack,
        is_leaf=is_pack_entry,
    )
    rng = np.random.default_rng(seed)
    flat = jax.tree_util.tree_leaves(pack, is_leaf=is_pack_entry)
    entry = next(e for e in flat if isinstance(e, dict))
    idx, cnt = np.asarray(entry["idx"]), np.asarray(entry["cnt"])
    if mode == "truncate":
        entry["idx"] = np.ascontiguousarray(idx[..., :-1])
        entry["ridx"] = np.ascontiguousarray(np.asarray(entry["ridx"])[..., :-1])
        # cnt/rcnt left claiming the old width: counts now exceed capacity
    elif mode == "oob":
        col = int(rng.integers(0, cnt.shape[-1]))
        flat_cnt = cnt.reshape(-1)
        live_cols = np.nonzero(flat_cnt > 0)[0]
        col = int(live_cols[col % len(live_cols)])
        idx2 = idx.reshape(-1, idx.shape[-1]).copy()
        idx2[col, 0] = int(entry["nkb"]) + 3  # one past the K-block grid
        entry["idx"] = idx2.reshape(idx.shape)
    elif mode == "nnz":
        entry["nnz"] = np.int32(int(entry["nnz"]) + 1)
    else:
        raise ValueError(f"unknown corruption mode {mode!r}")
    return pack


def burst_storm(cfg, n: int, *, at: float = 0.0, prompt_len: int = 8,
                max_new_tokens: int = 8, ttl: Optional[float] = None,
                seed: int = 0, rid0: int = 0) -> list[Request]:
    """``n`` requests all arriving at the same instant — the overload
    workload for backpressure/deadline-shedding tests and
    benchmarks/chaos_bench.py.  Seeded random prompts; greedy sampling so
    streams are bit-reproducible."""
    rng = np.random.default_rng(seed)
    return [
        Request(
            rid=rid0 + i,
            tokens=rng.integers(0, cfg.vocab_size, size=prompt_len).astype(np.int32),
            max_new_tokens=max_new_tokens,
            arrival=float(at),
            ttl=ttl,
        )
        for i in range(n)
    ]

"""Request lifecycle + arrival queue for the continuous-batching engine.

A ``Request`` moves through a small state machine with explicit failure
edges (docs/serving.md#failure-model):

  QUEUED   submitted, waiting for its arrival time AND a free slot
  PREFILL  admitted: its prompt is being scattered into a cache slot
           (models/model.py::lm_prefill_into) — transient within one
           engine.step(), which also samples the first token
  DECODE   occupying a slot; one token per engine step
  DONE     hit max_new_tokens or its eos_id; slot freed for the next request
  SHED     terminal, never admitted: the queue was at its depth limit at
           submit time (backpressure) or the request sat in-queue past its
           deadline (``arrival + ttl``).  A structured status, NOT an
           exception — load shedding is normal operation under overload.
  FAILED   terminal, admitted but quarantined: the engine detected
           non-finite logits on the request's slot (serving/engine.py) and
           its bounded retries (if any) are exhausted.

Retries: a quarantined request whose ``n_retries`` has not reached its
retry budget re-enters QUEUED with ``retry_at`` pushed out by exponential
backoff; its generated stream restarts from scratch (sampling is a pure
function of (weights, prompt, params, seed) — serving/sampler.py — so a
successful retry reproduces the fault-free stream exactly).

``RequestQueue`` is the engine-facing arrival buffer: FIFO over requests
whose ``ready_at`` time has passed (simulated-clock friendly — the engine
passes ``now`` explicitly, so tests can drive a virtual clock and the bench
can drive the wall clock), with an optional ``max_depth`` bound — a full
queue sheds at submit instead of growing without bound.
``poisson_arrivals`` builds the bench workload's arrival offsets.
"""
from __future__ import annotations

import bisect
import dataclasses
import enum
from typing import Optional

import numpy as np

__all__ = ["Status", "Request", "RequestQueue", "poisson_arrivals"]


class Status(enum.Enum):
    QUEUED = "queued"
    PREFILL = "prefill"
    DECODE = "decode"
    DONE = "done"
    SHED = "shed"      # terminal: dropped in-queue (deadline / backpressure)
    FAILED = "failed"  # terminal: quarantined in-flight, retries exhausted


#: statuses from which a request will never run (again)
TERMINAL = (Status.DONE, Status.SHED, Status.FAILED)


@dataclasses.dataclass
class Request:
    """One generation request plus its engine-side bookkeeping.

    tokens: (L,) int prompt.  max_new_tokens counts EVERY generated token,
    including the one produced from the prefill logits.  temperature <= 0 is
    greedy; seed feeds the per-request PRNG stream (serving/sampler.py).
    eos_id stops generation the step it is produced (the eos token itself is
    kept in ``generated``).  patches: optional (n_patches, frontend_dim)
    prompt embeddings for VLM (frontend='patch') configs.

    ttl: seconds after ``arrival`` the request may wait UN-ADMITTED before
    it is shed (None = wait forever; the engine fills in its ``deadline``
    default at submit).  The deadline is an admission deadline measured
    from the ORIGINAL arrival — a retry re-queued past it is shed too (the
    client it would answer is presumed gone).
    max_retries: quarantine-retry budget for THIS request (None = use the
    engine default); retry_backoff seconds double per attempt.
    """

    rid: int
    tokens: np.ndarray
    max_new_tokens: int
    temperature: float = 0.0
    top_k: int = 0
    seed: int = 0
    eos_id: Optional[int] = None
    arrival: float = 0.0
    patches: Optional[np.ndarray] = None
    ttl: Optional[float] = None
    max_retries: Optional[int] = None
    retry_backoff: float = 0.05
    # shared-prefix declaration (paged engines with prefix_cache > 0): the
    # first ``share_prefix_len`` prompt tokens are a common template whose
    # KV pages may be shared copy-on-write across requests hashing to the
    # same prefix (serving/engine.py#prefix-cache).  0 = no sharing.
    share_prefix_len: int = 0
    # engine-filled:
    status: Status = Status.QUEUED
    generated: list = dataclasses.field(default_factory=list)
    slot: Optional[int] = None
    t_admitted: Optional[float] = None  # prefill time == first-token time
    t_done: Optional[float] = None      # terminal time (DONE, SHED or FAILED)
    n_retries: int = 0
    retry_at: float = 0.0  # earliest re-admission time after a quarantine
    error: Optional[str] = None  # structured failure reason (FAILED / SHED)

    @property
    def prompt_len(self) -> int:
        return int(np.shape(self.tokens)[0])

    @property
    def ready_at(self) -> float:
        """Earliest time this request may be admitted: its arrival, pushed
        out by retry backoff after a quarantine."""
        return max(self.arrival, self.retry_at)

    @property
    def expires_at(self) -> Optional[float]:
        """Deadline for ADMISSION (None = never expires)."""
        return None if self.ttl is None else self.arrival + self.ttl

    @property
    def latency(self) -> Optional[float]:
        """Arrival -> terminal (None until the request reaches a terminal
        status with a stamped time — submit-time sheds carry no clock)."""
        return None if self.t_done is None else self.t_done - self.arrival


class RequestQueue:
    """Bounded, arrival-ordered admission buffer.

    The waiting list is kept sorted by ``ready_at`` (stable for ties, so
    equal-arrival requests admit in submission order) — submissions need NOT
    arrive pre-sorted; a request submitted after one with a later arrival
    still admits the moment its own arrival passes.

    max_depth: queue-depth limit.  ``submit`` on a full queue marks the
    request SHED and returns False instead of growing without bound —
    backpressure the caller can see.  ``requeue`` (quarantine retries) is
    exempt: a retry already holds a completed admission's worth of work.
    """

    def __init__(self, max_depth: Optional[int] = None):
        self.max_depth = max_depth
        self._waiting: list[Request] = []
        self.done: list[Request] = []  # every TERMINAL request, any status

    def submit(self, req: Request) -> bool:
        """Enqueue; False (status SHED) when the depth limit is hit."""
        if req.max_new_tokens < 1:
            raise ValueError(f"request {req.rid}: max_new_tokens must be >= 1")
        if self.max_depth is not None and len(self._waiting) >= self.max_depth:
            req.status = Status.SHED
            req.error = f"queue full (depth limit {self.max_depth})"
            self.done.append(req)
            return False
        req.status = Status.QUEUED
        bisect.insort(self._waiting, req, key=lambda r: r.ready_at)
        return True

    def requeue(self, req: Request) -> None:
        """Re-enter a quarantined request for a retry (depth-limit exempt)."""
        req.status = Status.QUEUED
        bisect.insort(self._waiting, req, key=lambda r: r.ready_at)

    def pop_ready(self, now: float) -> Optional[Request]:
        """Earliest-ready request whose ready_at has passed, else None."""
        if self._waiting and self._waiting[0].ready_at <= now:
            return self._waiting.pop(0)
        return None

    def shed_expired(self, now: float) -> list[Request]:
        """Drop every waiting request whose admission deadline has passed.

        Returns the shed requests (status SHED, t_done stamped) — the
        engine calls this at the top of every step, so a request is never
        admitted after its deadline and the queue cannot accumulate stale
        work under overload.
        """
        shed = []
        kept = []
        for r in self._waiting:
            exp = r.expires_at
            if exp is not None and now > exp:
                r.status = Status.SHED
                r.error = f"deadline: not admitted within ttl={r.ttl}s"
                r.t_done = now
                self.done.append(r)
                shed.append(r)
            else:
                kept.append(r)
        if shed:
            self._waiting = kept
        return shed

    def next_arrival(self) -> Optional[float]:
        return self._waiting[0].ready_at if self._waiting else None

    def finish(self, req: Request, now: float) -> None:
        req.status = Status.DONE
        req.t_done = now
        req.slot = None
        self.done.append(req)

    def fail(self, req: Request, now: float, error: str) -> None:
        """Terminal quarantine: retries exhausted (or disabled)."""
        req.status = Status.FAILED
        req.error = error
        req.t_done = now
        req.slot = None
        self.done.append(req)

    def __len__(self) -> int:
        return len(self._waiting)


def poisson_arrivals(n: int, rate: float, seed: int = 0) -> np.ndarray:
    """(n,) cumulative arrival offsets (seconds) for a rate req/s Poisson
    process; rate <= 0 => everything arrives at t=0 (burst)."""
    if rate <= 0:
        return np.zeros(n)
    rng = np.random.default_rng(seed)
    return np.cumsum(rng.exponential(1.0 / rate, size=n))

"""Request lifecycle + arrival queue for the continuous-batching engine.

A ``Request`` moves QUEUED -> PREFILL -> DECODE -> DONE:

  QUEUED   submitted, waiting for its arrival time AND a free slot
  PREFILL  admitted: its prompt is being scattered into a cache slot
           (models/model.py::lm_prefill_into) — transient within one
           engine.step(), which also samples the first token
  DECODE   occupying a slot; one token per engine step
  DONE     hit max_new_tokens or its eos_id; slot freed for the next request

``RequestQueue`` is the engine-facing arrival buffer: FIFO over requests
whose ``arrival`` time has passed (simulated-clock friendly — the engine
passes ``now`` explicitly, so tests can drive a virtual clock and the bench
can drive the wall clock).  ``poisson_arrivals`` builds the bench workload's
arrival offsets.
"""
from __future__ import annotations

import bisect
import dataclasses
import enum
from typing import Optional

import numpy as np

__all__ = ["Status", "Request", "RequestQueue", "poisson_arrivals"]


class Status(enum.Enum):
    QUEUED = "queued"
    PREFILL = "prefill"
    DECODE = "decode"
    DONE = "done"


@dataclasses.dataclass
class Request:
    """One generation request plus its engine-side bookkeeping.

    tokens: (L,) int prompt.  max_new_tokens counts EVERY generated token,
    including the one produced from the prefill logits.  temperature <= 0 is
    greedy; seed feeds the per-request PRNG stream (serving/sampler.py).
    eos_id stops generation the step it is produced (the eos token itself is
    kept in ``generated``).  patches: optional (n_patches, frontend_dim)
    prompt embeddings for VLM (frontend='patch') configs.
    """

    rid: int
    tokens: np.ndarray
    max_new_tokens: int
    temperature: float = 0.0
    top_k: int = 0
    seed: int = 0
    eos_id: Optional[int] = None
    arrival: float = 0.0
    patches: Optional[np.ndarray] = None
    # engine-filled:
    status: Status = Status.QUEUED
    generated: list = dataclasses.field(default_factory=list)
    slot: Optional[int] = None
    t_admitted: Optional[float] = None  # prefill time == first-token time
    t_done: Optional[float] = None

    @property
    def prompt_len(self) -> int:
        return int(np.shape(self.tokens)[0])

    @property
    def latency(self) -> Optional[float]:
        """Arrival -> completion (None until DONE)."""
        return None if self.t_done is None else self.t_done - self.arrival


class RequestQueue:
    """Arrival-ordered admission buffer.

    The waiting list is kept sorted by arrival time (stable for ties, so
    equal-arrival requests admit in submission order) — submissions need NOT
    arrive pre-sorted; a request submitted after one with a later arrival
    still admits the moment its own arrival passes.
    """

    def __init__(self):
        self._waiting: list[Request] = []
        self.done: list[Request] = []

    def submit(self, req: Request) -> None:
        if req.max_new_tokens < 1:
            raise ValueError(f"request {req.rid}: max_new_tokens must be >= 1")
        req.status = Status.QUEUED
        bisect.insort(self._waiting, req, key=lambda r: r.arrival)

    def pop_ready(self, now: float) -> Optional[Request]:
        """Earliest-arrived request whose arrival time has passed, else None."""
        if self._waiting and self._waiting[0].arrival <= now:
            return self._waiting.pop(0)
        return None

    def next_arrival(self) -> Optional[float]:
        return self._waiting[0].arrival if self._waiting else None

    def finish(self, req: Request, now: float) -> None:
        req.status = Status.DONE
        req.t_done = now
        req.slot = None
        self.done.append(req)

    def __len__(self) -> int:
        return len(self._waiting)


def poisson_arrivals(n: int, rate: float, seed: int = 0) -> np.ndarray:
    """(n,) cumulative arrival offsets (seconds) for a rate req/s Poisson
    process; rate <= 0 => everything arrives at t=0 (burst)."""
    if rate <= 0:
        return np.zeros(n)
    rng = np.random.default_rng(seed)
    return np.cumsum(rng.exponential(1.0 / rate, size=n))

"""Per-slot token sampling for the continuous-batching engine.

One vectorized ``sample_tokens`` call samples EVERY active slot of a decode
step in-jit: each row carries its own sampling params (temperature, top-k)
and its own PRNG key, so requests with different sampling settings — or the
same settings but different seeds — batch together without host round-trips.

Determinism contract: a request's token stream is a pure function of
(weights, prompt, temperature, top_k, seed) — the per-step key is
``fold_in(request_key(seed), n_generated)`` (see ``request_key`` /
``step_keys``), independent of which SLOT the request landed in, of the
engine capacity, and of whatever other requests share the batch.  Slot
recycling therefore cannot perturb sampling (tested in
tests/test_serving_engine.py::test_sampler_determinism).

MoE caveat: the PRNG stream is always batch-independent, but the LOGITS a
key samples from are not perfectly so for routed-MoE configs — expert
capacity C scales with the decode batch, so when C binds, ACTIVE requests
sharing a step can contend for expert slots in a way a solo session would
not (dead slots never contend: lm_decode forces them out of routing,
moe.py).  Engine-vs-lockstep token identity for MoE is therefore exact
only while capacity is non-binding (see
tests/test_serving_engine.py::test_per_slot_decode_recurrent_and_moe_families
and docs/serving.md).

temperature <= 0 selects greedy (argmax) — exactly the lockstep baseline's
``jnp.argmax(logits, -1)``, which is what makes the engine-vs-lockstep
token-identity tests exact.  top_k <= 0 keeps the full distribution.

Non-finite logits contract: the sampler NEVER sees a row the engine will
keep — the in-jit finite flag (models/model.py::logits_all_finite) is
computed on the same logits the sampler consumes, and the host discards the
token of any non-finite row when it quarantines that slot
(serving/engine.py::ServeEngine, docs/serving.md#failure-model).  A NaN row
still produces *some* token here (argmax/categorical on NaN is garbage but
defined — no exception escapes the jit), which is exactly why detection is a
data flag rather than a try/except.  Because the per-step key depends only
on (seed, n_generated) and retries restart the stream at n_generated=0, a
quarantined request that re-queues re-samples IDENTICAL tokens on its retry
— bit-equal to a fault-free run.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["request_key", "step_keys", "sample_tokens"]


def request_key(seed: int) -> np.ndarray:
    """Host-side (2,) uint32 base key for one request (old-style PRNG key —
    a plain array so the engine can keep a (capacity, 2) slot table)."""
    return np.asarray(jax.random.PRNGKey(seed))


def step_keys(base_keys, gen_idx):
    """(B, 2) base keys + (B,) per-slot generated-token counters -> (B, 2)
    per-step keys.  fold_in per row keeps streams independent across steps
    AND across requests (each request has its own base key)."""
    return jax.vmap(jax.random.fold_in)(base_keys, gen_idx)


def sample_tokens(logits, keys, temperature, top_k):
    """Sample one token per row.  All inputs batched, jit-friendly.

    logits: (B, V) float; keys: (B, 2) uint32 per-row PRNG keys;
    temperature: (B,) float (<= 0 => greedy); top_k: (B,) int32 (<= 0 => no
    top-k filter).  Returns (B,) int32.

    Vocab-padding note: models/model.py::_logits sets pad slots to -1e30, so
    they survive the top-k threshold only with probability exp(-1e30) = 0 —
    no pad token is ever sampled.
    """
    V = logits.shape[-1]
    greedy_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    is_greedy = temperature <= 0.0
    scaled = logits / jnp.maximum(temperature, 1e-6)[:, None]
    # per-row top-k: keep logits >= the row's k-th largest (ties all kept)
    sorted_desc = jnp.flip(jnp.sort(scaled, axis=-1), axis=-1)
    kth = jnp.take_along_axis(
        sorted_desc, jnp.clip(top_k - 1, 0, V - 1)[:, None], axis=-1
    )
    keep = (top_k[:, None] <= 0) | (scaled >= kth)
    filtered = jnp.where(keep, scaled, -jnp.inf)
    sampled = jax.vmap(jax.random.categorical)(keys, filtered).astype(jnp.int32)
    return jnp.where(is_greedy, greedy_tok, sampled)

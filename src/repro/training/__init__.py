from .steps import (  # noqa: F401
    init_train_state,
    make_algo,
    make_prune_fn,
    make_rigl_step,
    make_train_step,
    refresh_pack,
    snip_init,
    sparsity_map,
)

"""Step functions: sparse train step, RigL update step, serve steps.

Two compiled functions (paper Appendix H cost structure):

  train_step  — every step: masked fwd/bwd, optimizer on MASKED grads.
                One backward gives both gradients: we differentiate w.r.t. the
                effective weights w_eff = w * m, so the gradient is dense;
                g_sparse = g_dense * m feeds the optimizer.  Under pjit the
                dense gradient is a global (mesh-wide) array — the paper's
                Appendix M replica-sync bugs are impossible by construction.

  rigl_step   — every delta_t steps (t < T_end): same backward, then
                drop/grow (core.rigl), zero-init grown weights, reset their
                optimizer state.  Per Algorithm 1 the update step does NOT
                also take an optimizer step.

Kernel dispatch (cfg.sparse.kernel != 'dense'): train_step switches to the
Pallas sparse kernels — raw params + mask threading, no apply_masks, sparse
fwd AND bwd (kernels/).  The dense-gradient side channel every grow score
needs (|g| for rigl, |momentum| for snfs) comes from the Top-KAST backward
superset (core/rigl.py, docs/training.md#topkast): the state carries
``bwd_masks`` — per-layer B = A ∪ top-Δ exploration — and the pack routes
the wgrad kernels onto B's wider grid, so the gradient arriving at the
optimizer (and the SNFS momentum buffer) is the dense gradient restricted to
B with ZERO dense matmuls anywhere, every step AND at topology updates.
``method='topkast'`` additionally trains the exploration set B\\A itself
(optimizer on g⊙B) and drops/grows by magnitude within B.  Without kernel
dispatch the legacy cost split applies: rigl_step runs a dense backward,
amortized over delta_t >= 100 steps (paper Appendix H).
"""
from __future__ import annotations

import dataclasses
import functools
import inspect
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from ..core import (
    LayerSpec,
    SparseAlgo,
    UpdateSchedule,
    apply_masks,
    build_bwd_carrier,
    build_pack_state,
    dense_to_sparse_grad,
    get_distribution,
    init_masks,
    is_pack_entry,
    pack_mismatch,
    refresh_pack_state,
    rigl_update,
    snip_masks,
    topkast_backward_masks,
    tree_paths,
    validate_pack,
)
from ..core.pruning import PruningSchedule, prune_step
from ..models import init_lm, lm_loss
from ..optim import (
    LRSchedule,
    OptConfig,
    apply_opt,
    apply_opt_fused,
    init_opt,
    reset_connections,
    reset_new_connections,
)

__all__ = [
    "sparsity_map",
    "init_train_state",
    "make_train_step",
    "make_rigl_step",
    "make_prune_fn",
    "snip_init",
    "refresh_pack",
    "refresh_superset",
    "needs_bwd_masks",
]


def sparsity_map(cfg, params, sparse_flags) -> dict[str, float]:
    """Per-path target sparsities from the config's distribution."""
    flat_p = tree_paths(params)
    flat_f = tree_paths(sparse_flags)
    # official-code semantics: the distribution (and its nnz budget) is solved
    # over the MASKED layers only — embeddings/norms/biases are outside it.
    specs = [
        LayerSpec(name, flat_p[name].shape) for name, flag in flat_f.items() if flag
    ]
    sp = cfg.sparse
    dist = get_distribution(sp.distribution, specs, sp.sparsity, dense_first=False)
    return dist


def make_algo(cfg, total_steps: int) -> SparseAlgo:
    sp = cfg.sparse
    return SparseAlgo(
        method=sp.method,
        schedule=UpdateSchedule(
            delta_t=sp.delta_t,
            t_end=int(sp.t_end_fraction * total_steps),
            alpha=sp.alpha,
        ),
        grow_init=sp.grow_init,
        block_shape=sp.block_shape,
        backward_extra=getattr(sp, "backward_extra", 0.1),
    )


def needs_bwd_masks(sp) -> bool:
    """Does this config's state carry Top-KAST backward supersets?

    Yes for method='topkast' (any kernel: its optimizer trains B, its grow
    set lives inside B) and for rigl/snfs under kernel dispatch (the superset
    gradient is their dense-side grow-score channel — the sparse backward
    never computes a dense gradient, docs/training.md#topkast).
    """
    if sp.method == "pruning" or sp.sparsity == 0.0:
        return False
    dispatch = sp.kernel in ("masked", "block_sparse")
    return sp.method == "topkast" or (
        dispatch and sp.method in ("rigl", "snfs")
    )


def init_train_state(key, cfg, opt_cfg: OptConfig, *, loss_fn=None):
    """State dict: step/params/masks/opt/rng (+dense_mom for SNFS)."""
    k1, k2, k3 = jax.random.split(key, 3)
    params, axes, sparse_flags = init_lm(k1, cfg)
    if cfg.param_dtype == "bfloat16":
        # pure-bf16 weights (f32 optimizer master state lives in opt_state
        # unless OptConfig.state_dtype says otherwise) — needed to fit the
        # 314B grok cell in 16G HBM; see EXPERIMENTS.md.
        params = jax.tree_util.tree_map(
            lambda p: p.astype(jnp.bfloat16) if p.dtype == jnp.float32 else p,
            params,
        )
    sp = cfg.sparse
    if sp.method == "pruning" or sp.sparsity == 0.0:
        # dense start: all-ones masks on sparsifiable layers (pruning tightens)
        masks = jax.tree_util.tree_map(
            lambda p, f: jnp.ones(p.shape, jnp.bool_) if f else None,
            params,
            sparse_flags,
        )
    else:
        smap = sparsity_map(cfg, params, sparse_flags)
        if sp.kernel == "block_sparse":
            from ..configs.base import validate_sparse_kernel

            validate_sparse_kernel(sp)  # clean error when block_shape unset
            # static shape check: random_block_mask silently falls back to
            # elementwise masks on non-divisible layers, which the block
            # kernel would execute WRONGLY (whole blocks run unmasked) —
            # fail loudly instead of training a corrupted topology.  2-D
            # weights dispatch through the plain kernels; 3-D weight BANKS
            # (MoE experts, xLSTM per-head recurrences) dispatch through the
            # grouped kernels, whose blocks tile the trailing two dims.
            bs = sp.block_shape
            flat_p = tree_paths(params)
            bad = [
                name
                for name in smap
                if len(flat_p[name].shape) not in (2, 3)
                or flat_p[name].shape[-2] % bs[0]
                or flat_p[name].shape[-1] % bs[1]
            ]
            if bad:
                raise ValueError(
                    f"sparse.kernel='block_sparse' with block_shape={bs} "
                    f"does not tile these sparsifiable layers: {bad}; "
                    "choose a block edge dividing every layer dim"
                )
        # block-aligned init when block mode is on, so the topology is
        # executable by the block-sparse kernel from the very first step
        masks = init_masks(k2, params, smap, block_shape=sp.block_shape)
        # zero-out masked weights at init so nnz(w) matches the mask
        params = apply_masks(params, masks)
    state = {
        "step": jnp.zeros((), jnp.int32),
        "params": params,
        "masks": masks,
        "opt": init_opt(opt_cfg, params),
        "rng": k3,
        # lifetime count of steps whose loss/grads were non-finite and whose
        # optimizer update was therefore SKIPPED (params bit-unchanged) —
        # see make_train_step; checkpointed so restarts keep the tally
        "nonfinite_steps": jnp.zeros((), jnp.int32),
    }
    if needs_bwd_masks(sp):
        # Top-KAST backward supersets B ⊇ A (core/rigl.py): the wgrad side
        # channel for every grow score under kernel dispatch, and the trained
        # exploration set for method='topkast'.  Refreshed alongside the pack
        # after every topology update (refresh_superset).
        state["bwd_masks"] = topkast_backward_masks(
            params, masks, sp.backward_extra, jax.random.fold_in(k2, 1),
            block_shape=sp.block_shape,
        )
    if sp.kernel == "block_sparse" and sp.block_shape is not None:
        # host-packed tight-grid topology, carried in state + checkpointed.
        # INVARIANT: pack always describes state["masks"] — every rigl_step
        # must be followed by refresh_pack() (launch/train.py does this); the
        # train step's pack_stale metric reports any violation.
        state["pack"] = build_pack_state(
            masks, sp.block_shape, slack=getattr(sp, "pack_width_slack", 0.0),
            bwd_masks=state.get("bwd_masks"),
        )
    elif sp.kernel == "masked" and "bwd_masks" in state:
        # masked kernel needs no CSC pack — the superset rides along as the
        # elementwise carrier the Top-KAST masked VJP fuses (core/pack.py)
        state["pack"] = build_bwd_carrier(state["bwd_masks"])
    if sp.method == "snfs":
        state["dense_mom"] = jax.tree_util.tree_map(jnp.zeros_like, params)
    return state, axes, sparse_flags


def refresh_superset(state, cfg):
    """Redraw the Top-KAST backward supersets from the CURRENT masks/params.

    Called from refresh_pack right after every topology update.  For
    method='topkast' the exploration set is itself trained, so connections
    LEAVING the superset (B_old \\ B_new) are zeroed and their optimizer
    state reset — preserving the invariant that weights outside B are exactly
    0 (which is what makes ``grown`` connections zero-initialized for free).
    For rigl/snfs under dispatch the optimizer only ever touches A, so the
    redraw just moves the gradient side-channel.  SNFS's dense-momentum
    buffer is masked to the new superset either way: coordinates without a
    gradient channel must not carry stale momentum into grow scores.
    No-op for states without backward masks.
    """
    if "bwd_masks" not in state:
        return state
    sp = cfg.sparse
    key = jax.random.fold_in(state["rng"], 2 ** 20 + int(state["step"]))
    new_b = topkast_backward_masks(
        state["params"], state["masks"], sp.backward_extra, key,
        block_shape=sp.block_shape,
    )
    new_state = dict(state, bwd_masks=new_b)
    if sp.method == "topkast":
        leavers = jax.tree_util.tree_map(
            lambda o, n: None if o is None else o.astype(bool) & ~n.astype(bool),
            state["bwd_masks"],
            new_b,
            is_leaf=lambda x: x is None,
        )
        new_state["params"] = jax.tree_util.tree_map(
            lambda w, l: w if l is None else jnp.where(l, 0, w).astype(w.dtype),
            state["params"],
            leavers,
            is_leaf=lambda x: x is None,
        )
        new_state["opt"] = reset_connections(state["opt"], leavers)
    if "dense_mom" in state:
        new_state["dense_mom"] = jax.tree_util.tree_map(
            lambda mo, b: mo if b is None else mo * b.astype(mo.dtype),
            state["dense_mom"],
            new_b,
            is_leaf=lambda x: x is None,
        )
    return new_state


def refresh_pack(state, cfg):
    """Refresh superset + re-pack state["pack"] from state["masks"].

    Call right after EVERY topology-update step (host-side, amortized over
    delta_t).  First redraws the backward supersets (refresh_superset), then
    rebuilds the pack the kernels consume — the block_sparse CSC/CSR (+
    superset bidx view) or the masked-kernel bwd_mask carrier.  No-op for
    states without a pack.
    Widths never shrink (core/pack.py), so the jitted train step only
    retraces when a layer's max active-block count grows past its packed
    width — bounded drift, not per-update churn.
    ``cfg.sparse.pack_width_slack`` > 0 additionally rounds refreshed widths
    up to the next slack step (core.pack.slack_width), trading a few padded
    grid iterations for fewer retraces when production topologies drift.
    """
    state = refresh_superset(state, cfg)
    if "pack" not in state:
        return state
    if cfg.sparse.kernel == "masked":
        return dict(state, pack=build_bwd_carrier(state["bwd_masks"]))
    pack = refresh_pack_state(
        state["masks"], cfg.sparse.block_shape, prev=state["pack"],
        slack=getattr(cfg.sparse, "pack_width_slack", 0.0),
        bwd_masks=state.get("bwd_masks"),
    )
    # integrity guard (core/pack.py::validate_pack): a refresh that produced
    # inconsistent CSC/CSR books would make every subsequent kernel launch
    # execute the wrong topology — cheap host-side check, loud failure
    validate_pack(pack, where="refresh_pack")
    return dict(state, pack=pack)


def make_train_step(
    cfg,
    opt_cfg: OptConfig,
    lr_sched: LRSchedule,
    *,
    loss_fn: Callable | None = None,
    snfs_momentum: float = 0.9,
):
    """Build the hot-path step.

    With ``cfg.sparse.kernel`` in {'masked', 'block_sparse'} the step runs in
    KERNEL-DISPATCH mode: the loss is computed on RAW params with the mask
    pytree threaded into the model, every dispatched matmul (fwd and bwd)
    executes through the Pallas sparse kernels, and ``apply_masks`` is never
    called — the masked weight copy w⊙m is never materialized in HBM.  The
    gradient that comes back is already the paper's sparse gradient (the
    custom-VJP wgrad kernels fuse g⊙m), so the optimizer path is unchanged.

    SNFS needs a dense-gradient side channel every step for its momentum
    buffer; under dispatch the state's Top-KAST backward superset provides it
    (the wgrad kernels return the dense gradient restricted to B ⊇ A — see
    needs_bwd_masks), so snfs runs on the sparse kernels too.  For
    method='topkast' the optimizer itself trains the superset: grads (and
    weight decay) are masked by ``bwd_masks`` instead of ``masks``.

    With kernel='block_sparse' the state additionally carries
    ``state["pack"]`` (PackState, core/pack.py): the host-packed tight block
    topology is threaded into the kernels so every grid launches the TRUE
    active-block count instead of the worst-case padded width.  The step
    reports a ``pack_stale`` metric — nonzero iff the pack no longer matches
    the masks (i.e. a rigl_step ran without refresh_pack()).
    """
    dispatch = cfg.sparse.kernel not in (None, "dense")
    is_topkast = cfg.sparse.method == "topkast"
    if dispatch:
        from ..configs.base import validate_sparse_kernel

        validate_sparse_kernel(cfg.sparse)
    fused = dispatch and getattr(cfg.sparse, "fused_epilogue", False)
    if getattr(cfg.sparse, "fused_epilogue", False):
        # the fused path replaces the wgrad cotangent with the NEW MOMENTUM
        # (kernels/masked_matmul.py fused_* docstrings) — it only exists for
        # plain SGD+momentum single-microbatch steps; anything else would
        # silently compute a different update, so refuse loudly instead.
        bad = []
        if not dispatch:
            bad.append("kernel dispatch off (sparse.kernel is dense/None)")
        if opt_cfg.kind != "sgd":
            bad.append(f"optimizer kind {opt_cfg.kind!r} (need plain sgd)")
        if opt_cfg.nesterov:
            bad.append("nesterov (the kernel epilogue emits plain momentum)")
        if opt_cfg.grad_clip:
            bad.append("grad_clip (the raw gradient never exists to clip)")
        if max(getattr(cfg, "microbatches", 1), 1) != 1:
            bad.append("microbatches > 1 (the epilogue folds mom ONCE/step)")
        if cfg.sparse.method == "snfs":
            bad.append("method='snfs' (its dense-momentum buffer needs the "
                       "raw superset gradient every step)")
        if getattr(cfg, "bf16_grads", False):
            bad.append("bf16_grads (cotangent dtype must match the weights)")
        if cfg.dtype != "float32" and opt_cfg.state_dtype != "bfloat16":
            bad.append(
                f"compute dtype {cfg.dtype!r} with f32 optimizer state (the "
                "kernel would nearest-round momentum to the compute dtype; "
                "use dtype='float32', or opt in to bf16 momentum via "
                "OptConfig.state_dtype='bfloat16' for in-kernel stochastic "
                "rounding)"
            )
        if bad:
            raise ValueError(
                "sparse.fused_epilogue=True is unsupported with: "
                + "; ".join(bad)
            )
    if loss_fn is None:
        loss_fn = lambda p, b, masks=None, pack=None: lm_loss(
            p, cfg, b, masks=masks, pack=pack
        )
    elif dispatch and "masks" not in inspect.signature(loss_fn).parameters:
        raise ValueError(
            "kernel dispatch needs a loss_fn accepting masks= (raw params + "
            "mask threading); got one without it"
        )
    # PackState (tight block_sparse grids) is an optimization, not a contract:
    # custom loss_fns without a pack= parameter just fall back to the padded
    # traced pack.
    loss_accepts_pack = "pack" in inspect.signature(loss_fn).parameters
    if fused and not loss_accepts_pack:
        raise ValueError(
            "sparse.fused_epilogue=True needs a loss_fn accepting pack= — "
            "the momentum/seed epilogue operands ride in on the pack entries"
        )
    mb = max(getattr(cfg, "microbatches", 1), 1)
    acc_dt = jnp.bfloat16 if getattr(cfg, "grad_accum_dtype", "") == "bfloat16" else jnp.float32

    def _grads(w_eff, batch, masks=None, pack=None):
        if masks is None:
            loss_fn_ = loss_fn
        elif pack is not None and loss_accepts_pack:
            loss_fn_ = lambda p, b: loss_fn(p, b, masks=masks, pack=pack)
        else:
            loss_fn_ = lambda p, b: loss_fn(p, b, masks=masks)
        if mb == 1:
            return jax.value_and_grad(loss_fn_)(w_eff, batch)
        # gradient accumulation: one microbatch's activations live at a time
        bsz = jax.tree_util.tree_leaves(batch)[0].shape[0] // mb
        init = (
            jnp.float32(0.0),
            jax.tree_util.tree_map(lambda p: jnp.zeros(p.shape, acc_dt), w_eff),
        )

        def acc(carry, sub):
            loss_acc, g_acc = carry
            li, gi = jax.value_and_grad(loss_fn_)(w_eff, sub)
            g_acc = jax.tree_util.tree_map(
                lambda a, g: a + g.astype(acc_dt), g_acc, gi
            )
            return loss_acc + li, g_acc

        if getattr(cfg, "scan_microbatches", False):
            # small-HLO form (production + full-depth dry-run compile);
            # cost_analysis counts the body once, so roofline lowering uses
            # the unrolled branch below instead (DESIGN.md §8).
            stacked = jax.tree_util.tree_map(
                lambda x: x.reshape(mb, bsz, *x.shape[1:]), batch
            )
            (loss_acc, g_acc), _ = jax.lax.scan(
                lambda c, s: (acc(c, s), None), init, stacked
            )
        else:
            loss_acc, g_acc = init
            for i in range(mb):
                sub = jax.tree_util.tree_map(
                    lambda x: jax.lax.dynamic_slice_in_dim(x, i * bsz, bsz, 0),
                    batch,
                )
                loss_acc, g_acc = acc((loss_acc, g_acc), sub)
        inv = 1.0 / mb
        return loss_acc * inv, jax.tree_util.tree_map(lambda g: g * inv, g_acc)

    def train_step(state, batch):
        # KERNEL DISPATCH: raw params + mask threading; no apply_masks — w⊙m
        # lives only inside the kernels' VMEM pipelines and the returned
        # gradient is already masked (custom-VJP wgrad).  Legacy: pre-masked
        # effective weights, dense XLA matmuls.
        src = (
            state["params"]
            if dispatch
            else apply_masks(state["params"], state["masks"])
        )
        if getattr(cfg, "bf16_grads", False):
            # single downcast => bf16 cotangents => bf16 DP grad all-reduce
            src = jax.tree_util.tree_map(
                lambda w: w.astype(jnp.bfloat16)
                if w.dtype == jnp.float32
                else w,
                src,
            )
        if dispatch and needs_bwd_masks(cfg.sparse):
            # trace-time totality guard: EVERY dispatched layer must carry a
            # backward-superset pack view, else its wgrad would silently run
            # on the forward topology (or a dense matmul) instead of B's grid
            from ..models.layers import assert_total_dispatch

            assert_total_dispatch(
                state["masks"], (), kernel=cfg.sparse.kernel,
                where="train_step", pack=state.get("pack"), require_bwd=True,
            )
        pack = state.get("pack") if dispatch else None
        if fused:
            # FUSED EPILOGUE (docs/kernels.md#fused-epilogue): merge the SGD
            # operands into each dispatched pack entry.  layers.py routes
            # entries carrying "mom" onto the fused wgrad kernels, whose
            # weight cotangent IS the new momentum m_new = mu*mom + dw + wd*w
            # (masked to the wgrad support) — the raw dw never round-trips
            # through HBM.  mu/wd/sr are python statics baked into the trace;
            # mom/seed are traced operands.
            mu_, wd_ = opt_cfg.momentum, opt_cfg.weight_decay
            sr_ = opt_cfg.state_dtype == "bfloat16"
            is_none = lambda x: x is None
            flat_m, treedef = jax.tree_util.tree_flatten(
                state["masks"], is_leaf=is_none
            )
            flat_pe = (
                jax.tree_util.tree_leaves(pack, is_leaf=is_pack_entry)
                if pack is not None
                else [None] * len(flat_m)
            )
            flat_mom = jax.tree_util.tree_flatten(
                state["opt"]["momentum"], is_leaf=is_none
            )[0]
            entries = []
            for i, (m, pe, mo) in enumerate(zip(flat_m, flat_pe, flat_mom)):
                if m is None:
                    entries.append(None)
                    continue
                seed = (
                    state["step"] * jnp.int32(1000003) + jnp.int32(i)
                ).reshape(1)
                entries.append(
                    dict(pe or {})
                    | {"mom": mo, "seed": seed, "mu": mu_, "wd": wd_, "sr": sr_}
                )
            pack = jax.tree_util.tree_unflatten(treedef, entries)
        loss, g_dense = _grads(
            src,
            batch,
            masks=state["masks"] if dispatch else None,
            pack=pack,
        )
        # topkast trains the whole backward superset B (exploration set gets
        # optimizer updates); every other method optimizes A only.
        opt_masks = (
            state["bwd_masks"]
            if is_topkast and "bwd_masks" in state
            else state["masks"]
        )
        g_sparse = dense_to_sparse_grad(g_dense, opt_masks)
        # weight decay on ACTIVE weights only (inactive must stay untouched).
        # In dispatch mode src is RAW, so decay through the mask: m is bool,
        # the product w*m here is a grad-sized elementwise op, not a second
        # resident weight copy.
        if opt_cfg.weight_decay:
            wd = opt_cfg.weight_decay

            def _decay(g, w, m):
                if fused and m is not None:
                    # wd on dispatched leaves is folded into the kernel
                    # epilogue (g here is already m_new = mu*mom + dw + wd*w)
                    return g
                w_act = w if m is None else w * m.astype(w.dtype)
                return g + wd * w_act.astype(g.dtype)

            if dispatch or is_topkast:
                # decay over the OPTIMIZED support: A for rigl/set/snfs,
                # the backward superset B for topkast (its exploration
                # weights are trained, so they decay too); raw params carry
                # the B-supported values even in legacy mode.
                g_sparse = jax.tree_util.tree_map(
                    _decay, g_sparse, state["params"], opt_masks,
                    is_leaf=lambda x: x is None,
                )
            else:
                g_sparse = jax.tree_util.tree_map(
                    lambda g, w: g + wd * w.astype(g.dtype), g_sparse, src
                )
        lr = lr_sched(state["step"])
        # NOTE: in fused mode the dispatched leaves of g_sparse are the NEW
        # MOMENTUM (the raw gradient never exists in HBM), so grad_norm
        # reports the momentum-update norm there.  The nonfinite guard below
        # stays valid: m_new is finite iff the gradient contribution is.
        gnorm = jnp.sqrt(
            sum(
                jnp.sum(jnp.square(g.astype(jnp.float32)))
                for g in jax.tree_util.tree_leaves(g_sparse)
            )
        )
        # non-finite guard: a NaN/Inf loss or gradient must not touch the
        # params — one poisoned batch would otherwise destroy the run (and
        # under kernel dispatch, silently corrupt the sparse topology's
        # weights).  gnorm is finite iff every grad leaf is, so one scalar
        # decides; the update is SELECTED rather than branched so the step
        # stays a single XLA program (the skip costs one where() per leaf).
        ok = jnp.isfinite(loss) & jnp.isfinite(gnorm)
        opt_nowd = dataclasses.replace(opt_cfg, weight_decay=0.0)
        if fused:
            # dispatched leaves already carry m_new; plain leaves (embeddings,
            # norms) get the standard SGD+momentum update inside apply_opt_fused
            fused_flags = jax.tree_util.tree_map(
                lambda m: m is not None, opt_masks, is_leaf=lambda x: x is None
            )
            new_params, new_opt = apply_opt_fused(
                opt_nowd, g_sparse, state["opt"], state["params"], lr,
                fused_flags,
            )
        else:
            new_params, new_opt = apply_opt(
                opt_nowd, g_sparse, state["opt"], state["params"], lr
            )
        keep = lambda new, old: jax.tree_util.tree_map(
            lambda n, o: jnp.where(ok, n, o), new, old
        )
        nonfinite_steps = (
            state.get("nonfinite_steps", jnp.zeros((), jnp.int32))
            + (~ok).astype(jnp.int32)
        )
        new_state = dict(
            state,
            step=state["step"] + 1,  # the step index advances regardless
            params=keep(new_params, state["params"]),
            opt=keep(new_opt, state["opt"]),
            nonfinite_steps=nonfinite_steps,
        )
        if "dense_mom" in state:  # SNFS tracks dense-gradient momentum
            new_state["dense_mom"] = keep(
                jax.tree_util.tree_map(
                    lambda m, g: snfs_momentum * m + g.astype(m.dtype),
                    state["dense_mom"],
                    g_dense,
                ),
                state["dense_mom"],
            )
        metrics = {
            "loss": loss,
            "lr": lr,
            "grad_norm": gnorm,
            "nonfinite_steps": nonfinite_steps,
        }
        if dispatch and "pack" in state and cfg.sparse.kernel == "block_sparse":
            # staleness canary: #blocks where the packed topology disagrees
            # with the masks (incl. the superset bidx view when present).
            # Nonzero means a rigl_step ran without refresh_pack() and the
            # kernels execute a STALE topology — cheap to compute (tiny block
            # grids), surfaced every step.
            metrics["pack_stale"] = pack_mismatch(
                state["masks"], state["pack"], cfg.sparse.block_shape,
                bwd_masks=state.get("bwd_masks"),
            )
        return new_state, metrics

    return train_step


def make_rigl_step(cfg, algo: SparseAlgo, lr_sched: LRSchedule, *, loss_fn=None):
    """Topology-update step.

    Without kernel dispatch this is the paper's amortized DENSE backward
    (apply_masks + XLA matmuls): grow needs |dense grad| at inactive
    coordinates, which the sparse kernels never compute; delta_t >= 100
    amortizes the cost (Appendix H).

    Under kernel dispatch with backward supersets in the state
    (needs_bwd_masks) the update stays on the sparse kernels end-to-end: the
    backward returns the dense gradient restricted to B ⊇ A — exactly the
    grow-score channel rigl needs (and the momentum snfs accumulated every
    step) — so NO dense gradient is ever materialized.  Grow candidates are
    thereby restricted to the superset: coordinates outside B carry no
    gradient signal and score zero.  For method='topkast' the drop/grow is
    magnitude-driven inside B and needs no gradient at all (rigl_update).
    """
    dispatch = cfg.sparse.kernel not in (None, "dense")
    if loss_fn is None:
        loss_fn = lambda p, b, masks=None, pack=None: lm_loss(
            p, cfg, b, masks=masks, pack=pack
        )
    sig = inspect.signature(loss_fn).parameters
    accepts_masks = "masks" in sig
    accepts_pack = "pack" in sig

    def rigl_step(state, batch):
        if dispatch and accepts_masks and "bwd_masks" in state:
            # sparse backward on the superset-routed kernels: g_dense below
            # is the dense gradient ⊙ B, computed with zero dense matmuls
            pack = state.get("pack")
            if pack is not None and accepts_pack:
                lf = lambda p, b: loss_fn(
                    p, b, masks=state["masks"], pack=pack
                )
            else:
                lf = lambda p, b: loss_fn(p, b, masks=state["masks"])
            loss, g_dense = jax.value_and_grad(lf)(state["params"], batch)
        else:
            w_eff = apply_masks(state["params"], state["masks"])
            loss, g_dense = jax.value_and_grad(loss_fn)(w_eff, batch)
        key = jax.random.fold_in(state["rng"], state["step"])
        new_params, new_masks, grown = rigl_update(
            state["params"],
            state["masks"],
            g_dense,
            state["step"],
            algo,
            key,
            dense_momentum=state.get("dense_mom"),
            lr=float(lr_sched.base_lr),
            bwd_masks=state.get("bwd_masks"),
        )
        new_opt = reset_new_connections(state["opt"], grown)
        new_state = dict(
            state,
            step=state["step"] + 1,
            params=new_params,
            masks=new_masks,
            opt=new_opt,
        )
        return new_state, {"loss": loss}

    return rigl_step


def make_prune_fn(cfg, sched: PruningSchedule):
    def fn(state):
        new_params, new_masks = prune_step(
            state["params"], state["masks"], state["step"], sched
        )
        return dict(state, params=new_params, masks=new_masks)

    return fn


def snip_init(state, cfg, batch, *, loss_fn=None, saliency="weight_times_grad"):
    """Replace masks with one-shot SNIP masks computed on one batch."""
    loss_fn = loss_fn or (lambda p, b: lm_loss(p, cfg, b))
    _, axes, sparse_flags = init_lm(jax.random.PRNGKey(0), cfg)
    smap = sparsity_map(cfg, state["params"], sparse_flags)
    g = jax.grad(loss_fn)(state["params"], batch)
    masks = snip_masks(state["params"], g, smap, saliency=saliency)
    params = apply_masks(state["params"], masks)
    return dict(state, params=params, masks=masks)

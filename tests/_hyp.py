"""Optional-hypothesis shim.

CI images do not always ship ``hypothesis``.  When it is installed we re-export
the real ``given``/``settings``/``st``; when it is missing, property tests fall
back to a deterministic sweep of pseudo-random draws (seeded ``random.Random``)
so the invariants are still exercised — just with fewer, fixed examples.

``tests/test_distributions.py`` instead skips outright via
``pytest.importorskip`` (its strategies are richer than this shim covers).
"""
from __future__ import annotations

import random

try:  # pragma: no cover - depends on the environment
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    _N_FALLBACK_EXAMPLES = 8

    class _Strategy:
        def __init__(self, lo, hi, is_int):
            self.lo, self.hi, self.is_int = lo, hi, is_int

        def draw(self, rng: random.Random):
            if self.is_int:
                return rng.randint(self.lo, self.hi)
            return rng.uniform(self.lo, self.hi)

    class st:  # noqa: N801 - mimics the hypothesis module name
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(min_value, max_value, True)

        @staticmethod
        def floats(min_value, max_value):
            return _Strategy(min_value, max_value, False)

    def settings(**_kw):
        return lambda f: f

    def given(*strategies):
        def deco(f):
            def wrapper():
                rng = random.Random(0)
                for _ in range(_N_FALLBACK_EXAMPLES):
                    f(*(s.draw(rng) for s in strategies))

            # NOT functools.wraps: copying __wrapped__ would make pytest
            # introspect the original (parametrized) signature and demand
            # fixtures for the strategy arguments.
            wrapper.__name__ = f.__name__
            wrapper.__doc__ = f.__doc__
            return wrapper

        return deco


__all__ = ["given", "settings", "st", "HAVE_HYPOTHESIS"]

"""Property tests for the paged-KV block allocator (serving/block_pool.py).

The pool is plain host-side bookkeeping, but everything above it — table
scatter/gather correctness, COW prefix sharing, the chaos leak invariant —
assumes its four core properties, so they are pinned here directly:

  * no double-free: dropping a reference on a free page is rejected loudly;
  * refcounts match references: after ANY operation sequence, each page's
    refcount equals the number of outstanding references the caller holds;
  * partition: the free list and the live (refcount > 0) pages exactly
    partition the pool — nothing leaked, nothing double-tracked;
  * COW fork never mutates a shared page: ``fork`` trades exactly ONE
    reference for a fresh exclusive page and leaves the donor live for its
    remaining holders.

Driven through tests/_hyp.py: real ``hypothesis`` when installed, a seeded
deterministic sweep otherwise — each drawn integer seeds a random operation
script replayed against the pool AND a pure-python reference model of the
outstanding references, with the pool's own ``check`` audit after every op.
"""
import random

import pytest

from repro.serving import BlockPool

from _hyp import given, settings, st

pytestmark = pytest.mark.paged


# ---------------------------------------------------------------------------
# directed edge cases
# ---------------------------------------------------------------------------

def test_alloc_free_roundtrip_and_partition():
    pool = BlockPool(8, page_size=4)
    assert pool.n_free == 8 and pool.n_live == 0
    a = pool.alloc(3)
    assert len(set(a)) == 3 and pool.n_live == 3
    pool.check(expected_refs=a)
    pool.free(a)
    assert pool.n_free == 8 and pool.n_live == 0
    pool.check(expected_refs=[])


def test_double_free_rejected():
    pool = BlockPool(4, page_size=2)
    (page,) = pool.alloc(1)
    pool.free([page])
    with pytest.raises(ValueError, match="double free"):
        pool.free([page])
    # a fresh reference makes the page freeable exactly once again
    (page2,) = pool.alloc(1)
    pool.free([page2])
    with pytest.raises(ValueError, match="double free"):
        pool.free([page2])


def test_incref_requires_live_page():
    pool = BlockPool(4, page_size=2)
    with pytest.raises(ValueError, match="not live"):
        pool.incref([0])  # never allocated
    (page,) = pool.alloc(1)
    pool.incref([page])
    pool.free([page])
    pool.free([page])  # second reference
    with pytest.raises(ValueError, match="double free"):
        pool.free([page])


def test_alloc_overflow_raises_and_leaves_pool_intact():
    pool = BlockPool(4, page_size=2)
    held = pool.alloc(3)
    assert not pool.can_alloc(2)
    with pytest.raises(MemoryError):
        pool.alloc(2)
    pool.check(expected_refs=held)  # failed alloc took nothing


def test_fork_trades_one_reference_for_fresh_page():
    pool = BlockPool(4, page_size=2)
    (donor,) = pool.alloc(1)
    with pytest.raises(ValueError, match="exclusively held"):
        pool.fork(donor)  # refcount 1: write in place, don't fork
    pool.incref([donor])  # simulate a second table referencing the page
    new = pool.fork(donor)
    assert new != donor
    # donor still live for its remaining holder, new page exclusive
    pool.check(expected_refs=[donor, new])
    assert pool.n_forks == 1
    with pytest.raises(ValueError, match="not live"):
        pool.fork(pool.n_blocks)  # sentinel is never forkable


def test_sentinel_is_one_past_last_id_and_never_allocated():
    pool = BlockPool(5, page_size=8)
    assert pool.sentinel == 5
    pages = pool.alloc(5)
    assert pool.sentinel not in pages
    with pytest.raises(ValueError, match="double free"):
        pool.free([pool.sentinel])


# ---------------------------------------------------------------------------
# property: random operation scripts vs a reference model
# ---------------------------------------------------------------------------

def _run_script(seed: int, n_blocks: int, n_ops: int = 120) -> None:
    """Replay a seeded random alloc/incref/free/fork script against the pool
    and a reference multiset of outstanding references, auditing the pool's
    partition + refcount invariants after every operation."""
    rng = random.Random(seed)
    pool = BlockPool(n_blocks, page_size=4)
    refs: list[int] = []  # one entry per outstanding reference
    for _ in range(n_ops):
        op = rng.random()
        if op < 0.35 and pool.n_free:
            k = rng.randint(1, pool.n_free)
            got = pool.alloc(k)
            assert len(set(got)) == k, "alloc issued duplicate pages"
            assert not set(got) & set(refs), "alloc issued a live page"
            refs += got
        elif op < 0.55 and refs:
            page = rng.choice(refs)
            pool.incref([page])
            refs.append(page)
        elif op < 0.85 and refs:
            page = rng.choice(refs)
            refs.remove(page)
            pool.free([page])
        elif refs:
            page = rng.choice(refs)
            if refs.count(page) >= 2 and pool.n_free:
                before = refs.count(page)
                new = pool.fork(page)
                # fork NEVER mutates the shared page: the donor keeps its
                # other references, the new page is exclusive and fresh
                refs.remove(page)
                refs.append(new)
                assert new != page
                assert refs.count(page) == before - 1
                assert pool.refcount[page] == before - 1
                assert pool.refcount[new] == 1
            else:
                # fork must fail here: the page is exclusively held
                # (ValueError) or the pool has no page left for the copy
                # (MemoryError) — and a failed fork changes nothing
                with pytest.raises((ValueError, MemoryError)):
                    pool.fork(page)
        pool.check(expected_refs=refs)
    # drain: every reference frees exactly once, pool returns to empty
    rng.shuffle(refs)
    for page in refs:
        pool.free([page])
    pool.check(expected_refs=[])
    assert pool.n_free == n_blocks and pool.n_live == 0


@settings(max_examples=40, deadline=None)
@given(st.integers(min_value=0, max_value=2**31 - 1))
def test_random_scripts_hold_invariants_small_pool(seed):
    _run_script(seed, n_blocks=6)


@settings(max_examples=40, deadline=None)
@given(st.integers(min_value=0, max_value=2**31 - 1))
def test_random_scripts_hold_invariants_large_pool(seed):
    _run_script(seed, n_blocks=48)


def test_freed_pages_are_reissued_lifo():
    """Most-recently-freed page comes back first (documented allocator
    behaviour; correctness never depends on order, so this pins the policy
    explicitly rather than by accident elsewhere)."""
    pool = BlockPool(4, page_size=2)
    a = pool.alloc(4)
    pool.free([a[1]])
    pool.free([a[3]])
    assert pool.alloc(1) == [a[3]]
    assert pool.alloc(1) == [a[1]]

"""Checkpoint: roundtrip, bit-packed masks, keep-k GC, corruption fallback,
preemption-resume determinism."""
import dataclasses
import pathlib
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import latest_step, restore, save
from repro.configs import get_config
from repro.configs.base import SparseConfig
from repro.data import batch_for
from repro.optim import LRSchedule, OptConfig
from repro.training import init_train_state, make_train_step


@pytest.fixture
def state():
    cfg = get_config("h2o-danube-1.8b", smoke=True)
    cfg = dataclasses.replace(cfg, sparse=SparseConfig(sparsity=0.6))
    st, _, _ = init_train_state(jax.random.PRNGKey(0), cfg, OptConfig(kind="adam"))
    return cfg, st


def _tree_equal(a, b):
    la = jax.tree_util.tree_leaves(a, is_leaf=lambda x: x is None)
    lb = jax.tree_util.tree_leaves(b, is_leaf=lambda x: x is None)
    for x, y in zip(la, lb):
        if x is None:
            assert y is None
            continue
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_roundtrip_bitexact(state, tmp_path):
    cfg, st = state
    save(st, tmp_path, 7)
    restored, step = restore(st, tmp_path)
    assert step == 7
    _tree_equal(st, restored)


def test_masks_bitpacked_on_disk(state, tmp_path):
    cfg, st = state
    save(st, tmp_path, 1)
    npz = np.load(tmp_path / "step-0000000001" / "arrays.npz")
    packed = [k for k in npz.files if k.startswith("__packedmask__")]
    assert packed, "masks should be bit-packed"
    total_mask_bits = sum(
        m.size for m in jax.tree_util.tree_leaves(st["masks"]) if m is not None
    )
    packed_bytes = sum(npz[k].size for k in packed)
    assert packed_bytes <= total_mask_bits // 8 + 8 * len(packed)


def test_keep_last_k(state, tmp_path):
    cfg, st = state
    for s in (1, 2, 3, 4, 5):
        save(st, tmp_path, s, keep_last_k=2)
    dirs = sorted(p.name for p in tmp_path.glob("step-*"))
    assert dirs == ["step-0000000004", "step-0000000005"]


def test_corrupted_checkpoint_skipped(state, tmp_path):
    cfg, st = state
    save(st, tmp_path, 1)
    save(st, tmp_path, 2)
    # corrupt the newest
    (tmp_path / "step-0000000002" / "manifest.json").unlink()
    assert latest_step(tmp_path) == 1
    restored, step = restore(st, tmp_path)
    assert step == 1


def test_preemption_resume_bitexact(tmp_path):
    """train 6 steps straight == train 3, 'preempt', restore, train 3 more."""
    cfg = get_config("h2o-danube-1.8b", smoke=True)
    cfg = dataclasses.replace(
        cfg, dtype="float32", sparse=SparseConfig(sparsity=0.5)
    )
    opt = OptConfig(kind="sgd", momentum=0.9, weight_decay=0.0)
    lr = LRSchedule(kind="constant", base_lr=1e-2, warmup_steps=0)
    step_fn = jax.jit(make_train_step(cfg, opt, lr))

    def run(state, lo, hi):
        for t in range(lo, hi):
            state, _ = step_fn(state, batch_for(cfg, t, 4, 32, learnable=True))
        return state

    s_straight, _, _ = init_train_state(jax.random.PRNGKey(0), cfg, opt)
    s_straight = run(s_straight, 0, 6)

    s_a, _, _ = init_train_state(jax.random.PRNGKey(0), cfg, opt)
    s_a = run(s_a, 0, 3)
    save(s_a, tmp_path, 3)
    s_b, _ = restore(s_a, tmp_path)  # simulate a fresh process restoring
    s_b = run(s_b, 3, 6)
    _tree_equal(s_straight["params"], s_b["params"])
    _tree_equal(s_straight["masks"], s_b["masks"])

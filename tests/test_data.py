"""Data pipeline: determinism + host-shard disjointness + corpus sanity."""
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data import batch_for, byte_corpus, text_batch


def test_batches_deterministic():
    cfg = get_config("h2o-danube-1.8b", smoke=True)
    a = batch_for(cfg, 7, 4, 32)
    b = batch_for(cfg, 7, 4, 32)
    np.testing.assert_array_equal(np.asarray(a["tokens"]), np.asarray(b["tokens"]))


def test_steps_differ():
    cfg = get_config("h2o-danube-1.8b", smoke=True)
    a = batch_for(cfg, 1, 4, 32)
    b = batch_for(cfg, 2, 4, 32)
    assert not np.array_equal(np.asarray(a["tokens"]), np.asarray(b["tokens"]))


def test_hosts_get_different_shards():
    cfg = get_config("h2o-danube-1.8b", smoke=True)
    a = batch_for(cfg, 3, 4, 32, host_id=0)
    b = batch_for(cfg, 3, 4, 32, host_id=1)
    assert not np.array_equal(np.asarray(a["tokens"]), np.asarray(b["tokens"]))


def test_text_corpus_and_batches():
    corpus = byte_corpus(".")
    assert len(corpus) > 10_000
    b = text_batch(0, 4, 64, corpus=corpus)
    assert b["tokens"].shape == (4, 64)
    # next-byte targets: shifted by one
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["targets"][:, :-1])
    # train/valid splits don't overlap ranges
    tr = text_batch(0, 4, 64, corpus=corpus, split="train")
    va = text_batch(0, 4, 64, corpus=corpus, split="valid")
    assert not np.array_equal(tr["tokens"], va["tokens"])

"""Total kernel dispatch: the ssm/xlstm/moe families on the Pallas kernels.

Mirrors tests/test_pack_state.py for the model families newly ported onto the
sparse kernels (docs/kernels.md#dispatch-coverage): grouped-kernel parity vs
the jnp oracles, full-model fwd/grad equivalence against the dense reference
for BOTH Pallas modes, grouped PackState entries (per-expert / per-head
CSC+CSR), pack refresh-on-topology-update, decode-path pack reuse, and the
loud silent-fallback guards.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import SparseConfig
from repro.core import block_mask_of, tree_paths
from repro.core.pack import is_pack_entry, pack_mismatch, pack_stats
from repro.data import batch_for
from repro.kernels import (
    grouped_block_sparse_linear,
    grouped_masked_linear,
)
from repro.kernels import ref
from repro.kernels.block_sparse_matmul import (
    pack_group_mask,
    pack_group_mask_rows,
)
from repro.models import lm_decode, lm_forward, lm_loss, lm_prefill
from repro.optim import LRSchedule, OptConfig
from repro.training import (
    init_train_state,
    make_algo,
    make_rigl_step,
    make_train_step,
    refresh_pack,
)

pytestmark = pytest.mark.kernels

BLOCK = 16
ARCHS = ("hymba-1.5b", "xlstm-1.3b", "qwen2-moe-a2.7b")
# subtrees this PR ported onto the kernels, per family
NEW_SUBTREES = {
    "hymba-1.5b": ("ssm",),
    "xlstm-1.3b": ("mlstm", "slstm"),
    "qwen2-moe-a2.7b": ("moe",),
}


def _sp(kernel):
    return SparseConfig(
        sparsity=0.8, method="rigl", delta_t=10, alpha=0.3, kernel=kernel,
        block_shape=(BLOCK, BLOCK), kernel_block=(128, BLOCK, BLOCK),
    )


def _cfg(arch, kernel="block_sparse"):
    cfg = get_config(arch, smoke=True)
    return dataclasses.replace(cfg, dtype="float32", sparse=_sp(kernel))


@pytest.fixture(scope="module", params=ARCHS)
def arch_state(request):
    """One block_sparse train state per arch; masks/params are reused for the
    masked and dense modes (the masks are block-aligned, which every mode
    accepts)."""
    cfg = _cfg(request.param)
    st, _, _ = init_train_state(
        jax.random.PRNGKey(0), cfg, OptConfig(kind="adam")
    )
    b = batch_for(cfg, 0, 2, 16, learnable=True)
    return request.param, cfg, st, b


@pytest.fixture(scope="module")
def dense_ref(arch_state):
    """Dense-reference forward + gradient on the SAME raw params + masks."""
    arch, cfg, st, b = arch_state
    cfg_d = dataclasses.replace(cfg, sparse=_sp("dense"))
    h = lm_forward(st["params"], cfg_d, b, masks=st["masks"])[0]
    g = jax.grad(lambda p: lm_loss(p, cfg_d, b, masks=st["masks"]))(
        st["params"]
    )
    return h, g


# ---------------------------------------------------------------------------
# grouped kernels vs the jnp oracles (unit level)
# ---------------------------------------------------------------------------

def test_grouped_masked_linear_matches_ref():
    key = jax.random.PRNGKey(0)
    G, M, K, N = 3, 10, 64, 48
    x = jax.random.normal(key, (G, M, K), jnp.float32)
    w = jax.random.normal(jax.random.fold_in(key, 1), (G, K, N), jnp.float32)
    m = jax.random.uniform(jax.random.fold_in(key, 2), (G, K, N)) < 0.3
    out = grouped_masked_linear(x, w, m, interpret=True)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref.grouped_masked_matmul_ref(x, w, m)),
        rtol=1e-5, atol=1e-5,
    )
    gx, gw = jax.grad(
        lambda a, b: jnp.sum(grouped_masked_linear(a, b, m, interpret=True)),
        (0, 1),
    )(x, w)
    rx, rw = jax.grad(
        lambda a, b: jnp.sum(ref.grouped_masked_matmul_ref(a, b, m)), (0, 1)
    )(x, w)
    np.testing.assert_allclose(np.asarray(gx), np.asarray(rx), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(gw), np.asarray(rw), rtol=1e-5, atol=1e-5)
    # the per-group wgrad cotangent is EXACTLY zero off-mask
    assert bool(jnp.all(jnp.where(m, 0.0, gw) == 0))


def test_grouped_block_sparse_all_topology_sources_bit_identical():
    key = jax.random.PRNGKey(1)
    G, M, K, N, bkn = 3, 10, 64, 48, 16
    x = jax.random.normal(key, (G, M, K), jnp.float32)
    w = jax.random.normal(jax.random.fold_in(key, 1), (G, K, N), jnp.float32)
    bm = np.array(np.asarray(
        jax.random.uniform(jax.random.fold_in(key, 2), (G, K // bkn, N // bkn))
        < 0.4
    ))
    bm[1] = False  # dead group: legal, outputs zeros
    blk = (128, bkn, bkn)
    out_mask = grouped_block_sparse_linear(
        x, w, jnp.asarray(bm), block=blk, interpret=True
    )
    np.testing.assert_allclose(
        np.asarray(out_mask),
        np.asarray(ref.grouped_block_sparse_matmul_ref(x, w, jnp.asarray(bm), bkn, bkn)),
        rtol=1e-5, atol=1e-5,
    )
    assert bool(jnp.all(out_mask[1] == 0))
    idx, cnt = pack_group_mask(bm)
    ridx, rcnt = pack_group_mask_rows(bm)
    entry = {"idx": idx, "cnt": cnt, "ridx": ridx, "rcnt": rcnt}
    out_pack = grouped_block_sparse_linear(
        x, w, block=blk, pack=entry, interpret=True
    )
    # tight (host-packed) grids are bit-identical to the concrete-mask pack
    np.testing.assert_array_equal(np.asarray(out_pack), np.asarray(out_mask))
    # ... and to the traced worst-case pack (mask is a tracer under jit)
    out_traced = jax.jit(
        lambda a, b, mm: grouped_block_sparse_linear(
            a, b, mm, block=blk, interpret=True
        )
    )(x, w, jnp.asarray(bm))
    np.testing.assert_array_equal(np.asarray(out_traced), np.asarray(out_mask))
    # grads through the tight pack match the oracle
    gx, gw = jax.grad(
        lambda a, b: jnp.sum(grouped_block_sparse_linear(
            a, b, block=blk, pack=entry, interpret=True
        )),
        (0, 1),
    )(x, w)
    rx, rw = jax.grad(
        lambda a, b: jnp.sum(
            ref.grouped_block_sparse_matmul_ref(a, b, jnp.asarray(bm), bkn, bkn)
        ),
        (0, 1),
    )(x, w)
    np.testing.assert_allclose(np.asarray(gx), np.asarray(rx), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(gw), np.asarray(rw), rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# full-model equivalence: kernel modes vs the dense reference
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kernel", ["masked", "block_sparse"])
def test_forward_matches_dense_reference(arch_state, dense_ref, kernel):
    arch, cfg, st, b = arch_state
    cfg_k = dataclasses.replace(cfg, sparse=_sp(kernel))
    h = lm_forward(
        st["params"], cfg_k, b, masks=st["masks"],
        pack=st["pack"] if kernel == "block_sparse" else None,
    )[0]
    np.testing.assert_allclose(
        np.asarray(h), np.asarray(dense_ref[0]), rtol=1e-4, atol=1e-4,
        err_msg=f"{arch}/{kernel}",
    )


@pytest.mark.parametrize("kernel", ["masked", "block_sparse"])
def test_grads_match_dense_reference(arch_state, dense_ref, kernel):
    arch, cfg, st, b = arch_state
    cfg_k = dataclasses.replace(cfg, sparse=_sp(kernel))
    g = jax.grad(
        lambda p: lm_loss(
            p, cfg_k, b, masks=st["masks"],
            pack=st["pack"] if kernel == "block_sparse" else None,
        )
    )(st["params"])
    fk, fd = tree_paths(g), tree_paths(dense_ref[1])
    fm = tree_paths(st["masks"])
    fb = tree_paths(st.get("bwd_masks", {})) if "bwd_masks" in st else {}
    for name in fk:
        got, want = np.asarray(fk[name]), np.asarray(fd[name])
        mk = fm.get(name)
        if kernel == "block_sparse" and mk is not None:
            # the dispatched wgrad runs on the top-(k+Δ) backward superset
            # (docs/training.md#topkast): on the forward topology it must
            # equal the dense reference; the B\A surplus is the grow-score
            # side-channel, zero in the reference by construction of
            # apply_masks — and the dispatched grad must vanish outside B.
            m = np.asarray(mk, bool)
            bw = fb.get(name)
            assert bw is not None, f"{arch}/{name}: superset mask missing"
            assert np.all(got[~np.asarray(bw, bool)] == 0.0), (
                f"{arch}/{kernel}/{name}: gradient outside the superset"
            )
            got, want = got * m, want * m
        np.testing.assert_allclose(
            got, want, rtol=1e-4, atol=1e-4,
            err_msg=f"{arch}/{kernel}/{name}",
        )


def test_tight_pack_equals_traced_fallback_bitexact(arch_state):
    """Under jit the no-pack path uses the traced worst-case-width packs; the
    PackState path must be bit-identical (same add order, padded slots
    contribute nothing) — now including the grouped banks."""
    arch, cfg, st, b = arch_state
    h_tight = jax.jit(
        lambda p, m, pk: lm_forward(p, cfg, b, masks=m, pack=pk)[0]
    )(st["params"], st["masks"], st["pack"])
    h_padded = jax.jit(lambda p, m: lm_forward(p, cfg, b, masks=m)[0])(
        st["params"], st["masks"]
    )
    np.testing.assert_array_equal(np.asarray(h_tight), np.asarray(h_padded))


# ---------------------------------------------------------------------------
# PackState: grouped entries for the new subtrees
# ---------------------------------------------------------------------------

def test_pack_covers_new_subtrees(arch_state):
    arch, cfg, st, b = arch_state
    flat, _ = jax.tree_util.tree_flatten_with_path(
        st["pack"], is_leaf=is_pack_entry
    )
    from repro.core.masks import path_name

    entries = {path_name(p): e for p, e in flat}
    masks = tree_paths(st["masks"])
    for name, m in masks.items():
        if m is None:
            continue
        sub = name.split("/")[2] if name.startswith("layers/") else name
        if sub in NEW_SUBTREES[arch]:
            e = entries[name]
            assert e is not None, f"no pack entry for {name}"
            assert e["idx"].ndim == (3 if m.ndim == 3 else 2), name
            # grouped entries agree with the per-group host pack
            if m.ndim == 3:
                bm = np.asarray(block_mask_of(np.asarray(m, bool), (BLOCK, BLOCK)))
                idx_ref, cnt_ref = pack_group_mask(
                    bm, max_count=int(e["idx"].shape[-1])
                )
                np.testing.assert_array_equal(
                    np.asarray(e["idx"]), np.asarray(idx_ref), err_msg=name
                )
                np.testing.assert_array_equal(
                    np.asarray(e["cnt"]), np.asarray(cnt_ref), err_msg=name
                )
    assert int(pack_mismatch(st["masks"], st["pack"], (BLOCK, BLOCK))) == 0
    stats = pack_stats(st["pack"])
    assert stats["grid_iters_tight"] < stats["grid_iters_padded"]
    # at least one grouped entry exists for the moe/xlstm archs
    if arch != "hymba-1.5b":
        assert any(v["groups"] > 1 for v in stats["layers"].values())


def test_refresh_after_rigl_update_covers_grouped_banks():
    cfg = _cfg("qwen2-moe-a2.7b")
    opt = OptConfig(kind="adam", weight_decay=0.0, grad_clip=1.0)
    lr = LRSchedule(base_lr=3e-3, warmup_steps=2, total_steps=30)
    st, _, _ = init_train_state(jax.random.PRNGKey(1), cfg, opt)
    train = jax.jit(make_train_step(cfg, opt, lr))
    rigl = jax.jit(make_rigl_step(cfg, make_algo(cfg, 30), lr))
    st, m = train(st, batch_for(cfg, 0, 2, 16, learnable=True))
    assert int(m["pack_stale"]) == 0
    st, _ = rigl(st, batch_for(cfg, 1, 2, 16, learnable=True))
    stale = int(pack_mismatch(st["masks"], st["pack"], (BLOCK, BLOCK)))
    assert stale > 0, "rigl moved no blocks — test cfg too static"
    st = refresh_pack(st, cfg)
    assert int(pack_mismatch(st["masks"], st["pack"], (BLOCK, BLOCK))) == 0
    st, m = train(st, batch_for(cfg, 2, 2, 16, learnable=True))
    assert int(m["pack_stale"]) == 0
    assert np.isfinite(float(m["loss"]))


# ---------------------------------------------------------------------------
# serve: ssm/xlstm decode through the kernels, one pack reused per topology
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["hymba-1.5b", "xlstm-1.3b"])
def test_decode_path_pack_reuse(arch):
    cfg = _cfg(arch)
    st, _, _ = init_train_state(
        jax.random.PRNGKey(2), cfg, OptConfig(kind="adam")
    )
    toks = jax.random.randint(jax.random.PRNGKey(3), (2, 12), 0, cfg.vocab_size)
    kw = dict(masks=st["masks"])
    lg_n, c_n = lm_prefill(
        st["params"], cfg, {"tokens": toks[:, :8]}, max_len=12, **kw
    )
    lg_p, c_p = lm_prefill(
        st["params"], cfg, {"tokens": toks[:, :8]}, max_len=12,
        pack=st["pack"], **kw
    )
    np.testing.assert_array_equal(np.asarray(lg_n), np.asarray(lg_p))
    for t in range(8, 10):
        step_tok = toks[:, t : t + 1]
        lg_n, c_n = lm_decode(st["params"], cfg, c_n, step_tok, pos=t, **kw)
        # the SAME pack object is reused every decode step — no re-packing
        lg_p, c_p = lm_decode(
            st["params"], cfg, c_p, step_tok, pos=t, pack=st["pack"], **kw
        )
        np.testing.assert_array_equal(
            np.asarray(lg_n), np.asarray(lg_p), err_msg=f"pos {t}"
        )


# ---------------------------------------------------------------------------
# loud guards: no silent dense fallback under kernel dispatch
# ---------------------------------------------------------------------------

def test_assert_total_dispatch_flags_unconsumed_mask():
    from repro.models.layers import assert_total_dispatch

    masks = {"wi": {"w": jnp.ones((4, 4), bool)}, "extra": {"w": jnp.ones((4, 4), bool)}}
    # all leaves consumed: fine
    assert_total_dispatch(masks, ("wi", "extra"), kernel="masked", where="t")
    # dense mode never raises (w*m is the intended path there)
    assert_total_dispatch(masks, ("wi",), kernel="dense", where="t")
    with pytest.raises(RuntimeError, match="extra"):
        assert_total_dispatch(masks, ("wi",), kernel="masked", where="t")


def test_local_masked_fallback_is_loud():
    from repro.models.model import _local_masked

    p = {"sub": {"wi": {"w": jnp.ones((4, 4))}}}
    masks = {"sub": {"wi": {"w": jnp.ones((4, 4), bool)}}}
    # legacy modes still work
    out = _local_masked(p, masks, "sub", kernel="dense")
    np.testing.assert_array_equal(np.asarray(out["wi"]["w"]), np.ones((4, 4)))
    assert _local_masked(p, None, "sub", kernel="masked") is p["sub"]
    with pytest.raises(RuntimeError, match="dispatch"):
        _local_masked(p, masks, "sub", kernel="block_sparse")

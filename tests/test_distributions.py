"""Sparsity distribution tests: paper semantics + hypothesis invariants.

Requires ``hypothesis`` (pinned in requirements-dev.txt); the whole module is
skipped when it is absent so a bare CI image still collects the suite.
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.distributions import (
    LayerSpec,
    erdos_renyi_distribution,
    get_distribution,
    sparsity_overall,
    uniform_distribution,
)


def _layers(shapes, dense_first=False):
    return [
        LayerSpec(f"l{i}", s, dense=(i == 0 and dense_first))
        for i, s in enumerate(shapes)
    ]


def test_uniform_all_equal():
    ls = _layers([(64, 64), (64, 128), (128, 64)])
    d = uniform_distribution(ls, 0.8, dense_first=False)
    assert all(v == 0.8 for v in d.values())


def test_uniform_dense_first():
    ls = _layers([(64, 64), (64, 128)])
    d = uniform_distribution(ls, 0.8, dense_first=True)
    assert d["l0"] == 0.0 and d["l1"] == 0.8


def test_erk_hits_target_exactly():
    ls = _layers([(512, 512), (512, 2048), (2048, 512), (64, 64)])
    d = erdos_renyi_distribution(ls, 0.9)
    assert abs(sparsity_overall(ls, d) - 0.9) < 1e-9


def test_erk_small_layers_denser():
    """ER(K) gives smaller layers lower sparsity (the paper's key property)."""
    ls = _layers([(2048, 2048), (64, 64)])
    d = erdos_renyi_distribution(ls, 0.8)
    assert d["l1"] < d["l0"]


def test_erk_caps_at_dense():
    # tiny layer would need density > 1 -> pinned dense, eps re-solved
    ls = _layers([(4096, 4096), (8, 8)])
    d = erdos_renyi_distribution(ls, 0.5)
    assert d["l1"] == 0.0
    assert abs(sparsity_overall(ls, d) - 0.5) < 1e-9


def test_erk_kernel_dims():
    """ERK counts conv kernel dims; ER does not."""
    ls = [LayerSpec("c", (3, 3, 64, 64)), LayerSpec("d", (576, 64))]
    erk = erdos_renyi_distribution(ls, 0.8, kernel_aware=True)
    er = erdos_renyi_distribution(ls, 0.8, kernel_aware=False)
    assert erk["c"] != er["c"]


@settings(max_examples=50, deadline=None)
@given(
    st.lists(
        st.tuples(st.integers(8, 256), st.integers(8, 256)),
        min_size=2,
        max_size=8,
    ),
    st.floats(0.3, 0.95),
    st.sampled_from(["uniform", "er", "erk"]),
)
def test_property_valid_sparsities(shapes, sparsity, kind):
    ls = _layers(shapes)
    d = get_distribution(kind, ls, sparsity, dense_first=False)
    for v in d.values():
        assert 0.0 <= v < 1.0
    if kind in ("er", "erk"):
        assert abs(sparsity_overall(ls, d) - sparsity) < 1e-6


def test_zero_sparsity_is_dense():
    ls = _layers([(64, 64)])
    d = get_distribution("erk", ls, 0.0)
    assert d["l0"] == 0.0

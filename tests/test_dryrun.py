"""Dry-run machinery: HLO collective parser + a mini-mesh cell (subprocess)."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

from repro.analysis.hlo import collective_bytes
from repro.analysis.roofline import roofline_terms


def test_collective_parser_kinds_and_groups():
    hlo = """
  %all-reduce.5 = f32[2,4096,2560]{2,1,0} all-reduce(%fusion.1), channel_id=5, replica_groups=[4,2]<=[8], use_global_device_ids=true, to_apply=%add.1
  %all-gather.2 = bf16[8,128]{1,0} all-gather(%p.2), channel_id=3, replica_groups=[2,4]<=[8], dimensions={0}
  %reduce-scatter.1 = f32[16]{0} reduce-scatter(%x), channel_id=9, replica_groups=[1,8]<=[8], to_apply=%add
  %all-reduce-start.1 = f32[4]{0} all-reduce-start(%y), channel_id=11, replica_groups=[1,8]<=[8], to_apply=%add
  %all-reduce-done.1 = f32[4]{0} all-reduce-done(%all-reduce-start.1)
    """
    cb = collective_bytes(hlo)
    assert cb["all-reduce"] == 2 * 4096 * 2560 * 4 + 4 * 4  # incl. -start once
    assert cb["all-gather"] == 8 * 128 * 2 // 4  # operand = result / group(4)
    assert cb["reduce-scatter"] == 16 * 4 * 8  # operand = result * group(8)
    assert cb["total"] == sum(v for k, v in cb.items() if k != "total")


def test_roofline_terms_dominance():
    r = roofline_terms(1e15, 1e12, 1e9, chips=256, model_flops_total=6e17)
    assert r["dominant"] == "compute"
    assert r["compute_s"] == pytest.approx(1e15 / 197e12)
    r2 = roofline_terms(1e12, 1e13, 1e9, chips=256)
    assert r2["dominant"] == "memory"


MINI = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax
    from repro.launch import dryrun_lib
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    art = dryrun_lib.run_cell("h2o-danube-1.8b", "train_4k", mesh, save=False,
                              cfg_overrides={"n_layers": 2, "microbatches": 1})
    print(json.dumps({
        "flops": art["per_device"]["flops"],
        "coll": art["per_device"]["coll"],
        "dominant": art["roofline"]["dominant"],
        "fits": art["memory"]["fits_16g_hbm"],
    }))
""")


@pytest.mark.slow
def test_mini_mesh_cell():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src")
    )
    out = subprocess.run([sys.executable, "-c", MINI], capture_output=True,
                         text=True, env=env, timeout=1800)
    assert out.returncode == 0, out.stderr[-3000:]
    d = json.loads(out.stdout.strip().splitlines()[-1])
    assert d["flops"] > 1e9
    assert d["coll"] > 0, "DP/TP must produce collectives"
    assert d["dominant"] in ("compute", "memory", "collective")

"""Tight-grid flash attention vs the jnp oracle (interpret mode on CPU).

Covers this PR's kernel tier end to end: AttnSchedule builder vs a brute-force
numpy mask rasterizer (incl. degenerate windows and decode Sq=1), fwd parity
for {causal, window, causal+window} x {Sq=Sk, Sq!=Sk, non-aligned} x dtypes,
grad-vs-reference through the custom-VJP dq / dk/dv kernels, tight==padded
bit-identity, and the model-level attn_kernel dispatch (attention() and
lm_loss grads with flash_tight vs the chunked jnp path).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.attn_sched import (
    attn_sched_stats,
    build_attn_schedule,
    live_block_mask,
    rasterize_block_mask,
    sched_for,
)
from repro.kernels import ref
from repro.kernels.flash_attention import effective_blocks, flash_attention

pytestmark = pytest.mark.kernels

# (causal, window) mask families named for test ids
FAMILIES = {
    "causal": (True, 0),
    "window128": (False, 128),
    "window512": (False, 512),
    "causal+window128": (True, 128),
    "causal+window512": (True, 512),
}


def _qkv(key, bh, sq, sk, d, dtype=jnp.float32):
    q = jax.random.normal(key, (bh, sq, d)).astype(dtype)
    k = jax.random.normal(jax.random.fold_in(key, 1), (bh, sk, d)).astype(dtype)
    v = jax.random.normal(jax.random.fold_in(key, 2), (bh, sk, d)).astype(dtype)
    return q, k, v


# ---------------------------------------------------------------------------
# schedule builder vs brute-force rasterizer
# ---------------------------------------------------------------------------

@pytest.mark.parametrize(
    "sq,sk,bq,bk",
    [(256, 256, 128, 128), (256, 256, 64, 64), (100, 300, 64, 64),
     (1, 512, 128, 128), (640, 640, 128, 128), (48, 48, 16, 16)],
)
@pytest.mark.parametrize("family", sorted(FAMILIES))
def test_live_blocks_match_rasterizer(sq, sk, bq, bk, family):
    """The analytic block-liveness exactly matches rasterizing the (sq, sk)
    elementwise mask and reducing per block."""
    causal, window = FAMILIES[family]
    fast = live_block_mask(sq, sk, bq, bk, causal=causal, window=window)
    slow = rasterize_block_mask(sq, sk, bq, bk, causal=causal, window=window)
    np.testing.assert_array_equal(fast, slow)


@pytest.mark.parametrize(
    "window", [1, 7, 16, 64, 512, 10_000],  # < bk, == bk, >= sk degenerates
)
def test_live_blocks_degenerate_windows(window):
    sq = sk = 192
    bq = bk = 64
    fast = live_block_mask(sq, sk, bq, bk, causal=True, window=window)
    slow = rasterize_block_mask(sq, sk, bq, bk, causal=True, window=window)
    np.testing.assert_array_equal(fast, slow)
    if window >= sk:  # window covers everything: reduces to pure causal
        np.testing.assert_array_equal(
            fast, live_block_mask(sq, sk, bq, bk, causal=True, window=0)
        )
    if window <= bk:  # at most the diagonal + one predecessor block per row
        assert int(fast.sum(axis=1).max()) <= 2


def test_schedule_packing_semantics():
    """kv_idx/kv_cnt list each q row's live KV blocks ascending (padded 0);
    q_idx/q_cnt are the exact transpose view."""
    sched = build_attn_schedule(512, 512, 64, 64, causal=True, window=130)
    live = live_block_mask(512, 512, 64, 64, causal=True, window=130)
    kv_idx, kv_cnt = np.asarray(sched["kv_idx"]), np.asarray(sched["kv_cnt"])
    for i in range(live.shape[0]):
        act = np.nonzero(live[i])[0]
        assert kv_cnt[i] == len(act)
        np.testing.assert_array_equal(kv_idx[i, : len(act)], act)
        assert (kv_idx[i, len(act):] == 0).all()
    q_idx, q_cnt = np.asarray(sched["q_idx"]), np.asarray(sched["q_cnt"])
    for j in range(live.shape[1]):
        act = np.nonzero(live[:, j])[0]
        assert q_cnt[j] == len(act)
        np.testing.assert_array_equal(q_idx[j, : len(act)], act)
    assert int(sched["n_live"]) == int(live.sum())


def test_decode_schedule_sq1():
    """Decode-style Sq=1: one q row, right-aligned, window-tail KV blocks."""
    sched = build_attn_schedule(1, 4096, 16, 128, causal=True, window=512)
    assert np.asarray(sched["kv_cnt"]).shape == (1,)
    # the single query at position 4095 sees keys (3583, 4095] — exactly
    # blocks 28..31 (4 blocks of 128; the window lands on a block boundary)
    assert int(sched["kv_cnt"][0]) == 4
    np.testing.assert_array_equal(
        np.asarray(sched["kv_idx"])[0, :4], [28, 29, 30, 31]
    )
    stats = attn_sched_stats(sched)
    assert stats["grid_fraction"] == 4 / 32


def test_sched_stats_orderings():
    """grid_fraction >= live_fraction (width is a per-row max), and both are
    far under the dense grid for windowed long context."""
    sched = build_attn_schedule(4096, 4096, 128, 128, causal=True, window=512)
    st = attn_sched_stats(sched)
    assert st["live_fraction"] <= st["grid_fraction"] <= 0.5
    assert st["grid_iters_tight"] == st["n_q"] * st["width"]
    assert st["grid_iters_padded"] == st["n_q"] * st["n_k"]


# ---------------------------------------------------------------------------
# forward parity vs the jnp oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("family", sorted(FAMILIES))
@pytest.mark.parametrize(
    "sq,sk",
    [(256, 256), (128, 384), (100, 100), (96, 333)],  # =, !=, non-aligned
)
def test_forward_parity_f32(family, sq, sk):
    causal, window = FAMILIES[family]
    key = jax.random.PRNGKey(hash((family, sq, sk)) % 2**31)
    q, k, v = _qkv(key, 2, sq, sk, 64)
    out = flash_attention(
        q, k, v, causal=causal, window=window, bq=64, bk=64, interpret=True
    )
    expect = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(expect), atol=1e-5
    )


@pytest.mark.parametrize("family", ["causal", "causal+window128"])
def test_forward_parity_bf16(family):
    causal, window = FAMILIES[family]
    key = jax.random.PRNGKey(5)
    q, k, v = _qkv(key, 2, 256, 256, 64, jnp.bfloat16)
    out = flash_attention(
        q, k, v, causal=causal, window=window, interpret=True
    )
    expect = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(expect, np.float32), atol=3e-2
    )


def test_tight_equals_padded_bitexact():
    """Tight and dense-worst-case grids are the SAME kernel on the same
    schedule — outputs bit-identical, only the grid length differs."""
    key = jax.random.PRNGKey(7)
    q, k, v = _qkv(key, 2, 256, 256, 64)
    t = flash_attention(
        q, k, v, causal=True, window=128, tight=True, bq=64, bk=64,
        interpret=True,
    )
    p = flash_attention(
        q, k, v, causal=True, window=128, tight=False, bq=64, bk=64,
        interpret=True,
    )
    assert jnp.array_equal(t, p)


def test_explicit_sched_and_mismatch_is_loud():
    key = jax.random.PRNGKey(8)
    q, k, v = _qkv(key, 1, 256, 256, 64)
    bq, bk = effective_blocks(256, 256, 64, 64)
    sched = sched_for(256, 256, bq, bk, True, 128, 0)
    out = flash_attention(
        q, k, v, causal=True, window=128, sched=sched, bq=64, bk=64,
        interpret=True,
    )
    expect = ref.flash_attention_ref(q, k, v, causal=True, window=128)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), atol=1e-5)
    with pytest.raises(ValueError, match="sched built for"):
        flash_attention(
            q, k, v, causal=True, window=512, sched=sched, bq=64, bk=64,
            interpret=True,
        )


# ---------------------------------------------------------------------------
# backward: custom-VJP dq / dk/dv kernels vs jax.grad of the oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("family", sorted(FAMILIES))
def test_grads_vs_ref(family):
    causal, window = FAMILIES[family]
    key = jax.random.PRNGKey(11 + hash(family) % 1000)
    q, k, v = _qkv(key, 2, 192, 192, 64)

    f_k = lambda q, k, v: jnp.sum(jnp.sin(flash_attention(
        q, k, v, causal=causal, window=window, bq=64, bk=64, interpret=True
    )))
    f_r = lambda q, k, v: jnp.sum(jnp.sin(ref.flash_attention_ref(
        q, k, v, causal=causal, window=window
    )))
    gk = jax.grad(f_k, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(f_r, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


@pytest.mark.parametrize("sq,sk", [(64, 256), (100, 333)])
def test_grads_cross_length(sq, sk):
    """Sq != Sk (right-aligned offsets) and non-aligned lengths through the
    padding/trim path: padded rows/keys must contribute exactly nothing."""
    key = jax.random.PRNGKey(13)
    q, k, v = _qkv(key, 2, sq, sk, 64)
    f_k = lambda q, k, v: jnp.sum(jnp.cos(flash_attention(
        q, k, v, causal=True, window=96, bq=64, bk=64, interpret=True
    )))
    f_r = lambda q, k, v: jnp.sum(jnp.cos(ref.flash_attention_ref(
        q, k, v, causal=True, window=96
    )))
    gk = jax.grad(f_k, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(f_r, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_grads_under_jit_tight_equals_padded():
    key = jax.random.PRNGKey(17)
    q, k, v = _qkv(key, 1, 128, 128, 64)

    def loss(tight):
        return jax.jit(jax.grad(lambda q: jnp.sum(flash_attention(
            q, k, v, causal=True, window=64, tight=tight, bq=64, bk=64,
            interpret=True,
        ) ** 2)))(q)

    np.testing.assert_array_equal(
        np.asarray(loss(True)), np.asarray(loss(False))
    )


# ---------------------------------------------------------------------------
# model-level dispatch: attention() / lm_loss with attn_kernel set
# ---------------------------------------------------------------------------

def _smoke_cfg(attn_kernel, **kw):
    from repro.configs import get_config

    cfg = get_config("h2o-danube-1.8b", smoke=True)  # SWA stack, window > 0
    sp = dataclasses.replace(cfg.sparse, attn_kernel=attn_kernel)
    return dataclasses.replace(cfg, sparse=sp, dtype="float32", **kw)


@pytest.mark.parametrize("attn_kernel", ["flash", "flash_tight"])
def test_model_attention_matches_dense_path(attn_kernel):
    """attention() with the flash kernels == the chunked jnp path (f32), for
    both the local (windowed) and global layer kinds, GQA included."""
    from repro.models.attention import attn_init, attention

    cfg = _smoke_cfg(attn_kernel)
    key = jax.random.PRNGKey(0)
    p = jax.tree_util.tree_map(
        lambda b: b.value, attn_init(key, cfg), is_leaf=lambda x: hasattr(x, "value")
    )
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 64, cfg.d_model))
    for kind in ("local", "global"):
        out_f, _ = attention(p, x, cfg, kind=kind)
        out_d, _ = attention(p, x, _smoke_cfg("dense"), kind=kind)
        np.testing.assert_allclose(
            np.asarray(out_f), np.asarray(out_d), atol=2e-5
        )


def test_lm_loss_grads_flash_vs_dense():
    """Training parity: jax.grad(lm_loss) through the attention custom VJP
    matches the chunked jnp path — no silent fallback, no grad gaps."""
    from repro.models import init_lm, lm_loss

    cfg = _smoke_cfg("flash_tight")
    key = jax.random.PRNGKey(1)
    params, _, _ = init_lm(key, cfg)
    batch = {
        "tokens": jax.random.randint(key, (2, 64), 0, cfg.vocab_size),
        "targets": jax.random.randint(key, (2, 64), 0, cfg.vocab_size),
    }
    lf, gf = jax.value_and_grad(lambda p: lm_loss(p, cfg, batch))(params)
    cfg_d = _smoke_cfg("dense")
    ld, gd = jax.value_and_grad(lambda p: lm_loss(p, cfg_d, batch))(params)
    assert abs(float(lf) - float(ld)) < 1e-4
    for a, b in zip(
        jax.tree_util.tree_leaves(gf), jax.tree_util.tree_leaves(gd)
    ):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32), atol=5e-4
        )


@pytest.mark.parametrize("family", ["causal", "causal+window128"])
def test_softcap_forward_parity(family):
    """logit_softcap inside the online softmax == capping the dense scores
    before the mask (the gemma/grok convention, ref + _scores)."""
    causal, window = FAMILIES[family]
    key = jax.random.PRNGKey(23)
    q, k, v = _qkv(key, 2, 192, 192, 64)
    out = flash_attention(
        q, k, v, causal=causal, window=window, bq=64, bk=64, softcap=30.0,
        interpret=True,
    )
    expect = ref.flash_attention_ref(
        q, k, v, causal=causal, window=window, softcap=30.0
    )
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), atol=1e-5)


def test_softcap_grads_vs_ref():
    """The VJP chain factor (1 - tanh²) through dq AND dk/dv."""
    key = jax.random.PRNGKey(29)
    q, k, v = _qkv(key, 2, 192, 192, 64)
    f_k = lambda q, k, v: jnp.sum(jnp.sin(flash_attention(
        q, k, v, causal=True, bq=64, bk=64, softcap=20.0, interpret=True
    )))
    f_r = lambda q, k, v: jnp.sum(jnp.sin(ref.flash_attention_ref(
        q, k, v, causal=True, softcap=20.0
    )))
    gk = jax.grad(f_k, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(f_r, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


@pytest.mark.parametrize("G", [2, 4])
def test_gqa_folded_forward_bitexact_vs_repeated(G):
    """kv_groups=G reading unrepeated (BH/G) K/V == repeating K/V to the
    full head count — bit-identical (same arithmetic, different DMA source)."""
    key = jax.random.PRNGKey(31)
    BH = 8
    q = jax.random.normal(key, (BH, 128, 64))
    k = jax.random.normal(jax.random.fold_in(key, 1), (BH // G, 128, 64))
    v = jax.random.normal(jax.random.fold_in(key, 2), (BH // G, 128, 64))
    folded = flash_attention(
        q, k, v, causal=True, bq=64, bk=64, kv_groups=G, interpret=True
    )
    repeated = flash_attention(
        q, jnp.repeat(k, G, axis=0), jnp.repeat(v, G, axis=0), causal=True,
        bq=64, bk=64, interpret=True,
    )
    assert jnp.array_equal(folded, repeated)


@pytest.mark.parametrize("G", [2, 4])
def test_gqa_folded_grads_vs_repeated(G):
    """The restructured dk/dv grid sums over group members == the cotangent
    of jnp.repeat (which segment-sums over the group)."""
    key = jax.random.PRNGKey(37)
    BH = 8
    q = jax.random.normal(key, (BH, 128, 64))
    k = jax.random.normal(jax.random.fold_in(key, 1), (BH // G, 128, 64))
    v = jax.random.normal(jax.random.fold_in(key, 2), (BH // G, 128, 64))
    f_fold = lambda q, k, v: jnp.sum(jnp.sin(flash_attention(
        q, k, v, causal=True, window=96, bq=64, bk=64, kv_groups=G,
        softcap=15.0, interpret=True,
    )))
    f_rep = lambda q, k, v: jnp.sum(jnp.sin(flash_attention(
        q, jnp.repeat(k, G, axis=0), jnp.repeat(v, G, axis=0), causal=True,
        window=96, bq=64, bk=64, softcap=15.0, interpret=True,
    )))
    gf = jax.grad(f_fold, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(f_rep, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gf, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_gqa_layout_mismatch_is_loud():
    key = jax.random.PRNGKey(41)
    q, k, v = _qkv(key, 8, 128, 128, 64)  # k/v NOT unrepeated for G=4
    with pytest.raises(ValueError, match="UNREPEATED"):
        flash_attention(q, k, v, causal=True, kv_groups=4, interpret=True)


def test_model_softcap_flash_matches_dense():
    """Softcapped GQA config through flash_tight == the chunked jnp path —
    the dispatch that used to raise now runs the kernels for real."""
    from repro.models.attention import attn_init, attention

    cfg = dataclasses.replace(_smoke_cfg("flash_tight"), logit_softcap=30.0)
    cfg_d = dataclasses.replace(_smoke_cfg("dense"), logit_softcap=30.0)
    key = jax.random.PRNGKey(2)
    p = jax.tree_util.tree_map(
        lambda b: b.value, attn_init(key, cfg), is_leaf=lambda x: hasattr(x, "value")
    )
    x = jax.random.normal(key, (1, 64, cfg.d_model))
    for kind in ("local", "global"):
        out_f, _ = attention(p, x, cfg, kind=kind)
        out_d, _ = attention(p, x, cfg_d, kind=kind)
        np.testing.assert_allclose(
            np.asarray(out_f), np.asarray(out_d), atol=2e-5
        )


def test_validate_attn_kernel():
    from repro.configs.base import SparseConfig, validate_sparse_kernel

    with pytest.raises(ValueError, match="attn_kernel"):
        validate_sparse_kernel(SparseConfig(attn_kernel="flashiest"))
    with pytest.raises(ValueError, match="pack_width_slack"):
        validate_sparse_kernel(SparseConfig(pack_width_slack=1.5))
    validate_sparse_kernel(SparseConfig(attn_kernel="flash_tight"))


# ---------------------------------------------------------------------------
# paged prefix attention (scalar-prefetched block tables)
# ---------------------------------------------------------------------------

def _paged_case(key, B, H, KV, sq, n_pages, bs, d, ctx_vals):
    """Random (q, pool, table, ctx) with per-request prefix depths: each
    request owns the first ceil(ctx/bs) entries of its table row; the rest
    carry the sentinel N (unowned) and junk pool contents."""
    N = B * n_pages
    q = jax.random.normal(key, (B, H, sq, d), jnp.float32)
    pk = jax.random.normal(jax.random.fold_in(key, 1), (N, bs, KV, d),
                           jnp.float32)
    pv = jax.random.normal(jax.random.fold_in(key, 2), (N, bs, KV, d),
                           jnp.float32)
    rng = np.random.default_rng(7)
    perm = rng.permutation(N)
    table = np.full((B, n_pages), N, np.int32)
    ctx = np.asarray(ctx_vals, np.int32)
    for b in range(B):
        live = -(-int(ctx[b]) // bs)
        table[b, :live] = perm[b * n_pages : b * n_pages + live]
    return q, pk, pv, jnp.asarray(table), jnp.asarray(ctx)


def _paged_oracle(q, pk, pv, table, ctx):
    """Dense jnp reference: gather the table into a contiguous view, mask
    kpos >= ctx, softmax over the prefix only; rows with ctx == 0 get
    output 0 and lse == NEG_INF (the merge's 'no history' weight)."""
    from repro.models.attention import gather_kv_pool

    B, H, sq, d = q.shape
    view = gather_kv_pool({"k": pk, "v": pv}, table)
    KV = pk.shape[2]
    G = H // KV
    k = jnp.repeat(view["k"].transpose(0, 2, 1, 3), G, axis=1)  # (B,H,S,d)
    v = jnp.repeat(view["v"].transpose(0, 2, 1, 3), G, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(d)
    mask = (jnp.arange(k.shape[2])[None] < ctx[:, None])[:, None, None]
    s = jnp.where(mask, s, -jnp.inf)
    m = jnp.max(s, -1)
    w = jnp.exp(s - m[..., None])
    l = jnp.sum(jnp.where(mask, w, 0.0), -1)
    o = jnp.einsum("bhqk,bhkd->bhqd", jnp.where(mask, w, 0.0), v) / jnp.maximum(
        l[..., None], 1e-30
    )
    empty = ctx[:, None, None] == 0
    lse = jnp.where(empty, -1e30, m + jnp.log(jnp.maximum(l, 1e-30)))
    return jnp.where(empty[..., None], 0.0, o), lse


@pytest.mark.paged
@pytest.mark.parametrize("G", [1, 4])  # MHA and GQA head folding
def test_paged_prefix_kernel_matches_oracle(G):
    """flash_attention_paged == the masked-dense oracle over scattered
    pages: per-request prefix depths (incl. page-unaligned and the ctx=0
    empty-history row), sentinel tails, shuffled physical page ids."""
    from repro.kernels.flash_attention import flash_attention_paged

    KV, d, bs, n_pages = 2, 16, 8, 6
    H = KV * G
    q, pk, pv, table, ctx = _paged_case(
        jax.random.PRNGKey(0), B=4, H=H, KV=KV, sq=5, n_pages=n_pages,
        bs=bs, d=d, ctx_vals=[0, 3, 8 * 3, 8 * 6 - 2],
    )
    o, lse = flash_attention_paged(q, pk, pv, table, ctx, bq=16,
                                   interpret=True)
    o_ref, lse_ref = _paged_oracle(q, pk, pv, table, ctx)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref),
                               atol=2e-5, rtol=2e-5)
    np.testing.assert_allclose(np.asarray(lse), np.asarray(lse_ref),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.paged
def test_paged_prefix_empty_history_merge_weight_vanishes():
    """The ctx==0 lse sentinel must underflow to weight EXACTLY 0 in the
    two-phase logsumexp merge, so a no-history row's merged output is
    bit-identical to its self-attention output alone."""
    from repro.kernels.flash_attention import flash_attention_paged

    q, pk, pv, table, ctx = _paged_case(
        jax.random.PRNGKey(3), B=2, H=2, KV=2, sq=4, n_pages=3, bs=8, d=16,
        ctx_vals=[0, 0],
    )
    o, lse = flash_attention_paged(q, pk, pv, table, ctx, bq=16,
                                   interpret=True)
    assert np.all(np.asarray(o) == 0.0)
    l_self = jnp.zeros(o.shape[:3])  # any finite self-phase lse
    w_hist = jnp.exp(lse - jnp.maximum(lse, l_self))
    assert np.all(np.asarray(w_hist) == 0.0)

"""FLOP accounting vs the paper's published numbers (Fig 2-left, Table 4)."""
import pytest

from repro.core.flops import (
    method_train_flops,
    model_fwd_flops,
    resnet50_flop_multipliers,
    resnet50_layers,
)


def test_resnet50_dense_flops_magnitude():
    # paper: 8.2e9 test FLOPs for dense ResNet-50 (ours: conv+fc only)
    f = model_fwd_flops(resnet50_layers())
    assert 7.0e9 < f < 8.5e9


@pytest.mark.parametrize(
    "sparsity,dist,paper_train,paper_test",
    [
        (0.8, "uniform", 0.23, 0.23),
        (0.9, "uniform", 0.10, 0.10),
        (0.8, "erk", 0.42, 0.42),
        (0.9, "erk", 0.25, 0.24),
        (0.95, "uniform", 0.08, 0.08),
    ],
)
def test_rigl_multipliers_match_paper(sparsity, dist, paper_train, paper_test):
    m = resnet50_flop_multipliers(sparsity, dist)
    # tolerance 0.04 absolute: the paper counts some extra ops (BN etc.)
    assert m["rigl"]["train"] == pytest.approx(paper_train, abs=0.04)
    assert m["rigl"]["test"] == pytest.approx(paper_test, abs=0.04)


def test_method_ordering_matches_table1():
    """Space & FLOPs column of paper Table 1: sparse methods < SNFS < dense."""
    m = resnet50_flop_multipliers(0.8, "uniform")
    assert m["static"]["train"] == m["set"]["train"] == m["snip"]["train"]
    assert m["static"]["train"] < m["rigl"]["train"] * 1.05
    assert m["rigl"]["train"] < m["snfs"]["train"] < m["dense"]["train"]


def test_rigl_amortization_formula():
    """(3 fS dT + 2 fS + fD)/(dT+1): dT -> inf approaches 3 fS."""
    f_d, f_s = 100.0, 20.0
    r100 = method_train_flops("rigl", f_d, f_s, delta_t=100)
    r_inf = method_train_flops("rigl", f_d, f_s, delta_t=10**9)
    assert r_inf == pytest.approx(3 * f_s, rel=1e-6)
    assert r100 > r_inf  # finite dT pays for dense gradients
    expected = (3 * f_s * 100 + 2 * f_s + f_d) / 101
    assert r100 == pytest.approx(expected)


def test_snfs_is_dense_cost():
    f_d, f_s = 100.0, 20.0
    assert method_train_flops("snfs", f_d, f_s) == pytest.approx(2 * f_s + f_d)

"""Fused wgrad->optimizer epilogue (docs/kernels.md#fused-epilogue).

The fused kernels' weight cotangent IS the new SGD momentum
m_new = mu*mom + dw + wd*w (masked to the wgrad support), so a fused train
step must be numerically indistinguishable from the unfused step it replaces
— params, momentum and loss — for every dispatched kernel and method the
path supports.  Also: the loud-failure gating for unsupported combinations,
and the bf16 stochastic-rounding mode (momentum stored exactly on the bf16
grid).
"""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.configs.base import SparseConfig
from repro.data import batch_for
from repro.optim import LRSchedule, OptConfig
from repro.training import init_train_state, make_train_step

pytestmark = pytest.mark.kernels

BLOCK = 16


def _sp(kernel, method, fused):
    return SparseConfig(
        sparsity=0.8, method=method, delta_t=10, alpha=0.3, kernel=kernel,
        block_shape=(BLOCK, BLOCK), kernel_block=(128, BLOCK, BLOCK),
        fused_epilogue=fused,
    )


def _run(kernel, method, fused, state_dtype="float32", steps=2):
    cfg = get_config("h2o-danube-1.8b", smoke=True)
    cfg = dataclasses.replace(
        cfg, dtype="float32", sparse=_sp(kernel, method, fused)
    )
    opt = OptConfig(kind="sgd", momentum=0.9, weight_decay=1e-4,
                    grad_clip=0.0, state_dtype=state_dtype)
    lr = LRSchedule(base_lr=3e-3, warmup_steps=0, total_steps=10)
    state, _, _ = init_train_state(jax.random.PRNGKey(0), cfg, opt)
    step = jax.jit(make_train_step(cfg, opt, lr))
    for t in range(steps):
        state, m = step(state, batch_for(cfg, t, 2, 16, learnable=True))
    return state, m


def _maxdiff(a, b):
    return max(
        float(jnp.max(jnp.abs(x.astype(jnp.float32) - y.astype(jnp.float32))))
        for x, y in zip(
            jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
        )
    )


@pytest.mark.parametrize(
    "kernel,method",
    [("masked", "rigl"), ("block_sparse", "rigl"), ("masked", "topkast")],
)
def test_fused_step_matches_unfused(kernel, method):
    s0, m0 = _run(kernel, method, fused=False)
    s1, m1 = _run(kernel, method, fused=True)
    assert _maxdiff(s0["params"], s1["params"]) < 2e-6
    assert _maxdiff(s0["opt"]["momentum"], s1["opt"]["momentum"]) < 1e-5
    assert abs(float(m0["loss"]) - float(m1["loss"])) < 1e-5


def test_fused_sr_bf16_momentum():
    """state_dtype='bfloat16' switches the epilogue to in-kernel stochastic
    rounding: stored momentum is exactly bf16 and within ~1 bf16 ulp of the
    unfused f32 trajectory after a step."""
    s0, _ = _run("masked", "rigl", fused=False, state_dtype="bfloat16")
    s1, _ = _run("masked", "rigl", fused=True, state_dtype="bfloat16")
    for x in jax.tree_util.tree_leaves(s1["opt"]["momentum"]):
        assert x.dtype == jnp.bfloat16
    # both sides round to the bf16 grid (nearest vs stochastic), so they
    # agree to roughly one bf16 ulp of the largest momentum entry
    mref = max(
        float(jnp.max(jnp.abs(x.astype(jnp.float32))))
        for x in jax.tree_util.tree_leaves(s0["opt"]["momentum"])
    )
    assert _maxdiff(s0["opt"]["momentum"], s1["opt"]["momentum"]) < 2e-2 * max(
        mref, 1e-3
    )


@pytest.mark.parametrize(
    "opt_kw,needle",
    [
        (dict(kind="adam"), "sgd"),
        (dict(nesterov=True), "nesterov"),
        (dict(grad_clip=1.0), "grad_clip"),
    ],
)
def test_fused_rejects_unsupported_optimizer(opt_kw, needle):
    cfg = get_config("h2o-danube-1.8b", smoke=True)
    cfg = dataclasses.replace(
        cfg, dtype="float32", sparse=_sp("masked", "rigl", True)
    )
    opt = OptConfig(**{"kind": "sgd", "grad_clip": 0.0, **opt_kw})
    lr = LRSchedule(base_lr=3e-3, warmup_steps=0, total_steps=10)
    with pytest.raises(ValueError, match=needle):
        make_train_step(cfg, opt, lr)


def test_fused_rejects_snfs_microbatches_and_dense_kernel():
    lr = LRSchedule(base_lr=3e-3, warmup_steps=0, total_steps=10)
    opt = OptConfig(kind="sgd", grad_clip=0.0)
    base = get_config("h2o-danube-1.8b", smoke=True)
    base = dataclasses.replace(base, dtype="float32")

    cfg = dataclasses.replace(base, sparse=_sp("masked", "snfs", True))
    with pytest.raises(ValueError, match="snfs"):
        make_train_step(cfg, opt, lr)

    cfg = dataclasses.replace(
        base, microbatches=2, sparse=_sp("masked", "rigl", True)
    )
    with pytest.raises(ValueError, match="microbatches"):
        make_train_step(cfg, opt, lr)

    cfg = dataclasses.replace(base, sparse=_sp("dense", "rigl", True))
    with pytest.raises(ValueError):  # validate_sparse_kernel
        make_train_step(cfg, opt, lr)


def test_fused_rejects_bf16_compute_with_f32_state():
    """bf16 compute stores the cotangent in bf16 — only legal when the
    momentum state opts in to bf16 (stochastic rounding); f32 state would
    silently nearest-round the whole optimizer trajectory."""
    lr = LRSchedule(base_lr=3e-3, warmup_steps=0, total_steps=10)
    opt = OptConfig(kind="sgd", grad_clip=0.0, state_dtype="float32")
    cfg = get_config("h2o-danube-1.8b", smoke=True)
    cfg = dataclasses.replace(
        cfg, dtype="bfloat16", sparse=_sp("masked", "rigl", True)
    )
    with pytest.raises(ValueError, match="state_dtype"):
        make_train_step(cfg, opt, lr)
    # the same combo with bf16 state is accepted (SR mode)
    opt_sr = dataclasses.replace(opt, state_dtype="bfloat16")
    make_train_step(cfg, opt_sr, lr)

"""Pallas kernels vs pure-jnp oracles (interpret mode on CPU): shape/dtype
sweeps + hypothesis mask patterns."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ref
from repro.kernels.ops import block_sparse_linear, masked_linear, topk_threshold

SHAPES = [(128, 128, 128), (256, 384, 128), (128, 512, 256)]
DTYPES = [jnp.float32, jnp.bfloat16]


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_masked_matmul_sweep(shape, dtype):
    M, K, N = shape
    key = jax.random.PRNGKey(hash(shape) % 2**31)
    x = jax.random.normal(key, (M, K)).astype(dtype)
    w = jax.random.normal(jax.random.fold_in(key, 1), (K, N)).astype(dtype)
    m = jax.random.uniform(jax.random.fold_in(key, 2), (K, N)) > 0.8
    out = masked_linear(x, w, m, interpret=True)
    expect = ref.masked_matmul_ref(x, w, m)
    tol = 2e-5 * K if dtype == jnp.float32 else 2e-2 * np.sqrt(K)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(expect, np.float32), atol=tol
    )


@pytest.mark.parametrize("density", [0.0, 0.25, 0.75, 1.0])
def test_block_sparse_matmul_densities(density):
    M, K, N, bk, bn = 128, 512, 256, 128, 128
    key = jax.random.PRNGKey(3)
    x = jax.random.normal(key, (M, K), jnp.float32)
    w = jax.random.normal(jax.random.fold_in(key, 1), (K, N), jnp.float32)
    bm = jax.random.uniform(jax.random.fold_in(key, 2), (K // bk, N // bn)) < density
    out = block_sparse_linear(x, w, bm, interpret=True)
    expect = ref.block_sparse_matmul_ref(x, w, bm, bk, bn)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), atol=1e-3)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_property_block_sparse_random_masks(seed):
    M, K, N, bk, bn = 128, 256, 256, 128, 128
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (M, K), jnp.float32)
    w = jax.random.normal(jax.random.fold_in(key, 1), (K, N), jnp.float32)
    bm = jax.random.uniform(jax.random.fold_in(key, 2), (K // bk, N // bn)) < 0.5
    out = block_sparse_linear(x, w, bm, interpret=True)
    expect = ref.block_sparse_matmul_ref(x, w, bm, bk, bn)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), atol=1e-3)


@pytest.mark.parametrize("n,k", [(65536, 1000), (100_000, 5000), (200_000, 100)])
def test_topk_threshold_accuracy(n, k):
    x = jax.random.normal(jax.random.PRNGKey(0), (n,), jnp.float32)
    t = topk_threshold(x, k, interpret=True)
    cnt = int(jnp.sum(jnp.abs(x) >= t))
    assert abs(cnt - k) <= max(0.05 * k, 8), (cnt, k)
    exact = float(ref.kth_value_ref(x, k))
    assert abs(float(t) - exact) < 0.05 * max(exact, 1e-3)


def test_topk_threshold_matches_rigl_drop():
    """The kernel's threshold reproduces the exact-rank drop decision for
    all but a ~1% boundary band (RigL is robust to that)."""
    x = jax.random.normal(jax.random.PRNGKey(7), (50_000,), jnp.float32)
    k = 10_000
    t = topk_threshold(x, k, interpret=True)
    kernel_keep = np.asarray(jnp.abs(x) >= t)
    exact_keep = np.zeros(50_000, bool)
    exact_keep[np.argsort(-np.abs(np.asarray(x)))[:k]] = True
    disagree = (kernel_keep != exact_keep).mean()
    assert disagree < 0.02


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("shape", [(2, 256, 64), (4, 128, 128)])
def test_flash_attention_vs_ref(causal, shape):
    from repro.kernels.flash_attention import flash_attention

    BH, S, d = shape
    key = jax.random.PRNGKey(11)
    q = jax.random.normal(key, shape, jnp.float32)
    k = jax.random.normal(jax.random.fold_in(key, 1), shape, jnp.float32)
    v = jax.random.normal(jax.random.fold_in(key, 2), shape, jnp.float32)
    out = flash_attention(q, k, v, causal=causal, bq=128, bk=128, interpret=True)
    expect = ref.flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), atol=2e-5)


def test_flash_attention_bf16():
    from repro.kernels.flash_attention import flash_attention

    key = jax.random.PRNGKey(12)
    shape = (2, 256, 64)
    q = jax.random.normal(key, shape).astype(jnp.bfloat16)
    k = jax.random.normal(jax.random.fold_in(key, 1), shape).astype(jnp.bfloat16)
    v = jax.random.normal(jax.random.fold_in(key, 2), shape).astype(jnp.bfloat16)
    out = flash_attention(q, k, v, interpret=True)
    expect = ref.flash_attention_ref(q, k, v)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(expect, np.float32), atol=3e-2
    )

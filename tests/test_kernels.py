"""Pallas kernels vs pure-jnp oracles (interpret mode on CPU): shape/dtype
sweeps + hypothesis mask patterns."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hyp import given, settings, st

from repro.kernels import ref
from repro.kernels.ops import block_sparse_linear, masked_linear, topk_threshold

pytestmark = pytest.mark.kernels

SHAPES = [(128, 128, 128), (256, 384, 128), (128, 512, 256)]
DTYPES = [jnp.float32, jnp.bfloat16]


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_masked_matmul_sweep(shape, dtype):
    M, K, N = shape
    key = jax.random.PRNGKey(hash(shape) % 2**31)
    x = jax.random.normal(key, (M, K)).astype(dtype)
    w = jax.random.normal(jax.random.fold_in(key, 1), (K, N)).astype(dtype)
    m = jax.random.uniform(jax.random.fold_in(key, 2), (K, N)) > 0.8
    out = masked_linear(x, w, m, interpret=True)
    expect = ref.masked_matmul_ref(x, w, m)
    tol = 2e-5 * K if dtype == jnp.float32 else 2e-2 * np.sqrt(K)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(expect, np.float32), atol=tol
    )


@pytest.mark.parametrize("density", [0.0, 0.25, 0.75, 1.0])
def test_block_sparse_matmul_densities(density):
    M, K, N, bk, bn = 128, 512, 256, 128, 128
    key = jax.random.PRNGKey(3)
    x = jax.random.normal(key, (M, K), jnp.float32)
    w = jax.random.normal(jax.random.fold_in(key, 1), (K, N), jnp.float32)
    bm = jax.random.uniform(jax.random.fold_in(key, 2), (K // bk, N // bn)) < density
    out = block_sparse_linear(x, w, bm, interpret=True)
    expect = ref.block_sparse_matmul_ref(x, w, bm, bk, bn)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), atol=1e-3)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_property_block_sparse_random_masks(seed):
    M, K, N, bk, bn = 128, 256, 256, 128, 128
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (M, K), jnp.float32)
    w = jax.random.normal(jax.random.fold_in(key, 1), (K, N), jnp.float32)
    bm = jax.random.uniform(jax.random.fold_in(key, 2), (K // bk, N // bn)) < 0.5
    out = block_sparse_linear(x, w, bm, interpret=True)
    expect = ref.block_sparse_matmul_ref(x, w, bm, bk, bn)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), atol=1e-3)


# ---------------------------------------------------------------------------
# backward kernels (custom VJP) vs jax.grad of the dense-masked reference
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(128, 128, 128), (256, 384, 128), (100, 64, 96)])
def test_masked_matmul_grad_vs_ref(shape):
    """jax.grad through the Pallas dgrad/wgrad kernels == grad of ref (1e-4);
    last shape exercises the non-aligned-M padding path."""
    M, K, N = shape
    key = jax.random.PRNGKey(1 + hash(shape) % 2**31)
    x = jax.random.normal(key, (M, K), jnp.float32)
    w = jax.random.normal(jax.random.fold_in(key, 1), (K, N), jnp.float32)
    m = jax.random.uniform(jax.random.fold_in(key, 2), (K, N)) > 0.8

    f_k = lambda x, w: jnp.sum(jnp.sin(masked_linear(x, w, m, interpret=True)))
    f_r = lambda x, w: jnp.sum(jnp.sin(ref.masked_matmul_ref(x, w, m)))
    gx_k, gw_k = jax.grad(f_k, argnums=(0, 1))(x, w)
    gx_r, gw_r = jax.grad(f_r, argnums=(0, 1))(x, w)
    np.testing.assert_allclose(np.asarray(gx_k), np.asarray(gx_r), atol=1e-4)
    np.testing.assert_allclose(np.asarray(gw_k), np.asarray(gw_r), atol=1e-4)
    # the wgrad kernel fuses g*m: cotangent is exactly zero off-mask
    assert float(jnp.max(jnp.abs(jnp.where(m, 0.0, gw_k)))) == 0.0


@pytest.mark.parametrize("density", [0.0, 0.3, 0.7])
def test_block_sparse_grad_vs_ref(density):
    M, K, N, bk, bn = 100, 256, 256, 64, 64
    key = jax.random.PRNGKey(17)
    x = jax.random.normal(key, (M, K), jnp.float32)
    w = jax.random.normal(jax.random.fold_in(key, 1), (K, N), jnp.float32)
    bm = jax.random.uniform(jax.random.fold_in(key, 2), (K // bk, N // bn)) < density
    dense_mask = jnp.repeat(jnp.repeat(bm, bk, axis=0), bn, axis=1)

    f_k = lambda x, w: jnp.sum(
        jnp.cos(block_sparse_linear(x, w, bm, block=(128, bn, bk), interpret=True))
    )
    f_r = lambda x, w: jnp.sum(jnp.cos(ref.block_sparse_matmul_ref(x, w, bm, bk, bn)))
    gx_k, gw_k = jax.grad(f_k, argnums=(0, 1))(x, w)
    gx_r, gw_r = jax.grad(f_r, argnums=(0, 1))(x, w)
    # rtol for f32 accumulation-order noise on O(10) grads over K=256
    np.testing.assert_allclose(
        np.asarray(gx_k), np.asarray(gx_r), rtol=1e-4, atol=1e-4
    )
    np.testing.assert_allclose(
        np.asarray(gw_k), np.asarray(gw_r), rtol=1e-4, atol=1e-4
    )
    # packed wgrad scatters ONLY active blocks; everything else exactly zero
    assert float(jnp.max(jnp.abs(jnp.where(dense_mask, 0.0, gw_k)))) == 0.0


def test_block_sparse_grad_traced_mask_under_jit():
    """Training hot path: the block mask is a traced array inside jit."""
    K, N, bk, bn = 128, 128, 32, 32
    key = jax.random.PRNGKey(23)
    x = jax.random.normal(key, (64, K), jnp.float32)
    w = jax.random.normal(jax.random.fold_in(key, 1), (K, N), jnp.float32)
    bm = jax.random.uniform(jax.random.fold_in(key, 2), (K // bk, N // bn)) < 0.5

    gfn = jax.jit(
        jax.grad(
            lambda w, bmask: jnp.sum(
                block_sparse_linear(x, w, bmask, block=(128, bn, bk), interpret=True)
            )
        )
    )
    gw = gfn(w, bm)
    gr = jax.grad(
        lambda w: jnp.sum(ref.block_sparse_matmul_ref(x, w, bm, bk, bn))
    )(w)
    np.testing.assert_allclose(np.asarray(gw), np.asarray(gr), atol=1e-4)


def test_masked_linear_nonaligned_forward():
    """Satellite: odd batch*seq (and odd K/N) pad/trim instead of asserting."""
    key = jax.random.PRNGKey(5)
    for (M, K, N) in [(4, 128, 128), (100, 100, 200), (129, 64, 96)]:
        x = jax.random.normal(key, (M, K), jnp.float32)
        w = jax.random.normal(jax.random.fold_in(key, 1), (K, N), jnp.float32)
        m = jax.random.uniform(jax.random.fold_in(key, 2), (K, N)) > 0.5
        out = masked_linear(x, w, m, interpret=True)
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(ref.masked_matmul_ref(x, w, m)), atol=1e-3
        )


def test_block_sparse_linear_nonaligned_m():
    key = jax.random.PRNGKey(6)
    K, N, bk, bn = 256, 128, 64, 64
    x = jax.random.normal(key, (2, 25, K), jnp.float32)  # M=50, not 128-aligned
    w = jax.random.normal(jax.random.fold_in(key, 1), (K, N), jnp.float32)
    bm = jax.random.uniform(jax.random.fold_in(key, 2), (K // bk, N // bn)) < 0.5
    out = block_sparse_linear(x, w, bm, block=(128, bn, bk), interpret=True)
    expect = ref.block_sparse_matmul_ref(x.reshape(-1, K), w, bm, bk, bn)
    np.testing.assert_allclose(
        np.asarray(out).reshape(-1, N), np.asarray(expect), atol=1e-3
    )


def test_pack_block_mask_vectorized_semantics():
    """The argsort pack reproduces the per-column loop semantics exactly."""
    from repro.kernels.block_sparse_matmul import (
        pack_block_mask, pack_block_mask_rows, pack_block_mask_traced)

    rng = np.random.RandomState(0)
    for _ in range(20):
        bm = rng.rand(rng.randint(1, 9), rng.randint(1, 9)) < rng.rand()
        idx, cnt = pack_block_mask(bm)
        idx, cnt = np.asarray(idx), np.asarray(cnt)
        assert idx.shape == (bm.shape[1], max(int(bm.sum(0).max(initial=0)), 1))
        for j in range(bm.shape[1]):
            act = np.nonzero(bm[:, j])[0]
            assert cnt[j] == len(act)
            np.testing.assert_array_equal(idx[j, : len(act)], act)
            assert (idx[j, len(act):] == 0).all()
        # CSR rows pack == CSC pack of the transpose
        ridx, rcnt = pack_block_mask_rows(bm)
        idx_t, cnt_t = pack_block_mask(bm.T)
        np.testing.assert_array_equal(np.asarray(ridx), np.asarray(idx_t))
        np.testing.assert_array_equal(np.asarray(rcnt), np.asarray(cnt_t))
        # traced variant agrees on the shared (padded) prefix
        jidx, jcnt = pack_block_mask_traced(jnp.asarray(bm))
        np.testing.assert_array_equal(np.asarray(jcnt), cnt)
        np.testing.assert_array_equal(
            np.asarray(jidx)[:, : idx.shape[1]], idx
        )


@pytest.mark.parametrize("n,k", [(65536, 1000), (100_000, 5000), (200_000, 100)])
def test_topk_threshold_accuracy(n, k):
    x = jax.random.normal(jax.random.PRNGKey(0), (n,), jnp.float32)
    t = topk_threshold(x, k, interpret=True)
    cnt = int(jnp.sum(jnp.abs(x) >= t))
    assert abs(cnt - k) <= max(0.05 * k, 8), (cnt, k)
    exact = float(ref.kth_value_ref(x, k))
    assert abs(float(t) - exact) < 0.05 * max(exact, 1e-3)


def test_topk_threshold_matches_rigl_drop():
    """The kernel's threshold reproduces the exact-rank drop decision for
    all but a ~1% boundary band (RigL is robust to that)."""
    x = jax.random.normal(jax.random.PRNGKey(7), (50_000,), jnp.float32)
    k = 10_000
    t = topk_threshold(x, k, interpret=True)
    kernel_keep = np.asarray(jnp.abs(x) >= t)
    exact_keep = np.zeros(50_000, bool)
    exact_keep[np.argsort(-np.abs(np.asarray(x)))[:k]] = True
    disagree = (kernel_keep != exact_keep).mean()
    assert disagree < 0.02


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("shape", [(2, 256, 64), (4, 128, 128)])
def test_flash_attention_vs_ref(causal, shape):
    from repro.kernels.flash_attention import flash_attention

    BH, S, d = shape
    key = jax.random.PRNGKey(11)
    q = jax.random.normal(key, shape, jnp.float32)
    k = jax.random.normal(jax.random.fold_in(key, 1), shape, jnp.float32)
    v = jax.random.normal(jax.random.fold_in(key, 2), shape, jnp.float32)
    out = flash_attention(q, k, v, causal=causal, bq=128, bk=128, interpret=True)
    expect = ref.flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), atol=2e-5)


def test_flash_attention_bf16():
    from repro.kernels.flash_attention import flash_attention

    key = jax.random.PRNGKey(12)
    shape = (2, 256, 64)
    q = jax.random.normal(key, shape).astype(jnp.bfloat16)
    k = jax.random.normal(jax.random.fold_in(key, 1), shape).astype(jnp.bfloat16)
    v = jax.random.normal(jax.random.fold_in(key, 2), shape).astype(jnp.bfloat16)
    out = flash_attention(q, k, v, interpret=True)
    expect = ref.flash_attention_ref(q, k, v)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(expect, np.float32), atol=3e-2
    )

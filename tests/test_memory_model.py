"""Analytic HBM model sanity."""
from repro.analysis.memory_model import memory_model
from repro.configs import SHAPES, get_config


def _model(arch, shape, **kw):
    cfg = get_config(arch)
    n = 2e9 if "1" in arch else 1e9
    return memory_model(cfg, SHAPES[shape], {"data": 16, "model": 16},
                        n_params_total=n, n_sparsifiable=0.9 * n, **kw)


def test_train_has_state_terms():
    m = _model("h2o-danube-1.8b", "train_4k")
    for k in ("params", "opt_state", "grads", "masks_bool", "residual_saves"):
        assert k in m and m[k] > 0


def test_decode_has_kv_cache_not_opt():
    m = _model("h2o-danube-1.8b", "decode_32k")
    assert "kv_cache" in m and m["kv_cache"] > 0
    assert "opt_state" not in m


def test_windowed_cache_smaller_than_full():
    # danube (SWA-4096) cache at 32k must be ~8x smaller than a full cache
    swa = _model("h2o-danube-1.8b", "decode_32k")["kv_cache"]
    full = _model("qwen2-moe-a2.7b", "decode_32k")["kv_cache"]
    cfg_s = get_config("h2o-danube-1.8b")
    cfg_f = get_config("qwen2-moe-a2.7b")
    per_layer_s = swa / cfg_s.n_layers
    per_layer_f = full / cfg_f.n_layers
    assert per_layer_s < per_layer_f


def test_microbatching_shrinks_activations():
    import dataclasses
    cfg = get_config("mistral-large-123b")
    big = memory_model(dataclasses.replace(cfg, microbatches=1), SHAPES["train_4k"],
                       {"data": 16, "model": 16}, 1.23e11, 1.2e11)
    small = memory_model(cfg, SHAPES["train_4k"], {"data": 16, "model": 16},
                         1.23e11, 1.2e11)
    assert small["residual_saves"] < big["residual_saves"] / 8

"""Per-arch smoke tests: reduced config, one forward/train step, shapes + finite."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.configs.base import SparseConfig
from repro.core import mask_stats
from repro.data import batch_for
from repro.models import init_lm, lm_forward, lm_loss
from repro.optim import LRSchedule, OptConfig
from repro.training import init_train_state, make_train_step

B, S = 2, 64


def _batch(cfg, step=0):
    return batch_for(cfg, step, B, S, learnable=True)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_and_finite(arch):
    cfg = get_config(arch, smoke=True)
    params, axes, flags = init_lm(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg)
    h, _, aux = lm_forward(params, cfg, batch)
    assert h.shape[0] == B and h.shape[-1] == cfg.d_model
    assert bool(jnp.all(jnp.isfinite(h.astype(jnp.float32))))
    loss = lm_loss(params, cfg, batch)
    assert bool(jnp.isfinite(loss))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_one_sparse_train_step(arch):
    cfg = get_config(arch, smoke=True)
    cfg = dataclasses.replace(cfg, sparse=SparseConfig(sparsity=0.5))
    opt = OptConfig(kind="adam", weight_decay=0.0, grad_clip=1.0)
    state, _, _ = init_train_state(jax.random.PRNGKey(0), cfg, opt)
    step = jax.jit(make_train_step(cfg, opt, LRSchedule(base_lr=1e-3)))
    state, m = step(state, _batch(cfg))
    assert bool(jnp.isfinite(m["loss"]))
    assert int(state["step"]) == 1
    # masked weights stay masked after the optimizer step
    for p, msk in zip(
        jax.tree_util.tree_leaves(state["params"]),
        jax.tree_util.tree_leaves(
            jax.tree_util.tree_map(
                lambda x: x, state["masks"], is_leaf=lambda x: x is None
            )
        ),
    ):
        pass  # structural zip differs; checked in test_training_integration


@pytest.mark.parametrize("arch", ["h2o-danube-1.8b", "grok-1-314b", "xlstm-1.3b"])
def test_sparsity_respected(arch):
    cfg = get_config(arch, smoke=True)
    cfg = dataclasses.replace(cfg, sparse=SparseConfig(sparsity=0.75))
    state, _, _ = init_train_state(jax.random.PRNGKey(0), cfg, OptConfig())
    st = mask_stats(state["masks"])
    assert abs(st["sparsity"] - 0.75) < 0.02


def test_microbatch_equivalence():
    """mb>1 gradient accumulation == mb=1 (same math, chunked)."""
    cfg = get_config("h2o-danube-1.8b", smoke=True)
    cfg1 = dataclasses.replace(cfg, dtype="float32", microbatches=1,
                               sparse=SparseConfig(sparsity=0.5))
    cfg4 = dataclasses.replace(cfg1, microbatches=4)
    opt = OptConfig(kind="sgd", momentum=0.9, weight_decay=0.0)
    lr = LRSchedule(kind="constant", base_lr=1e-2, warmup_steps=0)
    batch = batch_for(cfg1, 0, 8, S, learnable=True)  # divisible by mb=4
    s1, _, _ = init_train_state(jax.random.PRNGKey(0), cfg1, opt)
    s4, _, _ = init_train_state(jax.random.PRNGKey(0), cfg4, opt)
    s1, m1 = jax.jit(make_train_step(cfg1, opt, lr))(s1, batch)
    s4, m4 = jax.jit(make_train_step(cfg4, opt, lr))(s4, batch)
    assert float(m1["loss"]) == pytest.approx(float(m4["loss"]), rel=1e-5)
    p1 = jax.tree_util.tree_leaves(s1["params"])
    p4 = jax.tree_util.tree_leaves(s4["params"])
    for a, b in zip(p1, p4):
        assert float(jnp.max(jnp.abs(a - b))) < 1e-5


def test_remat_group_matches_plain():
    cfg = get_config("gemma3-4b", smoke=True)
    base = dataclasses.replace(cfg, dtype="float32", remat=True)
    grouped = dataclasses.replace(base, remat_group=3)
    params, _, _ = init_lm(jax.random.PRNGKey(0), base)
    batch = _batch(base)
    l1 = lm_loss(params, base, batch)
    l2 = lm_loss(params, grouped, batch)
    assert float(l1) == pytest.approx(float(l2), rel=1e-6)
    g1 = jax.grad(lambda p: lm_loss(p, base, batch))(params)
    g2 = jax.grad(lambda p: lm_loss(p, grouped, batch))(params)
    for a, b in zip(jax.tree_util.tree_leaves(g1), jax.tree_util.tree_leaves(g2)):
        assert float(jnp.max(jnp.abs(a - b))) < 1e-5

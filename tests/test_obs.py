"""Observability tier: metrics/trace/export semantics + instrumentation.

What this tier pins (docs/observability.md):

  * registry semantics — counter monotonicity, histogram ``le`` bucket
    math, label-series memoization, idempotent registration with loud
    kind/schema mismatches;
  * export fidelity — the Prometheus text exposition ROUND-TRIPS (every
    rendered sample parses back to the exact value the registry held), the
    Chrome trace file is schema-valid for Perfetto, the ring truncates
    oldest-first without losing track-name metadata;
  * instrumentation honesty — a seeded virtual-clock engine run produces
    BIT-IDENTICAL metric snapshots and trace events across two runs
    (metrics as regression oracle, not just dashboard feed), quarantine
    instants mirror both ``engine.quarantine_log`` and the FaultInjector's
    fired log, and instrumentation never perturbs token streams.
"""
import dataclasses
import json
import math

import jax
import pytest

from repro.configs import get_config
from repro.models import init_lm
from repro.obs import (
    Counter,
    Gauge,
    Histogram,
    JsonlSink,
    MetricsRegistry,
    Observability,
    PeriodicFlusher,
    SpanTracer,
    exponential_buckets,
    median,
    median_by,
    parse_prometheus_text,
    percentile,
    prometheus_text,
    summarize,
)
from repro.serving import FaultInjector, ServeEngine, Status, burst_storm

pytestmark = pytest.mark.obs


# ---------------------------------------------------------------------------
# units: registry semantics
# ---------------------------------------------------------------------------


def test_counter_monotone():
    c = Counter()
    c.inc()
    c.inc(2.5)
    assert c.value == 3.5
    with pytest.raises(ValueError, match="negative"):
        c.inc(-1)


def test_gauge_set_inc_dec():
    g = Gauge()
    g.set(4.0)
    g.inc()
    g.dec(2.0)
    assert g.value == 3.0


def test_exponential_buckets_validation():
    assert exponential_buckets(1.0, 2.0, 3) == (1.0, 2.0, 4.0)
    for bad in [dict(start=0), dict(factor=1.0), dict(count=0)]:
        kw = dict(start=1e-3, factor=2.0, count=4)
        kw.update(bad)
        with pytest.raises(ValueError):
            exponential_buckets(**kw)


def test_histogram_le_bucket_semantics():
    h = Histogram(bounds=(1.0, 2.0, 4.0))
    # le (<=) semantics: a value ON a bound lands in that bound's bucket
    for v in [0.5, 1.0, 1.5, 2.0, 4.0, 9.0]:
        h.observe(v)
    assert h.counts == [2, 2, 1, 1]  # (..1], (1..2], (2..4], (4..inf)
    assert h.count == 6
    assert h.sum == pytest.approx(18.0)
    assert h.cumulative() == [(1.0, 2), (2.0, 4), (4.0, 5), (math.inf, 6)]


def test_histogram_bound_validation():
    with pytest.raises(ValueError):
        Histogram(bounds=())
    with pytest.raises(ValueError):
        Histogram(bounds=(1.0, 1.0))
    with pytest.raises(ValueError):
        Histogram(bounds=(1.0, math.inf))


def test_family_label_series_memoized():
    reg = MetricsRegistry()
    fam = reg.counter("reqs_total", "requests", labels=("status",))
    a = fam.labels("DONE")
    assert fam.labels("DONE") is a  # one child per label tuple, kept
    a.inc()
    fam.labels("SHED").inc(2)
    snap = reg.snapshot()["reqs_total"]
    assert snap["kind"] == "counter"
    assert [(s["labels"], s["value"]) for s in snap["series"]] == [
        ({"status": "DONE"}, 1.0),
        ({"status": "SHED"}, 2.0),
    ]
    with pytest.raises(ValueError, match="label"):
        fam.inc()  # label-free proxy is guarded on labeled families
    with pytest.raises(ValueError):
        fam.labels("a", "b")  # wrong arity


def test_registry_idempotent_and_loud_on_mismatch():
    reg = MetricsRegistry()
    a = reg.counter("x_total")
    assert reg.counter("x_total") is a  # get-or-create: two engines share
    with pytest.raises(ValueError, match="already registered"):
        reg.gauge("x_total")
    with pytest.raises(ValueError, match="already registered"):
        reg.counter("x_total", labels=("k",))
    with pytest.raises(ValueError, match="invalid metric name"):
        reg.counter("bad name")


# ---------------------------------------------------------------------------
# export: Prometheus round-trip, Chrome schema, ring, flusher
# ---------------------------------------------------------------------------


def test_prometheus_round_trip():
    reg = MetricsRegistry()
    reg.counter("reqs_total", "terminal requests", labels=("status",))
    reg.get("reqs_total").labels("DONE").inc(7)
    reg.get("reqs_total").labels('weird "quoted"\nvalue').inc()
    reg.gauge("occupancy", "slots").set(3)          # integer renders bare
    reg.gauge("ratio").set(0.1 + 0.2)               # float must round-trip
    reg.gauge("edge").set(math.inf)
    h = reg.histogram("wait_seconds", "queue wait", buckets=(0.1, 1.0))
    for v in [0.05, 0.1, 0.5, 30.0]:
        h.observe(v)

    text = prometheus_text(reg.snapshot())
    parsed = parse_prometheus_text(text)

    assert parsed["#types"] == {
        "reqs_total": "counter", "occupancy": "gauge", "ratio": "gauge",
        "edge": "gauge", "wait_seconds": "histogram",
    }
    assert parsed["reqs_total"][frozenset({("status", "DONE")})] == 7
    assert parsed["reqs_total"][
        frozenset({("status", 'weird "quoted"\nvalue')})
    ] == 1
    assert parsed["occupancy"][frozenset()] == 3
    assert parsed["ratio"][frozenset()] == 0.1 + 0.2  # exact, not approx
    assert parsed["edge"][frozenset()] == math.inf
    # cumulative buckets match Histogram.cumulative exactly
    buckets = parsed["wait_seconds_bucket"]
    assert buckets[frozenset({("le", "0.1")})] == 2
    assert buckets[frozenset({("le", "1")})] == 3
    assert buckets[frozenset({("le", "+Inf")})] == 4
    assert parsed["wait_seconds_count"][frozenset()] == 4
    assert parsed["wait_seconds_sum"][frozenset()] == pytest.approx(30.65)
    # integers render bare ('3', not '3.0') — what real exporters emit
    assert "occupancy 3\n" in text


def test_chrome_trace_schema(tmp_path):
    tr = SpanTracer(pid=0, process_name="serve")
    tr.thread_name(0, "engine")
    tr.thread_name(1, "slot0")
    tr.span("prefill", 1.5, 2.5, tid=1, cat="serve", args={"rid": 0})
    tr.span("clamped", 2.0, 1.0)  # inverted interval clamps to dur=0
    tr.instant("quarantine", 3.0, tid=1, cat="chaos")
    tr.counter("occupancy", 3.0, {"active": 2})
    path = tmp_path / "trace.json"
    tr.to_chrome(path)

    doc = json.loads(path.read_text())
    assert doc["displayTimeUnit"] == "ms"
    evs = doc["traceEvents"]
    # metadata first (viewers see names before the events that use them)
    assert [e["name"] for e in evs[:3]] == [
        "process_name", "thread_name", "thread_name"
    ]
    for e in evs:
        assert e["ph"] in {"X", "i", "C", "M"}
        assert isinstance(e["pid"], int) and isinstance(e["tid"], int)
        if e["ph"] != "M":
            assert isinstance(e["ts"], int) and e["ts"] >= 0
    span = next(e for e in evs if e["name"] == "prefill")
    assert span["ts"] == 1_500_000 and span["dur"] == 1_000_000  # µs ints
    assert next(e for e in evs if e["name"] == "clamped")["dur"] == 0
    inst = next(e for e in evs if e["name"] == "quarantine")
    assert inst["ph"] == "i" and inst["s"] == "t"


def test_ring_truncates_oldest_keeps_metadata():
    tr = SpanTracer(capacity=4, process_name="serve")
    tr.thread_name(0, "engine")
    for i in range(10):
        tr.instant(f"ev{i}", float(i))
    assert tr.n_emitted == 10 and tr.n_dropped == 6
    assert [e["name"] for e in tr.events] == ["ev6", "ev7", "ev8", "ev9"]
    # metadata rows are exempt from the ring — track names survive eviction
    names = [e["name"] for e in tr.chrome_events()]
    assert names[:2] == ["process_name", "thread_name"]


def test_periodic_flusher_rate_limit_and_incremental_sink(tmp_path):
    reg = MetricsRegistry()
    reg.counter("x_total").inc()
    tr = SpanTracer()
    for i in range(3):
        tr.instant(f"a{i}", float(i))
    fl = PeriodicFlusher(
        registry=reg, tracer=tr,
        metrics_path=tmp_path / "m.prom", trace_path=tmp_path / "t.json",
        events_path=tmp_path / "e.jsonl", interval=5.0,
    )
    assert fl.maybe_flush(0.0) is True
    assert fl.maybe_flush(3.0) is False  # inside the interval: rate-limited
    tr.instant("b", 4.0)
    assert fl.maybe_flush(6.0) is True
    fl.close(now=6.0)

    # sink got each event exactly once (incremental via n_emitted deltas)
    lines = (tmp_path / "e.jsonl").read_text().splitlines()
    assert [json.loads(l)["name"] for l in lines] == ["a0", "a1", "a2", "b"]
    parsed = parse_prometheus_text((tmp_path / "m.prom").read_text())
    assert parsed["x_total"][frozenset()] == 1
    assert json.loads((tmp_path / "t.json").read_text())["traceEvents"]


def test_jsonl_sink_appends(tmp_path):
    p = tmp_path / "nested" / "events.jsonl"  # parents created
    with JsonlSink(p) as s:
        s.write({"a": 1})
    with JsonlSink(p) as s:  # reopen appends, never truncates
        s.write({"b": 2})
    assert [json.loads(l) for l in p.read_text().splitlines()] == [
        {"a": 1}, {"b": 2}
    ]


# ---------------------------------------------------------------------------
# stats_util: empty-population safety, shared percentile math
# ---------------------------------------------------------------------------


def test_stats_util_empty_safe():
    assert percentile([], 50) == 0.0
    assert median([]) == 0.0
    s = summarize([])
    assert s["n"] == 0 and s["mean"] == 0.0 and s["p95"] == 0.0


def test_stats_util_values():
    xs = [3.0, 1.0, 2.0, 4.0]
    assert median(xs) == 2.5
    s = summarize(xs, qs=(50,))
    assert s == {"n": 4, "mean": 2.5, "min": 1.0, "max": 4.0,
                 "p50": pytest.approx(2.5)}
    runs = [{"tok_per_s": t} for t in (5.0, 1.0, 3.0, 4.0)]
    # even count takes the upper-middle run (matches serve_bench's median)
    assert median_by(runs, "tok_per_s")["tok_per_s"] == 4.0


# ---------------------------------------------------------------------------
# instrumented engine: determinism, correlation, zero perturbation
# ---------------------------------------------------------------------------


def _cfg():
    return dataclasses.replace(
        get_config("h2o-danube-1.8b", smoke=True), dtype="float32"
    )


def _drain(engine, dt=1.0, max_steps=2000):
    now = 0.0
    for _ in range(max_steps):
        if not (len(engine.queue) or engine.active.any()):
            return now
        engine.step(now)
        now += dt
    raise AssertionError("engine failed to drain")


def _streams(engine):
    return {r.rid: list(r.generated) for r in engine.queue.done
            if r.status is Status.DONE}


@pytest.fixture(scope="module")
def served():
    """(cfg, params) with every jit this module dispatches already warm, so
    the seeded-determinism runs see flat retrace counters."""
    cfg = _cfg()
    params, _, _ = init_lm(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(cfg, params, capacity=3, max_len=32)
    for r in burst_storm(cfg, 4, prompt_len=8, max_new_tokens=6):
        eng.submit(r)
    _drain(eng)
    return cfg, params


def _obs_run(cfg, params, *, n=4, **kw):
    obs = Observability(metrics=MetricsRegistry(), process_name="serve")
    eng = ServeEngine(cfg, params, capacity=3, max_len=32, obs=obs, **kw)
    for r in burst_storm(cfg, n, prompt_len=8, max_new_tokens=6):
        eng.submit(r)
    _drain(eng)
    return obs, eng


def test_metrics_deterministic_across_seeded_runs(served):
    cfg, params = served
    obs1, eng1 = _obs_run(cfg, params)
    obs2, eng2 = _obs_run(cfg, params)
    # the whole snapshot — counters, gauges AND timing histograms — is
    # bit-identical under the virtual clock: metrics as regression oracle
    assert obs1.metrics.snapshot() == obs2.metrics.snapshot()
    assert obs1.trace.chrome_events() == obs2.trace.chrome_events()
    assert _streams(eng1) == _streams(eng2)
    done = obs1.metrics.get("serve_requests_total").labels("DONE")
    assert done.value == 4.0
    tokens = obs1.metrics.get("serve_tokens_total")._default().value
    assert tokens == sum(len(s) for s in _streams(eng1).values())


def test_instrumentation_never_perturbs_streams(served):
    cfg, params = served
    bare = ServeEngine(cfg, params, capacity=3, max_len=32)
    for r in burst_storm(cfg, 4, prompt_len=8, max_new_tokens=6):
        bare.submit(r)
    _drain(bare)
    _, inst = _obs_run(cfg, params)
    assert _streams(bare) == _streams(inst)


def test_quarantine_trace_matches_injector_and_books(served):
    cfg, params = served
    # capacity 3, burst of 6: rids 0-2 hold slots 0-2 at step 2, so the
    # poisoning deterministically hits rid 0 (tests/test_serving_faults.py)
    inj = FaultInjector().poison_logits(step=2, slot=0)
    obs, eng = _obs_run(cfg, params, n=6, faults=inj, max_retries=0)

    assert eng.quarantine_log == [(2, 0, 0, 0, "decode")]
    quar = obs.trace.find("quarantine")
    assert [
        (e["args"]["step"], e["args"]["rid"], e["args"]["slot"],
         e["args"]["attempt"], e["args"]["where"])
        for e in quar
    ] == [tuple(q) for q in eng.quarantine_log]
    assert quar[0]["tid"] == 0 + 1  # slot s annotates on track s+1
    fired = obs.trace.find("fault_injected")
    assert [(e["args"]["step"], e["args"]["targeted"]) for e in fired] == [
        (step, list(plan)) for kind, step, plan in inj.log if kind == "decode"
    ]
    assert fired[0]["args"]["active"] == [{"slot": 0, "rid": 0, "attempt": 0}]
    snap = obs.metrics.snapshot()["serve_quarantine_total"]
    assert [(s["labels"], s["value"]) for s in snap["series"]] == [
        ({"where": "decode"}, 1.0), ({"where": "prefill"}, 0.0),
    ]


def test_stats_n_retraces_flat_when_warm(served):
    cfg, params = served
    _, eng = _obs_run(cfg, params)
    stats = eng.stats(0.0)
    # every shape this workload dispatches was compiled by the fixture:
    # steady-state traffic must not climb the retrace counter
    assert stats["n_retraces"] == 0
    gauge = eng.obs.metrics.get("serve_retraces")._default()
    assert gauge.value == 0.0

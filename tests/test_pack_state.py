"""PackState (core/pack.py): host-packed tight-grid block topology.

Covers the lifecycle documented in docs/kernels.md: build at init, bit-exact
equivalence of tight vs padded grids, refresh-on-topology-update, checkpoint
round-trip, decode-path pack reuse, and the loud error/staleness guards.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import restore, save
from repro.configs import get_config
from repro.configs.base import SparseConfig
from repro.core import block_mask_of, tree_paths
from repro.core.pack import (
    build_pack_state,
    is_pack_entry,
    pack_entry,
    pack_mismatch,
    pack_stats,
    refresh_pack_state,
    slack_width,
)
from repro.data import batch_for
from repro.kernels.block_sparse_matmul import (
    pack_block_mask,
    pack_block_mask_rows,
)
from repro.models import lm_decode, lm_forward, lm_prefill
from repro.optim import LRSchedule, OptConfig
from repro.training import (
    init_train_state,
    make_algo,
    make_rigl_step,
    make_train_step,
    refresh_pack,
)

pytestmark = pytest.mark.kernels

BLOCK = 16


def _cfg(sparsity=0.8):
    cfg = get_config("h2o-danube-1.8b", smoke=True)
    sp = SparseConfig(
        sparsity=sparsity, method="rigl", delta_t=10, alpha=0.3,
        kernel="block_sparse", block_shape=(BLOCK, BLOCK),
        kernel_block=(128, BLOCK, BLOCK),
    )
    return dataclasses.replace(cfg, dtype="float32", sparse=sp)


@pytest.fixture(scope="module")
def state():
    cfg = _cfg()
    st, _, _ = init_train_state(
        jax.random.PRNGKey(0), cfg, OptConfig(kind="adam")
    )
    return cfg, st


# ---------------------------------------------------------------------------
# build: entries match the host pack of each layer's block mask, widths tight
# ---------------------------------------------------------------------------

def test_build_matches_per_layer_host_pack(state):
    cfg, st = state
    assert "pack" in st
    flat_m = tree_paths(st["masks"])
    # tree_paths would flatten INTO the entry dicts; flatten with entries as
    # leaves instead so names align with the mask leaf names
    flat_entries, _ = jax.tree_util.tree_flatten_with_path(
        st["pack"], is_leaf=is_pack_entry
    )
    from repro.core.masks import path_name

    entries = {path_name(p): e for p, e in flat_entries}
    n_packed = 0
    for name, m in flat_m.items():
        e = entries[name]
        if m is None:
            assert e is None
            continue
        bm = np.asarray(block_mask_of(np.asarray(m, bool), (BLOCK, BLOCK)))
        idx_ref, cnt_ref = pack_block_mask(bm)
        ridx_ref, rcnt_ref = pack_block_mask_rows(bm)
        assert int(e["nnz"]) == int(bm.sum())
        assert int(e["nkb"]) == bm.shape[0]
        np.testing.assert_array_equal(np.asarray(e["cnt"]), np.asarray(cnt_ref))
        # widths are TIGHT: exactly the max per-column/row count, not the
        # worst case — both the fwd/wgrad (CSC) and dgrad (CSR) grids
        assert e["idx"].shape[1] == int(np.asarray(cnt_ref).max())
        np.testing.assert_array_equal(np.asarray(e["idx"]), np.asarray(idx_ref))
        assert e["ridx"].shape[1] == int(np.asarray(rcnt_ref).max())
        np.testing.assert_array_equal(np.asarray(e["ridx"]), np.asarray(ridx_ref))
        np.testing.assert_array_equal(np.asarray(e["rcnt"]), np.asarray(rcnt_ref))
        n_packed += 1
    assert n_packed > 0
    # at 80% block sparsity the summed grid widths must be far below padded
    stats = pack_stats(st["pack"])
    assert stats["grid_iters_tight"] < stats["grid_iters_padded"]
    assert pack_mismatch(st["masks"], st["pack"], (BLOCK, BLOCK)) == 0


# ---------------------------------------------------------------------------
# equivalence: tight grids == padded grids, bit-identical, fwd and grads
# ---------------------------------------------------------------------------

def test_tight_equals_padded_bitexact_under_jit(state):
    cfg, st = state
    b = batch_for(cfg, 0, 2, 32, learnable=True)
    # masks passed as jit args are tracers => the no-pack path uses the
    # traced, worst-case-padded pack; the pack path uses the tight grids
    h_tight = jax.jit(
        lambda p, m, pk: lm_forward(p, cfg, b, masks=m, pack=pk)[0]
    )(st["params"], st["masks"], st["pack"])
    h_padded = jax.jit(lambda p, m: lm_forward(p, cfg, b, masks=m)[0])(
        st["params"], st["masks"]
    )
    np.testing.assert_array_equal(np.asarray(h_tight), np.asarray(h_padded))


def test_tight_grads_match_padded(state):
    from repro.models import lm_loss

    cfg, st = state
    b = batch_for(cfg, 0, 2, 32, learnable=True)
    g_tight = jax.jit(
        jax.grad(lambda p: lm_loss(p, cfg, b, masks=st["masks"], pack=st["pack"]))
    )(st["params"])
    g_padded = jax.jit(
        jax.grad(lambda p: lm_loss(p, cfg, b, masks=st["masks"]))
    )(st["params"])
    ft, fp = tree_paths(g_tight), tree_paths(g_padded)
    fm = tree_paths(st["masks"])
    fb = tree_paths(st["bwd_masks"]) if "bwd_masks" in st else {}
    for name in ft:
        got, want = np.asarray(ft[name]), np.asarray(fp[name])
        mk = fm.get(name)
        if mk is not None:
            # the tight pack carries the backward superset B: its wgrad is
            # B-supported, while the padded no-pack path stays A-restricted.
            # The grids must agree on A; outside B the tight grad is zero.
            m = np.asarray(mk, bool)
            bw = fb.get(name)
            assert bw is not None, f"{name}: superset mask missing"
            assert np.all(got[~np.asarray(bw, bool)] == 0.0), name
            got, want = got * m, want * m
        np.testing.assert_allclose(
            got, want, rtol=1e-5, atol=1e-6, err_msg=name,
        )


# ---------------------------------------------------------------------------
# refresh on topology update
# ---------------------------------------------------------------------------

def test_refresh_after_rigl_update_restores_sync():
    cfg = _cfg()
    opt = OptConfig(kind="adam", weight_decay=0.0, grad_clip=1.0)
    lr = LRSchedule(base_lr=3e-3, warmup_steps=2, total_steps=30)
    st, _, _ = init_train_state(jax.random.PRNGKey(1), cfg, opt)
    algo = make_algo(cfg, 30)
    train = jax.jit(make_train_step(cfg, opt, lr))
    rigl = jax.jit(make_rigl_step(cfg, algo, lr))

    b = batch_for(cfg, 0, 2, 32, learnable=True)
    st, m = train(st, b)
    assert int(m["pack_stale"]) == 0
    st, _ = rigl(st, batch_for(cfg, 1, 2, 32, learnable=True))
    # topology moved, pack not yet refreshed: the canary must fire
    stale = int(pack_mismatch(st["masks"], st["pack"], (BLOCK, BLOCK)))
    assert stale > 0, "rigl moved no blocks — test cfg too static"
    st = refresh_pack(st, cfg)
    assert int(pack_mismatch(st["masks"], st["pack"], (BLOCK, BLOCK))) == 0
    st, m = train(st, batch_for(cfg, 2, 2, 32, learnable=True))
    assert int(m["pack_stale"]) == 0
    assert np.isfinite(float(m["loss"]))


def test_refresh_widths_never_shrink(state):
    cfg, st = state
    pack2 = refresh_pack_state(
        st["masks"], (BLOCK, BLOCK), prev=st["pack"]
    )
    flat1 = jax.tree_util.tree_leaves(st["pack"], is_leaf=is_pack_entry)
    flat2 = jax.tree_util.tree_leaves(pack2, is_leaf=is_pack_entry)
    for e1, e2 in zip(flat1, flat2):
        if e1 is None:
            continue
        assert e2["idx"].shape[1] >= e1["idx"].shape[1]
        assert e2["ridx"].shape[1] >= e1["ridx"].shape[1]


# ---------------------------------------------------------------------------
# width hysteresis (SparseConfig.pack_width_slack)
# ---------------------------------------------------------------------------

def test_slack_width_rounds_up_never_down():
    assert slack_width(3, 16, 0.0) == 3  # slack off: exact tight width
    assert slack_width(3, 16, 0.25) == 4  # step = ceil(.25*16) = 4
    assert slack_width(4, 16, 0.25) == 4
    assert slack_width(5, 16, 0.25) == 8
    assert slack_width(15, 16, 0.25) == 16
    assert slack_width(16, 16, 0.25) == 16  # capped at the worst case
    assert slack_width(1, 7, 0.5) == 4  # step = ceil(.5*7) = 4
    for w in range(1, 17):
        for s in (0.0, 0.1, 0.25, 0.5, 1.0):
            out = slack_width(w, 16, s)
            assert w <= out <= 16  # never down, never past worst case


def test_slack_reduces_retraces_on_drifting_topology():
    """Regression for the ROADMAP width-hysteresis item: over a refresh
    sequence whose per-column max drifts by one block at a time, slacked
    widths change (=> the jitted step retraces) strictly fewer times."""
    rng = np.random.RandomState(0)
    nkb, ncols = 16, 8

    def drifting_masks(steps):
        # start sparse, drift the per-column count upward one wiggle at a time
        bm = rng.rand(nkb, ncols) < 0.15
        bm[0, 0] = True
        seq = []
        for _ in range(steps):
            j = rng.randint(ncols)
            zeros = np.flatnonzero(~bm[:, j])
            if len(zeros):
                bm[zeros[rng.randint(len(zeros))], j] = True
            seq.append(bm.copy())
        return seq

    seq = drifting_masks(12)
    mask_seq = [np.repeat(np.repeat(b, BLOCK, 0), BLOCK, 1) for b in seq]

    def count_retraces(slack):
        shapes, prev = [], None
        for m in mask_seq:
            e = pack_entry(
                m, (BLOCK, BLOCK), slack=slack,
                min_width=0 if prev is None else prev["idx"].shape[-1],
                min_row_width=0 if prev is None else prev["ridx"].shape[-1],
            )
            shapes.append((e["idx"].shape, e["ridx"].shape))
            prev = e
        # a retrace happens exactly when the packed SHAPES change
        return sum(1 for a, b in zip(shapes, shapes[1:]) if a != b)

    tight, slacked = count_retraces(0.0), count_retraces(0.25)
    assert tight > 0, "drift produced no width growth — test rng too static"
    assert slacked < tight, (slacked, tight)


def test_slacked_pack_still_exact(state):
    """Slack pads the grid width, never the topology: a slacked pack must
    still reconstruct the masks exactly (pack_mismatch == 0)."""
    cfg, st = state
    pack_s = build_pack_state(st["masks"], (BLOCK, BLOCK), slack=0.5)
    assert int(pack_mismatch(st["masks"], pack_s, (BLOCK, BLOCK))) == 0
    for e, e0 in zip(
        jax.tree_util.tree_leaves(pack_s, is_leaf=is_pack_entry),
        jax.tree_util.tree_leaves(st["pack"], is_leaf=is_pack_entry),
    ):
        if e is None:
            continue
        assert e["idx"].shape[-1] >= e0["idx"].shape[-1]


# ---------------------------------------------------------------------------
# checkpoint round-trip
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip_pack(state, tmp_path):
    cfg, st = state
    save(st, tmp_path, 5)
    restored, step = restore(st, tmp_path)
    assert step == 5
    f1 = jax.tree_util.tree_leaves(st["pack"], is_leaf=lambda x: x is None)
    f2 = jax.tree_util.tree_leaves(restored["pack"], is_leaf=lambda x: x is None)
    assert len(f1) == len(f2) and len(f1) > 0
    for a, b in zip(f1, f2):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # the restored pack is still in sync with the restored masks
    assert int(
        pack_mismatch(restored["masks"], restored["pack"], (BLOCK, BLOCK))
    ) == 0


def test_restore_pre_packstate_checkpoint(state, tmp_path):
    """A checkpoint saved WITHOUT a pack (pre-PackState run) restores into a
    pack-bearing template: restore falls back to the template pack, and
    refresh_pack makes it consistent with the restored masks."""
    cfg, st = state
    legacy = {k: v for k, v in st.items() if k != "pack"}
    save(legacy, tmp_path, 3)
    restored, step = restore(st, tmp_path)  # template HAS a pack
    assert step == 3 and "pack" in restored
    restored = refresh_pack(restored, cfg)
    assert int(
        pack_mismatch(restored["masks"], restored["pack"], (BLOCK, BLOCK))
    ) == 0


def test_restore_missing_real_leaf_still_raises(state, tmp_path):
    """The pack/ fallback must not mask genuinely corrupt checkpoints."""
    cfg, st = state
    partial = {k: v for k, v in st.items() if k != "opt"}
    save(partial, tmp_path, 4)
    with pytest.raises(KeyError, match="opt"):
        restore(st, tmp_path, step=4)


# ---------------------------------------------------------------------------
# serve: prefill + decode reuse one pack, logits unchanged
# ---------------------------------------------------------------------------

def test_decode_path_pack_reuse(state):
    cfg, st = state
    key = jax.random.PRNGKey(2)
    tokens = jax.random.randint(key, (2, 16), 0, cfg.vocab_size)
    kw = dict(masks=st["masks"])
    logits_np, caches_np = lm_prefill(
        st["params"], cfg, {"tokens": tokens[:, :8]}, max_len=16, **kw
    )
    logits_pk, caches_pk = lm_prefill(
        st["params"], cfg, {"tokens": tokens[:, :8]}, max_len=16,
        pack=st["pack"], **kw
    )
    np.testing.assert_array_equal(np.asarray(logits_np), np.asarray(logits_pk))
    for t in range(8, 12):
        step_tok = tokens[:, t : t + 1]
        logits_np, caches_np = lm_decode(
            st["params"], cfg, caches_np, step_tok, pos=t, **kw
        )
        # the SAME pack object is reused every decode step — no re-packing
        logits_pk, caches_pk = lm_decode(
            st["params"], cfg, caches_pk, step_tok, pos=t,
            pack=st["pack"], **kw
        )
        np.testing.assert_array_equal(
            np.asarray(logits_np), np.asarray(logits_pk), err_msg=f"pos {t}"
        )


# ---------------------------------------------------------------------------
# loud errors (referenced from docs/kernels.md)
# ---------------------------------------------------------------------------

def test_pack_truncation_error_is_loud():
    bm = np.ones((4, 2), bool)
    with pytest.raises(ValueError, match="docs/kernels.md"):
        pack_block_mask(bm, max_count=2)


def test_empty_layer_error_is_loud():
    dead = jnp.zeros((64, 64), bool)
    with pytest.raises(ValueError, match="docs/kernels.md"):
        pack_entry(dead, (BLOCK, BLOCK), name="layers/0/mlp/wi/w")


def test_block_sparse_linear_requires_topology():
    from repro.kernels.ops import block_sparse_linear

    x = jnp.ones((8, 32), jnp.float32)
    w = jnp.ones((32, 32), jnp.float32)
    with pytest.raises(ValueError, match="docs/kernels.md"):
        block_sparse_linear(x, w)

"""Gradual magnitude pruning (Zhu & Gupta) + SNIP baselines."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import random_mask
from repro.core.pruning import PruningSchedule, prune_step, snip_masks


def test_cubic_ramp_endpoints():
    s = PruningSchedule(0.9, begin_step=100, end_step=1100)
    assert float(s.target(0)) == pytest.approx(0.0)
    assert float(s.target(100)) == pytest.approx(0.0)
    assert float(s.target(1100)) == pytest.approx(0.9)
    assert float(s.target(5000)) == pytest.approx(0.9)
    mid = float(s.target(600))
    assert 0.7 < mid < 0.9  # cubic: front-loaded pruning


def test_prune_monotone_and_magnitude_based():
    key = jax.random.PRNGKey(0)
    w = jax.random.normal(key, (32, 32))
    params = {"a": w}
    masks = {"a": jnp.ones((32, 32), bool)}
    sched = PruningSchedule(0.8, begin_step=0, end_step=100)
    p50, m50 = prune_step(params, masks, 50, sched)
    p100, m100 = prune_step(p50, m50, 100, sched)
    assert int(m100["a"].sum()) <= int(m50["a"].sum())
    # pruned = never regrown
    assert not bool(jnp.any(m100["a"] & ~m50["a"]))
    # survivors are the largest-magnitude weights
    k = int(m100["a"].sum())
    top = np.argsort(-np.abs(np.asarray(w)).ravel())[:k]
    surv = np.flatnonzero(np.asarray(m100["a"]).ravel())
    assert set(surv) == set(top)


def test_snip_saliency_vs_grad_only():
    """Appendix M bug #3: |theta*grad| (correct) differs from |grad|."""
    key = jax.random.PRNGKey(1)
    w = jax.random.normal(key, (64, 64))
    g = jax.random.normal(jax.random.fold_in(key, 1), (64, 64))
    params = {"a": w}
    grads = {"a": g}
    m_good = snip_masks(params, grads, {"a": 0.8})
    m_bad = snip_masks(params, grads, {"a": 0.8}, saliency="grad")
    assert int(m_good["a"].sum()) == int(m_bad["a"].sum())
    assert bool(jnp.any(m_good["a"] != m_bad["a"]))
    # correct saliency keeps exactly the top |w*g|
    k = int(m_good["a"].sum())
    top = np.argsort(-np.abs(np.asarray(w * g)).ravel())[:k]
    surv = np.flatnonzero(np.asarray(m_good["a"]).ravel())
    assert set(surv) == set(top)

"""RigL update semantics (paper Algorithm 1) + hypothesis invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hyp import given, settings, st

from repro.core import SparseAlgo, UpdateSchedule, random_mask, rigl_update_layer
from repro.core.rigl import _drop_grow, rigl_update
from repro.core.schedules import cosine_decay


def test_cosine_decay_endpoints():
    assert float(cosine_decay(0, 0.3, 1000)) == pytest.approx(0.3)
    assert float(cosine_decay(1000, 0.3, 1000)) == pytest.approx(0.0, abs=1e-7)
    assert float(cosine_decay(500, 0.3, 1000)) == pytest.approx(0.15)


def test_update_schedule_gating():
    s = UpdateSchedule(delta_t=100, t_end=1000, alpha=0.3)
    assert bool(s.is_update_step(100)) and bool(s.is_update_step(900))
    assert not bool(s.is_update_step(0))      # no update at t=0
    assert not bool(s.is_update_step(150))    # off-cycle
    assert not bool(s.is_update_step(1000))   # past t_end


def test_drop_smallest_magnitude():
    """Drop step removes exactly the smallest-|w| active connections."""
    w = jnp.asarray([[5.0, -4.0, 0.1], [-0.2, 3.0, 0.3]])
    m = jnp.ones_like(w, bool)
    g = jnp.asarray([[0.0, 0.0, 0.0], [0.0, 0.0, 0.0]])
    new_m, new_w, grown = rigl_update_layer(w, m, g, fraction=1 / 3)
    # k = floor(1/3 * 6) = 2 -> drop 0.1 and -0.2 (smallest two); the four
    # largest |w| must survive, and nnz is preserved by the grow step.
    kept = np.asarray(new_m)
    assert kept[0, 0] and kept[0, 1] and kept[1, 1] and kept[1, 2]
    assert int(new_m.sum()) == 6


def test_grow_highest_gradient_zero_init():
    w = jnp.asarray([[5.0, 0.01, 0.0, 0.0]])
    m = jnp.asarray([[True, True, False, False]])
    g = jnp.asarray([[9.0, 0.5, 7.0, 1.0]])
    new_m, new_w, grown = rigl_update_layer(w, m, g, fraction=0.5)
    # n_active=2, k=1: drop 0.01; grow candidates = {0.01's slot, idx2, idx3}
    # highest |g| among candidates is idx2 (7.0) -> grown, zero-initialized
    assert bool(new_m[0, 2]) and not bool(new_m[0, 1]) and not bool(new_m[0, 3])
    assert float(new_w[0, 2]) == 0.0
    assert bool(grown[0, 2])
    assert int(new_m.sum()) == 2  # nnz preserved


def test_freshly_dropped_can_regrow():
    """Official-code semantics: a just-dropped slot with top gradient regrows."""
    w = jnp.asarray([[5.0, 0.01, 0.0]])
    m = jnp.asarray([[True, True, False]])
    g = jnp.asarray([[0.0, 100.0, 1.0]])  # the dropped slot has the top grad
    new_m, new_w, grown = rigl_update_layer(w, m, g, fraction=0.5)
    assert bool(new_m[0, 1]) and bool(grown[0, 1])
    assert float(new_w[0, 1]) == 0.0  # re-initialized to zero


@settings(max_examples=40, deadline=None)
@given(
    st.integers(4, 12),
    st.integers(4, 12),
    st.floats(0.1, 0.9),
    st.floats(0.0, 0.6),
    st.integers(0, 2**31 - 1),
)
def test_property_nnz_preserved_exactly(rows, cols, sparsity, fraction, seed):
    key = jax.random.PRNGKey(seed)
    m = random_mask(key, (rows, cols), sparsity)
    w = jax.random.normal(jax.random.fold_in(key, 1), (rows, cols))
    g = jax.random.normal(jax.random.fold_in(key, 2), (rows, cols))
    new_m, new_w, grown = rigl_update_layer(w * m, m, g, fraction)
    assert int(new_m.sum()) == int(m.sum())  # bit-exact nnz preservation
    # grown connections were zero-initialized
    assert float(jnp.max(jnp.abs(jnp.where(grown, new_w, 0.0)))) == 0.0
    # masks stay boolean
    assert new_m.dtype == m.dtype


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**31 - 1), st.floats(0.2, 0.8))
def test_property_random_mask_exact_count(seed, sparsity):
    key = jax.random.PRNGKey(seed)
    shape = (32, 48)
    m = random_mask(key, shape, sparsity)
    expected = round((1 - sparsity) * 32 * 48)
    assert int(m.sum()) == expected


def test_block_mode_produces_block_structure():
    key = jax.random.PRNGKey(0)
    shape, blk = (32, 64), (8, 16)
    m = random_mask(key, shape, 0.0)  # start dense then drop blocks
    w = jax.random.normal(key, shape)
    g = jax.random.normal(jax.random.fold_in(key, 1), shape)
    new_m, _, _ = rigl_update_layer(w, m, g, 0.5, block_shape=blk)
    mb = np.asarray(new_m).reshape(4, 8, 4, 16)
    per_block = mb.sum(axis=(1, 3))
    assert set(np.unique(per_block)) <= {0, 8 * 16}  # all-or-nothing blocks


def test_set_and_snfs_growers():
    key = jax.random.PRNGKey(0)
    params = {"a": jax.random.normal(key, (16, 16))}
    masks = {"a": random_mask(key, (16, 16), 0.5)}
    grads = {"a": jax.random.normal(jax.random.fold_in(key, 1), (16, 16))}
    mom = {"a": jax.random.normal(jax.random.fold_in(key, 2), (16, 16))}
    for method in ("set", "snfs", "rigl"):
        algo = SparseAlgo(method=method, schedule=UpdateSchedule(t_end=100))
        p2, m2, grown = rigl_update(
            params, masks, grads, 50, algo, key, dense_momentum=mom
        )
        assert int(m2["a"].sum()) == int(masks["a"].sum())


def test_static_is_identity():
    key = jax.random.PRNGKey(0)
    params = {"a": jax.random.normal(key, (8, 8))}
    masks = {"a": random_mask(key, (8, 8), 0.5)}
    grads = {"a": jnp.ones((8, 8))}
    algo = SparseAlgo(method="static")
    p2, m2, grown = rigl_update(params, masks, grads, 50, algo, key)
    assert bool(jnp.all(m2["a"] == masks["a"]))
    assert not bool(grown["a"].any())


def test_dsr_global_reallocation():
    """DSR: total nnz preserved, per-layer budgets may shift (paper Table 1)."""
    from repro.core.rigl import dsr_update

    key = jax.random.PRNGKey(4)
    # layer 'a' has uniformly tiny weights -> global threshold drains it
    params = {
        "a": 0.01 * jax.random.normal(key, (32, 32)),
        "b": jax.random.normal(jax.random.fold_in(key, 1), (32, 32)),
    }
    masks = {
        "a": random_mask(key, (32, 32), 0.5),
        "b": random_mask(jax.random.fold_in(key, 2), (32, 32), 0.5),
    }
    algo = SparseAlgo(method="rigl", schedule=UpdateSchedule(delta_t=10, t_end=1000, alpha=0.4))
    p2, m2, grown = dsr_update(params, masks, 10, algo, key)
    total_before = int(masks["a"].sum()) + int(masks["b"].sum())
    total_after = int(m2["a"].sum()) + int(m2["b"].sum())
    assert total_after == total_before  # global nnz preserved
    # budget must have MOVED away from the tiny-weight layer
    assert int(m2["a"].sum()) < int(masks["a"].sum())
    assert int(m2["b"].sum()) > int(masks["b"].sum())

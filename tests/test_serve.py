"""Serving correctness: prefill + decode must reproduce full-forward logits."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import init_lm, lm_decode, lm_forward, lm_prefill
from repro.models.model import _logits

CAUSAL_ARCHS = [a for a in ARCH_IDS if a != "hubert-xlarge"]
B, S = 2, 32


@pytest.mark.parametrize("arch", CAUSAL_ARCHS)
def test_prefill_decode_matches_forward(arch):
    cfg = get_config(arch, smoke=True)
    cfg = dataclasses.replace(cfg, dtype="float32", moe_capacity_factor=16.0)
    key = jax.random.PRNGKey(0)
    params, _, _ = init_lm(key, cfg)
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": tokens}
    if cfg.frontend == "patch":
        batch["patches"] = jax.random.normal(
            key, (B, cfg.n_patches, cfg.frontend_dim), jnp.float32
        )
    h, _, _ = lm_forward(params, cfg, batch)
    full = _logits(params, cfg, h)

    pre = dict(batch)
    pre["tokens"] = tokens[:, : S - 1]
    max_len = S + (cfg.n_patches if cfg.frontend == "patch" else 0)
    logits_p, caches = lm_prefill(params, cfg, pre, max_len=max_len)
    assert float(jnp.max(jnp.abs(logits_p[:, 0] - full[:, -2]))) < 2e-4

    logits_d, caches = lm_decode(params, cfg, caches, tokens[:, S - 1 :], pos=max_len - 1)
    assert float(jnp.max(jnp.abs(logits_d[:, 0] - full[:, -1]))) < 2e-4


def test_multi_step_decode_chain():
    """Greedy decode token-by-token == teacher-forced forward on same tokens."""
    cfg = dataclasses.replace(
        get_config("gemma3-4b", smoke=True), dtype="float32"
    )
    key = jax.random.PRNGKey(1)
    params, _, _ = init_lm(key, cfg)
    tokens = jax.random.randint(key, (1, 24), 0, cfg.vocab_size)
    h, _, _ = lm_forward(params, cfg, {"tokens": tokens})
    full = _logits(params, cfg, h)

    _, caches = lm_prefill(params, cfg, {"tokens": tokens[:, :8]}, max_len=24)
    for t in range(8, 24):
        logits, caches = lm_decode(params, cfg, caches, tokens[:, t : t + 1], pos=t)
        err = float(jnp.max(jnp.abs(logits[:, 0] - full[:, t])))
        assert err < 5e-4, (t, err)


def test_windowed_cache_is_small():
    """SWA archs allocate only window-sized caches (long-context feasibility)."""
    from repro.models import init_caches

    cfg = get_config("h2o-danube-1.8b", smoke=True)  # all-local, window=16
    caches = init_caches(cfg, batch=2, max_len=4096)
    assert caches[0]["kv"]["k"].shape[1] == cfg.window


def test_recurrent_cache_constant_size():
    cfg = get_config("xlstm-1.3b", smoke=True)
    from repro.models import init_caches

    c1 = init_caches(cfg, 2, 128)
    c2 = init_caches(cfg, 2, 1 << 19)
    s1 = sum(x.size for x in jax.tree_util.tree_leaves(c1))
    s2 = sum(x.size for x in jax.tree_util.tree_leaves(c2))
    assert s1 == s2  # O(1) state independent of context length


def test_grok_softcap_serve_parity():
    """final_softcap must reach EVERY serving entry point, not just lm_loss:
    teacher-forced full-forward logits vs lm_prefill / lm_decode /
    lm_prefill_suffix on the grok smoke config — which also routes attention
    through flash_tight with an in-kernel logit_softcap, so this is the
    end-to-end 'grok cell serves on the tight softcapped flash path' check."""
    from repro.models import init_paged_caches, lm_prefill_into, lm_prefill_suffix

    cfg = get_config("grok-1-314b", smoke=True)
    cfg = dataclasses.replace(cfg, dtype="float32", moe_capacity_factor=16.0)
    assert cfg.sparse.attn_kernel == "flash_tight"
    assert cfg.logit_softcap and cfg.final_softcap
    key = jax.random.PRNGKey(3)
    params, _, _ = init_lm(key, cfg)
    S_, ctx = 32, 16
    tokens = jax.random.randint(key, (1, S_), 0, cfg.vocab_size)
    h, _, _ = lm_forward(params, cfg, {"tokens": tokens})
    full = _logits(params, cfg, h)
    # the cap itself must be live end to end: tanh bounds every true logit
    assert float(jnp.max(jnp.abs(full[..., : cfg.vocab_size]))) <= cfg.final_softcap

    logits_p, caches = lm_prefill(
        params, cfg, {"tokens": tokens[:, : S_ - 1]}, max_len=S_
    )
    assert float(jnp.max(jnp.abs(logits_p[:, 0] - full[:, -2]))) < 2e-4
    assert float(jnp.max(jnp.abs(logits_p[..., : cfg.vocab_size]))) <= cfg.final_softcap

    logits_d, _ = lm_decode(params, cfg, caches, tokens[:, S_ - 1 :], pos=S_ - 1)
    assert float(jnp.max(jnp.abs(logits_d[:, 0] - full[:, -1]))) < 2e-4

    # shared-prefix suffix path: prefix pages via paged admission, then only
    # the suffix runs through the model (flash history attention + softcaps)
    page = 8
    n_blocks = {"global": S_ // page, "local": S_ // page}
    paged = init_paged_caches(cfg, 1, S_, n_blocks, page)
    table = jnp.arange(S_ // page, dtype=jnp.int32)
    _, paged = lm_prefill_into(
        params, cfg, paged, {"tokens": tokens[:, :ctx]}, jnp.int32(0),
        max_len=S_, tables={"global": table},
    )
    logits_s, _ = lm_prefill_suffix(
        params, cfg, paged, {"tokens": tokens[:, ctx:]}, table, jnp.int32(ctx)
    )
    assert float(jnp.max(jnp.abs(logits_s[:, 0] - full[:, -1]))) < 2e-4

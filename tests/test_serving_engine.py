"""Continuous-batching engine: per-slot decode equivalence, slot recycling,
sampler determinism, request lifecycle.

The load-bearing contract: a request served through the engine — admitted
into an arbitrary slot of a shared cache, stepped with per-slot positions
alongside unrelated requests, possibly into a RECYCLED slot — produces
token-for-token what a dedicated single-request lockstep session (scalar-pos
lm_prefill + lm_decode, greedy) produces.  Checked for kernel='dense' and
kernel='block_sparse' (PackState threaded once per engine).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SparseConfig, get_config
from repro.models import init_caches, init_lm, lm_decode, lm_prefill, lm_prefill_into
from repro.optim import OptConfig
from repro.serving import Request, RequestQueue, ServeEngine, Status, poisson_arrivals
from repro.serving.sampler import request_key, sample_tokens, step_keys
from repro.training import init_train_state

pytestmark = pytest.mark.serve

BLOCK = 16


def _cfg():
    """All-local SWA smoke config (window=16) — ring wraparound territory."""
    return dataclasses.replace(
        get_config("h2o-danube-1.8b", smoke=True), dtype="float32"
    )


def _bs_state():
    cfg = dataclasses.replace(
        _cfg(),
        sparse=SparseConfig(
            sparsity=0.8, method="rigl", kernel="block_sparse",
            block_shape=(BLOCK, BLOCK), kernel_block=(128, BLOCK, BLOCK),
        ),
    )
    st, _, _ = init_train_state(jax.random.PRNGKey(0), cfg, OptConfig())
    return cfg, st


def _params(cfg, seed=0):
    params, _, _ = init_lm(jax.random.PRNGKey(seed), cfg)
    return params


def _prompt(cfg, length, seed):
    rng = np.random.default_rng(seed)
    return rng.integers(0, cfg.vocab_size, size=length).astype(np.int32)


def _lockstep_tokens(cfg, params, tokens, gen, max_len, *, masks=None, pack=None):
    """Greedy single-request reference: scalar-pos prefill + decode chain."""
    L = int(tokens.shape[0])
    logits, caches = lm_prefill(
        params, cfg, {"tokens": jnp.asarray(tokens)[None]}, max_len=max_len,
        masks=masks, pack=pack,
    )
    tok = int(jnp.argmax(logits[0, -1]))
    out = [tok]
    for i in range(gen - 1):
        logits, caches = lm_decode(
            params, cfg, caches, jnp.asarray([[tok]], jnp.int32), pos=L + i,
            masks=masks, pack=pack,
        )
        tok = int(jnp.argmax(logits[0, -1]))
        out.append(tok)
    return out


# ---------------------------------------------------------------------------
# per-slot decode primitive: staggered vector-pos batch == scalar sessions
# ---------------------------------------------------------------------------

def test_per_slot_decode_matches_scalar_sessions_with_ring_wrap():
    """Three staggered requests + one dead slot, decoded past cfg.window so
    every ring cache wraps, bit-match independent scalar-pos sessions; the
    dead slot's cache rows stay bit-untouched."""
    cfg = _cfg()
    assert cfg.window == 16
    params = _params(cfg)
    max_len, gen = 48, 24  # prompts 4/7/11 + 24 tokens: wraps window=16
    prompts = [_prompt(cfg, L, seed=L) for L in (4, 7, 11)]
    refs = [
        _lockstep_tokens(cfg, params, t, gen, max_len) for t in prompts
    ]

    cap = 4  # slot 3 stays dead throughout
    caches = init_caches(cfg, cap, max_len)
    pos = np.zeros(cap, np.int32)
    active = np.zeros(cap, bool)
    cur = np.zeros(cap, np.int32)
    outs = [[] for _ in range(cap)]
    for s, t in enumerate(prompts):
        logits, caches = lm_prefill_into(
            params, cfg, caches, {"tokens": jnp.asarray(t)[None]},
            jnp.int32(s), max_len,
        )
        cur[s] = int(jnp.argmax(logits[0, -1]))
        outs[s].append(int(cur[s]))
        pos[s], active[s] = t.shape[0], True

    dead_before = jax.tree_util.tree_map(
        lambda x: np.asarray(x[3]).copy(), caches
    )
    for _ in range(gen - 1):
        logits, caches = lm_decode(
            params, cfg, caches, jnp.asarray(cur)[:, None],
            pos=jnp.asarray(pos), active=jnp.asarray(active),
        )
        nxt = np.asarray(jnp.argmax(logits[:, -1], -1), np.int32)
        pos[active] += 1
        cur[active] = nxt[active]
        for s in np.nonzero(active)[0]:
            outs[s].append(int(nxt[s]))

    for s in range(3):
        assert outs[s] == refs[s], f"slot {s} diverged from scalar session"
    dead_after = jax.tree_util.tree_map(lambda x: np.asarray(x[3]), caches)
    for b, a in zip(
        jax.tree_util.tree_leaves(dead_before),
        jax.tree_util.tree_leaves(dead_after),
    ):
        np.testing.assert_array_equal(b, a, err_msg="dead slot state changed")


def test_active_mask_requires_vector_pos():
    cfg = _cfg()
    params = _params(cfg)
    caches = init_caches(cfg, 2, 8)
    with pytest.raises(ValueError, match="active"):
        lm_decode(
            params, cfg, caches, jnp.zeros((2, 1), jnp.int32), pos=0,
            active=jnp.ones((2,), bool),
        )


@pytest.mark.parametrize("arch", ["hymba-1.5b", "xlstm-1.3b", "qwen2-moe-a2.7b"])
def test_per_slot_decode_recurrent_and_moe_families(arch):
    """Vector-pos + active decode matches scalar sessions for the SSM-hybrid,
    xLSTM (recurrent states gated per-row) and MoE families.

    moe_capacity_factor=16.0 makes expert capacity NON-binding: capacity C
    scales with the decode batch, so when C binds, ACTIVE requests batched
    together can contend for expert slots in a way their solo lockstep
    sessions cannot — batch-vs-solo token identity for MoE holds only while
    capacity doesn't bind (docs/serving.md).  Dead-slot isolation is the
    separate, unconditional invariant: see
    test_moe_dead_slots_cannot_contend_expert_capacity.
    """
    cfg = dataclasses.replace(
        get_config(arch, smoke=True), dtype="float32", moe_capacity_factor=16.0
    )
    params = _params(cfg)
    max_len, gen = 32, 6
    prompts = [_prompt(cfg, L, seed=10 + L) for L in (3, 8)]
    refs = [_lockstep_tokens(cfg, params, t, gen, max_len) for t in prompts]

    cap = 3
    caches = init_caches(cfg, cap, max_len)
    pos = np.zeros(cap, np.int32)
    active = np.zeros(cap, bool)
    cur = np.zeros(cap, np.int32)
    outs = [[] for _ in range(cap)]
    for s, t in enumerate(prompts):
        logits, caches = lm_prefill_into(
            params, cfg, caches, {"tokens": jnp.asarray(t)[None]},
            jnp.int32(s), max_len,
        )
        cur[s] = int(jnp.argmax(logits[0, -1]))
        outs[s].append(int(cur[s]))
        pos[s], active[s] = t.shape[0], True
    for _ in range(gen - 1):
        logits, caches = lm_decode(
            params, cfg, caches, jnp.asarray(cur)[:, None],
            pos=jnp.asarray(pos), active=jnp.asarray(active),
        )
        nxt = np.asarray(jnp.argmax(logits[:, -1], -1), np.int32)
        pos[active] += 1
        cur[active] = nxt[active]
        for s in np.nonzero(active)[0]:
            outs[s].append(int(nxt[s]))
    for s in range(2):
        assert outs[s] == refs[s], f"{arch}: slot {s} diverged"


def test_moe_dead_slots_cannot_contend_expert_capacity():
    """Dead slots must be MoE-routing no-ops at the DEFAULT capacity factor.

    Expert capacity C is shared by every row of the decode batch with rank
    priority to lower indices, so without masking a parked slot's stale
    token at a LOW index could push an active request's token out of
    capacity and change its logits (the regression this pins down: active
    logits shifted by ~1 and flipped argmax).  lm_decode threads ``active``
    into moe(), forcing dead rows out of routing entirely — active logits
    must be bit-identical no matter what garbage dead slots hold."""
    cfg = dataclasses.replace(get_config("qwen2-moe-a2.7b", smoke=True),
                              dtype="float32")
    params = _params(cfg)
    cap, max_len = 8, 16
    # sanity: capacity binds at this batch (one expert CAN overflow) — at a
    # non-binding C this test would pass vacuously
    C = max(
        int(np.ceil(cap * cfg.top_k / cfg.n_experts * cfg.moe_capacity_factor)),
        min(cap, 4),
    )
    assert C < cap, "default-capacity config drifted: C no longer binds"

    caches = init_caches(cfg, cap, max_len)
    pos = np.zeros(cap, np.int32)
    active = np.zeros(cap, bool)
    cur = np.zeros(cap, np.int32)
    for i in range(4):  # active requests in HIGH slots 4..7; 0..3 stay dead
        s = 4 + i
        t = _prompt(cfg, 4, seed=40 + i)
        logits, caches = lm_prefill_into(
            params, cfg, caches, {"tokens": jnp.asarray(t)[None]},
            jnp.int32(s), max_len,
        )
        cur[s] = int(jnp.argmax(logits[0, -1]))
        pos[s], active[s] = 4, True

    def active_logits(dead_tok, dead_pos):
        tok = cur.copy()
        tok[:4] = dead_tok
        p = pos.copy()
        p[:4] = dead_pos
        logits, _ = lm_decode(
            params, cfg, caches, jnp.asarray(tok)[:, None],
            pos=jnp.asarray(p), active=jnp.asarray(active),
        )
        return np.asarray(logits[4:, -1])

    ref = active_logits(0, 0)
    for dead_tok, dead_pos in ((1, 0), (97, 3), (cfg.vocab_size - 1, 9)):
        got = active_logits(dead_tok, dead_pos)
        np.testing.assert_array_equal(
            got, ref,
            err_msg="dead-slot contents leaked into active rows' logits "
                    "(expert-capacity contention)",
        )


# ---------------------------------------------------------------------------
# engine: recycling, lifecycle, equivalence (dense + block_sparse)
# ---------------------------------------------------------------------------

def test_engine_recycles_slots_and_matches_lockstep():
    """More requests than capacity: every slot is reused at least once and
    every request is token-identical to its dedicated lockstep session."""
    cfg = _cfg()
    params = _params(cfg)
    max_len = 64
    shapes = [(4, 6), (7, 20), (11, 3), (5, 12), (9, 25), (6, 1)]
    reqs = [
        Request(rid=i, tokens=_prompt(cfg, L, seed=i), max_new_tokens=g)
        for i, (L, g) in enumerate(shapes)
    ]
    refs = {
        r.rid: _lockstep_tokens(cfg, params, r.tokens, r.max_new_tokens, max_len)
        for r in reqs
    }
    engine = ServeEngine(cfg, params, capacity=2, max_len=max_len)
    for r in reqs:
        engine.submit(r)
    stats = engine.run()
    assert stats["requests"] == len(reqs)
    assert stats["prefills"] == len(reqs)
    # recycling really happened: every admission reused one of the 2 slots
    admitted_slots = [s for _, s in engine.slot_history]
    assert len(admitted_slots) == 6 and set(admitted_slots) == {0, 1}
    assert max(admitted_slots.count(s) for s in (0, 1)) >= 2
    # ...and saved decode steps vs padding to the slowest (25-token) request
    assert stats["decode_steps"] < sum(g for _, g in shapes)
    for r in reqs:
        assert r.status is Status.DONE
        assert r.generated == refs[r.rid], f"request {r.rid} diverged"
        assert r.latency is not None and r.latency >= 0.0


def test_engine_equivalence_block_sparse_pack_threaded():
    """Acceptance: engine outputs == lockstep sessions under kernel-dispatch
    serving (raw weights + masks + PackState packed once per engine)."""
    cfg, st = _bs_state()
    params, masks, pack = st["params"], st["masks"], st["pack"]
    max_len = 48
    shapes = [(4, 5), (9, 14), (6, 8), (5, 18)]
    reqs = [
        Request(rid=i, tokens=_prompt(cfg, L, seed=20 + i), max_new_tokens=g)
        for i, (L, g) in enumerate(shapes)
    ]
    refs = {
        r.rid: _lockstep_tokens(
            cfg, params, r.tokens, r.max_new_tokens, max_len,
            masks=masks, pack=pack,
        )
        for r in reqs
    }
    engine = ServeEngine(
        cfg, params, capacity=2, max_len=max_len, masks=masks, pack=pack
    )
    for r in reqs:
        engine.submit(r)
    engine.run()
    for r in reqs:
        assert r.generated == refs[r.rid], f"request {r.rid} diverged"


def test_engine_eos_and_max_tokens_lifecycle():
    cfg = _cfg()
    params = _params(cfg)
    prompt = _prompt(cfg, 6, seed=3)
    ref = _lockstep_tokens(cfg, params, prompt, 12, 48)

    # eos at the 4th generated token stops generation there (eos kept)
    eos = ref[3]
    assert eos not in ref[:3], "test prompt degenerate: eos appears earlier"
    r_eos = Request(rid=0, tokens=prompt, max_new_tokens=12, eos_id=eos)
    # max_new_tokens=1 finishes straight from the prefill logits
    r_one = Request(rid=1, tokens=prompt, max_new_tokens=1)
    engine = ServeEngine(cfg, params, capacity=2, max_len=48)
    engine.submit(r_eos)
    engine.submit(r_one)
    stats = engine.run()
    assert r_eos.generated == ref[:4]
    assert r_one.generated == ref[:1]
    assert stats["requests"] == 2

    # oversize requests are rejected at submit, not at decode time
    with pytest.raises(ValueError, match="max_len"):
        engine.submit(Request(rid=2, tokens=_prompt(cfg, 40, 0), max_new_tokens=20))


def test_engine_respects_arrival_times():
    """A request whose arrival is in the future is not admitted early."""
    cfg = _cfg()
    params = _params(cfg)
    early = Request(rid=0, tokens=_prompt(cfg, 4, 0), max_new_tokens=4)
    late = Request(
        rid=1, tokens=_prompt(cfg, 4, 1), max_new_tokens=2, arrival=1e9
    )
    engine = ServeEngine(cfg, params, capacity=2, max_len=32)
    engine.submit(early)
    engine.submit(late)
    for _ in range(10):  # virtual clock never reaches `late`
        engine.step(now=0.0)
    assert early.status is Status.DONE
    assert late.status is Status.QUEUED and not engine.active.any()
    engine.step(now=2e9)
    assert late.status in (Status.DECODE, Status.DONE)


# ---------------------------------------------------------------------------
# prefill bucketing + greedy fast path
# ---------------------------------------------------------------------------

def test_padded_prefill_into_matches_exact_with_ring_wrap():
    """Bucketed prefill (end-padding + masked fill + n_valid logits) must
    match the exact-length path — including when the padding wraps a ring
    cache (L=20, window=16, padded to 32: unmasked pad writes would clobber
    still-needed true K/V at slots p % 16, a CATASTROPHIC >O(1) error).

    Tolerance note: the padded trace reduces attention softmaxes over a
    different (larger, masked) extent, so XLA's reduction order differs and
    float32 results carry ~1e-7 noise vs the exact trace — mathematically
    identical, not bit-identical.  Greedy TOKEN identity (the engine's
    observable contract) is asserted engine-vs-lockstep in
    test_engine_buckets_prompt_lengths_to_bounded_traces."""
    cfg = _cfg()
    params = _params(cfg)
    t = _prompt(cfg, 20, seed=9)
    max_len = 48
    ca = init_caches(cfg, 2, max_len)
    la, ca = lm_prefill_into(
        params, cfg, ca, {"tokens": jnp.asarray(t)[None]}, jnp.int32(1),
        max_len,
    )
    padded = np.zeros(32, np.int32)
    padded[:20] = t
    cb = init_caches(cfg, 2, max_len)
    lb, cb = lm_prefill_into(
        params, cfg, cb, {"tokens": jnp.asarray(padded)[None]}, jnp.int32(1),
        max_len, n_valid=jnp.int32(20),
    )
    np.testing.assert_allclose(np.asarray(la), np.asarray(lb),
                               rtol=1e-4, atol=1e-5)
    for x, y in zip(jax.tree_util.tree_leaves(ca), jax.tree_util.tree_leaves(cb)):
        np.testing.assert_allclose(
            np.asarray(x[1]), np.asarray(y[1]), rtol=1e-4, atol=1e-5,
            err_msg="padded prefill produced a different slot cache",
        )


def test_engine_buckets_prompt_lengths_to_bounded_traces():
    """Real traffic has arbitrary prompt lengths: the engine pads each to a
    power-of-two bucket, so many distinct lengths share one jitted prefill
    trace (bounded compile count + bounded lru_cache) AND still match their
    lockstep references exactly."""
    from repro.serving.engine import _prefill_fn

    cfg = _cfg()
    params = _params(cfg)
    max_len = 96  # unique cache key: isolates this test's miss count
    reqs = [
        Request(rid=i, tokens=_prompt(cfg, L, seed=50 + i), max_new_tokens=3)
        for i, L in enumerate((5, 6, 7, 8))  # all bucket to 8
    ]
    refs = {
        r.rid: _lockstep_tokens(cfg, params, r.tokens, r.max_new_tokens, max_len)
        for r in reqs
    }
    engine = ServeEngine(cfg, params, capacity=2, max_len=max_len)
    before = _prefill_fn.cache_info().misses
    for r in reqs:
        engine.submit(r)
    engine.run()
    assert _prefill_fn.cache_info().misses - before == 1, (
        "4 prompt lengths in one bucket must share one prefill trace"
    )
    assert engine.n_prefills == 4
    for r in reqs:
        assert r.generated == refs[r.rid], f"request {r.rid} diverged"


def test_greedy_steps_take_argmax_fast_path():
    """All-greedy traffic (the CLI default) must dispatch the argmax-only
    decode variant on every step; a stochastic slot in the batch selects the
    full sampler."""
    cfg = _cfg()
    params = _params(cfg)
    e1 = ServeEngine(cfg, params, capacity=2, max_len=32)
    e1.submit(Request(rid=0, tokens=_prompt(cfg, 4, seed=0), max_new_tokens=6))
    e1.run()
    assert e1.n_steps > 0 and e1.n_greedy_steps == e1.n_steps

    e2 = ServeEngine(cfg, params, capacity=2, max_len=32)
    e2.submit(Request(rid=0, tokens=_prompt(cfg, 4, seed=0), max_new_tokens=6,
                      temperature=0.8, seed=1))
    e2.run()
    assert e2.n_steps > 0 and e2.n_greedy_steps == 0


# ---------------------------------------------------------------------------
# sampler
# ---------------------------------------------------------------------------

def test_sampler_greedy_is_argmax_and_topk1_matches():
    logits = jnp.asarray(np.random.default_rng(0).standard_normal((5, 33)),
                         jnp.float32)
    keys = jnp.asarray(np.stack([request_key(i) for i in range(5)]))
    zero = jnp.zeros((5,))
    greedy = sample_tokens(logits, keys, zero, jnp.zeros((5,), jnp.int32))
    np.testing.assert_array_equal(
        np.asarray(greedy), np.asarray(jnp.argmax(logits, -1))
    )
    # top_k=1 at any temperature can only pick the argmax
    topk1 = sample_tokens(
        logits, keys, jnp.full((5,), 0.7), jnp.ones((5,), jnp.int32)
    )
    np.testing.assert_array_equal(np.asarray(topk1), np.asarray(greedy))


def test_sampler_determinism_and_slot_independence():
    """Same (weights, prompt, seed) => same tokens, regardless of slot,
    capacity, or batch company; different seeds diverge."""
    cfg = _cfg()
    params = _params(cfg)

    def run(capacity, seed, fillers):
        engine = ServeEngine(cfg, params, capacity=capacity, max_len=48)
        engine.submit(Request(
            rid=0, tokens=_prompt(cfg, 5, seed=7), max_new_tokens=10,
            temperature=0.8, top_k=12, seed=seed,
        ))
        for j in range(fillers):  # occupy lower slots with other traffic
            engine.submit(Request(
                rid=10 + j, tokens=_prompt(cfg, 3 + j, seed=j),
                max_new_tokens=6, temperature=1.3, seed=100 + j,
            ))
        engine.run()
        return [r for r in engine.queue.done if r.rid == 0][0].generated

    a = run(capacity=2, seed=1, fillers=0)
    b = run(capacity=2, seed=1, fillers=0)
    assert a == b, "same seed must reproduce the same stream"
    assert len(a) == 10
    c = run(capacity=4, seed=1, fillers=3)
    assert a == c, "slot index / batch company must not perturb sampling"
    d = run(capacity=2, seed=2, fillers=0)
    assert a != d, "different seeds should diverge (astronomically likely)"


def test_step_keys_fold_per_row():
    base = jnp.asarray(np.stack([request_key(3), request_key(3)]))
    k0 = step_keys(base, jnp.asarray([0, 1], jnp.int32))
    ref0 = jax.random.fold_in(jnp.asarray(request_key(3)), 0)
    ref1 = jax.random.fold_in(jnp.asarray(request_key(3)), 1)
    np.testing.assert_array_equal(np.asarray(k0[0]), np.asarray(ref0))
    np.testing.assert_array_equal(np.asarray(k0[1]), np.asarray(ref1))


# ---------------------------------------------------------------------------
# queue plumbing
# ---------------------------------------------------------------------------

def test_queue_fifo_and_arrival_gating():
    q = RequestQueue()
    for i, arr in enumerate([0.0, 0.5, 2.0]):
        q.submit(Request(rid=i, tokens=np.zeros(2, np.int32),
                         max_new_tokens=1, arrival=arr))
    assert q.pop_ready(0.0).rid == 0
    assert q.pop_ready(0.0) is None  # rid=1 hasn't arrived yet
    assert q.next_arrival() == 0.5
    assert q.pop_ready(1.0).rid == 1
    assert q.pop_ready(1.0) is None
    assert q.pop_ready(3.0).rid == 2
    with pytest.raises(ValueError, match="max_new_tokens"):
        q.submit(Request(rid=9, tokens=np.zeros(2, np.int32), max_new_tokens=0))


def test_queue_out_of_order_submission():
    """A late-arriving request submitted FIRST must not block one that has
    already arrived (the waiting list orders by arrival, not submission)."""
    q = RequestQueue()
    q.submit(Request(rid=0, tokens=np.zeros(2, np.int32), max_new_tokens=1,
                     arrival=5.0))
    q.submit(Request(rid=1, tokens=np.zeros(2, np.int32), max_new_tokens=1,
                     arrival=0.0))
    assert q.next_arrival() == 0.0
    assert q.pop_ready(1.0).rid == 1
    assert q.pop_ready(1.0) is None
    assert q.pop_ready(6.0).rid == 0


def test_poisson_arrivals_shape_and_burst():
    a = poisson_arrivals(10, 0.0)
    np.testing.assert_array_equal(a, np.zeros(10))
    b = poisson_arrivals(100, 50.0, seed=1)
    assert b.shape == (100,) and np.all(np.diff(b) >= 0)
    assert 100 / 50.0 * 0.3 < b[-1] < 100 / 50.0 * 3.0  # ~n/rate seconds


# ---------------------------------------------------------------------------
# paged KV cache: block-table engine == contiguous engine == lockstep
# ---------------------------------------------------------------------------

def _drain(engine, max_steps=2000):
    while len(engine.queue) or engine.active.any():
        engine.step(0.0)
        max_steps -= 1
        assert max_steps > 0, "engine failed to drain"
    return {r.rid: list(r.generated) for r in engine.queue.done}


def _run_both(cfg, params, reqs, *, capacity, max_len, page_size=8,
              masks=None, pack=None, **paged_kw):
    """(contiguous streams, paged streams, paged engine) on one workload."""
    import copy
    base = ServeEngine(cfg, params, capacity=capacity, max_len=max_len,
                       masks=masks, pack=pack)
    for r in copy.deepcopy(reqs):
        base.submit(r)
    paged = ServeEngine(cfg, params, capacity=capacity, max_len=max_len,
                        masks=masks, pack=pack, paged=True,
                        page_size=page_size, **paged_kw)
    for r in reqs:
        paged.submit(r)
    return _drain(base), _drain(paged), paged


@pytest.mark.paged
def test_paged_engine_identical_with_ring_wrap_and_recycling():
    """Acceptance: the paged engine (all-local SWA config — every cache a
    ring that WRAPS past cfg.window) is token-identical to the contiguous
    engine AND to dedicated lockstep sessions, across slot recycling; the
    pools drain to empty afterwards."""
    cfg = _cfg()
    assert cfg.window == 16
    params = _params(cfg)
    max_len = 64
    shapes = [(4, 24), (7, 20), (11, 3), (5, 12), (9, 25), (6, 1)]
    reqs = [
        Request(rid=i, tokens=_prompt(cfg, L, seed=i), max_new_tokens=g)
        for i, (L, g) in enumerate(shapes)
    ]
    refs = {
        r.rid: _lockstep_tokens(cfg, params, r.tokens, r.max_new_tokens, max_len)
        for r in reqs
    }
    base, paged, eng = _run_both(cfg, params, reqs, capacity=2, max_len=max_len)
    assert base == refs and paged == refs
    # slots really recycled through the page pools
    slots = [s for _, s in eng.slot_history]
    assert len(slots) == 6 and set(slots) == {0, 1}
    eng.check_pool_accounting()
    for pool in eng.pools.values():
        assert pool.n_live == 0  # every page returned on release


@pytest.mark.paged
@pytest.mark.parametrize("arch", ["hymba-1.5b", "xlstm-1.3b", "qwen2-moe-a2.7b"])
def test_paged_engine_recurrent_and_moe_families(arch):
    """Paged == contiguous token streams for the SSM-hybrid (paged KV +
    slot-batched recurrent state side by side), xLSTM (no KV at all — the
    paged engine degenerates gracefully) and MoE families."""
    cfg = dataclasses.replace(
        get_config(arch, smoke=True), dtype="float32", moe_capacity_factor=16.0
    )
    params = _params(cfg)
    shapes = [(3, 6), (8, 4), (5, 7)]
    reqs = [
        Request(rid=i, tokens=_prompt(cfg, L, seed=30 + i), max_new_tokens=g)
        for i, (L, g) in enumerate(shapes)
    ]
    base, paged, eng = _run_both(cfg, params, reqs, capacity=2, max_len=32)
    assert base == paged
    eng.check_pool_accounting()


@pytest.mark.paged
def test_paged_engine_block_sparse_pack_threaded():
    """Paged addressing composes with kernel-dispatch serving: raw weights +
    masks + PackState, tokens identical to the contiguous engine."""
    cfg, st = _bs_state()
    params, masks, pack = st["params"], st["masks"], st["pack"]
    shapes = [(4, 5), (9, 14), (6, 8)]
    reqs = [
        Request(rid=i, tokens=_prompt(cfg, L, seed=20 + i), max_new_tokens=g)
        for i, (L, g) in enumerate(shapes)
    ]
    base, paged, eng = _run_both(
        cfg, params, reqs, capacity=2, max_len=48, masks=masks, pack=pack
    )
    assert base == paged
    eng.check_pool_accounting()


def _shared_prefix_reqs(cfg, prefix, n, *, gen=6, seed=0, rid0=0):
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n):
        suffix = rng.integers(0, cfg.vocab_size,
                              size=int(rng.integers(1, 12))).astype(np.int32)
        reqs.append(Request(
            rid=rid0 + i, tokens=np.concatenate([prefix, suffix]),
            max_new_tokens=gen, share_prefix_len=len(prefix),
        ))
    return reqs


@pytest.mark.paged
def test_shared_prefix_admission_token_identical_with_cow():
    """Shared-prefix requests (one 24-token template, random suffixes,
    page_size 8 => 3 shared pages) decode token-identical to a no-sharing
    paged engine; the prefix cache takes hits, refcounts prove sharing, and
    the boundary-page COW fork fires for a whole-prompt-prefix request."""
    cfg = dataclasses.replace(
        get_config("mistral-large-123b", smoke=True), dtype="float32"
    )
    params = _params(cfg)
    prefix = _prompt(cfg, 24, seed=99)
    reqs = _shared_prefix_reqs(cfg, prefix, 6, seed=4)
    # rid 6: prompt == prefix exactly -> ctx clips to prompt_len-1, which is
    # page-UNALIGNED: the last shared page must FORK, not be written through
    reqs.append(Request(rid=6, tokens=prefix.copy(), max_new_tokens=4,
                        share_prefix_len=24))
    base, shared, eng = _run_both(
        cfg, params, reqs, capacity=2, max_len=64, prefix_cache=4
    )
    assert base == shared
    assert eng.n_prefix_hits >= 5  # first request misses + registers
    assert eng.pools["global"].n_forks >= 1
    eng.check_pool_accounting()
    # only the registered prefix entry still holds pages
    held = sum(len(e.pages) for e in eng._prefix_entries.values())
    assert eng.pools["global"].n_live == len(
        set().union(*(e.pages for e in eng._prefix_entries.values()))
    ) and held == 24 // 8
    # refcount evidence DURING service: admit two sharers, stop mid-flight
    eng2 = ServeEngine(cfg, params, capacity=2, max_len=64, paged=True,
                       page_size=8, prefix_cache=4)
    for r in _shared_prefix_reqs(cfg, prefix, 2, gen=20, seed=8, rid0=50):
        eng2.submit(r)
    eng2.step(0.0)
    shared_pages = next(iter(eng2._prefix_entries.values())).pages
    # cache ref + both slots' refs on every fully-shared page
    assert all(eng2.pools["global"].refcount[p] == 3 for p in shared_pages[:-1])
    eng2.check_pool_accounting()


@pytest.mark.paged
def test_paged_pool_capacity_bounds_submit_and_defers_admission():
    """submit() enforces the PAGE bound (an undersized pool rejects what the
    max_len row bound would admit); admission under pool pressure defers
    (requeue) instead of deadlocking and completes once pages free."""
    cfg = dataclasses.replace(
        get_config("mistral-large-123b", smoke=True), dtype="float32"
    )
    params = _params(cfg)
    # pool of 6 pages @ 8 = 48 positions, but max_len 64 rows
    eng = ServeEngine(cfg, params, capacity=2, max_len=64, paged=True,
                      page_size=8, n_blocks=6)
    # 49 positions -> 7 pages > 6: reject at submit even though 49 <= 64
    with pytest.raises(ValueError, match="pages"):
        eng.submit(Request(rid=0, tokens=_prompt(cfg, 41, 1),
                           max_new_tokens=8))
    # exact-capacity boundary: 48 positions == 6 pages is admissible
    fits = Request(rid=1, tokens=_prompt(cfg, 40, 2), max_new_tokens=8)
    # ...but only alone: this second request must WAIT for the first
    waits = Request(rid=2, tokens=_prompt(cfg, 8, 3), max_new_tokens=8)
    refs = {
        r.rid: _lockstep_tokens(cfg, params, r.tokens, r.max_new_tokens, 64)
        for r in (fits, waits)
    }
    assert eng.submit(fits) and eng.submit(waits)
    eng.step(0.0)
    assert fits.slot is not None and waits.slot is None  # deferred, not shed
    assert waits.status is Status.QUEUED
    streams = _drain(eng)
    assert streams == refs
    eng.check_pool_accounting()
    assert eng.pools["global"].n_live == 0


@pytest.mark.paged
def test_paged_engine_rejects_bad_geometry():
    cfg = _cfg()
    params = _params(cfg)
    with pytest.raises(ValueError, match="page_size"):
        ServeEngine(cfg, params, capacity=2, max_len=40, paged=True,
                    page_size=12)  # 12 divides neither ring 16 nor row 40
    with pytest.raises(ValueError, match="paged"):
        ServeEngine(cfg, params, capacity=2, max_len=32, prefix_cache=2)
    with pytest.raises(ValueError, match="all-global"):
        # danube is all-LOCAL: ring caches cannot host shared prefixes
        ServeEngine(cfg, params, capacity=2, max_len=32, paged=True,
                    page_size=8, prefix_cache=2)

"""Chaos tests: the serving failure model (docs/serving.md#failure-model).

The invariant under attack in every test: a fault touches EXACTLY the work
it was injected into.  A NaN slot quarantines one request (every other
stream bit-identical to a fault-free run); an expired request sheds in-queue
(a status, not an exception); a corrupted pack is rejected before it can
serve; a torn checkpoint dir is skipped, never restored; a non-finite loss
skips one optimizer update, bit-preserving the params.
"""
import dataclasses
import json
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import latest_step, restore, save
from repro.configs import SparseConfig, get_config
from repro.core import PackIntegrityError, validate_pack
from repro.data import batch_for
from repro.models import init_lm, lm_loss, logits_all_finite
from repro.optim import LRSchedule, OptConfig
from repro.serving import (
    FaultInjector,
    Request,
    RequestQueue,
    ServeEngine,
    Status,
    burst_storm,
    truncate_pack,
)
from repro.training import init_train_state, make_train_step

pytestmark = pytest.mark.chaos

BLOCK = 16


def _cfg():
    return dataclasses.replace(
        get_config("h2o-danube-1.8b", smoke=True), dtype="float32"
    )


def _params(cfg, seed=0):
    params, _, _ = init_lm(jax.random.PRNGKey(seed), cfg)
    return params


def _engine(cfg, params, **kw):
    kw.setdefault("capacity", 3)
    kw.setdefault("max_len", 32)
    return ServeEngine(cfg, params, **kw)


def _drain(engine, dt=1.0, max_steps=2000):
    """Virtual-clock drive until idle; returns final virtual time."""
    now = 0.0
    for _ in range(max_steps):
        if not (len(engine.queue) or engine.active.any()):
            return now
        engine.step(now)
        now += dt
    raise AssertionError("engine failed to drain")


def _streams(engine):
    return {r.rid: list(r.generated) for r in engine.queue.done
            if r.status is Status.DONE}


def _reqs(cfg, n, gen=6, **kw):
    return burst_storm(cfg, n, prompt_len=8, max_new_tokens=gen, **kw)


# ---------------------------------------------------------------------------
# units: finite flag, injector determinism, queue backpressure
# ---------------------------------------------------------------------------


def test_logits_all_finite_rowwise():
    x = jnp.ones((4, 3, 7))
    x = x.at[1, 2, 0].set(jnp.nan).at[3, 0, 4].set(jnp.inf)
    np.testing.assert_array_equal(
        np.asarray(logits_all_finite(x)), [True, False, True, False]
    )


def test_injector_and_storm_deterministic():
    a = FaultInjector(seed=5).poison_random(4, max_step=50, capacity=4)
    b = FaultInjector(seed=5).poison_random(4, max_step=50, capacity=4)
    assert a == b
    cfg = _cfg()
    s1, s2 = _reqs(cfg, 3, seed=9), _reqs(cfg, 3, seed=9)
    for r1, r2 in zip(s1, s2):
        np.testing.assert_array_equal(r1.tokens, r2.tokens)
        assert r1.arrival == r2.arrival == 0.0


def test_queue_backpressure_sheds_at_submit():
    q = RequestQueue(max_depth=2)
    reqs = [Request(rid=i, tokens=np.zeros(4, np.int32), max_new_tokens=2)
            for i in range(3)]
    assert q.submit(reqs[0]) and q.submit(reqs[1])
    assert not q.submit(reqs[2])
    assert reqs[2].status is Status.SHED
    assert "queue full" in reqs[2].error
    assert reqs[2] in q.done and len(q) == 2
    # retries are depth-limit exempt: a quarantined request always re-enters
    q.requeue(Request(rid=9, tokens=np.zeros(4, np.int32), max_new_tokens=2))
    assert len(q) == 3


def test_engine_queue_limit_and_deadline_default():
    cfg = _cfg()
    eng = _engine(cfg, _params(cfg), queue_limit=1, deadline=7.5)
    r0, r1 = _reqs(cfg, 2)
    assert eng.submit(r0) is True
    assert eng.submit(r1) is False and r1.status is Status.SHED
    assert r0.ttl == 7.5  # engine default stamped at submit
    explicit = _reqs(cfg, 1, rid0=5)[0]
    explicit.ttl = 2.0
    eng2 = _engine(cfg, _params(cfg), deadline=7.5)
    eng2.submit(explicit)
    assert explicit.ttl == 2.0  # per-request ttl wins over the default


# ---------------------------------------------------------------------------
# quarantine: isolation, retry recovery, retry exhaustion
# ---------------------------------------------------------------------------


def test_nan_quarantine_isolates_one_request():
    cfg = _cfg()
    params = _params(cfg)
    ref = _streams(_drain_engine(cfg, params, _reqs(cfg, 6)))
    # capacity 3, burst of 6: rids 0-2 admit into slots 0-2 at step 0, so
    # (step 2, slot 0) poisons rid 0 mid-decode, deterministically
    inj = FaultInjector().poison_logits(step=2, slot=0)
    eng = _drain_engine(cfg, params, _reqs(cfg, 6), faults=inj)
    failed = [r for r in eng.queue.done if r.status is Status.FAILED]
    assert [r.rid for r in failed] == [0]
    assert "non-finite" in failed[0].error
    assert eng.n_quarantined == 1
    assert eng.quarantine_log == [(2, 0, 0, 0, "decode")]
    got = _streams(eng)
    assert sorted(got) == [1, 2, 3, 4, 5]
    for rid, toks in got.items():
        assert toks == ref[rid], f"rid {rid} stream perturbed by quarantine"


def test_retry_recovers_exact_stream_with_backoff():
    cfg = _cfg()
    params = _params(cfg)
    ref = _streams(_drain_engine(cfg, params, _reqs(cfg, 6)))
    inj = FaultInjector().poison_logits(step=2, slot=0)
    reqs = _reqs(cfg, 6)
    reqs[0].retry_backoff = 3.0  # dt=1.0 steps => the retry must WAIT
    eng = _drain_engine(cfg, params, reqs, faults=inj, max_retries=2)
    got = _streams(eng)
    assert sorted(got) == [0, 1, 2, 3, 4, 5]  # everyone completed
    assert eng.n_quarantined == 1 and eng.n_retries_total == 1
    for rid, toks in got.items():
        assert toks == ref[rid], f"rid {rid} retry stream != fault-free run"
    r0 = next(r for r in eng.queue.done if r.rid == 0)
    assert r0.n_retries == 1
    assert r0.retry_at > 0 and r0.t_admitted >= r0.retry_at  # backoff gated


def test_retry_exhaustion_lands_failed():
    cfg = _cfg()
    params = _params(cfg)
    inj = FaultInjector().poison_prefill(rid=1)  # every attempt corrupted
    eng = _drain_engine(cfg, params, _reqs(cfg, 4), faults=inj, max_retries=2)
    r1 = next(r for r in eng.queue.done if r.rid == 1)
    assert r1.status is Status.FAILED
    assert r1.n_retries == 2 and "prefill" in r1.error
    assert eng.n_quarantined == 3  # initial attempt + 2 retries
    assert sorted(_streams(eng)) == [0, 2, 3]


def _drain_engine(cfg, params, reqs, **kw):
    eng = _engine(cfg, params, **kw)
    for r in reqs:
        assert eng.submit(r)
    _drain(eng)
    return eng


# ---------------------------------------------------------------------------
# deadline shedding under a burst storm
# ---------------------------------------------------------------------------


def test_deadline_shed_under_storm():
    cfg = _cfg()
    params = _params(cfg)
    # 9 requests, capacity 3, each needs ~6 virtual seconds of decode: a
    # ttl of 8 admits the first two waves and must shed the third
    eng = _engine(cfg, params, deadline=8.0)
    for r in _reqs(cfg, 9):
        assert eng.submit(r)
    _drain(eng)
    done = [r for r in eng.queue.done if r.status is Status.DONE]
    shed = [r for r in eng.queue.done if r.status is Status.SHED]
    assert len(done) + len(shed) == 9 and shed and done
    for r in shed:
        assert r.t_done is not None and "deadline" in r.error
        assert r.t_done > r.expires_at - 1e-9  # never shed early
    for r in done:
        assert r.t_admitted - r.arrival <= 8.0  # never admitted late
    s = eng.stats(1.0)
    assert s["shed"] == len(shed) and s["requests"] == len(done)


# ---------------------------------------------------------------------------
# pack integrity guard
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def bs_pack():
    cfg = dataclasses.replace(
        _cfg(),
        sparse=SparseConfig(
            sparsity=0.8, method="rigl", kernel="block_sparse",
            block_shape=(BLOCK, BLOCK), kernel_block=(128, BLOCK, BLOCK),
        ),
    )
    st, _, _ = init_train_state(jax.random.PRNGKey(0), cfg, OptConfig())
    return cfg, st


def test_validate_pack_accepts_real_pack(bs_pack):
    cfg, st = bs_pack
    assert validate_pack(st["pack"]) > 0
    assert validate_pack(None) == 0  # dense engines carry no pack


@pytest.mark.parametrize("mode", ["truncate", "oob", "nnz"])
def test_validate_pack_rejects_corruption(bs_pack, mode):
    cfg, st = bs_pack
    bad = truncate_pack(st["pack"], mode=mode)
    with pytest.raises(PackIntegrityError):
        validate_pack(bad)


def test_engine_construction_rejects_corrupt_pack(bs_pack):
    cfg, st = bs_pack
    bad = truncate_pack(st["pack"], mode="nnz")
    with pytest.raises(PackIntegrityError, match="ServeEngine.pack"):
        ServeEngine(cfg, st["params"], capacity=2, max_len=32,
                    masks=st["masks"], pack=bad)


# ---------------------------------------------------------------------------
# crash-atomic checkpoints
# ---------------------------------------------------------------------------


def _ckpt_state():
    cfg = dataclasses.replace(
        get_config("h2o-danube-1.8b", smoke=True),
        sparse=SparseConfig(sparsity=0.6),
    )
    st, _, _ = init_train_state(jax.random.PRNGKey(0), cfg, OptConfig())
    return cfg, st


def test_torn_checkpoint_skipped_on_restore(tmp_path):
    cfg, st = _ckpt_state()
    save(st, tmp_path, 1)
    save(st, tmp_path, 2)
    # tear the newest: truncate the array blob (crash mid-copy) — the
    # manifest's arrays_bytes no longer matches, so the dir is invalid
    blob = tmp_path / "step-0000000002" / "arrays.npz"
    blob.write_bytes(blob.read_bytes()[: blob.stat().st_size // 2])
    assert latest_step(tmp_path) == 1
    restored, step = restore(st, tmp_path)
    assert step == 1
    np.testing.assert_array_equal(
        np.asarray(restored["step"]), np.asarray(st["step"])
    )


def test_garbage_manifest_skipped(tmp_path):
    cfg, st = _ckpt_state()
    save(st, tmp_path, 3)
    save(st, tmp_path, 4)
    (tmp_path / "step-0000000004" / "manifest.json").write_text("{not json")
    assert latest_step(tmp_path) == 3
    _, step = restore(st, tmp_path)
    assert step == 3


def test_stray_tmp_dirs_collected(tmp_path):
    cfg, st = _ckpt_state()
    stray = tmp_path / "tmp-999"
    stray.mkdir(parents=True)
    (stray / "arrays.npz").write_bytes(b"partial")
    save(st, tmp_path, 5)
    assert not stray.exists()  # GC swept the crash-orphaned staging dir
    assert latest_step(tmp_path) == 5


def test_manifest_records_blob_size(tmp_path):
    cfg, st = _ckpt_state()
    save(st, tmp_path, 6)
    d = tmp_path / "step-0000000006"
    meta = json.loads((d / "manifest.json").read_text())
    assert meta["arrays_bytes"] == (d / "arrays.npz").stat().st_size


def test_pre_guard_checkpoint_restores_counter_fallback(tmp_path):
    cfg, st = _ckpt_state()
    old = {k: v for k, v in st.items() if k != "nonfinite_steps"}
    save(old, tmp_path, 7)
    restored, _ = restore(st, tmp_path)  # template HAS the counter
    assert int(restored["nonfinite_steps"]) == 0


# ---------------------------------------------------------------------------
# non-finite train-step guard
# ---------------------------------------------------------------------------


def test_train_step_skips_nonfinite_update():
    cfg = dataclasses.replace(
        get_config("h2o-danube-1.8b", smoke=True),
        dtype="float32", sparse=SparseConfig(sparsity=0.5),
    )
    opt = OptConfig(kind="sgd", momentum=0.9, weight_decay=0.0)
    lr = LRSchedule(kind="constant", base_lr=1e-2, warmup_steps=0)
    # poison enters through the BATCH so one compiled step covers both cases
    loss_fn = lambda p, b: lm_loss(p, cfg, b) + b["poison"]
    step_fn = jax.jit(make_train_step(cfg, opt, lr, loss_fn=loss_fn))
    st, _, _ = init_train_state(jax.random.PRNGKey(0), cfg, opt)
    batch = batch_for(cfg, 0, 2, 16, learnable=True)

    clean = dict(batch, poison=jnp.float32(0.0))
    st1, m1 = step_fn(st, clean)
    assert int(m1["nonfinite_steps"]) == 0
    assert math.isfinite(float(m1["loss"]))

    poisoned = dict(batch, poison=jnp.float32(np.nan))
    st2, m2 = step_fn(st1, poisoned)
    assert not math.isfinite(float(m2["loss"]))
    assert int(m2["nonfinite_steps"]) == 1
    assert int(st2["step"]) == int(st1["step"]) + 1  # step still advances
    for a, b in zip(jax.tree_util.tree_leaves(st1["params"]),
                    jax.tree_util.tree_leaves(st2["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(jax.tree_util.tree_leaves(st1["opt"]),
                    jax.tree_util.tree_leaves(st2["opt"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    st3, m3 = step_fn(st2, clean)  # recovery: the very next clean batch trains
    assert int(m3["nonfinite_steps"]) == 1
    changed = any(
        not np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree_util.tree_leaves(st2["params"]),
                        jax.tree_util.tree_leaves(st3["params"]))
    )
    assert changed


# ---------------------------------------------------------------------------
# stats / run edge cases
# ---------------------------------------------------------------------------


def test_stats_safe_on_zero_completed():
    cfg = _cfg()
    eng = _engine(cfg, _params(cfg))
    s = eng.stats(0.0)  # nothing ever submitted: must not index empty arrays
    assert s["requests"] == s["tokens"] == s["shed"] == s["failed"] == 0
    assert s["latency_p95_s"] == 0.0 and s["queue_wait_p95_s"] == 0.0


def test_run_stamps_wall_s_when_everything_sheds():
    cfg = _cfg()
    eng = _engine(cfg, _params(cfg))
    for r in _reqs(cfg, 2, ttl=0.0):  # expired the instant the clock moves
        assert eng.submit(r)
    stats = eng.run()
    assert stats["requests"] == 0 and stats["shed"] == 2
    assert stats["wall_s"] >= 0.0 and stats["tok_per_s"] == 0.0
    assert all(r.status is Status.SHED for r in eng.queue.done)


# ---------------------------------------------------------------------------
# paged pools under chaos: quarantine/retry storms must not leak pages
# ---------------------------------------------------------------------------

@pytest.mark.paged
def test_paged_pools_leak_free_under_quarantine_storm():
    """Every path a request can take out of a slot — DONE, decode-step
    quarantine (with retries), prefill quarantine through retry exhaustion
    to FAILED, deadline shed — must return its pages: after the storm
    drains, live pages are EXACTLY the prefix cache's holds and the pool
    books balance (ServeEngine.check_pool_accounting)."""
    cfg = dataclasses.replace(
        get_config("mistral-large-123b", smoke=True), dtype="float32"
    )
    params = _params(cfg)
    rng = np.random.default_rng(0)
    prefix = rng.integers(0, cfg.vocab_size, size=16).astype(np.int32)
    reqs = []
    for i in range(10):
        suffix = rng.integers(0, cfg.vocab_size,
                              size=int(rng.integers(1, 8))).astype(np.int32)
        reqs.append(Request(
            rid=i, tokens=np.concatenate([prefix, suffix]),
            max_new_tokens=6, share_prefix_len=16, max_retries=1,
            retry_backoff=0.5, ttl=200.0,
        ))
    faults = FaultInjector(seed=3)
    faults.poison_random(6, max_step=25, capacity=3)  # decode quarantines
    faults.poison_prefill(4)  # rid 4: every admission dies -> FAILED
    faults.poison_prefill(7)
    eng = ServeEngine(cfg, params, capacity=3, max_len=32, faults=faults,
                      paged=True, page_size=8, prefix_cache=2,
                      max_retries=1)
    for r in reqs:
        eng.submit(r)
    _drain(eng)
    assert eng.n_quarantined > 0 and eng.n_retries_total > 0
    by_status = {s: [r for r in eng.queue.done if r.status is s]
                 for s in (Status.DONE, Status.FAILED, Status.SHED)}
    assert {r.rid for r in by_status[Status.FAILED]} == {4, 7}
    assert len(by_status[Status.DONE]) == 8
    # the leak audit: slot references are all gone, pool books are exact
    eng.check_pool_accounting()
    cache_pages = {p for e in eng._prefix_entries.values() for p in e.pages}
    assert eng.pools["global"].n_live == len(cache_pages)
    assert all(not sp for sp in eng.slot_pages)
    # quarantined prefills never published garbage pages into the cache:
    # dropping the surviving entries drains the pool completely
    while eng._prefix_entries:
        eng._evict_prefix()
    eng.check_pool_accounting()
    assert eng.pools["global"].n_live == 0
    assert eng.pools["global"].n_free == eng.pools["global"].n_blocks

"""Sharding resolver unit tests + multi-device equivalence (subprocess)."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

from repro.launch.sharding import resolve_spec


class FakeMesh:
    def __init__(self, shape):
        self.shape = shape
        self.axis_names = tuple(shape)


MESH = FakeMesh({"data": 16, "model": 16})
POD = FakeMesh({"pod": 2, "data": 16, "model": 16})


def test_heads_sharded_when_divisible():
    spec = resolve_spec(("embed", "heads"), (2560, 8192), MESH)
    assert spec[1] == "model" and spec[0] is None


def test_fused_head_dim_shards_when_divisible():
    # internvl2: 14 heads x 64 = 896 IS divisible by 16 (mid-head split —
    # GSPMD reshards at the head reshape; compiles for every cell)
    spec = resolve_spec(("embed", "heads"), (896, 896), MESH)
    assert spec[1] == "model"


def test_nondivisible_dim_replicated():
    spec = resolve_spec(("embed", "heads"), (100, 100), MESH)
    assert spec == (None, None)


def test_experts_get_model_axis_when_divisible():
    spec = resolve_spec(("experts", "embed", "moe_mlp"), (16, 1024, 4096), MESH)
    assert spec[0] == "model" and spec[2] is None  # model used once


def test_grok_fallback_intra_expert_tp():
    # 8 experts don't divide 16 -> ff dim gets the model axis instead
    spec = resolve_spec(("experts", "embed", "moe_mlp"), (8, 6144, 32768), MESH)
    assert spec[0] is None and spec[2] == "model"


def test_fsdp_shards_embed_dim():
    spec = resolve_spec(("embed", "mlp"), (12288, 28672), MESH, fsdp=True)
    assert spec == ("data", "model")


def test_fsdp_skips_tiny_vectors():
    spec = resolve_spec(("embed",), (2560,), MESH, fsdp=True)
    assert spec == (None,)


def test_kv_seq_fallback_for_nondivisible_kv_heads():
    # mistral decode: kv=8 not divisible by model=16 -> shard cache seq dim
    spec = resolve_spec(
        ("act_batch", "act_kv_seq", "kv_heads", "head_dim"),
        (128, 32768, 8, 128),
        MESH,
    )
    assert spec[0] == "data" and spec[1] == "model" and spec[2] is None


def test_long_context_batch1_uses_all_axes_for_seq():
    spec = resolve_spec(
        ("act_batch", "act_kv_seq", "kv_heads", "head_dim"),
        (1, 524288, 8, 80),
        MESH,
    )
    assert spec[1] == ("data", "model")


def test_multipod_batch_over_pod_and_data():
    spec = resolve_spec(("act_batch", None, None), (256, 4096, 896), POD)
    assert spec[0] == ("pod", "data")


DIST_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses, json
    import jax, jax.numpy as jnp
    from repro.configs import get_config
    from repro.configs.base import SparseConfig
    from repro.data import batch_for
    from repro.launch.sharding import batch_shardings, state_shardings
    from repro.optim import LRSchedule, OptConfig
    from repro.training import init_train_state, make_train_step

    cfg = get_config("h2o-danube-1.8b", smoke=True)
    cfg = dataclasses.replace(cfg, dtype="float32",
                              sparse=SparseConfig(sparsity=0.5))
    opt = OptConfig(kind="sgd", momentum=0.9, weight_decay=0.0)
    lr = LRSchedule(kind="constant", base_lr=1e-2, warmup_steps=0)

    def run(mesh_shape):
        state, axes, _ = init_train_state(jax.random.PRNGKey(0), cfg, opt)
        losses = []
        step = make_train_step(cfg, opt, lr)
        if mesh_shape:
            mesh = jax.make_mesh(mesh_shape, ("data", "model"))
            st_sh = state_shardings(state, axes, mesh)
            state = jax.device_put(state, st_sh)
            fn = jax.jit(step)
        else:
            fn = jax.jit(step)
        for t in range(5):
            b = batch_for(cfg, t, 8, 64, learnable=True)
            if mesh_shape:
                b = jax.device_put(b, batch_shardings(b, mesh))
            state, m = fn(state, b)
            losses.append(float(m["loss"]))
        return losses

    single = run(None)
    multi = run((2, 4))
    print(json.dumps({"single": single, "multi": multi}))
    """
)


@pytest.mark.slow
def test_distributed_matches_single_device(tmp_path):
    """DP=2 x TP=4 must reproduce single-device training losses."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src")
    )
    out = subprocess.run(
        [sys.executable, "-c", DIST_SCRIPT],
        capture_output=True,
        text=True,
        env=env,
        timeout=900,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    data = json.loads(out.stdout.strip().splitlines()[-1])
    for a, b in zip(data["single"], data["multi"]):
        assert a == pytest.approx(b, rel=2e-3), data

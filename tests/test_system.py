"""End-to-end behaviour: the fault-tolerant driver + serve session."""
import dataclasses
import json
import subprocess
import sys
import os

import jax
import pytest

from repro.configs import get_config
from repro.configs.base import SparseConfig
from repro.core import apply_masks
from repro.launch.serve import serve_session
from repro.launch.train import run_with_restarts
from repro.optim import OptConfig
from repro.training import init_train_state


def test_train_driver_with_preemption(tmp_path):
    """Driver survives a mid-run preemption and finishes from checkpoint."""
    cfg = get_config("h2o-danube-1.8b", smoke=True)
    cfg = dataclasses.replace(
        cfg, sparse=SparseConfig(sparsity=0.8, method="rigl", delta_t=20)
    )
    state, log = run_with_restarts(
        cfg=cfg, steps=80, batch=8, seq=64, workdir=tmp_path,
        ckpt_every=20, preempt_at=40, log_every=20,
    )
    assert int(state["step"]) == 80
    result = json.loads((tmp_path / "result.json").read_text())
    assert abs(result["sparsity"] - 0.8) < 0.02
    assert result["metrics"][-1]["loss"] < result["metrics"][0]["loss"]


def test_serve_session_generates():
    cfg = get_config("hymba-1.5b", smoke=True)
    state, _, _ = init_train_state(jax.random.PRNGKey(0), cfg, OptConfig())
    w_eff = apply_masks(state["params"], state["masks"])
    toks, stats = serve_session(cfg, w_eff, batch=2, prompt_len=24, gen=6)
    assert toks.shape == (2, 6)
    assert stats["tok_per_s"] > 0

"""Topology-invariant tier (`pytest -m topology`, `make test-topology`).

Structural guarantees every mask-update method must satisfy, property-tested
via the optional-hypothesis shim (tests/_hyp.py):

  * cardinality: rigl_update conserves per-layer nnz for every method
  * drop/grow disjointness and grown ⊆ (new \\ old)
  * grown connections are zero-initialized (never-trained entries only)
  * 'static' is an exact identity
  * Top-KAST: A ⊆ B, |B| = min(total, |A| + ceil(Δ·total)), deterministic
    under a fixed key
  * loud ValueError when snfs/topkast state leaves are missing
  * superset-gradient parity: the DISPATCHED Top-KAST weight gradient equals
    the dense gradient restricted to B, so grow scores ranked on the superset
    match dense-gradient ranking exactly (the acceptance bar for running
    rigl/snfs/topkast with zero dense-gradient materialization)

Plus the methods_comparison smoke: every method row must emit finite
topology-distance telemetry.
"""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hyp import given, settings, st

from repro.core import (
    SparseAlgo,
    UpdateSchedule,
    mask_subset,
    random_mask,
    rigl_update,
    topkast_backward_masks,
)
from repro.core.masks import random_block_mask
from repro.core.rigl import topkast_superset_layer

pytestmark = pytest.mark.topology

SHAPES = {"a": (12, 16), "b": (16, 8)}
METHODS = ("rigl", "set", "snfs", "topkast")


def _algo(method, extra=0.15):
    return SparseAlgo(
        method=method,
        schedule=UpdateSchedule(delta_t=10, t_end=1000, alpha=0.3),
        backward_extra=extra,
    )


def _setup(seed, sparsity=0.75, extra=0.15):
    """Tiny two-layer problem with weights supported on A (as in training)."""
    key = jax.random.PRNGKey(seed)
    params, masks, grads, mom = {}, {}, {}, {}
    for i, (n, s) in enumerate(SHAPES.items()):
        params[n] = jax.random.normal(jax.random.fold_in(key, i), s)
        masks[n] = random_mask(jax.random.fold_in(key, 10 + i), s, sparsity)
        grads[n] = jax.random.normal(jax.random.fold_in(key, 20 + i), s)
        mom[n] = jax.random.normal(jax.random.fold_in(key, 30 + i), s)
        params[n] = params[n] * masks[n]
    bwd = topkast_backward_masks(
        params, masks, extra, jax.random.fold_in(key, 40)
    )
    return key, params, masks, grads, mom, bwd


@settings(max_examples=16, deadline=None)
@given(st.integers(min_value=0, max_value=63))
def test_cardinality_and_grown_invariants(seed):
    """For every method: nnz conserved, grown ⊆ new\\old, grown weights 0."""
    key, params, masks, grads, mom, bwd = _setup(seed)
    for method in METHODS:
        p2, m2, grown = rigl_update(
            params, masks, grads, 10, _algo(method),
            jax.random.fold_in(key, 50),
            dense_momentum=mom, bwd_masks=bwd,
        )
        for n in SHAPES:
            old = np.asarray(masks[n], bool)
            new = np.asarray(m2[n], bool)
            gr = np.asarray(grown[n], bool)
            assert new.sum() == old.sum(), (method, n, seed)
            # net-dropped and grown are disjoint: a slot the update removed
            # is never simultaneously flagged as a fresh activation (grown ⊆
            # new; freshly-dropped slots that regrow are in new, so they are
            # not net-dropped — official-code semantics)
            assert np.all(gr <= new), (method, n, seed)
            assert not np.any((old & ~new) & gr), (method, n, seed)
            w2 = np.asarray(p2[n])
            assert np.all(w2[gr] == 0.0), (method, n, seed)
            if method == "topkast":
                # new actives only ever come from inside the superset
                assert np.all(new <= (old | np.asarray(bwd[n], bool))), (n, seed)


@settings(max_examples=8, deadline=None)
@given(st.integers(min_value=0, max_value=63))
def test_static_is_identity(seed):
    key, params, masks, grads, mom, bwd = _setup(seed)
    p2, m2, grown = rigl_update(
        params, masks, grads, 10, _algo("static"), jax.random.fold_in(key, 50)
    )
    for n in SHAPES:
        assert np.array_equal(np.asarray(m2[n]), np.asarray(masks[n])), n
        assert np.array_equal(np.asarray(p2[n]), np.asarray(params[n])), n
        assert not np.asarray(grown[n]).any(), n


@settings(max_examples=16, deadline=None)
@given(
    st.integers(min_value=0, max_value=63),
    st.floats(min_value=0.0, max_value=0.5),
)
def test_topkast_superset_containment_and_size(seed, extra):
    """A ⊆ B with |B| = min(total, |A| + ceil(extra·total)), per layer."""
    key, params, masks, _, _, _ = _setup(seed)
    bwd = topkast_backward_masks(
        params, masks, extra, jax.random.fold_in(key, 7)
    )
    for n in SHAPES:
        A, B = masks[n], bwd[n]
        assert bool(mask_subset(A, B)), (n, seed, extra)
        total = A.size
        want = min(total, int(A.sum()) + math.ceil(extra * total))
        assert int(np.asarray(B, bool).sum()) == want, (n, seed, extra)


@settings(max_examples=8, deadline=None)
@given(st.integers(min_value=0, max_value=63))
def test_updates_deterministic_under_fixed_key(seed):
    """Same key, same inputs -> bit-identical masks/params for every method."""
    key, params, masks, grads, mom, bwd = _setup(seed)
    sub = jax.random.fold_in(key, 50)
    for method in METHODS:
        a = rigl_update(params, masks, grads, 10, _algo(method), sub,
                        dense_momentum=mom, bwd_masks=bwd)
        b = rigl_update(params, masks, grads, 10, _algo(method), sub,
                        dense_momentum=mom, bwd_masks=bwd)
        for n in SHAPES:
            assert np.array_equal(np.asarray(a[1][n]), np.asarray(b[1][n])), (
                method, n,
            )
            assert np.array_equal(np.asarray(a[0][n]), np.asarray(b[0][n])), (
                method, n,
            )
    # superset construction is deterministic too
    b1 = topkast_backward_masks(params, masks, 0.2, sub)
    b2 = topkast_backward_masks(params, masks, 0.2, sub)
    for n in SHAPES:
        assert np.array_equal(np.asarray(b1[n]), np.asarray(b2[n])), n


def test_snfs_missing_momentum_raises_loudly():
    key, params, masks, grads, _, _ = _setup(0)
    with pytest.raises(ValueError, match="dense_momentum.*'a'"):
        rigl_update(params, masks, grads, 10, _algo("snfs"), key)


def test_topkast_missing_bwd_masks_raises_loudly():
    key, params, masks, grads, _, _ = _setup(0)
    with pytest.raises(ValueError, match="bwd_masks.*'a'"):
        rigl_update(params, masks, grads, 10, _algo("topkast"), key)


def test_require_bwd_guard_flags_missing_superset_view():
    """assert_total_dispatch(require_bwd=True) raises at trace time when a
    mask leaf has no backward-superset pack view — the guard that proves no
    dense gradient can materialize during a Top-KAST/SNFS dispatched step."""
    from repro.models.layers import assert_total_dispatch

    masks = {"mlp": {"w": jnp.ones((4, 4), bool)}}
    with pytest.raises(RuntimeError, match="backward-superset"):
        assert_total_dispatch(
            masks, set(), kernel="masked", where="test",
            pack={"mlp": {"w": None}}, require_bwd=True,
        )
    # carrier and bidx views both satisfy it
    assert_total_dispatch(
        masks, set(), kernel="masked", where="test",
        pack={"mlp": {"w": {"bwd_mask": jnp.ones((4, 4), bool)}}},
        require_bwd=True,
    )


# --------------------------------------------------------------------------
# Superset-gradient parity: dispatched wgrad == dense grad restricted to B.
# --------------------------------------------------------------------------

def _topk_set(score, cand, k):
    """Indices of the k largest scores among flat candidate slots."""
    s = np.where(cand.reshape(-1), score.reshape(-1), -np.inf)
    return set(np.argsort(-s, kind="stable")[:k].tolist())


def test_topkast_masked_grad_parity_with_dense():
    """kernels/ops.py::topkast_masked_linear wgrad == dense grad ⊙ B, so the
    grow-score top-k on superset support matches the dense-gradient top-k."""
    from repro.kernels import topkast_masked_linear

    key = jax.random.PRNGKey(3)
    K, N = 32, 24
    x = jax.random.normal(jax.random.fold_in(key, 0), (8, K), jnp.float32)
    w = jax.random.normal(jax.random.fold_in(key, 1), (K, N), jnp.float32)
    A = random_mask(jax.random.fold_in(key, 2), (K, N), 0.8)
    B = topkast_superset_layer(w * A, A, 0.15, jax.random.fold_in(key, 3))
    y = jax.random.normal(jax.random.fold_in(key, 4), (8, N), jnp.float32)

    def disp(w):
        out = topkast_masked_linear(x, w, A, B, block=(128, 16, 16))
        return jnp.sum((out - y) ** 2)

    def dense(w_eff):
        # the DENSE gradient: d loss / d w_eff with no mask in the way —
        # Top-KAST's wgrad is exactly this restricted to B (the B\A slots
        # carry the exploration signal a grad through w*A would zero out)
        return jnp.sum((x @ w_eff - y) ** 2)

    l_disp, g_disp = jax.value_and_grad(disp)(w)
    l_dense, g_dense = jax.value_and_grad(dense)(w * A)
    np.testing.assert_allclose(float(l_disp), float(l_dense), rtol=1e-5)
    gB = np.asarray(g_dense) * np.asarray(B, np.float32)
    np.testing.assert_allclose(np.asarray(g_disp), gB, rtol=1e-5, atol=1e-5)
    # zero outside B: nothing dense ever materializes
    assert np.all(np.asarray(g_disp)[~np.asarray(B, bool)] == 0.0)
    # grow-score parity on the exploration candidates
    cand = np.asarray(B, bool) & ~np.asarray(A, bool)
    k = max(1, cand.sum() // 2)
    assert _topk_set(np.abs(np.asarray(g_disp)), cand, k) == _topk_set(
        np.abs(np.asarray(g_dense)), cand, k
    )


def test_topkast_block_sparse_grad_parity_with_dense():
    """Block-sparse route (pack carries bidx/bcnt): wgrad equals the dense
    gradient restricted to the superset BLOCKS, zero elsewhere."""
    from repro.core.pack import build_pack_state, validate_pack
    from repro.kernels import block_sparse_linear

    key = jax.random.PRNGKey(5)
    K, N, bs = 64, 48, 16
    x = jax.random.normal(jax.random.fold_in(key, 0), (8, K), jnp.float32)
    w = jax.random.normal(jax.random.fold_in(key, 1), (K, N), jnp.float32)
    A = random_block_mask(jax.random.fold_in(key, 2), (K, N), 0.75, (bs, bs))
    B = topkast_superset_layer(
        w * A, A, 0.15, jax.random.fold_in(key, 3), block_shape=(bs, bs)
    )
    masks = {"mlp": {"w": np.asarray(A, bool)}}
    bwd = {"mlp": {"w": np.asarray(B, bool)}}
    pack = build_pack_state(masks, (bs, bs), bwd_masks=bwd)
    validate_pack(pack)
    entry = pack["mlp"]["w"]
    assert entry is not None and "bidx" in entry, "superset CSC missing"

    def disp(w):
        return jnp.sum(
            block_sparse_linear(x, w, pack=entry, block=(128, bs, bs)) ** 2
        )

    def dense(w_eff):
        return jnp.sum((x @ w_eff) ** 2)

    g_disp = jax.grad(disp)(w)
    g_dense = jax.grad(dense)(w * A)
    gB = np.asarray(g_dense) * np.asarray(B, np.float32)
    np.testing.assert_allclose(np.asarray(g_disp), gB, rtol=1e-4, atol=1e-4)
    assert np.all(np.asarray(g_disp)[~np.asarray(B, bool)] == 0.0)


# --------------------------------------------------------------------------
# methods_comparison smoke: topology telemetry must be present and finite.
# --------------------------------------------------------------------------

def test_methods_comparison_smoke_topology_columns():
    from benchmarks.methods_comparison import METHODS as BENCH_METHODS, run

    rows = run(steps=60, delta_t=20)
    assert len(rows) == len(BENCH_METHODS)
    by_name = {r["name"].split("/", 1)[1]: r["derived"] for r in rows}
    assert "topkast" in by_name
    for m, d in by_name.items():
        for col in (
            "jaccard_dist_mean", "nhd_mean", "graph_edit_dist_total",
            "dropped_total", "grown_total", "n_updates",
        ):
            assert col in d, (m, col)
            assert np.isfinite(d[col]), (m, col, d[col])
        if m in ("rigl", "set", "snfs", "topkast"):
            assert d["n_updates"] == 2, (m, d["n_updates"])
            assert d["grown_total"] >= 0 and d["dropped_total"] > 0, m
            # cross-method distance columns vs the rigl reference
            assert "jaccard_dist_vs_rigl" in d and "nhd_vs_rigl" in d, m
            assert 0.0 <= d["jaccard_dist_vs_rigl"] <= 1.0, m
        if m in ("dense", "static", "snip", "small_dense"):
            assert d["n_updates"] == 0, m
